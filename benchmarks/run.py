"""Benchmark entrypoint: one section per paper table/figure + kernel micro
+ roofline summary. Prints ``name,us_per_call,derived`` CSV lines."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import generalization, kernels_micro, parallel_scaling, \
        roofline, solvers
    kernels_micro.run()
    solvers.run()
    parallel_scaling.run()
    generalization.run()
    # roofline summary (only if dry-run artifacts exist)
    try:
        rows = roofline.run()
        print(f"roofline_rows,{len(rows)},see artifacts/bench/roofline.json")
    except Exception as e:  # noqa: BLE001
        print(f"roofline_rows,0,unavailable: {e}")


if __name__ == "__main__":
    main()
