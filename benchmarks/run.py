"""Benchmark entrypoint: one section per paper table/figure + kernel micro
+ streaming re-tiering + cluster serving + roofline summary. Prints
``name,us_per_call,derived`` CSV lines and writes machine-readable
``artifacts/bench/BENCH_<section>.json`` artifacts (one per section, each
stamped with the section's wall-clock ``seconds``) so the perf trajectory —
rows AND runtime — is recorded across PRs.

``--sections cluster,kernels`` runs a subset; ``--scale small`` overrides the
shared dataset scale. With no arguments the behavior (all sections, default
scale) is unchanged.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

SECTIONS = ("kernels", "solvers", "parallel", "generalization", "stream",
            "cluster", "ingest", "frontend", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sections", default="",
                    help="comma-separated subset of: " + ",".join(SECTIONS)
                         + " (default: all)")
    ap.add_argument("--scale", default="",
                    help="dataset scale override (tiny/small/medium); "
                         "default: REPRO_BENCH_SCALE or 'small'")
    args = ap.parse_args()
    if args.scale:
        # before importing benchmark modules: they read the env at import
        os.environ["REPRO_BENCH_SCALE"] = args.scale
        os.environ["REPRO_BENCH_STREAM_SCALE"] = args.scale
        os.environ["REPRO_BENCH_CLUSTER_SCALE"] = args.scale
        os.environ["REPRO_BENCH_INGEST_SCALE"] = args.scale
        os.environ["REPRO_BENCH_FRONTEND_SCALE"] = args.scale
    selected = [s for s in args.sections.split(",") if s] or list(SECTIONS)
    unknown = set(selected) - set(SECTIONS)
    if unknown:
        ap.error(f"unknown sections {sorted(unknown)}; "
                 f"known: {','.join(SECTIONS)}")

    from benchmarks import common

    print("name,us_per_call,derived")
    from benchmarks import cluster, frontend, generalization, ingest, \
        kernels_micro, parallel_scaling, roofline, solvers, streaming

    def run_roofline() -> None:
        # roofline summary (only if dry-run artifacts exist)
        try:
            rows = roofline.run()
            common.emit("roofline_rows", len(rows),
                        "see artifacts/bench/BENCH_roofline.json")
        except Exception as e:  # noqa: BLE001
            common.emit("roofline_rows", 0, f"unavailable: {e}")

    runners = {
        "kernels": (kernels_micro.run, {}),
        "solvers": (solvers.run, {}),
        "parallel": (parallel_scaling.run, {}),
        "generalization": (generalization.run, {}),
        "stream": (streaming.run, {"scale": streaming.STREAM_SCALE}),
        "cluster": (cluster.run, {"scale": cluster.CLUSTER_SCALE}),
        "ingest": (ingest.run, {"scale": ingest.INGEST_SCALE}),
        "frontend": (frontend.run, {"scale": frontend.FRONTEND_SCALE}),
        "roofline": (run_roofline, {}),
    }
    try:
        for name in selected:
            fn, kw = runners[name]
            common.begin_section(name, **kw)
            fn()
    finally:
        # a failing section must not lose the sections already recorded
        for path in common.write_json():
            print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
