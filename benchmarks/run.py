"""Benchmark entrypoint: one section per paper table/figure + kernel micro
+ streaming re-tiering + roofline summary. Prints ``name,us_per_call,derived``
CSV lines and writes machine-readable ``artifacts/bench/BENCH_<section>.json``
artifacts (one per section) so the perf trajectory is recorded across PRs."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main() -> None:
    from benchmarks import common

    print("name,us_per_call,derived")
    from benchmarks import generalization, kernels_micro, parallel_scaling, \
        roofline, solvers, streaming
    try:
        common.begin_section("kernels")
        kernels_micro.run()
        common.begin_section("solvers")
        solvers.run()
        common.begin_section("parallel")
        parallel_scaling.run()
        common.begin_section("generalization")
        generalization.run()
        common.begin_section("stream", scale=streaming.STREAM_SCALE)
        streaming.run()
        # roofline summary (only if dry-run artifacts exist)
        common.begin_section("roofline")
        try:
            rows = roofline.run()
            common.emit("roofline_rows", len(rows),
                        "see artifacts/bench/roofline.json")
        except Exception as e:  # noqa: BLE001
            common.emit("roofline_rows", 0, f"unavailable: {e}")
    finally:
        # a failing section must not lose the sections already recorded
        for path in common.write_json():
            print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
