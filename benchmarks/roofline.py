"""Roofline report (deliverable g): reads artifacts/dryrun/*.json, derives
the three terms per (arch x shape x mesh), the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs usefulness ratio, and emits the EXPERIMENTS.md table.

Terms (per spec; cost_analysis on the SPMD-partitioned module is already
per-device, so no extra ÷chips on flops/bytes; collective bytes are summed
over the module and divided by chips x link bandwidth):
  compute_s    = HLO_FLOPs_per_device / 197e12
  memory_s     = HLO_bytes_per_device / 819e9
  collective_s = collective_bytes_per_device / 50e9
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# single source for the peak numbers: the live kernel profiler shares them
from repro.obs.profile import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: E402

# tokens per step for MODEL_FLOPS = 6·N_active·D
LM_TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
             "decode_32k": 128, "long_500k": 1}


def model_flops(arch: str, shape: str) -> float | None:
    from repro.configs import registry as R
    spec = R.all_archs().get(arch)
    if spec is None or spec.family != "lm":
        return None
    cfg = spec.config_for(shape)
    n = cfg.active_param_count()
    d = LM_TOKENS[shape]
    mult = 6 if shape == "train_4k" else 2   # fwd-only for serving shapes
    return float(mult) * n * d


def load_records(art_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*", "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


TIERING_SHAPES = {
    "solve_dense_m": (131072, 2 ** 20, 2 ** 23, None),
    "solve_dense_l": (2 ** 20, 2 ** 22, 2 ** 26, None),
    "solve_optpes_l": (2 ** 20, 2 ** 22, 2 ** 26, 4096),
    "solve_sparse_xl": (2 ** 20, 2 ** 22, 2 ** 28, 4096),
}


def _tiering_analytic(shape: str, n_chips: int) -> tuple[float, float] | None:
    """(flops, bytes) per chip — analytic, because the XLA bit-matvec path
    scans W-chunks and cost_analysis counts loop bodies once. Formulas:
    dense round: 2·C·Nq MXU MACs + 2·C·Wd popcount ops; reads A_q + A_d.
    optpes round: same per gathered row (K of them) + bound-array traffic.
    sparse round: 2·C·M gather+test ops; reads id lists + gathered words."""
    if shape == "serve_route":
        b, v, nd, k, l = 4096, 2 ** 17, 2 ** 22, 2 ** 16, 8
        wv, wd = v // 32, nd // 32
        flops = b * k * wv * 2 + b * l * wd
        bytes_ = 4.0 * (b * l * wd + k * wv + b * wd)
        return flops / n_chips, bytes_ / n_chips
    if shape not in TIERING_SHAPES:
        return None
    c, nq, nd, kk = TIERING_SHAPES[shape]
    wq, wd = nq // 32, nd // 32
    if shape == "solve_sparse_xl":
        m = 4096
        flops = 2.0 * c * m + 2.0 * c * nq            # g gather-test + f matvec
        bytes_ = 4.0 * (2 * c * m + c * wq) + 4.0 * nq
        return flops / n_chips, bytes_ / n_chips
    rows = kk if shape == "solve_optpes_l" else c    # optpes: K gathered rows
    flops = 2.0 * rows * nq + 2.0 * rows * wd
    bytes_ = 4.0 * rows * (wq + wd) + 4.0 * nq + \
        (6.0 * 4 * c if shape == "solve_optpes_l" else 4.0 * c)
    return flops / n_chips, bytes_ / n_chips


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    n = rec["n_chips"]
    flops = max(rec.get("flops", 0.0), 0.0)
    hbm = max(rec.get("bytes_accessed", 0.0), 0.0)
    coll = rec["collectives"]["total_bytes"] / n   # module total -> per chip
    src = "hlo"
    probe = rec.get("probe")
    if probe:                        # scan-corrected LM costs (see dryrun)
        flops, hbm = probe["flops"], probe["bytes"]
        coll = probe["coll"] / n
        src = "probe"
    elif rec["arch"] == "tiering-scsk":
        ana = _tiering_analytic(rec["shape"], n)
        if ana:
            flops, hbm = ana
            src = "analytic"
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec["arch"], rec["shape"])
    useful = (mf / (flops * n)) if (mf and flops > 0) else None
    roof_frac = (mf / n / PEAK_FLOPS) / bound if (mf and bound > 0) else None
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **{k: f"{v:.3e}" for k, v in terms.items()},
        "dominant": dom.replace("_s", ""),
        "model_flops_ratio": f"{useful:.3f}" if useful else "-",
        "roofline_frac": f"{roof_frac:.3f}" if roof_frac else "-",
        "mem_per_dev_GB": f"{rec['memory_analysis'].get('total_per_device_bytes', 0) / 2**30:.1f}",
        "cost_src": src,
    }


def run(art_dir: str = "artifacts/dryrun",
        out_path: str = "artifacts/bench/BENCH_roofline.json") -> list[dict]:
    rows = [a for a in (analyze(r) for r in load_records(art_dir)) if a]
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    if rows:
        cols = list(rows[0].keys())
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r[c]) for c in cols))
    else:
        print("roofline: no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun` first")
    return rows


if __name__ == "__main__":
    run()
