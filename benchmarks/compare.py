"""Perf/behaviour regression gate over BENCH_*.json and obs JSONL trees.

    python -m benchmarks.compare --baseline benchmarks/baselines/tiny \
        --tolerance-file benchmarks/tolerances.json

Loads every `BENCH_<section>.json` (and any `<run>.jsonl` telemetry
snapshot file) under two directories, flattens each into metric keys

    SECTION/ROW_NAME:metric      e.g. cluster/cluster_shards2:p95
    obs.RUN:metric_name          (JSONL trees: final-snapshot totals)

and diffs baseline vs candidate under per-metric tolerance rules. Rules
live in a JSON file — a `default` plus an ordered `rules` list of
`{"pattern": fnmatch, ...}` entries, FIRST match wins:

    {"pattern": "*:us_per_call", "skip": true}          never compared
    {"pattern": "*:p95*", "rel": 0.5, "direction": "high_bad"}
    {"pattern": "*:cov*", "rel": 0.1, "abs": 0.02, "direction": "low_bad"}

`direction` says which way is a regression: "high_bad" (latency-like),
"low_bad" (coverage-like), or "both". A value is regressed when it moves
past `base ± (rel * |base| + abs)` in a bad direction. Wall-clock numbers
must be skipped by rule — only the seeded, simulated metrics are stable
across machines, which is what makes a checked-in baseline meaningful.

Metrics are compared within sections present in BOTH trees; a metric
present in the baseline but gone from the candidate is itself a failure.
Whole-section asymmetries are never silent: a section only the candidate
has (a NEW bench the baseline predates) is reported as
`skipped-new-section` — a visible notice to regenerate the baseline — and
a section only the baseline has means the candidate DROPPED it, which
fails the gate (`SECTION-MISSING`) exactly like a disappeared metric.
Exit status: 0 clean, 1 on any regression or disappearance — CI gates on
it, and `launch.obs --diff` reuses `run_gate` for telemetry trees.
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT_TOLERANCE = {"rel": 0.25, "abs": 1e-9, "direction": "both"}


# -- tree loading --------------------------------------------------------------

def _num(text: str):
    """Numeric value of a derived-string token; booleans count as 0/1 so a
    parity/consistency flip is a comparable (and gateable) metric."""
    t = text.strip().rstrip("%")
    if t in ("True", "true"):
        return 1.0
    if t in ("False", "false"):
        return 0.0
    try:
        return float(t)
    except ValueError:
        return None


def parse_derived(derived: str) -> dict[str, float]:
    """The `k=v;k=v` payload of a BENCH row, numeric entries only."""
    out = {}
    for part in derived.split(";"):
        k, sep, v = part.partition("=")
        if not sep:
            continue
        val = _num(v)
        if val is not None:
            out[k.strip()] = val
    return out


def _flatten_data(prefix: str, obj, out: dict[str, float]) -> None:
    """Scalar numeric leaves of a row's `data` payload; lists (bucket
    arrays etc.) are deliberately not exploded."""
    if isinstance(obj, dict):
        for k, v in sorted(obj.items()):
            _flatten_data(f"{prefix}.{k}", v, out)
    elif isinstance(obj, bool):
        out[prefix] = float(obj)
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)


def _load_bench(path: str, section: str, metrics: dict[str, float]) -> None:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "rows" not in doc:
        return          # e.g. BENCH_roofline.json is a bare row list
    if "seconds" in doc:
        metrics[f"{section}:seconds"] = float(doc["seconds"])
    for row in doc["rows"]:
        key = f"{section}/{row['name']}"
        if "us_per_call" in row:
            metrics[f"{key}:us_per_call"] = float(row["us_per_call"])
        for k, v in parse_derived(row.get("derived", "")).items():
            metrics[f"{key}:{k}"] = v
        if "data" in row:
            flat: dict[str, float] = {}
            _flatten_data("data", row["data"], flat)
            for k, v in flat.items():
                metrics[f"{key}:{k}"] = v


def _load_jsonl(path: str, section: str, metrics: dict[str, float]) -> None:
    """Final-snapshot registry totals of one obs run: counters sum their
    series, gauges average theirs, histograms contribute count and sum."""
    from repro.obs import read_jsonl
    snaps = read_jsonl(path)
    if not snaps:
        return
    metrics[f"{section}:n_snapshots"] = float(len(snaps))
    for name, inst in sorted(snaps[-1].get("metrics", {}).items()):
        series = inst.get("series", [])
        kind = inst.get("type")
        if not series:
            continue
        if kind == "counter":
            metrics[f"{section}:{name}"] = float(
                sum(s["value"] for s in series))
        elif kind == "gauge":
            metrics[f"{section}:{name}"] = float(
                sum(s["value"] for s in series) / len(series))
        elif kind == "histogram":
            metrics[f"{section}:{name}.count"] = float(
                sum(s["value"]["count"] for s in series))
            metrics[f"{section}:{name}.sum"] = float(
                sum(s["value"]["sum"] for s in series))


def load_tree(root: str) -> dict[str, dict[str, float]]:
    """{section: {metric_key: value}} over one artifact directory."""
    sections: dict[str, dict[str, float]] = {}
    if not os.path.isdir(root):
        return sections
    for entry in sorted(os.listdir(root)):
        path = os.path.join(root, entry)
        if not os.path.isfile(path):
            continue
        if entry.startswith("BENCH_") and entry.endswith(".json"):
            section = entry[len("BENCH_"):-len(".json")]
            metrics: dict[str, float] = {}
            _load_bench(path, section, metrics)
            if metrics:
                sections[section] = metrics
        elif entry.endswith(".jsonl"):
            section = f"obs.{entry[:-len('.jsonl')]}"
            metrics = {}
            _load_jsonl(path, section, metrics)
            if metrics:
                sections[section] = metrics
    return sections


# -- tolerance rules -----------------------------------------------------------

def load_tolerances(path: str | None) -> tuple[dict, list[dict]]:
    if not path:
        return dict(DEFAULT_TOLERANCE), []
    with open(path) as f:
        doc = json.load(f)
    default = {**DEFAULT_TOLERANCE, **doc.get("default", {})}
    rules = doc.get("rules", [])
    for r in rules:
        if "pattern" not in r:
            raise ValueError(f"tolerance rule without a pattern: {r!r}")
    return default, rules


def rule_for(key: str, default: dict, rules: list[dict]) -> dict:
    for r in rules:
        if fnmatch.fnmatch(key, r["pattern"]):
            return {**default, **r}
    return default


# -- the diff ------------------------------------------------------------------

def compare_metric(key: str, base: float, new: float,
                   rule: dict) -> tuple[str, str]:
    """(status, note). Status: ok | skipped | REGRESSED."""
    if rule.get("skip"):
        return "skipped", rule.get("reason", "")
    tol = rule["rel"] * abs(base) + rule["abs"]
    delta = new - base
    direction = rule.get("direction", "both")
    bad = (delta > tol and direction in ("high_bad", "both")) or \
          (delta < -tol and direction in ("low_bad", "both"))
    note = f"Δ={delta:+.6g} tol=±{tol:.6g} ({direction})"
    return ("REGRESSED" if bad else "ok"), note


def diff_trees(base_tree: dict, new_tree: dict, default: dict,
               rules: list[dict]) -> list[dict]:
    """One finding per metric of every section common to both trees."""
    findings = []
    common = sorted(set(base_tree) & set(new_tree))
    for section in sorted(set(base_tree) | set(new_tree)):
        if section in common:
            continue
        if section in base_tree:
            # the candidate run dropped a whole section the baseline gates
            # — exactly the failure a freshly added section must not mask
            findings.append({
                "key": section, "status": "SECTION-MISSING",
                "base": float(len(base_tree[section])), "new": None,
                "note": "candidate dropped this whole section"})
        else:
            findings.append({
                "key": section, "status": "skipped-new-section",
                "base": None, "new": float(len(new_tree[section])),
                "note": "baseline predates this section — regenerate the "
                        "checked-in baseline to gate it"})
    for section in common:
        b, n = base_tree[section], new_tree[section]
        for key in sorted(set(b) | set(n)):
            rule = rule_for(key, default, rules)
            if key not in n:
                status = "skipped" if rule.get("skip") else "MISSING"
                findings.append({"key": key, "base": b[key], "new": None,
                                 "status": status,
                                 "note": "metric disappeared"})
            elif key not in b:
                findings.append({"key": key, "base": None, "new": n[key],
                                 "status": "new", "note": ""})
            else:
                status, note = compare_metric(key, b[key], n[key], rule)
                if status == "REGRESSED" and key.endswith(":roofline_frac"):
                    # a profile row regressing is a kernel-bandwidth story:
                    # surface the sibling achieved-GB/s delta so the CI log
                    # is diagnosable without rerunning the bench locally
                    gk = key[: -len("roofline_frac")] + "achieved_gbps"
                    if gk in b and gk in n:
                        note += (f"; achieved_gbps {b[gk]:.3f}->{n[gk]:.3f}"
                                 f" ({n[gk] - b[gk]:+.3f} GB/s)")
                findings.append({"key": key, "base": b[key], "new": n[key],
                                 "status": status, "note": note})
    return findings


def _fmt(v) -> str:
    if v is None:
        return "-"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.6g}"


def print_table(findings: list[dict], *, verbose: bool = False) -> None:
    shown = [f for f in findings if verbose
             or f["status"] not in ("ok", "skipped")]
    counts: dict[str, int] = {}
    for f in findings:
        counts[f["status"]] = counts.get(f["status"], 0) + 1
    if shown:
        w = max(len(f["key"]) for f in shown)
        print(f"{'metric':<{w}}  {'baseline':>14}  {'candidate':>14}  "
              f"status")
        for f in shown:
            print(f"{f['key']:<{w}}  {_fmt(f['base']):>14}  "
                  f"{_fmt(f['new']):>14}  {f['status']}"
                  + (f"  {f['note']}" if f["note"] else ""))
    print("[compare] " + "  ".join(
        f"{k}={counts[k]}" for k in sorted(counts)))


def gate(findings: list[dict]) -> int:
    """Exit status for a findings list: 1 on regression/disappearance —
    of a metric (MISSING) or of an entire section (SECTION-MISSING)."""
    return int(any(f["status"] in ("REGRESSED", "MISSING",
                                   "SECTION-MISSING")
                   for f in findings))


def run_gate(baseline: str, candidate: str, *,
             tolerance_file: str | None = None,
             verbose: bool = False) -> int:
    base_tree = load_tree(baseline)
    new_tree = load_tree(candidate)
    if not base_tree:
        print(f"[compare] no BENCH_*.json / *.jsonl under baseline "
              f"{baseline!r}")
        return 1
    if not new_tree:
        print(f"[compare] no BENCH_*.json / *.jsonl under candidate "
              f"{candidate!r}")
        return 1
    common = set(base_tree) & set(new_tree)
    if not common:
        print(f"[compare] no common sections between {baseline!r} "
              f"({sorted(base_tree)}) and {candidate!r} "
              f"({sorted(new_tree)})")
        return 1
    default, rules = load_tolerances(tolerance_file)
    findings = diff_trees(base_tree, new_tree, default, rules)
    print(f"[compare] {baseline} vs {candidate}: "
          f"{len(common)} common section(s) {sorted(common)}")
    print_table(findings, verbose=verbose)
    code = gate(findings)
    print(f"[compare] {'REGRESSION — failing the gate' if code else 'ok'}")
    return code


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="baseline artifact directory (checked-in)")
    ap.add_argument("--new", default="artifacts/bench", dest="candidate",
                    help="candidate artifact directory (this run's output)")
    ap.add_argument("--tolerance-file", default="",
                    help="per-metric tolerance rules JSON")
    ap.add_argument("--verbose", action="store_true",
                    help="print ok/skipped rows too")
    args = ap.parse_args()
    raise SystemExit(run_gate(args.baseline, args.candidate,
                              tolerance_file=args.tolerance_file or None,
                              verbose=args.verbose))


if __name__ == "__main__":
    main()
