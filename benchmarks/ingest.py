"""Ingest section: live corpus growth under load, measured end to end.

Question families (seeded, tiny scale by default so the section stays
CI-sized; REPRO_BENCH_INGEST_SCALE overrides):

  * append scaling: what does a word-aligned block append cost
    (`append_docs` + `with_doc_block`) as the arrival batch grows, and how
    much of the appended block is hole padding?
  * admission A/B: on identical arrivals and EQUAL budget trajectories
    (both arms track corpus growth, refits disabled so attribution is
    clean), does secretary-style optional admission beat mandatory-only
    growth on back-half windowed coverage?
  * rolling vs stop-the-world: the same sustained ingest once with
    replica-by-replica corpus rollouts and once with `immediate` swaps —
    both verified against the versioned single-tier oracle — plus the
    loadgen view: simulated p95/p99 when a corpus swap lands mid-traffic
    as a rolling outage vs one fleet-wide stop.
  * sustained ingest: the full serve → ingest → refit loop on a sharded
    fleet with per-window verification — the bench's outage count is
    `failed_windows` and the acceptance bar is zero.

Every subsection records its own wall-clock `seconds` next to its numbers
(PR 4 convention), on top of the section-level seconds `common` stamps.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import emit

INGEST_SCALE = os.environ.get("REPRO_BENCH_INGEST_SCALE", "tiny")
N_WINDOWS = int(os.environ.get("REPRO_BENCH_INGEST_WINDOWS", "10"))
APPEND_BATCHES = (16, 64, 256)


def _fresh_pipe(data, n_shards: int = 2):
    from repro import api
    return api.TieringPipeline.from_data(data).solve(
        "greedy", budget_frac=0.5, budget_split="traffic",
        n_shards=n_shards)


def _ingest_kw(**over):
    kw = dict(scenario="rotate", n_windows=N_WINDOWS,
              queries_per_window=256, seed=0, arrivals_per_window=64.0,
              correlation=0.6, budget_policy="track_corpus")
    kw.update(over)
    return kw


def append_scaling(data) -> dict:
    """Block-append + device-problem growth wall time per arrival batch."""
    from repro import ingest
    from repro.data import incidence

    out = {}
    t_sub = time.perf_counter()
    feed = ingest.DocumentFeed(log=data.log, vocab_size=data.corpus.vocab_size,
                               rate=float(max(APPEND_BATCHES)), seed=0)
    docs = list(feed.window(0))
    for n in APPEND_BATCHES:
        pipe = _fresh_pipe(data)
        batch = (docs * (n // max(len(docs), 1) + 1))[:n]
        t0 = time.perf_counter()
        delta = incidence.append_docs(pipe.data, batch)
        problem = pipe.problem.with_doc_block(delta.clause_cols, delta.n_docs)
        dt = time.perf_counter() - t0
        out[n] = {
            "docs_per_s": n / max(dt, 1e-9),
            "words_appended": delta.word_hi - delta.word_lo,
            "holes": delta.n_holes,
            "n_docs_after": problem.n_docs,
            "seconds": dt,
        }
        emit(f"ingest_append{n}", 1e6 * dt / n,
             f"docs_per_s={out[n]['docs_per_s']:.0f};"
             f"words={out[n]['words_appended']};holes={delta.n_holes}")
    out["seconds"] = time.perf_counter() - t_sub
    return out


def admission_ab(data) -> dict:
    """Optional admission on vs off at equal budget, identical arrivals.

    Refits are disabled on BOTH arms so the only difference is the policy;
    both arms track corpus growth, so budget trajectories are identical."""
    from repro import ingest

    t_sub = time.perf_counter()
    arms = {}
    for arm in ("off", "on"):
        t0 = time.perf_counter()
        rep = ingest.run_ingest(
            _fresh_pipe(data), admission=(arm == "on"), enable_refit=False,
            **_ingest_kw())
        arms[arm] = {
            "mean_cov": rep.mean_coverage, "late_cov": rep.late_coverage,
            "n_ingested": rep.n_ingested, "n_admitted": rep.n_admitted,
            "seconds": time.perf_counter() - t0,
        }
        emit(f"ingest_admission_{arm}", 0.0,
             f"mean_cov={rep.mean_coverage:.4f};"
             f"late_cov={rep.late_coverage:.4f};"
             f"ingested={rep.n_ingested};admitted={rep.n_admitted}")
    delta = arms["on"]["late_cov"] - arms["off"]["late_cov"]
    arms["late_cov_delta"] = delta
    arms["seconds"] = time.perf_counter() - t_sub
    emit("ingest_admission_delta", 0.0,
         f"late_cov_delta={delta:+.5f};"
         f"admitted={arms['on']['n_admitted']}")
    return arms


def rolling_vs_stw(data) -> dict:
    """Same sustained ingest under both rollout disciplines, verified; then
    the loadgen tail-latency view of a swap landing mid-traffic."""
    from repro import cluster, ingest

    t_sub = time.perf_counter()
    out = {}
    for mode in ("rolling", "stw"):
        t0 = time.perf_counter()
        pipe = _fresh_pipe(data)
        fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2, t2_replicas=2)
        rep = ingest.run_ingest(pipe, engine=fleet, rollout=mode,
                                verify=True, **_ingest_kw())
        out[mode] = {
            "mean_cov": rep.mean_coverage,
            "failed_windows": rep.failed_windows(),
            "final_version": rep.windows[-1].corpus_version,
            "consistent": fleet.consistency_ok(),
            "ingest_s_per_window": float(sum(
                w.ingest_seconds for w in rep.windows)) / len(rep.windows),
            "seconds": time.perf_counter() - t0,
        }
        emit(f"ingest_rollout_{mode}",
             1e6 * out[mode]["ingest_s_per_window"],
             f"cov={rep.mean_coverage:.4f};"
             f"failed={rep.failed_windows()};"
             f"v={out[mode]['final_version']};"
             f"consistent={out[mode]['consistent']}")

    # loadgen view: one corpus swap mid-stream, rolling outages vs one
    # fleet-wide stop, identical arrivals + ingest write stream on both arms
    pipe = _fresh_pipe(data)
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2, t2_replicas=2)
    sample = data.log.queries[:min(2048, data.log.n_queries)]
    plan = cluster.ClusterPlan.of_cluster(fleet)
    elig = fleet.classify(sample)
    lat = {}
    for mode in ("rolling", "stw"):
        rep = cluster.run_loadgen(plan, elig, n_queries=4000, seed=0,
                                  rollout_at_s=0.05, swap_ms=5.0,
                                  rollout_mode=mode, ingest_qps=200.0)
        lat[mode] = {
            "p95_ms": rep.p95_ms, "p99_ms": rep.p99_ms,
            "max_ms": rep.max_ms,
            "stw_delayed_queries": rep.stw_delayed_queries,
            "n_ingest_events": rep.n_ingest_events,
        }
        emit(f"ingest_loadgen_{mode}", 0.0,
             f"p95={rep.p95_ms:.4f};p99={rep.p99_ms:.4f};"
             f"max={rep.max_ms:.4f};delayed={rep.stw_delayed_queries};"
             f"ingest_events={rep.n_ingest_events}")
    out["loadgen"] = lat
    out["seconds"] = time.perf_counter() - t_sub
    return out


def sustained_ingest(data) -> dict:
    """The full loop — serve, ingest, refit on drift — on a rolling fleet
    with per-window versioned parity checks. Zero failed windows is the
    acceptance bar."""
    from repro import ingest

    t_sub = time.perf_counter()
    pipe = _fresh_pipe(data)
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2, t2_replicas=2)
    rep = ingest.run_ingest(pipe, engine=fleet, rollout="rolling",
                            verify=True, **_ingest_kw())
    out = {
        "windows": len(rep.windows),
        "mean_cov": rep.mean_coverage,
        "n_ingested": rep.n_ingested,
        "n_admitted": rep.n_admitted,
        "n_refits": rep.n_refits,
        "failed_windows": rep.failed_windows(),
        "final_version": rep.windows[-1].corpus_version,
        "final_docs": rep.windows[-1].n_docs,
        "consistent": fleet.consistency_ok(),
        "seconds": time.perf_counter() - t_sub,
    }
    emit("ingest_sustained", 1e6 * out["seconds"] / len(rep.windows),
         f"cov={rep.mean_coverage:.4f};ingested={rep.n_ingested};"
         f"admitted={rep.n_admitted};refits={rep.n_refits};"
         f"failed={out['failed_windows']};v={out['final_version']};"
         f"consistent={out['consistent']}")
    return out


def run() -> dict:
    from repro.data import incidence, synthetic

    corpus, log = synthetic.make_tiering_dataset(0, INGEST_SCALE)
    data = incidence.build_tiering_data(corpus, log, min_support=1e-3)

    results: dict[str, dict] = {}
    results["append_scaling"] = append_scaling(data)
    results["admission_ab"] = admission_ab(data)
    results["rolling_vs_stw"] = rolling_vs_stw(data)
    results["sustained_ingest"] = sustained_ingest(data)
    return results


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    from benchmarks import common
    common.begin_section("ingest", scale=INGEST_SCALE)
    run()
    for path in common.write_json():
        print(f"# wrote {path}", file=sys.stderr)
