"""Regenerate the auto-built tables in EXPERIMENTS.md from artifacts/."""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def dryrun_table() -> str:
    rows = []
    for path in sorted(glob.glob("artifacts/dryrun/*/*.json")):
        with open(path) as f:
            r = json.load(f)
        mesh = r["mesh"]
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], mesh, "SKIP (spec)", "-", "-",
                         "-", "-"))
            continue
        m = r["memory_analysis"]
        rows.append((
            r["arch"], r["shape"], mesh, r["kind"],
            f"{m.get('argument_size_in_bytes', 0) / 2**30:.2f}",
            f"{m.get('temp_size_in_bytes', 0) / 2**30:.2f}",
            f"{r['collectives']['total_bytes'] / 2**30:.2f}",
            f"{r.get('compile_s', 0):.1f}",
        ))
    hdr = ("| arch | shape | mesh | kind | args GiB/dev | temp GiB/dev "
           "| collective GiB (module) | compile s |\n"
           "|---|---|---|---|---|---|---|---|\n")
    return hdr + "\n".join("| " + " | ".join(map(str, r)) + " |"
                           for r in rows)


def roofline_table() -> str:
    with open("artifacts/bench/BENCH_roofline.json") as f:
        rows = json.load(f)
    cols = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
            "dominant", "model_flops_ratio", "roofline_frac", "cost_src"]
    hdr = "| " + " | ".join(cols) + " |\n" + \
        "|" + "---|" * len(cols) + "\n"
    return hdr + "\n".join(
        "| " + " | ".join(str(r[c]) for c in cols) + " |" for r in rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "both"
    if which in ("dryrun", "both"):
        print(dryrun_table())
    if which in ("roofline", "both"):
        print(roofline_table())
