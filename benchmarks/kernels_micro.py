"""Kernel microbenchmarks: XLA path wall-time (CPU host) + the VMEM/HBM
traffic model for the TPU kernels (the quantity the Pallas tiling targets),
plus the per-kernel achieved-vs-roofline profile (`repro.obs.profile`) on
the host path AND the forced-4-device mesh path (fresh subprocess: XLA
fixes the device count at init)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(out_dir: str = "artifacts/bench") -> None:
    from repro.kernels import autotune, ops

    # Tune (or reuse) the tile/strategy cache first so every timed dispatch
    # below — and the profile rows the compare gate watches — runs the
    # measured-best variant, not the hardcoded defaults. Values are
    # machine-local (gitignored artifacts/); only the entry count is emitted.
    tiles_path, n_tiles = autotune.ensure_cache()
    emit("autotune_cache_entries", float(n_tiles), f"path={tiles_path}")

    rng = np.random.default_rng(0)

    for c, w in ((4096, 1024), (16384, 2048)):
        a = jnp.asarray(rng.integers(0, 2 ** 32, (c, w), dtype=np.uint32))
        x = jnp.asarray(rng.standard_normal((w * 32, 1)), jnp.float32)
        mask = jnp.asarray(rng.integers(0, 2 ** 32, w, dtype=np.uint32))
        dt = _time(lambda: ops.bit_matvec(a, x, backend="xla"))
        hbm_gb = (c * w * 4 + w * 32 * 4 + c * 4) / 1e9
        emit(f"kernel_bit_matvec_c{c}_w{w}", dt * 1e6,
             f"hbm_GB={hbm_gb:.3f};tpu_mem_bound_us={hbm_gb / 819 * 1e6:.1f}")
        dt = _time(lambda: ops.coverage_gain(a, mask, backend="xla"))
        emit(f"kernel_coverage_gain_c{c}_w{w}", dt * 1e6,
             f"hbm_GB={hbm_gb:.3f}")

    ids = jnp.asarray(rng.integers(0, 2 ** 20, (4096, 512)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2 ** 32, 2 ** 15, dtype=np.uint32))
    dt = _time(lambda: ops.sparse_gain(ids, mask, backend="xla"))
    emit("kernel_sparse_gain_c4096_m512", dt * 1e6,
         f"gather_GB={4096 * 512 * 4 / 1e9:.3f}")

    profile()
    profile_mesh()
    obs_overhead()


def _profile_body(reps: int = 5) -> list[dict]:
    """Drive clause_match / bit_matvec / partition_gain under the process
    profiler's measuring scope; returns `PROFILER.summary()` rows. Shapes
    are fixed, so words_scanned/bytes_moved are machine-independent (the
    regression gate compares them tightly); sync timing is wall-clock."""
    from repro import obs
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    c, w = 4096, 512
    a = jnp.asarray(rng.integers(0, 2 ** 32, (c, w), dtype=np.uint32))
    x = jnp.asarray(rng.standard_normal((w * 32, 1)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2 ** 32, w, dtype=np.uint32))
    q = jnp.asarray(rng.integers(0, 2 ** 32, (512, 64), dtype=np.uint32))
    cl = jnp.asarray(rng.integers(0, 2 ** 32, (128, 64), dtype=np.uint32))
    bounds = tuple(int(b) for b in np.linspace(0, w, 5).astype(int))

    prev = obs.set_enabled(True)
    try:
        # warm outside the measuring scope so compile time is never counted;
        # scoped() isolates this subsection's aggregation from anything an
        # earlier subsection (or the warmup itself) accrued in this process
        jax.block_until_ready(ops.clause_match(q, cl))
        jax.block_until_ready(ops.bit_matvec(a, x))
        jax.block_until_ready(ops.partition_gain(a, mask, bounds))
        with obs.PROFILER.scoped(), obs.PROFILER.measuring():
            for _ in range(reps):
                ops.clause_match(q, cl)
                ops.bit_matvec(a, x)
                ops.partition_gain(a, mask, bounds)
            return obs.PROFILER.summary()
    finally:
        obs.set_enabled(prev)


def profile() -> list[dict]:
    """Host-path roofline profile rows -> BENCH_kernels.json."""
    rows = _profile_body()
    for r in rows:
        emit(f"profile_host_{r['op']}", r["us_per_call"],
             f"path={r['path']};words_scanned={r['words_scanned']};"
             f"bytes_moved={r['bytes_moved']};"
             f"achieved_gbps={r['achieved_gbps']};"
             f"roofline_frac={r['roofline_frac']}", data=r)
    return rows


_MESH_PROFILE_PROBE = r"""
import json
import repro.distributed as D
from benchmarks import kernels_micro

with D.use_mesh(D.shard_mesh()):
    rows = kernels_micro._profile_body()
print(json.dumps(rows))
"""


def profile_mesh(ndev: int = 4) -> list[dict]:
    """The same profile inside a forced-`ndev`-device mesh subprocess —
    partition_gain resolves to the owner-local shard_map fusion there, so
    its rows land under path="mesh"."""
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               JAX_PLATFORMS="cpu", REPRO_OBS="1",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(root, "src"), root]
                   + ([os.environ["PYTHONPATH"]]
                      if os.environ.get("PYTHONPATH") else [])))
    proc = subprocess.run([sys.executable, "-c", _MESH_PROFILE_PROBE],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    if proc.returncode != 0:
        emit("profile_mesh_error", 0.0,
             f"exit={proc.returncode}", data={"stderr": proc.stderr[-500:]})
        return []
    rows = json.loads(proc.stdout.strip().splitlines()[-1])
    for r in rows:
        emit(f"profile_mesh_{r['op']}", r["us_per_call"],
             f"path={r['path']};words_scanned={r['words_scanned']};"
             f"bytes_moved={r['bytes_moved']};"
             f"achieved_gbps={r['achieved_gbps']};"
             f"roofline_frac={r['roofline_frac']}", data=r)
    return rows


def obs_overhead(iters: int = 20) -> dict:
    """Disabled-telemetry tax on the serve hot path: `match_batch` bare vs
    wrapped in a (disabled) span + counter inc, exactly as `serve/engine.py`
    wraps it. The overhead must stay in the noise — the PR pins <5%."""
    from repro import obs
    from repro.serve import matching

    rng = np.random.default_rng(0)
    postings = jnp.asarray(
        rng.integers(0, 2 ** 32, (2048, 256), dtype=np.uint32))
    toks = jnp.asarray(rng.integers(0, 2048 * 32, (256, 8)), jnp.int32)
    ctr = obs.counter("bench_obs_overhead_total")

    def plain():
        return matching.match_batch(postings, toks)

    def wrapped():
        with obs.span("t1_match", n=int(toks.shape[0])) as sp:
            out = sp.sync(matching.match_batch(postings, toks))
        ctr.inc(int(toks.shape[0]))
        return out

    prev = obs.set_enabled(False)
    try:
        plain()                                   # compile once, shared
        t_plain = min(_time(plain, iters=iters) for _ in range(3))
        t_obs = min(_time(wrapped, iters=iters) for _ in range(3))
    finally:
        obs.set_enabled(prev)
    over = t_obs / t_plain - 1.0
    emit("kernel_obs_overhead_disabled", t_obs * 1e6,
         f"plain_us={t_plain * 1e6:.2f};overhead={over * 100:+.2f}%")
    return {"plain_us": t_plain * 1e6, "obs_us": t_obs * 1e6,
            "overhead": over}


if __name__ == "__main__":
    run()
