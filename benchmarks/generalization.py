"""Paper Fig. 5: train-fit vs test-generalization per tiering method.

clause (ours, per λ) vs flow-sgd (per λ) vs popularity vs flow-max.
The paper's claim is about points in the (train, test) plane: at *matched
training fit*, clause sits above flow-sgd on future traffic, because flow
can only memorize whole queries while clauses cover unseen queries that
contain a known sub-query. The dataset here is built heavy-tailed (novel
test mass ~15–30%) to reproduce the paper's regime ("a large fraction of
queries in the incoming traffic are novel ones", §1/§2.3).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit


def _heavy_tail_data():
    from repro.data import synthetic
    rng = np.random.default_rng(7)
    corpus = synthetic.make_corpus(rng, vocab_size=800, n_docs=4000,
                                   doc_len_mean=8.0)
    log = synthetic.make_query_log(rng, corpus, pool_size=40000,
                                   n_train=60000, n_test=20000,
                                   zipf_a=0.8)
    return corpus, log


def run(out_dir: str = "artifacts/bench") -> dict:
    from repro import api

    corpus, log = _heavy_tail_data()
    budget = corpus.n_docs // 2
    novel = log.novel_test_mass()
    emit("fig5_novel_test_mass", 0.0, f"{novel:.4f}")
    points = []

    # clause method across regularization λ — through the pipeline facade
    for lam in (1e-3, 3e-4, 1e-4, 3e-5):
        pipe = (api.TieringPipeline.from_corpus(corpus, log)
                .mine(min_support=lam, max_clauses=12000)
                .solve("optpes", budget=budget, time_limit=60.0))
        cov = pipe.coverage()
        elig = pipe.tiering().classify_queries(pipe.data.log.query_bits)
        novel_cov = float(log.test_weights[
            elig & (log.train_weights == 0)].sum())
        points.append({"method": "clause", "lam": lam,
                       "train": cov["train"], "test": cov["test"],
                       "novel_cov": novel_cov})
        emit(f"fig5_clause_lam{lam:g}", 0.0,
             f"train={cov['train']:.4f};test={cov['test']:.4f};"
             f"novel={novel_cov:.4f}")

    # flow baselines iterate the SAME registry via their data adapters
    pipe = (api.TieringPipeline.from_corpus(corpus, log)
            .mine(min_support=3e-4, max_clauses=12000))
    for lam in (0.0, 1e-4, 1e-3):
        r = api.solve(pipe.data, api.SolveConfig(
            budget=budget, solver="flow-sgd",
            options={"lam": lam, "steps": 250}))
        novel_cov = float(log.test_weights[
            r.extra["eligible_queries"] & (log.train_weights == 0)].sum())
        points.append({"method": "flow-sgd", "lam": lam,
                       "train": r.f_final, "test": r.extra["test_coverage"],
                       "novel_cov": novel_cov})
        emit(f"fig5_flowsgd_lam{lam:g}", 1e6 * r.time_history[-1],
             f"train={r.f_final:.4f};test={r.extra['test_coverage']:.4f};"
             f"novel={novel_cov:.4f}")
    for name, nm in (("flow-popularity", "popularity"), ("flow-max", "flow-max")):
        r = api.solve(pipe.data, api.SolveConfig(budget=budget, solver=name))
        points.append({"method": nm, "lam": None,
                       "train": r.f_final, "test": r.extra["test_coverage"],
                       "novel_cov": 0.0})
        emit(f"fig5_{nm}", 1e6 * r.time_history[-1],
             f"train={r.f_final:.4f};test={r.extra['test_coverage']:.4f}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig5_generalization.json"), "w") as f:
        json.dump(points, f)

    # --- the paper's claims, programmatically -------------------------------
    # (a) structural: flow NEVER covers novel traffic; clause does
    flow_novel = max(p["novel_cov"] for p in points
                     if p["method"] == "flow-sgd")
    clause_novel = max(p["novel_cov"] for p in points
                       if p["method"] == "clause")
    # (b) Fig-5 plane: at matched training fit, clause's test >= flow's.
    #     For each flow point, find a clause point with train >= flow.train
    #     - 2% and compare test coverage.
    matched = []
    for fp in (p for p in points if p["method"] == "flow-sgd"):
        cands = [p for p in points if p["method"] == "clause"
                 and p["train"] >= fp["train"] - 0.02]
        if cands:
            best = max(cands, key=lambda p: p["test"])
            matched.append((fp, best, best["test"] >= fp["test"]))
    holds_matched = all(m[2] for m in matched) if matched else None
    # (c) generalization GAP (test - train): clause's is better (novel
    #     queries ADD coverage for clause; flow only loses tail mass)
    gap_clause = max(p["test"] - p["train"] for p in points
                     if p["method"] == "clause")
    gap_flow = max(p["test"] - p["train"] for p in points
                   if p["method"] == "flow-sgd")
    emit("fig5_claim_flow_covers_no_novel", 0.0,
         f"flow_novel={flow_novel:.4f};clause_novel={clause_novel:.4f};"
         f"holds={flow_novel == 0.0 and clause_novel > 0}")
    emit("fig5_claim_matched_train_fit", 0.0,
         f"pairs={len(matched)};holds={holds_matched}")
    emit("fig5_claim_generalization_gap", 0.0,
         f"clause_gap={gap_clause:+.4f};flow_gap={gap_flow:+.4f};"
         f"holds={gap_clause > gap_flow}")
    return {"matched": holds_matched, "gap_clause": gap_clause,
            "gap_flow": gap_flow}


if __name__ == "__main__":
    run()
