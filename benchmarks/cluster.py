"""Cluster serving section: strong scaling over shard count, the
latency-vs-budget frontier (global AND traffic-split budgets), a
retiered-vs-static A/B under drift, a global-vs-split budget A/B, and the
loadgen service-model calibration.

Question families (seeded, tiny scale by default so the section stays
CI-sized; REPRO_BENCH_CLUSTER_SCALE overrides):

  * strong scaling: with the doc space split over {1,2,4} Tier-2 shards,
    does per-shard words-scanned (the per-machine roofline term) drop with
    shard count, and what do simulated p50/p95/p99 and throughput do?
  * frontier: sweeping the Tier-1 budget trades fleet word traffic against
    simulated tail latency — the paper's cost argument as a curve — at the
    SAME totals once with a global knapsack and once with per-shard
    traffic-split caps (the Fig.-1 machines-vs-coverage economics, measured:
    fleet_words is the machines proxy, coverage the served fraction).
  * drift A/B: on identical windows, a re-tiering cluster (rolling swaps)
    vs the same fleet frozen — coverage, traffic saving, and loadgen
    latency on each arm's final tiering.
  * budget-split A/B: on identical drift windows at EQUAL total budget, a
    globally-budgeted fleet vs per-shard traffic-split caps (hot shards get
    bigger local Tier-1s; refits re-allocate the split).
  * calibration: fit `t_fixed + words * t_word` against measured
    `match_batch` wall times across sub-index widths at tiny/small scale;
    the coefficients + R² land in BENCH_cluster.json so `run_loadgen` can
    be driven with measured, not assumed, service times.
  * mesh_routing: fused shard_map serve (ONE SPMD program per batch over
    the `"shard"` device axis) vs the sequential per-shard host dispatch,
    measured batch-serve wall-clock at {1, 2, 4} forced host devices (each
    device count is a fresh subprocess — XLA fixes the device count at
    init).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import emit

CLUSTER_SCALE = os.environ.get("REPRO_BENCH_CLUSTER_SCALE", "tiny")
SHARD_SWEEP = (1, 2, 4)
AB_SCENARIOS = ("rotate", "churn")
N_WINDOWS = int(os.environ.get("REPRO_BENCH_CLUSTER_WINDOWS", "8"))
CALIBRATION_SCALES = tuple(os.environ.get(
    "REPRO_BENCH_CALIBRATION_SCALES", "tiny,small").split(","))


def _fresh_pipe(data):
    from repro import api
    return api.TieringPipeline.from_data(data).solve("greedy",
                                                     budget_frac=0.5)


def _loadgen(fleet, queries, **kw):
    from repro import cluster
    plan = cluster.ClusterPlan.of_cluster(fleet)
    return cluster.run_loadgen(plan, fleet.classify(queries),
                               n_queries=4000, seed=0, **kw)


def run() -> dict:
    from repro import stream
    from repro.data import incidence, synthetic

    corpus, log = synthetic.make_tiering_dataset(0, CLUSTER_SCALE)
    data = incidence.build_tiering_data(corpus, log, min_support=1e-3)
    sample = log.queries[:min(2048, log.n_queries)]
    results: dict[str, dict] = {}

    # -- strong scaling over shard count --------------------------------------
    pipe = _fresh_pipe(data)
    scaling = {}
    for n_shards in SHARD_SWEEP:
        fleet = pipe.deploy_cluster(n_shards=n_shards, t1_replicas=2)
        batch = sample[:512]
        t0 = time.perf_counter()
        fleet.serve(batch)
        dt = time.perf_counter() - t0
        per_shard_words = max(
            s.n_words for s in fleet.shards)           # t2 words/query/shard
        rep = _loadgen(fleet, sample)
        scaling[n_shards] = {
            "per_shard_t2_words_per_query": per_shard_words,
            "p50_ms": rep.p50_ms, "p95_ms": rep.p95_ms, "p99_ms": rep.p99_ms,
            "throughput_qps": rep.throughput_qps,
            "fleet_words": rep.fleet_words,
        }
        emit(f"cluster_shards{n_shards}", 1e6 * dt / len(batch),
             f"per_shard_t2_words={per_shard_words};p50={rep.p50_ms:.4f};"
             f"p95={rep.p95_ms:.4f};p99={rep.p99_ms:.4f};"
             f"qps={rep.throughput_qps:.0f};fleet_words={rep.fleet_words}",
             data={"latency_hist": rep.latency_hist})
    results["strong_scaling"] = scaling

    # -- latency-vs-budget frontier: global vs traffic-split caps -------------
    frontier = {}
    for frac in (0.25, 0.5, 0.75):
        from repro import api
        point = {}
        for arm in ("global", "split"):
            fp = api.TieringPipeline.from_data(data)
            if arm == "split":
                fp.solve("greedy", budget_frac=frac,
                         budget_split="traffic", n_shards=2)
            else:
                fp.solve("greedy", budget_frac=frac)
            fleet = fp.deploy_cluster(n_shards=2, t1_replicas=2)
            rep = _loadgen(fleet, sample)
            cov = fp.coverage()
            point[arm] = {"p95_ms": rep.p95_ms,
                          "fleet_words": rep.fleet_words,
                          "tier1_fraction": rep.tier1_fraction,
                          "test_coverage": cov["test"],
                          "caps": list(fp.result.extra["caps"])
                          if arm == "split" else None}
            emit(f"cluster_budget{int(100 * frac)}_{arm}", 0.0,
                 f"p95={rep.p95_ms:.4f};fleet_words={rep.fleet_words};"
                 f"t1_frac={rep.tier1_fraction:.4f};"
                 f"cov={cov['test']:.4f}")
        frontier[frac] = point
    results["frontier"] = frontier

    # -- retiered vs static A/B under drift -----------------------------------
    ab = {}
    for scenario in AB_SCENARIOS:
        kw = dict(scenario=scenario, n_windows=N_WINDOWS,
                  queries_per_window=256, seed=0)
        sp = _fresh_pipe(data)
        static_fleet = sp.deploy_cluster(n_shards=2, t1_replicas=2)
        static = stream.run_stream(sp, engine=static_fleet,
                                   enable_refit=False, **kw)
        rp = _fresh_pipe(data)
        retiered_fleet = rp.deploy_cluster(n_shards=2, t1_replicas=2)
        retiered = stream.run_stream(rp, engine=retiered_fleet, **kw)
        # a late-window refit can leave the rolling swap mid-flight; finish
        # it so the latency probe measures the FINAL tiering's topology
        retiered_fleet.drain_rollout()
        lat_s = _loadgen(static_fleet, sample)
        lat_r = _loadgen(retiered_fleet, sample)
        ab[scenario] = {
            "static_cov": static.mean_coverage,
            "retiered_cov": retiered.mean_coverage,
            "static_saving": static.cumulative.cost_saving,
            "retiered_saving": retiered.cumulative.cost_saving,
            "static_p95_ms": lat_s.p95_ms, "retiered_p95_ms": lat_r.p95_ms,
            "n_refits": retiered.n_refits,
            "pair_consistent": retiered_fleet.consistency_ok(),
        }
        emit(f"cluster_ab_{scenario}_static", 0.0,
             f"cov={static.mean_coverage:.4f};"
             f"saving={static.cumulative.cost_saving:.4f};"
             f"p95={lat_s.p95_ms:.4f}",
             data={"latency_hist": lat_s.latency_hist})
        emit(f"cluster_ab_{scenario}_retiered", 0.0,
             f"cov={retiered.mean_coverage:.4f};"
             f"saving={retiered.cumulative.cost_saving:.4f};"
             f"p95={lat_r.p95_ms:.4f};refits={retiered.n_refits};"
             f"consistent={retiered_fleet.consistency_ok()}",
             data={"latency_hist": lat_r.latency_hist})
    results["ab"] = ab

    # -- global vs traffic-split budgets under drift (equal total budget) -----
    from repro import api
    split_ab = {}
    for scenario in AB_SCENARIOS:
        kw = dict(scenario=scenario, n_windows=N_WINDOWS,
                  queries_per_window=256, seed=0)
        arms = {}
        for arm in ("global", "traffic"):
            p = api.TieringPipeline.from_data(data)
            if arm == "traffic":
                p.solve("greedy", budget_frac=0.5, budget_split="traffic",
                        n_shards=2)
            else:
                p.solve("greedy", budget_frac=0.5)
            fleet = p.deploy_cluster(n_shards=2, t1_replicas=2)
            rep = stream.run_stream(p, engine=fleet, **kw)
            fleet.drain_rollout()
            lat = _loadgen(fleet, sample)
            caps = p.result.extra.get("caps")
            arms[arm] = {
                "cov": rep.mean_coverage,
                "saving": rep.cumulative.cost_saving,
                "p95_ms": lat.p95_ms,
                "fleet_words": lat.fleet_words,
                "refits": rep.n_refits,
                "pair_consistent": fleet.consistency_ok(),
                "caps": None if caps is None else list(caps),
            }
            emit(f"cluster_split_{scenario}_{arm}", 0.0,
                 f"cov={rep.mean_coverage:.4f};"
                 f"saving={rep.cumulative.cost_saving:.4f};"
                 f"p95={lat.p95_ms:.4f};fleet_words={lat.fleet_words};"
                 f"refits={rep.n_refits}",
                 data={"latency_hist": lat.latency_hist})
        split_ab[scenario] = arms
    results["budget_split_ab"] = split_ab

    # -- loadgen service-model calibration ------------------------------------
    results["calibration"] = calibrate()

    # -- fused shard_map routing vs sequential host dispatch ------------------
    results["mesh_routing"] = mesh_routing()
    return results


_MESH_PROBE = r"""
import json, os, sys, time
import numpy as np
from repro import api, distributed as D

scale, n_shards, batch = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
pipe = (api.TieringPipeline.from_synthetic(seed=0, scale=scale)
        .mine(min_support=1e-3).solve("greedy", budget_frac=0.5))
queries = pipe.log.queries[:batch]


def wall(fleet, reps=9):   # min-of-reps: 1-core forced-device scheduling jitter
    fleet.serve(queries)                        # warm (compile + caches)
    best = min(
        (lambda t0: (fleet.serve(queries), time.perf_counter() - t0)[1])(
            time.perf_counter())
        for _ in range(reps))
    return 1e6 * best / len(queries)

host_fleet = pipe.deploy_cluster(n_shards=n_shards, t1_replicas=2)
host_us = wall(host_fleet)
a = host_fleet.serve(queries[:64])
mesh_fleet = pipe.deploy_cluster(n_shards=n_shards, t1_replicas=2)
with D.use_mesh(D.shard_mesh()):
    plan = D.current_plan()
    fused_us = wall(mesh_fleet)
    b = mesh_fleet.serve(queries[:64])      # parity probed ON the mesh path
assert all(np.array_equal(x, y) for x, y in zip(a, b)), "parity"
print(json.dumps({
    "devices": plan.n_shard_devices, "n_shards": n_shards,
    "fused_active": plan.shard_fused, "host_us_per_query": round(host_us, 3),
    "fused_us_per_query": round(fused_us, 3)}))
"""


def mesh_routing(device_counts=(1, 2, 4), n_shards: int = 4,
                 batch: int = 512) -> dict:
    """Fused vs host dispatch at forced host-device counts (subprocesses:
    the device count is fixed at jax init). At 1 device the plan gates the
    fusion off, so both arms measure the host path — the honest baseline."""
    out = {}
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    for ndev in device_counts:
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
                   JAX_PLATFORMS="cpu",
                   PYTHONPATH=src + os.pathsep * bool(
                       os.environ.get("PYTHONPATH", ""))
                   + os.environ.get("PYTHONPATH", ""))
        proc = subprocess.run(
            [sys.executable, "-c", _MESH_PROBE, CLUSTER_SCALE,
             str(n_shards), str(batch)],
            capture_output=True, text=True, env=env, timeout=900)
        if proc.returncode != 0:
            out[ndev] = {"error": proc.stderr[-500:]}
            continue
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        out[ndev] = rec
        emit(f"cluster_mesh_d{ndev}", rec["fused_us_per_query"],
             f"host_us={rec['host_us_per_query']};"
             f"fused_us={rec['fused_us_per_query']};"
             f"shards={rec['n_shards']};fused_active={rec['fused_active']}")
    return out


def _timed(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args).block_until_ready()
    return time.perf_counter() - t0


def calibrate(scales: tuple[str, ...] = CALIBRATION_SCALES) -> dict:
    """Fit the loadgen service model against MEASURED `match_batch` walls.

    Sub-index width is the model's `words` variable, so slicing the packed
    postings to several widths (and spanning dataset scales) sweeps it;
    wall time per query at each width is one warm-started jitted call.
    """
    import jax.numpy as jnp

    from repro import cluster as cluster_pkg
    from repro.data import incidence, synthetic
    from repro.serve import matching

    words_l, us_l = [], []
    for scale in scales:
        corpus, log = synthetic.make_tiering_dataset(0, scale)
        postings = incidence.build_postings(corpus)
        toks = jnp.asarray(matching.pad_token_batch(
            log.queries[:min(512, log.n_queries)]))
        full_w = postings.shape[1]
        for frac in (0.125, 0.25, 0.5, 0.75, 1.0):
            w = max(1, int(full_w * frac))
            sub = jnp.asarray(postings[:, :w])
            matching.match_batch(sub, toks).block_until_ready()   # compile
            # min-of-reps: scheduling noise only ever ADDS time, so the
            # minimum is the cleanest estimate of the true service time
            dt = min(_timed(matching.match_batch, sub, toks)
                     for _ in range(10))
            words_l.append(w)
            us_l.append(1e6 * dt / int(toks.shape[0]))
    fit = cluster_pkg.fit_service_model(np.asarray(words_l),
                                        np.asarray(us_l))
    fit["scales"] = list(scales)
    fit["points"] = [{"words": int(w), "us_per_query": round(u, 3)}
                     for w, u in zip(words_l, us_l)]
    emit("cluster_calibration", fit["t_word_us"],
         f"t_fixed_us={fit['t_fixed_us']:.3f};"
         f"t_word_us={fit['t_word_us']:.4f};r2={fit['r2']:.4f};"
         f"points={fit['n_points']}")
    return fit


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    from benchmarks import common
    common.begin_section("cluster", scale=CLUSTER_SCALE)
    run()
    for path in common.write_json():
        print(f"# wrote {path}", file=sys.stderr)
