"""Cluster serving section: strong scaling over shard count, the
latency-vs-budget frontier, and a retiered-vs-static A/B under drift.

Three question families (seeded, tiny scale by default so the section stays
CI-sized; REPRO_BENCH_CLUSTER_SCALE overrides):

  * strong scaling: with the doc space split over {1,2,4} Tier-2 shards,
    does per-shard words-scanned (the per-machine roofline term) drop with
    shard count, and what do simulated p50/p95/p99 and throughput do?
  * frontier: sweeping the Tier-1 budget trades fleet word traffic against
    simulated tail latency — the paper's cost argument as a curve.
  * drift A/B: on identical windows, a re-tiering cluster (rolling swaps)
    vs the same fleet frozen — coverage, traffic saving, and loadgen
    latency on each arm's final tiering.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import emit

CLUSTER_SCALE = os.environ.get("REPRO_BENCH_CLUSTER_SCALE", "tiny")
SHARD_SWEEP = (1, 2, 4)
AB_SCENARIOS = ("rotate", "churn")
N_WINDOWS = int(os.environ.get("REPRO_BENCH_CLUSTER_WINDOWS", "8"))


def _fresh_pipe(data):
    from repro import api
    return api.TieringPipeline.from_data(data).solve("greedy",
                                                     budget_frac=0.5)


def _loadgen(fleet, queries, **kw):
    from repro import cluster
    plan = cluster.ClusterPlan.of_cluster(fleet)
    return cluster.run_loadgen(plan, fleet.classify(queries),
                               n_queries=4000, seed=0, **kw)


def run() -> dict:
    from repro import stream
    from repro.data import incidence, synthetic

    corpus, log = synthetic.make_tiering_dataset(0, CLUSTER_SCALE)
    data = incidence.build_tiering_data(corpus, log, min_support=1e-3)
    sample = log.queries[:min(2048, log.n_queries)]
    results: dict[str, dict] = {}

    # -- strong scaling over shard count --------------------------------------
    pipe = _fresh_pipe(data)
    scaling = {}
    for n_shards in SHARD_SWEEP:
        fleet = pipe.deploy_cluster(n_shards=n_shards, t1_replicas=2)
        batch = sample[:512]
        t0 = time.perf_counter()
        fleet.serve(batch)
        dt = time.perf_counter() - t0
        per_shard_words = max(
            s.n_words for s in fleet.shards)           # t2 words/query/shard
        rep = _loadgen(fleet, sample)
        scaling[n_shards] = {
            "per_shard_t2_words_per_query": per_shard_words,
            "p50_ms": rep.p50_ms, "p95_ms": rep.p95_ms, "p99_ms": rep.p99_ms,
            "throughput_qps": rep.throughput_qps,
            "fleet_words": rep.fleet_words,
        }
        emit(f"cluster_shards{n_shards}", 1e6 * dt / len(batch),
             f"per_shard_t2_words={per_shard_words};p50={rep.p50_ms:.4f};"
             f"p95={rep.p95_ms:.4f};p99={rep.p99_ms:.4f};"
             f"qps={rep.throughput_qps:.0f};fleet_words={rep.fleet_words}")
    results["strong_scaling"] = scaling

    # -- latency-vs-budget frontier -------------------------------------------
    frontier = {}
    for frac in (0.25, 0.5, 0.75):
        from repro import api
        fp = api.TieringPipeline.from_data(data).solve("greedy",
                                                       budget_frac=frac)
        fleet = fp.deploy_cluster(n_shards=2, t1_replicas=2)
        rep = _loadgen(fleet, sample)
        frontier[frac] = {"p95_ms": rep.p95_ms,
                          "fleet_words": rep.fleet_words,
                          "tier1_fraction": rep.tier1_fraction}
        emit(f"cluster_budget{int(100 * frac)}", 0.0,
             f"p95={rep.p95_ms:.4f};fleet_words={rep.fleet_words};"
             f"t1_frac={rep.tier1_fraction:.4f}")
    results["frontier"] = frontier

    # -- retiered vs static A/B under drift -----------------------------------
    ab = {}
    for scenario in AB_SCENARIOS:
        kw = dict(scenario=scenario, n_windows=N_WINDOWS,
                  queries_per_window=256, seed=0)
        sp = _fresh_pipe(data)
        static_fleet = sp.deploy_cluster(n_shards=2, t1_replicas=2)
        static = stream.run_stream(sp, engine=static_fleet,
                                   enable_refit=False, **kw)
        rp = _fresh_pipe(data)
        retiered_fleet = rp.deploy_cluster(n_shards=2, t1_replicas=2)
        retiered = stream.run_stream(rp, engine=retiered_fleet, **kw)
        # a late-window refit can leave the rolling swap mid-flight; finish
        # it so the latency probe measures the FINAL tiering's topology
        retiered_fleet.drain_rollout()
        lat_s = _loadgen(static_fleet, sample)
        lat_r = _loadgen(retiered_fleet, sample)
        ab[scenario] = {
            "static_cov": static.mean_coverage,
            "retiered_cov": retiered.mean_coverage,
            "static_saving": static.cumulative.cost_saving,
            "retiered_saving": retiered.cumulative.cost_saving,
            "static_p95_ms": lat_s.p95_ms, "retiered_p95_ms": lat_r.p95_ms,
            "n_refits": retiered.n_refits,
            "pair_consistent": retiered_fleet.consistency_ok(),
        }
        emit(f"cluster_ab_{scenario}_static", 0.0,
             f"cov={static.mean_coverage:.4f};"
             f"saving={static.cumulative.cost_saving:.4f};"
             f"p95={lat_s.p95_ms:.4f}")
        emit(f"cluster_ab_{scenario}_retiered", 0.0,
             f"cov={retiered.mean_coverage:.4f};"
             f"saving={retiered.cumulative.cost_saving:.4f};"
             f"p95={lat_r.p95_ms:.4f};refits={retiered.n_refits};"
             f"consistent={retiered_fleet.consistency_ok()}")
    results["ab"] = ab
    return results


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    from benchmarks import common
    common.begin_section("cluster", scale=CLUSTER_SCALE)
    run()
    for path in common.write_json():
        print(f"# wrote {path}", file=sys.stderr)
