"""Shared benchmark fixtures: one cached medium-scale tiering dataset,
plus the row recorder behind the CSV/JSON dual emission (`emit` prints the
CSV line AND records it under the current section so `run.py` can write
`artifacts/bench/BENCH_<section>.json` machine-readable artifacts)."""
from __future__ import annotations

import functools
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

BENCH_SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")

ROWS: list[dict] = []
_SECTION = "misc"
_SECTION_SCALE: dict[str, str] = {}
_SECTION_T0: dict[str, float] = {}
_SECTION_SECONDS: dict[str, float] = {}


@functools.lru_cache(maxsize=2)
def bench_data(scale: str = BENCH_SCALE, min_support: float = 5e-5,
               max_clauses: int = 4000):
    from repro.data import incidence, synthetic
    corpus, log = synthetic.make_tiering_dataset(0, scale)
    data = incidence.build_tiering_data(
        corpus, log, min_support=min_support, max_clauses=max_clauses)
    return data


def bench_problem(scale: str = BENCH_SCALE):
    from repro.core import SCSKProblem
    return SCSKProblem.from_data(bench_data(scale))


def begin_section(name: str, scale: str = BENCH_SCALE) -> None:
    """Route subsequent `emit` rows to BENCH_<name>.json. Pass `scale` when
    a section measures at a different dataset scale than BENCH_SCALE.
    Section wall-clock runs from here until the next section begins (or
    `write_json` runs) and lands in the artifact as "seconds"."""
    global _SECTION
    _close_section()
    _SECTION = name
    _SECTION_SCALE[name] = scale
    _SECTION_T0[name] = time.time()


def _close_section() -> None:
    t0 = _SECTION_T0.pop(_SECTION, None)
    if t0 is not None:
        _SECTION_SECONDS[_SECTION] = \
            _SECTION_SECONDS.get(_SECTION, 0.0) + (time.time() - t0)


def emit(name: str, us_per_call: float, derived: str = "",
         data: dict | None = None) -> None:
    """Print the CSV line and record the row. `data` attaches a structured
    payload (e.g. a latency histogram snapshot) to the JSON artifact row —
    it never appears on the CSV line."""
    print(f"{name},{us_per_call:.1f},{derived}")
    row = {"section": _SECTION, "name": name,
           "us_per_call": us_per_call, "derived": derived}
    if data is not None:
        row["data"] = data
    ROWS.append(row)


def write_json(out_dir: str = "artifacts/bench") -> list[str]:
    """One BENCH_<section>.json per section seen so far; returns the paths.
    Each artifact records the section's wall-clock "seconds", so BENCH
    trajectories capture runtime, not just us_per_call lines."""
    _close_section()
    os.makedirs(out_dir, exist_ok=True)
    sections: dict[str, list[dict]] = {}
    for row in ROWS:
        sections.setdefault(row["section"], []).append(
            {k: row[k] for k in ("name", "us_per_call", "derived", "data")
             if k in row})
    paths = []
    for section, rows in sections.items():
        path = os.path.join(out_dir, f"BENCH_{section}.json")
        with open(path, "w") as f:
            json.dump({"section": section, "generated": time.time(),
                       "scale": _SECTION_SCALE.get(section, BENCH_SCALE),
                       "seconds": round(_SECTION_SECONDS.get(section, 0.0), 3),
                       "rows": rows}, f, indent=1)
        paths.append(path)
    return paths
