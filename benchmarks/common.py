"""Shared benchmark fixtures: one cached medium-scale tiering dataset."""
from __future__ import annotations

import functools
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

BENCH_SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")


@functools.lru_cache(maxsize=2)
def bench_data(scale: str = BENCH_SCALE, min_support: float = 5e-5,
               max_clauses: int = 4000):
    from repro.data import incidence, synthetic
    corpus, log = synthetic.make_tiering_dataset(0, scale)
    data = incidence.build_tiering_data(
        corpus, log, min_support=min_support, max_clauses=max_clauses)
    return data


def bench_problem(scale: str = BENCH_SCALE):
    from repro.core import SCSKProblem
    return SCSKProblem.from_data(bench_data(scale))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
