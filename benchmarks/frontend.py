"""Serving front-end section: the classify-keyed result cache, hedged
dispatch, and overload admission, measured end to end.

Question families (seeded, tiny scale by default so the section stays
CI-sized; REPRO_BENCH_FRONTEND_SCALE overrides):

  * zipf replay: the SAME Zipf-resampled query stream served through a
    cache-on and a cache-off fleet — hit rate and fleet postings words per
    skew. The paper prices every query by words scanned (§2.2), so at
    web-like repeat traffic (skew ~1.1) the cache must cut fleet words by
    >= 2x; a spot batch is pinned against `serve_reference` so the saving
    never comes at the cost of exactness.
  * loadgen arms: modelled p99 for baseline vs hedged dispatch vs result
    cache at moderate offered load, on one plan with >= 2 replicas per
    group, plus an overload PAIR (10x the rate) with and without admission.
    Hedging must CUT p99 (p99_over_base < 1) and the cache arm must not be
    slower than baseline; under overload, admission must keep the admitted
    tail flat while the unprotected arm's queues collapse. Moderate load is
    the honest operating point for hedging — at saturation backups double
    load and queueing collapse dominates (measured, not assumed).
  * parity digest: a cache-on fleet served THROUGH a rolling tiering swap
    and THROUGH a rolling corpus swap, every batch compared bit-for-bit to
    the single-tier oracle at the corpus version it was served at, with
    repeat traffic so hits actually occur mid-roll. `parity` is the gated
    metric: 1.0 or the section regressed.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import emit

FRONTEND_SCALE = os.environ.get("REPRO_BENCH_FRONTEND_SCALE", "tiny")
ZIPF_SKEWS = (0.0, 1.1)
N_REPLAY = int(os.environ.get("REPRO_BENCH_FRONTEND_REPLAY", "2048"))
N_KEYS = int(os.environ.get("REPRO_BENCH_FRONTEND_KEYS", "256"))
BATCH = 256


def _pipe(data):
    from repro import api
    return api.TieringPipeline.from_data(data).solve("greedy",
                                                     budget_frac=0.5)


def _distinct_pool(queries, cap: int) -> list:
    """First `cap` queries distinct by token SET — the cache-key identity."""
    seen, pool = set(), []
    for q in queries:
        k = frozenset(q)
        if k not in seen:
            seen.add(k)
            pool.append(q)
            if len(pool) >= cap:
                break
    return pool


def run() -> dict:
    from repro import cluster
    from repro.cluster import frontend
    from repro.data import incidence, synthetic

    corpus, log = synthetic.make_tiering_dataset(0, FRONTEND_SCALE)
    data = incidence.build_tiering_data(corpus, log, min_support=1e-3)
    pipe = _pipe(data)
    results: dict[str, dict] = {}

    # -- zipf replay: cache-on vs cache-off fleet words per skew --------------
    pool = _distinct_pool(log.queries, N_KEYS)
    replay = {}
    for skew in ZIPF_SKEWS:
        idx = frontend.zipf_keys(N_REPLAY, len(pool), skew, seed=0)
        stream = [pool[i] for i in idx]
        arms = {}
        for arm in ("off", "on"):
            fleet = pipe.deploy_cluster(
                n_shards=2, t1_replicas=2,
                cache=frontend.ResultCache(capacity=4096) if arm == "on"
                else None)
            t0 = time.perf_counter()
            got = None
            for lo in range(0, len(stream), BATCH):
                got = fleet.serve(stream[lo:lo + BATCH])
            dt = time.perf_counter() - t0
            # exactness spot-check on the final (hit-heavy) batch
            ref = fleet.serve_reference(stream[-len(got):])
            exact = all(np.array_equal(a, b) for a, b in zip(got, ref))
            s = fleet.stats
            arms[arm] = {
                "fleet_words": s.tier1_words + s.tier2_words,
                "tier1_fraction": s.tier1_fraction,
                "hit_rate": fleet.cache.stats.hit_rate if fleet.cache
                else 0.0,
                "exact": exact,
                "us_per_query": 1e6 * dt / len(stream),
            }
        ratio = arms["off"]["fleet_words"] / max(1, arms["on"]["fleet_words"])
        replay[skew] = {**arms, "words_ratio": ratio}
        emit(f"frontend_zipf{int(10 * skew)}", arms["on"]["us_per_query"],
             f"hit_rate={arms['on']['hit_rate']:.4f};"
             f"words_off={arms['off']['fleet_words']};"
             f"words_on={arms['on']['fleet_words']};"
             f"words_ratio={ratio:.3f};"
             f"t1_frac={arms['on']['tier1_fraction']:.4f};"
             f"exact={arms['on']['exact'] and arms['off']['exact']}")
    results["zipf_replay"] = replay

    # -- loadgen arms: p99 with and without each front-end layer --------------
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2, t2_replicas=2)
    plan = cluster.ClusterPlan.of_cluster(fleet)
    sample = log.queries[:min(2048, log.n_queries)]
    elig = fleet.classify(sample)
    lg = dict(n_queries=4000, seed=0)
    base = cluster.run_loadgen(plan, elig, **lg)
    hedge = cluster.run_loadgen(plan, elig, hedge_ms=0.1, **lg)
    ck = frontend.zipf_keys(lg["n_queries"], N_KEYS, 1.1, seed=0)
    cached = cluster.run_loadgen(plan, elig, cache_keys=ck, **lg)
    # admission only matters under OVERLOAD: 10x the moderate rate, where
    # the unprotected fleet's queues collapse and shedding keeps the
    # admitted tail flat
    ov = dict(lg, rate_qps=200000.0)
    ov_base = cluster.run_loadgen(plan, elig, **ov)
    ov_adm = cluster.run_loadgen(
        plan, elig, admission=frontend.AdmissionPolicy(
            queue_bound_ms=0.3, deadline_ms=1.0), **ov)
    arms = {"base": base, "hedge": hedge, "cache": cached,
            "overload_base": ov_base, "overload_admission": ov_adm}
    results["loadgen"] = {}
    for name, rep in arms.items():
        ref = ov_base if name.startswith("overload") else base
        over = rep.p99_ms / ref.p99_ms if ref.p99_ms else 1.0
        results["loadgen"][name] = {**rep.to_dict(),
                                    "p99_over_base": over}
        extra = ""
        if name == "hedge":
            extra = (f";hedges={rep.n_hedges};hedge_wins={rep.n_hedge_wins}"
                     f";p99_over_base={over:.4f}")
        elif name == "overload_admission":
            extra = (f";shed={rep.n_shed};shed_t2={rep.n_shed_to_t2}"
                     f";p99_over_base={over:.4f}")
        elif name == "cache":
            wr = base.fleet_words / max(1, rep.fleet_words)
            extra = (f";hit_rate={rep.cache_hit_rate:.4f}"
                     f";words_ratio={wr:.3f};p99_over_base={over:.4f}")
        emit(f"frontend_loadgen_{name}", 0.0,
             f"p50={rep.p50_ms:.4f};p95={rep.p95_ms:.4f};"
             f"p99={rep.p99_ms:.4f};fleet_words={rep.fleet_words}" + extra,
             data={"latency_hist": rep.latency_hist})
    results["hedge_p99_cut_ms"] = base.p99_ms - hedge.p99_ms

    # -- parity digest: cache-on serving through BOTH rolling swap kinds ------
    results["parity"] = parity_digest(FRONTEND_SCALE)
    return results


def parity_digest(scale: str) -> dict:
    """Cache-on fleet vs the single-tier oracle, batch by batch, while a
    rolling tiering swap and then a rolling corpus swap are in flight.
    Repeat traffic (the same pool served every batch) keeps the cache hot,
    so mid-roll batches mix cached and fresh answers — the hard case."""
    from repro import ingest
    from repro.cluster import frontend
    from repro.data import incidence, synthetic

    corpus, log = synthetic.make_tiering_dataset(0, scale)
    data = incidence.build_tiering_data(corpus, log, min_support=1e-3)
    pipe = _pipe(data)
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2,
                                cache=frontend.ResultCache(capacity=4096))
    queries = _distinct_pool(log.queries, 96)
    parity = True
    fleet.serve(queries)                            # warm the cache

    # leg 1: rolling tiering swap (corpus fixed, generation rolls)
    fleet.swap_tiering(_pipe(data).solve(
        "greedy", budget_frac=0.25).tiering())
    tiering_batches = 0
    while True:
        got = fleet.serve(queries)
        ref = fleet.serve_reference(queries)
        parity &= all(np.array_equal(a, b) for a, b in zip(got, ref))
        tiering_batches += 1
        if fleet.router.rollout is None or tiering_batches >= 64:
            break
    tiering_ok = parity and fleet.router.rollout is None

    # leg 2: rolling corpus swap (append-only growth, version rolls)
    feed = ingest.DocumentFeed(log=data.log, vocab_size=data.corpus.vocab_size,
                               rate=48.0, seed=7)
    delta = incidence.append_docs(data, list(feed.window(0)))
    pipe.problem = pipe.problem.with_doc_block(delta.clause_cols,
                                               delta.n_docs)
    pipe.adopt_selection(pipe.problem.state_for(
        np.nonzero(np.asarray(pipe.result.selected))[0]))
    fleet.swap_corpus(data.postings, delta.n_docs, pipe.tiering())
    corpus_batches = 0
    while True:
        got = fleet.serve(queries)
        v = fleet.trace[-1].corpus_version
        ref = fleet.serve_reference(queries, corpus_version=v)
        parity &= all(np.array_equal(a, b) for a, b in zip(got, ref))
        corpus_batches += 1
        if fleet.router.rollout is None or corpus_batches >= 64:
            break
    corpus_ok = parity and fleet.router.rollout is None

    snap = fleet.cache.snapshot()
    out = {"parity": 1.0 if parity else 0.0,
           "tiering_swap_ok": tiering_ok, "corpus_swap_ok": corpus_ok,
           "consistent": fleet.consistency_ok(),
           "tiering_batches": tiering_batches,
           "corpus_batches": corpus_batches,
           "cache_hits": snap["hits"],
           "invalidations": snap["invalidations"]}
    emit("frontend_parity", 0.0,
         f"parity={out['parity']:.1f};tiering_swap={tiering_ok};"
         f"corpus_swap={corpus_ok};consistent={out['consistent']};"
         f"hits={snap['hits']};invalidations={snap['invalidations']}")
    return out


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    from benchmarks import common
    common.begin_section("frontend", scale=FRONTEND_SCALE)
    run()
    for path in common.write_json():
        print(f"# wrote {path}", file=sys.stderr)
