"""Paper Fig. 4: Opt/Pes speed vs parallel width.

The paper varies CPU count; the TPU-native analogue is the batched refresh
width K (how many candidates get exact re-evaluation per fused kernel call).
Larger K = more parallel work per round = fewer rounds, exactly the paper's
'more CPUs' axis."""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import bench_data, bench_problem, emit


def run(out_dir: str = "artifacts/bench") -> dict:
    from repro.core import optpes_greedy
    problem = bench_problem()
    data = bench_data()
    budget = data.n_docs // 4          # paper uses B = |D|/4 for Fig. 4

    out = {}
    for k in (16, 64, 256, 1024):
        t0 = time.perf_counter()
        r = optpes_greedy(problem, budget, k=k, time_limit=30.0)
        dt = time.perf_counter() - t0
        out[k] = {"seconds": dt, "f_final": r.f_final,
                  "steps": len(r.order), "evals": r.n_exact_evals}
        emit(f"fig4_optpes_k{k}", 1e6 * dt,
             f"f={r.f_final:.4f};steps={len(r.order)}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig4_parallel.json"), "w") as f:
        json.dump(out, f)
    return out


if __name__ == "__main__":
    run()
