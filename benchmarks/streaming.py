"""Streaming re-tiering section: static vs re-tiered serving under drift,
and warm vs cold re-solve latency.

Two question families, per drift scenario (seeded, tiny scale by default so
the section stays CI-sized; REPRO_BENCH_STREAM_SCALE overrides):

  * does the drift-aware controller beat a frozen tiering on identical
    traffic? (mean windowed Tier-1 coverage + cumulative word-traffic
    saving, static vs re-tiered)
  * what does a re-solve cost? warm (prune + resume the previous
    SolverState) vs cold (from scratch) wall time and selection steps on
    the same reweighted problem.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import emit

STREAM_SCALE = os.environ.get("REPRO_BENCH_STREAM_SCALE", "tiny")
SCENARIOS = ("rotate", "burst", "churn", "seasonal")
N_WINDOWS = int(os.environ.get("REPRO_BENCH_STREAM_WINDOWS", "12"))


def _fresh_pipe(data):
    from repro import api
    return api.TieringPipeline.from_data(data).solve("greedy",
                                                     budget_frac=0.5)


def run() -> dict:
    from repro import stream
    from repro.data import incidence, synthetic
    from repro.stream.window import prune_state

    corpus, log = synthetic.make_tiering_dataset(0, STREAM_SCALE)
    data = incidence.build_tiering_data(corpus, log, min_support=1e-3)

    results: dict[str, dict] = {}
    for scenario in SCENARIOS:
        kw = dict(scenario=scenario, n_windows=N_WINDOWS,
                  queries_per_window=512, seed=0)
        # identical windows for both arms: the simulator is seed-deterministic
        # both arms timed WITHOUT the parity test harness (verify_swaps
        # serves extra oracle batches); parity is probed untimed below
        t0 = time.perf_counter()
        static = stream.run_stream(_fresh_pipe(data), enable_refit=False, **kw)
        t_static = time.perf_counter() - t0
        t0 = time.perf_counter()
        retiered = stream.run_stream(_fresh_pipe(data), **kw)
        t_retiered = time.perf_counter() - t0
        results[scenario] = {
            "static_cov": static.mean_coverage,
            "retiered_cov": retiered.mean_coverage,
            "static_saving": static.cumulative.cost_saving,
            "retiered_saving": retiered.cumulative.cost_saving,
            "n_refits": retiered.n_refits, "n_warm": retiered.n_warm,
        }
        emit(f"stream_{scenario}_static",
             1e6 * t_static / N_WINDOWS,
             f"cov={static.mean_coverage:.4f};"
             f"saving={static.cumulative.cost_saving:.4f}")
        emit(f"stream_{scenario}_retiered",
             1e6 * t_retiered / N_WINDOWS,
             f"cov={retiered.mean_coverage:.4f};"
             f"saving={retiered.cumulative.cost_saving:.4f};"
             f"refits={retiered.n_refits};warm={retiered.n_warm}")

    # Theorem-3.1 parity probe, outside any timed region
    probe = stream.run_stream(_fresh_pipe(data), scenario="rotate",
                              n_windows=min(6, N_WINDOWS),
                              queries_per_window=512, seed=0,
                              verify_swaps=True)
    emit("stream_parity", 0.0,
         f"checks={probe.n_parity_checks};ok={probe.parity_all_ok()}")
    results["parity"] = {"checks": probe.n_parity_checks,
                         "ok": probe.parity_all_ok()}

    # warm vs cold re-solve on one drifted distribution (rotation, window 3)
    sim = stream.TrafficSimulator(log, "rotate", seed=0, n_windows=N_WINDOWS)
    drifted = sim.window_probs(3)
    pipe_warm, pipe_cold = _fresh_pipe(data), _fresh_pipe(data)
    prev_state = pipe_warm.result.state
    t0 = time.perf_counter()
    pruned, _, dropped = prune_state(pipe_warm.problem, prev_state,
                                     weights=drifted, min_unique_mass=2e-3)
    warm = pipe_warm.refit(drifted, state=pruned).result
    t_warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    cold = pipe_cold.refit(drifted, state=None).result
    t_cold = time.perf_counter() - t0
    emit("stream_refit_warm", 1e6 * t_warm,
         f"steps={len(warm.order)};pruned={len(dropped)};"
         f"f={warm.f_final:.4f}")
    emit("stream_refit_cold", 1e6 * t_cold,
         f"steps={len(cold.order)};f={cold.f_final:.4f}")
    emit("stream_refit_speedup", 0.0,
         f"warm_over_cold_time={t_warm / max(t_cold, 1e-9):.3f};"
         f"warm_steps_frac={len(warm.order) / max(1, len(cold.order)):.3f}")
    results["refit"] = {"warm_s": t_warm, "cold_s": t_cold,
                        "warm_steps": len(warm.order),
                        "cold_steps": len(cold.order)}
    return results


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    from benchmarks import common
    common.begin_section("stream", scale=STREAM_SCALE)
    run()
    for path in common.write_json():
        print(f"# wrote {path}", file=sys.stderr)
