"""Paper Fig. 2: objective f(X) vs wall-clock per optimization algorithm,
and Fig. 3: the solution path (f vs g) each algorithm traces.

Runs every core solver through the ONE `repro.api` registry with a shared
`SolveConfig` (time limits enforced per step by the `Trace` recorder), and
demonstrates the warm-started budget-sweep API: the Fig.-3 style sweep
resumes a single `SolverState` across budgets instead of re-solving.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import bench_data, bench_problem, emit

TIME_LIMIT = float(os.environ.get("REPRO_BENCH_SOLVER_TIME", "60"))

CORE_SOLVERS = ("agnostic", "isk1", "isk2", "greedy", "lazy", "optpes",
                "stochastic")


def run(out_dir: str = "artifacts/bench") -> dict:
    from repro import api
    problem = bench_problem()
    data = bench_data()
    budget = data.n_docs // 2

    # optional live emission through the Trace on_step hook
    # (REPRO_BENCH_LIVE=1 streams one line per 50 selections; the hook must
    # not change record_every, or it would alter the fig2/fig3 histories)
    def live_emit(trace):
        if trace.n_selections % 50 == 0:
            emit("fig2_live", 1e6 * trace.elapsed(),
                 f"{trace.config.solver};f={trace.last_f:.4f};"
                 f"g={trace.last_g:.0f};n={trace.n_selections}")
    live = os.environ.get("REPRO_BENCH_LIVE") == "1"

    results = {}
    for name in CORE_SOLVERS:
        cfg = api.SolveConfig(budget=budget, solver=name,
                              time_limit=TIME_LIMIT,
                              on_step=live_emit if live else None)
        r = api.solve(problem, cfg)
        results[name] = r
        emit(f"fig2_solver_{name}",
             1e6 * r.time_history[-1] / max(1, len(r.time_history)),
             f"f={r.f_final:.4f};g={r.g_final:.0f};evals={r.n_exact_evals}")

    # Fig.-3 budget sweep: ONE warm-started greedy state across budgets.
    # Each result's time_history covers only its resumed segment, so emit
    # the CUMULATIVE wall time — comparable to a cold solve at that budget.
    budgets = [budget // 4, budget // 2, budget]
    sweep = api.solve_sweep(problem, budgets, api.SolveConfig(
        budget=budget, solver="greedy", time_limit=TIME_LIMIT))
    cum_t = 0.0
    for b, r in zip(budgets, sweep):
        cum_t += r.time_history[-1]
        emit(f"fig3_sweep_B{b}", 1e6 * cum_t,
             f"f={r.f_final:.4f};g={r.g_final:.0f};steps={len(r.order)}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig2_fig3_solvers.json"), "w") as f:
        json.dump({
            name: {
                "f_history": r.f_history.tolist(),
                "g_history": r.g_history.tolist(),
                "time_history": r.time_history.tolist(),
                "f_final": r.f_final, "g_final": r.g_final,
                "n_exact_evals": r.n_exact_evals,
            } for name, r in results.items()
        }, f)

    # paper claims, checked programmatically
    claims = {
        "greedy_ge_isk1": results["greedy"].f_final
        >= results["isk1"].f_final - 1e-9,
        "greedy_beats_agnostic": results["greedy"].f_final
        > results["agnostic"].f_final,
        "lazy_fewer_evals": results["lazy"].n_exact_evals
        < results["greedy"].n_exact_evals,
        "greedy_path_denser": len(results["greedy"].f_history)
        > 4 * len(results["isk1"].f_history),
        "sweep_monotone": all(a.f_final <= b.f_final + 1e-9
                              for a, b in zip(sweep, sweep[1:])),
    }
    emit("fig2_claims", 0.0,
         ";".join(f"{k}={v}" for k, v in claims.items()))
    return claims


if __name__ == "__main__":
    run()
