"""Paper Fig. 2: objective f(X) vs wall-clock per optimization algorithm,
and Fig. 3: the solution path (f vs g) each algorithm traces."""
from __future__ import annotations

import json
import os

from benchmarks.common import bench_data, bench_problem, emit

TIME_LIMIT = float(os.environ.get("REPRO_BENCH_SOLVER_TIME", "60"))


def run(out_dir: str = "artifacts/bench") -> dict:
    from repro.core import SOLVERS
    problem = bench_problem()
    data = bench_data()
    budget = data.n_docs // 2

    results = {}
    for name in ("agnostic", "isk1", "isk2", "greedy", "lazy", "optpes",
                 "stochastic"):
        r = SOLVERS[name](problem, budget, time_limit=TIME_LIMIT)
        results[name] = r
        emit(f"fig2_solver_{name}",
             1e6 * r.time_history[-1] / max(1, len(r.time_history)),
             f"f={r.f_final:.4f};g={r.g_final:.0f};evals={r.n_exact_evals}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig2_fig3_solvers.json"), "w") as f:
        json.dump({
            name: {
                "f_history": r.f_history.tolist(),
                "g_history": r.g_history.tolist(),
                "time_history": r.time_history.tolist(),
                "f_final": r.f_final, "g_final": r.g_final,
                "n_exact_evals": r.n_exact_evals,
            } for name, r in results.items()
        }, f)

    # paper claims, checked programmatically
    claims = {
        "greedy_ge_isk1": results["greedy"].f_final
        >= results["isk1"].f_final - 1e-9,
        "greedy_beats_agnostic": results["greedy"].f_final
        > results["agnostic"].f_final,
        "lazy_fewer_evals": results["lazy"].n_exact_evals
        < results["greedy"].n_exact_evals,
        "greedy_path_denser": len(results["greedy"].f_history)
        > 4 * len(results["isk1"].f_history),
    }
    emit("fig2_claims", 0.0,
         ";".join(f"{k}={v}" for k, v in claims.items()))
    return claims


if __name__ == "__main__":
    run()
