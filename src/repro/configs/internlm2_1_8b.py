"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 — GQA [arXiv:2403.17297].

Pure full attention -> long_500k skipped per spec.
"""
from repro.configs.registry import register_lm
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="internlm2-1.8b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=92544,
    rope_theta=1_000_000.0, tie_embeddings=False,
    pure_full_attention=True,
)

SMOKE = TransformerConfig(
    name="internlm2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=512, tie_embeddings=False,
    pure_full_attention=True,
)

register_lm("internlm2-1.8b", CONFIG, n_micro=1, smoke_cfg=SMOKE)
