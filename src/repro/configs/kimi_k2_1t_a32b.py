"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8 — trillion-param MoE [arXiv:2501.kimi2].

Pure full attention -> long_500k skipped per spec. Optimizer: Adafactor
(bf16 Adam states for 1T params would not fit 512 x 16 GB; see DESIGN.md).
train_4k uses 8-way grad accumulation to bound layer-boundary activations.
"""
from repro.configs.registry import register_lm
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=2048, vocab_size=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048,
                  capacity_factor=1.25),
    rope_theta=50000.0, tie_embeddings=False,
    param_dtype="bfloat16",
    pure_full_attention=True,
)

SMOKE = TransformerConfig(
    name="kimi-k2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=32, vocab_size=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=2.0),
    tie_embeddings=False, pure_full_attention=True,
)

register_lm("kimi-k2-1t-a32b", CONFIG, n_micro=8, optimizer="adafactor",
            grad_accum_dtype="bfloat16", smoke_cfg=SMOKE)
