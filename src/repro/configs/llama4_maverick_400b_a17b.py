"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 [hf:meta-llama/Llama-4].

Early-fusion multimodality: the spec assigns the transformer BACKBONE only —
the vision frontend is a stub (input_specs provide token ids / precomputed
patch-embedding ids share the same embedding path). Pure full attention ->
long_500k skipped.
"""
from repro.configs.registry import register_lm
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=202048,
    moe=MoEConfig(n_experts=128, top_k=1, d_expert=8192,
                  capacity_factor=1.25),
    rope_theta=500000.0, tie_embeddings=False,
    param_dtype="bfloat16",
    pure_full_attention=True,
)

SMOKE = TransformerConfig(
    name="llama4-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=64, vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=1, d_expert=64, capacity_factor=2.0),
    tie_embeddings=False, pure_full_attention=True,
)

register_lm("llama4-maverick-400b-a17b", CONFIG, n_micro=4,
            optimizer="adamw", grad_accum_dtype="bfloat16", smoke_cfg=SMOKE)
