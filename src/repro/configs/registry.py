"""Architecture registry: every assigned arch (+ the paper's own) as a
selectable config, with per-shape abstract inputs, shardings, smoke builders
and the functions the dry-run lowers.

Cell kinds:
  train    -> trainer train_step(state, batch)   (optimizer update included)
  prefill  -> LM prefill (forward + cache build)
  decode   -> LM decode_step (1 new token against a seq_len KV cache)
  serve    -> family-specific serving fn
  solve    -> the paper's SCSK solver round (tiering arch)
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as mesh_lib

f32 = jnp.float32
i32 = jnp.int32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


@dataclasses.dataclass
class Cell:
    kind: str                       # train | prefill | decode | serve | solve
    inputs: dict[str, Any]          # name -> ShapeDtypeStruct (pytree ok)
    input_specs: dict[str, Any]     # name -> PartitionSpec (pytree ok)
    n_micro: int = 1                # train microbatching


@dataclasses.dataclass
class ArchSpec:
    name: str
    family: str                     # lm | gnn | recsys | tiering
    shapes: tuple[str, ...]
    skips: dict[str, str]
    config_for: Callable[[str], Any]
    cell_for: Callable[[str, Any], Cell]        # (shape, mesh) -> Cell
    loss_fn: Callable | None        # (cfg) -> fn(params, batch)
    serve_fn: Callable | None       # (cfg, shape) -> fn(params, batch)
    abstract_params: Callable       # (cfg) -> pytree of SDS
    param_specs: Callable           # (cfg) -> pytree of PartitionSpec
    optimizer: str = "adamw"
    grad_accum_dtype: str = "float32"
    smoke: Callable | None = None   # () -> (cfg, batch, kind)

    def runnable_shapes(self):
        return [s for s in self.shapes if s not in self.skips]


ARCHS: dict[str, ArchSpec] = {}


def register(spec: ArchSpec):
    ARCHS[spec.name] = spec
    return spec


def get_arch(name: str) -> ArchSpec:
    _load_all()
    return ARCHS[name]


_LOADED = False
_ARCH_MODULES = [
    "kimi_k2_1t_a32b", "llama4_maverick_400b_a17b", "gemma2_2b", "gemma3_12b",
    "internlm2_1_8b", "egnn", "bert4rec", "bst", "deepfm",
    "two_tower_retrieval", "tiering_scsk",
]


def _load_all():
    global _LOADED
    if _LOADED:
        return
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _LOADED = True


def all_archs() -> dict[str, ArchSpec]:
    _load_all()
    return dict(ARCHS)


# =============================================================================
# LM family glue
# =============================================================================

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def lm_cell(cfg, shape: str, mesh, n_micro: int, batch_div: int = 1) -> Cell:
    from repro.models import transformer as T
    dp = mesh_lib.data_axes(mesh)
    if shape == "train_4k":
        b, s = 256 // batch_div, 4096
        if n_micro > 1:
            tok = sds((n_micro, b // n_micro, s), i32)
            spec = P(None, dp, None)
        else:
            tok = sds((b, s), i32)
            spec = P(dp, None)
        return Cell("train",
                    {"tokens": tok, "labels": tok},
                    {"tokens": spec, "labels": spec}, n_micro=n_micro)
    if shape == "prefill_32k":
        b, s = 32, 32768
        return Cell("prefill", {"tokens": sds((b, s), i32)},
                    {"tokens": P(dp, None)})
    if shape in ("decode_32k", "long_500k"):
        b, s = (128, 32768) if shape == "decode_32k" else (1, 524288)
        shard_seq = b == 1
        cache = {"k": sds((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.d_head),
                          cfg.adtype),
                 "v": sds((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.d_head),
                          cfg.adtype)}
        if shard_seq:
            cspec = P(None, None, dp, None, "model")
        else:
            cspec = P(None, dp, None, None, "model")
        return Cell("decode",
                    {"cache": cache, "tokens": sds((b, 1), i32),
                     "cur_len": sds((), i32)},
                    {"cache": {"k": cspec, "v": cspec},
                     "tokens": P(dp, None) if not shard_seq else P(None, None),
                     "cur_len": P()})
    raise KeyError(shape)


def lm_loss(cfg):
    from repro.models import transformer as T
    return lambda params, batch: T.loss_fn(params, batch, cfg)


def lm_serve(cfg, shape):
    from repro.models import transformer as T
    if shape == "prefill_32k":
        def prefill(params, batch):
            h, _ = T.forward(params, batch["tokens"], cfg)
            return h[:, -1, :] @ T.unembed_matrix(params, cfg).astype(h.dtype)
        return prefill

    def decode(params, batch):
        return T.decode_step(params, batch["cache"], batch["tokens"],
                             batch["cur_len"], cfg)
    return decode


def register_lm(name: str, cfg, *, n_micro: int = 1, optimizer="adamw",
                grad_accum_dtype: str = "float32", smoke_cfg=None):
    from repro.models import transformer as T
    skips = {}
    if cfg.pure_full_attention:
        skips["long_500k"] = ("pure full attention: 500k-token context is "
                              "quadratic at prefill; spec says skip "
                              "(DESIGN.md §Arch-applicability)")

    def smoke():
        scfg = smoke_cfg
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, scfg.vocab_size, (2, 32)), i32)
        return scfg, {"tokens": toks, "labels": toks}, "train"

    return register(ArchSpec(
        name=name, family="lm", shapes=LM_SHAPES, skips=skips,
        config_for=lambda shape: cfg,
        cell_for=lambda shape, mesh: lm_cell(cfg, shape, mesh, n_micro),
        loss_fn=lm_loss,
        serve_fn=lm_serve,
        abstract_params=lambda c: jax.eval_shape(
            lambda: T.init_params(jax.random.key(0), c)),
        param_specs=lambda c: T.param_specs(c),
        optimizer=optimizer,
        grad_accum_dtype=grad_accum_dtype,
        smoke=smoke,
    ))


# =============================================================================
# GNN family glue (EGNN)
# =============================================================================

GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")

GNN_DIMS = {
    # nodes, edges(padded to 512 multiple), d_feat, n_classes, task
    "full_graph_sm": (2708, 10752, 1433, 7, "node_class"),
    "minibatch_lg": (180224, 196608, 602, 41, "node_class"),
    "ogb_products": (2449029, 61859328, 100, 47, "node_class"),
    "molecule": (3840, 8192, 16, 1, "graph_reg"),
}


def gnn_cell(cfg, shape: str, mesh) -> Cell:
    dp = mesh_lib.data_axes(mesh)
    n, e, d, c, task = GNN_DIMS[shape]
    inputs = {
        "node_feat": sds((n, d), f32),
        "coords": sds((n, 3), f32),
        "edges": sds((2, e), i32),
    }
    specs = {
        "node_feat": P(None, None),
        "coords": P(None, None),
        "edges": P(None, dp),
    }
    if task == "node_class":
        inputs["labels"] = sds((n,), i32)
        specs["labels"] = P(None)
    else:
        inputs["graph_ids"] = sds((n,), i32)
        inputs["targets"] = sds((128,), f32)
        specs["graph_ids"] = P(None)
        specs["targets"] = P(None)
    return Cell("train", inputs, specs)


# =============================================================================
# RecSys family glue
# =============================================================================

RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")
RECSYS_BATCH = {"train_batch": 65536, "serve_p99": 512, "serve_bulk": 262144}
N_CANDIDATES = 1_000_000
