"""egnn [gnn]: n_layers=4 d_hidden=64 E(n)-equivariant [arXiv:2102.09844].

d_feat / n_classes / task vary per assigned shape (cora / reddit-sampled /
ogb-products / batched molecules) — config_for(shape) reflects that.
Citation graphs carry synthetic 3D positions (EGNN requires coordinates;
DESIGN.md §Arch-applicability). minibatch_lg shapes are the static pads of
the real neighbor sampler in models/sampler.py (fanout 15-10, 1024 seeds).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.models import egnn as G


def _cfg(shape: str) -> G.EGNNConfig:
    n, e, d, c, task = R.GNN_DIMS[shape]
    return G.EGNNConfig(n_layers=4, d_hidden=64, d_feat=d, n_classes=c,
                        task=task)


def _smoke():
    cfg = G.EGNNConfig(n_layers=2, d_hidden=16, d_feat=8, n_classes=3)
    rng = np.random.default_rng(0)
    batch = {
        "node_feat": jnp.asarray(rng.standard_normal((24, 8)), jnp.float32),
        "coords": jnp.asarray(rng.standard_normal((24, 3)), jnp.float32),
        "edges": jnp.asarray(rng.integers(0, 24, (2, 64)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 3, 24), jnp.int32),
    }
    return cfg, batch, "train"


R.register(R.ArchSpec(
    name="egnn", family="gnn",
    shapes=R.GNN_SHAPES, skips={},
    config_for=_cfg,
    cell_for=lambda shape, mesh: R.gnn_cell(_cfg(shape), shape, mesh),
    loss_fn=lambda cfg: (lambda params, batch: G.loss_fn(params, batch, cfg)),
    serve_fn=lambda cfg, shape: (
        lambda params, batch: G.serve_step(params, batch, cfg)),
    abstract_params=lambda cfg: jax.eval_shape(
        lambda: G.init_params(jax.random.key(0), cfg)),
    param_specs=lambda cfg: jax.tree.map(
        lambda _: jax.sharding.PartitionSpec(),
        jax.eval_shape(lambda: G.init_params(jax.random.key(0), cfg))),
    optimizer="adamw",
    smoke=_smoke,
))
