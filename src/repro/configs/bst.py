"""bst [recsys]: embed_dim=32 seq_len=20 n_blocks=1 n_heads=8
mlp=1024-512-256, transformer-seq interaction (Alibaba) [arXiv:1905.06874]."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.launch import mesh as mesh_lib
from repro.models import recsys as M

CONFIG = M.BSTConfig()


def _cell(shape: str, mesh) -> R.Cell:
    dp = mesh_lib.data_axes(mesh)
    if shape in R.RECSYS_BATCH:
        b = R.RECSYS_BATCH[shape]
        kind = "train" if shape == "train_batch" else "serve"
        inputs = {"hist": R.sds((b, CONFIG.seq_len), R.i32),
                  "target": R.sds((b,), R.i32)}
        specs = {"hist": P(dp, None), "target": P(dp)}
        if kind == "train":
            inputs["labels"] = R.sds((b,), R.f32)
            specs["labels"] = P(dp)
        return R.Cell(kind, inputs, specs)
    return R.Cell("serve", {
        "hist": R.sds((1, CONFIG.seq_len), R.i32),
        "cand_ids": R.sds((R.N_CANDIDATES,), R.i32),
    }, {"hist": P(None, None), "cand_ids": P(dp)})


def _serve(cfg, shape):
    if shape == "retrieval_cand":
        return lambda p, b: M.bst_serve_candidates(p, b, cfg)
    return lambda p, b: M.bst_serve(p, b, cfg)


def _smoke():
    cfg = M.BSTConfig(n_items=64, embed_dim=16, seq_len=5, n_heads=4,
                      mlp_dims=(32, 16))
    rng = np.random.default_rng(0)
    batch = {"hist": jnp.asarray(rng.integers(0, 64, (8, 5)), jnp.int32),
             "target": jnp.asarray(rng.integers(0, 64, 8), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 2, 8), jnp.float32)}
    return cfg, batch, "train"


R.register(R.ArchSpec(
    name="bst", family="recsys",
    shapes=R.RECSYS_SHAPES, skips={},
    config_for=lambda shape: CONFIG,
    cell_for=_cell,
    loss_fn=lambda cfg: (lambda p, b: M.bst_loss(p, b, cfg)),
    serve_fn=_serve,
    abstract_params=lambda cfg: jax.eval_shape(
        lambda: M.bst_init(jax.random.key(0), cfg)),
    param_specs=M.bst_specs,
    optimizer="adamw",
    smoke=_smoke,
))
