"""deepfm [recsys]: n_sparse=39 embed_dim=10 mlp=400-400-400 interaction=fm
[arXiv:1703.04247]. Criteo-scale tables: 39 fields x 1M rows."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.launch import mesh as mesh_lib
from repro.models import recsys as M

CONFIG = M.DeepFMConfig()


def _cell(shape: str, mesh) -> R.Cell:
    dp = mesh_lib.data_axes(mesh)
    if shape in R.RECSYS_BATCH:
        b = R.RECSYS_BATCH[shape]
        kind = "train" if shape == "train_batch" else "serve"
        inputs = {"feat_ids": R.sds((b, CONFIG.n_fields), R.i32)}
        specs = {"feat_ids": P(dp, None)}
        if kind == "train":
            inputs["labels"] = R.sds((b,), R.f32)
            specs["labels"] = P(dp)
        return R.Cell(kind, inputs, specs)
    # retrieval_cand: 1 user context x 1M candidate items
    return R.Cell("serve", {
        "user_feat_ids": R.sds((1, CONFIG.n_fields - 1), R.i32),
        "cand_ids": R.sds((R.N_CANDIDATES,), R.i32),
    }, {
        "user_feat_ids": P(None, None),
        "cand_ids": P(dp),
    })


def _serve(cfg, shape):
    if shape == "retrieval_cand":
        return lambda p, b: M.deepfm_serve_candidates(p, b, cfg)
    return lambda p, b: M.deepfm_serve(p, b, cfg)


def _smoke():
    cfg = M.DeepFMConfig(n_fields=6, vocab_per_field=50, embed_dim=8,
                         mlp_dims=(32, 16))
    rng = np.random.default_rng(0)
    batch = {"feat_ids": jnp.asarray(rng.integers(0, 50, (16, 6)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 2, 16), jnp.float32)}
    return cfg, batch, "train"


R.register(R.ArchSpec(
    name="deepfm", family="recsys",
    shapes=R.RECSYS_SHAPES, skips={},
    config_for=lambda shape: CONFIG,
    cell_for=_cell,
    loss_fn=lambda cfg: (lambda p, b: M.deepfm_loss(p, b, cfg)),
    serve_fn=_serve,
    abstract_params=lambda cfg: jax.eval_shape(
        lambda: M.deepfm_init(jax.random.key(0), cfg)),
    param_specs=M.deepfm_specs,
    optimizer="adamw",
    smoke=_smoke,
))
