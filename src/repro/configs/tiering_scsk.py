"""tiering-scsk — the paper's own architecture: SCSK solver rounds and the
two-tier serving path at production scale (paper §4: |D| 10^6..10^12,
|X̄| 10^4..10^6), as dry-run-lowerable units.

Shapes (extra cells beyond the 40 assigned ones):
  solve_dense_m   dense bitset round, C=128k clauses, 1M queries, 8M docs
  solve_dense_l   dense bitset round, C=1M, 4M queries, 64M docs
  solve_optpes_l  Opt/Pes batched bound-refresh round at the _l scale
  solve_sparse_xl sparse-id round, C=1M, m(c) padded to 4096, 256M docs
  serve_route     two-tier classify+match, 64k-query batch

Sharding: clause axis over ('pod','data'); query-word axis over 'model' for
the f-side bit-matvec (psum over 'model'); covered masks replicated.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.launch import mesh as mesh_lib

u32 = jnp.uint32
BOOL = jnp.bool_


@dataclasses.dataclass(frozen=True)
class TieringScaleConfig:
    name: str = "tiering-scsk"
    refresh_k: int = 4096          # Opt/Pes batch width


CONFIG = TieringScaleConfig()

SHAPES = {
    # C, n_queries, n_docs, sparse M (or None)
    "solve_dense_m": (131072, 2 ** 20, 2 ** 23, None),
    "solve_dense_l": (2 ** 20, 2 ** 22, 2 ** 26, None),
    "solve_optpes_l": (2 ** 20, 2 ** 22, 2 ** 26, None),
    "solve_sparse_xl": (2 ** 20, 2 ** 22, 2 ** 28, 4096),
    "serve_route": None,
}


def _cell(shape: str, mesh) -> R.Cell:
    dp = mesh_lib.data_axes(mesh)
    if shape == "serve_route":
        # B bounded: a packed-postings AND-scan reads L*Wd words per query;
        # production match uses compressed postings — this cell sizes the
        # packed-Tier-1 regime (4M docs).
        b, v, nd, k = 4096, 2 ** 17, 2 ** 22, 2 ** 16
        wv, wd = v // 32, nd // 32
        return R.Cell("solve", {
            "tokens": R.sds((b, 8), R.i32),
            "clause_vocab_bits": R.sds((k, wv), u32),
            "postings": R.sds((v, wd), u32),
            "tier1_mask": R.sds((wd,), u32),
        }, {
            "tokens": P(dp, None),
            "clause_vocab_bits": P(dp, None),
            "postings": P(None, "model"),
            "tier1_mask": P(None),
        })
    c, nq, nd, m = SHAPES[shape]
    wq, wd = nq // 32, nd // 32
    inputs = {
        "clause_query_bits": R.sds((c, wq), u32),
        "query_weights": R.sds((nq,), R.f32),
        "covered_q": R.sds((wq,), u32),
        "covered_d": R.sds((wd,), u32),
        "selected": R.sds((c,), BOOL),
        "g_used": R.sds((), R.f32),
        "budget": R.sds((), R.f32),
    }
    specs = {
        "clause_query_bits": P(dp, "model"),
        "query_weights": P(None),
        "covered_q": P(None),
        "covered_d": P(None),
        "selected": P(dp),
        "g_used": P(),
        "budget": P(),
    }
    if m is not None:
        inputs["clause_doc_ids"] = R.sds((c, m), R.i32)
        specs["clause_doc_ids"] = P(dp, None)
    else:
        inputs["clause_doc_bits"] = R.sds((c, wd), u32)
        specs["clause_doc_bits"] = P(dp, "model")
    if shape == "solve_optpes_l":
        for nm in ("fbar", "flow"):
            inputs[nm] = R.sds((c,), R.f32)
            specs[nm] = P(dp)
        for nm in ("gbar", "glow"):           # per-partition bounds [C, P]
            inputs[nm] = R.sds((c, 1), R.f32)
            specs[nm] = P(dp, None)
    return R.Cell("solve", inputs, specs)


def solve_fn(shape: str):
    """Returns fn(batch) for lowering (no trainable params)."""
    from repro.core import bitset
    from repro.core.greedy import ratio_of
    from repro.core.sparse_step import sparse_greedy_step
    from repro.kernels import ops

    if shape == "serve_route":
        def route(batch):
            from repro.serve import matching
            toks = batch["tokens"]
            b = toks.shape[0]
            wv = batch["clause_vocab_bits"].shape[1]
            # query bits over vocab (subset test needs packed queries)
            qbits = jax.vmap(
                lambda t: bitset.from_indices(
                    jnp.maximum(t, 0), wv * 32, valid=t >= 0, unique=True))(toks)
            sub = jax.vmap(
                lambda q: bitset.is_subset(batch["clause_vocab_bits"],
                                           q[None, :]).any())(qbits)
            m2 = matching.match_batch(batch["postings"], toks)
            m1 = m2 & batch["tier1_mask"][None, :]
            return jnp.where(sub[:, None], m1, m2), sub
        return route

    if shape == "solve_sparse_xl":
        def sparse(batch):
            return sparse_greedy_step(
                batch["clause_doc_ids"], batch["clause_query_bits"],
                batch["query_weights"], batch["covered_q"],
                batch["covered_d"], batch["selected"], batch["g_used"],
                batch["budget"])
        return sparse

    if shape == "solve_optpes_l":
        def optpes(batch):
            from repro.core.constraint import GlobalBudget
            from repro.core.optpes import optpes_round
            from repro.core.problem import SCSKProblem
            wq = batch["clause_query_bits"].shape[1]
            nq = batch["query_weights"].shape[0]
            wpad = jnp.zeros(wq * 32, jnp.float32).at[:nq].set(
                batch["query_weights"])
            prob = SCSKProblem(
                clause_query_bits=batch["clause_query_bits"],
                clause_doc_bits=batch["clause_doc_bits"],
                query_weights=wpad, test_weights=wpad,
                n_queries=nq, n_docs=batch["covered_d"].shape[0] * 32)
            state = (batch["covered_q"], batch["covered_d"],
                     batch["selected"], batch["g_used"][None],
                     batch["fbar"], batch["flow"], batch["gbar"],
                     batch["glow"], jnp.float32(0.0))
            return optpes_round(prob, state,
                                GlobalBudget(budget=batch["budget"]),
                                k=CONFIG.refresh_k)
        return optpes

    def dense(batch):
        # gains inside shard_map: the chunked bit-matvec runs on LOCAL
        # [C/dp, Wq/tp] blocks (no resharding of the scan chunks — the
        # baseline pjit version let XLA reshard every W-chunk: 0.62 TB of
        # all-gathers per round, §Perf); one psum over 'model' combines
        # partial gains (C·4B — trivial). Gating and the owner-local row
        # select are the shared `distributed` helpers.
        from repro import distributed

        dp = distributed.current_plan().data_axes
        x = (batch["query_weights"] * (
            1.0 - bitset.unpack(batch["covered_q"]).astype(jnp.float32)
        )[:batch["query_weights"].shape[0]])[:, None]

        def gains(a_q, a_d, xw, cov_d):
            fg_p = ops.bit_matvec(a_q, xw)[:, 0]
            gg_p = ops.coverage_gain(a_d, cov_d).astype(jnp.float32)
            return (jax.lax.psum(fg_p, "model"),
                    jax.lax.psum(gg_p, "model"))

        fused = distributed.mesh_fused(
            gains,
            in_specs=(P(dp, "model"), P(dp, "model"), P("model"),
                      P("model")),
            out_specs=(P(dp), P(dp)))
        if fused is None:
            fg = ops.bit_matvec(batch["clause_query_bits"], x)[:, 0]
            gg = ops.coverage_gain(batch["clause_doc_bits"],
                                   batch["covered_d"]).astype(jnp.float32)
        else:
            fg, gg = fused(batch["clause_query_bits"],
                           batch["clause_doc_bits"], x, batch["covered_d"])
        feasible = (~batch["selected"]) & \
            (batch["g_used"] + gg <= batch["budget"]) & (fg > 0.0)
        score = jnp.where(feasible, ratio_of(fg, gg), -jnp.inf)
        j = jnp.argmax(score)
        # A[j] at a traced index on a (dp x model)-sharded operand makes
        # XLA all-gather the WHOLE matrix (512 GB here — §Perf);
        # `owner_row` lets the owning dp-rank dynamic-slice locally and a
        # [W]-sized psum broadcast the row (identity off-mesh).
        row_q = distributed.owner_row(batch["clause_query_bits"], j,
                                      w_axis="model")
        row_d = distributed.owner_row(batch["clause_doc_bits"], j,
                                      w_axis="model")
        covered_q = batch["covered_q"] | row_q
        covered_d = batch["covered_d"] | row_d
        return covered_q, covered_d, batch["selected"].at[j].set(True), j
    return dense


def _smoke():
    # exercised through the core solver tests; smoke = tiny dense round
    rng = np.random.default_rng(0)
    c, nq, nd = 64, 256, 512
    batch = {
        "clause_query_bits": jnp.asarray(
            rng.integers(0, 2 ** 32, (c, nq // 32), dtype=np.uint32)),
        "clause_doc_bits": jnp.asarray(
            rng.integers(0, 2 ** 32, (c, nd // 32), dtype=np.uint32)),
        "query_weights": jnp.asarray(rng.random(nq), jnp.float32),
        "covered_q": jnp.zeros(nq // 32, u32),
        "covered_d": jnp.zeros(nd // 32, u32),
        "selected": jnp.zeros(c, bool),
        "g_used": jnp.float32(0),
        "budget": jnp.float32(nd),
    }
    return CONFIG, batch, "solve"


R.register(R.ArchSpec(
    name="tiering-scsk", family="tiering",
    shapes=tuple(SHAPES.keys()), skips={},
    config_for=lambda shape: CONFIG,
    cell_for=_cell,
    loss_fn=None,
    serve_fn=lambda cfg, shape: (lambda params, batch: solve_fn(shape)(batch)),
    abstract_params=lambda cfg: {},
    param_specs=lambda cfg: {},
    optimizer="adamw",
    smoke=_smoke,
))
