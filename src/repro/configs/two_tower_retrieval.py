"""two-tower-retrieval [recsys]: embed_dim=256 tower_mlp=1024-512-256
interaction=dot, sampled-softmax retrieval [RecSys'19 YouTube].

This is the arch the paper's technique integrates with first-class:
`retrieval_cand` has a tiered variant (models/tiered_retrieval.py) where
Tier-1 candidates are selected by the SCSK solver — see §Perf hillclimb."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.launch import mesh as mesh_lib
from repro.models import recsys as M

CONFIG = M.TwoTowerConfig()


def _cell(shape: str, mesh) -> R.Cell:
    dp = mesh_lib.data_axes(mesh)
    fu, fi = CONFIG.n_user_fields, CONFIG.n_item_fields
    if shape in R.RECSYS_BATCH:
        b = R.RECSYS_BATCH[shape]
        kind = "train" if shape == "train_batch" else "serve"
        inputs = {"user_ids": R.sds((b, fu), R.i32),
                  "item_ids": R.sds((b, fi), R.i32)}
        specs = {"user_ids": P(dp, None), "item_ids": P(dp, None)}
        if kind == "train":
            inputs["item_logq"] = R.sds((b,), R.f32)
            specs["item_logq"] = P(dp)
        return R.Cell(kind, inputs, specs)
    if shape == "retrieval_cand_tiered":
        # paper technique: Tier-1 = budget-frac of the corpus (B = |D|/2)
        n1 = R.N_CANDIDATES // 2
        return R.Cell("serve", {
            "user_ids": R.sds((1, fu), R.i32),
            "tier1_emb": R.sds((n1, CONFIG.embed_dim), R.f32),
            "tier1_ids": R.sds((n1,), R.i32),
        }, {"user_ids": P(None, None), "tier1_emb": P(dp, None),
            "tier1_ids": P(dp)})
    return R.Cell("serve", {
        "user_ids": R.sds((1, fu), R.i32),
        "cand_emb": R.sds((R.N_CANDIDATES, CONFIG.embed_dim), R.f32),
    }, {"user_ids": P(None, None), "cand_emb": P(dp, None)})


def _serve(cfg, shape):
    if shape == "retrieval_cand":
        return lambda p, b: M.twotower_serve_candidates(p, b, cfg)
    if shape == "retrieval_cand_tiered":
        return lambda p, b: M.twotower_serve_candidates_tiered(p, b, cfg)
    return lambda p, b: M.twotower_serve(p, b, cfg)


def _smoke():
    cfg = M.TwoTowerConfig(n_user_fields=3, n_item_fields=3,
                           vocab_per_field=50, field_dim=8,
                           tower_dims=(32, 16), embed_dim=16)
    rng = np.random.default_rng(0)
    batch = {"user_ids": jnp.asarray(rng.integers(0, 50, (8, 3)), jnp.int32),
             "item_ids": jnp.asarray(rng.integers(0, 50, (8, 3)), jnp.int32),
             "item_logq": jnp.zeros(8, jnp.float32)}
    return cfg, batch, "train"


R.register(R.ArchSpec(
    name="two-tower-retrieval", family="recsys",
    shapes=R.RECSYS_SHAPES + ("retrieval_cand_tiered",), skips={},
    config_for=lambda shape: CONFIG,
    cell_for=_cell,
    loss_fn=lambda cfg: (lambda p, b: M.twotower_loss(p, b, cfg)),
    serve_fn=_serve,
    abstract_params=lambda cfg: jax.eval_shape(
        lambda: M.twotower_init(jax.random.key(0), cfg)),
    param_specs=M.twotower_specs,
    optimizer="adamw",
    smoke=_smoke,
))
