from repro.configs.registry import ARCHS, get_arch  # noqa: F401
