"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global, 128k context, QK-norm [hf:google/gemma-3].

Hybrid local:global (5:1, window 1024) -> long_500k RUNS for this arch.
"""
from repro.configs.registry import register_lm
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma3-12b",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_head=256,
    d_ff=15360, vocab_size=262144,
    local_window=1024, global_every=6, qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True, embed_scale=True,
    pure_full_attention=False,
)

SMOKE = TransformerConfig(
    name="gemma3-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=512,
    local_window=8, global_every=3, qk_norm=True,
    tie_embeddings=True, embed_scale=True, pure_full_attention=False,
)

register_lm("gemma3-12b", CONFIG, n_micro=2, smoke_cfg=SMOKE)
