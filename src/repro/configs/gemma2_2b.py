"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000 —
local+global alternating attention, logit softcaps [arXiv:2408.00118].

Hybrid local:global (1:1, window 4096) -> long_500k RUNS for this arch.
"""
from repro.configs.registry import register_lm
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma2-2b",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=9216, vocab_size=256000,
    local_window=4096, global_every=2,
    attn_softcap=50.0, final_softcap=30.0,
    tie_embeddings=True, embed_scale=True,
    pure_full_attention=False,
)

SMOKE = TransformerConfig(
    name="gemma2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=512,
    local_window=8, global_every=2, attn_softcap=50.0, final_softcap=30.0,
    tie_embeddings=True, embed_scale=True, pure_full_attention=False,
)

register_lm("gemma2-2b", CONFIG, n_micro=1, smoke_cfg=SMOKE)
