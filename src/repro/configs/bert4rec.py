"""bert4rec [recsys]: embed_dim=64 n_blocks=2 n_heads=2 seq_len=200,
bidirectional sequence encoder, masked-item objective [arXiv:1904.06690].
1M-item catalog; training uses sampled softmax (8192 shared negatives)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.launch import mesh as mesh_lib
from repro.models import recsys as M

CONFIG = M.Bert4RecConfig()


def _cell(shape: str, mesh) -> R.Cell:
    dp = mesh_lib.data_axes(mesh)
    s = CONFIG.seq_len
    if shape == "train_batch":
        b = R.RECSYS_BATCH[shape]
        return R.Cell("train", {
            "seq": R.sds((b, s), R.i32),
            "labels": R.sds((b, s), R.i32),
            "negatives": R.sds((CONFIG.n_negatives,), R.i32),
        }, {"seq": P(dp, None), "labels": P(dp, None), "negatives": P(None)})
    if shape in ("serve_p99", "serve_bulk"):
        b = R.RECSYS_BATCH[shape]
        return R.Cell("serve", {"seq": R.sds((b, s), R.i32)},
                      {"seq": P(dp, None)})
    return R.Cell("serve", {
        "seq": R.sds((1, s), R.i32),
        "cand_ids": R.sds((R.N_CANDIDATES,), R.i32),
    }, {"seq": P(None, None), "cand_ids": P(dp)})


def _serve(cfg, shape):
    if shape == "retrieval_cand":
        return lambda p, b: M.bert4rec_serve_candidates(p, b, cfg)
    return lambda p, b: M.bert4rec_serve(p, b, cfg)


def _smoke():
    cfg = M.Bert4RecConfig(n_items=64, embed_dim=16, seq_len=12, n_blocks=1,
                           n_heads=2, n_negatives=16)
    rng = np.random.default_rng(0)
    labels = np.full((8, 12), -100)
    labels[:, [2, 7]] = rng.integers(0, 64, (8, 2))
    seq = rng.integers(0, 64, (8, 12))
    seq[:, [2, 7]] = 64  # mask token
    batch = {"seq": jnp.asarray(seq, jnp.int32),
             "labels": jnp.asarray(labels, jnp.int32),
             "negatives": jnp.asarray(rng.integers(0, 64, 16), jnp.int32)}
    return cfg, batch, "train"


R.register(R.ArchSpec(
    name="bert4rec", family="recsys",
    shapes=R.RECSYS_SHAPES, skips={},
    config_for=lambda shape: CONFIG,
    cell_for=_cell,
    loss_fn=lambda cfg: (lambda p, b: M.bert4rec_loss(p, b, cfg)),
    serve_fn=_serve,
    abstract_params=lambda cfg: jax.eval_shape(
        lambda: M.bert4rec_init(jax.random.key(0), cfg)),
    param_specs=M.bert4rec_specs,
    optimizer="adamw",
    smoke=_smoke,
))
