"""Sharded checkpointing with atomic commit, checksums and elastic restore.

Layout per checkpoint:
  <dir>/step_<N>/
    arrays.npz         every leaf, keyed by '/'-joined tree path
    manifest.json      step, tree structure, per-array crc32, extra metadata
    COMMITTED          sentinel written last (atomic rename of tmp dir)

Restore is mesh-agnostic: arrays come back as host numpy and are re-placed
with whatever sharding the *current* mesh dictates — that is the elastic
re-shard path (save on mesh A, resume on mesh B), covered by tests. On a
multi-host pod each host saves only the shards it owns (addressable shards)
under the same protocol; this container is single-host so the save path
writes full arrays.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np


def _flat_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, state: dict, extra: dict | None = None,
         *, keep_last: int = 3, async_: bool = False) -> str:
    """state: arbitrary pytree dict (e.g. {'params':..., 'opt':..., 'rng':...})."""
    def _do():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        arrays = _flat_with_paths(state)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "extra": extra or {},
            "treedef": jax.tree_util.tree_structure(state).__repr__(),
            "crc": {k: zlib.crc32(v.tobytes()) for k, v in arrays.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep_last)
        return final

    if async_:
        t = threading.Thread(target=_do, daemon=True)
        t.start()
        return os.path.join(ckpt_dir, f"step_{step:08d}")
    return _do()


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "COMMITTED")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: dict, step: int | None = None,
            *, verify: bool = True):
    """Returns (step, state) with state matching `template`'s tree structure,
    leaves as host numpy (caller re-shards onto the current mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    if verify:
        for k in data.files:
            crc = zlib.crc32(data[k].tobytes())
            if crc != manifest["crc"][k]:
                raise IOError(f"checksum mismatch for {k} in {d}")
    arrays = _flat_with_paths(template)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    keys = list(arrays.keys())
    assert len(keys) == len(leaves)
    restored = [data[k] for k in keys]
    state = jax.tree_util.tree_unflatten(treedef, restored)
    return step, state, manifest["extra"]
