"""Optimizers: AdamW (low-precision states) and Adafactor (for 1T-param configs).

AdamW keeps m/v in a configurable dtype (bf16 default) — at 512-chip scale
this halves optimizer HBM, which the kimi-k2 memory analysis needs. Adafactor
factors the second moment into row/col statistics (O(n+m) instead of O(nm)),
the standard choice when even bf16 Adam states don't fit (1T params).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"              # adamw | adafactor | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    state_dtype: str = "bfloat16"    # adamw m/v dtype
    min_dim_factored: int = 128      # adafactor: factor only matrices >= this
    # scan the elementwise update over axis 0 of layer-stacked leaves: the
    # f32 temporaries then cover ONE layer slice instead of the whole stack
    # (kimi-k2: three 5.4 GB/device expert leaves -> ~90 MB working set).
    scan_update_axis0: bool = False
    scan_update_min_bytes: int = 1 << 28


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps) / max(1, cfg.decay_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def _global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_scale(grads, max_norm: float):
    """Global-norm clip as a SCALAR — folded into the per-leaf update so no
    scaled f32 copy of the whole gradient tree is ever materialized (at 1T
    params that copy alone is 16 GB/device)."""
    norm = _global_norm(grads)
    if max_norm <= 0:
        return jnp.float32(1.0), norm
    return jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9)), norm


def _maybe_scan_axis0(cfg: OptimizerConfig, fn, args: tuple):
    """Apply a per-leaf update fn, scanning over axis 0 for big stacked
    leaves (memory: one slice of temporaries live at a time)."""
    lead = args[0]
    big = lead.size * lead.dtype.itemsize >= cfg.scan_update_min_bytes
    same_lead = all(a.ndim >= 1 and a.shape[:1] == lead.shape[:1]
                    for a in args)
    if cfg.scan_update_axis0 and big and lead.ndim >= 3 and same_lead \
            and lead.shape[0] > 1:
        _, outs = jax.lax.scan(lambda c, xs: (c, fn(*xs)), None, args)
        return outs
    return fn(*args)


# -----------------------------------------------------------------------------
# AdamW
# -----------------------------------------------------------------------------

def adamw_init(cfg: OptimizerConfig, params):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adamw_update(cfg: OptimizerConfig, grads, state, params, step):
    scale, gnorm = clip_scale(grads, cfg.grad_clip)
    lr = schedule(cfg, step)
    c1 = 1.0 - cfg.b1 ** (step.astype(jnp.float32) + 1)
    c2 = 1.0 - cfg.b2 ** (step.astype(jnp.float32) + 1)
    dt = jnp.dtype(cfg.state_dtype)

    def upd_elem(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (-lr * delta).astype(p.dtype), m32.astype(dt), v32.astype(dt)

    def upd(g, m, v, p):
        return _maybe_scan_axis0(cfg, upd_elem, (g, m, v, p))

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    updates = jax.tree.map(lambda o: o[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return updates, {"m": m, "v": v}, {"grad_norm": gnorm, "lr": lr}


# -----------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), momentum-free
# -----------------------------------------------------------------------------

def _factored(cfg: OptimizerConfig, shape) -> bool:
    return (len(shape) >= 2 and shape[-1] >= cfg.min_dim_factored
            and shape[-2] >= cfg.min_dim_factored)


def adafactor_init(cfg: OptimizerConfig, params):
    def init_one(p):
        if _factored(cfg, p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"fac": jax.tree.map(init_one, params,
                                is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(cfg: OptimizerConfig, grads, state, params, step):
    scale, gnorm = clip_scale(grads, cfg.grad_clip)
    lr = schedule(cfg, step)
    beta2 = 1.0 - (step.astype(jnp.float32) + 1) ** -0.8

    def _core(g, p, vr=None, vc=None, v=None):
        g32 = g.astype(jnp.float32) * scale
        sq = g32 * g32 + 1e-30
        if vr is not None:
            vr = beta2 * vr + (1 - beta2) * sq.mean(axis=-1)
            vc = beta2 * vc + (1 - beta2) * sq.mean(axis=-2)
            denom = (vr[..., :, None] / jnp.maximum(
                vr.mean(axis=-1, keepdims=True)[..., :, None], 1e-30)) \
                * vc[..., None, :]
            pre = g32 * jax.lax.rsqrt(jnp.maximum(denom, 1e-30))
        else:
            v = beta2 * v + (1 - beta2) * sq
            pre = g32 * jax.lax.rsqrt(jnp.maximum(v, 1e-30))
        # update clipping by RMS (Adafactor's d=1.0)
        rms = jnp.sqrt(jnp.mean(pre * pre) + 1e-30)
        pre = pre / jnp.maximum(1.0, rms)
        delta = pre + cfg.weight_decay * p.astype(jnp.float32)
        if vr is not None:
            return (-lr * delta).astype(p.dtype), vr, vc
        return (-lr * delta).astype(p.dtype), v

    def upd(g, s, p):
        if "vr" in s:
            delta, vr, vc = _maybe_scan_axis0(
                cfg, lambda g_, p_, vr_, vc_: _core(g_, p_, vr=vr_, vc=vc_),
                (g, p, s["vr"], s["vc"]))
            return delta, {"vr": vr, "vc": vc}
        delta, v = _maybe_scan_axis0(
            cfg, lambda g_, p_, v_: _core(g_, p_, v=v_), (g, p, s["v"]))
        return delta, {"v": v}

    g_leaves, treedef = jax.tree.flatten(grads)
    s_leaves = treedef.flatten_up_to(state["fac"])
    p_leaves = treedef.flatten_up_to(params)
    out = [upd(g, s, p) for g, s, p in zip(g_leaves, s_leaves, p_leaves)]
    updates = jax.tree.unflatten(treedef, [o[0] for o in out])
    fac = jax.tree.unflatten(treedef, [o[1] for o in out])
    return updates, {"fac": fac}, {"grad_norm": gnorm, "lr": lr}


# -----------------------------------------------------------------------------
# registry
# -----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Any
    update: Any


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "adamw":
        return Optimizer(init=functools.partial(adamw_init, cfg),
                         update=functools.partial(adamw_update, cfg))
    if cfg.name == "adafactor":
        return Optimizer(init=functools.partial(adafactor_init, cfg),
                         update=functools.partial(adafactor_update, cfg))
    if cfg.name == "sgd":
        def sgd_init(params):
            return {}

        def sgd_update(grads, state, params, step):
            scale, gnorm = clip_scale(grads, cfg.grad_clip)
            lr = schedule(cfg, step)
            ups = jax.tree.map(
                lambda g, p: (-lr * scale * g.astype(jnp.float32)
                              ).astype(p.dtype), grads, params)
            return ups, state, {"grad_norm": gnorm, "lr": lr}
        return Optimizer(init=sgd_init, update=sgd_update)
    raise ValueError(cfg.name)
