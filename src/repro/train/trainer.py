"""Generic trainer: grad-accumulation, compression hook, fault-tolerant loop.

`make_train_step(loss_fn, opt_cfg, ...)` builds a single jittable
train_step(state, batch) -> (state, metrics) where
state = {params, opt, ef, step}. This is the exact function the multi-pod
dry-run lowers — optimizer update and compression numerics included.

`TrainingDriver` is the host-side loop: checkpoint/restart (auto-resume from
the newest committed checkpoint), failure injection for tests, and a
deadline-based straggler policy on the data iterator.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.distributed import compression as comp
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import OptimizerConfig, make_optimizer


def make_train_step(
    loss_fn: Callable,                   # (params, batch) -> (loss, metrics)
    opt_cfg: OptimizerConfig,
    *,
    n_micro: int = 1,
    compression: comp.CompressionConfig = comp.CompressionConfig(),
    grad_accum_dtype: str = "float32",
):
    opt = make_optimizer(opt_cfg)

    def init_state(params):
        return {
            "params": params,
            "opt": opt.init(params),
            "ef": comp.init_error_state(compression, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def train_step(state, batch):
        params = state["params"]
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            # batch leaves are [n_micro, ...]; scan accumulates grads so only
            # one microbatch's activations are live at a time.
            acc_dt = jnp.dtype(grad_accum_dtype)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, metrics), grads = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dt), acc, grads)
                return (acc, loss_acc + loss), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (grads, loss), metrics = jax.lax.scan(
                body, (zeros, jnp.float32(0.0)), batch)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        grads, ef = comp.compress_grads(compression, grads, state["ef"])
        updates, opt_state, opt_metrics = opt.update(
            grads, state["opt"], params, state["step"])
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                              params, updates)
        new_state = {"params": params, "opt": opt_state, "ef": ef,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_state, metrics

    return init_state, train_step


@dataclasses.dataclass
class DriverConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_last: int = 3
    max_steps: int = 200
    fail_at_step: int = -1          # failure injection (tests)
    batch_deadline_s: float | None = None   # straggler policy


class StragglerStats:
    def __init__(self):
        self.skipped = 0
        self.fetch_times: list[float] = []


class TrainingDriver:
    """Fault-tolerant host loop around a jitted train_step."""

    def __init__(self, init_state, train_step, cfg: DriverConfig):
        self.init_state = init_state
        self.train_step = jax.jit(train_step, donate_argnums=(0,))
        self.cfg = cfg
        self.straggler = StragglerStats()

    def run(self, params_init: Callable[[], Any],
            batches: Iterator[Any]) -> tuple[dict, list[dict]]:
        cfg = self.cfg
        os.makedirs(cfg.ckpt_dir, exist_ok=True)
        step0 = ckpt_lib.latest_step(cfg.ckpt_dir)
        if step0 is not None:
            template = self.init_state(params_init())
            _, state, _ = ckpt_lib.restore(cfg.ckpt_dir, template)
            state = jax.tree.map(jnp.asarray, state)
        else:
            state = self.init_state(params_init())

        history: list[dict] = []
        while int(state["step"]) < cfg.max_steps:
            t0 = time.perf_counter()
            batch = next(batches)
            fetch = time.perf_counter() - t0
            self.straggler.fetch_times.append(fetch)
            if (cfg.batch_deadline_s is not None
                    and fetch > cfg.batch_deadline_s):
                # straggler mitigation: drop the late batch, take the next
                self.straggler.skipped += 1
                continue
            state, metrics = self.train_step(state, batch)
            step = int(state["step"])
            history.append({k: float(v) for k, v in metrics.items()})
            if step % cfg.ckpt_every == 0 or step == cfg.max_steps:
                ckpt_lib.save(cfg.ckpt_dir, step, jax.device_get(state),
                              keep_last=cfg.keep_last)
            if cfg.fail_at_step == step:
                raise RuntimeError(f"injected failure at step {step}")
        return state, history
