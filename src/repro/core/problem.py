"""SCSKProblem: device-resident operands + batched marginal-gain oracles.

The paper's objective/constraint pair (eq. 12):
    f(X) = P_{q~Qn}[∃c∈X: c ⊆ q]      (monotone submodular, Thm 3.3)
    g(X) = |∪_{c∈X} m(c)|             (set cover, monotone submodular, Thm 3.4)

State is two packed bitsets (covered queries, covered docs). Marginal gains
are one fused kernel call each:
    f(j|X) for all j = A_q  @ (w ⊙ uncovered_q)   (weighted bit-matvec)
    g(j|X) for all j = popcount(A_d & ~covered_d)  (AND-NOT popcount)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset
from repro.core.state import SolverState
from repro.kernels import ops


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["clause_query_bits", "clause_doc_bits", "query_weights",
                 "test_weights"],
    meta_fields=["n_queries", "n_docs"],
)
@dataclasses.dataclass(frozen=True)
class SCSKProblem:
    clause_query_bits: jax.Array    # uint32 [C, Wq]
    clause_doc_bits: jax.Array      # uint32 [C, Wd]
    query_weights: jax.Array        # f32 [Wq*32] (zero-padded empirical probs)
    test_weights: jax.Array         # f32 [Wq*32] (test-split probs, eval only)
    n_queries: int
    n_docs: int

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_data(cls, data) -> "SCSKProblem":
        """From data.incidence.TieringData."""
        wq = data.clause_query_bits.shape[1]
        wtr = np.zeros(wq * 32, np.float32)
        wtr[:data.n_queries] = data.log.train_weights
        wte = np.zeros(wq * 32, np.float32)
        wte[:data.n_queries] = data.log.test_weights
        return cls(
            clause_query_bits=jnp.asarray(data.clause_query_bits),
            clause_doc_bits=jnp.asarray(data.clause_doc_bits),
            query_weights=jnp.asarray(wtr),
            test_weights=jnp.asarray(wte),
            n_queries=data.n_queries,
            n_docs=data.n_docs,
        )

    def with_weights(self, train_weights, test_weights=None) -> "SCSKProblem":
        """Reweighted copy for the SAME query universe (online re-tiering).

        Swaps only the empirical distribution; the packed clause/query/doc
        bitsets are shared with `self` (no incidence rebuild, no host->device
        transfer of the big operands). Solving the result must match solving a
        problem freshly built with the same weights — reuse is a pure
        optimization, asserted by tests/test_stream.py.

        Weights may be length `n_queries` (zero-padded here, like
        `from_data`) or already padded to `wq * 32`.

        Corpus appends (repro.ingest) never change the query universe, so a
        reweighted problem stays valid across `with_doc_block` growth — but
        the DOC side does change width: a `SolverState` captured before an
        append has `covered_d` at the old `wd` and cannot seed a post-append
        solve (old clauses may match appended docs, so zero-padding the
        bitset would under-count g). Re-derive it at the new width with
        `state_for(np.nonzero(selected)[0])`; `stream.prune_state` raises a
        `ValueError` naming the widths if handed a stale-width state.
        """
        def pad(w) -> jax.Array:
            w = np.asarray(w, np.float32)
            if w.shape != (self.n_queries,) and w.shape != (self.wq * 32,):
                raise ValueError(
                    f"weights must have shape ({self.n_queries},) or "
                    f"({self.wq * 32},), got {w.shape}")
            if w.shape[0] != self.wq * 32:
                padded = np.zeros(self.wq * 32, np.float32)
                padded[:w.shape[0]] = w
                w = padded
            return jnp.asarray(w)

        return dataclasses.replace(
            self,
            query_weights=pad(train_weights),
            test_weights=self.test_weights if test_weights is None
            else pad(test_weights),
        )

    def with_doc_block(self, clause_cols, n_docs: int) -> "SCSKProblem":
        """Grown copy for an appended word-aligned doc block (repro.ingest).

        `clause_cols` is the uint32 [C, wb] clause×block incidence from
        `data.incidence.append_docs` (`AppendDelta.clause_cols`); the block's
        columns are concatenated onto `clause_doc_bits` and `n_docs` becomes
        the post-append count. The query side (bitsets and weights) is
        shared with `self` untouched — documents never change the query
        universe. States captured against `self` are stale at the new width;
        see `with_weights` notes.
        """
        cols = jnp.asarray(np.asarray(clause_cols, np.uint32))
        if cols.shape[0] != self.n_clauses:
            raise ValueError(
                f"clause_cols must have {self.n_clauses} rows, "
                f"got {cols.shape[0]}")
        if n_docs < self.n_docs:
            raise ValueError("doc blocks are append-only: n_docs "
                             f"{n_docs} < current {self.n_docs}")
        return dataclasses.replace(
            self,
            clause_doc_bits=jnp.concatenate(
                [self.clause_doc_bits, cols], axis=1),
            n_docs=n_docs,
        )

    # -- shapes ---------------------------------------------------------------
    @property
    def n_clauses(self) -> int:
        return self.clause_query_bits.shape[0]

    @property
    def wq(self) -> int:
        return self.clause_query_bits.shape[1]

    @property
    def wd(self) -> int:
        return self.clause_doc_bits.shape[1]

    def empty_state(self):
        return (jnp.zeros(self.wq, jnp.uint32), jnp.zeros(self.wd, jnp.uint32))

    # -- solver state ---------------------------------------------------------
    def init_state(self) -> SolverState:
        """Fresh (cold-start) solver state: nothing selected, nothing covered."""
        covered_q, covered_d = self.empty_state()
        return SolverState(
            covered_q=covered_q,
            covered_d=covered_d,
            selected=jnp.zeros(self.n_clauses, bool),
            g_used=jnp.float32(0.0),
            step=jnp.int32(0),
        )

    def state_for(self, kept: np.ndarray) -> SolverState:
        """Exact `SolverState` for a clause subset, as if it were a solve
        prefix: covered bitsets re-OR'd on host, `g_used` recomputed."""
        kept = np.asarray(kept, np.int64)
        selected = np.zeros(self.n_clauses, bool)
        selected[kept] = True
        if len(kept):
            covered_q = np.bitwise_or.reduce(
                np.asarray(self.clause_query_bits)[kept], axis=0)
            covered_d = np.bitwise_or.reduce(
                np.asarray(self.clause_doc_bits)[kept], axis=0)
        else:
            covered_q = np.zeros(self.wq, np.uint32)
            covered_d = np.zeros(self.wd, np.uint32)
        return SolverState(
            covered_q=jnp.asarray(covered_q),
            covered_d=jnp.asarray(covered_d),
            selected=jnp.asarray(selected),
            g_used=jnp.float32(int(np.bitwise_count(covered_d).sum())),
            step=jnp.int32(len(kept)),
        )

    def apply(self, state: SolverState, j: jax.Array) -> SolverState:
        """Select clause j: fold its coverage into the state. jit-safe."""
        covered_q, covered_d = self.add_clause(state.covered_q,
                                               state.covered_d, j)
        return SolverState(
            covered_q=covered_q,
            covered_d=covered_d,
            selected=state.selected.at[j].set(True),
            g_used=self.g_value(covered_d),
            step=state.step + 1,
        )

    # -- oracles --------------------------------------------------------------
    def f_gains(self, covered_q: jax.Array, *, rows: jax.Array | None = None,
                weights: jax.Array | None = None) -> jax.Array:
        """Weighted f(j|X) for all clauses (or a gathered row subset)."""
        w = self.query_weights if weights is None else weights
        x = w * (1.0 - bitset.unpack(covered_q).astype(jnp.float32))
        a = self.clause_query_bits if rows is None else rows
        return ops.bit_matvec(a, x[:, None])[:, 0]

    def g_gains(self, covered_d: jax.Array, *, rows: jax.Array | None = None,
                bounds: tuple[int, ...] | None = None) -> jax.Array:
        """g(j|X) for all clauses (or a gathered row subset).

        With `bounds` (word offsets of a doc-space partition, see
        `core.constraint`), returns the per-partition cost-gain matrix
        g_k(j|X) as f32 [C, P] via the batched `ops.partition_gain` kernel;
        without it, the scalar-knapsack f32 [C] path is unchanged.
        """
        a = self.clause_doc_bits if rows is None else rows
        if bounds is None:
            return ops.coverage_gain(a, covered_d).astype(jnp.float32)
        return ops.partition_gain(a, covered_d, bounds).astype(jnp.float32)

    def f_value(self, covered_q: jax.Array, *, weights: jax.Array | None = None) -> jax.Array:
        w = self.query_weights if weights is None else weights
        return jnp.sum(w * bitset.unpack(covered_q).astype(jnp.float32))

    def g_value(self, covered_d: jax.Array,
                bounds: tuple[int, ...] | None = None) -> jax.Array:
        """g(X) = |covered_d|; with `bounds`, the per-partition fills
        g_k(X) as f32 [P]."""
        if bounds is None:
            return bitset.popcount(covered_d).sum().astype(jnp.float32)
        return jnp.stack(
            [bitset.popcount(covered_d[lo:hi]).sum()
             for lo, hi in zip(bounds, bounds[1:])]).astype(jnp.float32)

    def add_clause(self, covered_q: jax.Array, covered_d: jax.Array, j: jax.Array):
        return (covered_q | self.clause_query_bits[j],
                covered_d | self.clause_doc_bits[j])


@dataclasses.dataclass
class SolverResult:
    """Common result record for every solver (drives Figs. 2/3/5)."""
    name: str
    selected: np.ndarray            # bool [C]
    order: list[int]                # selections made BY THIS CALL, in order
    f_final: float
    g_final: float
    f_history: np.ndarray
    g_history: np.ndarray
    time_history: np.ndarray        # cumulative wall seconds per recorded point
    n_exact_evals: int = 0          # marginal-gain evaluations (laziness metric)
    state: SolverState | None = None  # final state; resume via solve(..., state=)
    extra: dict = dataclasses.field(default_factory=dict)  # solver-specific

    def summary(self) -> str:
        return (f"{self.name}: f={self.f_final:.4f} g={self.g_final:.0f} "
                f"|X|={int(self.selected.sum())} evals={self.n_exact_evals}")
