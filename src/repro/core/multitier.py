"""Multi-tier (>2) generalization — the paper's §6 future work, built the
way §1 prescribes: "applied to more than two tiers by iteratively splitting
a tier into two".

Tier construction (n tiers, budgets B_1 < B_2 < ... < B_{n-1} < |D|):
  level n-1: solve SCSK over the FULL corpus with budget B_{n-1} -> D_{n-1}
  level n-2: restrict the corpus to D_{n-1} (mask the clause->doc incidence)
             and solve with budget B_{n-2} -> D_{n-2} ⊆ D_{n-1}
  ... nesting holds by construction.
Routing: a query goes to the SMALLEST tier whose clause set covers it;
Theorem 3.1 applies per level, so every tier serves complete match sets for
its eligible queries (verified exhaustively in tests).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import bitset
from repro.core.config import SolveConfig
from repro.core.problem import SCSKProblem
from repro.core.tiering import ClauseTiering


@dataclasses.dataclass
class MultiTiering:
    tiers: list[ClauseTiering]        # smallest (tier 1) first
    tier_docs: list[np.ndarray]       # bool [n_docs] per tier (nested), full last

    def route(self, query_bits: np.ndarray) -> np.ndarray:
        """Per query: index of the smallest eligible tier (0-based);
        len(tiers) = the full index (always eligible)."""
        out = np.full(query_bits.shape[0], len(self.tiers), np.int32)
        for level in range(len(self.tiers) - 1, -1, -1):
            elig = self.tiers[level].classify_queries(query_bits)
            out[elig] = level
        return out

    def coverage(self, query_bits: np.ndarray, weights: np.ndarray) -> list[float]:
        """Traffic fraction served at each tier (last entry = full index)."""
        routes = self.route(query_bits)
        return [float(weights[routes == k].sum())
                for k in range(len(self.tiers) + 1)]

    def expected_cost(self, query_bits: np.ndarray, weights: np.ndarray) -> float:
        """Expected scanned-doc fraction per query vs the untiered system."""
        routes = self.route(query_bits)
        sizes = [d.mean() for d in self.tier_docs] + [1.0]
        cov = self.coverage(query_bits, weights)
        return float(sum(c * sizes[k] for k, c in enumerate(cov)))


def build_multitier(data, budgets: list[int], *, solver="optpes",
                    **solver_kw) -> MultiTiering:
    """budgets: ascending Tier-1..Tier-(n-1) document budgets.
    `solver` is a registry name (or a legacy `(problem, budget, **kw)`
    callable); solver-specific knobs ride in `solver_kw`.

    Construction: ONE greedy solve at the largest budget; each smaller tier
    is the longest greedy-path PREFIX fitting its budget. This is exactly
    the paper's Fig.-3 observation ("the greedy algorithm finds the entire
    solution path for different values of B") turned into the §6 multi-tier
    extension — prefixes give X_1 ⊆ X_2 ⊆ ... so full-corpus match-set
    unions nest and Theorem 3.1 holds *globally* at every level.

    (A naive recursive corpus-restriction split is NOT correct: a clause
    selected only at the inner level can match documents outside the parent
    tier; tests pin this down via `verify_multitier`.)
    """
    assert list(budgets) == sorted(budgets), "budgets must ascend"
    n_docs = data.n_docs
    problem = SCSKProblem.from_data(data)
    if callable(solver):
        result = solver(problem, budgets[-1], **solver_kw)
    else:
        from repro.core import registry
        cfg_kw = {k: solver_kw.pop(k) for k in
                  ("max_steps", "record_every", "time_limit", "seed",
                   "stop_policy") if k in solver_kw}
        result = registry.solve(problem, SolveConfig(
            budget=float(budgets[-1]), solver=solver, options=solver_kw,
            **cfg_kw))
    order = result.order
    assert order, "empty solve"

    # cumulative doc coverage along the greedy path
    tiers: list[ClauseTiering] = []
    tier_docs: list[np.ndarray] = []
    cum = np.zeros(data.clause_doc_bits.shape[1], np.uint32)
    cum_sizes = []
    for j in order:
        cum = cum | data.clause_doc_bits[j]
        cum_sizes.append(int(bitset.np_popcount(cum)))
    for budget in budgets:
        k = 0
        while k < len(order) and cum_sizes[k] <= budget:
            k += 1
        sel = np.zeros(problem.n_clauses, bool)
        sel[order[:k]] = True
        tier = ClauseTiering.from_selection(data, sel)
        tiers.append(tier)
        tier_docs.append(tier.tier1_docs)
    return MultiTiering(tiers=tiers, tier_docs=tier_docs)


def verify_multitier(mt: MultiTiering, data) -> bool:
    """Per-level Theorem 3.1 + nesting. Exhaustive over the query log."""
    for k in range(len(mt.tiers) - 1):
        if not np.all(mt.tier_docs[k] <= mt.tier_docs[k + 1]):
            return False
    routes = mt.route(data.log.query_bits)
    t_bits = [bitset.np_pack(d) for d in mt.tier_docs]
    for k, tb in enumerate(t_bits):
        q_at_k = routes == k
        if not q_at_k.any():
            continue
        missing = np.any(data.query_doc_bits[q_at_k] & ~tb[None, :])
        if missing:
            return False
    return True
