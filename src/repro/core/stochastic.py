"""Stochastic greedy: minibatch f-gain estimates (paper §3.2's "stochastic
version [15]" — Karimi et al. 2017 style).

At production scale the query log does not fit one evaluation pass; the
paper's formulation is stochastic maximization of f(X) = E_{q~Q} f_q(X).
Each round estimates f(j|X) from a weighted minibatch of queries (sampled
from the empirical distribution) while the cost g(j|X) stays exact (the
constraint must never be violated). The final objective is reported exactly.

The estimator is unbiased: E[f̂(j|X)] = f(j|X); with minibatch size m the
selection matches exact greedy w.h.p. for gaps >> 1/sqrt(m) — the tests
check end-objective parity within a few percent at small m.

Registered as "stochastic" (`repro.api`); minibatch size via
`options={"batch_queries": m}`, RNG via `config.seed`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import SolveConfig
from repro.core.constraint import resolve_constraint
from repro.core.greedy import ratio_of
from repro.core.problem import SCSKProblem, SolverResult
from repro.core.registry import register_solver
from repro.core.state import SolverState
from repro.core.trace import Trace


@jax.jit
def _stochastic_step(problem: SCSKProblem, state: SolverState, constraint,
                     w_mb):
    fg = problem.f_gains(state.covered_q, weights=w_mb)  # minibatch estimate
    gg, gg_part = constraint.gains(problem, state.covered_d)  # exact cost
    used = constraint.used(problem, state)
    feasible = (~state.selected) & constraint.feasible(used, gg_part) \
        & (fg > 0.0)
    score = jnp.where(feasible, ratio_of(fg, gg), -jnp.inf)
    j = jnp.argmax(score)
    stop = ~feasible[j]
    applied = problem.apply(state, j)
    state = jax.tree_util.tree_map(
        lambda cur, new: jnp.where(stop, cur, new), state, applied)
    return state, j, stop


@register_solver("stochastic", supports_state=True, supports_partition=True,
                 description="minibatch-f greedy (§3.2, Karimi-style)")
def solve_stochastic(problem: SCSKProblem, config: SolveConfig,
                     state: SolverState | None = None) -> SolverResult:
    batch_queries = int(config.opt("batch_queries", 2048))
    rng = np.random.default_rng(config.seed)
    w_full = np.asarray(problem.query_weights, np.float64)
    probs = w_full / w_full.sum()
    n = len(probs)

    state = problem.init_state() if state is None else state
    constraint = resolve_constraint(problem, config)
    trace = Trace(config, f0=float(problem.f_value(state.covered_q)),
                  g0=float(state.g_used))
    order: list[int] = []

    for _ in range(config.max_steps or problem.n_clauses):
        idx = rng.choice(n, size=batch_queries, p=probs)
        counts = np.bincount(idx, minlength=n).astype(np.float32)
        w_mb = jnp.asarray(counts / batch_queries)
        state, j, stop = _stochastic_step(problem, state, constraint, w_mb)
        if bool(stop):
            break
        order.append(int(j))
        # exact reporting (minibatch only drives selection)
        trace.on_select(float(problem.f_value(state.covered_q)),
                        float(state.g_used))
        if trace.should_stop():
            break

    trace.add_evals(2 * problem.n_clauses * max(1, len(order)))
    return trace.result(f"stochastic-greedy-m{batch_queries}",
                        problem, state, order)


def stochastic_greedy(
    problem: SCSKProblem,
    budget: float,
    *,
    batch_queries: int = 2048,
    seed: int = 0,
    max_steps: int | None = None,
    time_limit: float | None = None,
) -> SolverResult:
    """Legacy keyword entrypoint; prefer `repro.api.solve`."""
    return solve_stochastic(problem, SolveConfig(
        budget=budget, solver="stochastic", max_steps=max_steps,
        time_limit=time_limit, seed=seed,
        options={"batch_queries": batch_queries}))
