"""Stochastic greedy: minibatch f-gain estimates (paper §3.2's "stochastic
version [15]" — Karimi et al. 2017 style).

At production scale the query log does not fit one evaluation pass; the
paper's formulation is stochastic maximization of f(X) = E_{q~Q} f_q(X).
Each round estimates f(j|X) from a weighted minibatch of queries (sampled
from the empirical distribution) while the cost g(j|X) stays exact (the
constraint must never be violated). The final objective is reported exactly.

The estimator is unbiased: E[f̂(j|X)] = f(j|X); with minibatch size m the
selection matches exact greedy w.h.p. for gaps >> 1/sqrt(m) — the tests
check end-objective parity within a few percent at small m.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.greedy import ratio_of
from repro.core.problem import SCSKProblem, SolverResult


def stochastic_greedy(
    problem: SCSKProblem,
    budget: float,
    *,
    batch_queries: int = 2048,
    seed: int = 0,
    max_steps: int | None = None,
    time_limit: float | None = None,
) -> SolverResult:
    import jax

    rng = np.random.default_rng(seed)
    w_full = np.asarray(problem.query_weights, np.float64)
    probs = w_full / w_full.sum()
    n = len(probs)

    @jax.jit
    def step(covered_q, covered_d, selected, g_used, w_mb):
        fg = problem.f_gains(covered_q, weights=w_mb)     # minibatch estimate
        gg = problem.g_gains(covered_d)                   # exact cost
        feasible = (~selected) & (g_used + gg <= budget) & (fg > 0.0)
        score = jnp.where(feasible, ratio_of(fg, gg), -jnp.inf)
        j = jnp.argmax(score)
        stop = ~feasible[j]
        cq, cd = problem.add_clause(covered_q, covered_d, j)
        covered_q = jnp.where(stop, covered_q, cq)
        covered_d = jnp.where(stop, covered_d, cd)
        selected = selected.at[j].set(jnp.where(stop, selected[j], True))
        return covered_q, covered_d, selected, problem.g_value(covered_d), \
            j, stop

    covered_q, covered_d = problem.empty_state()
    selected = jnp.zeros(problem.n_clauses, bool)
    g_used = jnp.float32(0.0)
    order: list[int] = []
    fh, gh, th = [0.0], [0.0], [0.0]
    t0 = time.perf_counter()

    for _ in range(max_steps or problem.n_clauses):
        idx = rng.choice(n, size=batch_queries, p=probs)
        counts = np.bincount(idx, minlength=n).astype(np.float32)
        w_mb = jnp.asarray(counts / batch_queries)
        covered_q, covered_d, selected, g_used, j, stop = step(
            covered_q, covered_d, selected, g_used, w_mb)
        if bool(stop):
            break
        order.append(int(j))
        fh.append(float(problem.f_value(covered_q)))   # exact reporting
        gh.append(float(g_used))
        th.append(time.perf_counter() - t0)
        if time_limit is not None and th[-1] > time_limit:
            break

    return SolverResult(
        name=f"stochastic-greedy-m{batch_queries}",
        selected=np.asarray(selected), order=order,
        f_final=float(problem.f_value(covered_q)),
        g_final=float(g_used),
        f_history=np.asarray(fh), g_history=np.asarray(gh),
        time_history=np.asarray(th),
        n_exact_evals=2 * problem.n_clauses * max(1, len(order)),
    )
