"""SolverState: the explicit, checkpointable state shared by every solver.

A registered-dataclass pytree, so it passes through `jax.jit` boundaries,
`jax.lax.cond` branches, and `jax.tree_util.tree_map` unchanged. Holding the
full solve state in one value is what makes every solver warm-startable:
`solve(problem, cfg_B1)` returns a `SolverResult` carrying its final state,
and `solve(problem, cfg_B2, state=result.state)` resumes it — the budget-sweep
API (Figs. 2/3) is built on exactly this.
"""
from __future__ import annotations

import dataclasses
import functools

import jax


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["covered_q", "covered_d", "selected", "g_used", "step"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class SolverState:
    """Solve progress over an `SCSKProblem`.

    covered_q : uint32 [Wq]  packed bitset of covered queries, ∪_{c∈X} {q : c⊆q}
    covered_d : uint32 [Wd]  packed bitset of Tier-1 docs, ∪_{c∈X} m(c)
    selected  : bool   [C]   clause membership of X
    g_used    : f32 scalar   g(X) = |covered_d| (the knapsack fill)
    step      : i32 scalar   number of selections so far
    """
    covered_q: jax.Array
    covered_d: jax.Array
    selected: jax.Array
    g_used: jax.Array
    step: jax.Array

    def n_selected(self) -> int:
        return int(self.selected.sum())

    def replace(self, **kw) -> "SolverState":
        return dataclasses.replace(self, **kw)
