"""Query-selection (flow) baselines from Leung et al. [17], paper §2.3/§5.2.

All three parameterize tiering by a document set D₁ + the memorized query set
X^flow = {q ∈ Q_n : m(q) ⊆ D₁} (eq. 6/7) — so unseen queries always route to
Tier 2, which is exactly the generalization failure the paper demonstrates.

  popularity : doc score = P_{q~Qn}[d ∈ m(q)]; take top-B docs
  flow-max   : doc score = max_{q: d∈m(q)} P[q]; take top-B docs
  flow-sgd   : smooth-min convex relaxation of (5), minibatch SGD over doc
               logits + budget penalty, λ-regularized (drop rare queries)
"""
from __future__ import annotations

import dataclasses
import functools
import time
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset

if typing.TYPE_CHECKING:  # avoid circular import (data imports core.bitset)
    from repro.data.incidence import TieringData


@dataclasses.dataclass
class FlowResult:
    name: str
    tier1_docs: np.ndarray          # bool [n_docs]
    eligible_queries: np.ndarray    # bool [Nq]  (X^flow membership)
    train_coverage: float
    test_coverage: float
    wall_seconds: float


def _doc_scores_popularity(data: "TieringData", chunk: int = 1024) -> np.ndarray:
    score = np.zeros(data.n_docs, np.float64)
    w = data.log.train_weights
    for s in range(0, data.n_queries, chunk):
        blk = bitset.np_unpack(data.query_doc_bits[s:s + chunk], data.n_docs)
        score += w[s:s + chunk] @ blk
    return score


def _doc_scores_flowmax(data: "TieringData", chunk: int = 1024) -> np.ndarray:
    score = np.zeros(data.n_docs, np.float64)
    w = data.log.train_weights
    for s in range(0, data.n_queries, chunk):
        blk = bitset.np_unpack(data.query_doc_bits[s:s + chunk], data.n_docs)
        score = np.maximum(score, (w[s:s + chunk, None] * blk).max(axis=0))
    return score


def _finalize(name: str, data: "TieringData", doc_scores: np.ndarray, budget: int,
              t0: float, lam: float = 0.0) -> FlowResult:
    top = np.argsort(-doc_scores)[:budget]
    tier1 = np.zeros(data.n_docs, bool)
    tier1[top] = True
    t1_bits = bitset.np_pack(tier1)
    # X^flow: *training* queries (freq >= λ) whose match set fits in tier 1
    contained = ~np.any(data.query_doc_bits & ~t1_bits[None, :], axis=1)
    eligible = contained & (data.log.train_weights >= max(lam, 1e-300))
    return FlowResult(
        name=name,
        tier1_docs=tier1,
        eligible_queries=eligible,
        train_coverage=float(data.log.train_weights[eligible].sum()),
        test_coverage=float(data.log.test_weights[eligible].sum()),
        wall_seconds=time.perf_counter() - t0,
    )


def popularity(data: "TieringData", budget: int) -> FlowResult:
    t0 = time.perf_counter()
    return _finalize("popularity", data, _doc_scores_popularity(data), budget, t0)


def flow_max(data: "TieringData", budget: int) -> FlowResult:
    t0 = time.perf_counter()
    return _finalize("flow-max", data, _doc_scores_flowmax(data), budget, t0)


@functools.partial(jax.jit, static_argnames=("n_docs",))
def _sgd_step(theta, q_bits, q_w, budget, lr, tau, mu, n_docs: int):
    def loss_fn(theta):
        z = jax.nn.sigmoid(theta)                                   # [D]
        memb = bitset.unpack(q_bits, n_docs).astype(jnp.float32)    # [B, D]
        # smooth min over m(q): -tau * logsumexp(-z/tau) restricted to members
        neg = (-z[None, :] / tau) * memb + (1.0 - memb) * (-1e9)
        y = -tau * jax.nn.logsumexp(neg, axis=1)                    # [B]
        cover = jnp.sum(q_w * y)
        over = jax.nn.relu(jnp.sum(z) - budget)
        return -cover + mu * over * over / budget
    g = jax.grad(loss_fn)(theta)
    return theta - lr * g


def flow_sgd(data: "TieringData", budget: int, *, lam: float = 0.0,
             steps: int = 300, batch: int = 256, lr: float = 0.5,
             tau: float = 0.05, mu: float = 10.0, seed: int = 0) -> FlowResult:
    t0 = time.perf_counter()
    w = data.log.train_weights.copy()
    w[w < lam] = 0.0                               # λ-regularization (paper)
    keep = np.nonzero(w > 0)[0]
    probs = w[keep] / w[keep].sum()
    rng = np.random.default_rng(seed)
    theta = jnp.zeros(data.n_docs, jnp.float32)
    q_bits_all = jnp.asarray(data.query_doc_bits)
    for _ in range(steps):
        idx = keep[rng.choice(len(keep), size=min(batch, len(keep)), p=probs)]
        theta = _sgd_step(theta, q_bits_all[idx],
                          jnp.ones(len(idx), jnp.float32) / len(idx),
                          jnp.float32(budget), jnp.float32(lr),
                          jnp.float32(tau), jnp.float32(mu), data.n_docs)
    return _finalize(f"flow-sgd(λ={lam:g})", data,
                     np.asarray(theta, np.float64), budget, t0, lam=lam)
