"""Knapsack constraints: the budget side of SCSK as a first-class object.

The paper's single constraint g(X) <= B (eq. 12) models ONE machine's index
budget. A serving fleet has per-shard capacity: the doc space is partitioned
into word-aligned ranges (exactly `cluster.plan_shards`' split) and each
partition k carries its own cap B_k over its own cost g_k(X) = |m(X) ∩ D_k|.
This module extracts the budget/cost logic that used to live inline in the
solvers into a pluggable constraint object:

  * `GlobalBudget`      — today's scalar knapsack; the feasibility arithmetic
                          is bit-identical to the pre-refactor inline checks
                          (same comparisons on the same floats), pinned by
                          tests/test_constraint.py.
  * `PartitionedBudget` — per-partition doc-cost vectors g_k and caps B_k;
                          a clause is feasible iff EVERY partition it touches
                          still fits: ∀k. g_k(X) + g_k(j|X) <= B_k. The
                          batched per-partition cost-gain oracle is one fused
                          kernel call (`ops.partition_gain`).

Both are registered jax dataclasses, so they flow through jitted solver steps
as pytrees (caps are data, partition bounds are static metadata).

Every g_k is monotone submodular by the same Theorem-3.4 argument as g (a
coverage function restricted to D_k), so each partition's lower-bound update
rule (eq. 14 / Thm 4.1) remains valid per-coordinate — the lazy and opt/pes
solvers keep their laziness with vector bounds.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset


def partition_bounds(n_docs: int, n_parts: int) -> tuple[int, ...]:
    """Word-aligned doc-space partition: P+1 word offsets, 0 first, W last.

    Words are spread as evenly as possible and the partition count is clamped
    to the number of postings words — the SAME split `cluster.plan_shards`
    uses (it delegates here), so a `PartitionedBudget` built from this is
    aligned with the serving fleet's shards by construction.
    """
    if n_parts < 1:
        raise ValueError(f"n_parts must be >= 1, got {n_parts}")
    words = bitset.n_words(n_docs)
    n = min(n_parts, words)
    base, rem = divmod(words, n)
    bounds = [0]
    for i in range(n):
        bounds.append(bounds[-1] + base + (1 if i < rem else 0))
    return tuple(bounds)


class KnapsackConstraint:
    """Protocol every constraint implements (consumed by the solvers).

    used/value return f32 [P] fills, gains returns (total [C], per-part
    [C, P]) marginal costs, feasible masks candidates that fit EVERY
    partition. Implementations must be jit-traceable pytrees.
    """

    @property
    def n_parts(self) -> int:
        raise NotImplementedError

    @property
    def total(self) -> float:
        """Total budget across partitions (host-side reporting)."""
        raise NotImplementedError

    def used(self, problem, state) -> jax.Array:
        """f32 [P] fill of a SolverState (device)."""
        raise NotImplementedError

    def value(self, problem, covered_d) -> jax.Array:
        """f32 [P] fill of a covered-doc bitset (device)."""
        raise NotImplementedError

    def np_value(self, covered_d: np.ndarray) -> np.ndarray:
        """f64 [P] fill of a host covered-doc bitset (host solvers)."""
        raise NotImplementedError

    def gains(self, problem, covered_d, *, rows=None):
        """(g_total f32 [C], g_part f32 [C, P]) marginal costs."""
        raise NotImplementedError

    def feasible(self, used, g_part) -> jax.Array:
        """bool [C]: used[k] + g_part[:, k] <= B_k for every partition k."""
        raise NotImplementedError


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["budget"], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class GlobalBudget(KnapsackConstraint):
    """The paper's scalar knapsack g(X) <= B, as a constraint object.

    Feasibility is the literal pre-refactor comparison
    `g_used + g_gain <= budget` — no reshapes or reductions touch the floats,
    so solves are bit-identical to the inline-budget era.
    """
    budget: jax.Array     # f32 scalar

    def __post_init__(self):
        # tracer-safe: pytree unflatten re-runs this inside jit
        object.__setattr__(self, "budget",
                           jnp.asarray(self.budget, jnp.float32))

    @property
    def n_parts(self) -> int:
        return 1

    @property
    def total(self) -> float:
        return float(self.budget)

    def used(self, problem, state) -> jax.Array:
        return jnp.reshape(state.g_used, (1,))

    def value(self, problem, covered_d) -> jax.Array:
        return jnp.reshape(problem.g_value(covered_d), (1,))

    def np_value(self, covered_d: np.ndarray) -> np.ndarray:
        return np.asarray([bitset.np_popcount(covered_d)], np.float64)

    def gains(self, problem, covered_d, *, rows=None):
        gg = problem.g_gains(covered_d, rows=rows)
        return gg, gg[..., None]

    def feasible(self, used, g_part) -> jax.Array:
        return used[0] + g_part[..., 0] <= self.budget


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["caps"], meta_fields=["bounds"])
@dataclasses.dataclass(frozen=True)
class PartitionedBudget(KnapsackConstraint):
    """Per-partition caps B_k over word-aligned doc ranges.

    bounds : tuple of P+1 word offsets (static metadata; partitions are the
             contiguous word ranges [bounds[k], bounds[k+1]))
    caps   : f32 [P] per-partition doc budgets

    Feasibility masks a clause the moment ANY partition it touches is out of
    headroom; the objective side (f and the greedy ratio's total g) is
    untouched — partitioning constrains placement, not value.
    """
    caps: jax.Array
    bounds: tuple[int, ...]

    def __post_init__(self):
        bounds = tuple(int(b) for b in self.bounds)
        if len(bounds) < 2 or bounds[0] != 0 or \
                any(a >= b for a, b in zip(bounds, bounds[1:])):
            raise ValueError(f"bounds must be ascending word offsets "
                             f"starting at 0, got {bounds}")
        object.__setattr__(self, "bounds", bounds)
        caps = jnp.asarray(self.caps, jnp.float32)
        if caps.shape != (len(bounds) - 1,):
            raise ValueError(f"caps must have shape ({len(bounds) - 1},), "
                             f"got {caps.shape}")
        object.__setattr__(self, "caps", caps)

    @classmethod
    def from_split(cls, n_docs: int,
                   split: Mapping[int, float] | Sequence[float],
                   ) -> "PartitionedBudget":
        """From a {partition: cap} mapping or a cap sequence; partitions are
        `partition_bounds(n_docs, P)` word ranges."""
        if isinstance(split, Mapping):
            keys = sorted(split)
            if keys != list(range(len(keys))):
                raise ValueError(
                    f"budget split keys must be 0..P-1, got {keys}")
            caps = [float(split[k]) for k in keys]
        else:
            caps = [float(b) for b in split]
        bounds = partition_bounds(n_docs, len(caps))
        if len(bounds) - 1 != len(caps):
            raise ValueError(
                f"{len(caps)} partitions need >= {len(caps)} postings words; "
                f"n_docs={n_docs} only has {bounds[-1]}")
        return cls(caps=jnp.asarray(caps, jnp.float32), bounds=bounds)

    @property
    def n_parts(self) -> int:
        return len(self.bounds) - 1

    @property
    def total(self) -> float:
        return float(jnp.sum(self.caps))

    def scaled(self, new_total: float) -> "PartitionedBudget":
        """Same split shares at a different total budget (budget sweeps)."""
        return PartitionedBudget(
            caps=self.caps * (float(new_total) / max(self.total, 1e-30)),
            bounds=self.bounds)

    def used(self, problem, state) -> jax.Array:
        return self.value(problem, state.covered_d)

    def value(self, problem, covered_d) -> jax.Array:
        return problem.g_value(covered_d, bounds=self.bounds)

    def np_value(self, covered_d: np.ndarray) -> np.ndarray:
        covered_d = np.asarray(covered_d)
        return np.asarray(
            [bitset.np_popcount(covered_d[lo:hi])
             for lo, hi in zip(self.bounds, self.bounds[1:])], np.float64)

    def gains(self, problem, covered_d, *, rows=None):
        g_part = problem.g_gains(covered_d, rows=rows, bounds=self.bounds)
        return jnp.sum(g_part, axis=-1), g_part

    def feasible(self, used, g_part) -> jax.Array:
        return jnp.all(used + g_part <= self.caps, axis=-1)


def partition_capacities(n_docs: int, bounds: Sequence[int]) -> list[int]:
    """Physical doc capacity of each partition of a word-aligned split."""
    word = bitset.WORD
    return [min(n_docs, hi * word) - lo * word
            for lo, hi in zip(bounds, bounds[1:])]


def trim_state(problem, state, constraint):
    """Make a warm-start state feasible for (possibly shrunk) per-shard caps.

    Re-allocating a traffic split can hand a shard a cap BELOW the fill its
    frozen warm-prefix clauses already occupy; the solvers only mask NEW
    candidates, so the overflow would survive the solve. This drops every
    selected clause touching an over-cap partition (their budget is freed
    for the re-solve) and rebuilds the state exactly. Returns
    (state, dropped_indices); a no-op (same state object) when every
    partition already fits.
    """
    if state is None or constraint.n_parts == 1:
        return state, np.empty(0, np.int64)
    covered_d = np.asarray(state.covered_d)
    fills = constraint.np_value(covered_d)
    caps = np.asarray(constraint.caps, np.float64)
    over = np.nonzero(fills > caps)[0]
    if not len(over):
        return state, np.empty(0, np.int64)
    selected = np.asarray(state.selected)
    idx = np.nonzero(selected)[0].astype(np.int64)
    rows = np.asarray(problem.clause_doc_bits)[idx]
    touches = np.zeros(len(idx), bool)
    for k in over:
        lo, hi = constraint.bounds[k], constraint.bounds[k + 1]
        touches |= bitset.np_popcount(rows[:, lo:hi]) > 0
    kept = idx[~touches]
    return problem.state_for(kept), idx[touches]


def as_constraint(budget) -> KnapsackConstraint:
    """Normalize a scalar budget (or pass a constraint through)."""
    if isinstance(budget, KnapsackConstraint):
        return budget
    return GlobalBudget(budget=jnp.float32(budget))


def resolve_constraint(problem, config) -> KnapsackConstraint:
    """The constraint a SolveConfig implies for a given problem.

    Precedence: an explicit `config.constraint` wins; a `budget_split`
    mapping/sequence builds a `PartitionedBudget` over the problem's doc
    space; otherwise the scalar `config.budget` is a `GlobalBudget`.
    `budget_split="traffic"` needs traffic data and is resolved by
    `TieringPipeline` (api layer) before the solve reaches here.
    """
    if config.constraint is not None:
        return as_constraint(config.constraint)
    split = config.budget_split
    if split is None:
        return GlobalBudget(budget=jnp.float32(config.budget))
    if isinstance(split, str):
        raise ValueError(
            f"budget_split={split!r} must be resolved from traffic data by "
            "TieringPipeline (api layer); pass a mapping or a constraint "
            "object at this level")
    return PartitionedBudget.from_split(problem.n_docs, split)
