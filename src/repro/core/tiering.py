"""Clause tiering: the ψ/φ classifiers of paper §3.1 + coverage evaluation.

A `ClauseTiering` is the deployable artifact a solve produces: the selected
clause set (packed over vocab for online subset tests), the materialized
Tier-1 document set, and evaluation helpers. `verify_correctness` checks
Theorem 3.1 exhaustively on a query set.
"""
from __future__ import annotations

import dataclasses
import typing

import numpy as np

from repro.core import bitset

if typing.TYPE_CHECKING:  # avoid circular import (data imports core.bitset)
    from repro.data.incidence import TieringData


@dataclasses.dataclass
class ClauseTiering:
    clauses: list[tuple[int, ...]]
    clause_vocab_bits: np.ndarray     # packed [K, Wv] (ψ: subset test)
    tier1_docs: np.ndarray            # bool [n_docs]  (φ materialized)
    vocab_size: int

    @classmethod
    def from_selection(cls, data: "TieringData", selected: np.ndarray) -> "ClauseTiering":
        idx = np.nonzero(selected)[0]
        clauses = [data.clauses[i] for i in idx]
        cbits = np.zeros((len(clauses), data.corpus.vocab_size), bool)
        for i, c in enumerate(clauses):
            cbits[i, list(c)] = True
        t1 = np.zeros(data.n_docs, bool)
        if len(idx):
            t1_bits = data.clause_doc_bits[idx][0].copy()
            for r in data.clause_doc_bits[idx][1:]:
                t1_bits |= r
            t1 = bitset.np_unpack(t1_bits, data.n_docs)
        return cls(clauses=clauses, clause_vocab_bits=bitset.np_pack(cbits),
                   tier1_docs=t1, vocab_size=data.corpus.vocab_size)

    # ψ^clause (eq. 8): Tier 1 iff some selected clause ⊆ q
    def classify_queries(self, query_bits: np.ndarray, chunk: int = 4096) -> np.ndarray:
        out = np.zeros(query_bits.shape[0], bool)
        if len(self.clauses) == 0:
            return out
        for s in range(0, query_bits.shape[0], chunk):
            q = query_bits[s:s + chunk]                      # [b, Wv]
            sub = (q[:, None, :] & self.clause_vocab_bits[None]) == \
                self.clause_vocab_bits[None]
            out[s:s + chunk] = sub.all(axis=-1).any(axis=1)
        return out

    # φ^clause (eq. 9) for new documents
    def classify_docs(self, doc_bits: np.ndarray) -> np.ndarray:
        return self.classify_queries(doc_bits)

    def coverage(self, data: "TieringData") -> dict[str, float]:
        elig = self.classify_queries(data.log.query_bits)
        return {
            "train": float(data.log.train_weights[elig].sum()),
            "test": float(data.log.test_weights[elig].sum()),
            "tier1_frac": float(self.tier1_docs.mean()),
        }

    def verify_correctness(self, data: "TieringData") -> bool:
        """Theorem 3.1: every eligible query's match set is inside Tier 1."""
        elig = self.classify_queries(data.log.query_bits)
        t1 = bitset.np_pack(self.tier1_docs)
        m_out = data.query_doc_bits & ~t1[None, :]
        ok = ~np.any(m_out, axis=1)
        return bool(np.all(ok[elig]))
