"""SolveConfig: one config object for every solver behind `repro.api`.

Replaces the per-solver keyword soup (`budget`, `max_steps`, `record_every`,
`time_limit`, `seed`, plus solver-specific knobs) with a single frozen
dataclass consumed by the uniform signature

    solve(problem, config, state=None) -> SolverResult

Solver-specific options (`k` for optpes, `batch_queries` for stochastic,
`lam`/`steps` for flow-sgd, ...) travel in `options` so the registry stays
signature-uniform without losing per-solver tunability.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class SolveConfig:
    budget: float
    solver: str = "greedy"
    # Partitioned knapsack (shard-aware budgets, see core.constraint):
    #   budget_split — {partition: cap} mapping / cap sequence over the
    #       word-aligned doc partition, or the string "traffic" (resolved
    #       from observed traffic shares by TieringPipeline; invalid at the
    #       bare registry level). None = single global budget.
    #   constraint — an explicit KnapsackConstraint object; wins over both
    #       `budget` and `budget_split`.
    budget_split: Mapping[int, float] | Sequence[float] | str | None = None
    constraint: Any = None
    max_steps: int | None = None        # cap on selections this call
    record_every: int = 1               # trace density (history points)
    time_limit: float | None = None     # wall-clock seconds, checked per step
    seed: int = 0                       # stochastic solvers only
    # "exhaust": keep selecting the best *feasible* candidate until none
    #            remain (classic greedy; the pre-registry semantics).
    # "truncate": stop at the first step whose best candidate overflows the
    #            budget. The selection path then does not depend on the
    #            budget at all (paper Fig. 3: "greedy finds the entire
    #            solution path"), which is what makes warm-started budget
    #            sweeps exactly equal cold solves.
    stop_policy: str = "exhaust"
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    # Trace hooks: on_step(trace) after every selection, on_record(trace)
    # after every recorded history point. Used by benchmarks for live
    # emission; returning is the only contract (raise to abort).
    on_step: Callable | None = None
    on_record: Callable | None = None

    def __post_init__(self):
        if self.stop_policy not in ("exhaust", "truncate"):
            raise ValueError(f"unknown stop_policy: {self.stop_policy!r}")
        if self.record_every < 1:
            raise ValueError("record_every must be >= 1")
        if isinstance(self.budget_split, str) and \
                self.budget_split != "traffic":
            raise ValueError(
                f"unknown budget_split: {self.budget_split!r} "
                "(a mapping, a cap sequence, or 'traffic')")

    @property
    def partitioned(self) -> bool:
        """True when this config implies a multi-partition constraint."""
        if self.constraint is not None:
            return getattr(self.constraint, "n_parts", 1) > 1
        return self.budget_split is not None

    def replace(self, **kw) -> "SolveConfig":
        return dataclasses.replace(self, **kw)

    def opt(self, key: str, default=None):
        """Solver-specific option with a default."""
        return self.options.get(key, default)
