"""Production-scale sparse SCSK solver round (dry-run unit for tiering arch).

At |D| ~ 2^26+ the dense clause x doc bitset matrix is infeasible; each
clause carries m(c) as a padded id list and the covered-doc state stays one
packed bitset. This module is the shard-ready greedy round over that layout:
clause lists sharded over ('pod','data'); the covered masks replicated
(|D|/8 bytes); f-side incidence packed bits sharded over 'model'.

Mesh-aware paths (same pathology class as EXPERIMENTS §Perf H3): the f-gain
bit-matvec runs shard-locally with one psum, and the selected clause's rows
are owner-gathered — a traced-index gather on a sharded operand would
all-gather the whole matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitset
from repro.core.greedy import ratio_of
from repro.kernels import ops

P = jax.sharding.PartitionSpec


def _mesh_dp():
    from repro.distributed import mesh_context
    mesh = mesh_context.current_mesh()
    if mesh.size == 1 or "model" not in mesh.axis_names:
        return None, ()
    return mesh, tuple(a for a in mesh.axis_names if a != "model")


def _f_gains(clause_query_bits, x):
    mesh, dp = _mesh_dp()
    if mesh is None:
        return ops.bit_matvec(clause_query_bits, x)[:, 0]
    from repro.models.moe import shard_map

    def body(a_q, xw):
        return jax.lax.psum(ops.bit_matvec(a_q, xw)[:, 0], "model")

    return shard_map(body, mesh,
                     in_specs=(P(dp, "model"), P("model")),
                     out_specs=P(dp), check_vma=False)(clause_query_bits, x)


def _owner_row(mat, j, *, w_axis: str | None):
    """Row `j` of a dp-sharded matrix without an all-gather."""
    mesh, dp = _mesh_dp()
    if mesh is None:
        return mat[j]
    from repro.models.moe import shard_map

    def body(a, jj):
        rank = jnp.int32(0)
        for ax in dp:
            rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
        c_loc = a.shape[0]
        lj = jj - rank * c_loc
        inb = (lj >= 0) & (lj < c_loc)
        row = jnp.where(inb, a[jnp.clip(lj, 0, c_loc - 1)],
                        jnp.zeros_like(a[0]) if a.dtype != jnp.int32
                        else jnp.full_like(a[0], -1))
        if a.dtype == jnp.int32:
            # -1-padded id rows: combine via max (non-owners hold -1)
            for ax in dp:
                row = jax.lax.pmax(row, ax)
        else:
            for ax in dp:
                row = jax.lax.psum(row, ax)
        return row

    return shard_map(
        body, mesh,
        in_specs=(P(dp, w_axis), P()),
        out_specs=P(w_axis), check_vma=False)(mat, j)


@jax.jit
def sparse_greedy_step(
    clause_doc_ids: jnp.ndarray,     # int32 [C, M] (-1 padded, sorted)
    clause_query_bits: jnp.ndarray,  # uint32 [C, Wq]
    query_weights: jnp.ndarray,      # f32 [Wq*32]
    covered_q: jnp.ndarray,          # uint32 [Wq]
    covered_d: jnp.ndarray,          # uint32 [Wd]
    selected: jnp.ndarray,           # bool [C]
    g_used: jnp.ndarray,             # f32
    budget: jnp.ndarray,             # f32
):
    """One cost-ratio greedy selection over the sparse layout."""
    x = (query_weights * (1.0 - bitset.unpack(covered_q).astype(jnp.float32))
         )[:, None]
    fg = _f_gains(clause_query_bits, x)
    gg = ops.sparse_gain(clause_doc_ids, covered_d).astype(jnp.float32)
    feasible = (~selected) & (g_used + gg <= budget) & (fg > 0.0)
    score = jnp.where(feasible, ratio_of(fg, gg), -jnp.inf)
    j = jnp.argmax(score)
    stop = ~feasible[j]

    ids_j = _owner_row(clause_doc_ids, j, w_axis=None)
    row_q = _owner_row(clause_query_bits, j, w_axis="model") \
        if _mesh_dp()[0] is not None else clause_query_bits[j]
    new_d = covered_d | bitset.from_indices(
        jnp.maximum(ids_j, 0), covered_d.shape[0] * 32, valid=ids_j >= 0,
        unique=True)  # match-set id lists are sorted+unique by construction
    new_q = covered_q | row_q
    covered_q = jnp.where(stop, covered_q, new_q)
    covered_d = jnp.where(stop, covered_d, new_d)
    selected = selected.at[j].set(jnp.where(stop, selected[j], True))
    g_used = jnp.where(stop, g_used, g_used + gg[j])
    return covered_q, covered_d, selected, g_used, j, stop
