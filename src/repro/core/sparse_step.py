"""Production-scale sparse SCSK solver round (dry-run unit for tiering arch).

At |D| ~ 2^26+ the dense clause x doc bitset matrix is infeasible; each
clause carries m(c) as a padded id list and the covered-doc state stays one
packed bitset. This module is the shard-ready greedy round over that layout:
clause lists sharded over ('pod','data'); the covered masks replicated
(|D|/8 bytes); f-side incidence packed bits sharded over 'model'.

Mesh-aware paths (same pathology class as EXPERIMENTS §Perf H3): the f-gain
bit-matvec runs shard-locally with one psum, and the selected clause's rows
are owner-gathered (`distributed.owner_row`) — a traced-index gather on a
sharded operand would all-gather the whole matrix. All gating goes through
`distributed.mesh_fused`; this module carries no mesh boilerplate of its own.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import distributed
from repro.core import bitset
from repro.core.greedy import ratio_of
from repro.kernels import ops

P = jax.sharding.PartitionSpec


def _f_gains(clause_query_bits, x):
    dp = distributed.current_plan().data_axes

    def body(a_q, xw):
        return jax.lax.psum(ops.bit_matvec(a_q, xw)[:, 0], "model")

    fused = distributed.mesh_fused(body,
                                   in_specs=(P(dp, "model"), P("model")),
                                   out_specs=P(dp))
    if fused is None:
        return ops.bit_matvec(clause_query_bits, x)[:, 0]
    return fused(clause_query_bits, x)


@jax.jit
def sparse_greedy_step(
    clause_doc_ids: jnp.ndarray,     # int32 [C, M] (-1 padded, sorted)
    clause_query_bits: jnp.ndarray,  # uint32 [C, Wq]
    query_weights: jnp.ndarray,      # f32 [Wq*32]
    covered_q: jnp.ndarray,          # uint32 [Wq]
    covered_d: jnp.ndarray,          # uint32 [Wd]
    selected: jnp.ndarray,           # bool [C]
    g_used: jnp.ndarray,             # f32
    budget: jnp.ndarray,             # f32
):
    """One cost-ratio greedy selection over the sparse layout."""
    x = (query_weights * (1.0 - bitset.unpack(covered_q).astype(jnp.float32))
         )[:, None]
    fg = _f_gains(clause_query_bits, x)
    gg = ops.sparse_gain(clause_doc_ids, covered_d).astype(jnp.float32)
    feasible = (~selected) & (g_used + gg <= budget) & (fg > 0.0)
    score = jnp.where(feasible, ratio_of(fg, gg), -jnp.inf)
    j = jnp.argmax(score)
    stop = ~feasible[j]

    # -1-padded int32 id rows combine via pmax, packed rows via psum — both
    # owner-local (no all-gather), both falling back to mat[j] off-mesh
    ids_j = distributed.owner_row(clause_doc_ids, j, w_axis=None)
    row_q = distributed.owner_row(clause_query_bits, j, w_axis="model")
    new_d = covered_d | bitset.from_indices(
        jnp.maximum(ids_j, 0), covered_d.shape[0] * 32, valid=ids_j >= 0,
        unique=True)  # match-set id lists are sorted+unique by construction
    new_q = covered_q | row_q
    covered_q = jnp.where(stop, covered_q, new_q)
    covered_d = jnp.where(stop, covered_d, new_d)
    selected = selected.at[j].set(jnp.where(stop, selected[j], True))
    g_used = jnp.where(stop, g_used, g_used + gg[j])
    return covered_q, covered_d, selected, g_used, j, stop
