"""Trace: shared per-solve bookkeeping (history, timing, stop conditions).

Every solver used to privately maintain `fh/gh/th` lists, a `t0` clock, an
eval counter, and its own (subtly buggy) time-limit check. `Trace` extracts
that into one recorder:

  * `on_select(f, g)` after each selection — records a history point every
    `record_every` selections and fires the config's `on_step`/`on_record`
    callbacks (benchmarks use these for live emission).
  * `should_stop()` — checks the wall clock DIRECTLY each step. The old
    per-solver pattern compared `th[-1]`, which only refreshes every
    `record_every` selections, so large `record_every` values overshot
    `time_limit` arbitrarily.
  * `result(...)` — assembles the uniform `SolverResult`.
"""
from __future__ import annotations

import time

import numpy as np

from repro import obs
from repro.core.config import SolveConfig
from repro.core.problem import SolverResult
from repro.core.state import SolverState

_SELECTIONS = obs.counter("solver_selections_total",
                          "clauses selected across solves",
                          labels=("solver",))
_EVALS = obs.counter("solver_evals_total",
                     "exact (f, g) evaluations across solves",
                     labels=("solver",))
_SOLVE_F = obs.gauge("solver_last_f", "last solve's final objective",
                     labels=("solver",))


class Trace:
    def __init__(self, config: SolveConfig, *, f0: float = 0.0,
                 g0: float = 0.0):
        self.config = config
        self.f_history: list[float] = [f0]
        self.g_history: list[float] = [g0]
        self.time_history: list[float] = [0.0]
        self.n_selections = 0
        self.n_exact_evals = 0
        self.last_f = f0
        self.last_g = g0
        self._t0 = time.perf_counter()
        # label value cached once: solver name is fixed per Trace and the
        # counters fire on the per-selection hot path
        self._solver = str(config.solver)

    # -- clock ---------------------------------------------------------------
    def elapsed(self) -> float:
        return time.perf_counter() - self._t0

    def should_stop(self) -> bool:
        """Wall-clock time limit, checked against the live clock."""
        limit = self.config.time_limit
        return limit is not None and self.elapsed() > limit

    # -- recording -----------------------------------------------------------
    def add_evals(self, n: int) -> None:
        self.n_exact_evals += n
        _EVALS.inc(n, solver=self._solver)

    def on_select(self, f_val: float, g_val: float) -> None:
        """Call once per selection with the exact post-selection f/g."""
        self.last_f, self.last_g = float(f_val), float(g_val)
        if (self.n_selections % self.config.record_every) == 0:
            self.record()
        self.n_selections += 1
        _SELECTIONS.inc(solver=self._solver)
        if self.config.on_step is not None:
            self.config.on_step(self)

    def record(self) -> None:
        """Force a history point at the current (f, g, elapsed)."""
        self.f_history.append(self.last_f)
        self.g_history.append(self.last_g)
        self.time_history.append(self.elapsed())
        if self.config.on_record is not None:
            self.config.on_record(self)

    # -- result assembly ------------------------------------------------------
    def result(self, name: str, problem, state: SolverState,
               order: list[int], *, extra: dict | None = None) -> SolverResult:
        # flush the tail: with record_every > 1 the last selections may not
        # have a history point yet, which would leave *_history[-1] stale
        if self.n_selections and \
                (self.n_selections - 1) % self.config.record_every != 0:
            self.record()
        f_final = float(problem.f_value(state.covered_q))
        _SOLVE_F.set(f_final, solver=self._solver)
        obs.event("solve_done", solver=name, n_selections=self.n_selections,
                  n_exact_evals=self.n_exact_evals, f_final=f_final,
                  g_final=float(state.g_used),
                  seconds=round(self.elapsed(), 4))
        return SolverResult(
            name=name,
            selected=np.asarray(state.selected),
            order=order,
            f_final=f_final,
            g_final=float(state.g_used),
            f_history=np.asarray(self.f_history),
            g_history=np.asarray(self.g_history),
            time_history=np.asarray(self.time_history),
            n_exact_evals=self.n_exact_evals,
            state=state,
            extra=extra or {},
        )
