"""SCSK core: problem oracles, solver state, and the solver family.

The canonical way to run a solver is the `repro.api` layer:

    from repro import api

    pipe = (api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
            .mine(min_support=1e-3)
            .solve("optpes", budget_frac=0.5))
    engine = pipe.deploy()                      # -> serve.TieredEngine

or, one level lower, the uniform registry entrypoint:

    cfg = api.SolveConfig(budget=100.0, solver="greedy")
    result = api.solve(problem, cfg)            # -> SolverResult
    more = api.solve(problem, cfg.replace(budget=200.0), state=result.state)

Every solver in this package (greedy eq. 13, lazy Alg. 1, opt/pes Alg. 2,
isk1/isk2 Alg. 3, agnostic, stochastic) self-registers with
`@register_solver(name)` and shares the `SolverState` pytree + `Trace`
recorder, so all of them are warm-startable/checkpointable through one
signature: `solve(problem, config, state=None)`.

The bare functions (`greedy(problem, budget, ...)`, ...) and the `SOLVERS`
dict remain as thin legacy shims over the registry.
"""
from repro.core.agnostic import agnostic_greedy, solve_agnostic    # noqa: F401
from repro.core.config import SolveConfig                          # noqa: F401
from repro.core.constraint import (                                # noqa: F401
    GlobalBudget, KnapsackConstraint, PartitionedBudget, partition_bounds,
    partition_capacities, trim_state)
from repro.core.greedy import greedy, greedy_step, solve_greedy    # noqa: F401
from repro.core.isk import isk, solve_isk1, solve_isk2             # noqa: F401
from repro.core.lazy_greedy import lazy_greedy, solve_lazy_greedy  # noqa: F401
from repro.core.optpes import optpes_greedy, optpes_round, solve_optpes  # noqa: F401
from repro.core.problem import SCSKProblem, SolverResult           # noqa: F401
from repro.core.registry import (                                  # noqa: F401
    get_solver, list_solvers, register_solver, solve, solve_sweep)
from repro.core.state import SolverState                           # noqa: F401
from repro.core.stochastic import solve_stochastic, stochastic_greedy  # noqa: F401
from repro.core.tiering import ClauseTiering                       # noqa: F401
from repro.core.trace import Trace                                 # noqa: F401

# Legacy name -> callable(problem, budget, **kw) shim over the registry.
SOLVERS = {
    "greedy": greedy,
    "lazy": lazy_greedy,
    "optpes": optpes_greedy,
    "isk1": lambda p, b, **kw: isk(p, b, variant=1, **kw),
    "isk2": lambda p, b, **kw: isk(p, b, variant=2, **kw),
    "agnostic": agnostic_greedy,
    "stochastic": stochastic_greedy,
}
