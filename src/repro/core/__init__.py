from repro.core.agnostic import agnostic_greedy          # noqa: F401
from repro.core.greedy import greedy, greedy_step        # noqa: F401
from repro.core.isk import isk                           # noqa: F401
from repro.core.lazy_greedy import lazy_greedy           # noqa: F401
from repro.core.optpes import optpes_greedy, optpes_round  # noqa: F401
from repro.core.problem import SCSKProblem, SolverResult   # noqa: F401
from repro.core.stochastic import stochastic_greedy      # noqa: F401
from repro.core.tiering import ClauseTiering             # noqa: F401

SOLVERS = {
    "greedy": greedy,
    "lazy": lazy_greedy,
    "optpes": optpes_greedy,
    "isk1": lambda p, b, **kw: isk(p, b, variant=1, **kw),
    "isk2": lambda p, b, **kw: isk(p, b, variant=2, **kw),
    "agnostic": agnostic_greedy,
    "stochastic": stochastic_greedy,
}
