"""Constraint-Agnostic Greedy (Iyer & Bilmes 2013) — the paper's baseline.

Scores candidates by f-gain only (the cost g never enters the comparison),
with a classic lazy heap [Minoux 1978]. Feasibility of the popped winner is
still enforced (g(X ∪ {j}) <= B) — matching the paper's §5.1 description:
"much faster ... because it ignores the constraint in the selection process,
[but] converges to a clearly suboptimal solution".
"""
from __future__ import annotations

import heapq
import time

import jax.numpy as jnp
import numpy as np

from repro.core.lazy_greedy import _exact_gains_one, _singleton_gains
from repro.core.problem import SCSKProblem, SolverResult


def agnostic_greedy(problem: SCSKProblem, budget: float, *,
                    max_steps: int | None = None,
                    time_limit: float | None = None) -> SolverResult:
    c = problem.n_clauses
    covered_q, covered_d = problem.empty_state()
    fbar_d, gg_d = _singleton_gains(problem, covered_q, covered_d)
    fbar = np.asarray(fbar_d, np.float64)
    n_exact = 2 * c

    selected = np.zeros(c, bool)
    order: list[int] = []
    g_used, f_val = 0.0, 0.0
    fh, gh, th = [0.0], [0.0], [0.0]
    t0 = time.perf_counter()

    heap = [(-fbar[j], j) for j in range(c) if fbar[j] > 0]
    heapq.heapify(heap)
    steps = max_steps or c
    for _ in range(steps):
        chosen = -1
        while heap:
            _, j = heapq.heappop(heap)
            if selected[j]:
                continue
            fg, gg = _exact_gains_one(problem, covered_q, covered_d, jnp.int32(j))
            fbar[j] = float(fg)
            n_exact += 2
            if fbar[j] <= 0:
                continue
            if g_used + float(gg) > budget:
                continue                      # infeasible winner: drop
            if not heap or fbar[j] >= -heap[0][0]:
                chosen = j
                break
            heapq.heappush(heap, (-fbar[j], j))
        if chosen < 0:
            break
        covered_q, covered_d = problem.add_clause(
            covered_q, covered_d, jnp.int32(chosen))
        selected[chosen] = True
        order.append(chosen)
        f_val += fbar[chosen]
        g_used = float(problem.g_value(covered_d))
        fh.append(f_val)
        gh.append(g_used)
        th.append(time.perf_counter() - t0)
        if time_limit is not None and th[-1] > time_limit:
            break

    return SolverResult(
        name="constraint-agnostic",
        selected=selected, order=order,
        f_final=float(problem.f_value(covered_q)),
        g_final=g_used,
        f_history=np.asarray(fh), g_history=np.asarray(gh),
        time_history=np.asarray(th), n_exact_evals=n_exact,
    )
