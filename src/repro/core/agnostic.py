"""Constraint-Agnostic Greedy (Iyer & Bilmes 2013) — the paper's baseline.

Scores candidates by f-gain only (the cost g never enters the comparison),
with a classic lazy heap [Minoux 1978]. Feasibility of the popped winner is
still enforced (g(X ∪ {j}) <= B) — matching the paper's §5.1 description:
"much faster ... because it ignores the constraint in the selection process,
[but] converges to a clearly suboptimal solution".

Registered as "agnostic" (`repro.api`).
"""
from __future__ import annotations

import heapq

import jax.numpy as jnp
import numpy as np

from repro.core.config import SolveConfig
from repro.core.constraint import resolve_constraint
from repro.core.lazy_greedy import _exact_gains_one, _singleton_gains
from repro.core.problem import SCSKProblem, SolverResult
from repro.core.registry import register_solver
from repro.core.state import SolverState
from repro.core.trace import Trace


@register_solver("agnostic", supports_state=True,
                 description="f-gain-only lazy greedy baseline (§5.1)")
def solve_agnostic(problem: SCSKProblem, config: SolveConfig,
                   state: SolverState | None = None) -> SolverResult:
    c = problem.n_clauses
    state = problem.init_state() if state is None else state
    covered_q, covered_d = state.covered_q, state.covered_d
    budget = config.budget
    constraint = resolve_constraint(problem, config)

    fbar_d, _ = _singleton_gains(problem, constraint, covered_q, covered_d)
    fbar = np.asarray(fbar_d, np.float64)

    selected = np.asarray(state.selected).copy()
    order: list[int] = []
    g_used = float(state.g_used)
    f_val = float(problem.f_value(covered_q))
    trace = Trace(config, f0=f_val, g0=g_used)
    trace.add_evals(2 * c)

    heap = [(-fbar[j], j) for j in range(c) if fbar[j] > 0 and not selected[j]]
    heapq.heapify(heap)
    steps = config.max_steps or c
    for _ in range(steps):
        chosen = -1
        while heap:
            _, j = heapq.heappop(heap)
            if selected[j]:
                continue
            fg, gg_part = _exact_gains_one(problem, constraint, covered_q,
                                           covered_d, jnp.int32(j))
            fbar[j] = float(fg)
            trace.add_evals(2)
            if fbar[j] <= 0:
                continue
            if g_used + float(jnp.sum(gg_part)) > budget:
                continue                      # infeasible winner: drop
            if not heap or fbar[j] >= -heap[0][0]:
                chosen = j
                break
            heapq.heappush(heap, (-fbar[j], j))
        if chosen < 0:
            break
        covered_q, covered_d = problem.add_clause(
            covered_q, covered_d, jnp.int32(chosen))
        selected[chosen] = True
        order.append(chosen)
        f_val += fbar[chosen]
        g_used = float(problem.g_value(covered_d))
        trace.on_select(f_val, g_used)
        if trace.should_stop():
            break

    final = SolverState(
        covered_q=covered_q, covered_d=covered_d,
        selected=jnp.asarray(selected), g_used=jnp.float32(g_used),
        step=state.step + len(order))
    return trace.result("constraint-agnostic", problem, final, order)


def agnostic_greedy(problem: SCSKProblem, budget: float, *,
                    max_steps: int | None = None,
                    time_limit: float | None = None) -> SolverResult:
    """Legacy keyword entrypoint; prefer `repro.api.solve`."""
    return solve_agnostic(problem, SolveConfig(
        budget=budget, solver="agnostic", max_steps=max_steps,
        time_limit=time_limit))
