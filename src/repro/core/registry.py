"""Solver registry: names -> uniform `solve(problem, config, state)` callables.

Replaces the lambda-filled `SOLVERS` dict. Solver modules self-register with

    @register_solver("greedy", supports_state=True)
    def solve_greedy(problem, config, state=None) -> SolverResult: ...

and every consumer — benchmarks, the `TieringPipeline` facade, tests —
iterates ONE registry through the uniform entry points:

    solve(problem, config, state=None)        single solve / warm start
    solve_sweep(problem, budgets, config)     warm-started budget sweep

`needs_data=True` marks adapters (the flow baselines) that consume the full
`TieringData` instead of an `SCSKProblem`; `supports_state=True` marks
solvers that accept a `SolverState` to resume from.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.config import SolveConfig
from repro.core.constraint import resolve_constraint
from repro.core.problem import SolverResult
from repro.core.state import SolverState

_REGISTRY: dict[str, "SolverSpec"] = {}


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    name: str
    fn: Callable  # (problem, config, state) -> SolverResult
    supports_state: bool = False     # accepts state= for warm starts
    supports_truncate: bool = False  # implements stop_policy="truncate"
    supports_partition: bool = False  # masks per-partition knapsack caps
    needs_data: bool = False         # consumes TieringData, not SCSKProblem
    description: str = ""

    def __call__(self, problem, config: SolveConfig,
                 state: SolverState | None = None) -> SolverResult:
        return self.fn(problem, config, state)


def register_solver(name: str, *, supports_state: bool = False,
                    supports_truncate: bool = False,
                    supports_partition: bool = False,
                    needs_data: bool = False, description: str = ""):
    """Decorator: register `fn(problem, config, state=None) -> SolverResult`."""
    def deco(fn):
        if name in _REGISTRY and _REGISTRY[name].fn is not fn:
            raise ValueError(f"solver {name!r} already registered")
        _REGISTRY[name] = SolverSpec(
            name=name, fn=fn, supports_state=supports_state,
            supports_truncate=supports_truncate,
            supports_partition=supports_partition, needs_data=needs_data,
            description=description or (fn.__doc__ or "").strip().split("\n")[0])
        return fn
    return deco


def get_solver(name: str) -> SolverSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown solver {name!r}; registered: {list_solvers()}") from None


def list_solvers(*, needs_data: bool | None = None) -> list[str]:
    return sorted(n for n, s in _REGISTRY.items()
                  if needs_data is None or s.needs_data == needs_data)


def solve(problem, config: SolveConfig,
          state: SolverState | None = None) -> SolverResult:
    """The uniform entrypoint: dispatch `config.solver` from the registry."""
    spec = get_solver(config.solver)
    if state is not None and not spec.supports_state:
        raise ValueError(f"solver {spec.name!r} does not support warm starts")
    if config.stop_policy == "truncate" and not spec.supports_truncate:
        raise ValueError(
            f"solver {spec.name!r} does not implement stop_policy='truncate'")
    if config.partitioned and not spec.supports_partition:
        raise ValueError(
            f"solver {spec.name!r} does not implement partitioned budgets "
            f"(budget_split); solvers that do: "
            f"{[n for n, s in _REGISTRY.items() if s.supports_partition]}")
    result = spec.fn(problem, config, state)
    if config.partitioned and result.state is not None:
        # per-partition fill report: g_k(X) and the caps, for observability
        # and the per-shard acceptance checks (tests, launch --verify)
        constraint = resolve_constraint(problem, config)
        result.extra["g_part"] = constraint.np_value(
            np.asarray(result.state.covered_d))
        result.extra["caps"] = np.asarray(constraint.caps, np.float64)
        result.extra["bounds"] = constraint.bounds
    return result


def solve_sweep(problem, budgets: list[float],
                config: SolveConfig) -> list[SolverResult]:
    """Warm-started budget sweep: solve to B1, resume the SAME state to B2...

    Uses the "truncate" stop policy, under which the greedy selection path is
    budget-independent (paper Fig. 3), so each result's SELECTION —
    `order` (patched to the cumulative sequence), `selected`, `f_final`,
    `g_final`, `state` — is exactly what a cold solve at that budget would
    produce, without re-solving from scratch. The per-call bookkeeping
    (`f_history`/`time_history`/`n_exact_evals`) covers only each resumed
    segment; sum across results for sweep totals, don't compare a segment
    against a cold solve's.
    """
    if list(budgets) != sorted(budgets):
        raise ValueError("budgets must be ascending")
    spec = get_solver(config.solver)
    if not (spec.supports_state and spec.supports_truncate):
        raise ValueError(
            f"solver {config.solver!r} cannot sweep: it needs both warm "
            f"starts and the 'truncate' stop policy (budget-independent "
            f"selection path); solvers that can: "
            f"{[n for n, s in _REGISTRY.items() if s.supports_state and s.supports_truncate]}")
    cfg = config.replace(stop_policy="truncate")
    base_constraint = None
    if config.partitioned:
        # per-point constraints keep the SAME split shares, rescaled to each
        # total; the truncate ranking never reads the caps, so the selection
        # path stays budget-independent and warm == cold per point
        base_constraint = resolve_constraint(problem, config)
        if not hasattr(base_constraint, "scaled"):
            raise ValueError("budget_split sweeps need a PartitionedBudget "
                             "(or a constraint implementing .scaled)")
    state = None
    results: list[SolverResult] = []
    order: list[int] = []
    for b in budgets:
        step_cfg = cfg.replace(budget=float(b))
        if base_constraint is not None:
            step_cfg = step_cfg.replace(
                constraint=base_constraint.scaled(float(b)))
        r = solve(problem, step_cfg, state=state)
        order = order + r.order
        r.order = list(order)
        results.append(r)
        state = r.state
    return results
