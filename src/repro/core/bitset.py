"""Packed-uint32 bitset algebra.

The whole SCSK engine works over packed bitsets: coverage masks over queries
and documents, and clause->query / clause->doc incidence matrices. Packing is
32x denser than bool arrays and `lax.population_count` makes AND-NOT-popcount
the cheapest possible marginal-gain primitive on TPU VPUs.

Conventions:
  * a bitset over a universe of size n is a uint32 array [..., W] with
    W = ceil(n / 32); bit i lives in word i >> 5 at position i & 31
    (little-endian within the word).
  * padding bits (>= n) are always zero; every producer below guarantees it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32


def n_words(n_bits: int) -> int:
    return (n_bits + WORD - 1) // WORD


# ---------------------------------------------------------------------------
# numpy (host / preprocessing) side
# ---------------------------------------------------------------------------

def np_pack(bits: np.ndarray) -> np.ndarray:
    """Pack a bool array [..., n] into uint32 words [..., ceil(n/32)]."""
    bits = np.asarray(bits, dtype=bool)
    n = bits.shape[-1]
    w = n_words(n)
    padded = np.zeros(bits.shape[:-1] + (w * WORD,), dtype=bool)
    padded[..., :n] = bits
    padded = padded.reshape(bits.shape[:-1] + (w, WORD))
    weights = (np.uint32(1) << np.arange(WORD, dtype=np.uint32))
    return (padded.astype(np.uint32) * weights).sum(axis=-1, dtype=np.uint32)


def np_unpack(words: np.ndarray, n_bits: int) -> np.ndarray:
    """Unpack uint32 words [..., W] back to bool [..., n_bits]."""
    words = np.asarray(words, dtype=np.uint32)
    shifts = np.arange(WORD, dtype=np.uint32)
    bits = (words[..., :, None] >> shifts) & np.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (-1,))
    return bits[..., :n_bits].astype(bool)


def np_from_indices(idx: np.ndarray, n_bits: int) -> np.ndarray:
    """Bitset [W] with bits at `idx` set."""
    out = np.zeros(n_words(n_bits), dtype=np.uint32)
    idx = np.asarray(idx, dtype=np.int64)
    np.bitwise_or.at(out, idx >> 5, (np.uint32(1) << (idx & 31).astype(np.uint32)))
    return out


def np_to_indices(words: np.ndarray, n_bits: int) -> np.ndarray:
    return np.nonzero(np_unpack(words, n_bits))[-1]


def np_popcount(words: np.ndarray) -> np.ndarray:
    return np.bitwise_count(words.astype(np.uint32)).sum(axis=-1, dtype=np.int64)


# ---------------------------------------------------------------------------
# jax (device) side
# ---------------------------------------------------------------------------

def pack(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack bool [..., n] -> uint32 [..., W] (n padded up to a word multiple)."""
    n = bits.shape[-1]
    w = n_words(n)
    pad = w * WORD - n
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    bits = bits.reshape(bits.shape[:-1] + (w, WORD))
    weights = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=-1, dtype=jnp.uint32)


def unpack(words: jnp.ndarray, n_bits: int | None = None) -> jnp.ndarray:
    """Unpack uint32 [..., W] -> bool [..., n_bits or 32*W]."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (-1,))
    if n_bits is not None:
        bits = bits[..., :n_bits]
    return bits.astype(bool)


def popcount(words: jnp.ndarray) -> jnp.ndarray:
    """Total set bits along the last axis -> int32 [...]."""
    return jnp.sum(jax.lax.population_count(words).astype(jnp.int32), axis=-1)


def count_and_not(a: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """popcount(a & ~mask) along the last axis.

    This is the marginal-gain primitive: `a` is a candidate's incidence row,
    `mask` is the already-covered bitset.
    """
    return popcount(a & ~mask)


def bit_get(words: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather bits at positions `idx` from a flat bitset `words` [W]."""
    word = words[idx >> 5]
    return ((word >> (idx & 31).astype(jnp.uint32)) & jnp.uint32(1)).astype(jnp.bool_)


def or_rows(words: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """OR-reduce a stack of bitsets."""
    return jax.lax.reduce(
        words, jnp.uint32(0), jax.lax.bitwise_or, (axis,)
    )


def from_indices(idx: jnp.ndarray, n_bits: int, valid: jnp.ndarray | None = None,
                 *, unique: bool = False) -> jnp.ndarray:
    """Scatter-OR indices into a fresh bitset [W]. `valid` masks padded entries.

    unique=True (indices guaranteed distinct, e.g. sorted match-set lists):
    scatter-ADD of distinct powers of two is exactly OR — O(U) and scales to
    production bitsets (the one-hot route below is O(U*W) and would build a
    137 GB intermediate for a 2^28-doc universe).

    unique=False: jnp has no scatter-or and scatter-add double-counts
    duplicates, so we go through one-hot over words + OR-reduce; fine for
    U <= a few thousand and small W.
    """
    w = n_words(n_bits)
    bit = jnp.uint32(1) << (idx & 31).astype(jnp.uint32)
    word_idx = idx >> 5
    if valid is not None:
        bit = jnp.where(valid, bit, jnp.uint32(0))
        word_idx = jnp.where(valid, word_idx, 0)
    if unique:
        out = jnp.zeros((w,), jnp.uint32)
        return out.at[word_idx].add(bit, mode="drop")
    onehot = (word_idx[:, None] == jnp.arange(w)[None, :]).astype(jnp.uint32)  # [U, W]
    return or_rows(onehot * bit[:, None], axis=0)


def is_subset(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Elementwise bitset subset test a ⊆ b over the last axis (broadcasts)."""
    return jnp.all((a & b) == a, axis=-1)
