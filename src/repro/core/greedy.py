"""Cost-aware greedy for SCSK (paper eq. 13) — dense recompute-all variant.

Each step evaluates f(j|X) and g(j|X) for every candidate (two fused kernel
calls) and adds argmax_{feasible} f(j|X)/g(j|X). This is the semantics of
record: Lazy Greedy (Alg. 1) and Opt/Pes Greedy (Alg. 2) must select the same
sequence (up to exact ties), which the tests assert.

Registered as "greedy" (`repro.api`). Warm-startable: pass the `state` of a
previous `SolverResult` to resume — with `stop_policy="truncate"` the
selection path is budget-independent, so `solve_sweep` resumes across budgets
instead of re-solving from scratch (paper Fig. 3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.config import SolveConfig
from repro.core.constraint import as_constraint, resolve_constraint
from repro.core.problem import SCSKProblem, SolverResult
from repro.core.registry import register_solver
from repro.core.state import SolverState
from repro.core.trace import Trace

BIG = 1e12   # ratio stand-in for "free" clauses (g-gain == 0, f-gain > 0)


def ratio_of(fg: jax.Array, gg: jax.Array) -> jax.Array:
    return jnp.where(gg <= 0.0, fg * BIG, fg / jnp.maximum(gg, 1e-30))


@functools.partial(jax.jit, static_argnames=("cost_aware", "truncate"))
def _greedy_step(problem: SCSKProblem, state: SolverState, constraint, *,
                 cost_aware: bool = True, truncate: bool = False):
    fg = problem.f_gains(state.covered_q)
    gg, gg_part = constraint.gains(problem, state.covered_d)
    used = constraint.used(problem, state)
    candidates = (~state.selected) & (fg > 0.0)
    feasible = candidates & constraint.feasible(used, gg_part)
    score = ratio_of(fg, gg) if cost_aware else fg
    score = jnp.where(candidates if truncate else feasible, score, -jnp.inf)
    j = jnp.argmax(score)
    stop = ~feasible[j]
    applied = problem.apply(state, j)
    state = jax.tree_util.tree_map(
        lambda cur, new: jnp.where(stop, cur, new), state, applied)
    f_val = problem.f_value(state.covered_q)
    return state, f_val, j, stop


def greedy_step(problem: SCSKProblem, state: SolverState, budget, *,
                cost_aware: bool = True, truncate: bool = False):
    """One greedy selection over a SolverState.

    `budget` is a scalar knapsack budget or any `KnapsackConstraint` (a
    `PartitionedBudget` masks candidates that overflow ANY per-shard cap).
    Returns (state, f_val, j, stop). `truncate=False` masks the score to
    feasible candidates ("exhaust": classic greedy); `truncate=True` ranks
    ALL unselected candidates and stops at the first infeasible argmax, which
    makes the selection path budget-independent (warm-start sweeps).
    """
    return _greedy_step(problem, state, as_constraint(budget),
                        cost_aware=cost_aware, truncate=truncate)


@register_solver("greedy", supports_state=True, supports_truncate=True,
                 supports_partition=True,
                 description="dense cost-ratio greedy (paper eq. 13)")
def solve_greedy(problem: SCSKProblem, config: SolveConfig,
                 state: SolverState | None = None) -> SolverResult:
    cost_aware = bool(config.opt("cost_aware", True))
    state = problem.init_state() if state is None else state
    trace = Trace(config, f0=float(problem.f_value(state.covered_q)),
                  g0=float(state.g_used))
    constraint = resolve_constraint(problem, config)
    truncate = config.stop_policy == "truncate"
    c = problem.n_clauses

    order: list[int] = []
    steps = config.max_steps or c
    for _ in range(steps):
        state, f_val, j, stop = _greedy_step(
            problem, state, constraint, cost_aware=cost_aware,
            truncate=truncate)
        trace.add_evals(2 * c)
        if bool(stop):
            break
        order.append(int(j))
        trace.on_select(float(f_val), float(state.g_used))
        if trace.should_stop():
            break
    name = "greedy" if cost_aware else "agnostic-dense"
    return trace.result(name, problem, state, order)


def greedy(problem: SCSKProblem, budget: float, *, cost_aware: bool = True,
           max_steps: int | None = None, record_every: int = 1,
           time_limit: float | None = None) -> SolverResult:
    """Legacy keyword entrypoint; prefer `repro.api.solve`."""
    return solve_greedy(problem, SolveConfig(
        budget=budget, solver="greedy", max_steps=max_steps,
        record_every=record_every, time_limit=time_limit,
        options={"cost_aware": cost_aware}))
