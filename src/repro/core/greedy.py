"""Cost-aware greedy for SCSK (paper eq. 13) — dense recompute-all variant.

Each step evaluates f(j|X) and g(j|X) for every candidate (two fused kernel
calls) and adds argmax_{feasible} f(j|X)/g(j|X). This is the semantics of
record: Lazy Greedy (Alg. 1) and Opt/Pes Greedy (Alg. 2) must select the same
sequence (up to exact ties), which the tests assert.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problem import SCSKProblem, SolverResult

BIG = 1e12   # ratio stand-in for "free" clauses (g-gain == 0, f-gain > 0)


def ratio_of(fg: jax.Array, gg: jax.Array) -> jax.Array:
    return jnp.where(gg <= 0.0, fg * BIG, fg / jnp.maximum(gg, 1e-30))


@functools.partial(jax.jit, static_argnames=("cost_aware",))
def greedy_step(problem: SCSKProblem, covered_q, covered_d, selected,
                g_used, budget, *, cost_aware: bool = True):
    """One greedy selection. Returns updated state + (j, stop)."""
    fg = problem.f_gains(covered_q)
    gg = problem.g_gains(covered_d)
    feasible = (~selected) & (g_used + gg <= budget) & (fg > 0.0)
    score = ratio_of(fg, gg) if cost_aware else fg
    score = jnp.where(feasible, score, -jnp.inf)
    j = jnp.argmax(score)
    stop = ~feasible[j]
    covered_q2, covered_d2 = problem.add_clause(covered_q, covered_d, j)
    covered_q = jnp.where(stop, covered_q, covered_q2)
    covered_d = jnp.where(stop, covered_d, covered_d2)
    selected = selected.at[j].set(jnp.where(stop, selected[j], True))
    g_used = problem.g_value(covered_d)
    f_val = problem.f_value(covered_q)
    return covered_q, covered_d, selected, g_used, f_val, j, stop


def greedy(problem: SCSKProblem, budget: float, *, cost_aware: bool = True,
           max_steps: int | None = None, record_every: int = 1,
           time_limit: float | None = None) -> SolverResult:
    c = problem.n_clauses
    covered_q, covered_d = problem.empty_state()
    selected = jnp.zeros(c, bool)
    g_used = jnp.float32(0.0)
    budget = jnp.float32(budget)

    order: list[int] = []
    fh, gh, th = [0.0], [0.0], [0.0]
    t0 = time.perf_counter()
    n_evals = 0
    steps = max_steps or c
    for t in range(steps):
        covered_q, covered_d, selected, g_used, f_val, j, stop = greedy_step(
            problem, covered_q, covered_d, selected, g_used, budget,
            cost_aware=cost_aware)
        n_evals += 2 * c
        if bool(stop):
            break
        order.append(int(j))
        if (t % record_every) == 0:
            fh.append(float(f_val))
            gh.append(float(g_used))
            th.append(time.perf_counter() - t0)
        if time_limit is not None and th[-1] > time_limit:
            break
    name = "greedy" if cost_aware else "agnostic-dense"
    return SolverResult(
        name=name,
        selected=np.asarray(selected),
        order=order,
        f_final=float(problem.f_value(covered_q)),
        g_final=float(g_used),
        f_history=np.asarray(fh), g_history=np.asarray(gh),
        time_history=np.asarray(th), n_exact_evals=n_evals,
    )
