"""Optimistic/Pessimistic Greedy — paper Algorithm 2, TPU-native batched form.

The paper parallelizes over CPU threads: every candidate whose *optimistic*
ratio f̄/g̲ beats the best *pessimistic* ratio f̲/ḡ gets its gains refreshed
in parallel. On TPU we replace threads with a fixed-width batch: each round
gathers the top-K optimistic members of the refresh set C, re-evaluates their
exact gains with one fused kernel call, and selects once the exact-argmax
provably dominates every non-refreshed optimistic ratio (Theorem 4.2
guarantees j^(t) ∈ C, so this terminates with the exact greedy choice).

Bounds maintained per candidate (all eq.-14-style updates, Thm 4.1):
  f̄ upper / f̲ lower bounds of f(j|X);  ḡ upper / g̲ lower bounds of g(j|X).

The knapsack is a pluggable `KnapsackConstraint`: the ḡ/g̲ bounds are
per-partition MATRICES [C, P] (eq. 14 holds coordinatewise since every g_k is
submodular), ratios use the partition totals, and feasibility masks any
candidate whose optimistic cost overflows ANY per-shard cap. `GlobalBudget`
(P=1) reduces to the scalar pre-refactor arithmetic bit for bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.config import SolveConfig
from repro.core.constraint import resolve_constraint
from repro.core.greedy import ratio_of
from repro.core.problem import SCSKProblem, SolverResult
from repro.core.registry import register_solver
from repro.core.state import SolverState
from repro.core.trace import Trace

NEG = -jnp.inf


def _subset_gains(problem: SCSKProblem, constraint, covered_q, covered_d,
                  top_idx):
    """Exact f gains [K] and per-partition g gains [K, P] for K gathered rows.

    Mesh-aware: `A[top_idx]` on a (dp x model)-sharded incidence matrix makes
    XLA all-gather the whole operand (512 GB at solve_l scale — §Perf). The
    fused path (`distributed.mesh_fused`) instead slices rows owner-locally
    and folds the owner selection and the W-partial reduction into ONE psum
    over all mesh axes. Partitioned constraints take the direct path over
    the model axes — their covered_d word slices don't line up with the
    mesh's model sharding — but their per-partition gain kernel
    (`ops.partition_gain`) fuses owner-locally over the `"shard"` axis when
    one is present, so each partition's cost is computed on the device that
    owns it either way.
    """
    from repro import distributed
    from repro.core import bitset
    from repro.kernels import ops
    x = (problem.query_weights
         * (1.0 - bitset.unpack(covered_q).astype(jnp.float32)))[:, None]
    plan = distributed.current_plan()
    mesh, dp = plan.mesh, plan.data_axes
    P = jax.sharding.PartitionSpec

    def body(a_q, a_d, xw, cov_d, idx):
        rank = distributed.axis_rank(mesh, dp)
        rows_q = distributed.owner_select(a_q, idx, rank)
        rows_d = distributed.owner_select(a_d, idx, rank)
        fg_p = ops.bit_matvec(rows_q, xw)[:, 0]
        gg_p = ops.coverage_gain(rows_d, cov_d).astype(jnp.float32)
        axes = dp + ("model",)       # owner-select + W-partials in one psum
        return jax.lax.psum(fg_p, axes), jax.lax.psum(gg_p, axes)

    fused = None if constraint.n_parts > 1 else distributed.mesh_fused(
        body,
        in_specs=(P(dp, "model"), P(dp, "model"), P("model"), P("model"),
                  P()),
        out_specs=(P(), P()), mesh=mesh)
    if fused is None:
        rows_q = problem.clause_query_bits[top_idx]
        rows_d = problem.clause_doc_bits[top_idx]
        fg = ops.bit_matvec(rows_q, x)[:, 0]
        _, gg_part = constraint.gains(problem, covered_d, rows=rows_d)
        return fg, gg_part
    fg, gg = fused(problem.clause_query_bits, problem.clause_doc_bits, x,
                   covered_d, top_idx)
    return fg, gg[..., None]


@functools.partial(jax.jit, static_argnames=("k",))
def optpes_round(problem: SCSKProblem, state, constraint, *, k: int):
    """One refresh-(and maybe select) round. Fully batched.

    `state` is (covered_q, covered_d, selected, g_part [P], fbar [C],
    flow [C], gbar [C, P], glow [C, P], f_val).
    """
    (covered_q, covered_d, selected, g_part,
     fbar, flow, gbar, glow, f_val) = state

    feasible = (~selected) & constraint.feasible(g_part, glow) & (fbar > 0.0)
    opt = jnp.where(feasible, ratio_of(fbar, jnp.sum(glow, -1)), NEG)
    pes = jnp.where(feasible, ratio_of(flow, jnp.sum(gbar, -1)), NEG)
    best_pes = jnp.max(pes)
    in_c = feasible & (opt >= best_pes)

    # top-K of the refresh set C by optimistic ratio
    top_vals, top_idx = jax.lax.top_k(jnp.where(in_c, opt, NEG), k)
    valid = top_vals > NEG

    # exact re-evaluation (one fused kernel call over the gathered rows)
    fg, gg_part = _subset_gains(problem, constraint, covered_q, covered_d,
                                top_idx)
    gg = jnp.sum(gg_part, -1)

    def upd(arr, vals):
        keep = valid if vals.ndim == 1 else valid[:, None]
        return arr.at[top_idx].set(jnp.where(keep, vals, arr[top_idx]))
    fbar, flow = upd(fbar, fg), upd(flow, fg)
    gbar, glow = upd(gbar, gg_part), upd(glow, gg_part)

    # selection test: exact-argmax among refreshed beats all other optimists
    exact_feas = valid & (~selected[top_idx]) \
        & constraint.feasible(g_part, gg_part) & (fg > 0.0)
    exact_ratio = jnp.where(exact_feas, ratio_of(fg, gg), NEG)
    bi = jnp.argmax(exact_ratio)
    j_star = top_idx[bi]
    r_star = exact_ratio[bi]

    refreshed = jnp.zeros_like(selected).at[top_idx].set(valid)
    opt2 = jnp.where(feasible & ~refreshed,
                     ratio_of(fbar, jnp.sum(glow, -1)), NEG)
    other_best = jnp.max(opt2)
    do_select = (r_star > NEG) & (r_star >= other_best)
    any_feasible = jnp.any(feasible)

    def _row(mat, jj):
        """Owner-local row select (avoids whole-matrix all-gather on
        sharded operands — see _subset_gains)."""
        from repro import distributed
        return distributed.owner_row(mat, jj, w_axis="model")

    def select(args):
        covered_q, covered_d, selected, g_part, fbar, flow, gbar, glow, f_val = args
        fg_s, gg_s = fg[bi], gg_part[bi]
        cq = covered_q | _row(problem.clause_query_bits, j_star)
        cd = covered_d | _row(problem.clause_doc_bits, j_star)
        sel = selected.at[j_star].set(True)
        # eq. (14) lower-bound updates for every candidate, per partition
        glow2 = jnp.maximum(0.0, glow - gg_s[None, :])
        flow2 = jnp.maximum(0.0, flow - fg_s)
        return (cq, cd, sel, constraint.value(problem, cd),
                fbar, flow2, gbar, glow2, f_val + fg_s)

    def no_select(args):
        return args

    state = jax.lax.cond(
        do_select, select, no_select,
        (covered_q, covered_d, selected, g_part, fbar, flow, gbar, glow,
         f_val))
    return state, do_select, any_feasible, j_star


@register_solver("optpes", supports_state=True, supports_partition=True,
                 description="batched optimistic/pessimistic greedy (Alg. 2)")
def solve_optpes(problem: SCSKProblem, config: SolveConfig,
                 state: SolverState | None = None) -> SolverResult:
    c = problem.n_clauses
    k = min(int(config.opt("k", 256)), c)
    state = problem.init_state() if state is None else state
    constraint = resolve_constraint(problem, config)
    covered_q, covered_d = state.covered_q, state.covered_d
    f0 = float(problem.f_value(covered_q))
    # warm start: exact singleton gains at the resumed state are valid
    # optimistic AND pessimistic bounds (they are exact)
    fg0 = problem.f_gains(covered_q)
    _, gg0 = constraint.gains(problem, covered_d)
    round_state = (covered_q, covered_d, state.selected,
                   constraint.used(problem, state),
                   fg0, fg0, gg0, gg0, jnp.float32(f0))

    trace = Trace(config, f0=f0, g0=float(state.g_used))
    trace.add_evals(2 * c)
    order: list[int] = []
    max_sel = config.max_steps or c
    rounds_cap = 50 * c // k + 200
    rounds = 0
    while len(order) < max_sel and rounds < rounds_cap:
        round_state, did, any_feasible, j_star = optpes_round(
            problem, round_state, constraint, k=k)
        rounds += 1
        trace.add_evals(2 * k)
        if not bool(any_feasible):
            break
        if bool(did):
            order.append(int(j_star))
            trace.on_select(float(round_state[8]),
                            float(jnp.sum(round_state[3])))
            if trace.should_stop():
                break

    final = SolverState(
        covered_q=round_state[0], covered_d=round_state[1],
        selected=round_state[2], g_used=jnp.sum(round_state[3]),
        step=state.step + len(order))
    return trace.result(f"optpes-k{k}", problem, final, order)


def optpes_greedy(problem: SCSKProblem, budget: float, *, k: int = 256,
                  max_steps: int | None = None,
                  time_limit: float | None = None) -> SolverResult:
    """Legacy keyword entrypoint; prefer `repro.api.solve`."""
    return solve_optpes(problem, SolveConfig(
        budget=budget, solver="optpes", max_steps=max_steps,
        time_limit=time_limit, options={"k": k}))
