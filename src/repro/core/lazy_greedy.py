"""Lazy Greedy for SCSK — paper Algorithm 1, faithful host-heap version.

Keeps a max-heap keyed by the optimistic ratio f̄(j|X)/g̲(j|X) where
  f̄ : stale (upper-bound, by submodularity of f) marginal f-gains
  g̲ : lower bound of the g-gain maintained with the paper's update rule
      (eq. 14), proven correct in Theorem 4.1:
          g̲(j|X^{t+1}) = max(0, g̲(j|X^t) − g(j^{(t)}|X^t))

Only heap-top candidates get exact (expensive) re-evaluation, so the count of
exact oracle calls — `n_exact_evals` — is the laziness metric benchmarked in
Fig. 2/4. The selected sequence provably equals dense greedy's (tested).

The knapsack side is a pluggable `KnapsackConstraint`: every g̲ bound is a
per-partition VECTOR (each g_k is submodular, so eq. 14 holds coordinatewise)
and feasibility masks candidates whose optimistic cost overflows ANY
partition cap — with `GlobalBudget` (one partition) the arithmetic reduces to
the scalar pre-refactor comparisons, bit for bit.

Registered as "lazy" (`repro.api`). Warm-startable: resuming re-seeds the
bounds with exact singleton gains at the resumed state (valid upper/lower
bounds by submodularity), so the continuation equals a fresh lazy solve over
the residual problem.
"""
from __future__ import annotations

import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import SolveConfig
from repro.core.constraint import resolve_constraint
from repro.core.greedy import BIG
from repro.core.problem import SCSKProblem, SolverResult
from repro.core.registry import register_solver
from repro.core.state import SolverState
from repro.core.trace import Trace


@jax.jit
def _exact_gains_one(problem: SCSKProblem, constraint, covered_q, covered_d,
                     j):
    fg = problem.f_gains(covered_q, rows=problem.clause_query_bits[j][None])[0]
    _, gg_part = constraint.gains(
        problem, covered_d, rows=problem.clause_doc_bits[j][None])
    return fg, gg_part[0]


@jax.jit
def _singleton_gains(problem: SCSKProblem, constraint, covered_q, covered_d):
    fg = problem.f_gains(covered_q)
    _, gg_part = constraint.gains(problem, covered_d)
    return fg, gg_part


def _ratio(f: float, g: float) -> float:
    return f * BIG if g <= 0 else f / g


@register_solver("lazy", supports_state=True, supports_partition=True,
                 description="lazy greedy with Thm-4.1 bounds (Alg. 1)")
def solve_lazy_greedy(problem: SCSKProblem, config: SolveConfig,
                      state: SolverState | None = None) -> SolverResult:
    c = problem.n_clauses
    state = problem.init_state() if state is None else state
    covered_q, covered_d = state.covered_q, state.covered_d
    constraint = resolve_constraint(problem, config)
    caps = np.asarray(constraint.caps, np.float64) \
        if hasattr(constraint, "caps") else \
        np.asarray([float(constraint.budget)], np.float64)

    fbar_d, glow_d = _singleton_gains(problem, constraint, covered_q,
                                      covered_d)
    fbar = np.asarray(fbar_d, np.float64)
    glow = np.asarray(glow_d, np.float64)          # [C, P] per-partition g̲
    glow_tot = glow.sum(axis=-1)

    selected = np.asarray(state.selected).copy()
    order: list[int] = []
    g_used = float(state.g_used)
    g_part = constraint.np_value(np.asarray(covered_d))
    f_val = float(problem.f_value(covered_q))
    trace = Trace(config, f0=f_val, g0=g_used)
    trace.add_evals(2 * c)

    def fits(j: int) -> bool:
        """Optimistic feasibility: the lower-bound cost fits EVERY cap."""
        return bool(np.all(g_part + glow[j] <= caps))

    steps = config.max_steps or c
    for _ in range(steps):
        # rebuild heap of optimistically-feasible candidates (Alg. 1 outer loop)
        heap = [(-_ratio(fbar[j], glow_tot[j]), j) for j in range(c)
                if not selected[j] and fits(j) and fbar[j] > 0]
        heapq.heapify(heap)
        chosen = -1
        while heap:
            _, j = heapq.heappop(heap)
            # tighten bounds with exact evaluation
            fg, gg_part = _exact_gains_one(problem, constraint, covered_q,
                                           covered_d, jnp.int32(j))
            fbar[j] = float(fg)
            glow[j] = np.asarray(gg_part, np.float64)
            glow_tot[j] = glow[j].sum()
            trace.add_evals(2)
            if not fits(j):
                continue                          # Alg. 1: infeasible, skip
            if fbar[j] <= 0:
                continue
            r = _ratio(fbar[j], glow_tot[j])
            if not heap or r >= -heap[0][0]:
                chosen = j                        # exact top beats next optimist
                break
            heapq.heappush(heap, (-r, j))
        if chosen < 0:
            break
        # select
        fg_star, gg_star = fbar[chosen], glow[chosen].copy()
        covered_q, covered_d = problem.add_clause(
            covered_q, covered_d, jnp.int32(chosen))
        selected[chosen] = True
        order.append(chosen)
        g_part = constraint.np_value(np.asarray(covered_d))
        g_used = float(g_part.sum())   # partitions tile covered_d exactly
        f_val += fg_star
        # Theorem 4.1 bound update (eq. 14), per partition, every candidate
        glow = np.maximum(0.0, glow - gg_star[None, :])
        glow_tot = glow.sum(axis=-1)
        # f̄ stays as-is: stale f-gains upper-bound current ones (submodularity)
        trace.on_select(f_val, g_used)
        if trace.should_stop():
            break

    final = SolverState(
        covered_q=covered_q, covered_d=covered_d,
        selected=jnp.asarray(selected), g_used=jnp.float32(g_used),
        step=state.step + len(order))
    return trace.result("lazy-greedy", problem, final, order)


def lazy_greedy(problem: SCSKProblem, budget: float, *,
                max_steps: int | None = None,
                time_limit: float | None = None) -> SolverResult:
    """Legacy keyword entrypoint; prefer `repro.api.solve`."""
    return solve_lazy_greedy(problem, SolveConfig(
        budget=budget, solver="lazy", max_steps=max_steps,
        time_limit=time_limit))
