"""Lazy Greedy for SCSK — paper Algorithm 1, faithful host-heap version.

Keeps a max-heap keyed by the optimistic ratio f̄(j|X)/g̲(j|X) where
  f̄ : stale (upper-bound, by submodularity of f) marginal f-gains
  g̲ : lower bound of the g-gain maintained with the paper's update rule
      (eq. 14), proven correct in Theorem 4.1:
          g̲(j|X^{t+1}) = max(0, g̲(j|X^t) − g(j^{(t)}|X^t))

Only heap-top candidates get exact (expensive) re-evaluation, so the count of
exact oracle calls — `n_exact_evals` — is the laziness metric benchmarked in
Fig. 2/4. The selected sequence provably equals dense greedy's (tested).
"""
from __future__ import annotations

import heapq
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.greedy import BIG
from repro.core.problem import SCSKProblem, SolverResult


@jax.jit
def _exact_gains_one(problem: SCSKProblem, covered_q, covered_d, j):
    fg = problem.f_gains(covered_q, rows=problem.clause_query_bits[j][None])[0]
    gg = problem.g_gains(covered_d, rows=problem.clause_doc_bits[j][None])[0]
    return fg, gg


@jax.jit
def _singleton_gains(problem: SCSKProblem, covered_q, covered_d):
    return problem.f_gains(covered_q), problem.g_gains(covered_d)


def _ratio(f: float, g: float) -> float:
    return f * BIG if g <= 0 else f / g


def lazy_greedy(problem: SCSKProblem, budget: float, *,
                max_steps: int | None = None,
                time_limit: float | None = None) -> SolverResult:
    c = problem.n_clauses
    covered_q, covered_d = problem.empty_state()

    fbar_d, gg_d = _singleton_gains(problem, covered_q, covered_d)
    fbar = np.asarray(fbar_d, np.float64)
    glow = np.asarray(gg_d, np.float64)
    n_exact = 2 * c

    selected = np.zeros(c, bool)
    order: list[int] = []
    g_used = 0.0
    f_val = 0.0
    fh, gh, th = [0.0], [0.0], [0.0]
    t0 = time.perf_counter()

    steps = max_steps or c
    for _ in range(steps):
        # rebuild heap of optimistically-feasible candidates (Alg. 1 outer loop)
        heap = [(-_ratio(fbar[j], glow[j]), j) for j in range(c)
                if not selected[j] and g_used + glow[j] <= budget and fbar[j] > 0]
        heapq.heapify(heap)
        chosen = -1
        while heap:
            _, j = heapq.heappop(heap)
            # tighten bounds with exact evaluation
            fg, gg = _exact_gains_one(problem, covered_q, covered_d, jnp.int32(j))
            fbar[j], glow[j] = float(fg), float(gg)
            n_exact += 2
            if g_used + glow[j] > budget:
                continue                          # Alg. 1: infeasible, skip
            if fbar[j] <= 0:
                continue
            r = _ratio(fbar[j], glow[j])
            if not heap or r >= -heap[0][0]:
                chosen = j                        # exact top beats next optimist
                break
            heapq.heappush(heap, (-r, j))
        if chosen < 0:
            break
        # select
        fg_star, gg_star = fbar[chosen], glow[chosen]
        covered_q, covered_d = problem.add_clause(
            covered_q, covered_d, jnp.int32(chosen))
        selected[chosen] = True
        order.append(chosen)
        g_used = float(problem.g_value(covered_d))
        f_val += fg_star
        # Theorem 4.1 bound update (eq. 14) for every candidate
        glow = np.maximum(0.0, glow - gg_star)
        # f̄ stays as-is: stale f-gains upper-bound current ones (submodularity)
        fh.append(f_val)
        gh.append(g_used)
        th.append(time.perf_counter() - t0)
        if time_limit is not None and th[-1] > time_limit:
            break

    return SolverResult(
        name="lazy-greedy",
        selected=selected, order=order,
        f_final=float(problem.f_value(covered_q)),
        g_final=g_used,
        f_history=np.asarray(fh), g_history=np.asarray(gh),
        time_history=np.asarray(th), n_exact_evals=n_exact,
    )
