"""Iterative Submodular Knapsack — paper Algorithm 3 (Iyer & Bilmes 2013).

The submodular cost g is replaced by a modular upper bound that is tight at
the current solution X_t (eq. 15):

  ĝ₁: cost g(j|X_t∖j) for kept items, g({j}) for new items
  ĝ₂: cost g(j|X̄∖j)  for kept items, g(j|X_t) for new items

Since ĝ ≥ g everywhere, every inner solution is feasible for the true
constraint. The inner problem — max f(X) s.t. modular cost ≤ B' — is a plain
submodular knapsack solved with a batched cost-ratio greedy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset
from repro.core.config import SolveConfig
from repro.core.greedy import ratio_of
from repro.core.problem import SCSKProblem, SolverResult
from repro.core.registry import register_solver
from repro.core.state import SolverState
from repro.core.trace import Trace


@functools.partial(jax.jit, donate_argnames=())
def _knapsack_step(problem: SCSKProblem, covered_q, selected, spent, w, b_eff):
    fg = problem.f_gains(covered_q)
    feasible = (~selected) & (spent + w <= b_eff) & (fg > 0.0)
    score = jnp.where(feasible, ratio_of(fg, w), -jnp.inf)
    j = jnp.argmax(score)
    stop = ~feasible[j]
    cq = covered_q | problem.clause_query_bits[j]
    covered_q = jnp.where(stop, covered_q, cq)
    selected = selected.at[j].set(jnp.where(stop, selected[j], True))
    spent = jnp.where(stop, spent, spent + w[j])
    return covered_q, selected, spent, stop


def _modular_knapsack(problem: SCSKProblem, w: jax.Array, b_eff: float,
                      max_steps: int) -> np.ndarray:
    covered_q = jnp.zeros(problem.wq, jnp.uint32)
    selected = jnp.zeros(problem.n_clauses, bool)
    spent = jnp.float32(0.0)
    w = w.astype(jnp.float32)
    b_eff = jnp.float32(b_eff)
    for _ in range(max_steps):
        covered_q, selected, spent, stop = _knapsack_step(
            problem, covered_q, selected, spent, w, b_eff)
        if bool(stop):
            break
    return np.asarray(selected)


@jax.jit
def _or_except_one(rows: jax.Array) -> jax.Array:
    """[T, W] -> [T, W]: OR of all rows except row t (prefix/suffix trick)."""
    t = rows.shape[0]
    zeros = jnp.zeros((1, rows.shape[1]), rows.dtype)

    def scan_or(carry, row):
        return carry | row, carry
    _, prefix = jax.lax.scan(scan_or, zeros[0], rows)
    _, suffix = jax.lax.scan(scan_or, zeros[0], rows, reverse=True)
    return prefix | suffix


@jax.jit
def _coverage_counts(rows: jax.Array) -> jax.Array:
    """[C, W] packed -> int32 [W*32]: per-doc cover multiplicity."""
    def body(acc, row):
        return acc + bitset.unpack(row).astype(jnp.int32), None
    acc0 = jnp.zeros(rows.shape[1] * 32, jnp.int32)
    acc, _ = jax.lax.scan(body, acc0, rows)
    return acc


def _solve_isk(problem: SCSKProblem, config: SolveConfig, variant: int,
               state: SolverState | None = None) -> SolverResult:
    assert variant in (1, 2)
    if state is not None:
        raise ValueError("isk does not support warm starts")
    budget = config.budget
    c = problem.n_clauses
    singleton_g = problem.g_gains(jnp.zeros(problem.wd, jnp.uint32))
    if variant == 2:
        # g(j | X̄∖j) = #docs covered *only* by clause j — precomputable
        counts = _coverage_counts(problem.clause_doc_bits)            # [Wd*32]
        only_once = (counts == 1).astype(jnp.float32)
        w_kept_global = problem.f_gains(                              # reuse matvec
            jnp.zeros(problem.wd, jnp.uint32), rows=problem.clause_doc_bits,
            weights=only_once)

    selected = np.zeros(c, bool)
    trace = Trace(config)
    f_final, g_final = 0.0, 0.0
    max_inner = config.opt("max_inner") or c
    max_outer = int(config.opt("max_outer", 10))
    covered_q2 = jnp.zeros(problem.wq, jnp.uint32)
    covered_d2 = jnp.zeros(problem.wd, jnp.uint32)

    for _ in range(max_outer):
        sel_idx = np.nonzero(selected)[0]
        covered_d = (bitset.or_rows(problem.clause_doc_bits[sel_idx], axis=0)
                     if len(sel_idx) else jnp.zeros(problem.wd, jnp.uint32))
        g_xt = float(problem.g_value(covered_d))

        w = np.asarray(singleton_g, np.float64).copy() if variant == 1 \
            else np.asarray(problem.g_gains(covered_d), np.float64)
        if len(sel_idx):
            if variant == 1:
                rows = problem.clause_doc_bits[sel_idx]
                others = _or_except_one(rows)
                kept = problem.g_gains(jnp.zeros(problem.wd, jnp.uint32),
                                       rows=rows & ~others)
                w[sel_idx] = np.asarray(kept, np.float64)
            else:
                w[sel_idx] = np.asarray(w_kept_global, np.float64)[sel_idx]
        b_eff = budget - g_xt + float(w[sel_idx].sum()) if len(sel_idx) else budget

        new_sel = _modular_knapsack(problem, jnp.asarray(w), b_eff, max_inner)
        sel_idx2 = np.nonzero(new_sel)[0]
        covered_d2 = (bitset.or_rows(problem.clause_doc_bits[sel_idx2], axis=0)
                      if len(sel_idx2) else jnp.zeros(problem.wd, jnp.uint32))
        covered_q2 = (bitset.or_rows(problem.clause_query_bits[sel_idx2], axis=0)
                      if len(sel_idx2) else jnp.zeros(problem.wq, jnp.uint32))
        f_final = float(problem.f_value(covered_q2))
        g_final = float(problem.g_value(covered_d2))
        trace.on_select(f_final, g_final)
        if np.array_equal(new_sel, selected):
            break
        selected = new_sel
        if trace.should_stop():
            break

    final = SolverState(
        covered_q=covered_q2, covered_d=covered_d2,
        selected=jnp.asarray(selected), g_used=jnp.float32(g_final),
        step=jnp.int32(int(selected.sum())))
    return trace.result(f"isk{variant}", problem, final,
                        list(np.nonzero(selected)[0]))


@register_solver("isk1", description="iterative submodular knapsack, ĝ₁ bound")
def solve_isk1(problem: SCSKProblem, config: SolveConfig,
               state: SolverState | None = None) -> SolverResult:
    return _solve_isk(problem, config, 1, state)


@register_solver("isk2", description="iterative submodular knapsack, ĝ₂ bound")
def solve_isk2(problem: SCSKProblem, config: SolveConfig,
               state: SolverState | None = None) -> SolverResult:
    return _solve_isk(problem, config, 2, state)


def isk(problem: SCSKProblem, budget: float, *, variant: int = 1,
        max_outer: int = 10, max_inner: int | None = None,
        time_limit: float | None = None) -> SolverResult:
    """Legacy keyword entrypoint; prefer `repro.api.solve`."""
    return _solve_isk(problem, SolveConfig(
        budget=budget, solver=f"isk{variant}", time_limit=time_limit,
        options={"max_outer": max_outer, "max_inner": max_inner}), variant)
