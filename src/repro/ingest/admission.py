"""Secretary-style streaming admission of clauses into Tier 1.

Between warm refits, arriving documents activate clauses the last solve did
NOT select (a clause's marginal f/g ratio changes the moment new docs land in
its match set). Re-solving per arrival is off the table — the whole point of
the SCSK formulation is that solves are periodic — so admission is a ONE-PASS
online decision: each activated clause is offered once, with its current
marginal ratio f(j|X)/g(j|X), and is either admitted into the live selection
now (eviction deferred to the next warm refit) or passed over.

The policy is the classical observe-then-accept secretary relaxation adapted
to an infinite stream: the first `observe` offers are never admitted, only
recorded; afterwards an offer is admitted iff it clears the running
`quantile` of the last `window` observed ratios AND the live knapsack
constraint says the clause still fits every partition it touches. Admitting
only above a trailing quantile keeps the policy scale-free (ratios drift as
coverage saturates) and the constraint gate keeps every admission feasible —
the next refit starts from a feasible warm state.

This mirrors the threshold-based streaming-submodular tradition
(sieve/secretary hybrids); the knapsack-feasibility gate is the part the
tiering setting adds, because admission here spends real per-shard index
budget (`core.constraint.KnapsackConstraint`).

Note the MANDATORY/OPTIONAL split (Theorem 3.1): new docs matching an
already-selected clause are not offers — they MUST enter Tier 1 with their
clause, or eligible queries would miss them. The ingest controller handles
that by re-deriving coverage from the fixed selection (`state_for`); only
unselected clauses reach this policy.
"""
from __future__ import annotations

import collections
import dataclasses


@dataclasses.dataclass
class AdmissionDecision:
    clause: int
    ratio: float
    threshold: float
    admitted: bool
    reason: str        # "observe" | "infeasible" | "below" | "admitted"


class AdmissionPolicy:
    """Observe-then-accept trailing-quantile admission.

    observe   : offers recorded (never admitted) before the gate opens
    quantile  : trailing ratio quantile an offer must clear to be admitted
    window    : trailing offers the quantile is computed over
    min_ratio : absolute floor under which nothing is ever admitted
    """

    def __init__(self, *, observe: int = 16, quantile: float = 0.7,
                 window: int = 128, min_ratio: float = 0.0):
        if not 0.0 <= quantile <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {quantile}")
        self.observe = observe
        self.quantile = quantile
        self.min_ratio = min_ratio
        self._ratios: collections.deque[float] = collections.deque(
            maxlen=window)
        self.n_offers = 0
        self.n_admitted = 0
        self.n_infeasible = 0
        self.decisions: list[AdmissionDecision] = []

    def threshold(self) -> float:
        """The ratio an offer must clear right now (inf while observing)."""
        if self.n_offers < self.observe or not self._ratios:
            return float("inf")
        ranked = sorted(self._ratios)
        k = min(len(ranked) - 1, int(self.quantile * len(ranked)))
        return max(ranked[k], self.min_ratio)

    def offer(self, clause: int, ratio: float, feasible: bool) -> bool:
        """One-pass decision for an activated clause; True = admit now."""
        thr = self.threshold()
        self.n_offers += 1
        self._ratios.append(float(ratio))
        if self.n_offers <= self.observe:
            verdict, reason = False, "observe"
        elif not feasible:
            self.n_infeasible += 1
            verdict, reason = False, "infeasible"
        elif ratio >= thr:
            self.n_admitted += 1
            verdict, reason = True, "admitted"
        else:
            verdict, reason = False, "below"
        self.decisions.append(AdmissionDecision(
            clause=int(clause), ratio=float(ratio), threshold=thr,
            admitted=verdict, reason=reason))
        return verdict

    def summary(self) -> str:
        return (f"offers={self.n_offers} admitted={self.n_admitted} "
                f"infeasible={self.n_infeasible} thr={self.threshold():.4g}")
