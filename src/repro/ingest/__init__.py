"""repro.ingest — live document ingestion with streaming Tier-1 admission.

The corpus becomes mutable end to end: `data.incidence.append_docs` grows
the packed structures by word-aligned blocks (existing words never move),
`DocumentFeed` delivers drift-correlated arrivals, `AdmissionPolicy` makes
one-pass secretary-style admit decisions under live knapsack caps, and
`IngestController` splices the ingest leg into the serve → refit loop while
`TieredCluster.swap_corpus` rolls the new corpus version replica-by-replica
with zero downtime.
"""
from repro.ingest.admission import AdmissionDecision, AdmissionPolicy
from repro.ingest.controller import (IngestController, IngestReport,
                                     IngestWindowReport, run_ingest)
from repro.ingest.feed import DocumentFeed

__all__ = [
    "AdmissionDecision", "AdmissionPolicy", "DocumentFeed",
    "IngestController", "IngestReport", "IngestWindowReport", "run_ingest",
]
