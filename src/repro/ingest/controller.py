"""The ingest control loop: serve → ingest → (maybe) refit, per window.

`IngestController` grows `stream.RetieringController` with a live-corpus leg.
Each window:

  1. serve the window's queries (in small chunks, so rolling corpus swaps
     interleave with traffic the way a live fleet sees them);
  2. INGEST the window's document arrivals (`DocumentFeed`):
       a. append them to the corpus as one word-aligned block
          (`data.incidence.append_docs`) and grow the device problem
          (`SCSKProblem.with_doc_block`) — existing words never move;
       b. MANDATORY admission: with the selection fixed, any new doc matching
          a selected clause must enter Tier 1 (Theorem 3.1) — re-deriving the
          solver state from the fixed selection (`state_for`) against the
          grown problem does exactly that, and may overspend caps: eviction
          is deferred to the next warm refit (`trim_state` sheds overflow);
       c. OPTIONAL admission: clauses the last solve skipped but the new
          block activated are offered one-pass to the secretary-style
          `AdmissionPolicy`, scored by live marginal ratio through the
          existing f/g kernels and gated on real `KnapsackConstraint`
          headroom;
       d. roll the fleet to the new corpus version (`swap_corpus`): rolling
          replica-by-replica by default, or stop-the-world (`immediate`) as
          the comparison arm;
  3. on drift triggers, warm-refit exactly as the base loop — against the
     grown problem, with per-shard caps grown to the appended bounds.

Budget policy: `"track_corpus"` scales the caps with document growth (the
fleet buys shelf space as the corpus grows — coverage comparisons stay
budget-fair per doc); `"fixed"` keeps the original caps (ingest squeezes the
existing budget).
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bitset
from repro.obs.render import render_line
from repro.core.constraint import (GlobalBudget, PartitionedBudget,
                                   resolve_constraint)
from repro.data import incidence
from repro.ingest.admission import AdmissionPolicy
from repro.ingest.feed import DocumentFeed
from repro.serve.engine import ServeStats
from repro.stream.controller import RetieringController, WindowReport

_ADMISSION = obs.counter("admission_total",
                         "optional-admission offer decisions",
                         labels=("decision",))
_INGESTED = obs.counter("ingest_docs_total", "documents appended")
_CORPUS_V = obs.gauge("corpus_version", "live corpus version")
_REJECT_FRAC = obs.gauge("admission_reject_frac",
                         "rejected fraction of this window's offers")
from repro.stream.drift import TrafficSimulator, TrafficWindow


@dataclasses.dataclass
class IngestWindowReport:
    """One window of the serve → ingest → refit loop."""
    serve: WindowReport
    n_arrived: int = 0           # docs the feed delivered this window
    n_docs: int = 0              # corpus size after the append
    corpus_version: int = 0      # engine corpus version after the swap
    n_mandatory: int = 0         # Tier-1 docs added by the fixed selection
    n_offers: int = 0            # optional clauses offered to the policy
    n_admitted: int = 0          # ... of which admitted
    cap_overflow: float = 0.0    # max docs over any cap after mandatory growth
    ingest_seconds: float = 0.0  # append + admission + swap wall time
    ingest_ok: bool | None = None  # served-vs-reference parity (verify only)

    def line(self) -> str:
        return render_line(self.serve.line(), [
            ("@docs", f"+{self.n_arrived}docs "
                      f"(v{self.corpus_version}, {self.n_docs} total)"),
            ("admit", f"{self.n_admitted}/{self.n_offers}"),
            ("t1+", self.n_mandatory),
            ("ingest", self.ingest_ok)])

    def to_dict(self) -> dict:
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)
             if f.name != "serve"}
        d["serve"] = self.serve.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "IngestWindowReport":
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in names}
        kw["serve"] = WindowReport.from_dict(d.get("serve", {}))
        return cls(**kw)


@dataclasses.dataclass
class IngestReport:
    """A whole ingest run: per-window reports + cumulative serve stats."""
    scenario: str
    windows: list[IngestWindowReport]
    cumulative: ServeStats
    rollout: str = "rolling"
    admission_summary: str = ""

    @property
    def mean_coverage(self) -> float:
        return float(np.mean([w.serve.coverage for w in self.windows])) \
            if self.windows else 0.0

    @property
    def late_coverage(self) -> float:
        """Mean windowed coverage over the back half of the run — where the
        admission policy has had arrivals to act on (the A/B metric)."""
        if not self.windows:
            return 0.0
        tail = self.windows[len(self.windows) // 2:]
        return float(np.mean([w.serve.coverage for w in tail]))

    @property
    def n_ingested(self) -> int:
        return sum(w.n_arrived for w in self.windows)

    @property
    def n_admitted(self) -> int:
        return sum(w.n_admitted for w in self.windows)

    @property
    def n_refits(self) -> int:
        return sum(1 for w in self.windows if w.serve.refit)

    def failed_windows(self) -> int:
        """Windows where a performed check failed — served-vs-reference
        parity (`ingest_ok`) or refit parity — the bench's outage count."""
        return sum(1 for w in self.windows
                   if w.ingest_ok is False or w.serve.parity_ok is False)

    def summary(self) -> str:
        return render_line(f"[{self.scenario}/{self.rollout}]", [
            ("@windows", f"{len(self.windows)} windows"),
            ("@docs", f"+{self.n_ingested} docs"),
            ("admitted", self.n_admitted),
            ("mean_cov", self.mean_coverage),
            ("late_cov", self.late_coverage),
            ("refits", self.n_refits),
            ("failed", self.failed_windows())])

    def to_dict(self) -> dict:
        return {"scenario": self.scenario, "rollout": self.rollout,
                "admission_summary": self.admission_summary,
                "windows": [w.to_dict() for w in self.windows],
                "cumulative": self.cumulative.to_dict(),
                "mean_coverage": self.mean_coverage,
                "late_coverage": self.late_coverage,
                "n_ingested": self.n_ingested, "n_admitted": self.n_admitted,
                "n_refits": self.n_refits,
                "failed_windows": self.failed_windows()}

    @classmethod
    def from_dict(cls, d: dict) -> "IngestReport":
        return cls(scenario=d["scenario"],
                   windows=[IngestWindowReport.from_dict(w)
                            for w in d.get("windows", [])],
                   cumulative=ServeStats.from_dict(d.get("cumulative", {})),
                   rollout=d.get("rollout", "rolling"),
                   admission_summary=d.get("admission_summary", ""))


class IngestController(RetieringController):
    """Drift-aware re-tiering PLUS live document ingestion.

    `rollout="rolling"` swaps corpus versions replica-by-replica through the
    cluster's `swap_corpus` (single engines are inherently stop-the-world);
    `"stw"` forces `immediate=True` — the A/B comparison arm. `admission`
    None disables optional admission (mandatory Theorem-3.1 growth always
    happens; without it exactness would break the moment a doc arrived).
    """

    def __init__(self, pipe, *, feed: DocumentFeed,
                 admission: AdmissionPolicy | None = None,
                 rollout: str = "rolling",
                 budget_policy: str = "track_corpus",
                 verify_ingest: bool = False,
                 serve_batch: int | None = 64, **kw):
        if rollout not in ("rolling", "stw"):
            raise ValueError(f"rollout must be 'rolling' or 'stw', "
                             f"got {rollout!r}")
        if budget_policy not in ("track_corpus", "fixed"):
            raise ValueError(f"budget_policy must be 'track_corpus' or "
                             f"'fixed', got {budget_policy!r}")
        super().__init__(pipe, serve_batch=serve_batch, **kw)
        self.feed = feed
        self.admission = admission
        self.rollout = rollout
        self.budget_policy = budget_policy
        self.verify_ingest = verify_ingest

    # -- the loop -------------------------------------------------------------
    def step(self, window: TrafficWindow) -> IngestWindowReport:
        report, weights, signal, queries = self._serve_window(window)
        irep = self._ingest(window, weights)
        irep.serve = report
        if signal.triggered and self.enable_refit:
            self._refit_window(report, weights, queries)
        self._observe_window(irep, serve=report)
        return irep

    def run(self, simulator: TrafficSimulator) -> IngestReport:
        reports = [self.step(w) for w in simulator.windows()]
        return IngestReport(
            scenario=simulator.scenario, windows=reports,
            cumulative=self.cumulative, rollout=self.rollout,
            admission_summary=self.admission.summary()
            if self.admission else "off")

    # -- ingest ---------------------------------------------------------------
    def _ingest(self, window: TrafficWindow,
                weights: np.ndarray) -> IngestWindowReport:
        t0 = time.perf_counter()
        irep = IngestWindowReport(serve=None)  # caller splices the serve leg
        docs = self.feed.window(window.index, window.probs)
        irep.n_arrived = len(docs)
        if not docs:
            irep.n_docs = self.pipe.data.n_docs
            irep.corpus_version = getattr(self.engine, "corpus_version", 0)
            return irep
        with obs.span("ingest", window=window.index, n_docs=len(docs)):
            self._ingest_inner(window, weights, irep, docs)
        _INGESTED.inc(irep.n_arrived)
        _CORPUS_V.set(irep.corpus_version)
        obs.event("append", window=window.index, n_arrived=irep.n_arrived,
                  n_docs=irep.n_docs, corpus_version=irep.corpus_version,
                  n_mandatory=irep.n_mandatory, n_offers=irep.n_offers,
                  n_admitted=irep.n_admitted)
        irep.ingest_seconds = time.perf_counter() - t0
        return irep

    def _ingest_inner(self, window: TrafficWindow, weights: np.ndarray,
                      irep: IngestWindowReport, docs) -> None:
        pipe = self.pipe
        with obs.span("append", n_docs=len(docs)):
            delta = incidence.append_docs(pipe.data, docs)
            problem = pipe.problem.with_doc_block(delta.clause_cols,
                                                  delta.n_docs)
            pipe.problem = problem
            self._grow_budget(delta)

        # mandatory admission (Theorem 3.1): the state re-derived from the
        # FIXED selection against the grown problem folds every new doc a
        # selected clause matches into Tier 1 — overspent caps are shed at
        # the next warm refit, never here
        with obs.span("admission"):
            selected = np.asarray(pipe.result.selected)
            t1_before = int(pipe.result.g_final)
            state = problem.state_for(np.nonzero(selected)[0])
            constraint = resolve_constraint(problem, pipe.config)
            if self.admission is not None:
                state = self._admit(problem, state, constraint, delta,
                                    weights, irep)
            fills = constraint.np_value(np.asarray(state.covered_d))
            caps = np.asarray(constraint.caps, np.float64) \
                if isinstance(constraint, PartitionedBudget) \
                else np.asarray([constraint.total], np.float64)
            irep.cap_overflow = float(np.maximum(fills - caps, 0.0).max())
            pipe.adopt_selection(state)
            irep.n_mandatory = max(0, int(pipe.result.g_final) - t1_before)
        if irep.n_mandatory:
            obs.event("mandatory_admission", window=window.index,
                      n_docs_t1=irep.n_mandatory,
                      cap_overflow=irep.cap_overflow)

        with obs.span("swap", kind="corpus"):
            irep.corpus_version = self.engine.swap_corpus(
                pipe.data.postings, delta.n_docs, pipe.tiering(),
                immediate=(self.rollout == "stw"))
            if hasattr(self.engine, "corpus_version"):
                irep.corpus_version = self.engine.corpus_version
        irep.n_docs = delta.n_docs
        if self.verify_ingest:
            irep.ingest_ok = self._check_parity(
                [self.queries[i] for i in window.query_ids[:64]])

    def _admit(self, problem, state, constraint, delta, weights,
               irep: IngestWindowReport):
        """One-pass secretary offers over the clauses the new block ACTIVATED
        (nonzero match bits among appended docs) but the solve didn't select.
        Ratios use the CURRENT decayed traffic weights — admission chases the
        live distribution, not the one the last refit solved against."""
        activated = np.nonzero(
            (bitset.np_popcount(np.asarray(delta.clause_cols)) > 0)
            & ~np.asarray(state.selected))[0]
        if not len(activated):
            return state
        wpad = np.zeros(problem.wq * 32, np.float32)
        wpad[:len(weights)] = np.asarray(weights, np.float32)
        wdev = jnp.asarray(wpad)
        for j in activated:
            rows_q = problem.clause_query_bits[int(j):int(j) + 1]
            rows_d = problem.clause_doc_bits[int(j):int(j) + 1]
            fg = float(problem.f_gains(state.covered_q, rows=rows_q,
                                       weights=wdev)[0])
            _, g_part = constraint.gains(problem, state.covered_d,
                                         rows=rows_d)
            used = constraint.used(problem, state)
            feasible = bool(np.asarray(constraint.feasible(used, g_part))[0])
            g_tot = float(np.asarray(g_part).sum())
            ratio = fg / max(g_tot, 1.0)
            irep.n_offers += 1
            accepted = self.admission.offer(int(j), ratio, feasible)
            _ADMISSION.inc(decision="accept" if accepted else "reject")
            obs.event("admission", clause=int(j), ratio=round(ratio, 6),
                      feasible=feasible, accepted=accepted)
            if accepted:
                state = problem.apply(state, int(j))
                irep.n_admitted += 1
        if irep.n_offers:
            _REJECT_FRAC.set(round(
                1.0 - irep.n_admitted / irep.n_offers, 6))
        return state

    def _grow_budget(self, delta) -> None:
        """Align the knapsack with the appended doc space.

        Partitioned caps MUST grow their bounds to the new width (the last
        partition absorbs the appended words, mirroring `shard.grow_shards`)
        or every subsequent gains/feasibility call would misalign; whether
        the CAPS grow too is `budget_policy`. The explicit constraint then
        replaces any `budget_split` spec — re-allocation from traffic would
        silently rebuild stale bounds on the next refit."""
        pipe = self.pipe
        if pipe.config is None:
            return
        growth = delta.n_docs / max(delta.doc_lo, 1)
        scale = growth if self.budget_policy == "track_corpus" else 1.0
        cfg, split = pipe.config, pipe.config.budget_split
        if cfg.constraint is not None:
            old = cfg.constraint
        elif split is None:
            old = GlobalBudget(budget=float(cfg.budget))
        elif isinstance(split, str):
            return  # pipeline always pairs a string split with a constraint
        else:
            # caps spec never resolved to an object: bounds follow the
            # PRE-append doc space (delta.doc_lo), matching the fleet's plan
            old = PartitionedBudget.from_split(delta.doc_lo, split)
        if isinstance(old, PartitionedBudget):
            bounds = old.bounds[:-1] + (delta.word_hi,)
            caps = np.asarray(old.caps, np.float32).copy()
            # grow mode puts every appended word in the LAST partition
            # (shard.grow_shards), so the shelf space the growth buys goes
            # entirely to the last cap — proportional scaling would starve
            # it (mandatory admissions land there) while padding partitions
            # that gained no docs
            caps[-1] += old.total * (scale - 1.0)
            new = PartitionedBudget(caps=caps, bounds=bounds)
            pipe.config = pipe.config.replace(
                constraint=new, budget=new.total, budget_split=None)
            self._bounds = new.bounds
            qdb = pipe.data.query_doc_bits
            self._shard_mass = np.stack(
                [bitset.np_popcount(qdb[:, lo:hi]).astype(np.float64)
                 for lo, hi in zip(self._bounds, self._bounds[1:])], axis=1)
            self._shard_ref = self._shard_dists(self.accumulator.weights())
        elif isinstance(old, GlobalBudget):
            budget = float(old.total) * scale
            pipe.config = pipe.config.replace(
                budget=budget,
                constraint=GlobalBudget(budget=budget)
                if pipe.config.constraint is not None else None)

    # -- Theorem 3.1 spot check, corpus-version aware --------------------------
    def _check_parity(self, queries: list[tuple[int, ...]]) -> bool:
        """Served match sets == single-tier oracle AT THE VERSION SERVED.

        Mid-ingest-rollout a cluster legitimately serves an older corpus
        version; the oracle must be pinned to that version (the fleet's
        per-buffer Tier-2 snapshot), not the newest postings."""
        sample = queries[:64]
        if not sample:
            return True
        got = self.engine.serve(sample)
        trace = getattr(self.engine, "trace", None)
        if trace:
            want = self.engine.serve_reference(
                sample, corpus_version=trace[-1].corpus_version)
        else:
            want = self.engine.serve_reference(sample)
        return all(np.array_equal(a, b) for a, b in zip(got, want))


def run_ingest(pipe, *, scenario: str = "rotate", n_windows: int = 8,
               queries_per_window: int = 512, seed: int = 0,
               strength: float = 1.0,
               arrivals_per_window: float = 32.0, correlation: float = 0.6,
               admission: bool | AdmissionPolicy = True,
               enable_refit: bool = True, engine=None,
               rollout: str = "rolling", budget_policy: str = "track_corpus",
               verify: bool = False, **controller_kw) -> IngestReport:
    """Replay a drift scenario with live document ingestion end to end.

    `engine` accepts anything with the corpus-swap serving surface — a
    `serve.TieredEngine` (stop-the-world by nature) or a
    `cluster.TieredCluster` (rolling corpus swaps). The feed is seeded from
    `seed`, so A/B arms over the same seed see identical arrivals.
    """
    feed = DocumentFeed(log=pipe.log, vocab_size=pipe.corpus.vocab_size,
                        rate=arrivals_per_window, correlation=correlation,
                        seed=seed)
    policy = admission if isinstance(admission, AdmissionPolicy) else \
        (AdmissionPolicy() if admission else None)
    sim = TrafficSimulator(pipe.log, scenario, seed=seed, n_windows=n_windows,
                           queries_per_window=queries_per_window,
                           strength=strength)
    ctrl = IngestController(pipe, feed=feed, admission=policy,
                            rollout=rollout, budget_policy=budget_policy,
                            verify_ingest=verify, engine=engine,
                            enable_refit=enable_refit,
                            verify_swaps=verify, **controller_kw)
    return ctrl.run(sim)
