"""Seeded live-document feeds for the ingest loop.

A `DocumentFeed` produces per-window batches of new documents whose token
content is CORRELATED with the window's query traffic: with probability
`correlation`, a new document is seeded from a traffic-sampled query's token
set (it will therefore match the clauses that query satisfies — the arrivals
the admission policy should care about), plus zipf-sampled filler tokens;
otherwise it is pure background (zipf tokens only). Drifting traffic thus
drags the DOCUMENT distribution along with it, which is what makes streaming
Tier-1 admission a live decision rather than a warm-refit afterthought.

Determinism contract: `window(t, probs)` derives its rng from
`(seed, t)` alone — NOT from call order — so two controller arms (admission
on/off, rolling/stop-the-world) replaying the same scenario observe
bit-identical document arrivals, and A/B deltas are attributable to the
policy, not the feed.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DocumentFeed:
    """Poisson document arrivals correlated with window traffic.

    rate             : mean arrivals per window (Poisson)
    correlation      : P[a new doc is seeded from a traffic-sampled query]
    extra_tokens_mean: mean zipf filler tokens added per document
    """
    log: object                   # QueryLog: queries + probs universe
    vocab_size: int
    rate: float = 32.0
    correlation: float = 0.6
    extra_tokens_mean: float = 3.0
    zipf_a: float = 1.1
    seed: int = 0

    def __post_init__(self):
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** self.zipf_a
        self._zipf = p / p.sum()
        self.n_emitted = 0

    def window(self, t: int, probs: np.ndarray | None = None
               ) -> list[tuple[int, ...]]:
        """The documents arriving during window `t`.

        `probs` is the window's query-traffic distribution (e.g.
        `TrafficWindow.probs`); None falls back to the log's base weights.
        Deterministic in `(seed, t)` regardless of call order or arm.
        """
        rng = np.random.default_rng((self.seed, 9173, t))
        n = int(rng.poisson(self.rate))
        if probs is None:
            probs = np.asarray(self.log.train_weights, np.float64)
        probs = np.asarray(probs, np.float64)
        probs = probs / max(probs.sum(), 1e-30)
        docs = []
        for _ in range(n):
            toks: set[int] = set()
            if rng.random() < self.correlation:
                qi = int(rng.choice(len(probs), p=probs))
                toks |= set(self.log.queries[qi])
            k = int(rng.poisson(self.extra_tokens_mean))
            if k:
                toks |= set(int(v) for v in
                            rng.choice(self.vocab_size, size=k, p=self._zipf))
            if not toks:
                toks = {int(rng.choice(self.vocab_size, p=self._zipf))}
            docs.append(tuple(sorted(toks)))
        self.n_emitted += len(docs)
        return docs
