"""Production mesh construction (spec: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def batch_spec(mesh) -> jax.sharding.PartitionSpec:
    return jax.sharding.PartitionSpec(data_axes(mesh))
