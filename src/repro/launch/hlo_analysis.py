"""Post-SPMD HLO analysis: collective bytes + roofline term extraction.

`cost_analysis()` gives HLO FLOPs / bytes but not collective traffic, so we
parse the compiled module text and sum the *result* sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Result-size is the standard proxy (operand≈result for reduce ops;
all-gather results are the post-gather size — an upper bound on per-link
traffic that we divide by chip count downstream).
"""
from __future__ import annotations

import re

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum result bytes per collective kind over the compiled module."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        type_str, opname = m.groups()
        base = opname
        for k in COLLECTIVES:
            if base == k or base.startswith(k + "-start") or \
                    base == k + "-start":
                out[k]["count"] += 1
                out[k]["bytes"] += _shape_bytes(type_str)
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# TPU v5e hardware constants (spec §ROOFLINE)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip, 1 link assumed)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int) -> dict:
    return {
        "compute_s": flops / (n_chips * PEAK_FLOPS),
        "memory_s": hbm_bytes / (n_chips * HBM_BW),
        "collective_s": coll_bytes / (n_chips * ICI_BW),
    }


def dominant(terms: dict) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])
