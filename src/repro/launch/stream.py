"""Streaming re-tiering launcher: replay a drift scenario end to end.

`python -m repro.launch.stream --scenario burst --windows 3 --scale tiny`
builds the offline pipeline (mine -> solve -> deploy), then replays the
chosen nonstationary traffic scenario twice on IDENTICAL windows — once
with the tiering frozen (static baseline), once under the drift-aware
re-tiering controller (warm-started refits + atomic hot swaps) — and
prints per-window coverage/cost plus the A/B comparison.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    from repro import stream

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="rotate",
                    choices=stream.list_scenarios())
    ap.add_argument("--windows", type=int, default=8)
    ap.add_argument("--queries-per-window", type=int, default=512)
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "medium"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--strength", type=float, default=1.0,
                    help="drift intensity (scenario-specific)")
    ap.add_argument("--solver", default="greedy")
    ap.add_argument("--budget-frac", type=float, default=0.5)
    ap.add_argument("--min-support", type=float, default=1e-3)
    ap.add_argument("--cold", action="store_true",
                    help="disable warm starts (every refit solves cold)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the static-tiering A/B run")
    ap.add_argument("--verify", action="store_true",
                    help="Theorem-3.1 parity spot check after every swap")
    ap.add_argument("--obs-dir", default="artifacts/obs",
                    help="telemetry snapshot directory ('' disables export; "
                         "REPRO_OBS=0 disables the whole plane)")
    args = ap.parse_args()

    from repro import api, obs

    if args.obs_dir and obs.enabled():
        obs.set_exporter(obs.JsonlExporter(args.obs_dir, run="stream"))
    if obs.enabled():
        obs.SLO.set_rules(obs.default_slo_rules())

    def offline_pipe():
        return (api.TieringPipeline.from_synthetic(seed=args.seed,
                                                   scale=args.scale)
                .mine(min_support=args.min_support)
                .solve(args.solver, budget_frac=args.budget_frac))

    # every knob that shapes the traffic and the solve, in one header line,
    # so an A/B run is reproducible from the log alone
    print(f"[stream] scenario={args.scenario} windows={args.windows} "
          f"qpw={args.queries_per_window} scale={args.scale} "
          f"seed={args.seed} strength={args.strength} "
          f"solver={args.solver} budget_frac={args.budget_frac} "
          f"min_support={args.min_support} warm={not args.cold}")
    t0 = time.time()
    pipe = offline_pipe()
    print(f"[stream] offline solve: {pipe.result.summary()}  "
          f"({time.time() - t0:.1f}s)")

    # the simulator consumes the SAME --seed (window sampling) as the
    # offline dataset build above, so one flag pins the whole replay
    run_kw = dict(scenario=args.scenario, n_windows=args.windows,
                  queries_per_window=args.queries_per_window, seed=args.seed,
                  strength=args.strength)

    static = None
    if not args.no_baseline:
        # static baseline first: enable_refit=False never mutates the pipe,
        # so the re-tiering run below starts from the same offline solve
        static = stream.run_stream(pipe, enable_refit=False, **run_kw)
        print(f"[stream] static   {static.summary()}")

    report = stream.run_stream(pipe, warm=not args.cold,
                               verify_swaps=args.verify, **run_kw)
    for w in report.windows:
        print(f"[stream] {w.line()}")
    print(f"[stream] retiered {report.summary()}")

    if args.verify:
        if not report.parity_all_ok():
            raise SystemExit("[stream] PARITY FAILURE: a swapped tiering "
                             "broke Theorem 3.1 completeness")
        if report.n_parity_checks == 0:
            print("[stream] note: no refit/swap occurred, so no parity "
                  "checks ran (nothing to verify)")
        else:
            print(f"[stream] parity verified after "
                  f"{report.n_parity_checks} swaps")
    if static is not None:
        delta = report.mean_coverage - static.mean_coverage
        print(f"[stream] mean windowed tier-1 coverage: "
              f"static={static.mean_coverage:.3f} "
              f"retiered={report.mean_coverage:.3f} ({delta:+.3f})")
    if obs.enabled():
        print(f"[stream] {obs.dashboard()}")
        ex = obs.get_exporter()
        if ex is not None and ex.n_written:
            print(f"[stream] obs: {ex.n_written} snapshots -> {ex.path}")


if __name__ == "__main__":
    main()
