import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax-importing import: jax locks device count on first init.

"""Multi-pod dry run (deliverable e).

For every (architecture x input-shape x mesh) cell: lower + compile the
step function on the production mesh with abstract (ShapeDtypeStruct)
operands, print/record memory_analysis() and cost_analysis(), and parse the
compiled HLO for collective traffic. Artifacts land in
artifacts/dryrun/<mesh>/<arch>__<shape>.json, which benchmarks/roofline.py
turns into EXPERIMENTS.md tables.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch gemma2-2b
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --arch all
"""
import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402
import numpy as np    # noqa: E402

from repro.configs import registry as R                    # noqa: E402
from repro.distributed import mesh_context, sharding       # noqa: E402
from repro.distributed.compression import CompressionConfig  # noqa: E402
from repro.launch import hlo_analysis, mesh as mesh_lib    # noqa: E402
from repro.train.optimizer import OptimizerConfig          # noqa: E402
from repro.train.trainer import make_train_step            # noqa: E402


def build_lowering(arch: R.ArchSpec, shape: str, mesh):
    cfg = arch.config_for(shape)
    cell = arch.cell_for(shape, mesh)
    named = lambda tree: sharding.named(mesh, tree)

    if cell.kind == "train":
        opt_cfg = OptimizerConfig(name=arch.optimizer)
        init_state, train_step = make_train_step(
            arch.loss_fn(cfg), opt_cfg, n_micro=cell.n_micro,
            compression=CompressionConfig(),
            grad_accum_dtype=arch.grad_accum_dtype)
        aparams = arch.abstract_params(cfg)
        astate = jax.eval_shape(init_state, aparams)
        pspecs = sharding.add_fsdp(arch.param_specs(cfg), aparams, mesh)
        state_sh = sharding.state_shardings(mesh, pspecs, astate)
        fn = train_step
        args = (astate, cell.inputs)
        in_sh = (state_sh, named(cell.input_specs))
    else:
        serve = arch.serve_fn(cfg, shape)
        aparams = arch.abstract_params(cfg)
        pspecs = sharding.add_fsdp(arch.param_specs(cfg), aparams, mesh)
        fn = serve
        args = (aparams, cell.inputs)
        in_sh = (named(pspecs), named(cell.input_specs))
    return fn, args, in_sh, cell


def run_cell(arch: R.ArchSpec, shape: str, mesh_name: str, out_dir: str,
             skip_existing: bool = False) -> dict:
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    path = os.path.join(out_dir, mesh_name, f"{arch.name}__{shape}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    record = {"arch": arch.name, "shape": shape, "mesh": mesh_name,
              "status": "ok"}
    if shape in arch.skips:
        record["status"] = "skipped"
        record["reason"] = arch.skips[shape]
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"[dryrun] SKIP {arch.name} x {shape} ({mesh_name}): "
              f"{arch.skips[shape][:60]}...")
        return record

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        with mesh, mesh_context.use_mesh(mesh):
            fn, args, in_sh, cell = build_lowering(arch, shape, mesh)
            # donate the train state / kv cache: updated-in-place on device
            donate = (0,) if cell.kind == "train" else \
                ((1,) if cell.kind == "decode" else ())
            lowered = jax.jit(fn, in_shardings=in_sh,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            coll = hlo_analysis.collective_stats(hlo)
            probe = lm_cost_probe(arch, shape, mesh)

        record.update({
            "kind": cell.kind,
            "n_chips": n_chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops": float(cost.get("flops", -1.0)),
            "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
            "collectives": coll,
            "memory_analysis": _mem_dict(mem),
            "hlo_bytes": len(hlo),
            "probe": probe,
        })
        # per-device roofline inputs: cost_analysis on CPU reports the whole
        # (global) program; divide by chips downstream.
        print(f"[dryrun] OK   {arch.name} x {shape} ({mesh_name}) "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"GFLOPs={record['flops'] / 1e9:.1f} "
              f"coll={coll['total_bytes'] / 1e9:.2f}GB")
        print(f"         memory_analysis: {record['memory_analysis']}")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] FAIL {arch.name} x {shape} ({mesh_name}): "
              f"{record['error'][:200]}")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def lm_cost_probe(arch: R.ArchSpec, shape: str, mesh) -> dict | None:
    """XLA cost_analysis counts while-loop bodies ONCE, so scanned layers /
    microbatches / KV-chunks are undercounted by their trip counts. For LM
    cells we therefore lower scan-free probes at n_layers ∈ {1, 2} (chunked
    scans widened to a single chunk, one microbatch) and recover
      per_layer = cost(2L) - cost(1L);   fixed = cost(1L) - per_layer
      total ≈ n_micro * (fixed + n_layers * per_layer)
    Optimizer flops are O(params) — noise at these scales (documented)."""
    import dataclasses as dc
    if arch.family != "lm":
        return None
    cfg = arch.config_for(shape)
    cell = arch.cell_for(shape, mesh)
    n_micro = cell.n_micro
    probes = {}
    # decode probes: q_len=1 => single-chunk attention is exact and cheap.
    # train/prefill probes: keep real 4k KV chunking but UNROLLED (quadratic
    # score materialization at 32k would otherwise inflate the byte term).
    attn_chunk = 1 << 20 if cell.kind == "decode" else 4096
    for nl in (1, 2):
        pcfg = dc.replace(cfg, n_layers=nl, attn_chunk=attn_chunk,
                          attn_unroll=True, unroll_layers=True,
                          xent_chunk=1 << 20)
        parch = dc.replace(
            arch, config_for=lambda s, c=pcfg: c,
            cell_for=lambda s, m, c=pcfg: R.lm_cell(
                c, s, m, 1, batch_div=n_micro))
        fn, args, in_sh, _ = build_lowering(parch, shape, mesh)
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
        cost = compiled.cost_analysis() or {}
        coll = hlo_analysis.collective_stats(compiled.as_text())
        probes[nl] = {"flops": float(cost.get("flops", 0.0)),
                      "bytes": float(cost.get("bytes accessed", 0.0)),
                      "coll": float(coll["total_bytes"])}
    out = {}
    for key in ("flops", "bytes", "coll"):
        per_layer = max(probes[2][key] - probes[1][key], 0.0)
        fixed = max(probes[1][key] - per_layer, 0.0)
        out[key] = n_micro * (fixed + cfg.n_layers * per_layer)
        out[f"{key}_per_layer"] = per_layer
    out["n_layers"] = cfg.n_layers
    out["n_micro"] = n_micro
    return out


def _mem_dict(mem) -> dict:
    if mem is None:
        return {"note": "memory_analysis unavailable on this backend"}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_per_device_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out or {"repr": str(mem)[:500]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = R.all_archs()
    names = list(archs) if args.arch == "all" else args.arch.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for mesh_name in meshes:
        for name in names:
            arch = archs[name]
            shapes = arch.shapes if args.shape == "all" \
                else args.shape.split(",")
            for shape in shapes:
                results.append(run_cell(arch, shape, mesh_name, args.out,
                                        args.skip_existing))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"/ {len(results)} cells")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
