"""Live-ingest launcher: streaming admission + rolling corpus rebuilds.

`python -m repro.launch.ingest --scale tiny --windows 2 --verify`
builds the offline pipeline once, deploys a sharded fleet, then drives the
serve → ingest → refit loop (`repro.ingest.IngestController`):

  1. every window appends a seeded, drift-correlated batch of new documents
     to the live corpus (word-aligned block append — existing postings words
     never move);
  2. docs matched by selected clauses enter Tier 1 MANDATORILY
     (Theorem 3.1); clauses the new block activates are offered one-pass to
     the secretary-style admission policy under live per-shard caps;
  3. the fleet rolls to the new corpus version replica-by-replica
     (`--rollout stw` jumps stop-the-world instead — the comparison arm);
  4. drift triggers warm refits against the grown problem, exactly as the
     static-corpus loop.

`--verify` checks, per window, that served match sets equal the single-tier
oracle AT THE CORPUS VERSION SERVED (mid-rollout batches legitimately serve
the previous version) and, at the end, that no batch ever observed a mixed
(ψ, Tier-1, Tier-2) triple. Failures are named `SystemExit`s, so CI smoke
runs fail loudly.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "medium"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", default="rotate")
    ap.add_argument("--windows", type=int, default=6)
    ap.add_argument("--queries-per-window", type=int, default=256)
    ap.add_argument("--strength", type=float, default=1.0)
    ap.add_argument("--solver", default="greedy")
    ap.add_argument("--budget-frac", type=float, default=0.5)
    ap.add_argument("--min-support", type=float, default=1e-3)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=2,
                    help="Tier-1 replicas per shard")
    ap.add_argument("--t2-replicas", type=int, default=2,
                    help="Tier-2 replicas per shard (2+ keeps rolling corpus "
                         "swaps gap-free)")
    ap.add_argument("--arrivals", type=float, default=32.0,
                    help="mean new documents per window (Poisson)")
    ap.add_argument("--correlation", type=float, default=0.6,
                    help="P[an arriving doc is seeded from live traffic]")
    ap.add_argument("--rollout", default="rolling",
                    choices=["rolling", "stw"])
    ap.add_argument("--budget-policy", default="track_corpus",
                    choices=["track_corpus", "fixed"])
    ap.add_argument("--no-admission", action="store_true",
                    help="mandatory Theorem-3.1 growth only (A/B baseline)")
    ap.add_argument("--single-engine", action="store_true",
                    help="drive one TieredEngine instead of a fleet "
                         "(corpus swaps are then stop-the-world by nature)")
    ap.add_argument("--verify", action="store_true",
                    help="per-window versioned parity + mixed-triple check")
    ap.add_argument("--obs-dir", default="artifacts/obs",
                    help="telemetry snapshot directory ('' disables export; "
                         "REPRO_OBS=0 disables the whole plane)")
    args = ap.parse_args()

    from repro import api, ingest, obs

    if args.obs_dir and obs.enabled():
        obs.set_exporter(obs.JsonlExporter(args.obs_dir, run="ingest"))
    if obs.enabled():
        obs.SLO.set_rules(obs.default_slo_rules())

    print(f"[ingest] scale={args.scale} seed={args.seed} "
          f"scenario={args.scenario} windows={args.windows} "
          f"qpw={args.queries_per_window} arrivals={args.arrivals} "
          f"correlation={args.correlation} rollout={args.rollout} "
          f"budget_policy={args.budget_policy} "
          f"admission={'off' if args.no_admission else 'on'} "
          f"shards={args.shards} t1_replicas={args.replicas} "
          f"t2_replicas={args.t2_replicas}")
    t0 = time.time()
    pipe = (api.TieringPipeline.from_synthetic(seed=args.seed,
                                               scale=args.scale)
            .mine(min_support=args.min_support)
            .solve(args.solver, budget_frac=args.budget_frac,
                   budget_split="traffic", n_shards=args.shards))
    print(f"[ingest] offline solve: {pipe.result.summary()}  "
          f"({time.time() - t0:.1f}s)")

    engine = None
    if not args.single_engine:
        engine = pipe.deploy_cluster(n_shards=args.shards,
                                     t1_replicas=args.replicas,
                                     t2_replicas=args.t2_replicas)
        print(f"[ingest] fleet: {engine.describe()}")

    report = ingest.run_ingest(
        pipe, scenario=args.scenario, n_windows=args.windows,
        queries_per_window=args.queries_per_window, seed=args.seed,
        strength=args.strength, arrivals_per_window=args.arrivals,
        correlation=args.correlation, admission=not args.no_admission,
        engine=engine, rollout=args.rollout,
        budget_policy=args.budget_policy, verify=args.verify)
    for w in report.windows:
        print(f"[ingest] {w.line()}")
    print(f"[ingest] {report.summary()}  admission: "
          f"{report.admission_summary}")

    if args.verify:
        failed = report.failed_windows()
        if failed:
            raise SystemExit(f"[ingest] PARITY FAILURE: {failed} window(s) "
                             "diverged from the versioned single-tier oracle")
        if engine is not None and not engine.consistency_ok():
            raise SystemExit("[ingest] CONSISTENCY FAILURE: a batch saw a "
                             "mixed (ψ, Tier-1, Tier-2) triple")
        checks = sum(1 for w in report.windows if w.ingest_ok is not None)
        if checks == 0:
            raise SystemExit("[ingest] VERIFY FAILURE: no parity check ran")
        n_batches = len(engine.trace) if engine is not None else 0
        print(f"[ingest] verified: {checks} versioned parity checks ok"
              + (f", {n_batches} batches triple-consistent" if engine
                 is not None else ""))
    if obs.enabled():
        print(f"[ingest] {obs.dashboard()}")
        ex = obs.get_exporter()
        if ex is not None and ex.n_written:
            print(f"[ingest] obs: {ex.n_written} snapshots -> {ex.path}")


if __name__ == "__main__":
    main()
