"""Telemetry replay/summarize CLI for `artifacts/obs/` JSONL snapshots.

    python -m repro.launch.obs                      # summarize every run
    python -m repro.launch.obs --run ingest         # one run, windows + totals
    python -m repro.launch.obs --spans --events     # include span/event detail
    python -m repro.launch.obs --check \
        --require-metric cluster_words_total        # CI gate (exit 1 on miss)
    python -m repro.launch.obs --diff artifacts/obs.baseline \
        --tolerance-file benchmarks/tolerances.json # regression diff

`--check` asserts every run has at least one snapshot, every snapshot has the
required keys (window/ts/metrics/spans/events), and each `--require-metric`
name appears with a non-empty series in at least one snapshot — the CI
telemetry smoke gates on this. `--max-dropped-frac F` additionally gates on
span/event ring retention: any run whose final snapshot reports a dropped
fraction above F (or lacks the `rings` block entirely) fails the check.

`--diff BASE_DIR` compares this tree's snapshots against a baseline obs
directory through `benchmarks.compare` (same tolerance machinery as the
BENCH_*.json perf gate) and exits nonzero on regression.
"""
from __future__ import annotations

import argparse

from repro.obs import load_dir
from repro.obs.render import render_line

REQUIRED_KEYS = ("window", "ts", "metrics", "spans", "events")


def _counter_total(metrics: dict, name: str) -> float:
    inst = metrics.get(name)
    if not inst or inst.get("type") != "counter":
        return 0.0
    return sum(s["value"] for s in inst.get("series", []))


def summarize_run(name: str, snaps: list[dict], *, show_spans: bool,
                  show_events: bool) -> None:
    last = snaps[-1]["metrics"] if snaps else {}
    spans = [s for snap in snaps for s in snap.get("spans", [])]
    events = [e for snap in snaps for e in snap.get("events", [])]
    print(render_line(f"[{name}]", [
        ("@n", f"{len(snaps)} snapshots"),
        ("windows", f"{snaps[0]['window']}..{snaps[-1]['window']}"
         if snaps else "-"),
        ("queries", int(_counter_total(last, "serve_queries_total"))
         or int(_counter_total(last, "cluster_queries_total"))),
        ("words", int(_counter_total(last, "serve_words_total"))
         or int(_counter_total(last, "cluster_words_total"))),
        ("refits", int(_counter_total(last, "refits_total"))),
        ("spans", len(spans)), ("events", len(events))]))
    by_kind: dict[str, int] = {}
    for e in events:
        by_kind[e.get("kind", "?")] = by_kind.get(e.get("kind", "?"), 0) + 1
    if by_kind:
        print(render_line("  events:", sorted(by_kind.items())))
    if show_spans:
        by_name: dict[str, list[float]] = {}
        for s in spans:
            by_name.setdefault(s.get("name", "?"), []).append(
                float(s.get("wall_ms", 0.0)))
        for n in sorted(by_name):
            ms = by_name[n]
            print(render_line(f"  span {n}:", [
                ("n", len(ms)), ("total_ms", sum(ms)),
                ("mean_ms", sum(ms) / max(len(ms), 1)),
                ("max_ms", max(ms))]))
    if show_events:
        for e in events:
            fields = [(k, v) for k, v in e.items()
                      if k not in ("seq", "t_s", "kind")]
            print(render_line(f"  event {e.get('kind', '?')}:", fields))


def check(runs: dict[str, list[dict]], require_metrics: list[str],
          max_dropped_frac: float | None = None) -> int:
    """Returns the number of failures (0 = pass), printing each one."""
    failures = 0
    if not runs:
        print("[obs] CHECK FAIL: no *.jsonl snapshot files found")
        return 1
    for name, snaps in runs.items():
        if not snaps:
            print(f"[obs] CHECK FAIL: run {name!r} has no snapshots")
            failures += 1
            continue
        for i, snap in enumerate(snaps):
            missing = [k for k in REQUIRED_KEYS if k not in snap]
            if missing:
                print(f"[obs] CHECK FAIL: run {name!r} snapshot {i} is "
                      f"missing keys {missing}")
                failures += 1
        if max_dropped_frac is not None:
            rings = snaps[-1].get("rings")
            if not isinstance(rings, dict):
                print(f"[obs] CHECK FAIL: run {name!r} has no 'rings' "
                      f"retention block (needed for --max-dropped-frac)")
                failures += 1
            else:
                for ring_name, ring in sorted(rings.items()):
                    seen = int(ring.get("n_seen", 0))
                    dropped = int(ring.get("n_dropped", 0))
                    frac = dropped / max(seen, 1)
                    if frac > max_dropped_frac:
                        print(f"[obs] CHECK FAIL: run {name!r} dropped "
                              f"{frac:.1%} of {ring_name} "
                              f"({dropped}/{seen}) > "
                              f"{max_dropped_frac:.1%} — raise the ring "
                              f"capacity or export more often")
                        failures += 1
    for metric in require_metrics:
        found = any(
            snap.get("metrics", {}).get(metric, {}).get("series")
            for snaps in runs.values() for snap in snaps)
        if not found:
            print(f"[obs] CHECK FAIL: metric {metric!r} has no series in "
                  f"any snapshot")
            failures += 1
    if failures == 0:
        n = sum(len(s) for s in runs.values())
        print(f"[obs] check ok: {len(runs)} run(s), {n} snapshot(s), "
              f"{len(require_metrics)} required metric(s) present")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default="artifacts/obs",
                    help="snapshot directory to read")
    ap.add_argument("--run", default="",
                    help="summarize only this run name (file stem)")
    ap.add_argument("--spans", action="store_true",
                    help="per-span-name timing rollup")
    ap.add_argument("--events", action="store_true",
                    help="print every event")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: exit nonzero on missing snapshots/keys")
    ap.add_argument("--require-metric", action="append", default=[],
                    help="with --check: metric name that must have a "
                         "non-empty series (repeatable)")
    ap.add_argument("--max-dropped-frac", type=float, default=None,
                    help="with --check: fail any run whose final snapshot "
                         "reports a span/event ring dropped fraction above "
                         "this")
    ap.add_argument("--diff", default="", metavar="BASE_DIR",
                    help="diff this tree's snapshots against a baseline obs "
                         "directory via benchmarks.compare (exit 1 on "
                         "regression)")
    ap.add_argument("--tolerance-file", default="",
                    help="with --diff: per-metric tolerance rules JSON")
    args = ap.parse_args()

    if args.diff:
        try:
            from benchmarks import compare as _compare
        except ImportError:
            raise SystemExit("[obs] --diff needs the benchmarks/ package on "
                             "sys.path (run from the repo root)")
        raise SystemExit(_compare.run_gate(
            args.diff, args.dir, tolerance_file=args.tolerance_file or None))

    runs = load_dir(args.dir)
    if args.run:
        runs = {k: v for k, v in runs.items() if k == args.run}
    if args.check:
        raise SystemExit(1 if check(runs, args.require_metric,
                                    args.max_dropped_frac) else 0)
    if not runs:
        print(f"[obs] no snapshots under {args.dir}")
        return
    for name in sorted(runs):
        summarize_run(name, runs[name], show_spans=args.spans,
                      show_events=args.events)


if __name__ == "__main__":
    main()
