"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

Runs a REDUCED config end-to-end on the host devices (this container is
CPU-only; the full configs are exercised by the dry-run). Demonstrates the
full production loop: mesh, sharded state, checkpoint/restart, straggler
policy, optional gradient compression.
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np


def synthetic_lm_batches(cfg, batch: int, seq: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    while True:
        toks = rng.integers(0, cfg.vocab_size, (batch, seq), dtype=np.int64)
        yield {"tokens": toks.astype(np.int32),
               "labels": toks.astype(np.int32)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    from repro.configs import registry as R
    from repro.distributed import mesh_context
    from repro.distributed.compression import CompressionConfig
    from repro.launch import mesh as mesh_lib
    from repro.train.optimizer import OptimizerConfig
    from repro.train.trainer import DriverConfig, TrainingDriver, \
        make_train_step

    arch = R.get_arch(args.arch)
    cfg, smoke_batch, kind = arch.smoke()
    assert kind == "train", f"{args.arch} has no training smoke path"
    mesh = mesh_lib.make_host_mesh()

    with mesh, mesh_context.use_mesh(mesh):
        init_state, train_step = make_train_step(
            arch.loss_fn(cfg),
            OptimizerConfig(name=arch.optimizer, lr=args.lr,
                            warmup_steps=10, decay_steps=args.steps),
            compression=CompressionConfig(kind=args.compression))

        if arch.family == "lm":
            batches = synthetic_lm_batches(cfg, args.batch, args.seq)
        else:
            def repeat():
                while True:
                    yield smoke_batch
            batches = repeat()

        def params_init():
            if arch.family == "lm":
                from repro.models import transformer as T
                return T.init_params(jax.random.key(0), cfg)
            if arch.family == "gnn":
                from repro.models import egnn as G
                return G.init_params(jax.random.key(0), cfg)
            from repro.models import recsys as M
            init = {"deepfm": M.deepfm_init, "bst": M.bst_init,
                    "bert4rec": M.bert4rec_init,
                    "two-tower-retrieval": M.twotower_init}[args.arch]
            return init(jax.random.key(0), cfg)

        driver = TrainingDriver(init_state, train_step, DriverConfig(
            ckpt_dir=os.path.join(args.ckpt_dir, args.arch),
            ckpt_every=args.ckpt_every, max_steps=args.steps))
        state, history = driver.run(params_init, batches)

    print(f"[train] {args.arch}: {len(history)} steps this run, "
          f"loss {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
