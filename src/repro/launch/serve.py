"""Serving launcher: two-tier engine demo over a synthetic corpus.

`python -m repro.launch.serve --scale small --budget-frac 0.5 --requests 2000`
builds the full offline pipeline (mine -> solve -> materialize Tier 1) and
then serves batched requests, reporting coverage and word-traffic savings.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "medium"])
    ap.add_argument("--budget-frac", type=float, default=0.5)
    ap.add_argument("--min-support", type=float, default=1e-3)
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=128)
    args = ap.parse_args()

    from repro.core import SCSKProblem, optpes_greedy
    from repro.core.tiering import ClauseTiering
    from repro.data import incidence, synthetic
    from repro.serve.engine import TieredEngine

    t0 = time.time()
    corpus, log = synthetic.make_tiering_dataset(0, args.scale)
    data = incidence.build_tiering_data(corpus, log,
                                        min_support=args.min_support)
    problem = SCSKProblem.from_data(data)
    budget = int(corpus.n_docs * args.budget_frac)
    result = optpes_greedy(problem, budget)
    tiering = ClauseTiering.from_selection(data, result.selected)
    print(f"[serve] offline solve: {result.summary()}  "
          f"({time.time() - t0:.1f}s)")

    engine = TieredEngine(data.postings, tiering, data.n_docs)
    rng = np.random.default_rng(1)
    # request stream drawn from the *test* distribution (future traffic)
    probs = log.test_weights / log.test_weights.sum()
    served = 0
    t1 = time.time()
    while served < args.requests:
        n = min(args.batch, args.requests - served)
        idx = rng.choice(log.n_queries, size=n, p=probs)
        engine.serve([log.queries[i] for i in idx])
        served += n
    dt = time.time() - t1
    s = engine.stats
    print(f"[serve] {served} requests in {dt:.1f}s "
          f"({1e3 * dt / served:.2f} ms/req host-side)")
    print(f"[serve] tier-1 coverage: {s.tier1_fraction:.3f}  "
          f"word-traffic saving vs untiered: {s.cost_saving:.3f}")


if __name__ == "__main__":
    main()
