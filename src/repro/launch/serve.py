"""Serving launcher: two-tier engine demo over a synthetic corpus.

`python -m repro.launch.serve --scale small --budget-frac 0.5 --requests 2000`
builds the full offline pipeline (mine -> solve -> materialize Tier 1) and
then serves batched requests, reporting coverage and word-traffic savings.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "medium"])
    ap.add_argument("--budget-frac", type=float, default=0.5)
    ap.add_argument("--min-support", type=float, default=1e-3)
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--solver", default="optpes")
    args = ap.parse_args()

    from repro import api

    t0 = time.time()
    pipe = (api.TieringPipeline.from_synthetic(seed=0, scale=args.scale)
            .mine(min_support=args.min_support)
            .solve(args.solver, budget_frac=args.budget_frac))
    log = pipe.log
    print(f"[serve] offline solve: {pipe.result.summary()}  "
          f"({time.time() - t0:.1f}s)")

    engine = pipe.deploy()
    rng = np.random.default_rng(1)
    # request stream drawn from the *test* distribution (future traffic)
    probs = log.test_weights / log.test_weights.sum()
    served = 0
    t1 = time.time()
    while served < args.requests:
        n = min(args.batch, args.requests - served)
        idx = rng.choice(log.n_queries, size=n, p=probs)
        engine.serve([log.queries[i] for i in idx])
        served += n
    dt = time.time() - t1
    s = engine.stats
    print(f"[serve] {served} requests in {dt:.1f}s "
          f"({1e3 * dt / served:.2f} ms/req host-side)")
    print(f"[serve] tier-1 coverage: {s.tier1_fraction:.3f}  "
          f"word-traffic saving vs untiered: {s.cost_saving:.3f}")


if __name__ == "__main__":
    main()
