"""Cluster serving launcher: sharded scatter-gather fleet, end to end.

`python -m repro.launch.cluster --shards 2 --replicas 2 --windows 2 --scale tiny`
builds the offline pipeline once, then:

  1. strong-scaling loadgen: for each shard count in `--sweep` (default: just
     `--shards`) deploys a fleet and drives the discrete-event load generator
     (open-loop Poisson arrivals, straggler tail), reporting throughput,
     p50/p95/p99 latency and fleet word traffic;
  2. drift A/B on IDENTICAL traffic windows: a static single-engine baseline
     vs the cluster under the drift-aware re-tiering controller, whose swaps
     roll replica-by-replica (`--verify` asserts Theorem-3.1 parity after
     every swap AND that no batch saw a mixed (ψ, Tier-1) pair).

Every knob that shapes traffic is in the header line, so any run is
reproducible from its log alone.

`--mesh` runs the whole thing on the mesh-resident data plane: a `("shard",)`
device mesh is installed as the ambient `ExecutionPlan`, the router serves
every batch as ONE fused shard_map program (replicated ψ classify →
owner-local AND-match → psum OR-merge) and partitioned solves compute each
partition's gains on its owning device. On a CPU host with a single device,
4 host devices are forced (XLA fixes the count at init) so the fused path
actually engages; results are bit-identical either way.
"""
from __future__ import annotations

import argparse
import contextlib
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--mesh", action="store_true",
                    help="serve through the fused shard_map data plane "
                         "(forces 4 host devices if only 1 is visible)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="Tier-1 replicas per shard")
    ap.add_argument("--t2-replicas", type=int, default=1)
    ap.add_argument("--sweep", default="",
                    help="comma-separated shard counts for the strong-scaling"
                         " loadgen sweep (default: just --shards)")
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "medium"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", default="rotate")
    ap.add_argument("--windows", type=int, default=6)
    ap.add_argument("--queries-per-window", type=int, default=256)
    ap.add_argument("--strength", type=float, default=1.0)
    ap.add_argument("--solver", default="greedy")
    ap.add_argument("--budget-frac", type=float, default=0.5)
    ap.add_argument("--budget-split", default="",
                    help="shard-aware budgets: 'traffic' (size per-shard "
                         "caps from observed traffic shares; refits "
                         "re-allocate) or comma caps like '60,40'; empty = "
                         "one global budget")
    ap.add_argument("--min-support", type=float, default=1e-3)
    ap.add_argument("--rate", type=float, default=20000.0,
                    help="loadgen offered load, queries/s")
    ap.add_argument("--requests", type=int, default=4000,
                    help="loadgen arrivals per configuration")
    ap.add_argument("--cache", action="store_true",
                    help="serve through the classify-keyed front-end result "
                         "cache (and give the loadgen its sim twin)")
    ap.add_argument("--cache-capacity", type=int, default=8192)
    ap.add_argument("--cache-ttl", type=float, default=None,
                    help="result-cache TTL in seconds (default: no TTL)")
    ap.add_argument("--hedge-ms", type=float, default=None,
                    help="loadgen hedged dispatch: fire a backup subquery "
                         "after this many ms (default: no hedging)")
    ap.add_argument("--admission", default="",
                    help="loadgen overload admission QUEUE_MS[,DEADLINE_MS] "
                         "('-' skips a bound; empty disables)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the single-engine A/B run")
    ap.add_argument("--verify", action="store_true",
                    help="parity after every swap + mixed-pair check")
    ap.add_argument("--obs-dir", default="artifacts/obs",
                    help="telemetry snapshot directory ('' disables export; "
                         "REPRO_OBS=0 disables the whole plane)")
    args = ap.parse_args()

    if args.mesh and "jax" not in sys.modules and \
            "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count"
                                     "=4").strip()

    from repro import api, cluster, obs, stream

    if args.obs_dir and obs.enabled():
        obs.set_exporter(obs.JsonlExporter(args.obs_dir, run="cluster"))
    if obs.enabled():
        obs.SLO.set_rules(obs.default_slo_rules())

    stack = contextlib.ExitStack()
    if args.mesh:
        from repro import distributed
        mesh = stack.enter_context(
            distributed.use_mesh(distributed.shard_mesh()))
        print(f"[cluster] mesh: {mesh.size} device(s) on axis 'shard' — "
              f"fused shard_map serve "
              f"{'ON' if mesh.size > 1 else 'inert (1 device)'}")

    print(f"[cluster] scale={args.scale} seed={args.seed} "
          f"scenario={args.scenario} windows={args.windows} "
          f"qpw={args.queries_per_window} strength={args.strength} "
          f"solver={args.solver} budget_frac={args.budget_frac} "
          f"budget_split={args.budget_split or '-'} "
          f"shards={args.shards} t1_replicas={args.replicas} "
          f"t2_replicas={args.t2_replicas} cache={'on' if args.cache else '-'} "
          f"hedge_ms={args.hedge_ms if args.hedge_ms is not None else '-'} "
          f"admission={args.admission or '-'}")
    admission = cluster.AdmissionPolicy.parse(args.admission) \
        if args.admission else None
    budget_split = None
    if args.budget_split == "traffic":
        budget_split = "traffic"
    elif args.budget_split:
        budget_split = [float(c) for c in args.budget_split.split(",")]
    t0 = time.time()
    pipe = (api.TieringPipeline.from_synthetic(seed=args.seed,
                                               scale=args.scale)
            .mine(min_support=args.min_support)
            .solve(args.solver, budget_frac=args.budget_frac,
                   budget_split=budget_split, n_shards=args.shards))
    print(f"[cluster] offline solve: {pipe.result.summary()}  "
          f"({time.time() - t0:.1f}s)")
    if budget_split is not None:
        caps = pipe.result.extra["caps"]
        fill = pipe.result.extra["g_part"]
        print(f"[cluster] per-shard budgets B_k={[int(c) for c in caps]}  "
              f"fill g_k={[int(g) for g in fill]}")

    # -- 1. strong-scaling loadgen sweep -------------------------------------
    sweep = [int(s) for s in args.sweep.split(",") if s] or [args.shards]
    sample = pipe.log.queries[:min(2048, pipe.log.n_queries)]
    # the loadgen cache twin keys arrivals by the sample's token sets, in
    # the same i % size cycle the eligibility flags use — after one cycle
    # every repeat is a front-end hit, like the real ResultCache
    cache_keys = cluster.keys_of(sample) if args.cache else None
    elig = None     # eligibility depends only on ψ, not on the topology
    for n_shards in sweep:
        fleet = pipe.deploy_cluster(n_shards=n_shards,
                                    t1_replicas=args.replicas,
                                    t2_replicas=args.t2_replicas)
        if elig is None:
            elig = fleet.classify(sample)
        plan = cluster.ClusterPlan.of_cluster(fleet)
        rep = cluster.run_loadgen(plan, elig, rate_qps=args.rate,
                                  n_queries=args.requests, seed=args.seed,
                                  hedge_ms=args.hedge_ms,
                                  admission=admission,
                                  cache_keys=cache_keys,
                                  cache_capacity=args.cache_capacity,
                                  cache_ttl_s=args.cache_ttl)
        per_shard = max(rep.per_shard_t2_words) if rep.per_shard_t2_words \
            else 0
        print(f"[cluster] loadgen shards={len(fleet.shards)} "
              f"{rep.line()}  max_shard_t2_words={per_shard:,}")

    # -- 2. drift A/B: static single engine vs re-tiered cluster -------------
    run_kw = dict(scenario=args.scenario, n_windows=args.windows,
                  queries_per_window=args.queries_per_window, seed=args.seed,
                  strength=args.strength)
    static = None
    if not args.no_baseline:
        static = stream.run_stream(pipe, enable_refit=False, **run_kw)
        print(f"[cluster] single-engine static   {static.summary()}")

    fleet = pipe.deploy_cluster(
        n_shards=args.shards, t1_replicas=args.replicas,
        t2_replicas=args.t2_replicas,
        cache=cluster.ResultCache(capacity=args.cache_capacity,
                                  ttl_s=args.cache_ttl)
        if args.cache else None)
    report = stream.run_stream(pipe, engine=fleet,
                               verify_swaps=args.verify, **run_kw)
    for w in report.windows:
        print(f"[cluster] {w.line()}")
    print(f"[cluster] retiered cluster {report.summary()}  "
          f"[{fleet.describe()}]")

    if args.verify:
        if not fleet.consistency_ok():
            raise SystemExit("[cluster] CONSISTENCY FAILURE: a batch saw a "
                             "mixed (ψ, Tier-1) generation pair")
        if not report.parity_all_ok():
            raise SystemExit("[cluster] PARITY FAILURE: sharded serving "
                             "diverged from single-tier matching")
        # never verify vacuously: if no refit triggered (so no swap parity
        # check ran), probe scatter-gather exactness directly
        direct_checks = 0
        if report.n_parity_checks == 0:
            import numpy as np
            probe = pipe.log.queries[:256]
            for a, b in zip(fleet.serve(probe), fleet.serve_reference(probe)):
                if not np.array_equal(a, b):
                    raise SystemExit("[cluster] PARITY FAILURE: sharded "
                                     "serving diverged from single-tier "
                                     "matching on the direct probe")
            direct_checks = len(probe)
        cache_checks = 0
        if args.cache:
            # the second pass serves FROM the cache; its answers must stay
            # bit-identical to the single-tier oracle (exactness of a hit)
            import numpy as np
            probe = pipe.log.queries[:128]
            fleet.serve(probe)                     # populate
            hits0 = fleet.cache.stats.hits
            for a, b in zip(fleet.serve(probe), fleet.serve_reference(probe)):
                if not np.array_equal(a, b):
                    raise SystemExit("[cluster] CACHE PARITY FAILURE: a "
                                     "cached answer diverged from "
                                     "single-tier matching")
            if fleet.cache.stats.hits <= hits0:
                raise SystemExit("[cluster] CACHE FAILURE: repeat traffic "
                                 "produced no front-end hits")
            cache_checks = len(probe)
        if budget_split is not None:
            # per-shard Tier-1 doc counts must respect every cap B_k
            caps = pipe.result.extra["caps"]
            t1 = pipe.tiering().tier1_docs
            for s, cap in zip(fleet.shards, caps):
                local = int(t1[s.doc_lo:s.doc_lo + s.n_docs].sum())
                if local > cap:
                    raise SystemExit(
                        f"[cluster] BUDGET FAILURE: shard {s.index} holds "
                        f"{local} Tier-1 docs > cap {cap:.0f}")
        print(f"[cluster] verified: {report.n_parity_checks} swap parity "
              f"checks + {direct_checks} direct probes ok, "
              f"{len(fleet.trace)} batches pair-consistent"
              + (f", {cache_checks} cached answers oracle-exact"
                 if cache_checks else "")
              + (", per-shard caps respected" if budget_split is not None
                 else ""))
    if args.cache:
        c = fleet.cache.snapshot()
        print(f"[cluster] frontend cache: {c['hits']}/{c['lookups']} hits "
              f"(rate {c['hit_rate']:.3f}), {c['invalidations']} epoch "
              f"invalidations, size {c['size']}/{c['capacity']}")
    if static is not None:
        delta = report.mean_coverage - static.mean_coverage
        print(f"[cluster] mean windowed tier-1 coverage: "
              f"single-static={static.mean_coverage:.3f} "
              f"cluster-retiered={report.mean_coverage:.3f} ({delta:+.3f})")
    if obs.enabled():
        print(f"[cluster] {obs.dashboard()}")
        ex = obs.get_exporter()
        if ex is not None and ex.n_written:
            print(f"[cluster] obs: {ex.n_written} snapshots -> {ex.path}")
    stack.close()


if __name__ == "__main__":
    main()
