"""Deterministic discrete-event load generator for the serving cluster.

Open-loop seeded Poisson arrivals hit the fleet topology (per-shard,
per-replica words-per-query from a `ClusterPlan` snapshot); each query
scatters one subquery to the least-loaded replica of every shard it must
touch (Tier-1 shards with local D₁ when eligible, every Tier-2 shard
otherwise), each replica is a single-server FIFO queue, and the query
completes when its slowest subquery gathers — so tail latency captures both
queueing and the straggler amplification of wide scatter fan-outs.

Service-time model (per subquery):
    service = t_fixed_us + words_per_query * t_word_us    [microseconds]
with a seeded heavy-tail straggler: with probability `straggler_p` the
subquery is stretched by `straggler_x`. Everything is derived from one
`numpy` Generator, so two runs with equal arguments are bit-identical.

Optionally, a rolling Tier-1 swap can be injected mid-run (`rollout_at_s`):
replicas go unavailable one at a time for `swap_ms` each, in the same
replica-major order the live `RollingSwap` uses; eligible queries fall back
to the Tier-2 scatter when no Tier-1 cover remains, exactly like the router.
With `rollout_mode="stw"` the same aggregate swap time is instead ONE global
outage window — the whole fleet is down and every query arriving inside it
waits for the rebuild — which is the stop-the-world comparison arm for the
rolling-ingest benchmarks.

Ingest traffic (repro.ingest): `ingest_qps` adds a seeded Poisson stream of
document-append events. Grow-mode appends land every new word in the LAST
shard (`shard.grow_shards`), so each event writes `ingest_words` words into
every Tier-2 replica of that shard — writes queue in the same FIFO as reads
and show up as read-latency pressure, which is exactly the interference the
ingest benchmarks measure. `ingest_qps=0` draws nothing extra from the rng,
so query-only runs stay bit-identical to the pre-ingest generator.

Front-end layers (repro.cluster.frontend), each default-off and each drawing
from a SEPARATE seeded generator so defaults-off runs stay bit-identical to
the pre-frontend generator:

  * `hedge_ms` — hedged dispatch: when a subquery's predicted completion
    (queue wait + service) exceeds the hedge delay, a backup fires on the
    second-least-loaded replica of the same group after the delay;
    first-response-wins, the loser is CANCELLED (its queue slot rolls back
    to the work actually done, the extra words it scanned are reported as
    `hedge_extra_words`) — the classic p99-straggler amputation;
  * `admission` (an `AdmissionPolicy`) — bounded per-shard queues +
    deadline-aware shedding: over-bound eligible queries demote to the
    Tier-2 scatter (`n_shed_to_t2`), and a query the Tier-2 queue can't
    serve in time gets a DEGRADED immediate answer priced at `t_fixed` only
    (`n_shed`) — no postings scanned, the load-shed counters tell on it;
  * `cache_keys` — the front-end result cache in sim form: per-arrival key
    ids (e.g. `frontend.zipf_keys`), an LRU of `cache_capacity` keys with
    optional `cache_ttl_s`; a hit costs `t_fixed` and zero words, which is
    exactly how `ResultCache` prices a hit on the real router.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro import obs
from repro.cluster.frontend import AdmissionPolicy

_HEDGES = obs.counter("loadgen_hedges_total",
                      "backup subqueries fired by hedged dispatch")
_SHEDS = obs.counter("loadgen_sheds_total",
                     "queries shed by overload admission",
                     labels=("kind",))     # degraded | to_t2

# fixed bucket upper bounds (ms) for every loadgen latency histogram — pinned
# so any two runs' histograms merge bucket-by-bucket in BENCH_cluster.json
LATENCY_BUCKETS_MS = (0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0,
                      100.0, 200.0, 500.0, 1000.0)


@dataclasses.dataclass(frozen=True)
class ClusterPlan:
    """Static topology snapshot the simulator runs against.

    t1_words[s][r] / t2_words[s][r]: words-per-query of replica r of shard s
    (Tier-1 entries of 0 mean D₁ misses the shard — never contacted).
    """
    t1_words: tuple[tuple[int, ...], ...]
    t2_words: tuple[tuple[int, ...], ...]

    @classmethod
    def of_cluster(cls, cluster) -> "ClusterPlan":
        return cls(
            t1_words=tuple(tuple(r.words_per_query for r in g)
                           for g in cluster.router.t1),
            t2_words=tuple(tuple(r.words_per_query for r in g)
                           for g in cluster.router.t2))

    @property
    def n_shards(self) -> int:
        return len(self.t2_words)

    @property
    def t1_replicas(self) -> int:
        return max((len(g) for g in self.t1_words), default=0)

    @property
    def t2_replicas(self) -> int:
        return max((len(g) for g in self.t2_words), default=0)

    def resized(self, t1_replicas: int, t2_replicas: int) -> "ClusterPlan":
        """Same shard topology with each replica group resized (replicas in
        a group are homogeneous: they serve the same sub-index)."""
        if t1_replicas < 1 or t2_replicas < 1:
            raise ValueError("each replica group needs >= 1 replica")
        return ClusterPlan(
            t1_words=tuple((g[0],) * t1_replicas if g else ()
                           for g in self.t1_words),
            t2_words=tuple((g[0],) * t2_replicas if g else ()
                           for g in self.t2_words))


@dataclasses.dataclass
class LoadgenReport:
    n_queries: int
    offered_qps: float
    throughput_qps: float       # completed / makespan
    p50_ms: float
    p95_ms: float
    p99_ms: float
    mean_ms: float
    max_ms: float
    tier1_fraction: float
    fleet_words: int            # total postings words scanned fleet-wide
    per_shard_t2_words: tuple[int, ...]   # strong-scaling signal
    t2_fallback_queries: int    # eligible queries served by Tier 2 (rollout)
    # queueing observability (autoscaling inputs): busiest replica's busy
    # fraction of the makespan and worst queue backlog seen at dispatch, ms
    max_t1_util: float = 0.0
    max_t2_util: float = 0.0
    max_t1_backlog_ms: float = 0.0
    max_t2_backlog_ms: float = 0.0
    # ingest-under-load observability (repro.ingest)
    n_ingest_events: int = 0
    ingest_words_total: int = 0          # words written fleet-wide
    stw_delayed_queries: int = 0         # arrivals inside the stw outage
    # front-end layers (repro.cluster.frontend) — all zero when disabled
    n_hedges: int = 0                    # backup subqueries fired
    n_hedge_wins: int = 0                # hedges where the backup won
    n_hedge_cancels: int = 0             # losing legs cancelled mid-flight
    hedge_extra_words: int = 0           # words the cancelled legs scanned
    n_shed_to_t2: int = 0                # eligible queries demoted to Tier 2
    n_shed: int = 0                      # degraded immediate answers
    shed_frac: float = 0.0               # (n_shed + n_shed_to_t2) / queries
    n_cache_hits: int = 0                # result-cache hits (zero words)
    cache_hit_rate: float = 0.0
    # full latency distribution over LATENCY_BUCKETS_MS (an obs.Histogram
    # snapshot dict) — computed UNCONDITIONALLY, so the report is identical
    # whether or not the telemetry plane is on
    latency_hist: dict | None = None

    def line(self) -> str:
        extra = ""
        if self.n_hedges:
            extra += f"  hedges={self.n_hedges} ({self.n_hedge_wins} won)"
        if self.n_shed or self.n_shed_to_t2:
            extra += f"  shed={self.n_shed}+{self.n_shed_to_t2}->t2"
        if self.n_cache_hits:
            extra += f"  cache_hit={self.cache_hit_rate:.3f}"
        return (f"qps={self.throughput_qps:,.0f} (offered {self.offered_qps:,.0f})"
                f"  p50={self.p50_ms:.3f}ms p95={self.p95_ms:.3f}ms "
                f"p99={self.p99_ms:.3f}ms  t1={self.tier1_fraction:.3f}  "
                f"fleet_words={self.fleet_words:,}  "
                f"util={max(self.max_t1_util, self.max_t2_util):.2f}"
                f"{extra}")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["per_shard_t2_words"] = list(self.per_shard_t2_words)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "LoadgenReport":
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in names}
        if "per_shard_t2_words" in kw:
            kw["per_shard_t2_words"] = tuple(kw["per_shard_t2_words"])
        return cls(**kw)


def run_loadgen(plan: ClusterPlan, eligible: np.ndarray, *,
                rate_qps: float = 20000.0, n_queries: int = 4000,
                seed: int = 0, t_fixed_us: float = 20.0,
                t_word_us: float = 4.0, straggler_p: float = 0.01,
                straggler_x: float = 8.0, rollout_at_s: float | None = None,
                swap_ms: float = 5.0, rollout_mode: str = "rolling",
                ingest_qps: float = 0.0,
                ingest_words: int = 64,
                hedge_ms: float | None = None,
                admission: AdmissionPolicy | None = None,
                cache_keys: np.ndarray | None = None,
                cache_capacity: int = 4096,
                cache_ttl_s: float | None = None) -> LoadgenReport:
    """Simulate `n_queries` open-loop arrivals; queries cycle through the
    `eligible` flags (a classified sample of real traffic)."""
    if rollout_mode not in ("rolling", "stw"):
        raise ValueError(f"rollout_mode must be 'rolling' or 'stw', "
                         f"got {rollout_mode!r}")
    rng = np.random.default_rng(seed)
    eligible = np.asarray(eligible, bool)
    if eligible.size == 0:
        eligible = np.zeros(1, bool)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=n_queries))
    straggle = rng.random((n_queries, plan.n_shards)) < straggler_p
    # ingest arrivals draw AFTER the query stream, so ingest_qps=0 runs are
    # bit-identical to the pre-ingest generator
    ingest_times = np.empty(0)
    if ingest_qps > 0:
        n_ing = max(1, int(round(ingest_qps * float(arrivals[-1]))))
        ingest_times = np.cumsum(
            rng.exponential(1.0 / ingest_qps, size=n_ing))
    # front-end layers draw from SEPARATE seeded generators, and only when
    # enabled — defaults-off runs stay bit-identical to the pre-frontend
    # generator (the checked-in BENCH_cluster tiny baseline pins this)
    hedge_delay = hstraggle = None
    if hedge_ms is not None:
        hedge_delay = hedge_ms * 1e-3
        hrng = np.random.default_rng([seed, 0x6865646])
        hstraggle = hrng.random((n_queries, plan.n_shards)) < straggler_p
    qbound = dl = None
    if admission is not None:
        qbound = None if admission.queue_bound_ms is None \
            else admission.queue_bound_ms * 1e-3
        dl = None if admission.deadline_ms is None \
            else admission.deadline_ms * 1e-3
    admit = qbound is not None or dl is not None
    sim_cache: OrderedDict | None = None
    if cache_keys is not None:
        cache_keys = np.asarray(cache_keys, np.int64)
        if cache_keys.size == 0:
            raise ValueError("cache_keys must be non-empty when provided")
        if cache_capacity < 1:
            raise ValueError(f"cache_capacity must be >= 1, "
                             f"got {cache_capacity}")
        sim_cache = OrderedDict()

    # per-replica next-free times, flat-indexed [tier][shard][replica]
    free_t1 = [np.zeros(len(g)) for g in plan.t1_words]
    free_t2 = [np.zeros(len(g)) for g in plan.t2_words]
    busy_t1 = [np.zeros(len(g)) for g in plan.t1_words]
    busy_t2 = [np.zeros(len(g)) for g in plan.t2_words]
    backlog = [0.0, 0.0]         # worst queue wait seen at dispatch, per tier

    # replica-major rollout outage windows: (start, end) per t1 replica
    outages: dict[tuple[int, int], tuple[float, float]] = {}
    global_outage: tuple[float, float] | None = None
    if rollout_at_s is not None and rollout_mode == "stw":
        # stop-the-world: the SAME aggregate swap time (every replica of
        # both tiers), concentrated into one fleet-wide outage window
        n_reps = sum(len(g) for g in plan.t1_words) + \
            sum(len(g) for g in plan.t2_words)
        global_outage = (rollout_at_s,
                         rollout_at_s + swap_ms * 1e-3 * n_reps)
    elif rollout_at_s is not None:
        t = rollout_at_s
        n_reps = max((len(g) for g in plan.t1_words), default=0)
        for r in range(n_reps):
            for s in range(len(plan.t1_words)):
                if r < len(plan.t1_words[s]):
                    outages[(s, r)] = (t, t + swap_ms * 1e-3)
                    t += swap_ms * 1e-3

    def available(s: int, r: int, now: float) -> bool:
        lo_hi = outages.get((s, r))
        return lo_hi is None or not (lo_hi[0] <= now < lo_hi[1])

    latencies = np.empty(n_queries)
    fleet_words = 0
    n_t1 = 0
    fallbacks = 0
    per_shard_t2 = np.zeros(plan.n_shards, np.int64)
    n_ingest = 0
    ingest_total = 0
    stw_delayed = 0
    ing_ptr = 0
    n_hedges = n_hedge_wins = n_hedge_cancels = 0
    hedge_extra = 0.0
    n_shed = n_shed_to_t2 = 0
    n_cache_hits = 0
    last = plan.n_shards - 1       # grow-mode appends write the LAST shard

    def hedge_leg(free, busy, s, r1, start1, service1, words1, cand,
                  words_g, i, now):
        """Fire a backup on the least-loaded other replica of the group;
        first response wins, the LOSER is cancelled: its queue slot rolls
        back to the work it actually did and the words it scanned before
        cancellation are accounted as hedge waste, not shard traffic."""
        r2 = min(cand, key=lambda r: free[s][r])
        words2 = words_g[r2]
        service2 = (t_fixed_us + words2 * t_word_us) * 1e-6
        if hstraggle[i, s]:            # backup straggles independently
            service2 *= straggler_x
        start2 = max(now + hedge_delay, free[s][r2])
        c1, c2 = start1 + service1, start2 + service2
        win = min(c1, c2)
        for r, start, c in ((r1, start1, c1), (r2, start2, c2)):
            worked_to = min(c, win)    # the loser stops at the winner's done
            busy[s][r] += max(0.0, worked_to - start)
            free[s][r] = max(free[s][r], worked_to)
        backup_won = c2 < c1
        w_win, w_lose = (words2, words1) if backup_won else (words1, words2)
        st_l, sv_l, c_l = (start1, service1, c1) if backup_won \
            else (start2, service2, c2)
        frac = max(0.0, min(c_l, win) - st_l) / sv_l
        return win, backup_won, w_win, w_lose * frac

    def apply_ingest(until: float) -> None:
        """Queue every ingest write arriving before `until` on the last
        shard's Tier-2 replicas (all replicas apply every write)."""
        nonlocal ing_ptr, n_ingest, ingest_total
        while ing_ptr < len(ingest_times) and ingest_times[ing_ptr] <= until:
            it = float(ingest_times[ing_ptr])
            if global_outage and global_outage[0] <= it < global_outage[1]:
                it = global_outage[1]      # writes wait out the outage too
            service = (t_fixed_us + ingest_words * t_word_us) * 1e-6
            for r in range(len(plan.t2_words[last])):
                start = max(it, free_t2[last][r])
                free_t2[last][r] = start + service
                busy_t2[last][r] += service
            ingest_total += ingest_words * len(plan.t2_words[last])
            n_ingest += 1
            ing_ptr += 1

    for i in range(n_queries):
        t = arrivals[i]
        apply_ingest(t)
        if global_outage and global_outage[0] <= t < global_outage[1]:
            stw_delayed += 1
            t = global_outage[1]           # the fleet is down: wait it out
        if sim_cache is not None:
            # front-end result cache: a hit answers at the fixed cost with
            # ZERO postings words — no replica is ever contacted
            ck = int(cache_keys[i % cache_keys.size])
            ent = sim_cache.get(ck)
            if ent is not None and (cache_ttl_s is None
                                    or t - ent <= cache_ttl_s):
                sim_cache.move_to_end(ck)
                n_cache_hits += 1
                latencies[i] = (t - arrivals[i]) + t_fixed_us * 1e-6
                continue
            if ent is not None:            # TTL lapsed
                del sim_cache[ck]
        elig = bool(eligible[i % eligible.size])
        use_t1 = False
        if elig:
            # every shard with local D₁ needs an available replica
            picks = []
            for s, group in enumerate(plan.t1_words):
                words = [w for w in group]
                avail = [r for r in range(len(group))
                         if available(s, r, t) and words[r] > 0]
                if any(w > 0 for w in words) and not avail:
                    picks = None            # no Tier-1 cover: fall back
                    break
                if avail:
                    picks.append((s, min(avail, key=lambda r: free_t1[s][r])))
            if picks is not None:
                use_t1 = True
            else:
                fallbacks += 1
        if use_t1 and admit:
            # bounded per-shard queues: an over-bound (or deadline-hopeless)
            # eligible query demotes to the Tier-2-only scatter
            worst = pred = 0.0
            for s, r in picks:
                worst = max(worst, free_t1[s][r] - t)
                if dl is not None:
                    est = (t_fixed_us + plan.t1_words[s][r] * t_word_us) * 1e-6
                    pred = max(pred, max(t, free_t1[s][r]) + est)
            if (qbound is not None and worst > qbound) or \
                    (dl is not None and pred - t > dl):
                use_t1 = False
                n_shed_to_t2 += 1
        if use_t1:
            n_t1 += 1
            done = t
            for s, r in picks:
                words = plan.t1_words[s][r]
                service = (t_fixed_us + words * t_word_us) * 1e-6
                if straggle[i, s]:
                    service *= straggler_x
                start = max(t, free_t1[s][r])
                backlog[0] = max(backlog[0], start - t)
                comp = start + service
                cand = None
                if hedge_delay is not None and comp - t > hedge_delay:
                    group = plan.t1_words[s]
                    cand = [r2 for r2 in range(len(group))
                            if r2 != r and group[r2] > 0
                            and available(s, r2, t)]
                if cand:
                    comp, backup_won, w_win, w_extra = hedge_leg(
                        free_t1, busy_t1, s, r, start, service, words,
                        cand, plan.t1_words[s], i, t)
                    n_hedges += 1
                    n_hedge_wins += int(backup_won)
                    n_hedge_cancels += 1
                    hedge_extra += w_extra
                    fleet_words += w_win
                else:
                    free_t1[s][r] = comp
                    busy_t1[s][r] += service
                    fleet_words += words
                done = max(done, comp)
        else:
            t2_picks = [int(np.argmin(free_t2[s]))
                        for s in range(plan.n_shards)]
            if admit:
                # deadline-aware shedding: if even the Tier-2 scatter can't
                # make it, answer DEGRADED at the fixed cost (no scan)
                worst = pred = 0.0
                for s, r in enumerate(t2_picks):
                    worst = max(worst, free_t2[s][r] - t)
                    if dl is not None:
                        est = (t_fixed_us
                               + plan.t2_words[s][r] * t_word_us) * 1e-6
                        pred = max(pred, max(t, free_t2[s][r]) + est)
                if (qbound is not None and worst > qbound) or \
                        (dl is not None and pred - t > dl):
                    n_shed += 1
                    latencies[i] = (t - arrivals[i]) + t_fixed_us * 1e-6
                    continue               # degraded answers aren't cached
            done = t
            for s, group in enumerate(plan.t2_words):
                r = t2_picks[s]
                words = group[r]
                service = (t_fixed_us + words * t_word_us) * 1e-6
                if straggle[i, s]:
                    service *= straggler_x
                start = max(t, free_t2[s][r])
                backlog[1] = max(backlog[1], start - t)
                comp = start + service
                cand = None
                if hedge_delay is not None and comp - t > hedge_delay \
                        and len(group) > 1:
                    cand = [r2 for r2 in range(len(group)) if r2 != r]
                if cand:
                    comp, backup_won, w_win, w_extra = hedge_leg(
                        free_t2, busy_t2, s, r, start, service, words,
                        cand, group, i, t)
                    n_hedges += 1
                    n_hedge_wins += int(backup_won)
                    n_hedge_cancels += 1
                    hedge_extra += w_extra
                    fleet_words += w_win
                    per_shard_t2[s] += w_win
                else:
                    free_t2[s][r] = comp
                    busy_t2[s][r] += service
                    fleet_words += words
                    per_shard_t2[s] += words
                done = max(done, comp)
        latencies[i] = done - arrivals[i]  # from TRUE arrival (stw delays)
        if sim_cache is not None:          # full answers become cacheable
            sim_cache[ck] = t
            if len(sim_cache) > cache_capacity:
                sim_cache.popitem(last=False)

    apply_ingest(float("inf"))             # drain writes past the last read
    makespan = max(
        float(arrivals[-1] + latencies[-1]),
        max((float(f.max()) for f in free_t1 + free_t2 if f.size), default=0.0)
    ) - float(arrivals[0])
    lat_ms = latencies * 1e3
    # detached (always-on) histogram: the report's distribution never depends
    # on the REPRO_OBS switch; the registry copy is the gated fleet view
    hist = obs.Histogram("loadgen_latency_ms", always=True,
                         buckets=LATENCY_BUCKETS_MS)
    hist.observe_many(lat_ms)
    obs.histogram("loadgen_latency_ms", "end-to-end query latency",
                  buckets=LATENCY_BUCKETS_MS).observe_many(lat_ms)
    # registry-gated tail gauges for the SLO engine; the report percentiles
    # above stay the unconditional source of truth
    obs.gauge("loadgen_p95_ms", "last loadgen run's p95 latency").set(
        round(float(np.percentile(lat_ms, 95)), 6))
    obs.gauge("loadgen_p99_ms", "last loadgen run's p99 latency").set(
        round(float(np.percentile(lat_ms, 99)), 6))
    # front-end counters/gauges — inc(0) still creates the series, so the
    # telemetry check can require them from any loadgen-bearing run
    _HEDGES.inc(n_hedges)
    _SHEDS.inc(n_shed, kind="degraded")
    _SHEDS.inc(n_shed_to_t2, kind="to_t2")
    obs.gauge("loadgen_shed_frac",
              "last loadgen run's shed fraction (degraded + demoted)").set(
        round((n_shed + n_shed_to_t2) / n_queries, 6))
    if sim_cache is not None:
        obs.gauge("loadgen_cache_hit_rate",
                  "last loadgen run's result-cache hit rate").set(
            round(n_cache_hits / n_queries, 6))
    return LoadgenReport(
        n_queries=n_queries,
        offered_qps=rate_qps,
        throughput_qps=n_queries / max(makespan, 1e-12),
        p50_ms=float(np.percentile(lat_ms, 50)),
        p95_ms=float(np.percentile(lat_ms, 95)),
        p99_ms=float(np.percentile(lat_ms, 99)),
        mean_ms=float(lat_ms.mean()),
        max_ms=float(lat_ms.max()),
        tier1_fraction=n_t1 / n_queries,
        fleet_words=int(fleet_words),
        per_shard_t2_words=tuple(int(x) for x in per_shard_t2),
        t2_fallback_queries=fallbacks,
        max_t1_util=float(max((b.max() for b in busy_t1 if b.size),
                              default=0.0) / max(makespan, 1e-12)),
        max_t2_util=float(max((b.max() for b in busy_t2 if b.size),
                              default=0.0) / max(makespan, 1e-12)),
        max_t1_backlog_ms=float(backlog[0] * 1e3),
        max_t2_backlog_ms=float(backlog[1] * 1e3),
        n_ingest_events=n_ingest,
        ingest_words_total=int(ingest_total),
        stw_delayed_queries=stw_delayed,
        n_hedges=n_hedges,
        n_hedge_wins=n_hedge_wins,
        n_hedge_cancels=n_hedge_cancels,
        hedge_extra_words=int(round(hedge_extra)),
        n_shed_to_t2=n_shed_to_t2,
        n_shed=n_shed,
        shed_frac=(n_shed + n_shed_to_t2) / n_queries,
        n_cache_hits=n_cache_hits,
        cache_hit_rate=n_cache_hits / n_queries,
        latency_hist=hist.snapshot(),
    )


def fit_service_model(words: np.ndarray, us_per_query: np.ndarray) -> dict:
    """Least-squares fit of the service model `t = t_fixed + words * t_word`.

    `words`/`us_per_query` are paired measurements (e.g. `match_batch` wall
    time per query against sub-indexes of different packed widths). Returns
    {"t_fixed_us", "t_word_us", "r2", "n_points"} — the calibrated
    coefficients `run_loadgen` should be driven with, instead of its assumed
    defaults (ROADMAP "loadgen vs reality calibration").
    """
    w = np.asarray(words, np.float64)
    y = np.asarray(us_per_query, np.float64)
    if w.shape != y.shape or w.size < 2:
        raise ValueError("need >= 2 paired (words, us) measurements")
    a = np.stack([np.ones_like(w), w], axis=1)
    coef, *_ = np.linalg.lstsq(a, y, rcond=None)
    pred = a @ coef
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    return {
        "t_fixed_us": float(coef[0]),
        "t_word_us": float(coef[1]),
        "r2": 1.0 - ss_res / max(ss_tot, 1e-30),
        "n_points": int(w.size),
    }


@dataclasses.dataclass(frozen=True)
class ReplicaSuggestion:
    """`suggest_replicas` output: the sizing plus the loadgen run proving it."""
    t1_replicas: int
    t2_replicas: int
    report: LoadgenReport        # loadgen at the suggested sizing
    iterations: int
    meets_slo: bool

    def line(self) -> str:
        return (f"t1_replicas={self.t1_replicas} t2_replicas="
                f"{self.t2_replicas}  p95={self.report.p95_ms:.3f}ms  "
                f"{'meets' if self.meets_slo else 'MISSES'} SLO "
                f"({self.iterations} loadgen runs)")


def suggest_replicas(plan: ClusterPlan, offered_load: float, slo_p95: float,
                     *, eligible: np.ndarray | None = None,
                     tier1_fraction: float = 0.5, n_queries: int = 3000,
                     seed: int = 0, max_replicas: int = 64,
                     target_util: float = 0.7,
                     **loadgen_kw) -> ReplicaSuggestion:
    """Close the autoscaling loop: size `t1_replicas`/`t2_replicas` so the
    fleet absorbs `offered_load` (qps) within the `slo_p95` (ms) tail.

    Seeds each tier's count analytically from the busiest replica's
    utilization at the current sizing (replicas needed ≈ current ×
    util / target_util), then walks upward, always growing the tier with the
    worse queue backlog, re-running the deterministic load generator until
    the p95 SLO holds or `max_replicas` is hit. `eligible` fixes the
    classified traffic mix (default: a `tier1_fraction` Bernoulli pattern).
    """
    if eligible is None:
        rng = np.random.default_rng(seed + 1)
        eligible = rng.random(256) < tier1_fraction
    t1_n, t2_n = max(plan.t1_replicas, 1), max(plan.t2_replicas, 1)

    def run(t1_n: int, t2_n: int) -> LoadgenReport:
        return run_loadgen(plan.resized(t1_n, t2_n), eligible,
                           rate_qps=offered_load, n_queries=n_queries,
                           seed=seed, **loadgen_kw)

    rep = run(t1_n, t2_n)
    iterations = 1
    # analytic jump from the utilization signal (no search below this point:
    # a replica group saturates once its busiest member exceeds target_util)
    t1_n = min(max_replicas,
               max(t1_n, int(np.ceil(t1_n * rep.max_t1_util / target_util))))
    t2_n = min(max_replicas,
               max(t2_n, int(np.ceil(t2_n * rep.max_t2_util / target_util))))
    rep = run(t1_n, t2_n)
    iterations += 1
    while rep.p95_ms > slo_p95 and max(t1_n, t2_n) < max_replicas:
        # grow the tier whose queueing is worse (backlog, then utilization)
        grow_t1 = (rep.max_t1_backlog_ms, rep.max_t1_util) >= \
                  (rep.max_t2_backlog_ms, rep.max_t2_util)
        if grow_t1 and t1_n < max_replicas:
            t1_n += 1
        elif t2_n < max_replicas:
            t2_n += 1
        else:
            t1_n += 1
        rep = run(t1_n, t2_n)
        iterations += 1
    return ReplicaSuggestion(t1_replicas=t1_n, t2_replicas=t2_n, report=rep,
                             iterations=iterations,
                             meets_slo=bool(rep.p95_ms <= slo_p95))
