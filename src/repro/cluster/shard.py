"""Doc-space sharding of the packed postings index.

A cluster partitions the document universe into contiguous, WORD-ALIGNED
ranges so every shard's sub-index is a pure column slice of the packed
postings matrix — no unpack/repack, and a shard's local match bitset drops
into the global result at `[word_lo:word_hi]`. Shards partition the doc
space, so the scatter-gather OR-merge of per-shard match bitsets is exactly
the single-tier match set (Theorem 3.1 then holds shard-locally: a global
Tier-1 doc set restricted to a shard contains every eligible query's matches
that live in that shard).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitset, constraint


@dataclasses.dataclass(frozen=True)
class DocShard:
    """One contiguous word-aligned slice of the document universe."""
    index: int
    word_lo: int     # first postings word owned (inclusive)
    word_hi: int     # last postings word owned (exclusive)
    doc_lo: int      # global id of local doc 0 (== word_lo * 32)
    n_docs: int      # valid documents in this shard

    @property
    def n_words(self) -> int:
        return self.word_hi - self.word_lo


def plan_shards(n_docs: int, n_shards: int) -> list[DocShard]:
    """Partition `n_docs` documents into ≤ `n_shards` word-aligned ranges.

    Words are spread as evenly as possible; the effective shard count is
    clamped to the number of postings words (a shard must own ≥ 1 word).
    Delegates the split to `core.constraint.partition_bounds`, so a
    `PartitionedBudget` over the same (n_docs, n_shards) bounds exactly the
    doc ranges these shards serve — per-shard solver budgets and fleet
    shards line up by construction.
    """
    bounds = constraint.partition_bounds(n_docs, n_shards)
    shards = []
    for i, (lo, hi) in enumerate(zip(bounds, bounds[1:])):
        doc_lo = lo * bitset.WORD
        shards.append(DocShard(
            index=i, word_lo=lo, word_hi=hi, doc_lo=doc_lo,
            n_docs=min(n_docs, hi * bitset.WORD) - doc_lo))
    return shards


def grow_shards(shards: list[DocShard], n_docs_new: int) -> list[DocShard]:
    """Grow a shard plan for an appended word-aligned doc block.

    Grow mode (repro.ingest): every existing shard keeps its exact word
    range — so its Tier-2 column slice is bit-identical and content-carried
    through a rolling corpus swap — and the LAST shard absorbs the appended
    words. Rebalancing would realign bounds under a `PartitionedBudget` and
    force a full-fleet roll, so it is deliberately deferred to an offline
    re-plan. The last shard's `n_docs` is also refreshed: appends may fill
    hole slots' words and extend past the old tail.
    """
    if not shards:
        raise ValueError("cannot grow an empty shard plan")
    w_new = bitset.n_words(n_docs_new)
    last = shards[-1]
    if w_new < last.word_hi:
        raise ValueError(
            f"corpus shrank: {n_docs_new} docs need {w_new} words but the "
            f"plan already covers {last.word_hi}")
    grown = list(shards[:-1])
    grown.append(DocShard(
        index=last.index, word_lo=last.word_lo, word_hi=w_new,
        doc_lo=last.doc_lo,
        n_docs=min(n_docs_new, w_new * bitset.WORD) - last.doc_lo))
    return grown


def shard_postings(postings: np.ndarray, n_docs: int,
                   n_shards: int) -> tuple[list[DocShard], list[np.ndarray]]:
    """Split packed postings [V, Wd] into per-shard column slices.

    Returns `(shards, slices)` where `slices[i]` is the [V, shards[i].n_words]
    Tier-2 sub-index of shard i.
    """
    shards = plan_shards(n_docs, n_shards)
    return shards, [postings[:, s.word_lo:s.word_hi] for s in shards]


def shard_tier_postings(shard_slice: np.ndarray, shard: DocShard,
                        tier1_docs: np.ndarray) -> tuple[np.ndarray, int]:
    """Shard-local Tier-1 sub-index: the shard's Tier-2 slice masked to the
    shard's portion of D₁, plus the compacted words-per-query a re-indexed
    production Tier-1 of that size would scan (0 when D₁ misses the shard,
    in which case the router need not contact the shard at all).
    """
    local = np.asarray(tier1_docs[shard.doc_lo:shard.doc_lo + shard.n_docs],
                       bool)
    t1_bits = bitset.np_pack(local) if shard.n_docs else \
        np.zeros(shard.n_words, np.uint32)
    if t1_bits.shape[0] != shard.n_words:   # last shard: pad to slice width
        t1_bits = np.concatenate(
            [t1_bits, np.zeros(shard.n_words - t1_bits.shape[0], np.uint32)])
    n_local = int(local.sum())
    words = bitset.n_words(n_local) if n_local else 0
    return shard_slice & t1_bits[None, :], words
