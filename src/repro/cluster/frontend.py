"""Serving front-end: classify-keyed result cache + overload policies.

The paper prices every query by the postings words it scans (§2.2), yet real
traffic is heavy-tailed and repetitive — the same conjunctive query pattern
arrives again and again. A conjunctive match set m(q) depends ONLY on the
query's token SET, so the packed query vocab bitset the ψ^clause kernel
already consumes (`matching.pack_query_bits`) is an EXACT result key: two
queries with equal keys have bit-identical match sets at a fixed corpus
version. `ResultCache` exploits that:

  * key   = the packed classification bitset row, as bytes;
  * epoch = (generation, corpus_version, tier-1-served) — entries are scoped
    to the exact (ψ, corpus) state they were computed under, so every
    rolling tiering swap and every rolling corpus swap invalidates by
    construction and a hit stays bit-identical to `serve_reference`;
  * LRU + optional TTL, sharded by key hash so one hot bucket can't evict
    the whole working set.

The module also carries the front-end's overload policy surface
(`AdmissionPolicy`: bounded per-shard queues + deadline-aware shedding) and
the Zipf traffic helpers the frontend benchmarks replay
(`zipf_keys` / `keys_of`). Hedged dispatch and the admission queue model
live in `cluster.loadgen`, which consumes `AdmissionPolicy` directly.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from collections import OrderedDict

import numpy as np

from repro import obs

_LOOKUPS = obs.counter("frontend_cache_lookups_total",
                       "result-cache lookups at the serving front-end")
_HITS = obs.counter("frontend_cache_hits_total",
                    "result-cache hits (zero postings words scanned)")
_MISSES = obs.counter("frontend_cache_misses_total",
                      "result-cache misses (fresh tier match)")
_EVICT = obs.counter("frontend_cache_evictions_total",
                     "result-cache entries dropped",
                     labels=("reason",))     # lru | ttl | epoch


def prime_counters() -> None:
    """Create the front-end counter series at zero so a run that never
    caches still exports them (`launch.obs --check --require-metric`)."""
    _LOOKUPS.inc(0)
    _HITS.inc(0)
    _MISSES.inc(0)


@dataclasses.dataclass
class CacheStats:
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0       # LRU capacity pressure
    expirations: int = 0     # TTL lapse
    invalidations: int = 0   # epoch moved (tiering/corpus swap)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        return d


class ResultCache:
    """Sharded LRU + TTL cache of exact match-set rows, epoch-scoped.

    Stored value per key: (epoch, inserted_at, elig, packed row). `lookup`
    returns `(elig, row)` only when the entry's epoch equals the epoch the
    batch is being served at — a stale entry is evicted on sight, so a hit
    can never cross a tiering generation or corpus version. Exactness is
    therefore structural: the cache stores what the fleet computed at the
    SAME (ψ, Tier-1, Tier-2, corpus) tuple the batch would use afresh.
    """

    def __init__(self, capacity: int = 8192, ttl_s: float | None = None,
                 n_shards: int = 8, clock=time.monotonic):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.capacity = capacity
        self.ttl_s = ttl_s
        self.n_shards = min(n_shards, capacity)
        self._per_shard = max(1, capacity // self.n_shards)
        self._shards: list[OrderedDict] = [
            OrderedDict() for _ in range(self.n_shards)]
        self._clock = clock
        self.stats = CacheStats()
        prime_counters()

    def _shard(self, key: bytes) -> OrderedDict:
        return self._shards[zlib.crc32(key) % self.n_shards]

    def __len__(self) -> int:
        return sum(len(d) for d in self._shards)

    def lookup(self, epoch: tuple, key: bytes):
        """Return `(elig, row)` for a live entry at `epoch`, else None."""
        self.stats.lookups += 1
        _LOOKUPS.inc()
        d = self._shard(key)
        ent = d.get(key)
        if ent is None:
            self.stats.misses += 1
            _MISSES.inc()
            return None
        e_epoch, born, elig, row = ent
        if e_epoch != epoch:
            del d[key]
            self.stats.invalidations += 1
            _EVICT.inc(reason="epoch")
            self.stats.misses += 1
            _MISSES.inc()
            return None
        if self.ttl_s is not None and self._clock() - born > self.ttl_s:
            del d[key]
            self.stats.expirations += 1
            _EVICT.inc(reason="ttl")
            self.stats.misses += 1
            _MISSES.inc()
            return None
        d.move_to_end(key)
        self.stats.hits += 1
        _HITS.inc()
        return elig, row

    def insert(self, epoch: tuple, key: bytes, elig: bool,
               row: np.ndarray) -> None:
        d = self._shard(key)
        d[key] = (epoch, self._clock(), bool(elig),
                  np.array(row, copy=True))
        d.move_to_end(key)
        self.stats.insertions += 1
        while len(d) > self._per_shard:
            d.popitem(last=False)
            self.stats.evictions += 1
            _EVICT.inc(reason="lru")

    def invalidate_below(self, generation: int, corpus_version: int) -> int:
        """Eagerly drop entries older than the fleet's new target epoch —
        called when a rollout completes, so superseded results free memory
        immediately instead of lingering until LRU pressure finds them."""
        dropped = 0
        for d in self._shards:
            stale = [k for k, (e, *_rest) in d.items()
                     if e[0] < generation or e[1] < corpus_version]
            for k in stale:
                del d[k]
            dropped += len(stale)
        if dropped:
            self.stats.invalidations += dropped
            _EVICT.inc(dropped, reason="epoch")
        return dropped

    def clear(self) -> None:
        for d in self._shards:
            d.clear()

    def snapshot(self) -> dict:
        return {"size": len(self), "capacity": self.capacity,
                "ttl_s": self.ttl_s, "n_shards": self.n_shards,
                **self.stats.to_dict()}


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Overload admission for the front-end queue model (cluster.loadgen).

    `queue_bound_ms`: an arriving query whose chosen replicas' worst queue
    backlog exceeds this is not admitted to that tier — eligible queries
    demote to the Tier-2 scatter; Tier-2-bound queries shed to a degraded
    immediate answer priced at `t_fixed` only (no postings scanned).
    `deadline_ms`: same treatment when the predicted completion (queue wait
    + base service, stragglers unknowable at dispatch) would land past the
    deadline.
    """
    queue_bound_ms: float | None = None
    deadline_ms: float | None = None

    @classmethod
    def parse(cls, spec: str) -> "AdmissionPolicy":
        """Parse a `QUEUE_MS[,DEADLINE_MS]` CLI spec ('-' skips a bound)."""
        parts = [p.strip() for p in spec.split(",")]
        if not 1 <= len(parts) <= 2:
            raise ValueError(
                f"admission spec must be QUEUE_MS[,DEADLINE_MS], got {spec!r}")
        vals = [None if p in ("", "-") else float(p) for p in parts]
        vals += [None] * (2 - len(vals))
        return cls(queue_bound_ms=vals[0], deadline_ms=vals[1])

    @property
    def active(self) -> bool:
        return self.queue_bound_ms is not None or self.deadline_ms is not None


def zipf_keys(n: int, n_keys: int, skew: float, seed: int = 0) -> np.ndarray:
    """A seeded rank-skewed key stream: P(rank k) ∝ 1/k^skew over `n_keys`
    distinct keys. `skew=0` is uniform; ~1.0+ is web-like repeat traffic.
    Drives both the loadgen cache model and the real-fleet replay bench."""
    if n_keys < 1:
        raise ValueError(f"n_keys must be >= 1, got {n_keys}")
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = ranks ** -float(skew)
    p /= p.sum()
    rng = np.random.default_rng(seed)
    return rng.choice(n_keys, size=n, p=p).astype(np.int64)


def keys_of(queries: list[tuple[int, ...]]) -> np.ndarray:
    """Map each query to a stable small-int key by token SET (first-seen
    order) — the loadgen-side stand-in for the packed-bitset cache key,
    which is likewise insensitive to token order and duplicates."""
    ids: dict[frozenset, int] = {}
    out = np.empty(len(queries), np.int64)
    for i, q in enumerate(queries):
        out[i] = ids.setdefault(frozenset(q), len(ids))
    return out
