"""Scatter-gather routing over a sharded, replicated two-tier fleet.

Per batch, the `ClusterRouter`:

  1. picks the newest COMPLETE generation (every shard with a non-empty
     local D₁ has a live, non-draining Tier-1 replica at that generation's
     content, AND every shard has a Tier-2 replica at that generation's
     corpus version);
  2. runs ψ^clause ONCE for the whole batch through the packed
     clause-subset-test kernel (`kernels.ops.clause_match`) with that
     generation's clause set;
  3. scatters eligible queries to one Tier-1 replica per (non-empty) shard
     and the rest to one Tier-2 replica per shard, round-robin within each
     replica group — replicas are picked by CONTENT, so a batch is served
     entirely at one corpus version;
  4. gathers by OR-merging the per-shard packed match bitsets — shards own
     disjoint word ranges, so the merge is a word-slice placement and the
     result is bit-identical to single-tier matching at that version.

The (ψ, Tier-1, Tier-2) pairing invariant: classification and both serving
tiers always use the SAME generation's contents, per batch, by construction —
`BatchTrace` records all three (plus the corpus version) so tests can assert
no window ever observed a mixed triple. If a rolling swap leaves no complete
generation (single-replica groups mid-swap), the whole batch is served from
the newest corpus version with full Tier-2 cover, which is exact for any
query at that version.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.cluster import frontend
from repro.cluster import shard as shard_mod
from repro.cluster.rollout import (ClusterTieringBuffer, RollingSwap,
                                   StaleCorpusError)
from repro.core import bitset
from repro.core.tiering import ClauseTiering
from repro.serve import matching
from repro.serve.engine import ServeStats

# BatchTrace history kept per router; a long run_stream/run_ingest session
# retains this many batches (explicit capacity=None restores full history
# for the parity tests that audit every batch ever served)
DEFAULT_TRACE_CAPACITY = 4096

# per-(tier, shard) word-traffic attribution for the whole fleet
_CWORDS = obs.counter("cluster_words_total",
                      "postings words scanned across the fleet",
                      labels=("tier", "shard"))
_CQUERIES = obs.counter("cluster_queries_total",
                        "queries served through the cluster router")
_FALLBACK = obs.counter("cluster_fallback_batches_total",
                        "batches served full-Tier-2 (no complete generation)")


class ShardReplica:
    """One serving unit: a (tier, shard) sub-index plus its own counters.

    `content` identifies the sub-index BITS the replica holds (see
    `ClusterTieringBuffer.shard_content` / `t2_content`); `generation` is
    the newest generation it has acknowledged. The two differ exactly when
    a rollout carried the replica's content forward (its shard didn't
    change), which is what lets per-shard generations roll independently.
    """

    def __init__(self, tier: int, shard: shard_mod.DocShard,
                 postings, words_per_query: int, generation: int = 0,
                 content: int = 0):
        self.tier = tier
        self.shard = shard
        self.postings = jnp.asarray(postings)
        self.words_per_query = words_per_query
        self.generation = generation
        self.content = content
        self.draining = False
        self.n_batches = 0
        self.n_queries = 0
        self.words_scanned = 0
        self.n_installs = 0          # real sub-index installs (not carries)

    def commit(self, postings, words_per_query: int, generation: int,
               content: int | None = None, shard=None) -> None:
        """Install a new generation and rejoin the rotation (rollout phase 2).

        When `content` matches what the replica already holds, the commit is
        metadata-only: no device buffer moves (a carried shard costs
        nothing). `shard` updates the replica's DocShard when a corpus
        append grew its word range (repro.ingest grow mode).
        """
        if content is None or content != self.content:
            self.postings = jnp.asarray(postings)
            self.n_installs += 1
        self.words_per_query = words_per_query
        self.generation = generation
        if content is not None:
            self.content = content
        if shard is not None:
            self.shard = shard
        self.draining = False

    def match(self, tokens: jnp.ndarray) -> np.ndarray:
        """AND-match a padded token batch against the local sub-index."""
        self.account(int(tokens.shape[0]))
        return np.asarray(matching.match_batch(self.postings, tokens))

    def account(self, n_queries: int) -> None:
        """Batch bookkeeping without a local match — the fused mesh path
        serves from the SAME resident content this replica holds, so the
        replica this batch rotated onto still carries the counters."""
        self.n_batches += 1
        self.n_queries += n_queries
        self.words_scanned += n_queries * self.words_per_query

    def __repr__(self) -> str:  # debugging/observability
        return (f"ShardReplica(t{self.tier} s{self.shard.index} "
                f"gen={self.generation} c{self.content}"
                f"{' draining' if self.draining else ''})")


@dataclasses.dataclass(frozen=True)
class BatchTrace:
    """What one batch observed: the ψ generation it was classified with, the
    corpus version it was served at, and per served shard the CONTENT each
    replica held vs the content that generation prescribes — for BOTH
    tiers, so a mixed (ψ, Tier-1, Tier-2) triple is disprovable per batch."""
    psi_generation: int          # -1 = Tier-2 fallback (no ψ consulted)
    t1_generations: tuple[int, ...]
    n_tier1: int
    n_tier2: int
    t1_shards: tuple[int, ...] = ()         # shard index per Tier-1 server
    t1_contents: tuple[int, ...] = ()       # content each server held
    expected_contents: tuple[int, ...] = ()  # ψ generation's per-shard content
    corpus_version: int = 0                 # version the batch was served at
    t2_contents: tuple[int, ...] = ()       # Tier-2 content each server held
    expected_t2_contents: tuple[int, ...] = ()  # version's per-shard slices
    n_cached: int = 0    # front-end result-cache hits (n_tier1/n_tier2 count
    #                      only the fresh dispatches this batch paid for)

    @property
    def consistent(self) -> bool:
        """No mixed (ψ, Tier-1, Tier-2) triple, PER SHARD: every server held
        exactly the sub-index content the served generation prescribes for
        its shard and tier (generation numbers may differ across shards
        mid-roll — only content equality is what Theorem 3.1 needs)."""
        if self.t2_contents != self.expected_t2_contents:
            return False
        if self.t1_contents or self.expected_contents:
            return self.t1_contents == self.expected_contents
        return all(g == self.psi_generation for g in self.t1_generations)


class ClusterRouter:
    def __init__(self, shards: list[shard_mod.DocShard],
                 t1_groups: list[list[ShardReplica]],
                 t2_groups: list[list[ShardReplica]],
                 buffer0: ClusterTieringBuffer, n_docs: int, *,
                 trace_capacity: int | None = DEFAULT_TRACE_CAPACITY,
                 cache: frontend.ResultCache | None = None):
        self.shards = shards            # current target plan (grows in place)
        self.t1 = t1_groups
        self.t2 = t2_groups
        self.cache = cache
        frontend.prime_counters()       # export zeroed series cache or not
        self.n_docs = n_docs
        self._buffers: dict[int, ClusterTieringBuffer] = {
            buffer0.generation: buffer0}
        self.rollout: RollingSwap | None = None
        self._rr: dict[tuple[int, int], int] = {}
        self._mesh_tables: dict = {}     # fused-serve operands per generation
        self.trace: obs.Ring = obs.Ring(trace_capacity)
        self.stats = ServeStats(
            full_words_per_query=buffer0.w_total
            or sum(s.n_words for s in shards))

    # -- generations ----------------------------------------------------------
    @property
    def target_generation(self) -> int:
        return max(self._buffers)

    @property
    def target_tiering(self) -> ClauseTiering:
        return self._buffers[self.target_generation].tiering

    def live_generations(self) -> set[int]:
        return {r.generation for group in self.t1 for r in group}

    def _t2_covered(self, buf: ClusterTieringBuffer, *,
                    allow_draining: bool) -> bool:
        """Every shard has a Tier-2 replica at the buffer's corpus version.

        `allow_draining=True` is the fallback relaxation: a draining replica
        still physically holds its slice (drain only quiesces new batches
        ahead of an install), so reading it keeps the batch exact."""
        if not buf.t2_content:
            return True                  # legacy hand-built buffer: unversioned
        return all(any(r.content == buf.t2_content[s.index]
                       and (allow_draining or not r.draining)
                       for r in self.t2[s.index])
                   for s in (buf.shards or self.shards))

    def complete_generations(self) -> list[int]:
        """Generations servable end to end, oldest first: a routable Tier-1
        replica on every shard whose local D₁ is non-empty under that
        generation, AND full Tier-2 cover at that generation's corpus
        version.

        Routable means holding the generation's CONTENT for that shard — a
        replica whose shard was carried across generations serves both, so
        scoped rollouts never open a fallback gap on untouched shards."""
        out = []
        for g, buf in sorted(self._buffers.items()):
            t1_ok = all(not buf.shard_nonempty(s.index)
                        or any(r.content == buf.shard_content[s.index]
                               and not r.draining
                               for r in self.t1[s.index])
                        for s in (buf.shards or self.shards))
            if t1_ok and self._t2_covered(buf, allow_draining=False):
                out.append(g)
        return out

    def _fallback_buffer(self) -> ClusterTieringBuffer:
        """Newest corpus snapshot with full (possibly draining) Tier-2 cover
        — the version the mid-rollout gap serves entirely from Tier 2."""
        for g in sorted(self._buffers, reverse=True):
            if self._t2_covered(self._buffers[g], allow_draining=True):
                return self._buffers[g]
        raise RuntimeError(            # unreachable: rollouts keep old buffers
            "no live corpus version has full Tier-2 cover")

    # -- rolling swaps --------------------------------------------------------
    def begin_rollout(self, buffer: ClusterTieringBuffer) -> None:
        cur = self._buffers[self.target_generation]
        if buffer.corpus_version < cur.corpus_version:
            raise StaleCorpusError(
                f"rollout buffer was prepared at corpus version "
                f"{buffer.corpus_version} but the fleet has rolled to "
                f"{cur.corpus_version}; rebuild it from the appended data "
                "(prepare_tiering after the corpus swap)")
        if self.rollout is not None:        # supersede: finish the old roll
            self.rollout.run_to_completion()
        self._buffers[buffer.generation] = buffer
        self.rollout = RollingSwap(buffer, self.t1, self.t2)

    def advance_rollout(self, steps: int = 1) -> None:
        if self.rollout is None:
            return
        for _ in range(steps):
            self.rollout.step()
        if self.rollout.done:
            self.rollout = None
            self._prune_buffers()

    def _prune_buffers(self) -> None:
        keep = self.live_generations() | {self.target_generation}
        self._buffers = {g: b for g, b in self._buffers.items() if g in keep}
        if self.cache is not None:
            # epoch bump: results computed under a now-dead generation or
            # corpus version can never be served again — free them eagerly
            # instead of waiting for LRU pressure (lookup() would reject
            # them anyway, so this is memory hygiene, not correctness)
            self.cache.invalidate_below(
                min(self._buffers),
                min(b.corpus_version for b in self._buffers.values()))

    # -- routing --------------------------------------------------------------
    def _pick(self, group: list[ShardReplica], tier: int, shard_idx: int,
              content: int | None = None,
              draining_ok: bool = False) -> ShardReplica:
        ready = [r for r in group if (draining_ok or not r.draining)
                 and (content is None or r.content == content)]
        key = (tier, shard_idx)
        i = self._rr.get(key, 0)
        self._rr[key] = i + 1
        return ready[i % len(ready)]

    def classify(self, queries: list[tuple[int, ...]],
                 generation: int | None = None) -> np.ndarray:
        buf = self._buffers[self.target_generation if generation is None
                            else generation]
        return matching.classify_batch(
            buf.tiering.clause_vocab_bits, queries, buf.tiering.vocab_size)

    def serve(self, queries: list[tuple[int, ...]]) -> list[np.ndarray]:
        """Exact global match sets (sorted doc ids) per query, at the served
        buffer's corpus version.

        Two dispatch layouts, bit-identical by construction and pinned by
        tests/test_mesh.py: one host `match_batch` call per shard (the
        default), or — when the ambient `ExecutionPlan` carries a multi-
        device `"shard"` axis — ONE fused shard_map program per batch
        (`cluster.mesh_serve`: replicated ψ classify, owner-local AND-match
        on the resident slices, psum OR-merge).
        """
        self.advance_rollout()              # one drain-or-swap phase per batch
        b = len(queries)
        if b == 0:
            return []
        complete = self.complete_generations()
        if complete:
            gen = complete[-1]              # newest fully-covered generation
            buf, use_t1 = self._buffers[gen], True
        else:                               # mid-rollout gap: Tier 2 is exact
            gen, buf, use_t1 = -1, self._fallback_buffer(), False
            _FALLBACK.inc()
            obs.event("t2_fallback", corpus_version=buf.corpus_version,
                      n_queries=b)
        if buf.w_total and self.stats.full_words_per_query != buf.w_total:
            # corpus grew (or the served version moved): the saving
            # denominator follows the version this batch is served at
            self.stats.full_words_per_query = buf.w_total
        from repro import distributed
        plan = distributed.current_plan()
        cache = self.cache
        with obs.span("serve", n=b, generation=gen,
                      corpus_version=buf.corpus_version,
                      fused=bool(plan.shard_fused)):
            # -- front-end result cache: after classify-key, before tier
            # match, so the host and fused mesh paths share it. The key is
            # the packed query vocab bitset (the ψ^clause operand): equal
            # keys => equal token sets => bit-identical match sets at one
            # epoch, and the epoch pins (generation, corpus version, tier
            # path) so rolling swaps invalidate by construction.
            keys = epoch = None
            hits: list[tuple[int, tuple]] = []
            miss_idx = np.arange(b)
            if cache is not None:
                epoch = (buf.generation, buf.corpus_version, use_t1)
                with obs.span("frontend", n=b):
                    qbits = np.asarray(matching.pack_query_bits(
                        queries, buf.tiering.vocab_size))
                    keys = [qbits[j].tobytes() for j in range(b)]
                    miss = []
                    for j, k in enumerate(keys):
                        ent = cache.lookup(epoch, k)
                        if ent is None:
                            miss.append(j)
                        else:
                            hits.append((j, ent))
                    miss_idx = np.asarray(miss, int)
            if len(miss_idx) == b:          # no cache, or every query missed
                if plan.shard_fused:
                    out, elig = self._match_mesh(queries, buf, use_t1, plan)
                else:
                    out, elig = self._match_host(queries, buf, use_t1)
                m_out, m_elig = out, elig
            else:
                w_total = buf.w_total or self.stats.full_words_per_query
                out = np.zeros((b, w_total), np.uint32)
                elig = np.zeros(b, bool)
                m_out = np.zeros((0, w_total), np.uint32)
                m_elig = np.zeros(0, bool)
                if len(miss_idx):           # fresh-match only the misses
                    sub = [queries[j] for j in miss_idx]
                    if plan.shard_fused:
                        m_out, m_elig = self._match_mesh(sub, buf, use_t1,
                                                         plan)
                    else:
                        m_out, m_elig = self._match_host(sub, buf, use_t1)
                    out[miss_idx] = m_out
                    elig[miss_idx] = m_elig
                for j, (e, row) in hits:    # hits cost zero postings words
                    out[j] = row
                    elig[j] = e
            if cache is not None and len(miss_idx):
                for pos, j in enumerate(miss_idx):
                    cache.insert(epoch, keys[j], bool(m_elig[pos]),
                                 m_out[pos])
            self._account(buf, gen, m_elig, use_t1, n_cached=len(hits))
            if hits:
                self.stats.cache_hits += len(hits)
                # hits keep the traffic-mix metric (tier1_fraction) equal to
                # a cache-off run: the stored elig bit says which tier the
                # query BELONGS to, even though no replica was dispatched
                self.stats.n_tier1 += sum(1 for _, (e, _r) in hits if e)
            self.stats.n_queries += b
            _CQUERIES.inc(b)
            with obs.span("merge", n=b):
                return [bitset.np_to_indices(row, buf.n_docs or self.n_docs)
                        for row in out]

    def _match_host(self, queries, buf, use_t1
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Sequential per-shard host dispatch; returns (words [B, W], elig)."""
        b = len(queries)
        shards = buf.shards or self.shards
        out = np.zeros((b, buf.w_total or self.stats.full_words_per_query),
                       np.uint32)
        if use_t1:
            with obs.span("classify", n=b):
                elig = matching.classify_batch(
                    buf.tiering.clause_vocab_bits, queries,
                    buf.tiering.vocab_size)
        else:
            elig = np.zeros(b, bool)
        toks = matching.pad_token_batch(queries)
        idx1 = np.nonzero(elig)[0]
        if len(idx1):
            sub = jnp.asarray(toks[idx1])
            with obs.span("t1_match", n=int(len(idx1))) as sp:
                for s in shards:
                    if not buf.shard_nonempty(s.index):
                        continue            # D₁ misses this shard: no matches
                    rep = self._served(1, s.index, buf)
                    out[idx1, s.word_lo:s.word_hi] = sp.sync(rep.match(sub))
        idx2 = np.nonzero(~elig)[0]
        if len(idx2):
            sub = jnp.asarray(toks[idx2])
            with obs.span("t2_match", n=int(len(idx2))) as sp:
                for s in shards:
                    rep = self._served(2, s.index, buf,
                                       draining_ok=not use_t1)
                    out[idx2, s.word_lo:s.word_hi] = sp.sync(rep.match(sub))
        return out, np.asarray(elig, bool)

    def _match_mesh(self, queries, buf, use_t1, plan
                    ) -> tuple[np.ndarray, np.ndarray]:
        """One fused shard_map program for the whole batch; the replica this
        batch rotates onto still pays the (virtual) scan accounting, so
        observability matches the host path exactly."""
        from repro.cluster import mesh_serve
        # generation identifies the ψ clause set: two generations can share
        # every shard's Tier-1 CONTENT (doc sets equal, clauses not), so
        # contents alone would serve a stale clause_bits table; the corpus
        # version + t2 contents invalidate the table across appends
        key = (buf.generation, buf.corpus_version, buf.shard_content,
               buf.t2_content, use_t1, plan.mesh,
               len(buf.shards or self.shards))
        table = self._mesh_tables.get(key)
        if table is None:
            table = mesh_serve.build_table(buf, plan.n_shard_devices,
                                           use_t1=use_t1)
            if len(self._mesh_tables) > 8:
                self._mesh_tables.clear()
            self._mesh_tables[key] = table
        # ONE shard_map program: classify/match/merge fuse on-device, so the
        # fused path gets a single span instead of the host path's nest
        with obs.span("mesh_fused", n=len(queries)) as sp:
            out, elig = mesh_serve.serve_fused(table, queries, plan)
            sp.sync(out)
        n1 = int(elig.sum())
        for s in (buf.shards or self.shards):
            if n1 and use_t1 and buf.shard_nonempty(s.index):
                self._served(1, s.index, buf).account(n1)
            if n1 < len(queries):
                self._served(2, s.index, buf,
                             draining_ok=not use_t1).account(len(queries) - n1)
        return out, elig

    def _served(self, tier: int, shard_idx: int, buf,
                draining_ok: bool = False) -> ShardReplica:
        """Rotate the replica group and return the serving replica — picked
        by the BUFFER's content for that tier/shard, so every server this
        batch touches holds the same corpus version."""
        if tier == 1:
            return self._pick(self.t1[shard_idx], 1, shard_idx,
                              content=buf.shard_content[shard_idx])
        want = buf.t2_content[shard_idx] if buf.t2_content else None
        return self._pick(self.t2[shard_idx], 2, shard_idx, content=want,
                          draining_ok=draining_ok)

    def _account(self, buf, gen: int, elig: np.ndarray, use_t1: bool,
                 n_cached: int = 0) -> None:
        """Stats + BatchTrace from the replicas this batch was served by (or
        accounted against, on the fused path) — `_rr` already rotated, so
        `_pick` with a rewound rotation would misattribute; instead the
        counters were updated inside the match helpers and the trace reads
        the groups' current content directly."""
        n1 = int(elig.sum())
        n2 = len(elig) - n1
        shards = buf.shards or self.shards
        t1_gens, t1_shards, t1_contents, expected = [], [], [], []
        t2_contents, expected_t2 = [], []
        if n1:
            for s in shards:
                if not buf.shard_nonempty(s.index):
                    continue
                want = buf.shard_content[s.index]
                rep = next(r for r in self.t1[s.index]
                           if not r.draining and r.content == want)
                t1_gens.append(rep.generation)
                t1_shards.append(s.index)
                t1_contents.append(rep.content)
                expected.append(want)
                self.stats.tier1_words += n1 * rep.words_per_query
                _CWORDS.inc(n1 * rep.words_per_query, tier="t1",
                            shard=s.index)
            self.stats.n_tier1 += n1
        if n2:
            for s in shards:
                want = buf.t2_content[s.index] if buf.t2_content else None
                rep = next(r for r in self.t2[s.index]
                           if (want is None or r.content == want)
                           and (not use_t1 or not r.draining))
                self.stats.tier2_words += n2 * rep.words_per_query
                _CWORDS.inc(n2 * rep.words_per_query, tier="t2",
                            shard=s.index)
                t2_contents.append(rep.content)
                expected_t2.append(want if want is not None else rep.content)
        self.trace.append(BatchTrace(
            psi_generation=gen, t1_generations=tuple(t1_gens),
            n_tier1=n1, n_tier2=n2,
            t1_shards=tuple(t1_shards), t1_contents=tuple(t1_contents),
            expected_contents=tuple(expected),
            corpus_version=buf.corpus_version,
            t2_contents=tuple(t2_contents),
            expected_t2_contents=tuple(expected_t2),
            n_cached=n_cached))


class TieredCluster:
    """Engine-compatible facade over the sharded, replicated fleet.

    Duck-types the `serve.TieredEngine` surface (`serve`, `classify`,
    `serve_reference`, `stats`, `tiering`, `generation`, `prepare_tiering`,
    `swap_tiering`, `swap_corpus`) so `stream.RetieringController` and the
    ingest loop drive a whole cluster exactly as they drive one engine —
    except swaps here start ROLLING rollouts that progress one replica phase
    per served batch.
    """

    def __init__(self, postings: np.ndarray, tiering: ClauseTiering,
                 n_docs: int, *, n_shards: int = 2, t1_replicas: int = 2,
                 t2_replicas: int = 1,
                 trace_capacity: int | None = DEFAULT_TRACE_CAPACITY,
                 cache: "bool | int | frontend.ResultCache | None" = None):
        if t1_replicas < 1 or t2_replicas < 1:
            raise ValueError("each replica group needs >= 1 replica")
        # front-end result cache (repro.cluster.frontend): False/None = off,
        # True = defaults, an int = capacity, or a configured ResultCache
        if cache is None or cache is False:
            cache_obj = None
        elif isinstance(cache, frontend.ResultCache):
            cache_obj = cache
        elif cache is True:
            cache_obj = frontend.ResultCache()
        else:
            cache_obj = frontend.ResultCache(capacity=int(cache))
        self.n_docs = n_docs
        self.corpus_version = 0
        self._postings_host = np.asarray(postings)
        self.postings_t2 = jnp.asarray(postings)          # oracle index
        self.shards, self._slices = shard_mod.shard_postings(
            self._postings_host, n_docs, n_shards)
        self._content_seq = 0
        self._t2_dev = [jnp.asarray(sl) for sl in self._slices]
        self._t2_content = tuple(self._next_content() for _ in self.shards)
        buf0 = self._build_buffer(tiering, generation=0)
        t1 = [[ShardReplica(1, s, buf0.shard_postings[s.index],
                            buf0.shard_words[s.index],
                            content=buf0.shard_content[s.index])
               for _ in range(t1_replicas)] for s in self.shards]
        t2 = [[ShardReplica(2, s, self._t2_dev[s.index], s.n_words,
                            content=self._t2_content[s.index])
               for _ in range(t2_replicas)] for s in self.shards]
        self.router = ClusterRouter(self.shards, t1, t2, buf0, n_docs,
                                    trace_capacity=trace_capacity,
                                    cache=cache_obj)

    def _next_content(self) -> int:
        self._content_seq += 1
        return self._content_seq

    def _shard_t1(self, tiering: ClauseTiering, s) -> np.ndarray:
        return np.asarray(tiering.tier1_docs[s.doc_lo:s.doc_lo + s.n_docs],
                          bool)

    def _build_buffer(self, tiering: ClauseTiering,
                      generation: int) -> ClusterTieringBuffer:
        """Per-shard sub-indexes + content ids, pinned to the CURRENT corpus
        snapshot. A shard whose local D₁ slice equals the live target's
        carries that content id forward (its replicas won't drain during
        the rollout); changed shards get fresh ids. So a shard-scoped
        re-tiering builds a buffer that only rolls the shards it touched."""
        if len(tiering.tier1_docs) != self.n_docs:
            raise StaleCorpusError(
                f"tiering was built for {len(tiering.tier1_docs)} docs but "
                f"the corpus is at version {self.corpus_version} with "
                f"{self.n_docs}; rebuild it from the appended data")
        prev = None
        if hasattr(self, "router"):
            prev = self.router._buffers[self.router.target_generation]
        posts, words, contents = [], [], []
        for s in self.shards:
            p, w = shard_mod.shard_tier_postings(
                self._slices[s.index], s, tiering.tier1_docs)
            posts.append(jnp.asarray(p))
            words.append(w)
            if prev is not None and np.array_equal(
                    self._shard_t1(tiering, s),
                    self._shard_t1(prev.tiering, s)):
                contents.append(prev.shard_content[s.index])
            else:
                contents.append(self._next_content())
        return ClusterTieringBuffer(
            tiering=tiering, shard_postings=posts, shard_words=words,
            generation=generation, shard_content=tuple(contents),
            corpus_version=self.corpus_version, shards=tuple(self.shards),
            t2_postings=tuple(self._t2_dev), t2_content=self._t2_content,
            n_docs=self.n_docs, w_total=int(self._postings_host.shape[1]))

    # -- engine-compatible surface -------------------------------------------
    @property
    def stats(self) -> ServeStats:
        return self.router.stats

    @property
    def cache(self) -> frontend.ResultCache | None:
        """The front-end result cache, when serving with one (see
        `repro.cluster.frontend.ResultCache`)."""
        return self.router.cache

    @property
    def tiering(self) -> ClauseTiering:
        return self.router.target_tiering

    @property
    def generation(self) -> int:
        return self.router.target_generation

    @property
    def tier1_words_per_query(self) -> int:
        buf = self.router._buffers[self.generation]
        return sum(buf.shard_words)

    def classify(self, queries: list[tuple[int, ...]]) -> np.ndarray:
        return self.router.classify(queries)

    def serve(self, queries: list[tuple[int, ...]]) -> list[np.ndarray]:
        return self.router.serve(queries)

    def serve_reference(self, queries: list[tuple[int, ...]], *,
                        generation: int | None = None,
                        corpus_version: int | None = None
                        ) -> list[np.ndarray]:
        """Single-tier, single-shard oracle for correctness tests.

        By default matches against the NEWEST corpus; pass `corpus_version=`
        (e.g. `trace[-1].corpus_version`) or `generation=` to reference a
        batch served mid-ingest-rollout at an older version — the oracle is
        then the concatenation of that buffer's pinned Tier-2 slices.
        """
        if generation is not None and corpus_version is not None:
            raise ValueError("pass generation= or corpus_version=, not both")
        postings, n_docs = self.postings_t2, self.n_docs
        if generation is not None or corpus_version is not None:
            bufs = self.router._buffers
            if generation is not None:
                buf = bufs[generation]
            else:
                cands = [b for b in bufs.values()
                         if b.corpus_version == corpus_version]
                if not cands:
                    raise KeyError(
                        f"no live buffer at corpus version {corpus_version}; "
                        f"live: {sorted({b.corpus_version for b in bufs.values()})}")
                buf = max(cands, key=lambda b: b.generation)
            postings = buf.t2_postings[0] if len(buf.t2_postings) == 1 \
                else jnp.concatenate(buf.t2_postings, axis=1)
            n_docs = buf.n_docs
        toks = matching.pad_token_batch(queries)
        m = np.asarray(matching.match_batch(postings, jnp.asarray(toks)))
        return [bitset.np_to_indices(r, n_docs) for r in m]

    def prepare_tiering(self, tiering: ClauseTiering) -> ClusterTieringBuffer:
        """Build every shard's next Tier-1 sub-index OFF the request path."""
        return self._build_buffer(tiering, generation=0)

    def swap_tiering(self, tiering: ClauseTiering | ClusterTieringBuffer,
                     *, immediate: bool = False) -> int:
        """Start a rolling swap to a new tiering; returns its generation.

        The rollout advances one drain/swap phase per served batch; pass
        `immediate=True` (or call `drain_rollout`) to complete it with no
        traffic in between. Serving stays exact throughout either way.
        Raises `StaleCorpusError` for a tiering or prepared buffer built
        against an older corpus version than the fleet's.
        """
        buf = tiering if isinstance(tiering, ClusterTieringBuffer) \
            else self.prepare_tiering(tiering)
        buf = dataclasses.replace(
            buf, generation=self.router.target_generation + 1)
        self.router.begin_rollout(buf)
        if immediate:
            self.drain_rollout()
        return buf.generation

    def swap_corpus(self, postings: np.ndarray, n_docs: int,
                    tiering: ClauseTiering, *,
                    immediate: bool = False) -> int:
        """Roll the fleet to an appended corpus snapshot (repro.ingest).

        Grow mode: the shard plan keeps every existing word range and the
        LAST shard absorbs the appended words (`shard.grow_shards`), so
        untouched Tier-2 slices — bit-identical by the append-only layout —
        carry their content ids and never drain. The new tiering (rebuilt
        against the appended data, e.g. after mandatory/secretary admission)
        rides the same rollout, so ψ, Tier-1 and Tier-2 arrive as one
        generation. `immediate=True` is the stop-the-world rebuild: the
        whole fleet jumps versions with no traffic in between — the
        comparator arm for the rolling path's parity tests and benchmarks.
        """
        postings = np.asarray(postings)
        if n_docs < self.n_docs or \
                postings.shape[1] < self._postings_host.shape[1]:
            raise ValueError(
                f"corpus swaps are append-only: got {n_docs} docs x "
                f"{postings.shape[1]} words, have {self.n_docs} x "
                f"{self._postings_host.shape[1]}")
        old_shards = self.shards
        new_shards = shard_mod.grow_shards(old_shards, n_docs)
        new_slices = [postings[:, s.word_lo:s.word_hi] for s in new_shards]
        contents, dev = [], []
        for s, old in zip(new_shards, old_shards):
            if s == old:
                # append-only invariant: same word range => identical bits,
                # so the resident device slice is reused as-is
                contents.append(self._t2_content[s.index])
                dev.append(self._t2_dev[s.index])
            else:
                contents.append(self._next_content())
                dev.append(jnp.asarray(new_slices[s.index]))
        self._postings_host = postings
        self.postings_t2 = jnp.asarray(postings)
        self.shards = new_shards
        self._slices = new_slices
        self._t2_dev = dev
        self._t2_content = tuple(contents)
        self.n_docs = n_docs
        self.corpus_version += 1
        self.router.shards = new_shards
        self.router.n_docs = n_docs
        obs.event("corpus_swap", corpus_version=self.corpus_version,
                  n_docs=n_docs,
                  mode="immediate" if immediate else "rolling")
        return self.swap_tiering(tiering, immediate=immediate)

    def drain_rollout(self) -> None:
        """Finish any in-progress rollout without serving traffic."""
        while self.router.rollout is not None:
            self.router.advance_rollout()

    # -- observability --------------------------------------------------------
    @property
    def trace(self) -> obs.Ring:
        """Retained `BatchTrace` history (bounded ring; see
        `trace_capacity`). List-like: iterate, index, `len`, truthiness."""
        return self.router.trace

    def consistency_ok(self) -> bool:
        """True iff no served batch ever saw a mixed (ψ, Tier-1, Tier-2)
        triple."""
        return all(t.consistent for t in self.router.trace)

    def describe(self) -> str:
        t1n = sum(len(g) for g in self.router.t1)
        t2n = sum(len(g) for g in self.router.t2)
        return (f"{len(self.shards)} shards x ({t1n} t1 + {t2n} t2 replicas)"
                f"  gen={self.generation}  v{self.corpus_version}"
                f"  live={sorted(self.router.live_generations())}")
