"""Scatter-gather routing over a sharded, replicated two-tier fleet.

Per batch, the `ClusterRouter`:

  1. picks the newest COMPLETE Tier-1 generation (every shard with a
     non-empty local D₁ has a live, non-draining replica at that generation);
  2. runs ψ^clause ONCE for the whole batch through the packed
     clause-subset-test kernel (`kernels.ops.clause_match`) with that
     generation's clause set;
  3. scatters eligible queries to one Tier-1 replica per (non-empty) shard
     and the rest to one Tier-2 replica per shard, round-robin within each
     replica group;
  4. gathers by OR-merging the per-shard packed match bitsets — shards own
     disjoint word ranges, so the merge is a word-slice placement and the
     result is bit-identical to single-tier matching.

The (ψ, Tier-1) pairing invariant: classification and Tier-1 serving always
use the SAME generation, per batch, by construction — `BatchTrace` records
both so tests can assert no window ever observed a mixed pair. If a rolling
swap leaves no complete generation (single-replica groups mid-swap), the
whole batch is served from Tier 2, which is exact for any query.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.cluster import shard as shard_mod
from repro.cluster.rollout import ClusterTieringBuffer, RollingSwap
from repro.core import bitset
from repro.core.tiering import ClauseTiering
from repro.serve import matching
from repro.serve.engine import ServeStats


class ShardReplica:
    """One serving unit: a (tier, shard) sub-index plus its own counters.

    `content` identifies the sub-index BITS the replica holds (see
    `ClusterTieringBuffer.shard_content`); `generation` is the newest
    generation it has acknowledged. The two differ exactly when a rollout
    carried the replica's content forward (its shard didn't change), which
    is what lets per-shard generations roll independently.
    """

    def __init__(self, tier: int, shard: shard_mod.DocShard,
                 postings, words_per_query: int, generation: int = 0,
                 content: int = 0):
        self.tier = tier
        self.shard = shard
        self.postings = jnp.asarray(postings)
        self.words_per_query = words_per_query
        self.generation = generation
        self.content = content
        self.draining = False
        self.n_batches = 0
        self.n_queries = 0
        self.words_scanned = 0
        self.n_installs = 0          # real sub-index installs (not carries)

    def commit(self, postings, words_per_query: int, generation: int,
               content: int | None = None) -> None:
        """Install a new generation and rejoin the rotation (rollout phase 2).

        When `content` matches what the replica already holds, the commit is
        metadata-only: no device buffer moves (a carried shard costs nothing).
        """
        if content is None or content != self.content:
            self.postings = jnp.asarray(postings)
            self.n_installs += 1
        self.words_per_query = words_per_query
        self.generation = generation
        if content is not None:
            self.content = content
        self.draining = False

    def match(self, tokens: jnp.ndarray) -> np.ndarray:
        """AND-match a padded token batch against the local sub-index."""
        self.account(int(tokens.shape[0]))
        return np.asarray(matching.match_batch(self.postings, tokens))

    def account(self, n_queries: int) -> None:
        """Batch bookkeeping without a local match — the fused mesh path
        serves from the SAME resident content this replica holds, so the
        replica this batch rotated onto still carries the counters."""
        self.n_batches += 1
        self.n_queries += n_queries
        self.words_scanned += n_queries * self.words_per_query

    def __repr__(self) -> str:  # debugging/observability
        return (f"ShardReplica(t{self.tier} s{self.shard.index} "
                f"gen={self.generation} c{self.content}"
                f"{' draining' if self.draining else ''})")


@dataclasses.dataclass(frozen=True)
class BatchTrace:
    """What one batch observed: the ψ generation it was classified with and,
    per served shard, the CONTENT each Tier-1 replica held vs the content
    that ψ's generation prescribes for that shard."""
    psi_generation: int          # -1 = Tier-2 fallback (no ψ consulted)
    t1_generations: tuple[int, ...]
    n_tier1: int
    n_tier2: int
    t1_shards: tuple[int, ...] = ()         # shard index per Tier-1 server
    t1_contents: tuple[int, ...] = ()       # content each server held
    expected_contents: tuple[int, ...] = ()  # ψ generation's per-shard content

    @property
    def consistent(self) -> bool:
        """No mixed (ψ, Tier-1) pair, PER SHARD: every Tier-1 server held
        exactly the sub-index content the ψ generation prescribes for its
        shard (generation numbers may differ across shards mid-roll — only
        content equality is what Theorem 3.1 needs)."""
        if self.t1_contents or self.expected_contents:
            return self.t1_contents == self.expected_contents
        return all(g == self.psi_generation for g in self.t1_generations)


class ClusterRouter:
    def __init__(self, shards: list[shard_mod.DocShard],
                 t1_groups: list[list[ShardReplica]],
                 t2_groups: list[list[ShardReplica]],
                 buffer0: ClusterTieringBuffer, n_docs: int):
        self.shards = shards
        self.t1 = t1_groups
        self.t2 = t2_groups
        self.n_docs = n_docs
        self._buffers: dict[int, ClusterTieringBuffer] = {
            buffer0.generation: buffer0}
        self.rollout: RollingSwap | None = None
        self._rr: dict[tuple[int, int], int] = {}
        self._mesh_tables: dict = {}     # fused-serve operands per generation
        self.trace: list[BatchTrace] = []
        self.stats = ServeStats(
            full_words_per_query=sum(s.n_words for s in shards))

    # -- generations ----------------------------------------------------------
    @property
    def target_generation(self) -> int:
        return max(self._buffers)

    @property
    def target_tiering(self) -> ClauseTiering:
        return self._buffers[self.target_generation].tiering

    def live_generations(self) -> set[int]:
        return {r.generation for group in self.t1 for r in group}

    def complete_generations(self) -> list[int]:
        """Generations with a routable Tier-1 replica on every shard whose
        local D₁ is non-empty under that generation, oldest first.

        Routable means holding the generation's CONTENT for that shard — a
        replica whose shard was carried across generations serves both, so
        scoped rollouts never open a fallback gap on untouched shards."""
        out = []
        for g, buf in sorted(self._buffers.items()):
            if all(not buf.shard_nonempty(s.index)
                   or any(r.content == buf.shard_content[s.index]
                          and not r.draining
                          for r in self.t1[s.index])
                   for s in self.shards):
                out.append(g)
        return out

    # -- rolling swaps --------------------------------------------------------
    def begin_rollout(self, buffer: ClusterTieringBuffer) -> None:
        if self.rollout is not None:        # supersede: finish the old roll
            self.rollout.run_to_completion()
        self._buffers[buffer.generation] = buffer
        self.rollout = RollingSwap(buffer, self.t1)

    def advance_rollout(self, steps: int = 1) -> None:
        if self.rollout is None:
            return
        for _ in range(steps):
            self.rollout.step()
        if self.rollout.done:
            self.rollout = None
            self._prune_buffers()

    def _prune_buffers(self) -> None:
        keep = self.live_generations() | {self.target_generation}
        self._buffers = {g: b for g, b in self._buffers.items() if g in keep}

    # -- routing --------------------------------------------------------------
    def _pick(self, group: list[ShardReplica], tier: int, shard_idx: int,
              content: int | None = None) -> ShardReplica:
        ready = [r for r in group if not r.draining
                 and (content is None or r.content == content)]
        key = (tier, shard_idx)
        i = self._rr.get(key, 0)
        self._rr[key] = i + 1
        return ready[i % len(ready)]

    def classify(self, queries: list[tuple[int, ...]],
                 generation: int | None = None) -> np.ndarray:
        buf = self._buffers[self.target_generation if generation is None
                            else generation]
        return matching.classify_batch(
            buf.tiering.clause_vocab_bits, queries, buf.tiering.vocab_size)

    def serve(self, queries: list[tuple[int, ...]]) -> list[np.ndarray]:
        """Exact global match sets (sorted doc ids) per query.

        Two dispatch layouts, bit-identical by construction and pinned by
        tests/test_mesh.py: one host `match_batch` call per shard (the
        default), or — when the ambient `ExecutionPlan` carries a multi-
        device `"shard"` axis — ONE fused shard_map program per batch
        (`cluster.mesh_serve`: replicated ψ classify, owner-local AND-match
        on the resident slices, psum OR-merge).
        """
        self.advance_rollout()              # one drain-or-swap phase per batch
        b = len(queries)
        if b == 0:
            return []
        complete = self.complete_generations()
        if complete:
            gen = complete[-1]              # newest fully-covered generation
            buf = self._buffers[gen]
        else:                               # mid-rollout gap: Tier 2 is exact
            gen, buf = -1, None
        from repro import distributed
        plan = distributed.current_plan()
        if plan.shard_fused:
            out, elig = self._match_mesh(queries, buf, plan)
        else:
            out, elig = self._match_host(queries, buf)
        self._account(buf, gen, elig)
        self.stats.n_queries += b
        return [bitset.np_to_indices(row, self.n_docs) for row in out]

    def _match_host(self, queries, buf) -> tuple[np.ndarray, np.ndarray]:
        """Sequential per-shard host dispatch; returns (words [B, W], elig)."""
        b = len(queries)
        out = np.zeros((b, self.stats.full_words_per_query), np.uint32)
        if buf is not None:
            elig = matching.classify_batch(
                buf.tiering.clause_vocab_bits, queries,
                buf.tiering.vocab_size)
        else:
            elig = np.zeros(b, bool)
        toks = matching.pad_token_batch(queries)
        idx1 = np.nonzero(elig)[0]
        if len(idx1):
            sub = jnp.asarray(toks[idx1])
            for s in self.shards:
                if not buf.shard_nonempty(s.index):
                    continue                # D₁ misses this shard: no matches
                rep = self._served(1, s.index, buf)
                out[idx1, s.word_lo:s.word_hi] = rep.match(sub)
        idx2 = np.nonzero(~elig)[0]
        if len(idx2):
            sub = jnp.asarray(toks[idx2])
            for s in self.shards:
                out[idx2, s.word_lo:s.word_hi] = \
                    self._served(2, s.index, buf).match(sub)
        return out, np.asarray(elig, bool)

    def _match_mesh(self, queries, buf, plan) -> tuple[np.ndarray, np.ndarray]:
        """One fused shard_map program for the whole batch; the replica this
        batch rotates onto still pays the (virtual) scan accounting, so
        observability matches the host path exactly."""
        from repro.cluster import mesh_serve
        # generation identifies the ψ clause set: two generations can share
        # every shard's Tier-1 CONTENT (doc sets equal, clauses not), so
        # shard_content alone would serve a stale clause_bits table
        key = ((buf.generation, buf.shard_content) if buf is not None
               else None, plan.mesh, len(self.shards))
        table = self._mesh_tables.get(key)
        if table is None:
            table = mesh_serve.build_table(
                self.shards, [g[0].postings for g in self.t2], buf,
                self.stats.full_words_per_query,
                self._buffers[self.target_generation].tiering.vocab_size,
                plan.n_shard_devices)
            if len(self._mesh_tables) > 8:
                self._mesh_tables.clear()
            self._mesh_tables[key] = table
        out, elig = mesh_serve.serve_fused(table, queries, plan)
        n1 = int(elig.sum())
        for s in self.shards:
            if n1 and buf is not None and buf.shard_nonempty(s.index):
                self._served(1, s.index, buf).account(n1)
            if n1 < len(queries):
                self._served(2, s.index, buf).account(len(queries) - n1)
        return out, elig

    def _served(self, tier: int, shard_idx: int, buf) -> ShardReplica:
        """Rotate the replica group and return the serving replica."""
        if tier == 1:
            return self._pick(self.t1[shard_idx], 1, shard_idx,
                              content=buf.shard_content[shard_idx])
        return self._pick(self.t2[shard_idx], 2, shard_idx)

    def _account(self, buf, gen: int, elig: np.ndarray) -> None:
        """Stats + BatchTrace from the replicas this batch was served by (or
        accounted against, on the fused path) — `_rr` already rotated, so
        `_pick` with a rewound rotation would misattribute; instead the
        counters were updated inside the match helpers and the trace reads
        the groups' current content directly."""
        n1 = int(elig.sum())
        n2 = len(elig) - n1
        t1_gens, t1_shards, t1_contents, expected = [], [], [], []
        if n1:
            for s in self.shards:
                if not buf.shard_nonempty(s.index):
                    continue
                want = buf.shard_content[s.index]
                rep = next(r for r in self.t1[s.index]
                           if not r.draining and r.content == want)
                t1_gens.append(rep.generation)
                t1_shards.append(s.index)
                t1_contents.append(rep.content)
                expected.append(want)
                self.stats.tier1_words += n1 * rep.words_per_query
            self.stats.n_tier1 += n1
        if n2:
            for s in self.shards:
                self.stats.tier2_words += n2 * self.t2[s.index][0].words_per_query
        self.trace.append(BatchTrace(
            psi_generation=gen, t1_generations=tuple(t1_gens),
            n_tier1=n1, n_tier2=n2,
            t1_shards=tuple(t1_shards), t1_contents=tuple(t1_contents),
            expected_contents=tuple(expected)))


class TieredCluster:
    """Engine-compatible facade over the sharded, replicated fleet.

    Duck-types the `serve.TieredEngine` surface (`serve`, `classify`,
    `serve_reference`, `stats`, `tiering`, `generation`, `prepare_tiering`,
    `swap_tiering`) so `stream.RetieringController` drives a whole cluster
    exactly as it drives one engine — except `swap_tiering` here starts a
    ROLLING swap that progresses one replica phase per served batch.
    """

    def __init__(self, postings: np.ndarray, tiering: ClauseTiering,
                 n_docs: int, *, n_shards: int = 2, t1_replicas: int = 2,
                 t2_replicas: int = 1):
        if t1_replicas < 1 or t2_replicas < 1:
            raise ValueError("each replica group needs >= 1 replica")
        self.n_docs = n_docs
        self._postings_host = np.asarray(postings)
        self.postings_t2 = jnp.asarray(postings)          # oracle index
        self.shards, self._slices = shard_mod.shard_postings(
            self._postings_host, n_docs, n_shards)
        self._content_seq = 0
        buf0 = self._build_buffer(tiering, generation=0)
        t1 = [[ShardReplica(1, s, buf0.shard_postings[s.index],
                            buf0.shard_words[s.index],
                            content=buf0.shard_content[s.index])
               for _ in range(t1_replicas)] for s in self.shards]
        t2 = [[ShardReplica(2, s, self._slices[s.index], s.n_words)
               for _ in range(t2_replicas)] for s in self.shards]
        self.router = ClusterRouter(self.shards, t1, t2, buf0, n_docs)

    def _shard_t1(self, tiering: ClauseTiering, s) -> np.ndarray:
        return np.asarray(tiering.tier1_docs[s.doc_lo:s.doc_lo + s.n_docs],
                          bool)

    def _build_buffer(self, tiering: ClauseTiering,
                      generation: int) -> ClusterTieringBuffer:
        """Per-shard sub-indexes + content ids. A shard whose local D₁ slice
        equals the live target's carries that content id forward (its
        replicas won't drain during the rollout); changed shards get fresh
        ids. So a shard-scoped re-tiering builds a buffer that only rolls
        the shards it touched."""
        prev = None
        if hasattr(self, "router"):
            prev = self.router._buffers[self.router.target_generation]
        posts, words, contents = [], [], []
        for s in self.shards:
            p, w = shard_mod.shard_tier_postings(
                self._slices[s.index], s, tiering.tier1_docs)
            posts.append(jnp.asarray(p))
            words.append(w)
            if prev is not None and np.array_equal(
                    self._shard_t1(tiering, s),
                    self._shard_t1(prev.tiering, s)):
                contents.append(prev.shard_content[s.index])
            else:
                self._content_seq += 1
                contents.append(self._content_seq)
        return ClusterTieringBuffer(tiering=tiering, shard_postings=posts,
                                    shard_words=words, generation=generation,
                                    shard_content=tuple(contents))

    # -- engine-compatible surface -------------------------------------------
    @property
    def stats(self) -> ServeStats:
        return self.router.stats

    @property
    def tiering(self) -> ClauseTiering:
        return self.router.target_tiering

    @property
    def generation(self) -> int:
        return self.router.target_generation

    @property
    def tier1_words_per_query(self) -> int:
        buf = self.router._buffers[self.generation]
        return sum(buf.shard_words)

    def classify(self, queries: list[tuple[int, ...]]) -> np.ndarray:
        return self.router.classify(queries)

    def serve(self, queries: list[tuple[int, ...]]) -> list[np.ndarray]:
        return self.router.serve(queries)

    def serve_reference(self, queries: list[tuple[int, ...]]) -> list[np.ndarray]:
        """Single-tier, single-shard oracle for correctness tests."""
        toks = matching.pad_token_batch(queries)
        m = np.asarray(matching.match_batch(self.postings_t2,
                                            jnp.asarray(toks)))
        return [bitset.np_to_indices(r, self.n_docs) for r in m]

    def prepare_tiering(self, tiering: ClauseTiering) -> ClusterTieringBuffer:
        """Build every shard's next Tier-1 sub-index OFF the request path."""
        return self._build_buffer(tiering, generation=0)

    def swap_tiering(self, tiering: ClauseTiering | ClusterTieringBuffer,
                     *, immediate: bool = False) -> int:
        """Start a rolling swap to a new tiering; returns its generation.

        The rollout advances one drain/swap phase per served batch; pass
        `immediate=True` (or call `drain_rollout`) to complete it with no
        traffic in between. Serving stays exact throughout either way.
        """
        buf = tiering if isinstance(tiering, ClusterTieringBuffer) \
            else self.prepare_tiering(tiering)
        buf = dataclasses.replace(
            buf, generation=self.router.target_generation + 1)
        self.router.begin_rollout(buf)
        if immediate:
            self.drain_rollout()
        return buf.generation

    def drain_rollout(self) -> None:
        """Finish any in-progress rollout without serving traffic."""
        while self.router.rollout is not None:
            self.router.advance_rollout()

    # -- observability --------------------------------------------------------
    @property
    def trace(self) -> list[BatchTrace]:
        return self.router.trace

    def consistency_ok(self) -> bool:
        """True iff no served batch ever saw a mixed (ψ, Tier-1) pair."""
        return all(t.consistent for t in self.router.trace)

    def describe(self) -> str:
        t1n = sum(len(g) for g in self.router.t1)
        t2n = sum(len(g) for g in self.router.t2)
        return (f"{len(self.shards)} shards x ({t1n} t1 + {t2n} t2 replicas)"
                f"  gen={self.generation}"
                f"  live={sorted(self.router.live_generations())}")
