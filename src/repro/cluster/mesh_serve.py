"""Fused scatter-gather serving: one shard_map program per batch.

The host router issues one sequential dispatch per shard per batch; on a
mesh the shards ARE devices, so the whole serve path fuses into a single
SPMD program over the `"shard"` axis:

  1. replicated classify — every device runs the packed clause-subset-test
     kernel (`ops.clause_match`) on the full batch, so the ψ^clause decision
     needs no broadcast;
  2. scatter — each query's work lands on the devices that own its doc
     words: the device holds its shard's RESIDENT Tier-1 and Tier-2 postings
     slices and AND-matches the batch against the slice ψ prescribes per
     query (Tier-1 for eligible, Tier-2 for the rest — the same replica
     content the host router would pick);
  3. gather — shards own disjoint word ranges, so the OR-merge of per-shard
     match bitsets is ONE psum: every global word has exactly one owner,
     non-owners contribute zeros, and an integer sum of disjoint
     contributions IS the bitwise OR.

Bit-identity with the host path is by construction: the classify kernel, the
AND-reduce, and the word placement are the same ops on the same bits — only
the dispatch moves. Parity at every shard/replica count is pinned by
tests/test_mesh.py (replicas don't enter: replicas of a shard hold identical
content, which is exactly what lets the mesh hold one copy per shard).

Operands live in a `MeshRouteTable`: per-shard slices are zero-padded to the
widest shard and stacked leading-axis-sharded over `"shard"` (pad shards
write zeros into a scratch word range past the real index, so they never
touch owned words). Tables are built once per (generation content, CORPUS
VERSION, topology) — a corpus append invalidates by key, and the table's
Tier-2 slices come from the buffer's pinned snapshot rather than the live
replicas, so a mid-roll replica can never leak a mixed-version slice into
the fused path. Batch shapes are bucketed to powers of two so recompiles
stay rare.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import distributed
from repro.kernels import ops
from repro.serve import matching

ONES = 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class MeshRouteTable:
    """Device-resident operands of the fused serve program for ONE
    (ψ generation, fleet topology) pair. `S'` is the shard count padded to a
    multiple of the `"shard"` axis size; `wmax` the widest shard's words."""
    clause_bits: jnp.ndarray   # uint32 [K, Wv]  ψ clauses (replicated)
    t1: jnp.ndarray            # uint32 [S', V, wmax]  resident Tier-1 slices
    t2: jnp.ndarray            # uint32 [S', V, wmax]  resident Tier-2 slices
    off: jnp.ndarray           # int32 [S'] owned word_lo (pad rows: w_total)
    wid: jnp.ndarray           # int32 [S'] owned words (pad rows: 0)
    t1w: jnp.ndarray           # int32 [S'] compacted Tier-1 words (0: no D₁)
    w_total: int               # global packed match-set width
    wmax: int
    vocab_size: int


def build_table(buf, n_devices: int, *, use_t1: bool = True) -> MeshRouteTable:
    """Stack per-shard resident slices for the fused program.

    Every operand comes from ONE `ClusterTieringBuffer`: its Tier-1
    sub-indexes (the SAME bits a committed replica holds) and its pinned
    corpus snapshot — shard plan, Tier-2 slices, global width — so a table
    can never pair tiers from different corpus versions (repro.ingest).
    With `use_t1=False` (the mid-rollout gap, served entirely at the
    buffer's corpus version) the ψ clause set is empty and every query
    routes to the buffer's Tier-2 slices, still one fused dispatch.
    """
    shards = buf.shards
    vocab_size = buf.tiering.vocab_size
    wmax = max(s.n_words for s in shards)
    s_pad = -len(shards) % n_devices
    v = int(np.asarray(buf.t2_postings[0]).shape[0])
    t1_l, t2_l, off, wid, t1w = [], [], [], [], []
    for s in shards:
        pad = ((0, 0), (0, wmax - s.n_words))
        t2_l.append(np.pad(np.asarray(buf.t2_postings[s.index]), pad))
        if use_t1:
            t1_l.append(np.pad(np.asarray(buf.shard_postings[s.index]), pad))
            t1w.append(buf.shard_words[s.index])
        else:
            t1_l.append(np.zeros((v, wmax), np.uint32))
            t1w.append(0)
        off.append(s.word_lo)
        wid.append(s.n_words)
    for _ in range(s_pad):          # pad shards: zero words, scratch offset
        t1_l.append(np.zeros((v, wmax), np.uint32))
        t2_l.append(np.zeros((v, wmax), np.uint32))
        off.append(buf.w_total)
        wid.append(0)
        t1w.append(0)
    cbits = buf.tiering.clause_vocab_bits if use_t1 else \
        np.zeros((0, max(1, -(-vocab_size // 32))), np.uint32)
    return MeshRouteTable(
        clause_bits=jnp.asarray(cbits),
        t1=jnp.asarray(np.stack(t1_l)), t2=jnp.asarray(np.stack(t2_l)),
        off=jnp.asarray(off, jnp.int32), wid=jnp.asarray(wid, jnp.int32),
        t1w=jnp.asarray(t1w, jnp.int32),
        w_total=buf.w_total, wmax=wmax, vocab_size=vocab_size)


_PROGRAMS: dict = {}


def _program(mesh, axis: str, w_total: int, wmax: int, n_clauses: int):
    """The compiled fused program for one (mesh, widths, ψ size) signature."""
    key = (mesh, axis, w_total, wmax, n_clauses > 0)
    if key in _PROGRAMS:
        return _PROGRAMS[key]

    def body(qbits, cbits, toks, t1, t2, off, wid, t1w):
        elig = ops.clause_match(qbits, cbits)              # replicated [B]
        valid = toks >= 0
        safe = jnp.where(valid, toks, 0)
        cols = jnp.arange(wmax, dtype=jnp.int32)
        out = jnp.zeros((toks.shape[0], w_total + wmax), jnp.uint32)
        for i in range(t1.shape[0]):                       # local shards
            # owner-local AND-match: ψ picks the resident slice per query
            rows = jnp.where((elig & (t1w[i] > 0))[:, None, None],
                             t1[i][safe], t2[i][safe])     # [B, L, wmax]
            rows = jnp.where(valid[:, :, None], rows, jnp.uint32(ONES))
            m = jax.lax.reduce(rows, jnp.uint32(ONES),
                               jax.lax.bitwise_and, (1,))
            # host parity: the router never contacts a shard whose local D₁
            # is empty for an eligible query — its words stay zero
            m = jnp.where(elig[:, None] & (t1w[i] == 0), jnp.uint32(0), m)
            m = jnp.where(cols[None, :] < wid[i], m, jnp.uint32(0))
            out = jax.lax.dynamic_update_slice(out, m, (0, off[i]))
        # disjoint-word OR-merge: every word has one owner, so + == |
        return jax.lax.psum(out, axis), elig

    fused = distributed.mesh_fused(
        body,
        in_specs=(P(), P(), P(), P(axis), P(axis), P(axis), P(axis),
                  P(axis)),
        out_specs=(P(), P()), axis=axis, mesh=mesh)
    prog = jax.jit(fused)
    if len(_PROGRAMS) > 32:
        _PROGRAMS.clear()
    _PROGRAMS[key] = prog
    return prog


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


def serve_fused(table: MeshRouteTable, queries, plan
                ) -> tuple[np.ndarray, np.ndarray]:
    """Serve one batch through the fused program.

    Returns `(match_words [B, w_total] uint32, eligible [B] bool)` —
    bit-identical to the host router's scatter-gather OR-merge. Batch and
    token dims are bucketed to powers of two (padded queries are empty and
    sliced off) so the program compiles once per bucket, not per batch.
    """
    b = len(queries)
    bb = _bucket(b)
    lb = _bucket(max((len(q) for q in queries), default=1))
    toks = np.full((bb, lb), -1, np.int32)
    toks[:b] = matching.pad_token_batch(queries, pad_len=lb)
    qbits = np.zeros((bb, max(1, -(-table.vocab_size // 32))), np.uint32)
    if table.clause_bits.shape[0]:
        qbits[:b] = matching.pack_query_bits(queries, table.vocab_size)
    prog = _program(plan.mesh, plan.shard_axis, table.w_total, table.wmax,
                    int(table.clause_bits.shape[0]))
    out, elig = prog(jnp.asarray(qbits), table.clause_bits,
                     jnp.asarray(toks), table.t1, table.t2,
                     table.off, table.wid, table.t1w)
    return (np.asarray(out[:b, :table.w_total]),
            np.asarray(elig[:b]).astype(bool))
