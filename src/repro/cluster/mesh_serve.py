"""Fused scatter-gather serving: one shard_map program per batch.

The host router issues one sequential dispatch per shard per batch; on a
mesh the shards ARE devices, so the whole serve path fuses into a single
SPMD program over the `"shard"` axis:

  1. replicated classify — every device runs the packed clause-subset-test
     kernel (`ops.clause_match`) on the full batch, so the ψ^clause decision
     needs no broadcast;
  2. scatter — each query's work lands on the devices that own its doc
     words: the device holds its shard's RESIDENT postings as ONE stacked
     tier matrix (`tiers[s, 0]` = Tier-2, `tiers[s, 1]` = Tier-1) and the
     shared `fused_match.select_rows_match` core turns ψ's per-query tier
     choice into gather index arithmetic — one postings row fetched per
     (query, token), half the gather traffic of the old fetch-both-then-
     `where` schedule;
  3. gather — shards own disjoint word ranges, so the OR-merge is a
     `ppermute` ring: each step every device ships only its LOCAL [S_loc, B,
     wmax] match block to its ring neighbor and ORs the block it received
     into the owned word range (read-modify-write, so a narrow shard's zero
     tail never clobbers a neighbor's words). Wire bytes per device-step are
     `B * wmax * S_loc` — the owned slice — instead of the full-width
     `B * W_total` the old `psum` shipped, a ~`n_devices`× reduction (see
     ROADMAP "ring-merge wire model"). OR of disjoint contributions equals
     the integer psum it replaces, so the output is bit-identical.

Bit-identity with the host path is by construction: the classify kernel, the
AND-reduce, and the word placement are the same ops on the same bits — only
the dispatch moves. Parity at every shard/replica count is pinned by
tests/test_mesh.py (replicas don't enter: replicas of a shard hold identical
content, which is exactly what lets the mesh hold one copy per shard).

Operands live in a `MeshRouteTable`: per-shard slices are zero-padded to the
widest shard and stacked leading-axis-sharded over `"shard"` (pad shards
write zeros into a scratch word range past the real index, so they never
touch owned words). Tables are built once per (generation content, CORPUS
VERSION, topology) — a corpus append invalidates by key, and the table's
Tier-2 slices come from the buffer's pinned snapshot rather than the live
replicas, so a mid-roll replica can never leak a mixed-version slice into
the fused path. Batches are bucketed to powers of two and, past
`_PIPE_CHUNK` queries, split into chunks whose dispatches are all issued
before any result is awaited — the host packs and classifies chunk i+1
while the mesh is still AND-matching chunk i.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import distributed
from repro.kernels import fused_match
from repro.kernels import ops
from repro.serve import matching

ONES = 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class MeshRouteTable:
    """Device-resident operands of the fused serve program for ONE
    (ψ generation, fleet topology) pair. `S'` is the shard count padded to a
    multiple of the `"shard"` axis size; `wmax` the widest shard's words."""
    clause_bits: jnp.ndarray   # uint32 [K, Wv]  ψ clauses (replicated)
    tiers: jnp.ndarray         # uint32 [S', 2, V, wmax]  resident slices
    #                            (index 0: Tier-2, index 1: Tier-1)
    off: jnp.ndarray           # int32 [S'] owned word_lo (pad rows: w_total)
    wid: jnp.ndarray           # int32 [S'] owned words (pad rows: 0)
    t1w: jnp.ndarray           # int32 [S'] compacted Tier-1 words (0: no D₁)
    w_total: int               # global packed match-set width
    wmax: int
    vocab_size: int


def build_table(buf, n_devices: int, *, use_t1: bool = True) -> MeshRouteTable:
    """Stack per-shard resident slices for the fused program.

    Every operand comes from ONE `ClusterTieringBuffer`: its Tier-1
    sub-indexes (the SAME bits a committed replica holds) and its pinned
    corpus snapshot — shard plan, Tier-2 slices, global width — so a table
    can never pair tiers from different corpus versions (repro.ingest).
    With `use_t1=False` (the mid-rollout gap, served entirely at the
    buffer's corpus version) the ψ clause set is empty and every query
    routes to the buffer's Tier-2 slices, still one fused dispatch.
    """
    shards = buf.shards
    vocab_size = buf.tiering.vocab_size
    wmax = max(s.n_words for s in shards)
    s_pad = -len(shards) % n_devices
    v = int(np.asarray(buf.t2_postings[0]).shape[0])
    tiers_l, off, wid, t1w = [], [], [], []
    for s in shards:
        pad = ((0, 0), (0, wmax - s.n_words))
        t2 = np.pad(np.asarray(buf.t2_postings[s.index]), pad)
        if use_t1:
            t1 = np.pad(np.asarray(buf.shard_postings[s.index]), pad)
            t1w.append(buf.shard_words[s.index])
        else:
            t1 = np.zeros((v, wmax), np.uint32)
            t1w.append(0)
        tiers_l.append(np.stack([t2, t1]))           # [2, V, wmax]
        off.append(s.word_lo)
        wid.append(s.n_words)
    for _ in range(s_pad):          # pad shards: zero words, scratch offset
        tiers_l.append(np.zeros((2, v, wmax), np.uint32))
        off.append(buf.w_total)
        wid.append(0)
        t1w.append(0)
    cbits = buf.tiering.clause_vocab_bits if use_t1 else \
        np.zeros((0, max(1, -(-vocab_size // 32))), np.uint32)
    return MeshRouteTable(
        clause_bits=jnp.asarray(cbits),
        tiers=jnp.asarray(np.stack(tiers_l)),
        off=jnp.asarray(off, jnp.int32), wid=jnp.asarray(wid, jnp.int32),
        t1w=jnp.asarray(t1w, jnp.int32),
        w_total=buf.w_total, wmax=wmax, vocab_size=vocab_size)


_PROGRAMS: dict = {}


def _program(mesh, axis: str, w_total: int, wmax: int, n_clauses: int):
    """The compiled fused program for one (mesh, widths, ψ size) signature."""
    key = (mesh, axis, w_total, wmax, n_clauses > 0)
    if key in _PROGRAMS:
        return _PROGRAMS[key]

    n_dev = mesh.shape[axis]

    def body(qbits, cbits, toks, tiers, off, wid, t1w):
        elig = ops.clause_match(qbits, cbits)              # replicated [B]
        cols = jnp.arange(wmax, dtype=jnp.int32)
        b = toks.shape[0]
        s_loc, _, v, _ = tiers.shape                       # local shards
        blocks = []
        for i in range(s_loc):
            # owner-local AND-match: ψ picks the resident tier per query via
            # the stacked-gather core (one row fetch per query token)
            m = fused_match.select_rows_match(
                tiers[i].reshape(2 * v, wmax), v,
                elig & (t1w[i] > 0), toks)
            # host parity: the router never contacts a shard whose local D₁
            # is empty for an eligible query — its words stay zero
            m = jnp.where(elig[:, None] & (t1w[i] == 0), jnp.uint32(0), m)
            m = jnp.where(cols[None, :] < wid[i], m, jnp.uint32(0))
            blocks.append(m)
        blk = jnp.stack(blocks)                            # [S_loc, B, wmax]

        out = jnp.zeros((b, w_total + wmax), jnp.uint32)

        def scatter(out, blk, offs):
            # read-OR-write: a narrow shard's zero tail (wid < wmax) lands on
            # a neighbor's owned words and must not overwrite them
            for i in range(s_loc):
                cur = jax.lax.dynamic_slice(out, (0, offs[i]), (b, wmax))
                out = jax.lax.dynamic_update_slice(out, cur | blk[i],
                                                   (0, offs[i]))
            return out

        out = scatter(out, blk, off)
        # ring OR-merge: circulate each device's owned match block around the
        # ring; after n_dev-1 hops every device has OR'd every shard's
        # contribution, replicating the full match set (disjoint OR == the
        # integer psum this replaces) at 1/n_dev the per-step wire bytes.
        perm = [(d, (d + 1) % n_dev) for d in range(n_dev)]
        for _ in range(n_dev - 1):
            blk = jax.lax.ppermute(blk, axis, perm)
            off = jax.lax.ppermute(off, axis, perm)
            out = scatter(out, blk, off)
        return out, elig

    fused = distributed.mesh_fused(
        body,
        in_specs=(P(), P(), P(), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(), P()), axis=axis, mesh=mesh)
    prog = jax.jit(fused)
    if len(_PROGRAMS) > 32:
        _PROGRAMS.clear()
    _PROGRAMS[key] = prog
    return prog


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


_PIPE_CHUNK = 512


def serve_fused(table: MeshRouteTable, queries, plan
                ) -> tuple[np.ndarray, np.ndarray]:
    """Serve one batch through the fused program.

    Returns `(match_words [B, w_total] uint32, eligible [B] bool)` —
    bit-identical to the host router's scatter-gather OR-merge. Batch and
    token dims are bucketed to powers of two (padded queries are empty and
    sliced off) so the program compiles once per bucket, not per batch.
    Batches past `_PIPE_CHUNK` are split into chunks and every chunk's
    dispatch is issued before any result is awaited: JAX's async dispatch
    overlaps the host-side pack+classify of chunk i+1 with the device-side
    AND-match of chunk i.
    """
    b = len(queries)
    lb = _bucket(max((len(q) for q in queries), default=1))
    wv = max(1, -(-table.vocab_size // 32))
    prog = _program(plan.mesh, plan.shard_axis, table.w_total, table.wmax,
                    int(table.clause_bits.shape[0]))
    spans = [(lo, min(lo + _PIPE_CHUNK, b))
             for lo in range(0, max(b, 1), _PIPE_CHUNK)]
    pending = []
    for lo, hi in spans:
        sub = list(queries[lo:hi])
        bb = _bucket(hi - lo)
        toks = np.full((bb, lb), -1, np.int32)
        toks[:hi - lo] = matching.pad_token_batch(sub, pad_len=lb)
        qbits = np.zeros((bb, wv), np.uint32)
        if table.clause_bits.shape[0] and sub:
            qbits[:hi - lo] = matching.pack_query_bits(sub, table.vocab_size)
        pending.append(prog(jnp.asarray(qbits), table.clause_bits,
                            jnp.asarray(toks), table.tiers,
                            table.off, table.wid, table.t1w))
    match = np.concatenate([np.asarray(o[:hi - lo, :table.w_total])
                            for (lo, hi), (o, _) in zip(spans, pending)])
    elig = np.concatenate([np.asarray(e[:hi - lo]).astype(bool)
                           for (lo, hi), (_, e) in zip(spans, pending)])
    return match, elig
