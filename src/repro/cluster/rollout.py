"""Rolling Tier-1 swaps: drain → swap → undrain, one replica at a time.

A re-tiering changes BOTH halves of the serving contract — the ψ^clause
classifier at the router and the Tier-1 sub-indexes on the replicas — and
Theorem 3.1 only holds when a query classified by generation g's ψ is served
by generation g's Tier-1. The cluster therefore never hot-swaps the fleet at
once: a `RollingSwap` walks the Tier-1 replicas in REPLICA-MAJOR order
(replica r of every shard, then r+1, ...), so with ≥ 2 replicas per shard
some complete generation exists at every instant and the router always
classifies with the ψ of the generation it routes to. With a single replica
per shard there is a mid-rollout gap where no generation covers every shard;
the router then routes eligible traffic to Tier 2, which is exact for any
query — correctness never depends on rollout timing.

Each replica swap is two-phase: `step()` first marks the replica draining
(the router stops sending it batches; in-flight work finishes), the next
`step()` commits the new (sub-index, words, generation) and undrains.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.tiering import ClauseTiering


@dataclasses.dataclass(frozen=True)
class ClusterTieringBuffer:
    """An off-path-built per-shard Tier-1 generation, ready to roll out."""
    tiering: ClauseTiering
    shard_postings: list[jnp.ndarray]   # per-shard Tier-1 sub-indexes
    shard_words: list[int]              # compacted words/query per shard
    generation: int = 0

    def shard_nonempty(self, s: int) -> bool:
        return self.shard_words[s] > 0


class RollingSwap:
    """Walks `t1_groups` (list per shard of replica lists) toward `buffer`."""

    def __init__(self, buffer: ClusterTieringBuffer, t1_groups):
        self.buffer = buffer
        # replica-major: [:, 0] then [:, 1] ... so one full cover swaps first
        n_replicas = max((len(g) for g in t1_groups), default=0)
        self._pending = [g[r] for r in range(n_replicas)
                         for g in t1_groups if r < len(g)]
        self._draining = None
        self.n_swapped = 0

    @property
    def done(self) -> bool:
        return self._draining is None and not self._pending

    def step(self):
        """Advance one phase; returns the replica acted on (or None if done)."""
        if self._draining is not None:
            rep = self._draining
            rep.commit(self.buffer.shard_postings[rep.shard.index],
                       self.buffer.shard_words[rep.shard.index],
                       self.buffer.generation)
            self._draining = None
            self.n_swapped += 1
            return rep
        if not self._pending:
            return None
        rep = self._pending.pop(0)
        rep.draining = True
        self._draining = rep
        return rep

    def run_to_completion(self) -> int:
        """Swap every remaining replica (no traffic between steps)."""
        while not self.done:
            self.step()
        return self.n_swapped
