"""Rolling swaps: drain → swap → undrain, one replica at a time — for
Tier-1 tierings AND (repro.ingest) for corpus-versioned postings.

A re-tiering changes BOTH halves of the serving contract — the ψ^clause
classifier at the router and the Tier-1 sub-indexes on the replicas — and
Theorem 3.1 only holds when a query classified by generation g's ψ is served
by generation g's Tier-1 *content*. A corpus append additionally changes the
Tier-2 postings slices, and exactness then needs a third leg: the (ψ, Tier-1,
Tier-2) triple a batch observes must all come from ONE corpus version. The
cluster therefore never hot-swaps the fleet at once: a `RollingSwap` walks
the replicas in REPLICA-MAJOR order (replica r of every changed Tier-1
shard, then every changed Tier-2 shard, then r+1, ...), so with ≥ 2 replicas
per group some complete (ψ, postings) cover exists at every instant and the
router always serves a batch entirely at one version.

Generations roll PER SHARD, independently: every buffer carries a per-shard
CONTENT id for each tier (`shard_content` for Tier-1, `t2_content` for the
Tier-2 slices), and a replica already holding a shard's target content — a
shard the change didn't touch, the common case for scoped refits and for
grow-mode corpus appends (only the LAST shard's word range grows) — is left
in place without ever draining. Only the shards whose sub-index actually
changed pay the drain→swap→undrain walk. Content, not the generation number,
is what correctness needs: the router picks replicas by content and
`BatchTrace` records served-vs-expected content per shard for both tiers.

With a single replica per (changed) shard there is a mid-rollout gap where
no generation covers every shard; the router then routes the batch to the
newest corpus version with full Tier-2 cover, which is exact for any query
at that version — correctness never depends on rollout timing.

Each replica swap is two-phase: `step()` first marks the replica draining
(the router stops sending it batches; in-flight work finishes), the next
`step()` commits the new (sub-index, words, generation, content) and
undrains.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro import obs
from repro.core.tiering import ClauseTiering


class StaleCorpusError(RuntimeError):
    """A swap was requested against an outdated corpus version.

    Raised (instead of the bare shape assert / KeyError it used to surface
    as) when a prepared `ClusterTieringBuffer` — or a raw `ClauseTiering`
    sized for the old document universe — is handed to the fleet after the
    corpus has rolled past the version it was built against. The fix is
    always the same: rebuild the tiering/buffer from the appended
    `TieringData` (current `n_docs`) and swap that.
    """


@dataclasses.dataclass(frozen=True)
class ClusterTieringBuffer:
    """An off-path-built per-shard generation, ready to roll out.

    Besides the Tier-1 sub-indexes, the buffer pins the ENTIRE corpus
    snapshot it was built against (repro.ingest): the shard plan, the
    per-shard Tier-2 postings slices with their content ids, and the
    (n_docs, w_total) extent. Serving a batch strictly from one buffer is
    what makes a mid-rollout batch exact — the router never mixes tiers
    from different corpus versions. Snapshot fields are shared references
    (append-only growth never rewrites a word), so carrying them is free.
    """
    tiering: ClauseTiering
    shard_postings: list[jnp.ndarray]   # per-shard Tier-1 sub-indexes
    shard_words: list[int]              # compacted words/query per shard
    generation: int = 0
    # content id per shard: equal ids <=> bit-identical sub-index, so buffers
    # that share a shard's content are interchangeable on that shard
    shard_content: tuple[int, ...] = ()
    # corpus snapshot (defaults keep hand-built test buffers constructible)
    corpus_version: int = 0
    shards: tuple = ()                  # DocShard plan at this version
    t2_postings: tuple = ()             # per-shard Tier-2 column slices
    t2_content: tuple[int, ...] = ()    # content id per Tier-2 slice
    n_docs: int = 0
    w_total: int = 0                    # postings words at this version

    def shard_nonempty(self, s: int) -> bool:
        return self.shard_words[s] > 0


class RollingSwap:
    """Walks the replica groups toward `buffer`, one replica phase at a time.

    Tier-1 replicas already holding their shard's target content commit
    instantly (metadata-only, no drain) at construction; Tier-2 replicas
    whose slice content is unchanged — every corpus-untouched shard — are
    not touched at all. The rest swap one at a time in replica-major order,
    Tier-1 shards before Tier-2 shards within each replica column, so one
    full (ψ, Tier-1, Tier-2) cover lands before the second column starts.
    """

    def __init__(self, buffer: ClusterTieringBuffer, t1_groups,
                 t2_groups=()):
        self.buffer = buffer
        self.n_swapped = 0
        self.n_carried = 0
        pending = []
        for g in t1_groups:
            for rep in g:
                if rep.content == buffer.shard_content[rep.shard.index]:
                    rep.commit(buffer.shard_postings[rep.shard.index],
                               buffer.shard_words[rep.shard.index],
                               buffer.generation,
                               buffer.shard_content[rep.shard.index],
                               shard=self._plan(rep))
                    self.n_carried += 1
                else:
                    pending.append(rep)
        if buffer.t2_content:
            for g in t2_groups:
                for rep in g:
                    if rep.content != buffer.t2_content[rep.shard.index]:
                        pending.append(rep)
        # replica-major: [:, 0] then [:, 1] ... so one full cover swaps first
        groups = list(t1_groups) + list(t2_groups)
        n_replicas = max((len(g) for g in groups), default=0)
        by_rep = {id(r): i for g in groups for i, r in enumerate(g)}
        self._pending = [r for i in range(n_replicas)
                         for r in pending if by_rep[id(r)] == i]
        self._draining = None
        obs.event("rollout_begin", generation=buffer.generation,
                  corpus_version=buffer.corpus_version,
                  carried=self.n_carried, pending=len(self._pending))
        if self.done:                    # all content carried: instant rollout
            obs.event("rollout_done", generation=buffer.generation,
                      corpus_version=buffer.corpus_version,
                      swapped=0, carried=self.n_carried)

    def _plan(self, rep):
        """The replica's DocShard under the buffer's plan (grow mode may
        have widened the last shard); None when the buffer predates plans."""
        if rep.shard.index < len(self.buffer.shards):
            return self.buffer.shards[rep.shard.index]
        return None

    def _commit(self, rep) -> None:
        s = rep.shard.index
        if rep.tier == 1:
            rep.commit(self.buffer.shard_postings[s],
                       self.buffer.shard_words[s], self.buffer.generation,
                       self.buffer.shard_content[s], shard=self._plan(rep))
        else:
            new_shard = self._plan(rep)
            rep.commit(self.buffer.t2_postings[s],
                       new_shard.n_words if new_shard is not None
                       else rep.words_per_query,
                       self.buffer.generation, self.buffer.t2_content[s],
                       shard=new_shard)

    @property
    def done(self) -> bool:
        return self._draining is None and not self._pending

    def step(self):
        """Advance one phase; returns the replica acted on (or None if done)."""
        if self._draining is not None:
            rep = self._draining
            self._commit(rep)
            self._draining = None
            self.n_swapped += 1
            obs.event("replica_swap", tier=rep.tier, shard=rep.shard.index,
                      generation=rep.generation, content=rep.content)
            if self.done:
                obs.event("rollout_done", generation=self.buffer.generation,
                          corpus_version=self.buffer.corpus_version,
                          swapped=self.n_swapped, carried=self.n_carried)
            return rep
        if not self._pending:
            return None
        rep = self._pending.pop(0)
        rep.draining = True
        self._draining = rep
        obs.event("replica_drain", tier=rep.tier, shard=rep.shard.index,
                  generation=rep.generation, content=rep.content)
        return rep

    def run_to_completion(self) -> int:
        """Swap every remaining replica (no traffic between steps)."""
        while not self.done:
            self.step()
        return self.n_swapped
