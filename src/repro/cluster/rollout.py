"""Rolling Tier-1 swaps: drain → swap → undrain, one replica at a time.

A re-tiering changes BOTH halves of the serving contract — the ψ^clause
classifier at the router and the Tier-1 sub-indexes on the replicas — and
Theorem 3.1 only holds when a query classified by generation g's ψ is served
by generation g's Tier-1 *content*. The cluster therefore never hot-swaps the
fleet at once: a `RollingSwap` walks the Tier-1 replicas in REPLICA-MAJOR
order (replica r of every shard, then r+1, ...), so with ≥ 2 replicas per
shard some complete generation exists at every instant and the router always
classifies with the ψ of the generation it routes to.

Generations roll PER SHARD, independently: every buffer carries a per-shard
CONTENT id (`shard_content`), and a replica already holding a shard's target
content — a shard the re-tiering didn't touch, the common case for scoped
shard-aware refits — commits instantly at swap start, metadata-only, without
ever draining. Only the shards whose Tier-1 sub-index actually changed pay
the drain→swap→undrain walk, so a one-shard re-tiering disturbs exactly that
shard's replicas. Content, not the generation number, is what correctness
needs: the router picks replicas by content and `BatchTrace` records
served-vs-expected content per shard.

With a single replica per (changed) shard there is a mid-rollout gap where no
generation covers every shard; the router then routes eligible traffic to
Tier 2, which is exact for any query — correctness never depends on rollout
timing.

Each replica swap is two-phase: `step()` first marks the replica draining
(the router stops sending it batches; in-flight work finishes), the next
`step()` commits the new (sub-index, words, generation, content) and
undrains.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.tiering import ClauseTiering


@dataclasses.dataclass(frozen=True)
class ClusterTieringBuffer:
    """An off-path-built per-shard Tier-1 generation, ready to roll out."""
    tiering: ClauseTiering
    shard_postings: list[jnp.ndarray]   # per-shard Tier-1 sub-indexes
    shard_words: list[int]              # compacted words/query per shard
    generation: int = 0
    # content id per shard: equal ids <=> bit-identical sub-index, so buffers
    # that share a shard's content are interchangeable on that shard
    shard_content: tuple[int, ...] = ()

    def shard_nonempty(self, s: int) -> bool:
        return self.shard_words[s] > 0


class RollingSwap:
    """Walks `t1_groups` (list per shard of replica lists) toward `buffer`.

    Replicas already holding their shard's target content commit instantly
    (metadata-only, no drain) at construction; the rest swap one at a time in
    replica-major order.
    """

    def __init__(self, buffer: ClusterTieringBuffer, t1_groups):
        self.buffer = buffer
        self.n_swapped = 0
        self.n_carried = 0
        pending = []
        for g in t1_groups:
            for rep in g:
                if rep.content == buffer.shard_content[rep.shard.index]:
                    rep.commit(buffer.shard_postings[rep.shard.index],
                               buffer.shard_words[rep.shard.index],
                               buffer.generation,
                               buffer.shard_content[rep.shard.index])
                    self.n_carried += 1
                else:
                    pending.append(rep)
        # replica-major: [:, 0] then [:, 1] ... so one full cover swaps first
        n_replicas = max((len(g) for g in t1_groups), default=0)
        by_rep = {id(r): i for g in t1_groups for i, r in enumerate(g)}
        self._pending = [r for i in range(n_replicas)
                         for r in pending if by_rep[id(r)] == i]
        self._draining = None

    @property
    def done(self) -> bool:
        return self._draining is None and not self._pending

    def step(self):
        """Advance one phase; returns the replica acted on (or None if done)."""
        if self._draining is not None:
            rep = self._draining
            rep.commit(self.buffer.shard_postings[rep.shard.index],
                       self.buffer.shard_words[rep.shard.index],
                       self.buffer.generation,
                       self.buffer.shard_content[rep.shard.index])
            self._draining = None
            self.n_swapped += 1
            return rep
        if not self._pending:
            return None
        rep = self._pending.pop(0)
        rep.draining = True
        self._draining = rep
        return rep

    def run_to_completion(self) -> int:
        """Swap every remaining replica (no traffic between steps)."""
        while not self.done:
            self.step()
        return self.n_swapped
