"""repro.cluster — sharded, replicated two-tier serving (paper §2.2, Fig. 1).

The paper's economics are fleet economics: a small Tier 1 matters because a
FLEET of small replicas absorbs eligible traffic that would otherwise need
full-index machines. This package models that fleet end to end:

  * `shard_postings` / `DocShard` — word-aligned doc-sharding of the packed
    postings; per-shard Tier-1 sub-indexes via `shard_tier_postings`;
  * `ShardReplica` / `ClusterRouter` — replica groups per (tier, shard) and
    the batch router: one batched ψ^clause kernel call
    (`kernels.ops.clause_match`), scatter to Tier-1/Tier-2 replicas,
    OR-merge of packed per-shard match bitsets — bit-identical to
    single-tier matching (Theorem 3.1 per shard);
  * `RollingSwap` / `ClusterTieringBuffer` — zero-downtime re-tiering with
    PER-SHARD generations: each buffer carries per-shard CONTENT ids, so
    shards a re-tiering didn't touch carry their replicas across
    generations metadata-only (no drain, no install) while changed shards
    drain and swap one replica at a time; no batch ever observes a mixed
    (ψ, Tier-1) content pair per shard (`BatchTrace` proves it);
  * `ClusterPlan` / `run_loadgen` — deterministic discrete-event load
    generator: open-loop Poisson arrivals, words-scanned service model
    (calibrate it with `fit_service_model` against measured `match_batch`
    walls), straggler tail, per-replica FIFO queueing; reports throughput,
    p50/p95/p99 latency, fleet word traffic and per-replica
    utilization/backlog — which `suggest_replicas(plan, offered_load,
    slo_p95)` closes into an autoscaling loop;
  * `TieredCluster` — engine-compatible facade, so
    `stream.RetieringController` re-tiers a whole cluster through rolling
    swaps exactly as it hot-swaps one engine.

Quickstart:

    from repro import api, cluster

    pipe = (api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
            .mine(min_support=1e-3).solve("greedy", budget_frac=0.5))
    fleet = pipe.deploy_cluster(n_shards=4, t1_replicas=2)
    results = fleet.serve(pipe.log.queries[:64])      # exact match sets
    rep = cluster.run_loadgen(cluster.ClusterPlan.of_cluster(fleet),
                              fleet.classify(pipe.log.queries[:512]))
    print(rep.line())

CLI: `python -m repro.launch.cluster --shards 2 --replicas 2 --windows 2`
"""
from repro.cluster.frontend import (                   # noqa: F401
    AdmissionPolicy, CacheStats, ResultCache, keys_of, zipf_keys)
from repro.cluster.loadgen import (                    # noqa: F401
    ClusterPlan, LoadgenReport, ReplicaSuggestion, fit_service_model,
    run_loadgen, suggest_replicas)
from repro.cluster.mesh_serve import (                 # noqa: F401
    MeshRouteTable, serve_fused)
from repro.cluster.rollout import (                    # noqa: F401
    ClusterTieringBuffer, RollingSwap, StaleCorpusError)
from repro.cluster.router import (                     # noqa: F401
    BatchTrace, ClusterRouter, ShardReplica, TieredCluster)
from repro.cluster.shard import (                      # noqa: F401
    DocShard, grow_shards, plan_shards, shard_postings,
    shard_tier_postings)

__all__ = [
    "AdmissionPolicy", "BatchTrace", "CacheStats", "ClusterPlan",
    "ClusterRouter", "ClusterTieringBuffer", "DocShard", "LoadgenReport",
    "MeshRouteTable", "ReplicaSuggestion", "ResultCache", "RollingSwap",
    "ShardReplica", "StaleCorpusError", "TieredCluster",
    "fit_service_model", "grow_shards", "keys_of", "plan_shards",
    "run_loadgen", "serve_fused", "shard_postings", "shard_tier_postings",
    "suggest_replicas", "zipf_keys",
]
