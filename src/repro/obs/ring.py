"""Bounded append-only buffer with list semantics over the retained tail.

The telemetry plane's containment primitive: spans, events and the
cluster's `BatchTrace` history all go through a `Ring`, so a long
`run_stream`/`run_ingest` session holds a fixed amount of history instead
of growing without limit. `capacity=None` is the explicit full-history
mode the parity tests use (every batch retained, nothing dropped).
"""
from __future__ import annotations

import collections
from typing import Iterable, Iterator


class Ring:
    """A deque-backed ring that quacks like the list it replaced.

    Supports `append`/`extend`, `len`, truthiness, iteration, negative
    indexing and slicing (slices materialize the retained tail). Tracks
    `n_seen` (ever appended) so `n_dropped` makes silent truncation
    visible — exporters and dashboards report it instead of pretending
    the retained tail is the whole history.
    """

    __slots__ = ("_q", "n_seen")

    def __init__(self, capacity: int | None = None,
                 items: Iterable | None = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1 or None for unbounded, "
                             f"got {capacity}")
        self._q: collections.deque = collections.deque(maxlen=capacity)
        self.n_seen = 0
        if items is not None:
            self.extend(items)

    @property
    def capacity(self) -> int | None:
        return self._q.maxlen

    @property
    def n_dropped(self) -> int:
        return self.n_seen - len(self._q)

    def append(self, item) -> None:
        self._q.append(item)
        self.n_seen += 1

    def extend(self, items: Iterable) -> None:
        for item in items:
            self.append(item)

    def clear(self) -> None:
        """Drop the retained tail (keeps `n_seen` so drops stay auditable)."""
        self._q.clear()

    def to_list(self) -> list:
        return list(self._q)

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __iter__(self) -> Iterator:
        return iter(self._q)

    def __getitem__(self, index):
        if isinstance(index, slice):
            return list(self._q)[index]
        return self._q[index]

    def __repr__(self) -> str:
        cap = "∞" if self.capacity is None else str(self.capacity)
        return (f"Ring({len(self._q)}/{cap} retained, "
                f"{self.n_dropped} dropped)")
