"""Span tracing: timed, nested sections of the request and control paths.

A span times one named section (`classify`, `t1_match`, `merge`, `refit`,
`swap`, `append`, ...) with wall-clock duration and — when the caller asks
via `span.sync(x)` — device-sync timing that blocks on a JAX value so the
measured interval covers actual device work, not just dispatch.

Spans nest: the recorder keeps a stack per process, so a `serve` span
opened around a batch contains `classify`/`t1_match`/`merge` children with
parent ids and depths, making one served batch or one drift-triggered
refit a single readable trace. Finished spans land in a bounded `Ring` as
plain dicts (JSON-ready for the exporter).

When the plane is disabled `repro.obs.span()` hands out the shared
`NULL_SPAN` whose methods are all no-ops — the hot path never builds a
Span object at all.
"""
from __future__ import annotations

import time
from typing import Iterator

from repro.obs.ring import Ring

DEFAULT_SPAN_CAPACITY = 4096


class _NullSpan:
    """Shared do-nothing span handed out while the plane is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self

    def sync(self, value):
        return value


NULL_SPAN = _NullSpan()


class Span:
    """One timed section; append-on-exit into the recorder's ring."""

    __slots__ = ("recorder", "name", "id", "parent", "depth",
                 "t0_s", "_t0", "wall_ms", "sync_ms", "attrs")

    def __init__(self, recorder: "SpanRecorder", name: str, attrs: dict):
        self.recorder = recorder
        self.name = name
        self.id = -1
        self.parent = -1
        self.depth = 0
        self.t0_s = 0.0
        self._t0 = 0.0
        self.wall_ms = 0.0
        self.sync_ms = 0.0
        self.attrs = attrs

    def __enter__(self) -> "Span":
        self.recorder._open(self)
        self.t0_s = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.wall_ms = (time.perf_counter() - self._t0) * 1e3
        self.recorder._close(self)
        return False

    def set(self, **attrs) -> "Span":
        """Attach attributes (batch size, generation, words scanned...)."""
        self.attrs.update(attrs)
        return self

    def sync(self, value):
        """Block until `value` is device-ready, folding the wait into
        `sync_ms`; returns `value` so call sites stay expressions."""
        t0 = time.perf_counter()
        try:
            import jax
            value = jax.block_until_ready(value)
        except Exception:
            pass  # non-JAX value (or no runtime) — wall clock still covers it
        self.sync_ms += (time.perf_counter() - t0) * 1e3
        return value

    def to_dict(self) -> dict:
        d = {
            "id": self.id,
            "parent": self.parent,
            "name": self.name,
            "depth": self.depth,
            "t0_s": self.t0_s,
            "wall_ms": round(self.wall_ms, 4),
            "sync_ms": round(self.sync_ms, 4),
        }
        if self.attrs:
            d.update(self.attrs)
        return d


class SpanRecorder:
    """Stack-nested span recorder over a bounded ring of finished spans.

    `seq` numbers every finished span monotonically (drops included), so
    the per-window exporter can cursor with `since(seq)` instead of
    re-reading the whole ring.
    """

    def __init__(self, capacity: int | None = DEFAULT_SPAN_CAPACITY):
        self.ring = Ring(capacity)
        self._stack: list[Span] = []
        self._next_id = 0

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def _open(self, span: Span) -> None:
        span.id = self._next_id
        self._next_id += 1
        if self._stack:
            span.parent = self._stack[-1].id
            span.depth = self._stack[-1].depth + 1
        self._stack.append(span)

    def _close(self, span: Span) -> None:
        # tolerate out-of-order exits (exceptions unwound a child first)
        if span in self._stack:
            while self._stack and self._stack[-1] is not span:
                self._stack.pop()
            self._stack.pop()
        self.ring.append(span.to_dict())

    @property
    def seq(self) -> int:
        """Count of spans ever finished (drops included)."""
        return self.ring.n_seen

    def since(self, seq: int) -> list[dict]:
        """Finished spans with ordinal >= `seq` still retained in the ring."""
        start = self.ring.n_seen - len(self.ring)  # ordinal of ring[0]
        if seq <= start:
            return self.ring.to_list()
        if seq >= self.ring.n_seen:
            return []
        return self.ring[seq - start:]

    def to_list(self) -> list[dict]:
        return self.ring.to_list()

    def of_name(self, name: str) -> list[dict]:
        return [s for s in self.ring if s["name"] == name]

    def children(self, span_id: int) -> list[dict]:
        return [s for s in self.ring if s["parent"] == span_id]

    def walk(self) -> Iterator[dict]:
        return iter(self.ring)

    def reset(self) -> None:
        self.ring = Ring(self.ring.capacity)
        self._stack.clear()
        self._next_id = 0
