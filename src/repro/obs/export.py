"""Per-window JSONL snapshot exporter + reader for `launch.obs` replay.

One run writes one JSONL file under the obs directory (default
`artifacts/obs/`); each line is one window snapshot:

    {"window": i, "ts": ..., "metrics": {registry.collect()},
     "spans": [finished spans since the last snapshot],
     "events": [events since the last snapshot], ...extra}

Snapshots carry only the spans/events that finished since the previous
export (cursored by seq in `repro.obs.export_window`), so a long run's
file is an append-only log, not repeated full dumps. Metrics are
cumulative registry state — downstream diffing recovers per-window rates.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

DEFAULT_DIR = "artifacts/obs"


class JsonlExporter:
    """Appends one JSON line per window snapshot to `<dir>/<run>.jsonl`."""

    def __init__(self, dir: str | os.PathLike = DEFAULT_DIR,  # noqa: A002
                 run: str | None = None, overwrite: bool = True):
        self.dir = Path(dir)
        if run is None:
            run = time.strftime("run-%Y%m%d-%H%M%S") + f"-p{os.getpid()}"
        self.run = run
        self.path = self.dir / f"{run}.jsonl"
        self.n_written = 0
        if overwrite and self.path.exists():
            self.path.unlink()           # a named run restarts its file

    def export(self, snapshot: dict) -> Path:
        self.dir.mkdir(parents=True, exist_ok=True)
        with open(self.path, "a") as fh:
            fh.write(json.dumps(snapshot, default=_json_default,
                                sort_keys=True) + "\n")
        self.n_written += 1
        return self.path


def _json_default(value):
    """numpy scalars/arrays sneak into snapshots; make them JSON-able."""
    if hasattr(value, "item") and not hasattr(value, "__len__"):
        return value.item()
    if hasattr(value, "tolist"):
        return value.tolist()
    return str(value)


def read_jsonl(path: str | os.PathLike) -> list[dict]:
    """All snapshots in one run file, in write order."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def load_dir(dir: str | os.PathLike = DEFAULT_DIR  # noqa: A002
             ) -> dict[str, list[dict]]:
    """All runs in an obs directory: run name -> snapshots."""
    d = Path(dir)
    if not d.is_dir():
        return {}
    return {p.stem: read_jsonl(p) for p in sorted(d.glob("*.jsonl"))}
