"""Per-dispatch kernel cost accountant: words, bytes, device-sync wall.

Every public op in `repro.kernels.ops` reports a shape-derived cost model
(uint32 postings words read, modelled HBM bytes for operands + result) to
the process profiler on each dispatch, labelled `(op, path)` where path is
the resolved placement ("xla" / "interpret" / "pallas", or "mesh" for the
owner-local shard_map fusions). Two tiers of accounting:

  * always (while the plane is on): two counter incs —
    `kernel_words_scanned_total{op,path}` and
    `kernel_bytes_moved_total{op,path}` — cheap enough for production
    dispatch, and what the CI telemetry smoke asserts on.
  * measuring (explicit `with PROFILER.measuring():`): additionally blocks
    on each result (`jax.block_until_ready`) and accrues device-sync
    wall-clock per (op, path), so `summary()` can derive per-kernel
    achieved bandwidth and the achieved-vs-roofline fraction. Blocking
    defeats async dispatch, so this tier is opt-in — benchmarks only.

Under `REPRO_OBS=0` the ops never call in here at all (they gate on the
same `_state.on` switch), so profiling is a complete no-op and serve
results stay bit-identical.

The peak numbers are the single source the dry-run roofline report
(`benchmarks/roofline.py`) also uses: v5p-class 197 TFLOP/s, 819 GB/s HBM,
50 GB/s ICI per link.
"""
from __future__ import annotations

import contextlib
import time

from repro.obs.registry import MetricsRegistry

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


class KernelProfiler:
    """Aggregates per-(op, path) dispatch costs; see the module docstring."""

    def __init__(self, registry: MetricsRegistry):
        self._words = registry.counter(
            "kernel_words_scanned_total",
            "uint32 postings words read per kernel dispatch",
            labels=("op", "path"))
        self._bytes = registry.counter(
            "kernel_bytes_moved_total",
            "modelled HBM bytes (operands + result) per kernel dispatch",
            labels=("op", "path"))
        self.active = False
        self._agg: dict[tuple[str, str], dict] = {}

    def record(self, op: str, path: str, words: int, nbytes: int,
               out=None, t0: float = 0.0) -> None:
        """One dispatch. With `out` (measuring mode) also blocks on it and
        accrues wall-clock from `t0` (taken just before the dispatch)."""
        self._words.inc(words, op=op, path=path)
        self._bytes.inc(nbytes, op=op, path=path)
        if not (self.active and out is not None):
            return
        import jax
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        a = self._agg.setdefault((op, path), {"calls": 0, "words": 0,
                                              "bytes": 0, "sync_s": 0.0})
        a["calls"] += 1
        a["words"] += int(words)
        a["bytes"] += int(nbytes)
        a["sync_s"] += dt

    @contextlib.contextmanager
    def measuring(self):
        """Scope where dispatches are synchronously timed (benchmarks)."""
        prev, self.active = self.active, True
        try:
            yield self
        finally:
            self.active = prev

    @contextlib.contextmanager
    def scoped(self):
        """Isolated measured-aggregation scope: enters empty, and whatever
        was accrued before the scope is restored on exit. Benchmark
        subsections wrap themselves in this so `profile` / `profile_mesh`
        rows can never mix counters accumulated by an earlier subsection
        (or by warmup dispatches) in the same process."""
        saved, self._agg = self._agg, {}
        try:
            yield self
        finally:
            self._agg = saved

    def summary(self) -> list[dict]:
        """Measured aggregation as JSON-ready rows, one per (op, path):
        totals plus achieved GB/s and the fraction of the HBM roofline."""
        rows = []
        for (op, path), a in sorted(self._agg.items()):
            sync = max(a["sync_s"], 1e-12)
            gbps = a["bytes"] / sync / 1e9
            rows.append({
                "op": op, "path": path, "calls": a["calls"],
                "words_scanned": int(a["words"]),
                "bytes_moved": int(a["bytes"]),
                "sync_s": round(a["sync_s"], 6),
                "us_per_call": round(1e6 * a["sync_s"] / max(a["calls"], 1),
                                     3),
                "achieved_gbps": round(gbps, 3),
                "roofline_frac": round(gbps / (HBM_BW / 1e9), 6),
            })
        return rows

    def reset(self) -> None:
        """Drop the measured aggregation (the registry counters are owned
        by the registry and reset with it)."""
        self._agg.clear()
