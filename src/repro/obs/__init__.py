"""repro.obs — the fleet-wide telemetry plane.

Zero-dependency (numpy + stdlib) observability for every layer of the
system: a process-global `MetricsRegistry` of typed instruments, a
`SpanRecorder` for nested request/control-path traces, an `EventLog` for
discrete control-plane occurrences, and a per-window JSONL exporter.

The module-level singletons (`REGISTRY`, `SPANS`, `EVENTS`) are what the
instrumented call sites use, via the shortcuts below:

    words = obs.counter("cluster_words_total", labels=("tier", "shard"))
    words.inc(n, tier="t1", shard=k)

    with obs.span("t1_match", shard=k) as sp:
        hits = sp.sync(match_batch(...))

    obs.event("drift_detected", window=i, tv=signal.tv_distance)

On top of the raw plane sit two judgment layers: `SLO` (repro.obs.slo) —
declarative SLO rules with multi-window burn-rate alerting, evaluated once
per exported window — and `PROFILER` (repro.obs.profile) — the
per-dispatch kernel cost accountant behind `kernel_words_scanned_total`
and the achieved-vs-roofline rows in BENCH_kernels.json.

Everything is gated on one switch: `REPRO_OBS=0` in the environment (or
`obs.disable()` at runtime) turns the whole plane into no-ops — counters
skip, `span()` returns the shared `NULL_SPAN`, events drop, SLO
evaluation and kernel profiling never run — and serve results stay
bit-identical (pinned by tests/test_obs.py and the `obs_overhead`
micro-bench). Instruments built directly with `always=True` (e.g. the
loadgen latency histogram) bypass the switch so simulation OUTPUTS never
depend on it.
"""
from __future__ import annotations

from repro.obs import _state
from repro.obs.events import DEFAULT_EVENT_CAPACITY, EventLog
from repro.obs.export import DEFAULT_DIR, JsonlExporter, load_dir, read_jsonl
from repro.obs.profile import HBM_BW, ICI_BW, PEAK_FLOPS, KernelProfiler
from repro.obs.registry import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                                MetricsRegistry)
from repro.obs.render import fmt_value, render_line
from repro.obs.ring import Ring
from repro.obs.slo import SLOEngine, SLORule, default_slo_rules
from repro.obs.spans import (DEFAULT_SPAN_CAPACITY, NULL_SPAN, Span,
                             SpanRecorder)

__all__ = [
    "REGISTRY", "SPANS", "EVENTS", "SLO", "PROFILER",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "Ring",
    "SpanRecorder", "Span", "NULL_SPAN", "EventLog", "JsonlExporter",
    "SLOEngine", "SLORule", "default_slo_rules", "KernelProfiler",
    "counter", "gauge", "histogram", "span", "event",
    "enabled", "disabled", "enable", "disable", "set_enabled",
    "set_exporter", "get_exporter", "export_window", "dashboard", "reset",
    "read_jsonl", "load_dir", "render_line", "fmt_value",
    "DEFAULT_BUCKETS", "DEFAULT_DIR",
    "DEFAULT_SPAN_CAPACITY", "DEFAULT_EVENT_CAPACITY",
    "PEAK_FLOPS", "HBM_BW", "ICI_BW",
]

REGISTRY = MetricsRegistry()
SPANS = SpanRecorder()
EVENTS = EventLog()
SLO = SLOEngine(REGISTRY, EVENTS)
PROFILER = KernelProfiler(REGISTRY)

_exporter: JsonlExporter | None = None
_span_cursor = 0
_event_cursor = 0


# -- the switch ----------------------------------------------------------------
def enabled() -> bool:
    return _state.on


def disabled() -> bool:
    return not _state.on


def enable() -> bool:
    """Turn collection on process-wide; returns the previous setting."""
    return _state.enable()


def disable() -> bool:
    """Turn collection off process-wide; returns the previous setting."""
    return _state.disable()


def set_enabled(value: bool) -> bool:
    """Set the switch to `value`; returns the previous setting (so callers
    can save/restore around a scoped section)."""
    return _state.set_enabled(value)


# -- instruments ---------------------------------------------------------------
def counter(name: str, help: str = "",  # noqa: A002
            labels: tuple[str, ...] = ()) -> Counter:
    return REGISTRY.counter(name, help, labels)


def gauge(name: str, help: str = "",  # noqa: A002
          labels: tuple[str, ...] = ()) -> Gauge:
    return REGISTRY.gauge(name, help, labels)


def histogram(name: str, help: str = "",  # noqa: A002
              labels: tuple[str, ...] = (),
              buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, help, labels, buckets)


def span(name: str, **attrs):
    """A context-managed `Span` — or the no-op `NULL_SPAN` when disabled,
    so the hot path never allocates."""
    if not _state.on:
        return NULL_SPAN
    return SPANS.span(name, **attrs)


def event(kind: str, **fields) -> dict | None:
    if not _state.on:
        return None
    return EVENTS.emit(kind, **fields)


# -- export --------------------------------------------------------------------
def set_exporter(exporter: JsonlExporter | None) -> JsonlExporter | None:
    """Install (or clear, with None) the process exporter. Controllers call
    `export_window` unconditionally; without an installed exporter it is a
    no-op, so test runs don't spray snapshot files."""
    global _exporter, _span_cursor, _event_cursor
    prev, _exporter = _exporter, exporter
    _span_cursor = SPANS.seq
    _event_cursor = EVENTS.seq
    return prev


def get_exporter() -> JsonlExporter | None:
    return _exporter


def snapshot_window(index: int, **extra) -> dict:
    """Build (without writing) one window snapshot; advances the span and
    event cursors so the next snapshot carries only new activity.

    SLO rules are evaluated FIRST, so a breach/recovery transition lands in
    this window's `events` delta and the primed `slo_breaches_total` series
    in its `metrics`. The `rings` block surfaces span/event retention
    (`n_seen`/`n_dropped`) so silent truncation never reads as coverage —
    `launch.obs --check --max-dropped-frac` gates on it."""
    global _span_cursor, _event_cursor
    import time
    slo = SLO.evaluate(index)
    snap = {
        "window": index,
        "ts": time.time(),
        "metrics": REGISTRY.collect(),
        "spans": SPANS.since(_span_cursor),
        "events": EVENTS.since(_event_cursor),
        "slo": slo,
        "rings": {
            "spans": {"n_seen": SPANS.ring.n_seen,
                      "n_dropped": SPANS.ring.n_dropped},
            "events": {"n_seen": EVENTS.ring.n_seen,
                       "n_dropped": EVENTS.ring.n_dropped},
        },
    }
    snap.update(extra)
    _span_cursor = SPANS.seq
    _event_cursor = EVENTS.seq
    return snap


def export_window(index: int, **extra) -> dict | None:
    """Snapshot + write one window to the installed exporter. No-op (returns
    None) when the plane is disabled or no exporter is installed."""
    if not _state.on or _exporter is None:
        return None
    snap = snapshot_window(index, **extra)
    _exporter.export(snap)
    return snap


def dashboard() -> str:
    """One human line over the whole registry — the launchers print this."""
    pairs = [
        ("queries", int(REGISTRY.total("serve_queries_total"))
         or int(REGISTRY.total("cluster_queries_total"))),
        ("t1_hits", int(REGISTRY.total("serve_tier1_hits_total"))),
        ("words", int(REGISTRY.total("serve_words_total"))
         or int(REGISTRY.total("cluster_words_total"))),
        ("refits", int(REGISTRY.total("refits_total")) or None),
        ("swaps", int(REGISTRY.total("swaps_total")) or None),
        ("admitted", int(REGISTRY.total("admission_total")) or None),
        ("kernel_words",
         int(REGISTRY.total("kernel_words_scanned_total")) or None),
        ("events", len(EVENTS) or None),
        ("spans", len(SPANS.ring) or None),
        ("slo", SLO.segment()),
    ]
    return render_line("obs:", [(k, v) for k, v in pairs if v is not None])


def reset() -> None:
    """Zero every series and drop spans/events/cursors plus SLO burn state
    and profiler aggregation (tests, A/B arms). Instrument registrations,
    installed SLO rules and the installed exporter survive."""
    global _span_cursor, _event_cursor
    REGISTRY.reset()
    SPANS.reset()
    EVENTS.reset()
    SLO.reset()
    PROFILER.reset()
    _span_cursor = 0
    _event_cursor = 0
