"""Typed metric instruments and the registry that names them.

Three instrument kinds, all label-aware:

  * `Counter` — monotonic accumulators (queries served, words scanned,
    refits performed); `inc()` only, negative increments raise.
  * `Gauge` — last-write-wins point-in-time values (live generation,
    corpus version, window coverage).
  * `Histogram` — fixed-bucket distributions (latency, span durations);
    bucket bounds are pinned at registration so two snapshots of the same
    series are always mergeable bucket-by-bucket.

Series are keyed by label values (`shard`, `tier`, `solver`, `generation`,
`corpus_version`, ...). A `MetricsRegistry` maps names to instruments
idempotently — registering the same (name, kind, labelnames) twice returns
the same instrument, so callers never coordinate; a conflicting
re-registration raises instead of silently forking the series.

Hot-path cost: every mutator starts with one attribute check of
`_state.on` — with the plane disabled (`REPRO_OBS=0`) nothing else runs.
Detached instruments (constructed directly with `always=True`, e.g. the
loadgen latency histogram) record regardless of the switch, so simulation
outputs never depend on whether telemetry is on.
"""
from __future__ import annotations

import numpy as np

from repro.obs import _state

# latency-shaped default: sub-0.1ms to 1s, roughly x2-x2.5 per step
DEFAULT_BUCKETS = (0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0,
                   100.0, 200.0, 500.0, 1000.0)


class Instrument:
    kind = "instrument"

    def __init__(self, name: str, help: str = "",  # noqa: A002
                 labels: tuple[str, ...] = (), always: bool = False):
        self.name = name
        self.help = help
        self.labelnames = tuple(labels)
        self._always = always
        self._series: dict[tuple, object] = {}

    # -- label plumbing -------------------------------------------------------
    def _key(self, labels: dict) -> tuple:
        if len(labels) != len(self.labelnames) or \
                any(k not in labels for k in self.labelnames):
            raise ValueError(
                f"{self.kind} {self.name!r} takes labels "
                f"{list(self.labelnames)}, got {sorted(labels)}")
        return tuple(str(labels[k]) for k in self.labelnames)

    def labels_of(self, key: tuple) -> dict:
        return dict(zip(self.labelnames, key))

    @property
    def n_series(self) -> int:
        return len(self._series)

    def clear(self) -> None:
        self._series.clear()

    # -- export ---------------------------------------------------------------
    def _export_value(self, value):
        return value

    def to_dict(self) -> dict:
        return {
            "type": self.kind,
            "help": self.help,
            "labels": list(self.labelnames),
            "series": [{"labels": self.labels_of(k),
                        "value": self._export_value(v)}
                       for k, v in sorted(self._series.items())],
        }


class Counter(Instrument):
    kind = "counter"

    def inc(self, value: float = 1, **labels) -> None:
        if not (_state.on or self._always):
            return
        if value < 0:
            raise ValueError(
                f"counter {self.name!r} is monotonic, got inc({value})")
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0) + value

    def value(self, **labels) -> float:
        return self._series.get(self._key(labels), 0)

    def total(self) -> float:
        return sum(self._series.values())


class Gauge(Instrument):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        if not (_state.on or self._always):
            return
        self._series[self._key(labels)] = value

    def value(self, **labels) -> float | None:
        return self._series.get(self._key(labels))


class _HistSeries:
    __slots__ = ("counts", "sum", "count", "min", "max")

    def __init__(self, n_buckets: int):
        self.counts = np.zeros(n_buckets + 1, np.int64)  # +1: overflow
        self.sum = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")


class Histogram(Instrument):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",  # noqa: A002
                 labels: tuple[str, ...] = (), always: bool = False,
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help, labels, always)
        buckets = tuple(float(b) for b in buckets)
        if not buckets or any(b >= a for b, a in zip(buckets, buckets[1:])):
            raise ValueError(
                f"histogram {name!r} needs strictly increasing bucket "
                f"upper bounds, got {buckets}")
        self.buckets = buckets

    def _series_for(self, labels: dict) -> _HistSeries:
        key = self._key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries(len(self.buckets))
        return s

    def observe(self, value: float, **labels) -> None:
        if not (_state.on or self._always):
            return
        s = self._series_for(labels)
        s.counts[int(np.searchsorted(self.buckets, value, side="left"))] += 1
        s.sum += float(value)
        s.count += 1
        s.min = min(s.min, float(value))
        s.max = max(s.max, float(value))

    def observe_many(self, values, **labels) -> None:
        """Vectorized `observe` (the loadgen folds whole latency arrays)."""
        if not (_state.on or self._always):
            return
        v = np.asarray(values, np.float64).ravel()
        if v.size == 0:
            return
        s = self._series_for(labels)
        idx = np.searchsorted(self.buckets, v, side="left")
        s.counts += np.bincount(idx, minlength=len(self.buckets) + 1)
        s.sum += float(v.sum())
        s.count += int(v.size)
        s.min = min(s.min, float(v.min()))
        s.max = max(s.max, float(v.max()))

    def percentile(self, q: float, **labels) -> float:
        """Bucket-interpolated percentile estimate (q in [0, 100])."""
        s = self._series.get(self._key(labels))
        if s is None or s.count == 0:
            return float("nan")
        target = s.count * q / 100.0
        cum = np.cumsum(s.counts)
        b = int(np.searchsorted(cum, target, side="left"))
        if b >= len(self.buckets):          # landed in the overflow bucket
            return s.max
        lo = self.buckets[b - 1] if b > 0 else min(s.min, self.buckets[b])
        hi = self.buckets[b]
        prev = cum[b - 1] if b > 0 else 0
        frac = (target - prev) / max(s.counts[b], 1)
        return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))

    def snapshot(self, **labels) -> dict:
        """One series as a plain dict (the uniform exporter payload)."""
        s = self._series.get(self._key(labels))
        if s is None:
            s = _HistSeries(len(self.buckets))
        return self._export_value(s)

    def _export_value(self, s: _HistSeries) -> dict:
        return {
            "buckets": list(self.buckets),
            "counts": [int(c) for c in s.counts],
            "count": int(s.count),
            "sum": float(s.sum),
            "min": None if s.count == 0 else float(s.min),
            "max": None if s.count == 0 else float(s.max),
        }


class MetricsRegistry:
    """Name -> instrument, idempotent per (name, kind, labelnames)."""

    def __init__(self):
        self._instruments: dict[str, Instrument] = {}

    def _register(self, cls, name: str, help: str,  # noqa: A002
                  labels: tuple[str, ...], **kw) -> Instrument:
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls(name, help, tuple(labels),
                                                 **kw)
            return inst
        if not isinstance(inst, cls) or inst.labelnames != tuple(labels):
            raise ValueError(
                f"metric {name!r} already registered as {inst.kind} with "
                f"labels {list(inst.labelnames)}; cannot re-register as "
                f"{cls.kind} with labels {list(labels)}")
        if isinstance(inst, Histogram) and "buckets" in kw and \
                inst.buckets != tuple(float(b) for b in kw["buckets"]):
            raise ValueError(
                f"histogram {name!r} already registered with buckets "
                f"{inst.buckets}; conflicting buckets {kw['buckets']}")
        return inst

    def counter(self, name: str, help: str = "",  # noqa: A002
                labels: tuple[str, ...] = ()) -> Counter:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",  # noqa: A002
              labels: tuple[str, ...] = ()) -> Gauge:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",  # noqa: A002
                  labels: tuple[str, ...] = (),
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labels, buckets=buckets)

    def get(self, name: str) -> Instrument | None:
        return self._instruments.get(name)

    def names(self) -> list[str]:
        return sorted(self._instruments)

    def __contains__(self, name: str) -> bool:
        return name in self._instruments

    def total(self, name: str, default: float = 0.0) -> float:
        """Sum of a counter's series across all labels (dashboard helper)."""
        inst = self._instruments.get(name)
        if not isinstance(inst, Counter):
            return default
        return inst.total()

    def collect(self) -> dict:
        """The whole registry as a JSON-ready dict (series with any data)."""
        return {name: inst.to_dict()
                for name, inst in sorted(self._instruments.items())
                if inst.n_series}

    def reset(self) -> None:
        """Zero every series; registered instruments (and their identity —
        callers may hold references) survive."""
        for inst in self._instruments.values():
            inst.clear()
