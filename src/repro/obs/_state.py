"""The one global on/off switch for the telemetry plane.

Every instrument's hot-path method begins with a read of the module
attribute `on` — a single no-op attribute check is ALL a disabled plane
costs (pinned by the `obs_overhead` micro-bench and tests/test_obs.py).
`REPRO_OBS=0` disables collection for the whole process at import;
`enable()`/`disable()` flip it at runtime (tests, A/B overhead runs).
"""
from __future__ import annotations

import os

_OFF_VALUES = ("0", "false", "off", "no")

on: bool = os.environ.get("REPRO_OBS", "1").strip().lower() \
    not in _OFF_VALUES


def enable() -> bool:
    """Turn collection on; returns the previous setting."""
    global on
    prev, on = on, True
    return prev


def disable() -> bool:
    """Turn collection off; returns the previous setting."""
    global on
    prev, on = on, False
    return prev


def set_enabled(value: bool) -> bool:
    """Set the switch directly; returns the previous setting."""
    global on
    prev, on = on, bool(value)
    return prev
