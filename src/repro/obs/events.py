"""Structured event log for discrete control-plane occurrences.

Counters say *how much*, spans say *how long*; events say *what happened* —
drift detected, warm-vs-cold refit, replica drain/undrain, corpus swap,
admission accept/reject, rollout begin/done. Each event is one JSON-ready
dict with a monotonic `seq`, wall-clock `t_s`, a `kind`, and free-form
fields, retained in a bounded `Ring`.
"""
from __future__ import annotations

import time

from repro.obs.ring import Ring

DEFAULT_EVENT_CAPACITY = 4096


class EventLog:
    def __init__(self, capacity: int | None = DEFAULT_EVENT_CAPACITY):
        self.ring = Ring(capacity)

    def emit(self, kind: str, **fields) -> dict:
        ev = {"seq": self.ring.n_seen, "t_s": time.time(), "kind": kind}
        ev.update(fields)
        self.ring.append(ev)
        return ev

    @property
    def seq(self) -> int:
        """Count of events ever emitted (drops included)."""
        return self.ring.n_seen

    def since(self, seq: int) -> list[dict]:
        start = self.ring.n_seen - len(self.ring)
        if seq <= start:
            return self.ring.to_list()
        if seq >= self.ring.n_seen:
            return []
        return self.ring[seq - start:]

    def of_kind(self, kind: str) -> list[dict]:
        return [e for e in self.ring if e["kind"] == kind]

    def to_list(self) -> list[dict]:
        return self.ring.to_list()

    def __len__(self) -> int:
        return len(self.ring)

    def reset(self) -> None:
        self.ring = Ring(self.ring.capacity)
