"""Declarative SLO rules + SRE-style multi-window burn-rate alerting.

An `SLORule` names a metric *spec* — a tiny expression language evaluated
against the live `MetricsRegistry` once per export window:

    gauge:NAME[{k=v,...}]   last written value (mean over matching series)
    delta:NAME[{k=v,...}]   counter increase since the previous window
    pQQ:NAME[{k=v,...}]     windowed percentile (QQ in (0, 100]) over a
                            histogram's bucket-count DELTAS since the
                            previous window — the registry histograms are
                            cumulative, so per-window tails need the diff
    ratio:A/B               windowed delta(A) / delta(B) over two counter
                            targets; None while the denominator is flat

plus a bound (`max=` and/or `min=`) saying what good looks like. Each
window the rule's indicator (in/out of bound) feeds two sliding burn
windows — a fast one for paging-grade spikes and a slow one so a single
blip doesn't alarm — and an alert fires only when BOTH burn fractions
exceed their thresholds (the multi-window burn-rate pattern from the SRE
workbook). Recovery has hysteresis: `clear_windows` consecutive good
windows before `slo_recovered`. Transitions land in the `EventLog`
(`slo_breach` / `slo_recovered`), increment `slo_breaches_total{rule=}`,
and the full per-rule status rides in every JSONL window snapshot under
`"slo"` (see `repro.obs.snapshot_window`).

A rule with `when=` only counts windows where the guard spec clears
`when_min` — e.g. the refit wall-clock budget is judged only on windows
that actually refit, so a stale gauge never alarms.

Everything is inert under `REPRO_OBS=0`: `evaluate` returns `{}` without
touching rule state, so disabled runs stay bit-identical.
"""
from __future__ import annotations

import collections
import dataclasses
import re

import numpy as np

from repro.obs import _state
from repro.obs.registry import Counter, Gauge, Histogram, MetricsRegistry

_TARGET_RE = re.compile(r"^(?P<name>[A-Za-z_][A-Za-z0-9_]*)"
                        r"(?:\{(?P<filt>[^}]*)\})?$")


@dataclasses.dataclass(frozen=True)
class SLORule:
    """One objective: a metric spec, its bound, and burn-rate shaping."""
    name: str                    # rule id ("serve_p95", ...)
    metric: str                  # spec, e.g. "p95:loadgen_latency_ms"
    max: float | None = None     # breach indicator when value > max
    min: float | None = None     # breach indicator when value < min
    fast_windows: int = 1        # paging window (recent windows)
    slow_windows: int = 4        # confirmation window
    fast_burn: float = 1.0       # bad fraction of the fast window to alarm
    slow_burn: float = 0.5       # bad fraction of the slow window to alarm
    clear_windows: int = 2       # consecutive good windows to recover
    when: str | None = None      # guard spec: window counts only when ...
    when_min: float = 1.0        # ... eval(when) >= when_min

    def __post_init__(self):
        if self.max is None and self.min is None:
            raise ValueError(f"SLO rule {self.name!r} needs max= or min=")
        if self.fast_windows < 1 or self.slow_windows < self.fast_windows:
            raise ValueError(
                f"SLO rule {self.name!r} needs 1 <= fast_windows "
                f"<= slow_windows, got {self.fast_windows}/{self.slow_windows}")


class _RuleState:
    __slots__ = ("history", "breached", "last")

    def __init__(self):
        self.history: collections.deque = collections.deque(maxlen=64)
        self.breached = False
        self.last: dict[str, object] = {}   # spec -> prior cumulative value


def _parse_target(text: str) -> tuple[str, dict[str, str]]:
    m = _TARGET_RE.match(text.strip())
    if not m:
        raise ValueError(f"bad SLO metric target {text!r} "
                         "(want NAME or NAME{label=value,...})")
    filt = {}
    if m.group("filt"):
        for part in m.group("filt").split(","):
            k, sep, v = part.partition("=")
            if not sep:
                raise ValueError(f"bad label filter {part!r} in {text!r}")
            filt[k.strip()] = v.strip()
    return m.group("name"), filt


def _matching_series(inst, filt: dict) -> list:
    return [s for s in inst.to_dict()["series"]
            if all(s["labels"].get(k) == v for k, v in filt.items())]


def _percentile_of_counts(buckets: list[float], counts: np.ndarray,
                          q: float) -> float:
    """Bucket-interpolated percentile over windowed count deltas. The last
    entry of `counts` is the overflow bucket; a target landing there clamps
    to the top bound (the delta's true max is unknowable)."""
    target = counts.sum() * q / 100.0
    cum = np.cumsum(counts)
    b = int(np.searchsorted(cum, target, side="left"))
    if b >= len(buckets):
        return float(buckets[-1])
    lo = buckets[b - 1] if b > 0 else 0.0
    hi = buckets[b]
    prev = cum[b - 1] if b > 0 else 0.0
    frac = (target - prev) / max(counts[b], 1)
    return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))


class SLOEngine:
    """Evaluates the installed rules against a registry, once per window."""

    def __init__(self, registry: MetricsRegistry, events):
        self.registry = registry
        self.events = events
        self.rules: list[SLORule] = []
        self._rule_state: dict[str, _RuleState] = {}
        self._breaches = registry.counter(
            "slo_breaches_total", "good->breach transitions per SLO rule",
            labels=("rule",))

    # -- rule management ------------------------------------------------------
    def set_rules(self, rules) -> "SLOEngine":
        self.rules = list(rules)
        self._rule_state.clear()
        return self

    def add_rule(self, rule: SLORule) -> "SLOEngine":
        self.rules.append(rule)
        return self

    def reset(self) -> None:
        """Drop burn/breach/delta state; the installed rules survive."""
        self._rule_state.clear()

    # -- spec evaluation ------------------------------------------------------
    def _eval_spec(self, spec: str, st: _RuleState) -> float | None:
        kind, sep, rest = spec.partition(":")
        if not sep:
            raise ValueError(f"bad SLO metric spec {spec!r} (want KIND:...)")
        if kind == "ratio":
            num, sep, den = rest.partition("/")
            if not sep:
                raise ValueError(f"ratio spec {spec!r} wants NUM/DEN")
            da = self._eval_spec(f"delta:{num.strip()}", st)
            db = self._eval_spec(f"delta:{den.strip()}", st)
            if da is None or not db:
                return None
            return da / db
        if kind == "gauge":
            name, filt = _parse_target(rest)
            inst = self.registry.get(name)
            if not isinstance(inst, Gauge):
                return None
            series = _matching_series(inst, filt)
            if not series:
                return None
            return float(np.mean([s["value"] for s in series]))
        if kind == "delta":
            name, filt = _parse_target(rest)
            inst = self.registry.get(name)
            if not isinstance(inst, Counter):
                return None
            cur = float(sum(s["value"]
                            for s in _matching_series(inst, filt)))
            prev = st.last.get(spec)
            st.last[spec] = cur
            if prev is None:
                return cur                    # counters start at 0 per run
            return max(cur - float(prev), 0.0)   # obs.reset() rewinds them
        if kind.startswith("p"):
            q = float(kind[1:])
            if not 0.0 < q <= 100.0:
                raise ValueError(f"percentile spec {spec!r} wants p(0,100]")
            name, filt = _parse_target(rest)
            inst = self.registry.get(name)
            if not isinstance(inst, Histogram):
                return None
            series = _matching_series(inst, filt)
            counts = np.zeros(len(inst.buckets) + 1, np.int64)
            for s in series:
                counts += np.asarray(s["value"]["counts"], np.int64)
            prev = st.last.get(spec)
            st.last[spec] = counts
            delta = counts if prev is None else \
                np.maximum(counts - np.asarray(prev, np.int64), 0)
            if delta.sum() == 0:
                return None                   # no new observations: N/A
            return _percentile_of_counts(list(inst.buckets), delta, q)
        raise ValueError(f"unknown SLO metric spec kind {kind!r} in {spec!r}")

    # -- the per-window pass --------------------------------------------------
    def evaluate(self, window: int) -> dict:
        """Evaluate every rule against the current registry; emits breach /
        recovery transitions and returns the JSON-ready status payload.
        Complete no-op (returns {}) when the plane is disabled."""
        if not _state.on or not self.rules:
            return {}
        out: dict[str, dict] = {}
        for r in self.rules:
            st = self._rule_state.setdefault(r.name, _RuleState())
            # prime the series so the counter exports even when never burned
            self._breaches.inc(0, rule=r.name)
            value = self._eval_spec(r.metric, st)
            applicable = True
            if r.when is not None:
                guard = self._eval_spec(r.when, st)
                applicable = guard is not None and guard >= r.when_min
            bad = None
            if applicable and value is not None:
                bad = bool((r.max is not None and value > r.max)
                           or (r.min is not None and value < r.min))
                st.history.append(1.0 if bad else 0.0)
            h = list(st.history)
            fast = float(np.mean(h[-r.fast_windows:])) if h else 0.0
            slow = float(np.mean(h[-r.slow_windows:])) if h else 0.0
            transition = None
            if not st.breached:
                if len(h) >= r.fast_windows and fast >= r.fast_burn \
                        and slow >= r.slow_burn:
                    st.breached = True
                    transition = "slo_breach"
                    self._breaches.inc(1, rule=r.name)
            else:
                tail = h[-r.clear_windows:]
                if len(tail) >= r.clear_windows and not any(tail):
                    st.breached = False
                    transition = "slo_recovered"
            if transition:
                self.events.emit(transition, rule=r.name, window=window,
                                 metric=r.metric, value=value,
                                 max=r.max, min=r.min,
                                 fast_burn=round(fast, 4),
                                 slow_burn=round(slow, 4))
            out[r.name] = {"value": value, "bad": bad,
                           "breached": st.breached,
                           "fast_burn": round(fast, 4),
                           "slow_burn": round(slow, 4)}
        return {"rules": out,
                "breached": sorted(n for n, s in out.items()
                                   if s["breached"])}

    # -- status ---------------------------------------------------------------
    def breached(self) -> list[str]:
        return sorted(n for n, s in self._rule_state.items() if s.breached)

    def segment(self) -> str | None:
        """The dashboard fragment: None without rules, else ok/BREACH."""
        if not self.rules:
            return None
        b = self.breached()
        return f"BREACH({','.join(b)})" if b else f"ok({len(self.rules)})"


def default_slo_rules() -> list[SLORule]:
    """The fleet defaults the launchers install: generous bounds meant to
    catch pathologies (runaway tails, collapsed coverage, refits eating the
    window), not to page on tiny-scale noise."""
    return [
        SLORule("serve_p95", "p95:loadgen_latency_ms", max=250.0,
                fast_windows=1, slow_windows=4),
        SLORule("serve_p99", "p99:loadgen_latency_ms", max=1000.0,
                fast_windows=1, slow_windows=4),
        SLORule("coverage_floor", "gauge:window_coverage", min=0.01,
                fast_windows=2, slow_windows=4, fast_burn=1.0,
                slow_burn=0.5),
        SLORule("t2_fallback_rate",
                "ratio:cluster_fallback_batches_total/cluster_queries_total",
                max=0.5),
        SLORule("refit_budget", "gauge:refit_seconds", max=120.0,
                when="delta:refits_total", when_min=1.0),
        # secretary admission legitimately rejects almost every offer under
        # tight headroom; alarm only when essentially NOTHING gets through
        SLORule("admission_reject_rate",
                "ratio:admission_total{decision=reject}/admission_total",
                max=0.999, fast_windows=2, slow_windows=4),
        # front-end (cluster.frontend): a cache that stops hitting entirely
        # under repeat traffic means epoch churn or key instability (the
        # ratio is None — rule inert — until lookups actually flow), and a
        # fleet shedding most of its traffic is answering degraded
        SLORule("cache_hit_rate_floor",
                "ratio:frontend_cache_hits_total/frontend_cache_lookups_total",
                min=0.001, fast_windows=2, slow_windows=6),
        SLORule("shed_ratio_ceiling", "gauge:loadgen_shed_frac", max=0.5,
                fast_windows=2, slow_windows=4),
    ]
