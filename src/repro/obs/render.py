"""One renderer for every human-facing report line.

`WindowReport.line()`, `IngestWindowReport.line()`, `StreamReport.summary()`,
`IngestReport.summary()` and the launchers' dashboard all used to hand-roll
their own f-strings; they now build `(key, value)` pairs and let
`render_line` format them uniformly: floats to 3 decimals, bools as
`ok`/`FAIL`, `None` values skipped, sequences compact.
"""
from __future__ import annotations


def fmt_value(value) -> str:
    if isinstance(value, bool):
        return "ok" if value else "FAIL"
    if isinstance(value, float):
        return f"{value:.3f}"
    if isinstance(value, (list, tuple)):
        return "[" + ",".join(fmt_value(v) for v in value) + "]"
    return str(value)


def render_line(tag: str, fields, *, sep: str = "  ") -> str:
    """`tag  k1=v1  k2=v2 ...`; fields is a dict or (key, value) pairs.

    A `None` value drops the pair; a key starting with `@` renders the
    value bare (no `key=` prefix) — for pre-formatted fragments like
    `window  12` or `+3docs`.
    """
    pairs = fields.items() if hasattr(fields, "items") else fields
    parts = [tag] if tag else []
    for k, v in pairs:
        if v is None:
            continue
        parts.append(fmt_value(v) if k.startswith("@") else
                     f"{k}={fmt_value(v)}")
    return sep.join(parts)
