"""Conjunctive matching over packed postings (paper §2.1, eq. 1).

m(q) = ∩_{v∈q} postings(v) — computed as an AND-reduce over packed doc
bitsets. Batched for serving: a [B, L]-padded token-id batch produces a
[B, Wd] packed match-set batch in one jitted call. Works against either the
full (Tier-2) postings or a Tier-1 sub-index produced by `tier_postings`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset


@jax.jit
def match_batch(postings: jnp.ndarray,       # uint32 [V, W]
                tokens: jnp.ndarray,         # int32 [B, L], -1 padded
                ) -> jnp.ndarray:            # uint32 [B, W]
    """AND of postings rows per query; padded slots contribute all-ones."""
    valid = tokens >= 0
    rows = postings[jnp.where(valid, tokens, 0)]            # [B, L, W]
    rows = jnp.where(valid[..., None], rows, jnp.uint32(0xFFFFFFFF))
    return jax.lax.reduce(rows, jnp.uint32(0xFFFFFFFF),
                          jax.lax.bitwise_and, (1,))


def tier_postings(postings: np.ndarray, tier1_docs: np.ndarray) -> np.ndarray:
    """Restrict a postings matrix to Tier-1 documents.

    Production would re-index with a compacted doc-id space; for the
    measurement harness we keep global ids and mask, which preserves
    result-set semantics exactly.
    """
    t1 = bitset.np_pack(tier1_docs)
    return postings & t1[None, :]


def pad_token_batch(queries: list[tuple[int, ...]], pad_len: int | None = None) -> np.ndarray:
    l = pad_len or max((len(q) for q in queries), default=1)
    out = np.full((len(queries), l), -1, np.int32)
    for i, q in enumerate(queries):
        out[i, :len(q)] = list(q)[:l]
    return out


def pack_query_bits(queries: list[tuple[int, ...]], vocab_size: int) -> np.ndarray:
    """Token tuples -> packed vocab bitsets [B, Wv] (ψ^clause operand)."""
    qbits = np.zeros((len(queries), vocab_size), bool)
    for i, q in enumerate(queries):
        qbits[i, list(q)] = True
    return bitset.np_pack(qbits)


def classify_batch(clause_vocab_bits: np.ndarray,
                   queries: list[tuple[int, ...]], vocab_size: int,
                   *, backend: str | None = None) -> np.ndarray:
    """Batched ψ^clause (eq. 8) through the clause-subset-test kernel.

    One kernel call per serving batch; semantically identical to
    `ClauseTiering.classify_queries` (the per-query host reference).
    """
    from repro.kernels import ops
    if len(queries) == 0 or clause_vocab_bits.shape[0] == 0:
        return np.zeros(len(queries), bool)
    qbits = pack_query_bits(queries, vocab_size)
    return np.asarray(ops.clause_match(
        jnp.asarray(qbits), jnp.asarray(clause_vocab_bits), backend=backend))
