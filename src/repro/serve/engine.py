"""Two-tier serving engine (paper Fig. 1) with clause query classification.

Request path per batch:
  1. ψ^clause — packed subset test of the selected clauses against each query
  2. eligible queries  -> Tier-1 match (postings restricted to D₁)
  3. ineligible queries -> Tier-2 match (full postings)
Theorem 3.1 guarantees step 2 returns the COMPLETE match set for eligible
queries; `TieredEngine.serve` asserts nothing silently — the integration test
compares every result against single-tier matching.

Cost accounting: Tier-1 postings only index |D₁| docs, so a Tier-1 match
touches ~|D₁|/|D| of the word traffic — the engine reports both tiers' word
traffic so benchmarks can translate coverage into served-cost savings (the
paper's "half-sized Tier 1 needs half the machines" argument, §2.2).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bitset
from repro.core.tiering import ClauseTiering
from repro.serve import matching

# registry instruments the engine publishes into (self-gating: these are
# no-ops under REPRO_OBS=0, and ServeStats stays the source of truth either
# way — the counters are a fleet-aggregated VIEW, never an input)
_QUERIES = obs.counter("serve_queries_total", "queries served")
_T1_HITS = obs.counter("serve_tier1_hits_total",
                       "queries answered entirely from Tier 1")
_WORDS = obs.counter("serve_words_total",
                     "postings words scanned", labels=("tier",))


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    n_tier1: int = 0
    tier1_words: int = 0            # postings words scanned in tier 1
    tier2_words: int = 0
    full_words_per_query: int = 0   # untiered per-query traffic (denominator)
    cache_hits: int = 0             # front-end result-cache hits (zero words
    #                                 scanned; cluster.frontend.ResultCache)

    @property
    def tier1_fraction(self) -> float:
        return self.n_tier1 / max(1, self.n_queries)

    @property
    def cache_hit_rate(self) -> float:
        return self.cache_hits / max(1, self.n_queries)

    @property
    def cost_saving(self) -> float:
        """Word-traffic saving vs an untiered (Tier-2-only) system."""
        base = self.n_queries * self.full_words_per_query
        if base == 0:
            return 0.0
        return 1.0 - (self.tier1_words + self.tier2_words) / base

    def reset(self) -> None:
        """Zero the traffic counters (window boundary); the engine-constant
        `full_words_per_query` survives so ratios keep meaning."""
        self.n_queries = self.n_tier1 = 0
        self.tier1_words = self.tier2_words = 0
        self.cache_hits = 0

    def merge(self, other: "ServeStats") -> "ServeStats":
        """Fold another window's counters into this one, in place."""
        if self.full_words_per_query == 0:
            self.full_words_per_query = other.full_words_per_query
        elif other.full_words_per_query not in (0, self.full_words_per_query):
            raise ValueError(
                "merging stats from engines with different postings widths "
                f"({self.full_words_per_query} vs {other.full_words_per_query})")
        self.n_queries += other.n_queries
        self.n_tier1 += other.n_tier1
        self.tier1_words += other.tier1_words
        self.tier2_words += other.tier2_words
        self.cache_hits += other.cache_hits
        return self

    def snapshot(self) -> "ServeStats":
        """Detached copy (per-window reporting while counters keep running)."""
        return dataclasses.replace(self)

    def to_dict(self) -> dict:
        """JSON-ready dict: raw counters + the derived ratios (the uniform
        exporter payload; `from_dict` ignores the derived keys)."""
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        d["tier1_fraction"] = self.tier1_fraction
        d["cost_saving"] = self.cost_saving
        d["cache_hit_rate"] = self.cache_hit_rate
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServeStats":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclasses.dataclass(frozen=True)
class TieringBuffer:
    """An off-path-built Tier-1 generation, ready to swap in."""
    tiering: ClauseTiering
    postings_t1: jnp.ndarray
    tier1_words_per_query: int
    generation: int = 0


class TieredEngine:
    def __init__(self, postings: np.ndarray, tiering: ClauseTiering,
                 n_docs: int):
        self.n_docs = n_docs
        self.corpus_version = 0
        self._postings_host = np.asarray(postings)   # for re-tiering builds
        self.postings_t2 = jnp.asarray(postings)
        self._live = self.prepare_tiering(tiering)   # generation 0
        self.stats = ServeStats(
            full_words_per_query=postings.shape[1])

    # the live generation is ONE reference: readers grab self._live once per
    # batch, so (ψ, Tier-1 index) always come from the same clause selection
    @property
    def tiering(self) -> ClauseTiering:
        return self._live.tiering

    @property
    def postings_t1(self) -> jnp.ndarray:
        return self._live.postings_t1

    @property
    def tier1_words_per_query(self) -> int:
        return self._live.tier1_words_per_query

    @property
    def generation(self) -> int:
        return self._live.generation

    # -- zero-downtime re-tiering ---------------------------------------------
    def prepare_tiering(self, tiering: ClauseTiering) -> TieringBuffer:
        """Build the next Tier-1 generation OFF the request path.

        All the expensive work — masking the postings matrix to the new D₁
        and shipping it to device — happens here, against local buffers; the
        live generation keeps serving untouched.
        """
        postings_t1 = jnp.asarray(
            matching.tier_postings(self._postings_host, tiering.tier1_docs))
        # a production Tier-1 re-indexes with a compacted |D1| doc space:
        # its per-query word traffic is ceil(|D1|/32), not the full W.
        words = bitset.n_words(int(tiering.tier1_docs.sum()))
        return TieringBuffer(tiering=tiering, postings_t1=postings_t1,
                             tier1_words_per_query=words)

    def swap_tiering(self, tiering: ClauseTiering | TieringBuffer) -> int:
        """Atomically route traffic to a new tiering; returns the generation.

        Accepts either a raw `ClauseTiering` (built off-path here) or a
        `TieringBuffer` from `prepare_tiering`. The commit is a SINGLE
        reference store of the whole generation, and `serve` reads that
        reference exactly once per batch — a batch sees either the old
        (ψ, Tier-1 index) pair or the new one, never a mix, so Theorem 3.1
        completeness holds on both sides of the swap.
        """
        buf = tiering if isinstance(tiering, TieringBuffer) \
            else self.prepare_tiering(tiering)
        self._live = dataclasses.replace(
            buf, generation=self._live.generation + 1)
        obs.event("tiering_swap", generation=self._live.generation,
                  corpus_version=self.corpus_version)
        return self._live.generation

    def swap_corpus(self, postings: np.ndarray, n_docs: int,
                    tiering: ClauseTiering, *,
                    immediate: bool = True) -> int:
        """Swap to an appended corpus snapshot (repro.ingest).

        A single engine has one copy of each tier, so the swap is
        stop-the-world by nature: both tiers and ψ move in one reference
        store between batches (`immediate` is accepted for cluster-facade
        parity but a single engine cannot roll). Append-only growth means
        every already-served match set stays valid at the new version.
        """
        del immediate                    # single engine: always atomic
        postings = np.asarray(postings)
        if n_docs < self.n_docs or \
                postings.shape[1] < self._postings_host.shape[1]:
            raise ValueError(
                f"corpus swaps are append-only: got {n_docs} docs x "
                f"{postings.shape[1]} words, have {self.n_docs} x "
                f"{self._postings_host.shape[1]}")
        self._postings_host = postings
        self.postings_t2 = jnp.asarray(postings)
        self.n_docs = n_docs
        self.corpus_version += 1
        self.stats.full_words_per_query = int(postings.shape[1])
        obs.event("corpus_swap", corpus_version=self.corpus_version,
                  n_docs=self.n_docs, mode="immediate")
        return self.swap_tiering(tiering)

    @staticmethod
    def _classify(tiering: ClauseTiering,
                  queries: list[tuple[int, ...]]) -> np.ndarray:
        # batched ψ^clause via the clause-subset-test kernel — one call per
        # batch (the old per-query host path lives on as the test reference
        # in ClauseTiering.classify_queries)
        return matching.classify_batch(
            tiering.clause_vocab_bits, queries, tiering.vocab_size)

    def classify(self, queries: list[tuple[int, ...]]) -> np.ndarray:
        return self._classify(self._live.tiering, queries)

    def serve(self, queries: list[tuple[int, ...]]) -> list[np.ndarray]:
        """Returns the match set (sorted doc ids) per query."""
        live = self._live                    # one read: a consistent generation
        with obs.span("serve", n=len(queries), generation=live.generation):
            with obs.span("classify"):
                elig = self._classify(live.tiering, queries)
            toks = matching.pad_token_batch(queries)
            out: list[np.ndarray | None] = [None] * len(queries)
            w = self.postings_t2.shape[1]
            matched: list[tuple[np.ndarray, np.ndarray]] = []
            for tier, sel in ((1, elig), (2, ~elig)):
                idx = np.nonzero(sel)[0]
                if len(idx) == 0:
                    continue
                postings = live.postings_t1 if tier == 1 else self.postings_t2
                with obs.span("t1_match" if tier == 1 else "t2_match",
                              n=int(len(idx))) as sp:
                    m = np.asarray(sp.sync(
                        matching.match_batch(postings, jnp.asarray(toks[idx]))))
                matched.append((idx, m))
                if tier == 1:
                    self.stats.n_tier1 += len(idx)
                    self.stats.tier1_words += \
                        len(idx) * live.tier1_words_per_query
                    _WORDS.inc(len(idx) * live.tier1_words_per_query,
                               tier="t1")
                else:
                    self.stats.tier2_words += len(idx) * w
                    _WORDS.inc(len(idx) * w, tier="t2")
            with obs.span("merge"):
                for idx, m in matched:
                    for row, qi in enumerate(idx):
                        out[qi] = bitset.np_to_indices(m[row], self.n_docs)
            self.stats.n_queries += len(queries)
            _QUERIES.inc(len(queries))
            _T1_HITS.inc(int(np.count_nonzero(elig)))
        return [o if o is not None else np.empty(0, np.int64) for o in out]

    def serve_reference(self, queries: list[tuple[int, ...]]) -> list[np.ndarray]:
        """Single-tier oracle for correctness tests."""
        toks = matching.pad_token_batch(queries)
        m = np.asarray(matching.match_batch(self.postings_t2, jnp.asarray(toks)))
        return [bitset.np_to_indices(r, self.n_docs) for r in m]
