"""Two-tier serving engine (paper Fig. 1) with clause query classification.

Request path per batch:
  1. ψ^clause — packed subset test of the selected clauses against each query
  2. eligible queries  -> Tier-1 match (postings restricted to D₁)
  3. ineligible queries -> Tier-2 match (full postings)
Theorem 3.1 guarantees step 2 returns the COMPLETE match set for eligible
queries; `TieredEngine.serve` asserts nothing silently — the integration test
compares every result against single-tier matching.

Cost accounting: Tier-1 postings only index |D₁| docs, so a Tier-1 match
touches ~|D₁|/|D| of the word traffic — the engine reports both tiers' word
traffic so benchmarks can translate coverage into served-cost savings (the
paper's "half-sized Tier 1 needs half the machines" argument, §2.2).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import bitset
from repro.core.tiering import ClauseTiering
from repro.serve import matching


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    n_tier1: int = 0
    tier1_words: int = 0      # postings words scanned in tier 1
    tier2_words: int = 0

    @property
    def tier1_fraction(self) -> float:
        return self.n_tier1 / max(1, self.n_queries)

    full_words_per_query: int = 0

    @property
    def cost_saving(self) -> float:
        """Word-traffic saving vs an untiered (Tier-2-only) system."""
        base = self.n_queries * self.full_words_per_query
        if base == 0:
            return 0.0
        return 1.0 - (self.tier1_words + self.tier2_words) / base


class TieredEngine:
    def __init__(self, postings: np.ndarray, tiering: ClauseTiering,
                 n_docs: int):
        self.n_docs = n_docs
        self.tiering = tiering
        self.postings_t2 = jnp.asarray(postings)
        # tier-1 sub-index: only D₁ columns survive
        self.postings_t1 = jnp.asarray(
            matching.tier_postings(postings, tiering.tier1_docs))
        # a production Tier-1 re-indexes with a compacted |D1| doc space:
        # its per-query word traffic is ceil(|D1|/32), not the full W.
        self.tier1_words_per_query = bitset.n_words(int(tiering.tier1_docs.sum()))
        self.stats = ServeStats(
            full_words_per_query=postings.shape[1])

    def classify(self, queries: list[tuple[int, ...]]) -> np.ndarray:
        qbits = np.zeros((len(queries), self.tiering.vocab_size), bool)
        for i, q in enumerate(queries):
            qbits[i, list(q)] = True
        return self.tiering.classify_queries(bitset.np_pack(qbits))

    def serve(self, queries: list[tuple[int, ...]]) -> list[np.ndarray]:
        """Returns the match set (sorted doc ids) per query."""
        elig = self.classify(queries)
        toks = matching.pad_token_batch(queries)
        out: list[np.ndarray | None] = [None] * len(queries)
        w = self.postings_t2.shape[1]
        for tier, sel in ((1, elig), (2, ~elig)):
            idx = np.nonzero(sel)[0]
            if len(idx) == 0:
                continue
            postings = self.postings_t1 if tier == 1 else self.postings_t2
            m = np.asarray(matching.match_batch(postings, jnp.asarray(toks[idx])))
            for row, qi in enumerate(idx):
                out[qi] = bitset.np_to_indices(m[row], self.n_docs)
            if tier == 1:
                self.stats.n_tier1 += len(idx)
                self.stats.tier1_words += len(idx) * self.tier1_words_per_query
            else:
                self.stats.tier2_words += len(idx) * w
        self.stats.n_queries += len(queries)
        return [o if o is not None else np.empty(0, np.int64) for o in out]

    def serve_reference(self, queries: list[tuple[int, ...]]) -> list[np.ndarray]:
        """Single-tier oracle for correctness tests."""
        toks = matching.pad_token_batch(queries)
        m = np.asarray(matching.match_batch(self.postings_t2, jnp.asarray(toks)))
        return [bitset.np_to_indices(r, self.n_docs) for r in m]
