"""Decoder-only transformer LM family (5 assigned archs).

Features per the assigned configs: GQA, RoPE, local/global attention
alternation (gemma2 1:1, gemma3 5:1), attention + final logit softcaps
(gemma2), QK-norm (gemma3), dense SwiGLU or MoE FFN (kimi-k2, llama4),
tied/untied embeddings, scan-over-layers with remat, chunked flash-style
attention, sequence-chunked cross-entropy, KV-cache decode.

Everything is shape-static and lowers on abstract inputs; MoE layers use
the ambient-mesh expert-parallel shard_map (models/moe.py).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common
from repro.models.moe import MoEConfig, init_moe_params, moe_apply

P = jax.sharding.PartitionSpec


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    moe: MoEConfig | None = None
    rope_theta: float = 10000.0
    local_window: int | None = None     # sliding window for local layers
    global_every: int = 0               # 0: all-global; n: every n-th layer global
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qk_norm: bool = False
    tie_embeddings: bool = True
    embed_scale: bool = False           # gemma-style sqrt(D) embedding scale
    dtype: str = "bfloat16"             # activation dtype
    param_dtype: str = "float32"        # storage dtype (bf16 for 1T configs)
    remat: bool = True
    xent_chunk: int = 512
    attn_chunk: int = 1024
    pure_full_attention: bool = False   # True => long_500k cell is skipped
    # cost-probe knobs (dry-run only): XLA cost analysis counts scan bodies
    # once, so probes unroll the layer stack and the attention KV chunks
    unroll_layers: bool = False
    attn_unroll: bool = False

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    def is_global_layer(self) -> np.ndarray:
        if self.global_every <= 0 or self.local_window is None:
            return np.ones(self.n_layers, bool)
        idx = np.arange(self.n_layers)
        return (idx % self.global_every) == (self.global_every - 1)

    def param_count(self) -> int:
        """Exact parameter count (for MODEL_FLOPS = 6·N·D bookkeeping)."""
        p = self.vocab_size * self.d_model          # embed
        if not self.tie_embeddings:
            p += self.d_model * self.vocab_size
        per_layer = (self.d_model * (self.n_heads + 2 * self.n_kv_heads)
                     * self.d_head
                     + self.n_heads * self.d_head * self.d_model
                     + 2 * self.d_model)
        if self.qk_norm:
            per_layer += 2 * self.d_head
        if self.moe is not None:
            per_layer += self.d_model * self.moe.n_experts
            per_layer += self.moe.n_experts * 3 * self.d_model * self.moe.d_expert
        else:
            per_layer += 3 * self.d_model * self.d_ff
        return p + self.n_layers * per_layer + self.d_model

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        full_experts = self.n_layers * self.moe.n_experts * 3 * self.d_model * self.moe.d_expert
        active_experts = self.n_layers * self.moe.top_k * 3 * self.d_model * self.moe.d_expert
        return self.param_count() - full_experts + active_experts


# -----------------------------------------------------------------------------
# params
# -----------------------------------------------------------------------------

def init_params(rng: jax.Array, cfg: TransformerConfig) -> dict:
    keys = jax.random.split(rng, 8)
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    l = cfg.n_layers

    def stack(fn, key):
        return jax.vmap(fn)(jax.random.split(key, l))

    def layer_attn(k):
        ks = jax.random.split(k, 4)
        return {
            "wq": common.dense_init(ks[0], (d, h * dh)),
            "wk": common.dense_init(ks[1], (d, kv * dh)),
            "wv": common.dense_init(ks[2], (d, kv * dh)),
            "wo": common.dense_init(ks[3], (h * dh, d)) / math.sqrt(2 * l),
        }

    def layer_ffn(k):
        if cfg.moe is not None:
            return init_moe_params(k, d, cfg.moe)
        ks = jax.random.split(k, 3)
        return {
            "w1": common.dense_init(ks[0], (d, cfg.d_ff)),
            "w3": common.dense_init(ks[1], (d, cfg.d_ff)),
            "w2": common.dense_init(ks[2], (cfg.d_ff, d)) / math.sqrt(2 * l),
        }

    params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, d)) * 0.01,
        "layers": {
            "attn": stack(layer_attn, keys[1]),
            "ffn": stack(layer_ffn, keys[2]),
            "ln1": jnp.zeros((l, d)),
            "ln2": jnp.zeros((l, d)),
        },
        "final_norm": jnp.zeros(d),
    }
    if cfg.qk_norm:
        params["layers"]["qnorm"] = jnp.zeros((l, dh))
        params["layers"]["knorm"] = jnp.zeros((l, dh))
    if not cfg.tie_embeddings:
        params["unembed"] = common.dense_init(keys[3], (d, cfg.vocab_size))
    pdt = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(lambda p: p.astype(pdt), params)


def param_specs(cfg: TransformerConfig) -> dict:
    """PartitionSpecs matching init_params' tree (megatron-style TP over
    'model'; FSDP over 'data' is applied on top by the trainer when on)."""
    attn = {"wq": P(None, None, "model"), "wk": P(None, None, "model"),
            "wv": P(None, None, "model"), "wo": P(None, "model", None)}
    if cfg.moe is not None:
        ffn = {"gate": P(None, None, None),
               "w1": P(None, "model", None, None),
               "w3": P(None, "model", None, None),
               "w2": P(None, "model", None, None)}
    else:
        ffn = {"w1": P(None, None, "model"), "w3": P(None, None, "model"),
               "w2": P(None, "model", None)}
    specs = {
        "embed": P(None, "model"),
        "layers": {"attn": attn, "ffn": ffn,
                   "ln1": P(None, None), "ln2": P(None, None)},
        "final_norm": P(None),
    }
    if cfg.qk_norm:
        specs["layers"]["qnorm"] = P(None, None)
        specs["layers"]["knorm"] = P(None, None)
    if not cfg.tie_embeddings:
        specs["unembed"] = P(None, "model")
    return specs


# -----------------------------------------------------------------------------
# forward
# -----------------------------------------------------------------------------

_NO_WINDOW = 1 << 30


def _attention_block(cfg: TransformerConfig, lp: dict, h: jnp.ndarray,
                     window: jnp.ndarray, *, positions, kv_len=None,
                     cache_kv=None):
    """Returns (attn_out, (k_new, v_new)). cache_kv: (k,v) [B,Smax,kv,dh]."""
    b, s, d = h.shape
    nh, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    a = common.rms_norm(h, lp["ln1"])
    q = (a @ lp["attn"]["wq"].astype(a.dtype)).reshape(b, s, nh, dh)
    k = (a @ lp["attn"]["wk"].astype(a.dtype)).reshape(b, s, nkv, dh)
    v = (a @ lp["attn"]["wv"].astype(a.dtype)).reshape(b, s, nkv, dh)
    if cfg.qk_norm:
        q = common.rms_norm(q, lp["qnorm"])
        k = common.rms_norm(k, lp["knorm"])
    q = common.rope(q, positions, cfg.rope_theta)
    k = common.rope(k, positions, cfg.rope_theta)

    if cache_kv is None:
        out = common.chunked_attention(
            q, k, v, causal=True, window=window, cap=cfg.attn_softcap,
            chunk=min(cfg.attn_chunk, s), q_offset=0,
            unroll=cfg.attn_unroll)
        k_new, v_new = k, v
    else:
        ck, cv = cache_kv
        pos0 = positions[0]
        k_new = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                             (0, pos0, 0, 0))
        v_new = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                             (0, pos0, 0, 0))
        out = common.chunked_attention(
            q, k_new, v_new, causal=True, window=window, cap=cfg.attn_softcap,
            chunk=min(cfg.attn_chunk, k_new.shape[1]),
            q_offset=pos0, kv_len=kv_len, unroll=cfg.attn_unroll)
    out = out.reshape(b, s, nh * dh)
    return out @ lp["attn"]["wo"].astype(out.dtype), (k_new, v_new)


def _ffn_block(cfg: TransformerConfig, lp: dict, h: jnp.ndarray):
    b, s, d = h.shape
    m = common.rms_norm(h, lp["ln2"])
    if cfg.moe is not None:
        y, aux = moe_apply(lp["ffn"], m.reshape(b * s, d), cfg.moe)
        return y.reshape(b, s, d), aux
    w = lp["ffn"]
    hh = jax.nn.silu(m @ w["w1"].astype(m.dtype)) * (m @ w["w3"].astype(m.dtype))
    return hh @ w["w2"].astype(m.dtype), jnp.float32(0.0)


def _window_of(cfg: TransformerConfig, is_global: jnp.ndarray) -> jnp.ndarray:
    w = cfg.local_window if cfg.local_window is not None else _NO_WINDOW
    return jnp.where(is_global, _NO_WINDOW, w)


def forward(params: dict, tokens: jnp.ndarray, cfg: TransformerConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B, S] -> (hidden [B, S, D], aux_loss)."""
    b, s = tokens.shape
    h = params["embed"][tokens].astype(cfg.adtype)
    if cfg.embed_scale:
        h = h * math.sqrt(cfg.d_model)
    positions = jnp.arange(s)
    flags = jnp.asarray(cfg.is_global_layer())

    from repro.distributed import mesh_context

    def layer(h, xs):
        lp, flag = xs
        dp = mesh_context.data_axes()
        attn, _ = _attention_block(cfg, lp, h, _window_of(cfg, flag),
                                   positions=positions)
        # pin the residual stream to token-sharding (megatron row-parallel
        # all-reduce after wo / w2) — see mesh_context.shard_hint
        h = mesh_context.shard_hint(h + attn, dp, None, None)
        ffn, aux = _ffn_block(cfg, lp, h)
        return mesh_context.shard_hint(h + ffn, dp, None, None), aux

    body = jax.checkpoint(layer) if cfg.remat else layer
    if cfg.unroll_layers:   # cost probes: scan bodies are cost-counted once
        aux_sum = jnp.float32(0.0)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            h, aux = body(h, (lp, flags[i]))
            aux_sum = aux_sum + aux
        return common.rms_norm(h, params["final_norm"]), aux_sum
    h, auxs = jax.lax.scan(body, h, (params["layers"], flags))
    h = common.rms_norm(h, params["final_norm"])
    return h, jnp.sum(auxs)


def unembed_matrix(params: dict, cfg: TransformerConfig) -> jnp.ndarray:
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def loss_fn(params: dict, batch: dict, cfg: TransformerConfig):
    """batch: tokens [B, S] int32, labels [B, S] int32 (-100 ignored)."""
    hidden, aux = forward(params, batch["tokens"], cfg)
    xent = common.chunked_cross_entropy(
        hidden, unembed_matrix(params, cfg), batch["labels"],
        cap=cfg.final_softcap, chunk=min(cfg.xent_chunk, hidden.shape[1]))
    loss = xent + 0.01 * aux
    return loss, {"xent": xent, "aux": aux}


# -----------------------------------------------------------------------------
# decode (serve_step)
# -----------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, cfg.adtype),
            "v": jnp.zeros(shape, cfg.adtype)}


def cache_specs(cfg: TransformerConfig, shard_seq: bool) -> dict:
    """KV cache sharding: batch over 'data' (or, for batch-1 long-context,
    sequence over 'data'); head_dim over 'model' (kv-head counts don't divide
    16-way TP, head_dim always does)."""
    if shard_seq:
        spec = P(None, None, "data", None, "model")
    else:
        spec = P(None, "data", None, None, "model")
    return {"k": spec, "v": spec}


def decode_step(params: dict, cache: dict, tokens: jnp.ndarray,
                cur_len: jnp.ndarray, cfg: TransformerConfig):
    """One serving step: tokens [B, 1] given a cache filled to cur_len.
    Returns (next-token logits [B, V], updated cache)."""
    b = tokens.shape[0]
    h = params["embed"][tokens].astype(cfg.adtype)
    if cfg.embed_scale:
        h = h * math.sqrt(cfg.d_model)
    positions = jnp.full((1,), cur_len, jnp.int32)
    flags = jnp.asarray(cfg.is_global_layer())

    def layer(h, xs):
        lp, flag, ck, cv = xs
        attn, (k_new, v_new) = _attention_block(
            cfg, lp, h, _window_of(cfg, flag), positions=positions,
            kv_len=cur_len + 1, cache_kv=(ck, cv))
        h = h + attn
        ffn, _ = _ffn_block(cfg, lp, h)
        return h + ffn, (k_new, v_new)

    if cfg.unroll_layers:   # cost probes
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            h, (kn, vn) = layer(h, (lp, flags[i], cache["k"][i],
                                    cache["v"][i]))
            ks.append(kn)
            vs.append(vn)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    else:
        h, (k_new, v_new) = jax.lax.scan(
            layer, h, (params["layers"], flags, cache["k"], cache["v"]))
    h = common.rms_norm(h, params["final_norm"])
    logits = h[:, 0, :] @ unembed_matrix(params, cfg).astype(h.dtype)
    logits = common.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, {"k": k_new, "v": v_new}
