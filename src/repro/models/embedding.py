"""Sharded embedding tables + EmbeddingBag (JAX has neither natively).

Lookup strategy over the mesh 'model' axis: tables are ROW-sharded
([V, D] -> [V/tp, D] per rank); indices are data-sharded and replicated
across 'model'; each rank contributes rows it owns (masked gather) and a
psum over 'model' assembles the full embedding. This is the classic
mask+psum row-sharded lookup — the collective cost (B·F·D per step) is what
the deepfm roofline sees, and the §Perf hillclimb attacks it.

EmbeddingBag = gather + segment-sum (here: masked sum over the bag axis),
exactly as the spec prescribes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import mesh_context
from repro.models.moe import shard_map

P = jax.sharding.PartitionSpec


def _local_lookup(table_local: jnp.ndarray, idx: jnp.ndarray,
                  axis: str | None) -> jnp.ndarray:
    """Masked gather of locally-owned rows; zeros elsewhere."""
    v_local = table_local.shape[0]
    rank = jax.lax.axis_index(axis) if axis else 0
    lo = rank * v_local
    local = (idx >= lo) & (idx < lo + v_local)
    rows = table_local[jnp.clip(idx - lo, 0, v_local - 1)]
    out = jnp.where(local[..., None], rows, 0)
    if axis is not None:
        out = jax.lax.psum(out, axis)
    return out


def lookup(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """table [V, D] (row-sharded over 'model' when a mesh is ambient),
    idx [...] int32 -> [..., D]."""
    mesh = mesh_context.current_mesh()
    axis = mesh_context.model_axis_in(mesh)
    if axis is None:
        return table[idx]
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    data_ranks = 1
    for a in data_axes:
        data_ranks *= mesh.shape[a]
    # batch-1 serving (retrieval_cand) can't shard the index dim: replicate
    shardable = data_axes and idx.shape[0] % data_ranks == 0
    idx_spec = P(data_axes) if shardable else P()

    def body(tbl, ix):
        return _local_lookup(tbl, ix, axis)

    return shard_map(
        body, mesh,
        in_specs=(P(axis, None), idx_spec),
        out_specs=idx_spec,
    )(table, idx)


def bag_lookup(table: jnp.ndarray, idx: jnp.ndarray,
               valid: jnp.ndarray | None = None,
               combiner: str = "sum") -> jnp.ndarray:
    """EmbeddingBag: idx [B, L] -> [B, D] (sum/mean over the bag axis)."""
    rows = lookup(table, jnp.maximum(idx, 0))              # [B, L, D]
    if valid is None:
        valid = idx >= 0
    rows = jnp.where(valid[..., None], rows, 0)
    out = rows.sum(axis=-2)
    if combiner == "mean":
        out = out / jnp.maximum(valid.sum(axis=-1, keepdims=True), 1)
    return out


def table_spec() -> P:
    return P("model", None)
