"""Tiered candidate retrieval: the paper's technique integrated into the
two-tower serving path (DESIGN.md §6).

Offline:
  * items carry attribute sets (synthetic Zipf categories);
  * queries carry attribute predicates; m(q) = items matching all predicates;
  * SCSK solve picks clause set X, Tier-1 = ∪_{c∈X} m(c)  (|Tier-1| <= B).
Online (`tiered_retrieval_scores`):
  * ψ^clause routes each query: eligible -> score ONLY the Tier-1 candidate
    embeddings (|D1|/|D| of the FLOPs/bytes); else -> full corpus.
  * Theorem 3.1 guarantees eligible queries lose no matching candidate, so
    top-k over matching items is unchanged (asserted in tests).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset
from repro.core.tiering import ClauseTiering
from repro.data import incidence


@dataclasses.dataclass
class TieredIndex:
    tiering: ClauseTiering
    tier1_ids: np.ndarray            # item ids in Tier 1 (sorted)
    data: incidence.TieringData

    @property
    def tier1_frac(self) -> float:
        return len(self.tier1_ids) / self.data.n_docs


def build_tiered_index(seed: int = 0, scale: str = "tiny",
                       budget_frac: float = 0.5,
                       min_support: float = 1e-3,
                       solver: str = "optpes") -> TieredIndex:
    """Items = 'documents' over an attribute vocabulary; queries = predicate
    sets from the same distribution machinery as the paper pipeline."""
    from repro.api import TieringPipeline
    pipe = (TieringPipeline.from_synthetic(seed=seed, scale=scale)
            .mine(min_support=min_support)
            .solve(solver, budget_frac=budget_frac))
    tiering = pipe.tiering()
    return TieredIndex(tiering=tiering,
                       tier1_ids=np.nonzero(tiering.tier1_docs)[0],
                       data=pipe.data)


def tiered_retrieval_scores(
    user_emb: jnp.ndarray,          # [D]
    cand_emb: jnp.ndarray,          # [N, D] full-corpus item embeddings
    tier1_ids: jnp.ndarray,         # [N1] Tier-1 item ids
    eligible: bool | jnp.ndarray,   # ψ(q) for this query
    match_mask: jnp.ndarray,        # [N] bool — m(q) (which items match)
    k: int = 100,
):
    """Returns (values, indices) of the top-k *matching* candidates.

    Eligible queries read only the [N1, D] Tier-1 slice — that is the FLOP /
    HBM saving the paper's Tier-1 buys (measured in benchmarks)."""
    def tier1_path(_):
        sub = cand_emb[tier1_ids]                     # [N1, D] gather
        s = sub @ user_emb
        s = jnp.where(match_mask[tier1_ids], s, -jnp.inf)
        v, i = jax.lax.top_k(s, k)
        return v, tier1_ids[i]

    def full_path(_):
        s = cand_emb @ user_emb
        s = jnp.where(match_mask, s, -jnp.inf)
        return jax.lax.top_k(s, k)

    if isinstance(eligible, bool):
        return tier1_path(None) if eligible else full_path(None)
    return jax.lax.cond(eligible, tier1_path, full_path, None)
