"""RecSys architectures: DeepFM, BST, BERT4Rec, two-tower retrieval.

Every model exposes (Config, init_params, loss_fn, serve_step,
serve_candidates, param_specs). Embedding tables are huge (10^6+ rows per
field) and row-sharded via models/embedding.py. Large-vocab softmaxes use
in-batch/sampled softmax (the two-tower spec's "sampled-softmax retrieval").
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import common, embedding
from repro.models.egnn import _mlp, _mlp_params  # plain MLP helpers

P = jax.sharding.PartitionSpec


# =============================================================================
# DeepFM (arXiv:1703.04247)
# =============================================================================

@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    name: str = "deepfm"
    n_fields: int = 39
    vocab_per_field: int = 1_000_000
    embed_dim: int = 10
    mlp_dims: tuple[int, ...] = (400, 400, 400)
    dtype: str = "float32"

    @property
    def total_vocab(self) -> int:
        return self.n_fields * self.vocab_per_field


def deepfm_init(rng: jax.Array, cfg: DeepFMConfig) -> dict:
    ks = jax.random.split(rng, 4)
    return {
        "emb": jax.random.normal(ks[0], (cfg.total_vocab, cfg.embed_dim),
                                 jnp.float32) * 0.01,
        "lin": jax.random.normal(ks[1], (cfg.total_vocab, 1), jnp.float32) * 0.01,
        "mlp": _mlp_params(ks[2], (cfg.n_fields * cfg.embed_dim,)
                           + cfg.mlp_dims + (1,)),
        "bias": jnp.zeros(()),
    }


def deepfm_specs(cfg: DeepFMConfig) -> dict:
    return {
        "emb": embedding.table_spec(),
        "lin": embedding.table_spec(),
        "mlp": [{"w": P(None, None), "b": P(None)} for _ in
                range(len(cfg.mlp_dims) + 1)],
        "bias": P(),
    }


def _field_offsets(cfg: DeepFMConfig) -> jnp.ndarray:
    return jnp.arange(cfg.n_fields, dtype=jnp.int32) * cfg.vocab_per_field


def deepfm_logits(params: dict, feat_ids: jnp.ndarray, cfg: DeepFMConfig):
    """feat_ids [B, n_fields] (per-field local ids)."""
    idx = feat_ids + _field_offsets(cfg)[None, :]
    v = embedding.lookup(params["emb"], idx)                 # [B, F, D]
    lin = embedding.lookup(params["lin"], idx)[..., 0]       # [B, F]
    # FM second order: ½((Σv)² − Σv²)
    s = v.sum(axis=1)
    fm2 = 0.5 * (s * s - (v * v).sum(axis=1)).sum(axis=-1)   # [B]
    deep = _mlp(params["mlp"], v.reshape(v.shape[0], -1))[:, 0]
    return params["bias"] + lin.sum(axis=1) + fm2 + deep


def deepfm_loss(params: dict, batch: dict, cfg: DeepFMConfig):
    logits = deepfm_logits(params, batch["feat_ids"], cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(jax.nn.softplus(logits) - y * logits)    # BCE-with-logits
    return loss, {"bce": loss}


def deepfm_serve(params: dict, batch: dict, cfg: DeepFMConfig):
    return jax.nn.sigmoid(deepfm_logits(params, batch["feat_ids"], cfg))


def deepfm_serve_candidates(params: dict, batch: dict, cfg: DeepFMConfig):
    """retrieval_cand: one user context × N candidate items. The candidate
    item id fills field 0; user context fields 1..F-1 are broadcast."""
    user = jnp.broadcast_to(batch["user_feat_ids"],
                            (batch["cand_ids"].shape[0],
                             batch["user_feat_ids"].shape[-1]))
    feat = jnp.concatenate([batch["cand_ids"][:, None], user], axis=1)
    scores = deepfm_logits(params, feat, cfg)
    return jax.lax.top_k(scores, min(100, scores.shape[0]))


# =============================================================================
# BST — Behavior Sequence Transformer (arXiv:1905.06874)
# =============================================================================

@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    n_items: int = 1_000_000
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    mlp_dims: tuple[int, ...] = (1024, 512, 256)
    dtype: str = "float32"


def _tx_block_init(rng, d, ff_mult=4):
    ks = jax.random.split(rng, 6)
    return {
        "wq": common.dense_init(ks[0], (d, d)),
        "wk": common.dense_init(ks[1], (d, d)),
        "wv": common.dense_init(ks[2], (d, d)),
        "wo": common.dense_init(ks[3], (d, d)),
        "ln1": jnp.zeros(d), "ln2": jnp.zeros(d),
        "ff1": common.dense_init(ks[4], (d, ff_mult * d)),
        "ff2": common.dense_init(ks[5], (ff_mult * d, d)),
    }


def _tx_block(bp, h, n_heads):
    b, s, d = h.shape
    dh = d // n_heads
    a = common.rms_norm(h, bp["ln1"])
    q = (a @ bp["wq"].astype(a.dtype)).reshape(b, s, n_heads, dh)
    k = (a @ bp["wk"].astype(a.dtype)).reshape(b, s, n_heads, dh)
    v = (a @ bp["wv"].astype(a.dtype)).reshape(b, s, n_heads, dh)
    out = common.chunked_attention(q, k, v, causal=False, chunk=s)
    h = h + out.reshape(b, s, d) @ bp["wo"].astype(h.dtype)
    m = common.rms_norm(h, bp["ln2"])
    return h + jax.nn.gelu(m @ bp["ff1"].astype(m.dtype)) @ bp["ff2"].astype(m.dtype)


def bst_init(rng: jax.Array, cfg: BSTConfig) -> dict:
    ks = jax.random.split(rng, 4 + cfg.n_blocks)
    d = cfg.embed_dim
    return {
        "item_emb": jax.random.normal(ks[0], (cfg.n_items, d), jnp.float32) * 0.01,
        "pos_emb": jax.random.normal(ks[1], (cfg.seq_len + 1, d), jnp.float32) * 0.01,
        "blocks": [_tx_block_init(ks[2 + i], d) for i in range(cfg.n_blocks)],
        "mlp": _mlp_params(ks[-1], ((cfg.seq_len + 1) * d,) + cfg.mlp_dims + (1,)),
    }


def bst_specs(cfg: BSTConfig) -> dict:
    blk = {"wq": P(None, "model"), "wk": P(None, "model"),
           "wv": P(None, "model"), "wo": P("model", None),
           "ln1": P(None), "ln2": P(None),
           "ff1": P(None, "model"), "ff2": P("model", None)}
    return {
        "item_emb": embedding.table_spec(),
        "pos_emb": P(None, None),
        "blocks": [dict(blk) for _ in range(cfg.n_blocks)],
        "mlp": [{"w": P(None, None), "b": P(None)} for _ in
                range(len(cfg.mlp_dims) + 1)],
    }


def bst_logits(params: dict, hist: jnp.ndarray, target: jnp.ndarray,
               cfg: BSTConfig):
    """hist [B, L] item ids (-1 pad), target [B] item id."""
    seq = jnp.concatenate([jnp.maximum(hist, 0), target[:, None]], axis=1)
    h = embedding.lookup(params["item_emb"], seq)            # [B, L+1, D]
    h = h + params["pos_emb"][None].astype(h.dtype)
    for bp in params["blocks"]:
        h = _tx_block(bp, h, cfg.n_heads)
    return _mlp(params["mlp"], h.reshape(h.shape[0], -1))[:, 0]


def bst_loss(params: dict, batch: dict, cfg: BSTConfig):
    logits = bst_logits(params, batch["hist"], batch["target"], cfg)
    logits = logits.astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(jax.nn.softplus(logits) - y * logits)
    return loss, {"bce": loss}


def bst_serve(params: dict, batch: dict, cfg: BSTConfig):
    return jax.nn.sigmoid(bst_logits(params, batch["hist"], batch["target"], cfg))


def bst_serve_candidates(params: dict, batch: dict, cfg: BSTConfig):
    """One user history × N candidate targets."""
    n = batch["cand_ids"].shape[0]
    hist = jnp.broadcast_to(batch["hist"], (n, batch["hist"].shape[-1]))
    scores = bst_logits(params, hist, batch["cand_ids"], cfg)
    return jax.lax.top_k(scores, min(100, n))


# =============================================================================
# BERT4Rec (arXiv:1904.06690)
# =============================================================================

@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    n_items: int = 1_000_000          # +1 mask token appended
    embed_dim: int = 64
    seq_len: int = 200
    n_blocks: int = 2
    n_heads: int = 2
    n_negatives: int = 8192           # sampled softmax
    dtype: str = "float32"

    @property
    def table_rows(self) -> int:
        # mask token + padding up to a 512 multiple so the row-sharded table
        # divides any mesh axis combination
        return -(-(self.n_items + 1) // 512) * 512


def bert4rec_init(rng: jax.Array, cfg: Bert4RecConfig) -> dict:
    ks = jax.random.split(rng, 3 + cfg.n_blocks)
    d = cfg.embed_dim
    return {
        "item_emb": jax.random.normal(
            ks[0], (cfg.table_rows, d), jnp.float32) * 0.01,
        "pos_emb": jax.random.normal(ks[1], (cfg.seq_len, d), jnp.float32) * 0.01,
        "blocks": [_tx_block_init(ks[2 + i], d) for i in range(cfg.n_blocks)],
        "out_norm": jnp.zeros(d),
    }


def bert4rec_specs(cfg: Bert4RecConfig) -> dict:
    blk = {"wq": P(None, "model"), "wk": P(None, "model"),
           "wv": P(None, "model"), "wo": P("model", None),
           "ln1": P(None), "ln2": P(None),
           "ff1": P(None, "model"), "ff2": P("model", None)}
    return {
        "item_emb": embedding.table_spec(),
        "pos_emb": P(None, None),
        "blocks": [dict(blk) for _ in range(cfg.n_blocks)],
        "out_norm": P(None),
    }


def bert4rec_encode(params: dict, seq: jnp.ndarray, cfg: Bert4RecConfig):
    h = embedding.lookup(params["item_emb"], jnp.maximum(seq, 0))
    h = h + params["pos_emb"][None].astype(h.dtype)
    for bp in params["blocks"]:
        h = _tx_block(bp, h, cfg.n_heads)
    return common.rms_norm(h, params["out_norm"])            # [B, S, D]


def bert4rec_loss(params: dict, batch: dict, cfg: Bert4RecConfig):
    """Masked-item prediction with sampled softmax.

    batch: seq [B, S] (mask token = n_items), labels [B, S] (-100 = not
    masked), negatives [K] sampled item ids (shared across the batch).
    """
    h = bert4rec_encode(params, batch["seq"], cfg)
    labels = batch["labels"]
    valid = labels >= 0
    gold = jnp.maximum(labels, 0)
    pos_emb = embedding.lookup(params["item_emb"], gold)     # [B, S, D]
    neg_emb = embedding.lookup(params["item_emb"], batch["negatives"])  # [K, D]
    pos_logit = jnp.sum(h * pos_emb, axis=-1, keepdims=True)            # [B,S,1]
    neg_logit = jnp.einsum("bsd,kd->bsk", h, neg_emb)
    logits = jnp.concatenate([pos_logit, neg_logit], axis=-1).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    xent = logz - logits[..., 0]
    loss = jnp.sum(jnp.where(valid, xent, 0.0)) / jnp.maximum(valid.sum(), 1)
    return loss, {"xent": loss}


def bert4rec_serve(params: dict, batch: dict, cfg: Bert4RecConfig,
                   *, naive: bool = False, k: int = 100, chunk: int = 2048):
    """Next-item top-k over the full catalog for the last position.

    Production path (§Perf hillclimb): the [B, V] score matrix must never
    leave its model-shard — each rank computes scores against its LOCAL
    table rows in batch chunks, takes a LOCAL top-k, and only the [ranks, k]
    candidates are all-gathered and merged. vs the naive path this removes
    the B*V score all-gather (~1 TB collective at serve_bulk scale) and
    keeps the score transient at [chunk, V/ranks].
    """
    from repro.distributed import mesh_context
    from repro.models.moe import shard_map

    h = bert4rec_encode(params, batch["seq"], cfg)[:, -1]    # [B, D]
    mesh = mesh_context.current_mesh()
    axis = mesh_context.model_axis_in(mesh)
    if naive or axis is None:
        scores = h @ params["item_emb"].T.astype(h.dtype)    # [B, rows]
        valid = jnp.arange(cfg.table_rows) < cfg.n_items
        scores = jnp.where(valid[None, :], scores, -jnp.inf)
        return jax.lax.top_k(scores, k)

    n_ranks = mesh.shape[axis]
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dranks = 1
    for a in dp:
        dranks *= mesh.shape[a]
    tok_spec = P(dp) if (dp and h.shape[0] % dranks == 0) else P()

    def body(h_loc, emb_loc):
        v_loc = emb_loc.shape[0]
        rank = jax.lax.axis_index(axis)
        lo = rank * v_loc
        valid = (jnp.arange(v_loc) + lo) < cfg.n_items
        b_loc = h_loc.shape[0]
        bc = min(chunk, b_loc)
        outs_v, outs_i = [], []
        for s in range(0, b_loc, bc):           # unrolled: probe-countable
            sc = h_loc[s:s + bc] @ emb_loc.T.astype(h_loc.dtype)
            sc = jnp.where(valid[None, :], sc, -jnp.inf)
            v, i = jax.lax.top_k(sc, k)         # local top-k: [bc, k]
            outs_v.append(v)
            outs_i.append(i + lo)
        v = jnp.concatenate(outs_v)             # [B_loc, k]
        i = jnp.concatenate(outs_i)
        # merge across model ranks: k*ranks candidates per row, tiny
        v_all = jax.lax.all_gather(v, axis, axis=1)   # [B_loc, R, k]
        i_all = jax.lax.all_gather(i, axis, axis=1)
        v_all = v_all.reshape(v.shape[0], -1)
        i_all = i_all.reshape(v.shape[0], -1)
        vk, sel = jax.lax.top_k(v_all, k)
        return vk, jnp.take_along_axis(i_all, sel, axis=1)

    return shard_map(
        body, mesh,
        in_specs=(tok_spec, P(axis, None)),
        out_specs=(tok_spec, tok_spec),
        # outputs ARE replicated over 'model' (post-all_gather merge), but
        # the static checker can't see through top_k/take_along_axis
        check_vma=False,
    )(h, params["item_emb"])


def bert4rec_serve_candidates(params: dict, batch: dict, cfg: Bert4RecConfig):
    h = bert4rec_encode(params, batch["seq"], cfg)[:, -1]    # [1, D]
    cand = embedding.lookup(params["item_emb"], batch["cand_ids"])
    scores = (cand @ h[0]).astype(jnp.float32)
    return jax.lax.top_k(scores, min(100, scores.shape[0]))


# =============================================================================
# Two-tower retrieval (YouTube RecSys'19-style, sampled softmax + logQ)
# =============================================================================

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    n_user_fields: int = 8
    n_item_fields: int = 8
    vocab_per_field: int = 1_000_000
    field_dim: int = 32
    tower_dims: tuple[int, ...] = (1024, 512, 256)
    embed_dim: int = 256
    temperature: float = 0.05
    dtype: str = "float32"


def twotower_init(rng: jax.Array, cfg: TwoTowerConfig) -> dict:
    ks = jax.random.split(rng, 4)
    du = cfg.n_user_fields * cfg.field_dim
    di = cfg.n_item_fields * cfg.field_dim
    return {
        "user_emb": jax.random.normal(
            ks[0], (cfg.n_user_fields * cfg.vocab_per_field, cfg.field_dim),
            jnp.float32) * 0.01,
        "item_emb": jax.random.normal(
            ks[1], (cfg.n_item_fields * cfg.vocab_per_field, cfg.field_dim),
            jnp.float32) * 0.01,
        "user_mlp": _mlp_params(ks[2], (du,) + cfg.tower_dims),
        "item_mlp": _mlp_params(ks[3], (di,) + cfg.tower_dims),
    }


def twotower_specs(cfg: TwoTowerConfig) -> dict:
    return {
        "user_emb": embedding.table_spec(),
        "item_emb": embedding.table_spec(),
        "user_mlp": [{"w": P(None, None), "b": P(None)} for _ in cfg.tower_dims],
        "item_mlp": [{"w": P(None, None), "b": P(None)} for _ in cfg.tower_dims],
    }


def _tower(emb_table, mlp, feat_ids, n_fields, vocab):
    idx = feat_ids + (jnp.arange(n_fields, dtype=jnp.int32) * vocab)[None, :]
    v = embedding.lookup(emb_table, idx)                     # [B, F, d]
    z = _mlp(mlp, v.reshape(v.shape[0], -1))
    return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-6)


def twotower_user(params, user_ids, cfg: TwoTowerConfig):
    return _tower(params["user_emb"], params["user_mlp"], user_ids,
                  cfg.n_user_fields, cfg.vocab_per_field)


def twotower_item(params, item_ids, cfg: TwoTowerConfig):
    return _tower(params["item_emb"], params["item_mlp"], item_ids,
                  cfg.n_item_fields, cfg.vocab_per_field)


def twotower_loss(params: dict, batch: dict, cfg: TwoTowerConfig):
    """In-batch sampled softmax with logQ correction.

    batch: user_ids [B, Fu], item_ids [B, Fi], item_logq [B] (log sampling
    probability of each in-batch item, for the correction)."""
    u = twotower_user(params, batch["user_ids"], cfg)        # [B, D]
    it = twotower_item(params, batch["item_ids"], cfg)       # [B, D]
    scores = (u @ it.T).astype(jnp.float32) / cfg.temperature
    scores = scores - batch["item_logq"][None, :]            # logQ correction
    b = scores.shape[0]
    labels = jnp.arange(b)
    logz = jax.nn.logsumexp(scores, axis=-1)
    gold = jnp.take_along_axis(scores, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean(jnp.argmax(scores, -1) == labels)
    return loss, {"xent": loss, "in_batch_acc": acc}


def twotower_serve(params: dict, batch: dict, cfg: TwoTowerConfig):
    """Online scoring: user × item pairwise dot (p99 path)."""
    u = twotower_user(params, batch["user_ids"], cfg)
    it = twotower_item(params, batch["item_ids"], cfg)
    return jnp.sum(u * it, axis=-1)


def twotower_serve_candidates(params: dict, batch: dict, cfg: TwoTowerConfig):
    """retrieval_cand: 1 user × N precomputed candidate embeddings
    [N, D] -> top-k. The candidate matrix is the serving index (built
    offline by `twotower_item` over the catalog)."""
    u = twotower_user(params, batch["user_ids"], cfg)        # [1, D]
    scores = (batch["cand_emb"] @ u[0]).astype(jnp.float32)  # [N]
    return jax.lax.top_k(scores, min(100, scores.shape[0]))


def twotower_serve_candidates_tiered(params: dict, batch: dict,
                                     cfg: TwoTowerConfig):
    """The paper's technique in the retrieval hot path: a ψ^clause-eligible
    query scores ONLY the Tier-1 slice of the index (|D1|/|D| of the FLOPs
    and candidate-matrix HBM traffic); Theorem 3.1 guarantees no matching
    candidate is lost. `tier1_emb` is the materialized Tier-1 index
    (gathered offline at tiering-build time, like the Tier-1 postings).
    Ineligible queries fall back to the full index (handled by the plain
    serve path; the dry-run cell measures the Tier-1-hit cost)."""
    u = twotower_user(params, batch["user_ids"], cfg)        # [1, D]
    scores = (batch["tier1_emb"] @ u[0]).astype(jnp.float32)  # [N1]
    v, i = jax.lax.top_k(scores, min(100, scores.shape[0]))
    return v, batch["tier1_ids"][i]                           # global ids
