"""Shared model building blocks (pure functional, no framework deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding. x: [..., S, H, D], positions: [S] or [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs      # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]                            # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(rng: jax.Array, shape: tuple[int, ...], in_axis: int = -2) -> jnp.ndarray:
    fan_in = shape[in_axis]
    return (jax.random.normal(rng, shape, jnp.float32) / np.sqrt(fan_in))


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def chunked_attention(
    q: jnp.ndarray,                # [B, Sq, Hq, D]
    k: jnp.ndarray,                # [B, Skv, Hkv, D]
    v: jnp.ndarray,                # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    window: int | jnp.ndarray | None = None,
    cap: float | None = None,
    q_offset: int | jnp.ndarray = 0,
    kv_len: jnp.ndarray | None = None,    # valid KV prefix length (decode)
    chunk: int = 1024,
    unroll: bool = False,   # python loop over chunks (dry-run cost probes:
                            # lax.scan bodies are counted ONCE by XLA cost
                            # analysis, so probes unroll)
) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure JAX (lax.scan over KV
    chunks). Never materializes the [Sq, Skv] score matrix — the memory
    roofline term sees O(Sq * chunk) transients only. Supports GQA, sliding
    windows, logit softcap and decode offsets; the Pallas kernel
    (kernels/flash_attention.py) implements the same contract on TPU.

    `window` may be a traced scalar (per-layer flag inside a scanned stack).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    group = hq // hkv
    qf = (q.astype(jnp.float32) / np.sqrt(d)).reshape(b, sq, hkv, group, d)
    q_pos = jnp.arange(sq) + q_offset                       # [Sq]

    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (skv + pad) // chunk
    kc = k.reshape(b, n_chunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m, l, acc = carry
        ci, k_blk, v_blk = xs
        k_pos = ci * chunk + jnp.arange(chunk)              # [chunk]
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k_blk.astype(jnp.float32))
        s = softcap(s, cap)
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        if kv_len is not None:
            mask &= k_pos[None, :] < kv_len
        mask &= k_pos[None, :] < skv                        # padding
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, v_blk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, hkv, group), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, group), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, group, d), jnp.float32)
    if unroll:
        carry = (m0, l0, a0)
        for ci in range(n_chunks):
            carry, _ = body(carry, (jnp.int32(ci), kc[ci], vc[ci]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def chunked_cross_entropy(
    hidden: jnp.ndarray,           # [B, S, D]
    unembed: jnp.ndarray,          # [D, V]
    labels: jnp.ndarray,           # [B, S] int32 (-100 = ignore)
    *,
    cap: float | None = None,
    chunk: int = 512,
) -> jnp.ndarray:
    """Sequence-chunked softmax xent: logits [B, chunk, V] transients instead
    of [B, S, V] — kills the dominant memory term of LM training steps."""
    b, s, d = hidden.shape
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    n = (s + pad) // chunk
    hc = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        h_blk, y_blk = xs
        logits = softcap(
            jnp.einsum("bsd,dv->bsv", h_blk.astype(jnp.float32),
                       unembed.astype(jnp.float32)), cap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        y = jnp.maximum(y_blk, 0)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        valid = y_blk >= 0
        tot += jnp.sum(jnp.where(valid, logz - gold, 0.0))
        cnt += jnp.sum(valid)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.int32(0)), (hc, lc))
    return tot / jnp.maximum(cnt, 1)
