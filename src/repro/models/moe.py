"""Mixture-of-Experts FFN with expert parallelism (replicated-token EP).

Sharding strategy (DESIGN.md §7): expert weights are sharded over the mesh
'model' axis; tokens are sharded over the remaining axes ('pod','data') and
replicated across 'model'. Each model-rank computes the contribution of its
local expert shard for all of its tokens — no all-to-all; one psum over
'model' combines expert outputs (same collective cost as a tensor-parallel
MLP). Capacity is per-expert (GShard-style) so the grouped GEMM is a dense
[E_local, cap, D] x [E_local, D, F] einsum — static shapes, MXU-friendly,
trivially differentiable.

Routing (gate, top-k, aux loss) happens *outside* the shard_map in plain
SPMD-land, so the expert-parallel path and the dense oracle route
identically and the load-balance statistics are global.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed import mesh_context
from repro.distributed.plan import shard_map  # noqa: F401  (compat re-export)

P = jax.sharding.PartitionSpec


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25


def init_moe_params(rng: jax.Array, d_model: int, cfg: MoEConfig) -> dict:
    k = jax.random.split(rng, 4)
    e, f = cfg.n_experts, cfg.d_expert
    s_in = 1.0 / math.sqrt(d_model)
    s_f = 1.0 / math.sqrt(f)
    return {
        "gate": jax.random.normal(k[0], (d_model, e), jnp.float32) * s_in,
        "w1": jax.random.normal(k[1], (e, d_model, f), jnp.float32) * s_in,
        "w3": jax.random.normal(k[2], (e, d_model, f), jnp.float32) * s_in,
        "w2": jax.random.normal(k[3], (e, f, d_model), jnp.float32) * s_f,
    }


def _route(params: dict, x: jnp.ndarray, cfg: MoEConfig):
    logits = (x @ params["gate"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, cfg.top_k)
    topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    me = probs.mean(axis=0)
    ce = jax.nn.one_hot(topk_e[:, 0], cfg.n_experts).mean(axis=0)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return topk_e, topk_p, aux


def _dispatch_local(x, topk_e, topk_p, w1, w3, w2, *, cfg: MoEConfig,
                    n_ranks: int, axis: str | None, cap_e: int):
    """Per-device body. x: [T_loc, D]; w*: local expert shard [E_local, ...]."""
    t, d = x.shape
    e_local = cfg.n_experts // n_ranks
    rank = jax.lax.axis_index(axis) if axis else 0
    lo = rank * e_local

    e_flat = topk_e.reshape(-1)                                  # [T*k]
    p_flat = topk_p.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t), cfg.top_k)

    local = (e_flat >= lo) & (e_flat < lo + e_local)
    e_loc = jnp.where(local, e_flat - lo, 0)
    onehot = (e_loc[:, None] == jnp.arange(e_local)[None, :]) & local[:, None]
    pos = jnp.cumsum(onehot, axis=0) - onehot.astype(jnp.int32)
    pos = (pos * onehot).sum(-1)                                 # rank within expert
    keep = local & (pos < cap_e)
    slot = jnp.where(keep, e_loc * cap_e + pos, e_local * cap_e)  # overflow row

    # dispatch/combine one top-k slice at a time: a pair-major [T*k, D]
    # gather would materialize 1.75 GB/step/device f32 buffers at kimi-k2
    # scale (EXPERIMENTS.md §Perf); per-slice intermediates are [T, D].
    k = cfg.top_k
    slot_k = slot.reshape(t, k)
    keep_k = keep.reshape(t, k)
    p_k = topk_p.astype(x.dtype)
    x_buf = jnp.zeros((e_local * cap_e + 1, d), x.dtype)
    for j in range(k):
        s_j = jnp.where(keep_k[:, j], slot_k[:, j], e_local * cap_e)
        x_buf = x_buf.at[s_j].set(x, mode="drop")
    xb = x_buf[:-1].reshape(e_local, cap_e, d)
    h1 = jnp.einsum("ecd,edf->ecf", xb, w1.astype(x.dtype))
    h3 = jnp.einsum("ecd,edf->ecf", xb, w3.astype(x.dtype))
    yb = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h1) * h3, w2.astype(x.dtype))
    y_buf = jnp.concatenate(
        [yb.reshape(e_local * cap_e, d), jnp.zeros((1, d), x.dtype)])
    y = jnp.zeros_like(x)
    for j in range(k):
        s_j = jnp.where(keep_k[:, j], slot_k[:, j], e_local * cap_e)
        y = y + y_buf[s_j] * p_k[:, j:j + 1]

    if axis is not None:
        y = jax.lax.psum(y, axis)
    return y


def moe_apply(params: dict, x: jnp.ndarray, cfg: MoEConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [T, D] -> ([T, D], aux_loss). Expert-parallel over the ambient
    mesh's 'model' axis when present."""
    mesh = mesh_context.current_mesh()
    axis = mesh_context.model_axis_in(mesh)
    n_ranks = mesh.shape[axis] if axis else 1
    assert cfg.n_experts % n_ranks == 0, (cfg.n_experts, n_ranks)

    topk_e, topk_p, aux = _route(params, x, cfg)

    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    data_ranks = 1
    for a in data_axes:
        data_ranks *= mesh.shape[a]
    t_local = max(1, x.shape[0] // max(1, data_ranks))
    cap_e = max(1, math.ceil(t_local * cfg.top_k * cfg.capacity_factor
                             / cfg.n_experts))

    if axis is None:
        return _dispatch_local(
            x, topk_e, topk_p, params["w1"], params["w3"], params["w2"],
            cfg=cfg, n_ranks=1, axis=None, cap_e=cap_e), aux

    def body(x, te, tp, w1, w3, w2):
        return _dispatch_local(x, te, tp, w1, w3, w2, cfg=cfg,
                               n_ranks=n_ranks, axis=axis, cap_e=cap_e)

    tok_spec = P(data_axes if data_axes else None)
    fn = shard_map(
        body, mesh,
        in_specs=(tok_spec, tok_spec, tok_spec, P(axis), P(axis), P(axis)),
        out_specs=tok_spec,
    )
    return fn(x, topk_e, topk_p, params["w1"], params["w3"], params["w2"]), aux


def moe_apply_dense_oracle(params: dict, x: jnp.ndarray, cfg: MoEConfig) -> jnp.ndarray:
    """Reference: python loop over experts, no capacity dropping (tests)."""
    topk_e, topk_p, _ = _route(params, x, cfg)
    y = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        h1 = x @ params["w1"][e].astype(x.dtype)
        h3 = x @ params["w3"][e].astype(x.dtype)
        ye = (jax.nn.silu(h1) * h3) @ params["w2"][e].astype(x.dtype)
        w_e = jnp.sum(jnp.where(topk_e == e, topk_p, 0.0), axis=-1)
        y += ye * w_e[:, None].astype(x.dtype)
    return y
