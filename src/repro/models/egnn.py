"""E(n)-Equivariant GNN (Satorras et al., arXiv:2102.09844).

Message passing is expressed exactly as the spec requires for JAX:
edge-index gather -> message MLP -> `jax.ops.segment_sum` scatter onto
nodes. Coordinates update equivariantly: x_i += Σ_j (x_i - x_j)·φ_x(m_ij).

Batch layout (uniform across the four assigned shapes):
  node_feat [N, F] f32, coords [N, 3] f32, edges [2, E] int32 (src, dst;
  -1 padded), labels [N] int32 (-100 pad) or graph_ids [N] + targets [G].
Graphs without physical coordinates (cora / ogb_products) get synthetic 3D
positions — EGNN requires positions; noted in DESIGN.md §Arch-applicability.

Sharding: edges over ('pod','data'), nodes replicated; the edge->node
segment_sum psums over the edge shards (XLA inserts it from the specs).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import common

P = jax.sharding.PartitionSpec


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 7
    task: str = "node_class"       # node_class | graph_reg
    coord_agg_clip: float = 100.0  # stability clamp on coordinate updates
    dtype: str = "float32"

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)


def _mlp_params(rng, dims):
    ks = jax.random.split(rng, len(dims) - 1)
    return [{"w": common.dense_init(ks[i], (dims[i], dims[i + 1])),
             "b": jnp.zeros(dims[i + 1])} for i in range(len(dims) - 1)]


def _mlp(params, x, act=jax.nn.silu, last_act=False):
    for i, layer in enumerate(params):
        x = x @ layer["w"].astype(x.dtype) + layer["b"].astype(x.dtype)
        if i < len(params) - 1 or last_act:
            x = act(x)
    return x


def init_params(rng: jax.Array, cfg: EGNNConfig) -> dict:
    keys = jax.random.split(rng, cfg.n_layers + 3)
    dh = cfg.d_hidden

    def layer(k):
        ks = jax.random.split(k, 3)
        return {
            "phi_e": _mlp_params(ks[0], (2 * dh + 1, dh, dh)),
            "phi_x": _mlp_params(ks[1], (dh, dh, 1)),
            "phi_h": _mlp_params(ks[2], (2 * dh, dh, dh)),
        }

    return {
        "embed": _mlp_params(keys[0], (cfg.d_feat, dh)),
        "layers": [layer(keys[i + 1]) for i in range(cfg.n_layers)],
        "readout": _mlp_params(keys[-1], (dh, dh, cfg.n_classes)),
    }


def param_specs(cfg: EGNNConfig) -> dict:
    rep = jax.tree.map(lambda _: P(), init_abstract(cfg))
    return rep


def init_abstract(cfg: EGNNConfig):
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def _layer_messages(lp: dict, h, x, edges, cfg: EGNNConfig,
                    dp_axes: tuple[str, ...]):
    """Edge-parallel message pass. Called per device inside shard_map (edges
    sharded, h/x replicated); returns psum'd (agg [N, dh], xup [N, 3]).
    Keeping the scatter inside shard_map stops the SPMD partitioner from
    replicating the [E, dh] message tensor (observed 61 GB/device on
    ogb_products otherwise)."""
    src, dst = edges[0], edges[1]
    valid = (src >= 0) & (dst >= 0)
    src_ = jnp.where(valid, src, 0)
    dst_ = jnp.where(valid, dst, 0)
    n = h.shape[0]
    dx = x[dst_] - x[src_]
    dist2 = jnp.sum(dx * dx, axis=-1, keepdims=True)
    m = _mlp(lp["phi_e"], jnp.concatenate(
        [h[dst_], h[src_], dist2], axis=-1), last_act=True)      # [E_loc, dh]
    m = jnp.where(valid[:, None], m, 0)
    coef = jnp.clip(_mlp(lp["phi_x"], m), -cfg.coord_agg_clip,
                    cfg.coord_agg_clip)
    xup = jax.ops.segment_sum(dx * coef, dst_, num_segments=n)
    agg = jax.ops.segment_sum(m, dst_, num_segments=n)
    deg = jax.ops.segment_sum(valid.astype(h.dtype), dst_, num_segments=n)
    for ax in dp_axes:
        xup = jax.lax.psum(xup, ax)
        agg = jax.lax.psum(agg, ax)
        deg = jax.lax.psum(deg, ax)
    return agg, xup, deg


def forward(params: dict, batch: dict, cfg: EGNNConfig):
    """Returns (node_embeddings [N, dh], coords' [N, 3])."""
    from repro.distributed import mesh_context
    from repro.models.moe import shard_map

    h = _mlp(params["embed"], batch["node_feat"].astype(cfg.adtype))
    x = batch["coords"].astype(cfg.adtype)
    edges = batch["edges"]

    mesh = mesh_context.current_mesh()
    dp = tuple(a for a in mesh.axis_names if a != "model")
    use_shmap = bool(dp) and edges.shape[1] % max(
        1, int(np.prod([mesh.shape[a] for a in dp]))) == 0 and \
        np.prod([mesh.shape[a] for a in dp]) > 1

    def one_layer(lp, h, x):
        if use_shmap:
            rep = P()
            msg = shard_map(
                lambda hh, xx, ee: _layer_messages(lp, hh, xx, ee, cfg, dp),
                mesh, in_specs=(rep, rep, P(None, dp)),
                out_specs=(rep, rep, rep))
            agg, xup, deg = msg(h, x, edges)
        else:
            agg, xup, deg = _layer_messages(lp, h, x, edges, cfg, ())
        inv_deg = 1.0 / jnp.maximum(deg, 1.0)
        x = x + xup * inv_deg[:, None]
        h = h + _mlp(lp["phi_h"], jnp.concatenate([h, agg], axis=-1))
        return h, x

    # remat: edge tensors ([E, dh] messages) are recomputed in backward —
    # saving them across layers costs ~#edges*dh*4B*layers (61 GB/device
    # on ogb_products otherwise).
    for lp in params["layers"]:
        h, x = jax.checkpoint(one_layer)(lp, h, x)
    return h, x


def loss_fn(params: dict, batch: dict, cfg: EGNNConfig):
    h, _ = forward(params, batch, cfg)
    logits = _mlp(params["readout"], h).astype(jnp.float32)      # [N, C]
    if cfg.task == "node_class":
        labels = batch["labels"]
        valid = labels >= 0
        y = jnp.maximum(labels, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        loss = jnp.sum(jnp.where(valid, logz - gold, 0.0)) / \
            jnp.maximum(valid.sum(), 1)
        acc = jnp.sum(jnp.where(valid, jnp.argmax(logits, -1) == y, False)) / \
            jnp.maximum(valid.sum(), 1)
        return loss, {"xent": loss, "acc": acc}
    # graph regression: mean-pool node embeddings per graph
    gid = batch["graph_ids"]
    g = batch["targets"].shape[0]
    pooled = jax.ops.segment_sum(logits[:, :1], gid, num_segments=g)
    count = jax.ops.segment_sum(jnp.ones_like(gid, jnp.float32), gid,
                                num_segments=g)
    pred = pooled[:, 0] / jnp.maximum(count, 1.0)
    loss = jnp.mean((pred - batch["targets"]) ** 2)
    return loss, {"mse": loss}


def serve_step(params: dict, batch: dict, cfg: EGNNConfig):
    """Inference: class logits (or predictions) for every node."""
    h, x = forward(params, batch, cfg)
    return _mlp(params["readout"], h).astype(jnp.float32), x
