"""Layer-wise uniform neighbor sampler (GraphSAGE-style, fanout e.g. 15-10).

Host-side numpy over a CSR adjacency — this is the real data-pipeline
component the `minibatch_lg` shape requires, producing statically-padded
subgraph batches for the jitted EGNN step.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray      # [N+1]
    indices: np.ndarray     # [E]
    n_nodes: int

    @classmethod
    def from_edges(cls, edges: np.ndarray, n_nodes: int) -> "CSRGraph":
        """edges: [2, E] (src, dst) -> CSR over outgoing src->dst."""
        src, dst = edges
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return cls(indptr=indptr, indices=dst, n_nodes=n_nodes)


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    rng: np.random.Generator,
    *,
    pad_nodes: int | None = None,
    pad_edges: int | None = None,
):
    """Returns (node_ids [N'], edges_local [2, E'], seed_mask [N']) with the
    sampled edges remapped to subgraph-local ids, padded to static shapes."""
    frontier = np.unique(seeds)
    all_nodes = [frontier]
    all_src, all_dst = [], []
    for fanout in fanouts:
        next_front = []
        for u in frontier:
            nbrs = graph.indices[graph.indptr[u]:graph.indptr[u + 1]]
            if len(nbrs) == 0:
                continue
            take = nbrs if len(nbrs) <= fanout else rng.choice(
                nbrs, size=fanout, replace=False)
            all_src.append(take)
            all_dst.append(np.full(len(take), u, np.int64))
            next_front.append(take)
        frontier = (np.unique(np.concatenate(next_front))
                    if next_front else np.empty(0, np.int64))
        all_nodes.append(frontier)
    nodes = np.unique(np.concatenate(all_nodes))
    src = np.concatenate(all_src) if all_src else np.empty(0, np.int64)
    dst = np.concatenate(all_dst) if all_dst else np.empty(0, np.int64)
    # remap to local ids
    remap = -np.ones(graph.n_nodes, np.int64)
    remap[nodes] = np.arange(len(nodes))
    edges = np.stack([remap[src], remap[dst]]).astype(np.int32)
    seed_mask = np.isin(nodes, seeds)

    if pad_nodes is not None:
        assert len(nodes) <= pad_nodes, (len(nodes), pad_nodes)
        nodes = np.pad(nodes, (0, pad_nodes - len(nodes)),
                       constant_values=-1)
        seed_mask = np.pad(seed_mask, (0, pad_nodes - len(seed_mask)))
    if pad_edges is not None:
        assert edges.shape[1] <= pad_edges, (edges.shape[1], pad_edges)
        edges = np.pad(edges, ((0, 0), (0, pad_edges - edges.shape[1])),
                       constant_values=-1)
    return nodes, edges, seed_mask
