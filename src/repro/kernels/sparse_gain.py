"""Pallas TPU kernel: gather-based coverage gains over padded doc-id lists.

At production scale (|D| ~ 2^26+, |X̄| ~ 2^20) a dense clause x doc bitset
matrix is infeasible (TBs); each clause instead carries its match set m(c) as
a padded int32 id list. The covered-doc set stays a packed bitset (|D|/8
bytes, e.g. 8 MB for 64M docs) and lives whole in VMEM; the kernel gathers
covered bits at the candidate's doc ids and counts the uncovered ones.

gains[c] = |{m : ids[c, m] >= 0 and bit(covered, ids[c, m]) == 0}|

TPU note: the inner op is a dynamic VMEM gather (`mask[idx >> 5]`), which
lowers to per-lane dynamic slices on TPU; the id lists should be sorted at
build time so gathers are quasi-sequential (we do this in data/incidence.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, mask_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]                          # [BC, BM] int32
    valid = ids >= 0
    idx = jnp.where(valid, ids, 0)
    words = mask_ref[0, idx >> 5]               # [BC, BM] uint32 (VMEM gather)
    bit = (words >> (idx & 31).astype(jnp.uint32)) & jnp.uint32(1)
    fresh = valid & (bit == jnp.uint32(0))
    o_ref[...] += jnp.sum(fresh.astype(jnp.int32), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_c", "block_m", "interpret"))
def sparse_gain(
    doc_ids: jnp.ndarray,     # int32 [C, M], -1 padded
    mask: jnp.ndarray,        # uint32 [W]
    *,
    block_c: int = 64,
    block_m: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:             # int32 [C]
    c, m = doc_ids.shape
    bc = min(block_c, c)
    bm = min(block_m, m)
    cp = -c % bc
    mp = -m % bm
    if cp or mp:
        doc_ids = jnp.pad(doc_ids, ((0, cp), (0, mp)), constant_values=-1)
    grid = ((c + cp) // bc, (m + mp) // bm)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bm), lambda i, j: (i, j)),
            pl.BlockSpec((1, mask.shape[0]), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bc, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c + cp, 1), jnp.int32),
        interpret=interpret,
    )(doc_ids, mask[None, :])
    return out[:c, 0]
