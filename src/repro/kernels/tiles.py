"""Leaf module for tiling arithmetic shared by every kernel wrapper.

Lives below both `ops.py` (the dispatch layer) and the kernel modules so
neither import direction creates a cycle; `ops.block_dim` re-exports it as
the public name.
"""
from __future__ import annotations

WORD = 32


def block_dim(n: int, block: int) -> tuple[int, int, int]:
    """Shared pad-to-block/grid setup for every kernel in this package.

    Clamps the requested block size to the actual extent and returns
    ``(block, pad, n_blocks)`` so callers pad `n` up to ``n + pad`` (a
    multiple of ``block``) and launch ``n_blocks`` grid steps along the axis.
    """
    b = max(1, min(block, n))
    pad = -n % b
    return b, pad, (n + pad) // b


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (>= 1) — the shape-bucketing the tile
    autotuner keys its cache on, so one tuned entry covers every call shape
    that rounds to the same bucket."""
    b = 1
    while b < n:
        b *= 2
    return b
