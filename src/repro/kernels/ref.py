"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's test sweeps shapes/dtypes and
asserts allclose against these functions. They are also the XLA fallback used
on non-TPU backends (memory-naive; `ops.py` chunks them where needed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32


def unpack_bits_f32(words: jnp.ndarray) -> jnp.ndarray:
    """uint32 [..., W] -> f32 [..., W*32] of {0,1}."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (-1,)).astype(jnp.float32)


def bit_matvec(a_bits: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Packed-bit matrix times dense matrix.

    a_bits: uint32 [C, W]   (bit i of row c = A[c, i])
    x:      f32    [W*32, R]
    returns f32 [C, R] = unpack(A) @ x
    """
    return unpack_bits_f32(a_bits) @ x


def coverage_gain(a_bits: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Unweighted marginal coverage gains.

    a_bits: uint32 [C, W] candidate incidence rows
    mask:   uint32 [W]    already-covered bitset
    returns int32 [C] = popcount(a & ~mask) per row
    """
    fresh = a_bits & ~mask[None, :]
    return jnp.sum(jax.lax.population_count(fresh).astype(jnp.int32), axis=-1)


def clause_match(query_bits: jnp.ndarray, clause_bits: jnp.ndarray) -> jnp.ndarray:
    """Batched ψ^clause subset test (paper eq. 8).

    query_bits:  uint32 [B, Wv] packed query term sets
    clause_bits: uint32 [K, Wv] packed selected clauses
    returns bool [B]: eligible[b] = ∃k . clause k ⊆ query b
    """
    miss = clause_bits[None, :, :] & ~query_bits[:, None, :]     # [B, K, Wv]
    sub = jnp.all(miss == 0, axis=-1)                            # [B, K]
    return jnp.any(sub, axis=-1)


def sparse_gain(doc_ids: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Gather-based marginal gains over padded id lists (production scale).

    doc_ids: int32 [C, M], padded with -1
    mask:    uint32 [W] covered bitset over the id universe
    returns int32 [C] = |{m : id >= 0 and bit(mask, id) == 0}|
    """
    valid = doc_ids >= 0
    idx = jnp.where(valid, doc_ids, 0)
    words = mask[idx >> 5]
    bit = (words >> (idx & 31).astype(jnp.uint32)) & jnp.uint32(1)
    return jnp.sum((valid & (bit == 0)).astype(jnp.int32), axis=-1)


def flash_attention(
    q: jnp.ndarray,      # [B, Sq, Hq, D]
    k: jnp.ndarray,      # [B, Skv, Hkv, D]
    v: jnp.ndarray,      # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    window: int | None = None,     # sliding window (local attention)
    softcap: float | None = None,  # gemma-style logit soft-capping
    q_offset: int = 0,             # absolute position of q[0] (decode)
) -> jnp.ndarray:
    """Reference GQA attention with optional sliding window + logit softcap."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)
