"""Pallas TPU kernel: packed-bit matrix x dense matrix (weighted coverage gains).

The SCSK gain oracle is `gains = A @ (w * uncovered)` where A is a {0,1}
clause-incidence matrix. Storing A as packed uint32 gives a 32x reduction in
HBM traffic versus an int8/bf16 materialization — the op is memory-bound, so
this is a direct 32x on the dominant roofline term. Inside the kernel each
VMEM tile is unpacked to f32 on the fly and fed to the MXU as a [BC, BW*32]
x [BW*32, R] matmul.

Schedule:
  grid = (C/BC,); the word axis is streamed INSIDE the kernel. Both operands
  stay in HBM (`memory_space=ANY`) and each W-block — the [BC, BW] packed
  tile plus its [BW*32, R] x slab — is double-buffered into VMEM with
  `make_async_copy`: block j+1's DMAs are issued before block j's
  unpack+matmul runs, overlapping the HBM streaming (the roofline term) with
  MXU work instead of paying copy latency between grid steps. The [BC, R]
  accumulator is loop-carried and written once.
  VMEM per step: 2*BC*BW*4 (packed slots) + 2*BW*32*R*4 (x slots) +
  BC*BW*32*4 (unpacked scratch, compiler-managed) + BC*R*4 (acc). Defaults
  BC=128, BW=128 give ~2.3 MB << 16 MB VMEM and a 4096-wide MXU contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiles import block_dim

WORD = 32


def _kernel(a_hbm, x_hbm, o_ref, a_buf, x_buf, sem_a, sem_x, *,
            block_c: int, block_w: int, n_w: int):
    i = pl.program_id(0)

    def copy_a(j, slot):
        return pltpu.make_async_copy(
            a_hbm.at[pl.ds(i * block_c, block_c), pl.ds(j * block_w, block_w)],
            a_buf.at[slot],
            sem_a.at[slot],
        )

    def copy_x(j, slot):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(j * block_w * WORD, block_w * WORD), :],
            x_buf.at[slot],
            sem_x.at[slot],
        )

    copy_a(0, 0).start()
    copy_x(0, 0).start()

    def step(j, acc):
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < n_w)
        def _prefetch():                             # next block, other slot
            nxt = jax.lax.rem(j + 1, 2)
            copy_a(j + 1, nxt).start()
            copy_x(j + 1, nxt).start()

        copy_a(j, slot).wait()
        copy_x(j, slot).wait()
        a = a_buf[slot]                              # [BC, BW] uint32
        shifts = jnp.arange(WORD, dtype=jnp.uint32)
        bits = (a[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
        bits = bits.reshape(a.shape[0], -1).astype(jnp.float32)   # [BC, BW*32]
        return acc + jnp.dot(bits, x_buf[slot],
                             preferred_element_type=jnp.float32)

    init = jnp.zeros(o_ref.shape, jnp.float32)
    o_ref[...] = jax.lax.fori_loop(0, n_w, step, init)


@functools.partial(jax.jit, static_argnames=("block_c", "block_w", "interpret"))
def bit_matvec(
    a_bits: jnp.ndarray,       # uint32 [C, W]
    x: jnp.ndarray,            # f32 [W*32, R]
    *,
    block_c: int = 128,
    block_w: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:              # f32 [C, R]
    c, w = a_bits.shape
    wb, r = x.shape
    assert wb == w * WORD, (a_bits.shape, x.shape)
    # pad to tile multiples; zero words / zero x rows contribute nothing.
    bc, cp, nc = block_dim(c, block_c)
    bw, wp, nw = block_dim(w, block_w)
    if cp or wp:
        a_bits = jnp.pad(a_bits, ((0, cp), (0, wp)))
        x = jnp.pad(x, ((0, wp * WORD), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, block_c=bc, block_w=bw, n_w=nw),
        grid=(nc,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),    # streamed by the kernel
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((bc, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((c + cp), r), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, bc, bw), jnp.uint32),     # packed A slots
            pltpu.VMEM((2, bw * WORD, r), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(a_bits, x)
    return out[:c]
