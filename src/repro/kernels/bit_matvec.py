"""Pallas TPU kernel: packed-bit matrix x dense matrix (weighted coverage gains).

The SCSK gain oracle is `gains = A @ (w * uncovered)` where A is a {0,1}
clause-incidence matrix. Storing A as packed uint32 gives a 32x reduction in
HBM traffic versus an int8/bf16 materialization — the op is memory-bound, so
this is a direct 32x on the dominant roofline term. Inside the kernel each
VMEM tile is unpacked to f32 on the fly and fed to the MXU as a [BC, BW*32]
x [BW*32, R] matmul.

Tiling:
  grid = (C/BC, W/BW); W is the minor (sequential) axis so the [BC, R] output
  tile stays resident and accumulates across W-blocks.
  VMEM per step: BC*BW*4 (packed A) + BW*32*R*4 (x) + BC*BW*32*4 (unpacked
  scratch, compiler-managed) + BC*R*4 (acc). Defaults BC=128, BW=128 give a
  working set of ~2.2 MB << 16 MB VMEM and a 4096-wide MXU contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiles import block_dim

WORD = 32


def _kernel(a_ref, x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                                   # [BC, BW] uint32
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (a[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    bits = bits.reshape(a.shape[0], -1).astype(jnp.float32)   # [BC, BW*32]
    x = x_ref[...]                                   # [BW*32, R] f32
    o_ref[...] += jnp.dot(bits, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_c", "block_w", "interpret"))
def bit_matvec(
    a_bits: jnp.ndarray,       # uint32 [C, W]
    x: jnp.ndarray,            # f32 [W*32, R]
    *,
    block_c: int = 128,
    block_w: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:              # f32 [C, R]
    c, w = a_bits.shape
    wb, r = x.shape
    assert wb == w * WORD, (a_bits.shape, x.shape)
    # pad to tile multiples; zero words / zero x rows contribute nothing.
    bc, cp, nc = block_dim(c, block_c)
    bw, wp, nw = block_dim(w, block_w)
    if cp or wp:
        a_bits = jnp.pad(a_bits, ((0, cp), (0, wp)))
        x = jnp.pad(x, ((0, wp * WORD), (0, 0)))
    grid = (nc, nw)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bw * WORD, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bc, r), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((c + cp), r), jnp.float32),
        interpret=interpret,
    )(a_bits, x)
    return out[:c]
