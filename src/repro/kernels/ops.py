"""Backend dispatch for the kernel package.

Three execution paths per op:
  * "pallas"     — real TPU lowering (pl.pallas_call, interpret=False)
  * "interpret"  — Pallas interpret mode (kernel body evaluated on CPU);
                   used by tests to validate the TPU kernel logic
  * "xla"        — pure-jnp reference (chunked where memory-naive), the
                   default on CPU hosts and the path dry-run lowering uses

Placement is one table + one resolver: every public op looks its
implementation up in `_IMPLS` under the path `distributed.ExecutionPlan`
resolves for it — no per-op `if pallas/interpret/xla` chains. Default
resolution: pallas on TPU backends, xla elsewhere. Override with the env var
`REPRO_KERNEL_BACKEND` (a default, or per-op placements like
"xla,clause_match=interpret") or the per-call `backend=` argument.

Mesh placement rides the same plan: under a `"shard"`-axis mesh,
`partition_gain` computes each word-aligned partition's gains on the device
that owns the partition (owner-local slices, one gather of the [C, P] result
crossing the wire) — integer-exact, bit-identical to the xla reference.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.distributed import plan as _plan
from repro.obs import _state as _obs_state
from repro.kernels import autotune as _autotune
from repro.kernels import bit_matvec as _bm
from repro.kernels import clause_match as _cm
from repro.kernels import coverage_gain as _cg
from repro.kernels import fused_match as _fm
from repro.kernels import partition_gain as _pg
from repro.kernels import ref as _ref
from repro.kernels import sparse_gain as _sg
from repro.kernels.tiles import block_dim  # noqa: F401  (public re-export)

WORD = 32


def resolve_backend(backend: str | None = None) -> str:
    """Back-compat alias for `distributed.resolve_backend` (the plan layer
    owns placement now). Raises ValueError on a bad choice."""
    return _plan.resolve_backend(backend)


# -- XLA host strategies -------------------------------------------------------
# Each op's "xla" path is a small family of integer-exact decompositions; the
# winner flips with shape (and host), so the tile autotuner picks per bucket
# (`strategy=` kwarg) and the historical default stays the fallback.

@functools.partial(jax.jit, static_argnames=("chunk_w",))
def _bit_matvec_xla_scan(a_bits: jnp.ndarray, x: jnp.ndarray, chunk_w: int = 256) -> jnp.ndarray:
    """Chunked unpack+matmul so the f32 unpack never exceeds ~C*chunk_w*128B."""
    c, w = a_bits.shape
    cw = min(chunk_w, w)
    pad = -w % cw
    if pad:
        a_bits = jnp.pad(a_bits, ((0, 0), (0, pad)))
        x = jnp.pad(x, ((0, pad * WORD), (0, 0)))
    nw = (w + pad) // cw
    a_c = a_bits.reshape(c, nw, cw).transpose(1, 0, 2)        # [nw, C, cw]
    x_c = x.reshape(nw, cw * WORD, x.shape[-1])               # [nw, cw*32, R]

    def body(acc, operand):
        a_blk, x_blk = operand
        return acc + _ref.unpack_bits_f32(a_blk) @ x_blk, None

    # init inherits the inputs' varying-manual-axes (shard_map vma tracking):
    # a plain zeros carry would mismatch the body output type inside shard_map
    init = (jnp.zeros((c, x.shape[-1]), jnp.float32)
            + x[:1, :] * 0.0 + a_bits[:, :1].astype(jnp.float32) * 0.0)
    acc, _ = jax.lax.scan(body, init, (a_c, x_c))
    return acc


@jax.jit
def _bit_matvec_xla_unroll(a_bits: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """32 shift-mask matvecs: never materializes an unpacked [C, W*32] plane,
    so it wins when R is large enough that the f32 unpack dominates."""
    c, w = a_bits.shape
    r = x.shape[-1]
    xr = x.reshape(w, WORD, r)
    acc = jnp.zeros((c, r), jnp.float32)
    for bit in range(WORD):
        lane = ((a_bits >> jnp.uint32(bit)) & jnp.uint32(1)).astype(jnp.float32)
        acc = acc + lane @ xr[:, bit, :]
    return acc


@jax.jit
def _bit_matvec_xla_lut(a_bits: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Byte-LUT gather: precompute each byte position's 256 partial sums
    (one [256, 8] unpack table against x), then one gather + sum per byte.
    Trades the per-row unpack for 4 gathers/word — the fastest host path for
    narrow R at bench shapes. Float sums reassociate vs. the scan path
    (allclose, not bit-equal), which matters to nobody downstream: match
    bitsets stay integer ops."""
    c, w = a_bits.shape
    r = x.shape[-1]
    byte_sh = jnp.arange(4, dtype=jnp.uint32) * 8
    byts = ((a_bits[:, :, None] >> byte_sh) & jnp.uint32(0xFF))
    byts = byts.astype(jnp.int32).reshape(c, w * 4)              # [C, W*4]
    tbl = (((jnp.arange(256)[:, None] >> jnp.arange(8)) & 1)
           ).astype(jnp.float32)                                 # [256, 8]
    xb = x.reshape(w * 4, 8, r)
    partial = jnp.einsum("vb,pbr->pvr", tbl, xb)                 # [W*4, 256, R]
    picked = jnp.take_along_axis(partial, byts.T[:, :, None], axis=1)
    return jnp.sum(picked, axis=0)                               # [C, R]


def _bit_matvec_xla(a_bits: jnp.ndarray, x: jnp.ndarray, *,
                    strategy: str = "scan", chunk_w: int = 256) -> jnp.ndarray:
    if strategy == "unroll":
        return _bit_matvec_xla_unroll(a_bits, x)
    if strategy == "lut":
        return _bit_matvec_xla_lut(a_bits, x)
    return _bit_matvec_xla_scan(a_bits, x, chunk_w=chunk_w)


_clause_match_xla_plain = jax.jit(_ref.clause_match)


@functools.partial(jax.jit, static_argnames=("chunk_b",))
def _clause_match_xla_scan(query_bits: jnp.ndarray, clause_bits: jnp.ndarray,
                           chunk_b: int = 1024) -> jnp.ndarray:
    """Chunked over queries so the [b, K, Wv] subset-test intermediate stays
    bounded regardless of batch size."""
    b = query_bits.shape[0]
    cb = min(chunk_b, max(1, b))
    pad = -b % cb
    if pad:
        query_bits = jnp.pad(query_bits, ((0, pad), (0, 0)))
    chunks = query_bits.reshape(-1, cb, query_bits.shape[1])

    def body(_, q):
        return None, _ref.clause_match(q, clause_bits)

    _, out = jax.lax.scan(body, None, chunks)
    return out.reshape(-1)[:b]


@jax.jit
def _clause_match_xla_gemm(query_bits: jnp.ndarray, clause_bits: jnp.ndarray) -> jnp.ndarray:
    """Subset test as one GEMM: clause k ⊆ query b iff the intersection
    popcount equals the clause popcount. Exact in f32 up to 2^24 set bits per
    row — vocab words * 32 is far below that everywhere in this repo."""
    qf = _ref.unpack_bits_f32(query_bits)                        # [B, Wv*32]
    cf = _ref.unpack_bits_f32(clause_bits)                       # [K, Wv*32]
    inter = qf @ cf.T                                            # [B, K]
    need = jnp.sum(cf, axis=-1)
    return jnp.any(inter == need[None, :], axis=-1)


def _clause_match_xla(query_bits: jnp.ndarray, clause_bits: jnp.ndarray, *,
                      strategy: str = "scan", chunk_b: int = 1024) -> jnp.ndarray:
    if strategy == "plain":
        return _clause_match_xla_plain(query_bits, clause_bits)
    if strategy == "gemm":
        return _clause_match_xla_gemm(query_bits, clause_bits)
    return _clause_match_xla_scan(query_bits, clause_bits, chunk_b=chunk_b)


@functools.partial(jax.jit, static_argnames=("bounds",))
def _partition_gain_xla(a_bits: jnp.ndarray, mask: jnp.ndarray,
                        bounds: tuple[int, ...]) -> jnp.ndarray:
    """Integer-exact per-partition slice popcounts; peak memory is bounded by
    C * widest-partition (each column materializes one word slice)."""
    cols = [jnp.sum(jax.lax.population_count(
                a_bits[:, lo:hi] & ~mask[None, lo:hi]).astype(jnp.int32),
                axis=-1)
            for lo, hi in zip(bounds, bounds[1:])]
    return jnp.stack(cols, axis=-1)


# -- placement table -----------------------------------------------------------
# op -> {path -> impl}. "interpret" is always the pallas body run through the
# Pallas interpreter, so the TPU kernel logic is what CPU tests validate.

_IMPLS = {
    "bit_matvec": {
        "pallas": _bm.bit_matvec,
        "interpret": functools.partial(_bm.bit_matvec, interpret=True),
        "xla": _bit_matvec_xla,
    },
    "coverage_gain": {
        "pallas": _cg.coverage_gain,
        "interpret": functools.partial(_cg.coverage_gain, interpret=True),
        "xla": _ref.coverage_gain,
    },
    "clause_match": {
        "pallas": _cm.clause_match,
        "interpret": functools.partial(_cm.clause_match, interpret=True),
        "xla": _clause_match_xla,
    },
    "partition_gain": {
        "pallas": _pg.partition_gain,
        "interpret": functools.partial(_pg.partition_gain, interpret=True),
        "xla": _partition_gain_xla,
    },
    "sparse_gain": {
        "pallas": _sg.sparse_gain,
        "interpret": functools.partial(_sg.sparse_gain, interpret=True),
        "xla": _ref.sparse_gain,
    },
    "fused_match": {
        "pallas": _fm.fused_match,
        "interpret": functools.partial(_fm.fused_match, interpret=True),
        "xla": _fm.fused_match_xla,
    },
}


def _impl(op: str, backend: str | None):
    return _IMPLS[op][_plan.current_plan().placement(op, backend)]


# -- dispatch cost accounting (repro.obs.profile) ------------------------------
# Shape-derived models: uint32 postings words READ per call, plus modelled
# HBM bytes (uint32/f32 operands + result). Reported to the process profiler
# on every dispatch while the telemetry plane is on — one `_state.on` check
# is the only cost when it is off (REPRO_OBS=0: complete no-op).

def _cost_bit_matvec(a_bits, x):
    c, w = a_bits.shape
    r = int(x.shape[-1])
    return c * w, 4 * (c * w + w * WORD * r + c * r)


def _cost_coverage_gain(a_bits, mask):
    c, w = a_bits.shape
    return c * w, 4 * (c * w + w + c)


def _cost_clause_match(query_bits, clause_bits):
    b, wv = query_bits.shape
    k = clause_bits.shape[0]
    return (b + k) * wv, 4 * (b + k) * wv + b


def _cost_partition_gain(a_bits, mask, bounds):
    c, w = a_bits.shape
    p = len(bounds) - 1
    return c * w + w, 4 * (c * w + w + c * p)


def _cost_sparse_gain(doc_ids, mask):
    c, m = doc_ids.shape
    return c * m, 4 * (2 * c * m + c)


def _cost_fused_match(query_bits, clause_bits, tokens, t1, t2):
    b, wv = query_bits.shape
    k = clause_bits.shape[0]
    ell = tokens.shape[1]
    w = t1.shape[-1]
    words = (b + k) * wv + b * ell * w          # classify reads + row gathers
    return words, 4 * words + 4 * b * w + b


_PROF = None


def _profiler():
    global _PROF
    if _PROF is None:                # bind late: repro.obs owns the singleton
        from repro import obs
        _PROF = obs.PROFILER
    return _PROF


def _profiled(op: str, path: str, fn, cost, *args):
    """Dispatch `fn(*args)` with cost accounting (plane known to be on)."""
    prof = _profiler()
    words, nbytes = cost(*args)
    t0 = time.perf_counter() if prof.active else 0.0
    out = fn(*args)
    prof.record(op, path, words, nbytes,
                out=out if prof.active else None, t0=t0)
    return out


def _run(op: str, backend: str | None, cost, *args):
    plan = _plan.current_plan()
    path = plan.placement(op, backend)
    fn = _IMPLS[op][path]
    # Measured-best tiles/strategy for this (op, path, shape-bucket), if the
    # autotune cache has an entry; {} keeps the impl's hardcoded defaults.
    tiles = plan.tile_params(op, path, _autotune.bucket_from_args(op, args))
    if tiles:
        fn = functools.partial(fn, **tiles)
    if not _obs_state.on:
        return fn(*args)
    return _profiled(op, path, fn, cost, *args)


# -- public ops ----------------------------------------------------------------

def bit_matvec(a_bits: jnp.ndarray, x: jnp.ndarray, *, backend: str | None = None) -> jnp.ndarray:
    """gains [C, R] = unpack(a_bits [C, W]) @ x [W*32, R]."""
    return _run("bit_matvec", backend, _cost_bit_matvec, a_bits, x)


def coverage_gain(a_bits: jnp.ndarray, mask: jnp.ndarray, *, backend: str | None = None) -> jnp.ndarray:
    """gains [C] = popcount(a_bits & ~mask)."""
    return _run("coverage_gain", backend, _cost_coverage_gain, a_bits, mask)


def clause_match(query_bits: jnp.ndarray, clause_bits: jnp.ndarray, *,
                 backend: str | None = None) -> jnp.ndarray:
    """eligible [B] bool = any clause row is a bitwise subset of the query.

    This is the batched ψ^clause classifier (paper eq. 8): one call per
    serving batch replaces the engine's per-query host loop.
    """
    if clause_bits.shape[0] == 0 or query_bits.shape[0] == 0:
        return jnp.zeros((query_bits.shape[0],), bool)
    return _run("clause_match", backend, _cost_clause_match,
                query_bits, clause_bits)


def fused_match(query_bits: jnp.ndarray, clause_bits: jnp.ndarray,
                tokens: jnp.ndarray, t1: jnp.ndarray, t2: jnp.ndarray, *,
                backend: str | None = None):
    """One-dispatch ψ classify + tier-selected AND-match.

    Returns ``(match [B, W] uint32, eligible [B] bool)``: each query's token
    posting rows are gathered from `t1` when the query is clause-eligible and
    from `t2` otherwise, then AND-reduced over valid (>= 0) tokens. The old
    serve path round-tripped `eligible` between two dispatches; this is the
    fusion that removes that host sync. An empty `clause_bits` ([0, Wv])
    statically routes everyone to Tier-2.
    """
    return _run("fused_match", backend, _cost_fused_match,
                query_bits, clause_bits, tokens, t1, t2)


def partition_gain(a_bits: jnp.ndarray, mask: jnp.ndarray,
                   bounds, *, backend: str | None = None) -> jnp.ndarray:
    """gains [C, P]: per-partition popcount(a & ~mask) over word ranges.

    `bounds` is the word-offset cut list (len P+1, bounds[0]=0, bounds[-1]=W)
    of a word-aligned doc-space partition — the batched g_k(.|X) oracle
    behind `core.constraint.PartitionedBudget`.

    Under a `"shard"`-axis mesh the partitions ARE the fleet shards: each
    device popcounts its own partition's word slice locally (the same
    owner-local fusion the global Opt/Pes f/g path has) and only the [C, P]
    result gather crosses the wire — integer-exact, so the output is
    bit-identical to the single-device path.
    """
    bounds = tuple(int(b) for b in bounds)
    plan = _plan.current_plan()

    def cost(a, m):
        return _cost_partition_gain(a, m, bounds)

    # an explicitly pinned path (backend= arg or per-op env placement) wins
    # over the mesh fusion — pinning exists to exercise a specific kernel
    if plan.shard_fused and not plan.pinned("partition_gain", backend):
        def fused(a, m):
            return _partition_gain_mesh(a, m, bounds, plan)
        if not _obs_state.on:
            return fused(a_bits, mask)
        return _profiled("partition_gain", "mesh", fused, cost, a_bits, mask)

    path = plan.placement("partition_gain", backend)
    impl = _IMPLS["partition_gain"][path]
    tiles = plan.tile_params(
        "partition_gain", path,
        _autotune.bucket("partition_gain", a_bits.shape[0], a_bits.shape[1],
                         len(bounds) - 1))

    def host(a, m):
        return impl(a, m, bounds, **tiles)

    if not _obs_state.on:
        return host(a_bits, mask)
    return _profiled("partition_gain", path, host, cost, a_bits, mask)


def sparse_gain(doc_ids: jnp.ndarray, mask: jnp.ndarray, *, backend: str | None = None) -> jnp.ndarray:
    """gains [C] over padded id lists."""
    return _run("sparse_gain", backend, _cost_sparse_gain, doc_ids, mask)


# -- owner-local partitioned gains over the "shard" mesh axis ------------------

def _partition_gain_mesh(a_bits: jnp.ndarray, mask: jnp.ndarray,
                         bounds: tuple[int, ...], plan) -> jnp.ndarray:
    """Each partition's AND-NOT popcount on the device that owns it.

    The [C, W] operand is restacked into per-partition slices [P', C, wmax]
    (P' padded to a multiple of the shard-axis size, slices zero-padded to
    the widest partition — padded mask words are all-ones so they contribute
    0), sharded over `"shard"`, popcounted owner-locally, and the [C, P]
    columns gathered back. Integer int32 sums: exact at any scale, matching
    `_partition_gain_xla` bit for bit.
    """
    from jax.sharding import PartitionSpec as P

    c, _ = a_bits.shape
    p = len(bounds) - 1
    d = plan.n_shard_devices
    p_pad = -p % d
    wmax = max(hi - lo for lo, hi in zip(bounds, bounds[1:]))

    ones = jnp.uint32(0xFFFFFFFF)

    def stack(k):
        if k >= p:      # padding partition: all-ones mask -> zero gains
            return (jnp.zeros((c, wmax), jnp.uint32),
                    jnp.full((wmax,), ones, jnp.uint32))
        lo, hi = bounds[k], bounds[k + 1]
        wp = wmax - (hi - lo)
        return (jnp.pad(a_bits[:, lo:hi], ((0, 0), (0, wp))),
                jnp.concatenate([mask[lo:hi],
                                 jnp.full((wp,), ones, jnp.uint32)]))

    parts = [stack(k) for k in range(p + p_pad)]
    a_parts = jnp.stack([a for a, _ in parts])       # [P', C, wmax]
    m_parts = jnp.stack([m for _, m in parts])       # [P', wmax]

    def body(ap, mp):
        fresh = ap & ~mp[:, None, :]
        return jnp.sum(jax.lax.population_count(fresh).astype(jnp.int32),
                       axis=-1).T                    # [C, P_local]

    ax = plan.shard_axis
    fused = _plan.mesh_fused(
        body, in_specs=(P(ax), P(ax)), out_specs=P(None, ax),
        axis=ax, mesh=plan.mesh)
    return fused(a_parts, m_parts)[:, :p]
