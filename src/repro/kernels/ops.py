"""Backend dispatch for the kernel package.

Three execution paths per op:
  * "pallas"     — real TPU lowering (pl.pallas_call, interpret=False)
  * "interpret"  — Pallas interpret mode (kernel body evaluated on CPU);
                   used by tests to validate the TPU kernel logic
  * "xla"        — pure-jnp reference (chunked where memory-naive), the
                   default on CPU hosts and the path dry-run lowering uses

Default resolution: pallas on TPU backends, xla elsewhere. Override with the
env var REPRO_KERNEL_BACKEND or the per-call `backend=` argument.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import bit_matvec as _bm
from repro.kernels import clause_match as _cm
from repro.kernels import coverage_gain as _cg
from repro.kernels import partition_gain as _pg
from repro.kernels import ref as _ref
from repro.kernels import sparse_gain as _sg
from repro.kernels.tiles import block_dim  # noqa: F401  (public re-export)

WORD = 32


def resolve_backend(backend: str | None = None) -> str:
    b = backend or os.environ.get("REPRO_KERNEL_BACKEND", "auto")
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    assert b in ("pallas", "interpret", "xla"), b
    return b


@functools.partial(jax.jit, static_argnames=("chunk_w",))
def _bit_matvec_xla(a_bits: jnp.ndarray, x: jnp.ndarray, chunk_w: int = 256) -> jnp.ndarray:
    """Chunked unpack+matmul so the f32 unpack never exceeds ~C*chunk_w*128B."""
    c, w = a_bits.shape
    cw = min(chunk_w, w)
    pad = -w % cw
    if pad:
        a_bits = jnp.pad(a_bits, ((0, 0), (0, pad)))
        x = jnp.pad(x, ((0, pad * WORD), (0, 0)))
    nw = (w + pad) // cw
    a_c = a_bits.reshape(c, nw, cw).transpose(1, 0, 2)        # [nw, C, cw]
    x_c = x.reshape(nw, cw * WORD, x.shape[-1])               # [nw, cw*32, R]

    def body(acc, operand):
        a_blk, x_blk = operand
        return acc + _ref.unpack_bits_f32(a_blk) @ x_blk, None

    # init inherits the inputs' varying-manual-axes (shard_map vma tracking):
    # a plain zeros carry would mismatch the body output type inside shard_map
    init = (jnp.zeros((c, x.shape[-1]), jnp.float32)
            + x[:1, :] * 0.0 + a_bits[:, :1].astype(jnp.float32) * 0.0)
    acc, _ = jax.lax.scan(body, init, (a_c, x_c))
    return acc


def bit_matvec(a_bits: jnp.ndarray, x: jnp.ndarray, *, backend: str | None = None) -> jnp.ndarray:
    """gains [C, R] = unpack(a_bits [C, W]) @ x [W*32, R]."""
    b = resolve_backend(backend)
    if b == "pallas":
        return _bm.bit_matvec(a_bits, x)
    if b == "interpret":
        return _bm.bit_matvec(a_bits, x, interpret=True)
    return _bit_matvec_xla(a_bits, x)


def coverage_gain(a_bits: jnp.ndarray, mask: jnp.ndarray, *, backend: str | None = None) -> jnp.ndarray:
    """gains [C] = popcount(a_bits & ~mask)."""
    b = resolve_backend(backend)
    if b == "pallas":
        return _cg.coverage_gain(a_bits, mask)
    if b == "interpret":
        return _cg.coverage_gain(a_bits, mask, interpret=True)
    return _ref.coverage_gain(a_bits, mask)


@functools.partial(jax.jit, static_argnames=("chunk_b",))
def _clause_match_xla(query_bits: jnp.ndarray, clause_bits: jnp.ndarray,
                      chunk_b: int = 1024) -> jnp.ndarray:
    """Chunked over queries so the [b, K, Wv] subset-test intermediate stays
    bounded regardless of batch size."""
    b = query_bits.shape[0]
    cb = min(chunk_b, max(1, b))
    pad = -b % cb
    if pad:
        query_bits = jnp.pad(query_bits, ((0, pad), (0, 0)))
    chunks = query_bits.reshape(-1, cb, query_bits.shape[1])

    def body(_, q):
        return None, _ref.clause_match(q, clause_bits)

    _, out = jax.lax.scan(body, None, chunks)
    return out.reshape(-1)[:b]


def clause_match(query_bits: jnp.ndarray, clause_bits: jnp.ndarray, *,
                 backend: str | None = None) -> jnp.ndarray:
    """eligible [B] bool = any clause row is a bitwise subset of the query.

    This is the batched ψ^clause classifier (paper eq. 8): one call per
    serving batch replaces the engine's per-query host loop.
    """
    if clause_bits.shape[0] == 0 or query_bits.shape[0] == 0:
        return jnp.zeros((query_bits.shape[0],), bool)
    b = resolve_backend(backend)
    if b == "pallas":
        return _cm.clause_match(query_bits, clause_bits)
    if b == "interpret":
        return _cm.clause_match(query_bits, clause_bits, interpret=True)
    return _clause_match_xla(query_bits, clause_bits)


@functools.partial(jax.jit, static_argnames=("bounds",))
def _partition_gain_xla(a_bits: jnp.ndarray, mask: jnp.ndarray,
                        bounds: tuple[int, ...]) -> jnp.ndarray:
    """Integer-exact per-partition slice popcounts; peak memory is bounded by
    C * widest-partition (each column materializes one word slice)."""
    cols = [jnp.sum(jax.lax.population_count(
                a_bits[:, lo:hi] & ~mask[None, lo:hi]).astype(jnp.int32),
                axis=-1)
            for lo, hi in zip(bounds, bounds[1:])]
    return jnp.stack(cols, axis=-1)


def partition_gain(a_bits: jnp.ndarray, mask: jnp.ndarray,
                   bounds, *, backend: str | None = None) -> jnp.ndarray:
    """gains [C, P]: per-partition popcount(a & ~mask) over word ranges.

    `bounds` is the word-offset cut list (len P+1, bounds[0]=0, bounds[-1]=W)
    of a word-aligned doc-space partition — the batched g_k(.|X) oracle
    behind `core.constraint.PartitionedBudget`.
    """
    bounds = tuple(int(b) for b in bounds)
    b = resolve_backend(backend)
    if b == "pallas":
        return _pg.partition_gain(a_bits, mask, bounds)
    if b == "interpret":
        return _pg.partition_gain(a_bits, mask, bounds, interpret=True)
    return _partition_gain_xla(a_bits, mask, bounds)


def sparse_gain(doc_ids: jnp.ndarray, mask: jnp.ndarray, *, backend: str | None = None) -> jnp.ndarray:
    """gains [C] over padded id lists."""
    b = resolve_backend(backend)
    if b == "pallas":
        return _sg.sparse_gain(doc_ids, mask)
    if b == "interpret":
        return _sg.sparse_gain(doc_ids, mask, interpret=True)
    return _ref.sparse_gain(doc_ids, mask)
