"""Pallas TPU flash attention: GQA + causal + sliding window + logit softcap.

Same contract as models/common.chunked_attention (the XLA fallback) and
kernels/ref.flash_attention (the oracle). Online-softmax accumulators (m, l,
acc) live in VMEM scratch and persist across the KV grid axis; fully-masked
KV blocks are skipped under the causal/window structure (the classic
flash-attention block-skipping that the XLA path cannot express).

Layout: heads are grouped GQA-style — inputs are reshaped to
  q   [B, Hkv, G, Sq, D]
  k,v [B, Hkv, Skv, D]
grid = (B, Hkv, Sq/bq, Skv/bk), KV minor (sequential) for accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, n_k: int, causal: bool, window: int | None,
            cap: float | None, q_offset: int, scale: float, kv_valid: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + q_offset
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < kv_valid          # padded KV columns contribute nothing
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window

    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # [G, bq, D]
        k = k_ref[0, 0].astype(jnp.float32)             # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)             # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # [G, bq, bk]
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        s = jnp.where(mask[None], s, NEG)
        m_prev = m_ref[...]                              # [G, bq]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p, v, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # [G, bq, D]
        m_ref[...] = m_new

    if causal or window is not None:
        # block-level skip: first/last kv positions this block could touch
        blk_q_lo = iq * bq + q_offset
        blk_q_hi = blk_q_lo + bq - 1
        blk_k_lo = ik * bk
        live = jnp.bool_(True)
        if causal:
            live &= blk_k_lo <= blk_q_hi
        if window is not None:
            blk_k_hi = blk_k_lo + bk - 1
            live &= blk_k_hi > blk_q_lo - window
        pl.when(live)(compute)
    else:
        compute()

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[..., None]
                       ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "q_offset",
                     "block_q", "block_k", "interpret"))
def _flash_attention_impl(
    q: jnp.ndarray,                 # [B, Sq, Hq, D]
    k: jnp.ndarray,                 # [B, Skv, Hkv, D]
    v: jnp.ndarray,                 # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    from jax.experimental.pallas import tpu as pltpu
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    qp = (-sq) % bq
    kp = (-skv) % bk
    qh = q.transpose(0, 2, 1, 3).reshape(b, hkv, g, sq, d)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    if qp:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, 0), (0, qp), (0, 0)))
    if kp:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, kp), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, kp), (0, 0)))
    n_q = (sq + qp) // bq
    n_k = (skv + kp) // bk
    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, n_k=n_k, causal=causal, window=window,
        cap=softcap, q_offset=q_offset, scale=1.0 / np.sqrt(d),
        kv_valid=skv)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, g, bq, d), lambda b_, h, i, j: (b_, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j: (b_, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, bq, d),
                               lambda b_, h, i, j: (b_, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, sq + qp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, bq), jnp.float32),
            pltpu.VMEM((g, bq), jnp.float32),
            pltpu.VMEM((g, bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out[:, :, :, :sq, :].reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
    return out


def _cost(q, k, v):
    """Cost model in the `ops.py` convention: 32-bit-word-equivalents read
    (operand bytes / 4, attention has no packed postings) plus modelled HBM
    bytes for operands + result (the result has q's shape and dtype)."""
    op_bytes = q.size * q.dtype.itemsize \
        + (k.size + v.size) * k.dtype.itemsize
    nbytes = op_bytes + q.size * q.dtype.itemsize
    return op_bytes // 4, nbytes


def flash_attention(
    q: jnp.ndarray,                 # [B, Sq, Hq, D]
    k: jnp.ndarray,                 # [B, Skv, Hkv, D]
    v: jnp.ndarray,                 # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Flash attention with the same `obs.PROFILER` cost accounting every
    `ops.py` op gets — it is the one Pallas kernel dispatched outside the
    ops table, so without this wrapper its traffic never lands in
    `kernel_bytes_moved_total`."""
    kw = dict(causal=causal, window=window, softcap=softcap,
              q_offset=q_offset, block_q=block_q, block_k=block_k,
              interpret=interpret)
    from repro.obs import _state as _obs_state
    if not _obs_state.on:
        return _flash_attention_impl(q, k, v, **kw)
    from repro.kernels import ops as _ops
    path = "interpret" if interpret else "pallas"
    return _ops._profiled("flash_attention", path,
                          lambda q_, k_, v_: _flash_attention_impl(q_, k_, v_, **kw),
                          _cost, q, k, v)
