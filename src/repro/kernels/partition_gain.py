"""Pallas TPU kernel: batched per-partition AND-NOT-popcount gains.

gains[c, k] = popcount(A[c, lo_k:hi_k] & ~covered[lo_k:hi_k]) — the
g_k(.|X) document-cost oracle of a partitioned knapsack (per-shard budgets
B_k over word-aligned doc ranges). One fused pass over the packed incidence
rows computes EVERY partition's cost-gain column at once: the AND-NOT
popcount runs on the VPU exactly like `coverage_gain`, and the word→partition
reduction is a popcount @ segment-one-hot matmul on the MXU, so arbitrary
(word-aligned) partition boundaries never break the `block_dim` tiling.

Counts are exact while n_docs < 2**24 (f32 integer accumulation); the
dispatch layer's XLA path (`ops.partition_gain`) is integer-exact at any
scale and is the semantics of record.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.tiles import block_dim

_LANE = 128          # f32 lane tile: pad the partition axis up to it


def _kernel(a_ref, m_ref, s_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                       # [BC, BW] uint32
    m = m_ref[...]                       # [1, BW] uint32
    fresh = a & ~m
    cnt = jax.lax.population_count(fresh).astype(jnp.float32)
    # word -> partition segment reduction as one MXU matmul
    o_ref[...] += jnp.dot(cnt, s_ref[...],
                          preferred_element_type=jnp.float32)


def segment_selector(n_words: int, bounds: tuple[int, ...],
                     n_cols: int) -> jnp.ndarray:
    """f32 [n_words, n_cols] one-hot of each word's owning partition."""
    cuts = jnp.asarray(bounds[1:-1], jnp.int32)
    part = jnp.searchsorted(cuts, jnp.arange(n_words, dtype=jnp.int32),
                            side="right")
    return jax.nn.one_hot(part, n_cols, dtype=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("bounds", "block_c", "block_w",
                                    "interpret"))
def partition_gain(
    a_bits: jnp.ndarray,      # uint32 [C, W]
    mask: jnp.ndarray,        # uint32 [W]
    bounds: tuple[int, ...],  # word offsets, len P+1, bounds[0]=0, [-1]=W
    *,
    block_c: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:             # int32 [C, P]
    c, w = a_bits.shape
    p = len(bounds) - 1
    bc, cp, nc = block_dim(c, block_c)
    bw, wp, nw = block_dim(w, block_w)
    pp = -p % _LANE
    if cp or wp:
        # padded words carry zero incidence bits -> contribute 0 to any column
        a_bits = jnp.pad(a_bits, ((0, cp), (0, wp)))
        # np scalar, not a python int: 0xFFFFFFFF would be weak-typed int32
        # and overflow abstractification the first time a pad is non-empty
        mask = jnp.pad(mask, (0, wp), constant_values=np.uint32(0xFFFFFFFF))
    sel = segment_selector(w + wp, bounds, p + pp)
    grid = (nc, nw)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bw), lambda i, j: (i, j)),
            pl.BlockSpec((1, bw), lambda i, j: (0, j)),
            pl.BlockSpec((bw, p + pp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bc, p + pp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c + cp, p + pp), jnp.float32),
        interpret=interpret,
    )(a_bits, mask[None, :], sel)
    return out[:c, :p].astype(jnp.int32)
