"""Seeded, deterministic tile/strategy autotuner for the packed-bit kernels.

Every kernel dispatch in `ops.py` resolves its tuning parameters through
`ExecutionPlan.tile_params`, which lands here: the call shape is rounded to a
power-of-two bucket (`tiles.pow2_bucket`) and looked up in a persisted JSON
cache keyed ``"{op}|{path}|{bucket}"``.  A hit overrides the hardcoded
defaults (block sizes for the Pallas/interpret kernels, algorithm strategy +
chunking for the XLA host fallbacks); a miss keeps the status-quo defaults, so
the cache is a pure go-faster overlay and never a correctness dependency.

Cache resolution order:

- ``REPRO_KERNEL_TILES=0|off|none``  → autotuning disabled, defaults only.
- ``REPRO_KERNEL_TILES=/path.json``  → explicit cache file.
- unset                              → ``artifacts/autotune/tiles.json``.

The search itself (`search` / `ensure_cache`, also exposed as
``python -m repro.kernels.autotune``) is deterministic by construction: data
is synthesized from a fixed seed, candidates are enumerated in a fixed order,
timing uses interleaved round-robin trials with a median reduce (robust to
wall-clock drift on shared hosts), and ties break toward the earlier
candidate.  The *picked* entries are machine-dependent by design — that is the
point of tuning — which is why the cache lives under the gitignored
``artifacts/`` tree and is regenerated per host, never committed.
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Any, Callable, Dict, List, Sequence, Tuple

from repro.kernels.tiles import pow2_bucket

ENV_VAR = "REPRO_KERNEL_TILES"
DEFAULT_CACHE = os.path.join("artifacts", "autotune", "tiles.json")
_DISABLED = ("0", "off", "none", "false")
CACHE_VERSION = 1

# ---------------------------------------------------------------------------
# Candidate spaces.
#
# Keyed (op, path).  Pallas/interpret entries sweep block shapes; the XLA host
# path sweeps *algorithm strategies* (the block structure there is XLA's
# business, but the decomposition — scan-chunked unpack+GEMM vs. 32-way
# shift-mask unroll vs. byte-LUT gather — changes the memory traffic shape and
# the winner flips with (C, W, R)).  Every candidate is integer-exact; only
# speed differs.
# ---------------------------------------------------------------------------

_BLOCKS_CM = [
    {"block_b": bb, "block_k": bk} for bb in (32, 64, 128) for bk in (32, 64, 128)
]
_BLOCKS_CW = [
    {"block_c": bc, "block_w": bw} for bc in (64, 128, 256) for bw in (64, 128, 256)
]
_BLOCKS_CW_WIDE = [
    {"block_c": bc, "block_w": bw} for bc in (128, 256) for bw in (128, 256, 512)
]

SPACES: Dict[Tuple[str, str], List[Dict[str, Any]]] = {
    ("clause_match", "xla"): [
        {"strategy": "plain"},
        {"strategy": "scan", "chunk_b": 256},
        {"strategy": "scan", "chunk_b": 512},
        {"strategy": "scan", "chunk_b": 1024},
        {"strategy": "gemm"},
    ],
    ("bit_matvec", "xla"): [
        {"strategy": "scan", "chunk_w": 128},
        {"strategy": "scan", "chunk_w": 256},
        {"strategy": "scan", "chunk_w": 512},
        {"strategy": "unroll"},
        {"strategy": "lut"},
    ],
    ("clause_match", "pallas"): _BLOCKS_CM,
    ("clause_match", "interpret"): _BLOCKS_CM,
    ("bit_matvec", "pallas"): _BLOCKS_CW,
    ("bit_matvec", "interpret"): _BLOCKS_CW,
    ("coverage_gain", "pallas"): _BLOCKS_CW_WIDE,
    ("coverage_gain", "interpret"): _BLOCKS_CW_WIDE,
    ("partition_gain", "pallas"): _BLOCKS_CW_WIDE,
    ("partition_gain", "interpret"): _BLOCKS_CW_WIDE,
}


def bucket(op: str, *dims: int) -> str:
    """Canonical bucket string for an op's characteristic dims (pow2-rounded)."""
    names = {
        "clause_match": ("b", "k", "w"),
        "bit_matvec": ("c", "w", "r"),
        "coverage_gain": ("c", "w"),
        "partition_gain": ("c", "w", "p"),
        "fused_match": ("b", "l", "w"),
    }[op]
    return "_".join(f"{n}{pow2_bucket(max(1, d))}" for n, d in zip(names, dims))


def bucket_from_args(op: str, args: Sequence[Any]):
    """Derive the shape bucket from the positional args `ops._run` sees.

    Returns None for ops with no tunable space (dispatch then skips the cache
    lookup entirely, keeping the hot path at two dict probes).
    """
    if op == "clause_match":
        q, c = args[0], args[1]
        return bucket(op, q.shape[0], c.shape[0], q.shape[1])
    if op == "bit_matvec":
        a, x = args[0], args[1]
        r = x.shape[1] if x.ndim > 1 else 1
        return bucket(op, a.shape[0], a.shape[1], r)
    if op == "coverage_gain":
        a = args[0]
        return bucket(op, a.shape[0], a.shape[1])
    return None


# ---------------------------------------------------------------------------
# Cache lookup (hot path — memoized on the env value so a test flipping
# REPRO_KERNEL_TILES via monkeypatch invalidates naturally; call
# `invalidate()` after rewriting the cache file in-place).
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=8)
def _load_entries(path: str) -> Dict[str, Dict[str, Any]]:
    try:
        with open(path) as fh:
            blob = json.load(fh)
    except (OSError, ValueError):
        return {}
    if not isinstance(blob, dict) or blob.get("version") != CACHE_VERSION:
        return {}
    entries = blob.get("entries", {})
    return entries if isinstance(entries, dict) else {}


@functools.lru_cache(maxsize=4096)
def _tile_params_cached(env_raw, op: str, path: str, shape_bucket: str):
    if env_raw is not None and env_raw.strip().lower() in _DISABLED:
        return {}
    cache_path = env_raw if env_raw else DEFAULT_CACHE
    got = _load_entries(cache_path).get(f"{op}|{path}|{shape_bucket}")
    if not isinstance(got, dict):
        return {}
    # Drop bookkeeping keys; whatever remains is kwargs for the kernel impl.
    return {k: v for k, v in got.items() if not k.startswith("_")}


def tile_params(op: str, path: str, shape_bucket) -> Dict[str, Any]:
    """Tuned kwargs for (op, path, bucket); {} on miss or when disabled."""
    if shape_bucket is None:
        return {}
    return dict(_tile_params_cached(os.environ.get(ENV_VAR), op, path, shape_bucket))


def invalidate() -> None:
    """Drop memoized cache state (tests rewrite tiles.json in place)."""
    _load_entries.cache_clear()
    _tile_params_cached.cache_clear()


def cache_path() -> str:
    raw = os.environ.get(ENV_VAR)
    if raw and raw.strip().lower() not in _DISABLED:
        return raw
    return DEFAULT_CACHE


# ---------------------------------------------------------------------------
# Search.
# ---------------------------------------------------------------------------

# Default tuning workload: the shapes the checked-in benchmarks exercise, so a
# fresh cache immediately feeds the profile/micro rows.  (op, path, dims).
DEFAULT_WORKLOAD: List[Tuple[str, str, Tuple[int, ...]]] = [
    ("clause_match", "xla", (512, 128, 64)),
    ("clause_match", "xla", (2048, 512, 64)),
    ("bit_matvec", "xla", (4096, 512, 1)),
    ("bit_matvec", "xla", (4096, 1024, 1)),
]


def _synth(op: str, dims: Tuple[int, ...], seed: int):
    import numpy as np

    rng = np.random.default_rng(seed)
    if op == "clause_match":
        b, k, wv = dims
        q = rng.integers(0, 1 << 32, size=(b, wv), dtype=np.uint32)
        c = (
            rng.integers(0, 1 << 32, size=(k, wv), dtype=np.uint32)
            & rng.integers(0, 1 << 32, size=(k, wv), dtype=np.uint32)
            & rng.integers(0, 1 << 32, size=(k, wv), dtype=np.uint32)
        )
        hits = max(1, min(b, k) // 4)  # force some real subset matches
        c[:hits] &= q[:hits]
        return (q, c)
    if op == "bit_matvec":
        c, w, r = dims
        a = rng.integers(0, 1 << 32, size=(c, w), dtype=np.uint32)
        x = rng.standard_normal((w * 32, r), dtype=np.float32)
        return (a, x)
    if op == "coverage_gain":
        c, w = dims
        a = rng.integers(0, 1 << 32, size=(c, w), dtype=np.uint32)
        m = rng.integers(0, 1 << 32, size=(w,), dtype=np.uint32)
        return (a, m)
    if op == "partition_gain":
        c, w, p = dims
        a = rng.integers(0, 1 << 32, size=(c, w), dtype=np.uint32)
        m = rng.integers(0, 1 << 32, size=(w,), dtype=np.uint32)
        bounds = tuple(int(v) for v in np.linspace(0, c, p + 1).astype(int))
        return (a, m, bounds)
    raise ValueError(f"no synthetic workload for op {op!r}")


def _impl_call(op: str, path: str, args, params: Dict[str, Any]) -> Callable[[], Any]:
    from repro.kernels import ops as _ops

    fn = _ops._IMPLS[op][path]
    return lambda: fn(*args, **params)


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def search(
    workload: Sequence[Tuple[str, str, Tuple[int, ...]]] | None = None,
    *,
    seed: int = 0,
    reps: int = 3,
    out: str | None = None,
    verbose: bool = False,
) -> Dict[str, Any]:
    """Measure every candidate for every workload entry and persist the picks.

    Timing is interleaved round-robin (candidate 0 rep 0, candidate 1 rep 0,
    ..., candidate 0 rep 1, ...) with a median reduce so slow drift on a busy
    host biases all candidates equally instead of whichever ran last.
    """
    import jax
    import numpy as np

    workload = list(workload if workload is not None else DEFAULT_WORKLOAD)
    entries: Dict[str, Dict[str, Any]] = {}
    for op, path, dims in workload:
        space = SPACES.get((op, path))
        if not space:
            continue
        host_args = _synth(op, dims, seed)
        args = tuple(
            jax.numpy.asarray(a) if isinstance(a, np.ndarray) else a for a in host_args
        )
        calls = [_impl_call(op, path, args, params) for params in space]
        # Warm (compile) every candidate before any timed trial.
        baseline = None
        for call in calls:
            got = jax.block_until_ready(call())
            if baseline is None:
                baseline = got
            else:
                # Tuning must never trade exactness for speed.
                # float candidates reassociate sums (lut/unroll vs scan), so
                # tolerance, not bit-equality; integer ops compare exactly
                ok = jax.numpy.allclose(
                    jax.numpy.asarray(got, jax.numpy.float32),
                    jax.numpy.asarray(baseline, jax.numpy.float32),
                    rtol=1e-4, atol=1e-3,
                )
                if not bool(ok):  # pragma: no cover - guards impl bugs
                    raise AssertionError(f"autotune candidate mismatch for {op}/{path}")
        times: List[List[float]] = [[] for _ in calls]
        for _ in range(reps):
            for idx, call in enumerate(calls):
                t0 = time.perf_counter()
                jax.block_until_ready(call())
                times[idx].append(time.perf_counter() - t0)
        med = [_median(t) for t in times]
        best = min(range(len(space)), key=lambda i: (med[i], i))
        key = f"{op}|{path}|{bucket(op, *dims)}"
        entries[key] = dict(space[best])
        entries[key]["_us"] = round(med[best] * 1e6, 1)
        if verbose:
            print(f"{key}: {space[best]} ({med[best] * 1e6:.0f} us)")
    blob = {
        "version": CACHE_VERSION,
        "seed": seed,
        "backend": jax.default_backend(),
        "entries": dict(sorted(entries.items())),
    }
    dest = out if out is not None else cache_path()
    os.makedirs(os.path.dirname(dest) or ".", exist_ok=True)
    with open(dest, "w") as fh:
        json.dump(blob, fh, indent=2, sort_keys=True)
        fh.write("\n")
    invalidate()
    return blob


def ensure_cache(*, seed: int = 0) -> Tuple[str, int]:
    """Create the default-workload cache if the resolved path has none.

    Returns (path, n_entries).  No-op (path, 0 entries counted from disk) when
    tuning is disabled via the env switch.
    """
    raw = os.environ.get(ENV_VAR)
    if raw is not None and raw.strip().lower() in _DISABLED:
        return ("<disabled>", 0)
    path = cache_path()
    entries = _load_entries(path)
    if entries:
        return (path, len(entries))
    blob = search(seed=seed, out=path)
    return (path, len(blob["entries"]))


def main(argv: Sequence[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description="regenerate the kernel tile cache")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None, help=f"cache path (default {DEFAULT_CACHE})")
    ns = ap.parse_args(argv)
    blob = search(seed=ns.seed, reps=ns.reps, out=ns.out, verbose=True)
    dest = ns.out if ns.out is not None else cache_path()
    print(f"wrote {len(blob['entries'])} entries -> {dest}")
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
