"""Pallas TPU kernel: batched packed clause-subset test (ψ^clause, eq. 8).

eligible[b] = ∃k . clause_k ⊆ query_b, over uint32-packed vocab bitsets.
One call classifies a whole serving batch — this replaces the engine's old
per-query host loop on the request path and is what the cluster router runs
once per batch before scatter-gathering to the tiers.

The subset test c ⊆ q is `(c & ~q) == 0` word-wise; a pure VPU op. Schedule:
  grid = (B/BB,); the clause axis is streamed INSIDE the kernel. The clause
  matrix stays in HBM (`memory_space=ANY`) and each [BK, Wv] block is
  double-buffered into VMEM with `make_async_copy`: while block j computes,
  block j+1 is already in flight on the second buffer slot, so the HBM read
  of the postings overlaps the VPU subset test instead of serializing ahead
  of it (the old grid-minor schedule paid the copy latency every step).
  The [BB, 1] eligibility accumulator lives in registers across the loop.
  VMEM: 2*BK*Wv*4 (clause slots) + BB*Wv*4 + the [BB, BK, Wv] mismatch
  intermediate — ≤ ~1.1 MB at the BB=BK=64, Wv=64 defaults, << 16 MB.
Zero-padded clause rows are the empty clause (⊆ everything), so padded K
rows are masked by their global index before the OR-reduce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiles import block_dim


def _kernel(q_ref, c_hbm, o_ref, c_buf, sem, *,
            n_clauses: int, block_k: int, n_k: int):
    def copy_in(j, slot):
        return pltpu.make_async_copy(
            c_hbm.at[pl.ds(j * block_k, block_k), :],
            c_buf.at[slot],
            sem.at[slot],
        )

    copy_in(0, 0).start()
    q = q_ref[...]                                   # [BB, Wv] uint32

    def step(j, acc):
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < n_k)
        def _prefetch():                             # next block, other slot
            copy_in(j + 1, jax.lax.rem(j + 1, 2)).start()

        copy_in(j, slot).wait()
        c = c_buf[slot]                              # [BK, Wv] uint32
        miss = c[None, :, :] & ~q[:, None, :]        # [BB, BK, Wv]
        sub = jnp.all(miss == 0, axis=-1)            # [BB, BK] bool
        # mask zero-padded clause rows (empty clause matches everything)
        k_global = jax.lax.broadcasted_iota(jnp.int32, sub.shape, 1) \
            + j * block_k
        sub = jnp.logical_and(sub, k_global < n_clauses)
        return acc | jnp.any(sub, axis=1, keepdims=True).astype(jnp.int32)

    init = jnp.zeros((q.shape[0], 1), jnp.int32)
    o_ref[...] = jax.lax.fori_loop(0, n_k, step, init)


@functools.partial(jax.jit, static_argnames=("block_b", "block_k", "interpret"))
def clause_match(
    query_bits: jnp.ndarray,   # uint32 [B, Wv]
    clause_bits: jnp.ndarray,  # uint32 [K, Wv]
    *,
    block_b: int = 64,
    block_k: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:              # bool [B]
    b, wv = query_bits.shape
    k, wk = clause_bits.shape
    assert wv == wk, (query_bits.shape, clause_bits.shape)
    bb, bp, nb = block_dim(b, block_b)
    bk, kp, nk = block_dim(k, block_k)
    if bp:
        query_bits = jnp.pad(query_bits, ((0, bp), (0, 0)))
    if kp:
        clause_bits = jnp.pad(clause_bits, ((0, kp), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, n_clauses=k, block_k=bk, n_k=nk),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bb, wv), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),    # streamed by the kernel
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b + bp, 1), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((2, bk, wv), jnp.uint32),     # double-buffer slots
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(query_bits, clause_bits)
    return out[:b, 0].astype(bool)
