"""Pallas TPU kernel: batched packed clause-subset test (ψ^clause, eq. 8).

eligible[b] = ∃k . clause_k ⊆ query_b, over uint32-packed vocab bitsets.
One call classifies a whole serving batch — this replaces the engine's old
per-query host loop on the request path and is what the cluster router runs
once per batch before scatter-gathering to the tiers.

The subset test c ⊆ q is `(c & ~q) == 0` word-wise; a pure VPU op. Tiling:
  grid = (B/BB, K/BK); K is the minor (sequential) axis so the [BB, 1]
  eligibility accumulator stays resident and ORs across clause blocks.
  The [BB, BK, Wv] mismatch intermediate lives in VMEM: with the default
  BB=BK=64 and Wv ≤ 64 (2048-term vocab) that is ≤ 1 MB << 16 MB VMEM.
Zero-padded clause rows are the empty clause (⊆ everything), so padded K
rows are masked by their global index before the OR-reduce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiles import block_dim


def _kernel(q_ref, c_ref, o_ref, *, n_clauses: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]                                   # [BB, Wv] uint32
    c = c_ref[...]                                   # [BK, Wv] uint32
    miss = c[None, :, :] & ~q[:, None, :]            # [BB, BK, Wv]
    sub = jnp.all(miss == 0, axis=-1)                # [BB, BK] bool
    # mask zero-padded clause rows (empty clause matches everything)
    k_global = jax.lax.broadcasted_iota(jnp.int32, sub.shape, 1) \
        + j * c.shape[0]
    sub = jnp.logical_and(sub, k_global < n_clauses)
    o_ref[...] |= jnp.any(sub, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b", "block_k", "interpret"))
def clause_match(
    query_bits: jnp.ndarray,   # uint32 [B, Wv]
    clause_bits: jnp.ndarray,  # uint32 [K, Wv]
    *,
    block_b: int = 64,
    block_k: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:              # bool [B]
    b, wv = query_bits.shape
    k, wk = clause_bits.shape
    assert wv == wk, (query_bits.shape, clause_bits.shape)
    bb, bp, nb = block_dim(b, block_b)
    bk, kp, nk = block_dim(k, block_k)
    if bp:
        query_bits = jnp.pad(query_bits, ((0, bp), (0, 0)))
    if kp:
        clause_bits = jnp.pad(clause_bits, ((0, kp), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, n_clauses=k),
        grid=(nb, nk),
        in_specs=[
            pl.BlockSpec((bb, wv), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, wv), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b + bp, 1), jnp.int32),
        interpret=interpret,
    )(query_bits, clause_bits)
    return out[:b, 0].astype(bool)
