"""Fused ψ classify + tier-selected AND-match — one dispatch on the serve path.

The pre-fusion serve path ran two dispatches per batch with the eligibility
bitset round-tripping between them: `clause_match` produced `eligible [B]`,
the host picked Tier-1 or Tier-2 postings per query, and a second dispatch
AND-reduced the selected rows. This module collapses that into one op:

    match, eligible = fused_match(qbits, cbits, tokens, t1, t2)

with the two tiers stacked into a single [2V, W] matrix (rows [0, V) =
Tier-2, rows [V, 2V) = Tier-1) so tier selection is index arithmetic on the
gather — `row = tiers[sel * V + token]` — instead of a both-tier double
gather followed by a `where`. Every path is integer-exact and bit-identical
to `matching.match_batch` over the per-query-selected tier.

The Pallas path streams postings rows straight from HBM via scalar-prefetch
(`PrefetchScalarGridSpec`): the (eligibility, token) scalars are prefetched
ahead of the grid so each (b, l) step's BlockSpec index_map computes the row
address and the pipeline fetches exactly the rows the batch needs — the
gather never materializes a [B, L, W] intermediate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import clause_match as _cm
from repro.kernels import ref as _ref

_ONES = 0xFFFFFFFF


def select_rows_match(tiers2v: jnp.ndarray,      # uint32 [2V, W] (t2 ++ t1)
                      n_vocab_rows: int,         # V (static)
                      use_t1: jnp.ndarray,       # bool/int [B]
                      tokens: jnp.ndarray,       # int32 [B, L], -1 padded
                      ) -> jnp.ndarray:          # uint32 [B, W]
    """Tier-selected AND-match core (shared by the XLA path and the mesh
    serve body): one gather per (query, token) against the stacked tiers,
    padded slots contribute all-ones."""
    valid = tokens >= 0
    safe = jnp.where(valid, tokens, 0)
    idx = safe + jnp.where(use_t1, n_vocab_rows, 0).astype(safe.dtype)[:, None]
    rows = tiers2v[idx]                                      # [B, L, W]
    rows = jnp.where(valid[..., None], rows, jnp.uint32(_ONES))
    return jax.lax.reduce(rows, jnp.uint32(_ONES),
                          jax.lax.bitwise_and, (1,))


@jax.jit
def fused_match_xla(query_bits: jnp.ndarray, clause_bits: jnp.ndarray,
                    tokens: jnp.ndarray, t1: jnp.ndarray, t2: jnp.ndarray):
    if clause_bits.shape[0]:
        elig = _ref.clause_match(query_bits, clause_bits)
    else:                       # empty clause set: everyone serves Tier-2
        elig = jnp.zeros((query_bits.shape[0],), bool)
    tiers = jnp.concatenate([t2, t1], axis=0)
    return select_rows_match(tiers, t1.shape[0], elig, tokens), elig


def _match_kernel(sel_ref, toks_ref, row_ref, o_ref):
    del sel_ref
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, _ONES, jnp.uint32)

    @pl.when(toks_ref[b, l] >= 0)
    def _and():
        o_ref[...] &= row_ref[...]


@functools.partial(jax.jit, static_argnames=("n_vocab_rows", "interpret"))
def _tier_match(tiers2v: jnp.ndarray, n_vocab_rows: int, sel: jnp.ndarray,
                tokens: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    b, l = tokens.shape
    w = tiers2v.shape[1]
    v = n_vocab_rows
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, l),
        in_specs=[
            # row address = tier select * V + token; padded (-1) slots fetch
            # row 0 and are dropped by the `toks >= 0` guard in the kernel.
            pl.BlockSpec((1, w), lambda bi, li, sel_ref, toks_ref:
                         (sel_ref[bi] * v + jnp.maximum(toks_ref[bi, li], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda bi, li, sel_ref, toks_ref: (bi, 0)),
    )
    return pl.pallas_call(
        _match_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, w), jnp.uint32),
        interpret=interpret,
    )(sel, tokens, tiers2v)


@functools.partial(jax.jit, static_argnames=("block_b", "block_k", "interpret"))
def fused_match(query_bits: jnp.ndarray, clause_bits: jnp.ndarray,
                tokens: jnp.ndarray, t1: jnp.ndarray, t2: jnp.ndarray, *,
                block_b: int = 64, block_k: int = 64,
                interpret: bool = False):
    if clause_bits.shape[0]:
        elig = _cm.clause_match(query_bits, clause_bits, block_b=block_b,
                                block_k=block_k, interpret=interpret)
    else:
        elig = jnp.zeros((query_bits.shape[0],), bool)
    tiers = jnp.concatenate([t2, t1], axis=0)
    match = _tier_match(tiers, t1.shape[0], elig.astype(jnp.int32), tokens,
                        interpret=interpret)
    return match, elig
