"""Pallas TPU kernel: fused AND-NOT-popcount row reduction (unweighted gains).

gains[c] = popcount(A[c] & ~covered) — the fast path for uniform query weights
and for the g(.|X) document-cost oracle. Pure VPU op (no MXU): one pass over
the packed incidence rows, 32 bits per lane-element of HBM traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiles import block_dim


def _kernel(a_ref, m_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                       # [BC, BW] uint32
    m = m_ref[...]                       # [1, BW] uint32
    fresh = a & ~m
    cnt = jax.lax.population_count(fresh).astype(jnp.int32)
    o_ref[...] += jnp.sum(cnt, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_c", "block_w", "interpret"))
def coverage_gain(
    a_bits: jnp.ndarray,      # uint32 [C, W]
    mask: jnp.ndarray,        # uint32 [W]
    *,
    block_c: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:             # int32 [C]
    c, w = a_bits.shape
    bc, cp, nc = block_dim(c, block_c)
    bw, wp, nw = block_dim(w, block_w)
    if cp or wp:
        a_bits = jnp.pad(a_bits, ((0, cp), (0, wp)))
        mask = jnp.pad(mask, (0, wp))
    grid = (nc, nw)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bw), lambda i, j: (i, j)),
            pl.BlockSpec((1, bw), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bc, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c + cp, 1), jnp.int32),
        interpret=interpret,
    )(a_bits, mask[None, :])
    return out[:c, 0]
