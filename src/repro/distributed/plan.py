"""Mesh-resident execution plan: one object that answers "where does this
op run?" for every kernel dispatch and every shard_map fusion in the repo.

Before this module, placement logic was scattered three ways:

  * `kernels/ops.py` carried a per-op `if pallas/interpret/xla` chain;
  * `core/optpes.py` and `core/sparse_step.py` each hand-rolled the same
    mesh-gating boilerplate (size check, dp-axes derivation, rank math,
    owner-local row gathers) in front of their shard_map bodies;
  * the cluster router had no device story at all — one host dispatch per
    shard.

`ExecutionPlan` binds the ambient `mesh_context` mesh, the `"shard"` axis
(solver partitions == fleet shards == mesh devices), and the resolved kernel
backend into a single immutable value. Everything placement-aware asks it:

    plan = current_plan()
    plan.placement("clause_match")   # "pallas" | "interpret" | "xla"
    plan.shard_fused                 # fuse over the "shard" axis?
    plan.model_fused                 # fuse over the "model" axis?

Backend resolution honours `REPRO_KERNEL_BACKEND`, either a single choice
("xla") or per-op placements ("xla,clause_match=interpret"); a bad value
raises `ValueError` naming the valid choices (it used to be a bare `assert`
that vanished under `python -O`).

`mesh_fused(body, ...)` is the single shard_map gate the solvers and the
cluster router share: it returns the bound shard-mapped callable when the
ambient (or given) mesh can fuse over the requested axis, else `None` so the
caller runs its direct path — no more copy-pasted `mesh.size == 1 or axis
not in mesh.axis_names` blocks. `axis_rank`/`owner_select`/`owner_row` are
the shared owner-local gather primitives those bodies were duplicating.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import mesh_context

BACKENDS = ("pallas", "interpret", "xla")
SHARD_AXIS = "shard"

try:
    from jax import shard_map as _shard_map  # jax >= 0.7

    def shard_map(f, mesh, in_specs, out_specs, **kw):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _sm_old

    def shard_map(f, mesh, in_specs, out_specs, **kw):
        # old API spells replication checking `check_rep`; same semantics
        # (the ring OR-merge's replicated-by-construction outputs defeat the
        # static inference either way, so the flag must actually map through)
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
        return _sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       **kw)


# -- backend resolution --------------------------------------------------------

def _check(b: str, source: str) -> str:
    if b not in BACKENDS:
        raise ValueError(
            f"invalid kernel backend {b!r} (from {source}); "
            f"valid choices: {', '.join(BACKENDS)} or 'auto'")
    return b


@functools.lru_cache(maxsize=8)
def _parse_placements(raw: str) -> tuple[str, dict[str, str]]:
    default, per_op = "auto", {}
    for entry in raw.split(","):
        entry = entry.strip()
        if not entry:
            continue
        if "=" in entry:
            op, _, b = entry.partition("=")
            b = b.strip()
            per_op[op.strip()] = b if b == "auto" else \
                _check(b, "REPRO_KERNEL_BACKEND")
        else:
            default = entry if entry == "auto" else \
                _check(entry, "REPRO_KERNEL_BACKEND")
    return default, per_op


def _env_placements() -> tuple[str, dict[str, str]]:
    """Parse REPRO_KERNEL_BACKEND: a default and/or per-op `op=backend`
    entries, comma-separated — e.g. "xla" or "xla,clause_match=interpret".
    Parsed once per distinct env value (this sits on the serving hot path)."""
    return _parse_placements(os.environ.get("REPRO_KERNEL_BACKEND", "auto"))


def resolve_backend(backend: str | None = None, op: str | None = None) -> str:
    """Resolve the execution path for one kernel call.

    Precedence: explicit `backend=` argument > per-op `REPRO_KERNEL_BACKEND`
    placement > its default entry > auto (pallas on TPU, xla elsewhere).
    """
    if backend is not None and backend != "auto":
        return _check(backend, "backend argument")
    default, per_op = _env_placements()
    b = per_op.get(op, default) if op is not None else default
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return b


# -- the plan ------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Where ops run: the bound mesh, its role axes, the kernel backend.

    `shard_axis` is the fleet/partition axis (`"shard"`): when present with
    size > 1, the cluster router serves each batch as ONE shard_map program
    and `ops.partition_gain` computes each partition's gains on the device
    that owns it. `model_axis`/`data_axes` are the training-style roles the
    solver fusions (`optpes`, `sparse_step`) shard over.
    """
    mesh: Mesh
    backend: str
    shard_axis: str | None
    model_axis: str | None
    data_axes: tuple[str, ...]

    @property
    def n_shard_devices(self) -> int:
        return self.mesh.shape[self.shard_axis] if self.shard_axis else 1

    @property
    def shard_fused(self) -> bool:
        """Fuse fleet-facing ops over the `"shard"` axis?"""
        return self.shard_axis is not None and self.n_shard_devices > 1

    @property
    def model_fused(self) -> bool:
        """Fuse solver gain kernels over the `"model"` axis?"""
        return self.model_axis is not None and self.mesh.size > 1

    def placement(self, op: str, backend: str | None = None) -> str:
        """The execution path for `op` under this plan."""
        if backend is not None and backend != "auto":
            return _check(backend, "backend argument")
        _, per_op = _env_placements()
        b = per_op.get(op)
        if b == "auto":     # per-op auto: true auto-resolution, not default
            return "pallas" if jax.default_backend() == "tpu" else "xla"
        return b if b is not None else self.backend

    def pinned(self, op: str, backend: str | None = None) -> bool:
        """True when `op`'s path is explicitly overridden (call argument or
        per-op env placement) — mesh fusions step aside so the pinned
        kernel implementation actually runs."""
        if backend is not None and backend != "auto":
            return True
        return op in _env_placements()[1]

    def tile_params(self, op: str, path: str, shape_bucket) -> dict:
        """Autotuned kernel kwargs for (op, path, shape-bucket) — the tile
        sibling of `placement`: placement picks WHICH impl runs, this picks
        HOW it tiles/decomposes. {} (impl defaults) on cache miss, when
        `shape_bucket` is None (untunable op), or when autotuning is disabled
        via REPRO_KERNEL_TILES=0."""
        if shape_bucket is None:
            return {}
        from repro.kernels import autotune  # leaf module; lazy to keep plan import-light
        return autotune.tile_params(op, path, shape_bucket)


def current_plan(backend: str | None = None) -> ExecutionPlan:
    """The plan the ambient `mesh_context` mesh implies."""
    mesh = mesh_context.current_mesh()
    names = mesh.axis_names
    return ExecutionPlan(
        mesh=mesh,
        backend=resolve_backend(backend),
        shard_axis=SHARD_AXIS if SHARD_AXIS in names else None,
        model_axis="model" if "model" in names else None,
        data_axes=tuple(a for a in names
                        if a not in ("model", SHARD_AXIS)),
    )


def shard_mesh(n_devices: int | None = None) -> Mesh:
    """A 1-D `("shard",)` mesh over (up to) `n_devices` local devices —
    what `use_mesh` wants for the fused cluster data plane."""
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return Mesh(devs, (SHARD_AXIS,))


# -- shared shard_map fusion helpers ------------------------------------------

def mesh_fused(body, *, in_specs, out_specs, axis: str = "model",
               mesh: Mesh | None = None):
    """The one mesh gate: bind `body` over `mesh` (ambient by default), or
    return None when the mesh cannot fuse over `axis` — the caller then runs
    its direct single-device path. `check_vma` is off repo-wide: the packed
    uint32 operands and owner-select psums defeat vma inference.
    """
    mesh = mesh_context.current_mesh() if mesh is None else mesh
    if mesh.size == 1 or axis not in mesh.axis_names:
        return None
    return shard_map(body, mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)


def axis_rank(mesh: Mesh, axes) -> jnp.ndarray:
    """Row-major rank of the calling device over `axes` (shard_map body)."""
    rank = jnp.int32(0)
    for ax in axes:
        rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
    return rank


def owner_select(a: jnp.ndarray, idx: jnp.ndarray, rank: jnp.ndarray,
                 *, fill=0):
    """Owner-local rows `idx` (global indices) of a row-sharded local block.

    Inside a shard_map body: rows this device owns are sliced locally,
    out-of-range rows come back as `fill` — combine across owners with a
    psum (fill=0) or pmax (fill=-1 for padded id rows). Works for scalar or
    vector `idx`.
    """
    c_loc = a.shape[0]
    lidx = idx - rank * c_loc
    inb = (lidx >= 0) & (lidx < c_loc)
    rows = a[jnp.clip(lidx, 0, c_loc - 1)]
    keep = inb[..., None] if jnp.ndim(idx) else inb
    return jnp.where(keep, rows, jnp.full_like(rows, fill))


def owner_row(mat: jnp.ndarray, j: jnp.ndarray, *,
              w_axis: str | None = None, mesh: Mesh | None = None):
    """Row `j` of a dp-row-sharded matrix WITHOUT an all-gather.

    A traced-index gather on a sharded operand makes XLA all-gather the
    whole matrix (512 GB at solve_l scale — EXPERIMENTS §Perf); instead the
    owning dp-rank slices locally and a [W]-sized collective broadcasts the
    row. int32 matrices are treated as -1-padded id rows (combined via
    pmax); packed/float rows combine via psum. Falls back to `mat[j]` when
    the mesh can't fuse.
    """
    mesh = mesh_context.current_mesh() if mesh is None else mesh
    dp = tuple(a for a in mesh.axis_names if a != "model")
    is_ids = mat.dtype == jnp.int32

    def body(a, jj):
        row = owner_select(a, jj, axis_rank(mesh, dp),
                           fill=-1 if is_ids else 0)
        for ax in dp:
            row = jax.lax.pmax(row, ax) if is_ids else jax.lax.psum(row, ax)
        return row

    fused = mesh_fused(body, in_specs=(P(dp, w_axis), P()),
                       out_specs=P(w_axis), mesh=mesh)
    if fused is None:
        return mat[j]
    return fused(mat, j)
