"""repro.distributed — meshes, placement, and fused execution.

  * `mesh_context` — the ambient mesh (`use_mesh`, `current_mesh`,
    `shard_hint`): model code never threads a Mesh through calls.
  * `plan` — the mesh-resident execution plan: `ExecutionPlan` binds the
    ambient mesh, the `"shard"` fleet axis and the kernel backend;
    `mesh_fused` is the single shard_map gate every fused path (solver
    gain kernels, the cluster scatter-gather router, `partition_gain`)
    goes through; `owner_row`/`owner_select` are the shared owner-local
    gather primitives.
  * `sharding` — FSDP-augmented param specs, optimizer-state spec
    derivation (training side).
  * `compression` — quantized collectives.
"""
from repro.distributed.mesh_context import (            # noqa: F401
    current_mesh, shard_hint, use_mesh)
from repro.distributed.plan import (                    # noqa: F401
    BACKENDS, SHARD_AXIS, ExecutionPlan, axis_rank, current_plan,
    mesh_fused, owner_row, owner_select, resolve_backend, shard_map,
    shard_mesh)

__all__ = [
    "BACKENDS", "ExecutionPlan", "SHARD_AXIS", "axis_rank", "current_mesh",
    "current_plan", "mesh_fused", "owner_row", "owner_select",
    "resolve_backend", "shard_hint", "shard_map", "shard_mesh", "use_mesh",
]
