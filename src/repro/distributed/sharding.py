"""Sharding utilities: FSDP-augmented param specs, opt-state spec derivation.

Model modules publish TP ('model'-axis) PartitionSpecs; `add_fsdp` shards the
big matrices' contraction dim over 'data' on top (ZeRO-3-style storage;
XLA SPMD inserts the gather-on-use all-gathers). Optimizer-state specs are
derived from param specs by shape matching (Adafactor's factored vr/vc drop
one axis of the param spec).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def add_fsdp(specs, abstract_params, mesh, *, min_size: int = 2 ** 20):
    """Shard the first currently-unsharded dim that divides the 'data' axis,
    for every param with >= min_size elements."""
    if "data" not in mesh.axis_names:
        return specs
    dp = tuple(a for a in mesh.axis_names if a != "model")  # ('pod','data')
    n_data = 1
    for a in dp:
        n_data *= mesh.shape[a]

    def one(spec: P, leaf):
        shape = leaf.shape
        if np.prod(shape) < min_size:
            return spec
        parts = list(spec) + [None] * (len(shape) - len(spec))
        for i, (dim, pspec) in enumerate(zip(shape, parts)):
            # dim >= 128 excludes the scanned layer-stack axis (slicing a
            # 'data'-sharded leading axis inside scan would collective every
            # layer) and keeps small tensors replicated.
            if pspec is None and dim % n_data == 0 and dim >= 128:
                parts[i] = dp if len(dp) > 1 else dp[0]
                return P(*parts)
        return spec

    s_leaves, treedef = jax.tree.flatten(specs,
                                         is_leaf=lambda x: isinstance(x, P))
    p_leaves = treedef.flatten_up_to(abstract_params)
    return jax.tree.unflatten(
        treedef, [one(s, p) for s, p in zip(s_leaves, p_leaves)])


def opt_state_specs(param_specs, abstract_params, abstract_opt):
    """Match every optimizer-state leaf to its param's spec by shape."""
    p_specs = {tuple(l.shape): s for s, l in zip(
        jax.tree.leaves(param_specs, is_leaf=lambda x: isinstance(x, P)),
        jax.tree.leaves(abstract_params))}

    def one(leaf):
        shape = tuple(leaf.shape)
        if shape in p_specs:
            return p_specs[shape]
        # factored second moments: param spec minus one trailing axis
        for pshape, spec in p_specs.items():
            parts = list(spec) + [None] * (len(pshape) - len(spec))
            if shape == pshape[:-1]:                      # vr
                return P(*parts[:-1])
            if shape == pshape[:-2] + pshape[-1:]:        # vc
                return P(*(parts[:-2] + parts[-1:]))
        return P()                                        # scalars etc.

    return jax.tree.map(one, abstract_opt)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def state_shardings(mesh, param_specs, abstract_state):
    """Shardings for a trainer state {params, opt, ef, step}."""
    out = {
        "params": param_specs,
        "opt": opt_state_specs(param_specs, abstract_state["params"],
                               abstract_state["opt"]),
        "ef": opt_state_specs(param_specs, abstract_state["params"],
                              abstract_state["ef"]),
        "step": P(),
    }
    return named(mesh, out)
