"""Gradient compression: top-k sparsification + error feedback, int8 quant.

Two layers:

1. `compress_grads` — the numerics used by the trainer: an error-feedback
   (EF/EF21-style) transformation whose residual state lives in the optimizer
   state. This reproduces the convergence behaviour of compressed
   all-reduce; tests verify a small LM still trains.

2. `quantized_psum` — the wire format for real pods: inside a shard_map
   data-parallel block, quantize the local gradient shard to int8 with a
   per-tensor scale, psum the int8 payload (4x fewer collective bytes),
   dequantize. Used by the dry-run's compression variant to demonstrate the
   collective-term reduction in §Perf.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"          # none | int8 | topk
    topk_frac: float = 0.01     # fraction of entries kept per tensor


def init_error_state(cfg: CompressionConfig, params):
    if cfg.kind == "none":
        return {}
    return {"ef": jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def _quant_int8(x: jnp.ndarray):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_mask(x: jnp.ndarray, frac: float):
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def compress_grads(cfg: CompressionConfig, grads, err_state):
    """grads (fp32 tree) -> (compressed grads, new error state)."""
    if cfg.kind == "none":
        return grads, err_state

    def one(g, e):
        acc = g.astype(jnp.float32) + e
        if cfg.kind == "int8":
            c = _quant_int8(acc)
        elif cfg.kind == "topk":
            c = _topk_mask(acc, cfg.topk_frac)
        else:
            raise ValueError(cfg.kind)
        return c, acc - c

    out = jax.tree.map(one, grads, err_state["ef"])
    comp = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    ef = jax.tree.map(lambda o: o[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    return comp, {"ef": ef}


def quantized_psum(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """int8 all-reduce inside a shard_map block: 4x collective bytes vs f32.

    Per-shard symmetric quantization; scales are combined with a (tiny) f32
    psum of the per-shard scale so dequantization is exact to 1 ulp of the
    shared grid.
    """
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    # all shards must use a common grid -> take the max scale across shards
    scale = jax.lax.pmax(scale, axis)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)   # int8 payload on the wire
    return total.astype(jnp.float32) * scale
