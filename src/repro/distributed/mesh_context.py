"""Ambient mesh context.

Model code that needs `shard_map` (MoE expert parallelism, row-sharded
embedding lookups) queries the ambient mesh here instead of threading a Mesh
through every call. The trainer / dry-run / tests set it with `use_mesh`.
When no mesh is set, model code falls back to single-device semantics (a
1-device mesh), so plain CPU tests run unchanged.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import Mesh

_CURRENT: list[Mesh | None] = [None]


def current_mesh() -> Mesh:
    if _CURRENT[0] is not None:
        return _CURRENT[0]
    return Mesh(jax.devices()[:1], ("data",))


def model_axis_in(mesh: Mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = _CURRENT[0]
    _CURRENT[0] = mesh
    try:
        yield mesh
    finally:
        _CURRENT[0] = prev


def data_axes() -> tuple[str, ...]:
    return tuple(a for a in current_mesh().axis_names if a != "model")


def shard_hint(x, *entries):
    """with_sharding_constraint against the ambient mesh; no-op on 1 device.

    Used to pin the transformer residual stream to token-sharding (batch
    over ('pod','data'), D replicated): without it the SPMD partitioner
    bounces activations between D-sharded (attention/FFN matmul outputs)
    and token-sharded (MoE shard_map boundary) layouts via 'involuntary
    full rematerialization' — a full [tokens, D] replicated buffer per
    device (1.75 GB/layer at kimi-k2 scale; see EXPERIMENTS.md §Perf)."""
    import jax
    mesh = _CURRENT[0]
    if mesh is None or mesh.size == 1:
        return x
    spec = jax.sharding.PartitionSpec(*entries)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
