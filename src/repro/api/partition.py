"""Per-shard budget allocation from observed traffic (shard-aware tiering).

The paper's knapsack budget B models one machine's index capacity; a fleet
has per-shard capacity. This module turns a traffic distribution into the
per-shard caps of a `core.constraint.PartitionedBudget`:

  * `shard_traffic_shares` — each shard's share of the fleet's word-traffic
    demand: share_k ∝ Σ_q w(q) · |m(q) ∩ D_k| over the doc partition. This
    is what the shard actually serves (its slice of every match set), so a
    hot shard is one whose documents the traffic keeps matching.
  * `partition_budgets` — B_k = total · share_k, clamped to each shard's
    physical doc capacity, integerized by largest remainder, with overflow
    redistributed to shards that still have headroom. Deterministic.

`TieringPipeline.solve(budget_split="traffic", n_shards=K)` composes the
two against its own query-doc incidence and the live solve weights.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import bitset


def shard_traffic_shares(query_doc_bits: np.ndarray, weights: np.ndarray,
                         bounds: Sequence[int]) -> np.ndarray:
    """f64 [P] normalized traffic demand per doc partition.

    query_doc_bits : packed m(q) per unique query, uint32 [Nq, Wd]
    weights        : empirical query distribution, [Nq]
    bounds         : word offsets of the partition (len P+1)
    """
    bounds = tuple(int(b) for b in bounds)
    w = np.asarray(weights, np.float64)
    demand = np.asarray(
        [(w * bitset.np_popcount(query_doc_bits[:, lo:hi])).sum()
         for lo, hi in zip(bounds, bounds[1:])], np.float64)
    total = demand.sum()
    if total <= 0:
        return np.full(len(bounds) - 1, 1.0 / (len(bounds) - 1))
    return demand / total


def partition_budgets(shards, weights, total: float) -> dict[int, float]:
    """Size per-shard caps B_k from traffic shares; Σ B_k == int(total).

    shards  : per-shard doc capacities — `cluster.DocShard`s (their
              `n_docs`) or plain ints
    weights : per-shard traffic shares (any nonnegative vector; normalized
              here), e.g. `shard_traffic_shares(...)` or a decayed online
              estimate
    total   : the fleet-wide Tier-1 doc budget

    Caps are integers (doc counts): largest-remainder rounding, with any
    mass a full shard cannot absorb redistributed to shards that still have
    headroom, proportionally to their share. Raises if `total` exceeds the
    fleet's physical capacity.
    """
    capacity = np.asarray(
        [s if isinstance(s, (int, np.integer)) else int(s.n_docs)
         for s in shards], np.float64)
    share = np.asarray(weights, np.float64)
    if share.shape != capacity.shape:
        raise ValueError(
            f"need one weight per shard: {share.shape} vs {capacity.shape}")
    if np.any(share < 0):
        raise ValueError("traffic shares must be nonnegative")
    total = float(int(total))
    if total > capacity.sum():
        raise ValueError(f"total budget {total:.0f} exceeds fleet capacity "
                         f"{capacity.sum():.0f}")
    share = share / share.sum() if share.sum() > 0 \
        else np.full_like(capacity, 1.0 / len(capacity))

    caps = np.zeros_like(capacity)
    remaining = total
    live = np.ones(len(capacity), bool)      # shards below capacity
    # water-fill: give each live shard its proportional ask, clamp at
    # capacity, re-split what the clamped shards couldn't take
    while remaining > 1e-9 and live.any():
        s = share * live
        if s.sum() <= 0:                      # only zero-share shards left
            s = live.astype(np.float64)
        ask = remaining * s / s.sum()
        grant = np.minimum(ask, capacity - caps)
        caps += grant
        remaining -= grant.sum()
        live = capacity - caps > 1e-9
        if grant.sum() <= 1e-12:
            break
    # integerize by largest remainder without breaching capacity
    floors = np.floor(caps)
    leftover = int(round(total - floors.sum()))
    order = np.argsort(-(caps - floors))
    for k in order:
        if leftover <= 0:
            break
        if floors[k] + 1 <= capacity[k]:
            floors[k] += 1
            leftover -= 1
    return {k: float(floors[k]) for k in range(len(floors))}
