"""repro.api — the unified solver surface.

One import gives the whole redesigned API:

  * `SolverState`      — registered-dataclass pytree of solve progress;
                         every solver is warm-startable/checkpointable.
  * `SolveConfig`      — one config dataclass for every solver (budget,
                         max_steps, record_every, time_limit, seed, options).
  * `solve(problem, config, state=None)`
                       — the uniform entrypoint over the solver registry
                         (all SCSK solvers + the flow-baseline adapters).
  * `solve_sweep(problem, budgets, config)`
                       — warm-started budget sweeps (Fig. 2/3) that resume
                         the same `SolverState` instead of re-solving.
  * `register_solver`  — decorator to add new solvers to the registry.
  * `Trace`            — shared per-solve recorder (history, timing,
                         `on_step`/`on_record` callbacks, time limits).
  * `TieringPipeline`  — fluent facade for the full paper pipeline:
                         data -> mine -> solve -> tiering -> deploy, plus
                         `refit(weights, state=...)` for warm-started
                         re-solves against drifted traffic (the
                         `repro.stream` online re-tiering loop rides it).
  * `GlobalBudget` / `PartitionedBudget`
                       — the knapsack side as a pluggable constraint:
                         one machine's budget, or per-shard caps B_k over
                         word-aligned doc partitions. `budget_split=
                         "traffic"` (solve/refit/sweep) sizes the caps from
                         traffic shares via `shard_traffic_shares` +
                         `partition_budgets`.

Quickstart:

    from repro import api

    pipe = (api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
            .mine(min_support=1e-3)
            .solve("optpes", budget_frac=0.5))
    assert pipe.verify()                  # Theorem 3.1
    engine = pipe.deploy()                # serve.TieredEngine
"""
from repro.core.config import SolveConfig                      # noqa: F401
from repro.core.constraint import (                            # noqa: F401
    GlobalBudget, KnapsackConstraint, PartitionedBudget, partition_bounds,
    partition_capacities, trim_state)
from repro.core.problem import SCSKProblem, SolverResult       # noqa: F401
from repro.core.registry import (                              # noqa: F401
    SolverSpec, get_solver, list_solvers, register_solver, solve, solve_sweep)
from repro.core.state import SolverState                       # noqa: F401
from repro.core.trace import Trace                             # noqa: F401

# importing these populates the registry
import repro.core  # noqa: F401,E402  (SCSK solvers self-register)
from repro.api import flow_adapter  # noqa: F401,E402  (flow baselines)
from repro.api.partition import (  # noqa: F401,E402
    partition_budgets, shard_traffic_shares)
from repro.api.pipeline import TieringPipeline  # noqa: F401,E402

# the mesh-resident data plane rides the same one-import surface: install a
# ("shard",) mesh with `use_mesh(shard_mesh())` and solves/serving fuse
# (owner-local partition gains, one shard_map serve program per batch)
from repro.distributed import (  # noqa: F401,E402
    ExecutionPlan, current_plan, shard_mesh, use_mesh)

__all__ = [
    "ExecutionPlan", "GlobalBudget", "KnapsackConstraint",
    "PartitionedBudget", "SCSKProblem", "SolveConfig", "SolverResult",
    "SolverSpec", "SolverState", "TieringPipeline", "Trace", "current_plan",
    "get_solver", "list_solvers", "partition_bounds", "partition_budgets",
    "partition_capacities", "register_solver", "shard_mesh",
    "shard_traffic_shares", "solve", "solve_sweep", "trim_state",
    "use_mesh",
]
