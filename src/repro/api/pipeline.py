"""TieringPipeline: the paper's whole pipeline behind one fluent facade.

    from repro import api

    engine = (api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
              .mine(min_support=1e-3)
              .solve("optpes", budget_frac=0.5)
              .deploy())

Each stage materializes the artifact the next one consumes:

    from_*      -> corpus + query log
    mine        -> TieringData (FPGrowth clauses + packed incidence)
                   and the device-resident SCSKProblem
    solve       -> SolverResult via the solver registry (any registered
                   name, incl. the flow baselines)
    tiering     -> ClauseTiering (ψ/φ classifiers of §3.1)
    deploy      -> serve.TieredEngine ready for traffic

The pipeline keeps every intermediate (`.data`, `.problem`, `.result`) so
benchmarks can reach in, and `solve` accepts `state=` / returns cumulative
results so budget sweeps ride the same facade (`.sweep(budgets)`).
"""
from __future__ import annotations

from typing import Mapping

import numpy as np

from repro.core import registry
from repro.core.config import SolveConfig
from repro.core.constraint import PartitionedBudget, partition_bounds
from repro.core.problem import SCSKProblem, SolverResult
from repro.core.state import SolverState
from repro.core.tiering import ClauseTiering

# SolveConfig fields settable via TieringPipeline.solve(**options)
_CONFIG_KEYS = ("max_steps", "record_every", "time_limit", "seed",
                "stop_policy", "on_step", "on_record")

_UNSET = object()   # "argument not passed" sentinel (None is meaningful)


class TieringPipeline:
    def __init__(self, corpus, log):
        self.corpus = corpus
        self.log = log
        self.data = None               # data.incidence.TieringData
        self.problem: SCSKProblem | None = None
        self.config: SolveConfig | None = None
        self.result: SolverResult | None = None
        self._tiering: ClauseTiering | None = None

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_synthetic(cls, seed: int = 0, scale: str = "tiny") -> "TieringPipeline":
        from repro.data import synthetic
        corpus, log = synthetic.make_tiering_dataset(seed, scale)
        return cls(corpus, log)

    @classmethod
    def from_corpus(cls, corpus, log) -> "TieringPipeline":
        return cls(corpus, log)

    @classmethod
    def from_data(cls, data) -> "TieringPipeline":
        """Start from an already-built TieringData (skips `mine`)."""
        pipe = cls(data.corpus, data.log)
        pipe.data = data
        pipe.problem = SCSKProblem.from_data(data)
        return pipe

    # -- stages --------------------------------------------------------------
    def mine(self, min_support: float = 1e-3, *, max_clause_len: int = 4,
             max_clauses: int | None = None) -> "TieringPipeline":
        """FPGrowth clause mining (§3.3) + packed incidence structures."""
        from repro.data import incidence
        self.data = incidence.build_tiering_data(
            self.corpus, self.log, min_support=min_support,
            max_clause_len=max_clause_len, max_clauses=max_clauses)
        self.problem = SCSKProblem.from_data(self.data)
        self._tiering = None
        return self

    # -- shard-aware budgets --------------------------------------------------
    def partition_constraint(self, total: float, budget_split,
                             n_shards: int | None = None,
                             weights: np.ndarray | None = None,
                             ) -> PartitionedBudget:
        """Resolve a `budget_split` spec into a `PartitionedBudget`.

        `budget_split="traffic"` sizes each shard's cap from its share of
        the weighted match-set mass (`api.partition.shard_traffic_shares` of
        `weights`, default: the problem's current solve weights) via the
        `partition_budgets` allocator; a mapping/sequence is taken as the
        caps directly. Partitions are the word-aligned
        `core.constraint.partition_bounds` split — the SAME split
        `cluster.plan_shards` serves, so solver budgets and fleet shards
        line up by construction.
        """
        from repro.api.partition import partition_budgets, \
            shard_traffic_shares
        from repro.core.constraint import partition_capacities
        n_docs = self.corpus.n_docs
        if not isinstance(budget_split, str):
            split = dict(budget_split) if isinstance(budget_split, Mapping) \
                else list(budget_split)
            if n_shards is not None and len(split) != n_shards:
                raise ValueError(f"budget_split has {len(split)} caps but "
                                 f"n_shards={n_shards}")
            constraint = PartitionedBudget.from_split(n_docs, split)
            # explicit caps ARE the budget; a conflicting explicit total is
            # a mistake, not something to silently ignore
            if total is not None and abs(constraint.total - float(total)) \
                    > 1e-6:
                raise ValueError(
                    f"budget_split caps sum to {constraint.total:.0f} but "
                    f"budget={float(total):.0f}; pass one or the other")
            return constraint
        if self.data is None:
            raise RuntimeError("budget_split='traffic' needs mined data")
        if total is None:
            raise ValueError("budget_split='traffic' needs a total budget")
        bounds = partition_bounds(n_docs, n_shards or 2)
        if weights is None:
            weights = np.asarray(self.problem.query_weights,
                                 np.float64)[:self.log.n_queries]
        shares = shard_traffic_shares(self.data.query_doc_bits, weights,
                                      bounds)
        caps = partition_budgets(partition_capacities(n_docs, bounds),
                                 shares, total)
        return PartitionedBudget.from_split(n_docs, caps)

    @property
    def n_partitions(self) -> int | None:
        """Partition count of the current solve's constraint (None=global)."""
        if self.config is None or not self.config.partitioned:
            return None
        if self.config.constraint is not None:
            return self.config.constraint.n_parts
        split = self.config.budget_split
        return None if isinstance(split, str) else len(split)

    def solve(self, solver: str = "optpes", budget: float | None = None, *,
              budget_frac: float = 0.5, state: SolverState | None = None,
              config: SolveConfig | None = None, budget_split=None,
              n_shards: int | None = None, **options) -> "TieringPipeline":
        """SCSK solve via the registry. `**options` splits into SolveConfig
        fields (max_steps, time_limit, ...) and solver-specific options.
        An explicit `config=` carries everything itself (its `solver` wins)
        and cannot be combined with budget/options arguments.

        `budget_split` makes the knapsack shard-aware: a {shard: cap}
        mapping / cap sequence (the caps define the total; an explicit
        `budget=` must agree or this raises), or "traffic" to size
        `n_shards` caps from each shard's share of the weighted match
        traffic, splitting the `budget`/`budget_frac` total."""
        if self.data is None:
            raise RuntimeError("call mine() (or from_data) before solve()")
        if config is not None and (budget is not None or options or
                                   budget_split is not None):
            raise ValueError(
                "pass either config= or budget/budget_frac/budget_split/"
                "**options — an explicit SolveConfig already carries those")
        if config is None:
            # int truncation matches the pre-facade entrypoints
            # (budget = int(n_docs * frac)); an explicit budget is kept as-is
            explicit = None if budget is None else float(budget)
            budget = float(int(self.corpus.n_docs * budget_frac)
                           if budget is None else budget)
            cfg_kw = {k: options.pop(k) for k in _CONFIG_KEYS if k in options}
            if budget_split is not None:
                # explicit cap splits define their own total (validated
                # against an explicit budget=); "traffic" splits the
                # budget/budget_frac total by observed shares
                constraint = self.partition_constraint(
                    budget if isinstance(budget_split, str) else explicit,
                    budget_split, n_shards)
                cfg_kw.update(budget=constraint.total, constraint=constraint,
                              budget_split=budget_split)
            else:
                cfg_kw["budget"] = budget
            config = SolveConfig(solver=solver, options=options, **cfg_kw)
        spec = registry.get_solver(config.solver)
        target = self.data if spec.needs_data else self.problem
        self.config = config
        self.result = registry.solve(target, config, state=state)
        self._tiering = None
        return self

    def sweep(self, budgets: list[float], solver: str = "greedy", *,
              budget_split=None, n_shards: int | None = None,
              **options) -> list[SolverResult]:
        """Warm-started budget sweep (Fig. 2/3); leaves the largest-budget
        result as the pipeline's current result.

        With `budget_split`, each total budget keeps the SAME split shares
        (the largest-budget constraint rescaled per point) — the truncate
        ranking ignores caps, so the warm path still equals cold solves.
        Note truncate's usual under-fill applies (globally too): each point
        stops at the first argmax overflowing any cap, so an exhaust-policy
        `solve()` at the same caps may pack more."""
        if self.problem is None:
            raise RuntimeError("call mine() (or from_data) before sweep()")
        cfg_kw = {k: options.pop(k) for k in _CONFIG_KEYS if k in options}
        if budget_split is not None:
            constraint = self.partition_constraint(
                float(budgets[-1]) if isinstance(budget_split, str)
                else None, budget_split, n_shards)
            # explicit caps act as SHARES over a sweep: rescaled per point
            constraint = constraint.scaled(float(budgets[-1]))
            cfg_kw.update(constraint=constraint, budget_split=budget_split)
        config = SolveConfig(budget=float(budgets[-1]), solver=solver,
                             options=options, **cfg_kw)
        results = registry.solve_sweep(self.problem, budgets, config)
        self.config = config
        self.result = results[-1]
        self._tiering = None
        return results

    def refit(self, weights, *, state: SolverState | None = None,
              budget: float | None = None, budget_frac: float | None = None,
              solver: str | None = None, budget_split=_UNSET,
              n_shards: int | None = None, **options) -> "TieringPipeline":
        """Re-solve against a NEW empirical query distribution (re-tiering).

        `weights` is the updated distribution over the pipeline's unique-query
        universe (length `n_queries`, e.g. from `repro.stream.LogAccumulator`).
        The problem is reweighted via `SCSKProblem.with_weights` — the packed
        incidence bitsets are reused, not rebuilt — and solved with the prior
        config (budget/solver/options default to the previous solve's).

        Pass `state=` to warm-start from a prior `SolverState` (typically the
        previous solve's state, optionally pruned by
        `repro.stream.prune_state`); omit it for a cold re-solve. The mined
        clause universe is fixed at `mine()` time, so the resulting tiering
        stays Theorem-3.1-exact regardless of the weights.

        `budget_split` defaults to the previous solve's: a "traffic" split
        RE-ALLOCATES the per-shard caps from the NEW `weights` (hot shards
        grow, cold shards shrink, total unchanged) on every refit. Pass
        `budget_split=None` explicitly to drop back to a global budget.
        """
        if self.problem is None:
            raise RuntimeError("call mine() (or from_data) before refit()")
        base = self.config if self.config is not None else \
            SolveConfig(budget=float(int(self.corpus.n_docs * 0.5)))
        if budget is not None and budget_frac is not None:
            raise ValueError("pass either budget= or budget_frac=, not both")
        kw = {}
        if budget_frac is not None:
            budget = float(int(self.corpus.n_docs * budget_frac))
        if budget is not None:
            kw["budget"] = float(budget)
        if solver is not None:
            kw["solver"] = solver
        cfg_kw = {k: options.pop(k) for k in _CONFIG_KEYS if k in options}
        if options:
            kw["options"] = {**dict(base.options), **options}
        split = base.budget_split if budget_split is _UNSET else budget_split
        if split is not None:
            parts = n_shards or self.n_partitions
            constraint = self.partition_constraint(
                kw.get("budget", base.budget) if isinstance(split, str)
                else kw.get("budget"),
                split, parts,
                weights=np.asarray(weights, np.float64)[:self.log.n_queries]
                if isinstance(split, str) else None)
            kw.update(budget=constraint.total, budget_split=split,
                      constraint=constraint)
        elif budget_split is not _UNSET:
            kw.update(budget_split=None, constraint=None)  # explicit opt-out
        elif base.constraint is not None:
            # an explicit constraint object (no budget_split spec) carries
            # through refits, rescaled to any new total
            if "budget" in kw and hasattr(base.constraint, "scaled"):
                kw["constraint"] = base.constraint.scaled(kw["budget"])
        config = base.replace(**kw, **cfg_kw)
        spec = registry.get_solver(config.solver)
        if spec.needs_data:
            raise ValueError(
                f"refit() requires an SCSK solver (got {config.solver!r}): "
                "flow baselines consume the full TieringData whose weights "
                "are frozen at mine() time")
        if state is not None and not spec.supports_state:
            raise ValueError(
                f"solver {config.solver!r} does not support warm starts; "
                "pass state=None for a cold refit")
        if state is not None:
            wd = int(np.asarray(state.covered_d).shape[0])
            if wd != self.problem.wd:
                raise ValueError(
                    f"stale warm-start state: covered_d has {wd} words but "
                    f"the problem has wd={self.problem.wd} (corpus appended "
                    "since the state was captured?); re-derive it with "
                    "problem.state_for before refitting")
        self.problem = self.problem.with_weights(weights)
        if state is not None and config.partitioned:
            # re-allocation can shrink a cap below the warm prefix's frozen
            # fill; solvers only mask NEW candidates, so shed the overflow
            # (drop clauses touching over-cap shards) before resuming
            from repro.core.constraint import resolve_constraint, trim_state
            state, _ = trim_state(self.problem, state,
                                  resolve_constraint(self.problem, config))
        self.config = config
        self.result = registry.solve(self.problem, config, state=state)
        self._tiering = None
        return self

    def adopt_selection(self, state: SolverState) -> "TieringPipeline":
        """Install an externally-evolved selection as the current result.

        The ingest admission loop (repro.ingest) grows the selection between
        refits — mandatory Tier-1 admissions plus secretary-admitted clauses
        applied via `SCSKProblem.apply` — and this folds that state back into
        the pipeline so `tiering()`, `refit(state=...)` and `deploy*` see it.
        The state must be sized for the CURRENT problem (post-append widths).
        """
        if self.result is None:
            raise RuntimeError("call solve() before adopt_selection()")
        wd = int(np.asarray(state.covered_d).shape[0])
        if wd != self.problem.wd:
            raise ValueError(
                f"state covered_d has {wd} words, problem has "
                f"wd={self.problem.wd}; derive the state against the "
                "current (post-append) problem")
        self.result.state = state
        self.result.selected = np.asarray(state.selected)
        self.result.f_final = float(self.problem.f_value(state.covered_q))
        self.result.g_final = float(state.g_used)
        self._tiering = None
        return self

    # -- artifacts -----------------------------------------------------------
    def tiering(self) -> ClauseTiering:
        """The deployable ψ/φ artifact for the current solve."""
        if self.result is None:
            raise RuntimeError("call solve() before tiering()")
        if self.config is not None and \
                registry.get_solver(self.config.solver).needs_data:
            raise RuntimeError(
                f"solver {self.config.solver!r} is a flow baseline: it "
                "selects a document set, not clauses, so there is no clause "
                "tiering to deploy (ψ^flow cannot serve novel queries, paper "
                "§2.3). Its artifacts are in result.extra['flow'].")
        if self._tiering is None:
            self._tiering = ClauseTiering.from_selection(
                self.data, self.result.selected)
        return self._tiering

    def coverage(self) -> dict[str, float]:
        return self.tiering().coverage(self.data)

    def verify(self) -> bool:
        """Theorem 3.1, checked exhaustively over the query log."""
        return self.tiering().verify_correctness(self.data)

    def deploy(self):
        """-> serve.TieredEngine serving guaranteed-complete match sets."""
        from repro.serve.engine import TieredEngine
        return TieredEngine(self.data.postings, self.tiering(),
                            self.data.n_docs)

    def deploy_cluster(self, *, n_shards: int | None = None,
                       t1_replicas: int = 2, t2_replicas: int = 1,
                       trace_capacity: int | None | str = "default",
                       cache=None):
        """-> cluster.TieredCluster: the same tiering served by a sharded,
        replicated fleet (scatter-gather + rolling swaps), still exact.

        `n_shards` defaults to the solve's partition count when the solve
        used a shard-aware `budget_split` (the fleet's shards then coincide
        with the budget partitions, so each B_k bounds exactly one shard's
        local Tier-1 sub-index), else 2. `trace_capacity` bounds the
        retained `BatchTrace` history (None = keep every batch). `cache`
        attaches a classify-keyed front-end result cache (True = defaults,
        an int = capacity, or a configured `cluster.ResultCache`) — hits
        stay bit-identical to fresh matches across rolling swaps."""
        from repro.cluster import TieredCluster
        from repro.cluster.router import DEFAULT_TRACE_CAPACITY
        if n_shards is None:
            n_shards = self.n_partitions or 2
        if trace_capacity == "default":
            trace_capacity = DEFAULT_TRACE_CAPACITY
        return TieredCluster(self.data.postings, self.tiering(),
                             self.data.n_docs, n_shards=n_shards,
                             t1_replicas=t1_replicas,
                             t2_replicas=t2_replicas,
                             trace_capacity=trace_capacity,
                             cache=cache)

    def summary(self) -> str:
        parts = [f"{self.corpus.n_docs} docs", f"{self.log.n_queries} queries"]
        if self.data is not None:
            parts.append(f"{len(self.data.clauses)} clauses")
        if self.result is not None:
            parts.append(self.result.summary())
        return " | ".join(parts)
