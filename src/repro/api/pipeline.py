"""TieringPipeline: the paper's whole pipeline behind one fluent facade.

    from repro import api

    engine = (api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
              .mine(min_support=1e-3)
              .solve("optpes", budget_frac=0.5)
              .deploy())

Each stage materializes the artifact the next one consumes:

    from_*      -> corpus + query log
    mine        -> TieringData (FPGrowth clauses + packed incidence)
                   and the device-resident SCSKProblem
    solve       -> SolverResult via the solver registry (any registered
                   name, incl. the flow baselines)
    tiering     -> ClauseTiering (ψ/φ classifiers of §3.1)
    deploy      -> serve.TieredEngine ready for traffic

The pipeline keeps every intermediate (`.data`, `.problem`, `.result`) so
benchmarks can reach in, and `solve` accepts `state=` / returns cumulative
results so budget sweeps ride the same facade (`.sweep(budgets)`).
"""
from __future__ import annotations

from repro.core import registry
from repro.core.config import SolveConfig
from repro.core.problem import SCSKProblem, SolverResult
from repro.core.state import SolverState
from repro.core.tiering import ClauseTiering

# SolveConfig fields settable via TieringPipeline.solve(**options)
_CONFIG_KEYS = ("max_steps", "record_every", "time_limit", "seed",
                "stop_policy", "on_step", "on_record")


class TieringPipeline:
    def __init__(self, corpus, log):
        self.corpus = corpus
        self.log = log
        self.data = None               # data.incidence.TieringData
        self.problem: SCSKProblem | None = None
        self.config: SolveConfig | None = None
        self.result: SolverResult | None = None
        self._tiering: ClauseTiering | None = None

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_synthetic(cls, seed: int = 0, scale: str = "tiny") -> "TieringPipeline":
        from repro.data import synthetic
        corpus, log = synthetic.make_tiering_dataset(seed, scale)
        return cls(corpus, log)

    @classmethod
    def from_corpus(cls, corpus, log) -> "TieringPipeline":
        return cls(corpus, log)

    @classmethod
    def from_data(cls, data) -> "TieringPipeline":
        """Start from an already-built TieringData (skips `mine`)."""
        pipe = cls(data.corpus, data.log)
        pipe.data = data
        pipe.problem = SCSKProblem.from_data(data)
        return pipe

    # -- stages --------------------------------------------------------------
    def mine(self, min_support: float = 1e-3, *, max_clause_len: int = 4,
             max_clauses: int | None = None) -> "TieringPipeline":
        """FPGrowth clause mining (§3.3) + packed incidence structures."""
        from repro.data import incidence
        self.data = incidence.build_tiering_data(
            self.corpus, self.log, min_support=min_support,
            max_clause_len=max_clause_len, max_clauses=max_clauses)
        self.problem = SCSKProblem.from_data(self.data)
        self._tiering = None
        return self

    def solve(self, solver: str = "optpes", budget: float | None = None, *,
              budget_frac: float = 0.5, state: SolverState | None = None,
              config: SolveConfig | None = None, **options) -> "TieringPipeline":
        """SCSK solve via the registry. `**options` splits into SolveConfig
        fields (max_steps, time_limit, ...) and solver-specific options.
        An explicit `config=` carries everything itself (its `solver` wins)
        and cannot be combined with budget/options arguments."""
        if self.data is None:
            raise RuntimeError("call mine() (or from_data) before solve()")
        if config is not None and (budget is not None or options):
            raise ValueError(
                "pass either config= or budget/budget_frac/**options — an "
                "explicit SolveConfig already carries those")
        if config is None:
            # int truncation matches the pre-facade entrypoints
            # (budget = int(n_docs * frac)); an explicit budget is kept as-is
            budget = float(int(self.corpus.n_docs * budget_frac)
                           if budget is None else budget)
            cfg_kw = {k: options.pop(k) for k in _CONFIG_KEYS if k in options}
            config = SolveConfig(budget=budget, solver=solver,
                                 options=options, **cfg_kw)
        spec = registry.get_solver(config.solver)
        target = self.data if spec.needs_data else self.problem
        self.config = config
        self.result = registry.solve(target, config, state=state)
        self._tiering = None
        return self

    def sweep(self, budgets: list[float], solver: str = "greedy",
              **options) -> list[SolverResult]:
        """Warm-started budget sweep (Fig. 2/3); leaves the largest-budget
        result as the pipeline's current result."""
        if self.problem is None:
            raise RuntimeError("call mine() (or from_data) before sweep()")
        cfg_kw = {k: options.pop(k) for k in _CONFIG_KEYS if k in options}
        config = SolveConfig(budget=float(budgets[-1]), solver=solver,
                             options=options, **cfg_kw)
        results = registry.solve_sweep(self.problem, budgets, config)
        self.config = config
        self.result = results[-1]
        self._tiering = None
        return results

    def refit(self, weights, *, state: SolverState | None = None,
              budget: float | None = None, budget_frac: float | None = None,
              solver: str | None = None, **options) -> "TieringPipeline":
        """Re-solve against a NEW empirical query distribution (re-tiering).

        `weights` is the updated distribution over the pipeline's unique-query
        universe (length `n_queries`, e.g. from `repro.stream.LogAccumulator`).
        The problem is reweighted via `SCSKProblem.with_weights` — the packed
        incidence bitsets are reused, not rebuilt — and solved with the prior
        config (budget/solver/options default to the previous solve's).

        Pass `state=` to warm-start from a prior `SolverState` (typically the
        previous solve's state, optionally pruned by
        `repro.stream.prune_state`); omit it for a cold re-solve. The mined
        clause universe is fixed at `mine()` time, so the resulting tiering
        stays Theorem-3.1-exact regardless of the weights.
        """
        if self.problem is None:
            raise RuntimeError("call mine() (or from_data) before refit()")
        base = self.config if self.config is not None else \
            SolveConfig(budget=float(int(self.corpus.n_docs * 0.5)))
        if budget is not None and budget_frac is not None:
            raise ValueError("pass either budget= or budget_frac=, not both")
        kw = {}
        if budget_frac is not None:
            budget = float(int(self.corpus.n_docs * budget_frac))
        if budget is not None:
            kw["budget"] = float(budget)
        if solver is not None:
            kw["solver"] = solver
        cfg_kw = {k: options.pop(k) for k in _CONFIG_KEYS if k in options}
        if options:
            kw["options"] = {**dict(base.options), **options}
        config = base.replace(**kw, **cfg_kw)
        spec = registry.get_solver(config.solver)
        if spec.needs_data:
            raise ValueError(
                f"refit() requires an SCSK solver (got {config.solver!r}): "
                "flow baselines consume the full TieringData whose weights "
                "are frozen at mine() time")
        if state is not None and not spec.supports_state:
            raise ValueError(
                f"solver {config.solver!r} does not support warm starts; "
                "pass state=None for a cold refit")
        self.problem = self.problem.with_weights(weights)
        self.config = config
        self.result = registry.solve(self.problem, config, state=state)
        self._tiering = None
        return self

    # -- artifacts -----------------------------------------------------------
    def tiering(self) -> ClauseTiering:
        """The deployable ψ/φ artifact for the current solve."""
        if self.result is None:
            raise RuntimeError("call solve() before tiering()")
        if self.config is not None and \
                registry.get_solver(self.config.solver).needs_data:
            raise RuntimeError(
                f"solver {self.config.solver!r} is a flow baseline: it "
                "selects a document set, not clauses, so there is no clause "
                "tiering to deploy (ψ^flow cannot serve novel queries, paper "
                "§2.3). Its artifacts are in result.extra['flow'].")
        if self._tiering is None:
            self._tiering = ClauseTiering.from_selection(
                self.data, self.result.selected)
        return self._tiering

    def coverage(self) -> dict[str, float]:
        return self.tiering().coverage(self.data)

    def verify(self) -> bool:
        """Theorem 3.1, checked exhaustively over the query log."""
        return self.tiering().verify_correctness(self.data)

    def deploy(self):
        """-> serve.TieredEngine serving guaranteed-complete match sets."""
        from repro.serve.engine import TieredEngine
        return TieredEngine(self.data.postings, self.tiering(),
                            self.data.n_docs)

    def deploy_cluster(self, *, n_shards: int = 2, t1_replicas: int = 2,
                       t2_replicas: int = 1):
        """-> cluster.TieredCluster: the same tiering served by a sharded,
        replicated fleet (scatter-gather + rolling swaps), still exact."""
        from repro.cluster import TieredCluster
        return TieredCluster(self.data.postings, self.tiering(),
                             self.data.n_docs, n_shards=n_shards,
                             t1_replicas=t1_replicas,
                             t2_replicas=t2_replicas)

    def summary(self) -> str:
        parts = [f"{self.corpus.n_docs} docs", f"{self.log.n_queries} queries"]
        if self.data is not None:
            parts.append(f"{len(self.data.clauses)} clauses")
        if self.result is not None:
            parts.append(self.result.summary())
        return " | ".join(parts)
