"""Registry adapters for the data-based flow baselines (paper §2.3/§5.2).

The flow family (popularity / flow-max / flow-sgd) parameterizes tiering by a
document set rather than clauses, and consumes the full `TieringData` (it
needs per-query match sets, not the clause incidence an `SCSKProblem` keeps).
These thin adapters put them behind the SAME `solve(problem, config, state)`
signature as the SCSK solvers, so `benchmarks/solvers.py` and
`benchmarks/generalization.py` iterate one registry.

Calling convention: pass the `TieringData` either AS the problem argument, or
via `config.options["data"]` when the positional slot holds an `SCSKProblem`.
The returned `SolverResult` maps the flow quantities onto the common record
(f_final = train coverage, g_final = Tier-1 doc count, selected = no clauses)
and keeps the native `FlowResult` in `result.extra["flow"]`.
"""
from __future__ import annotations

import numpy as np

from repro.core import flow
from repro.core.config import SolveConfig
from repro.core.problem import SolverResult
from repro.core.registry import register_solver
from repro.core.state import SolverState
from repro.data.incidence import TieringData


def _data_of(problem, config: SolveConfig) -> TieringData:
    if isinstance(problem, TieringData):
        return problem
    data = config.opt("data")
    if data is None:
        raise ValueError(
            "flow baselines need the TieringData: pass it as the problem "
            "argument or in config.options['data']")
    return data


def _to_result(r: flow.FlowResult, data: TieringData) -> SolverResult:
    n_clauses = len(data.clauses)
    return SolverResult(
        name=r.name,
        selected=np.zeros(n_clauses, bool),   # flow selects docs, not clauses
        order=[],
        f_final=r.train_coverage,
        g_final=float(r.tier1_docs.sum()),
        f_history=np.asarray([0.0, r.train_coverage]),
        g_history=np.asarray([0.0, float(r.tier1_docs.sum())]),
        time_history=np.asarray([0.0, r.wall_seconds]),
        extra={"flow": r, "test_coverage": r.test_coverage,
               "tier1_docs": r.tier1_docs,
               "eligible_queries": r.eligible_queries},
    )


@register_solver("flow-popularity", needs_data=True,
                 description="top-B docs by P[d ∈ m(q)] (Leung et al.)")
def solve_flow_popularity(problem, config: SolveConfig,
                          state: SolverState | None = None) -> SolverResult:
    data = _data_of(problem, config)
    return _to_result(flow.popularity(data, int(config.budget)), data)


@register_solver("flow-max", needs_data=True,
                 description="top-B docs by max_q P[q] (Leung et al.)")
def solve_flow_max(problem, config: SolveConfig,
                   state: SolverState | None = None) -> SolverResult:
    data = _data_of(problem, config)
    return _to_result(flow.flow_max(data, int(config.budget)), data)


@register_solver("flow-sgd", needs_data=True,
                 description="smooth-min SGD relaxation of eq. 5 (Leung et al.)")
def solve_flow_sgd(problem, config: SolveConfig,
                   state: SolverState | None = None) -> SolverResult:
    data = _data_of(problem, config)
    kw = {k: config.options[k] for k in
          ("lam", "steps", "batch", "lr", "tau", "mu") if k in config.options}
    return _to_result(
        flow.flow_sgd(data, int(config.budget), seed=config.seed, **kw), data)
