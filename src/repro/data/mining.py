"""FPGrowth frequent-itemset mining (paper §3.3).

The regularized ground set X̄ = {c : P_{q~Qn}[c ⊆ q] >= λ} is mined from the
weighted unique-query log with FPGrowth [Han et al. 2000], exactly as the
paper does. This is one-off host-side preprocessing (numpy/python), like the
paper's Lucene indexing step; the solvers downstream are all JAX.

`brute_force_frequent` is the test oracle.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools


@dataclasses.dataclass
class _Node:
    item: int
    count: float
    parent: "_Node | None"
    children: dict[int, "_Node"] = dataclasses.field(default_factory=dict)


def fpgrowth(
    transactions: list[tuple[int, ...]],
    weights: list[float] | None,
    min_support: float,
    *,
    max_len: int = 4,
    max_items: int | None = None,
) -> dict[tuple[int, ...], float]:
    """Weighted FPGrowth.

    transactions: item-id tuples (sets).
    weights:      per-transaction weight (empirical probability); None = 1.0.
    min_support:  λ, in the same unit as weights (probability if weights sum
                  to 1).
    Returns {sorted clause tuple -> support}.
    """
    if weights is None:
        weights = [1.0] * len(transactions)

    item_support: dict[int, float] = collections.defaultdict(float)
    for t, w in zip(transactions, weights):
        for it in set(t):
            item_support[it] += w
    frequent = {it: s for it, s in item_support.items() if s >= min_support}
    # global order: decreasing support, ties by id (deterministic)
    order = {it: r for r, it in enumerate(
        sorted(frequent, key=lambda i: (-frequent[i], i)))}

    root = _Node(item=-1, count=0.0, parent=None)
    header: dict[int, list[_Node]] = collections.defaultdict(list)

    def insert(items: list[int], w: float) -> None:
        node = root
        for it in items:
            child = node.children.get(it)
            if child is None:
                child = _Node(item=it, count=0.0, parent=node)
                node.children[it] = child
                header[it].append(child)
            child.count += w
            node = child

    for t, w in zip(transactions, weights):
        items = sorted((it for it in set(t) if it in frequent),
                       key=lambda i: order[i])
        if items:
            insert(items, w)

    results: dict[tuple[int, ...], float] = {}

    def mine(suffix: tuple[int, ...], hdr: dict[int, list[_Node]],
             supports: dict[int, float]) -> None:
        if max_items is not None and len(results) >= max_items:
            return
        for it in sorted(supports, key=lambda i: (-supports[i], i)):
            s = supports[it]
            if s < min_support:
                continue
            clause = tuple(sorted(suffix + (it,)))
            results[clause] = s
            if max_items is not None and len(results) >= max_items:
                return
            if len(clause) >= max_len:
                continue
            # conditional pattern base for `it`
            cond: list[tuple[list[int], float]] = []
            for node in hdr[it]:
                path: list[int] = []
                p = node.parent
                while p is not None and p.item != -1:
                    path.append(p.item)
                    p = p.parent
                if path:
                    cond.append((list(reversed(path)), node.count))
            # build conditional tree
            csup: dict[int, float] = collections.defaultdict(float)
            for path, w in cond:
                for x in path:
                    csup[x] += w
            csup = {x: s2 for x, s2 in csup.items() if s2 >= min_support}
            if not csup:
                continue
            croot = _Node(item=-1, count=0.0, parent=None)
            chdr: dict[int, list[_Node]] = collections.defaultdict(list)
            corder = {x: r for r, x in enumerate(
                sorted(csup, key=lambda i: (-csup[i], i)))}
            for path, w in cond:
                items = sorted((x for x in path if x in csup),
                               key=lambda i: corder[i])
                node = croot
                for x in items:
                    child = node.children.get(x)
                    if child is None:
                        child = _Node(item=x, count=0.0, parent=node)
                        node.children[x] = child
                        chdr[x].append(child)
                    child.count += w
                    node = child
            mine(clause, chdr, dict(csup))

    mine((), header, {it: frequent[it] for it in frequent})
    return results


def brute_force_frequent(
    transactions: list[tuple[int, ...]],
    weights: list[float] | None,
    min_support: float,
    *,
    max_len: int = 4,
) -> dict[tuple[int, ...], float]:
    """Test oracle: enumerate every itemset of size <= max_len."""
    if weights is None:
        weights = [1.0] * len(transactions)
    support: dict[tuple[int, ...], float] = collections.defaultdict(float)
    for t, w in zip(transactions, weights):
        items = sorted(set(t))
        for k in range(1, min(max_len, len(items)) + 1):
            for combo in itertools.combinations(items, k):
                support[combo] += w
    return {c: s for c, s in support.items() if s >= min_support}
