"""Incidence-structure builders: postings, match sets, clause incidence.

Turns the host-side corpus/query log into the packed-bitset operands the SCSK
engine consumes:

  postings_bits     uint32 [V, Wd]   token -> doc bitset (the inverted index)
  clause_doc_bits   uint32 [C, Wd]   m(c) per clause  (paper eq. 1, AND of postings)
  clause_query_bits uint32 [C, Wq]   {q : c ⊆ q} per clause
  query_doc_bits    uint32 [Nq, Wd]  m(q) per unique query (flow baselines)
  clause_doc_ids    int32  [C, M]    padded+sorted m(c) id lists (sparse path)

`append_docs` grows every one of those structures in place by a whole-word
document block (repro.ingest): existing words are NEVER rewritten, so any
column slice taken before the append stays bit-identical afterwards — the
invariant the cluster's content-carried rolling postings swaps rely on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitset
from repro.data.synthetic import Corpus, QueryLog


def build_postings(corpus: Corpus) -> np.ndarray:
    """Packed postings lists: bit d of row v set iff v ∈ doc d."""
    n_docs = corpus.n_docs
    bits = np.zeros((corpus.vocab_size, n_docs), dtype=bool)
    for d, toks in enumerate(corpus.doc_tokens):
        bits[list(toks), d] = True
    return bitset.np_pack(bits)


def match_bits(postings: np.ndarray, clause: tuple[int, ...], n_docs: int) -> np.ndarray:
    """m(clause) as a packed bitset: AND of the clause terms' postings."""
    out = np.full(postings.shape[1], 0xFFFFFFFF, dtype=np.uint32)
    for t in clause:
        out &= postings[t]
    # clear padding bits beyond n_docs
    pad_mask = bitset.np_pack(np.ones(n_docs, dtype=bool))
    return out & pad_mask


def clause_doc_incidence(postings: np.ndarray, clauses: list[tuple[int, ...]],
                         n_docs: int) -> np.ndarray:
    return np.stack([match_bits(postings, c, n_docs) for c in clauses]) \
        if clauses else np.zeros((0, postings.shape[1]), np.uint32)


def clause_query_incidence(
    query_bits: np.ndarray,            # packed [Nq, Wv]
    clauses: list[tuple[int, ...]],
    vocab_size: int,
    chunk: int = 512,
) -> np.ndarray:
    """Packed [C, Wq]: bit q of row c set iff c ⊆ q. Chunked subset test."""
    nq = query_bits.shape[0]
    cbits = np.zeros((len(clauses), vocab_size), dtype=bool)
    for i, c in enumerate(clauses):
        cbits[i, list(c)] = True
    cpk = bitset.np_pack(cbits)                       # [C, Wv]
    out = np.zeros((len(clauses), nq), dtype=bool)
    for s in range(0, len(clauses), chunk):
        blk = cpk[s:s + chunk]                        # [b, Wv]
        sub = (query_bits[None, :, :] & blk[:, None, :]) == blk[:, None, :]
        out[s:s + chunk] = sub.all(axis=-1)
    return bitset.np_pack(out)


def query_doc_incidence(postings: np.ndarray, log: QueryLog, n_docs: int) -> np.ndarray:
    """m(q) per unique query, packed [Nq, Wd] (used by flow baselines)."""
    return np.stack([match_bits(postings, q, n_docs) for q in log.queries])


def padded_id_lists(rows_bits: np.ndarray, n_bits: int,
                    pad_to: int | None = None) -> np.ndarray:
    """Packed rows -> int32 [R, M] sorted id lists padded with -1."""
    lists = [bitset.np_to_indices(r, n_bits) for r in rows_bits]
    m = pad_to or max((len(x) for x in lists), default=1)
    out = np.full((len(lists), max(m, 1)), -1, dtype=np.int32)
    for i, x in enumerate(lists):
        out[i, :len(x)] = x          # np.nonzero is already sorted
    return out


@dataclasses.dataclass
class TieringData:
    """Everything the solvers and baselines need, in host numpy."""
    corpus: Corpus
    log: QueryLog
    postings: np.ndarray             # [V, Wd]
    clauses: list[tuple[int, ...]]
    clause_support: np.ndarray       # f64 [C] empirical P[c ⊆ q]
    clause_doc_bits: np.ndarray      # [C, Wd]
    clause_query_bits: np.ndarray    # [C, Wq]
    query_doc_bits: np.ndarray       # [Nq, Wd]

    @property
    def n_docs(self) -> int:
        return self.corpus.n_docs

    @property
    def n_queries(self) -> int:
        return self.log.n_queries


@dataclasses.dataclass(frozen=True)
class AppendDelta:
    """What one `append_docs` call added, in block coordinates.

    The block is word-aligned: it starts at word `word_lo` (doc id
    `doc_lo = word_lo * 32`), which means up to 31 hole slots pad the
    previous tail word first. Holes are permanent empty documents — `()`
    token sets with zero bits in every incidence structure — so no existing
    postings word is ever rewritten and they can never match any clause or
    query. `clause_cols` is the appended clause×block incidence, ready for
    `SCSKProblem.with_doc_block`.
    """
    doc_lo: int                # global id of the first appended slot (hole or doc)
    n_holes: int               # alignment padding slots before the real docs
    n_new: int                 # real documents appended
    word_lo: int               # first appended postings word (inclusive)
    word_hi: int               # one past the last appended word == new Wd
    clause_cols: np.ndarray    # uint32 [C, word_hi - word_lo] block m(c) columns
    n_docs: int                # corpus.n_docs after the append (incl. holes)


def append_docs(data: "TieringData", docs: list[tuple[int, ...]]) -> AppendDelta:
    """Append a word-aligned document block to every incidence structure.

    Mutates `data` (corpus, postings, clause_doc_bits, query_doc_bits) in
    place and returns the `AppendDelta` describing the block. Append-only in
    whole words: the block starts at the next word boundary (hole slots fill
    the tail partial word), new columns are computed only over the block —
    O((V + C + Nq) · block_words) — and concatenated, so every pre-existing
    word keeps its exact bits. Clause/query *vocab*-side structures are
    untouched: documents don't change the query universe.
    """
    if not docs:
        raise ValueError("append_docs needs at least one document")
    corpus = data.corpus
    word_lo = data.postings.shape[1]
    doc_lo = word_lo * bitset.WORD
    n_holes = doc_lo - corpus.n_docs
    n_new = len(docs)
    n_docs_new = doc_lo + n_new
    word_hi = bitset.n_words(n_docs_new)

    for t in docs:
        bad = [v for v in t if not 0 <= int(v) < corpus.vocab_size]
        if bad:
            raise ValueError(f"document tokens {bad} outside vocab "
                             f"[0, {corpus.vocab_size})")
    corpus.doc_tokens.extend([()] * n_holes)
    corpus.doc_tokens.extend(tuple(sorted(set(int(v) for v in t)))
                             for t in docs)

    # block postings [V, wb]: bit (d - doc_lo) of row v set iff v ∈ doc d
    wb = word_hi - word_lo
    blk = np.zeros((corpus.vocab_size, n_new), dtype=bool)
    for j, toks in enumerate(corpus.doc_tokens[doc_lo:]):
        blk[list(toks), j] = True
    blk_postings = bitset.np_pack(blk)       # [V, wb]: doc_lo is word-aligned

    # corpus doc_bits rows: holes are all-zero rows, then the packed docs
    hole_rows = np.zeros((n_holes, corpus.doc_bits.shape[1]), np.uint32)
    doc_rows = bitset.np_pack(blk.T)
    corpus.doc_bits = np.concatenate([corpus.doc_bits, hole_rows, doc_rows])

    # incidence columns over the block only (block doc ids are local)
    clause_cols = clause_doc_incidence(blk_postings, data.clauses, n_new)
    query_cols = query_doc_incidence(blk_postings, data.log, n_new) \
        if data.log.queries else np.zeros((0, wb), np.uint32)

    data.postings = np.concatenate([data.postings, blk_postings], axis=1)
    data.clause_doc_bits = np.concatenate(
        [data.clause_doc_bits, clause_cols], axis=1)
    data.query_doc_bits = np.concatenate(
        [data.query_doc_bits, query_cols], axis=1)
    return AppendDelta(doc_lo=doc_lo - n_holes, n_holes=n_holes, n_new=n_new,
                       word_lo=word_lo, word_hi=word_hi,
                       clause_cols=clause_cols, n_docs=corpus.n_docs)


def build_tiering_data(corpus: Corpus, log: QueryLog, *, min_support: float,
                       max_clause_len: int = 4,
                       max_clauses: int | None = None) -> TieringData:
    from repro.data import mining
    # mine with head-room, THEN keep the top-support clauses: fpgrowth's
    # max_items stops recursion mid-mining (an arbitrary subset, not the
    # most frequent patterns)
    mined = mining.fpgrowth(
        log.queries, list(log.train_weights), min_support,
        max_len=max_clause_len,
        max_items=None if max_clauses is None else 10 * max_clauses)
    clauses = sorted(mined, key=lambda c: (-mined[c], c))
    if max_clauses is not None:
        clauses = clauses[:max_clauses]
    postings = build_postings(corpus)
    return TieringData(
        corpus=corpus,
        log=log,
        postings=postings,
        clauses=clauses,
        clause_support=np.array([mined[c] for c in clauses]),
        clause_doc_bits=clause_doc_incidence(postings, clauses, corpus.n_docs),
        clause_query_bits=clause_query_incidence(
            log.query_bits, clauses, corpus.vocab_size),
        query_doc_bits=query_doc_incidence(postings, log, corpus.n_docs),
    )
