"""Incidence-structure builders: postings, match sets, clause incidence.

Turns the host-side corpus/query log into the packed-bitset operands the SCSK
engine consumes:

  postings_bits     uint32 [V, Wd]   token -> doc bitset (the inverted index)
  clause_doc_bits   uint32 [C, Wd]   m(c) per clause  (paper eq. 1, AND of postings)
  clause_query_bits uint32 [C, Wq]   {q : c ⊆ q} per clause
  query_doc_bits    uint32 [Nq, Wd]  m(q) per unique query (flow baselines)
  clause_doc_ids    int32  [C, M]    padded+sorted m(c) id lists (sparse path)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitset
from repro.data.synthetic import Corpus, QueryLog


def build_postings(corpus: Corpus) -> np.ndarray:
    """Packed postings lists: bit d of row v set iff v ∈ doc d."""
    n_docs = corpus.n_docs
    bits = np.zeros((corpus.vocab_size, n_docs), dtype=bool)
    for d, toks in enumerate(corpus.doc_tokens):
        bits[list(toks), d] = True
    return bitset.np_pack(bits)


def match_bits(postings: np.ndarray, clause: tuple[int, ...], n_docs: int) -> np.ndarray:
    """m(clause) as a packed bitset: AND of the clause terms' postings."""
    out = np.full(postings.shape[1], 0xFFFFFFFF, dtype=np.uint32)
    for t in clause:
        out &= postings[t]
    # clear padding bits beyond n_docs
    pad_mask = bitset.np_pack(np.ones(n_docs, dtype=bool))
    return out & pad_mask


def clause_doc_incidence(postings: np.ndarray, clauses: list[tuple[int, ...]],
                         n_docs: int) -> np.ndarray:
    return np.stack([match_bits(postings, c, n_docs) for c in clauses]) \
        if clauses else np.zeros((0, postings.shape[1]), np.uint32)


def clause_query_incidence(
    query_bits: np.ndarray,            # packed [Nq, Wv]
    clauses: list[tuple[int, ...]],
    vocab_size: int,
    chunk: int = 512,
) -> np.ndarray:
    """Packed [C, Wq]: bit q of row c set iff c ⊆ q. Chunked subset test."""
    nq = query_bits.shape[0]
    cbits = np.zeros((len(clauses), vocab_size), dtype=bool)
    for i, c in enumerate(clauses):
        cbits[i, list(c)] = True
    cpk = bitset.np_pack(cbits)                       # [C, Wv]
    out = np.zeros((len(clauses), nq), dtype=bool)
    for s in range(0, len(clauses), chunk):
        blk = cpk[s:s + chunk]                        # [b, Wv]
        sub = (query_bits[None, :, :] & blk[:, None, :]) == blk[:, None, :]
        out[s:s + chunk] = sub.all(axis=-1)
    return bitset.np_pack(out)


def query_doc_incidence(postings: np.ndarray, log: QueryLog, n_docs: int) -> np.ndarray:
    """m(q) per unique query, packed [Nq, Wd] (used by flow baselines)."""
    return np.stack([match_bits(postings, q, n_docs) for q in log.queries])


def padded_id_lists(rows_bits: np.ndarray, n_bits: int,
                    pad_to: int | None = None) -> np.ndarray:
    """Packed rows -> int32 [R, M] sorted id lists padded with -1."""
    lists = [bitset.np_to_indices(r, n_bits) for r in rows_bits]
    m = pad_to or max((len(x) for x in lists), default=1)
    out = np.full((len(lists), max(m, 1)), -1, dtype=np.int32)
    for i, x in enumerate(lists):
        out[i, :len(x)] = x          # np.nonzero is already sorted
    return out


@dataclasses.dataclass
class TieringData:
    """Everything the solvers and baselines need, in host numpy."""
    corpus: Corpus
    log: QueryLog
    postings: np.ndarray             # [V, Wd]
    clauses: list[tuple[int, ...]]
    clause_support: np.ndarray       # f64 [C] empirical P[c ⊆ q]
    clause_doc_bits: np.ndarray      # [C, Wd]
    clause_query_bits: np.ndarray    # [C, Wq]
    query_doc_bits: np.ndarray       # [Nq, Wd]

    @property
    def n_docs(self) -> int:
        return self.corpus.n_docs

    @property
    def n_queries(self) -> int:
        return self.log.n_queries


def build_tiering_data(corpus: Corpus, log: QueryLog, *, min_support: float,
                       max_clause_len: int = 4,
                       max_clauses: int | None = None) -> TieringData:
    from repro.data import mining
    # mine with head-room, THEN keep the top-support clauses: fpgrowth's
    # max_items stops recursion mid-mining (an arbitrary subset, not the
    # most frequent patterns)
    mined = mining.fpgrowth(
        log.queries, list(log.train_weights), min_support,
        max_len=max_clause_len,
        max_items=None if max_clauses is None else 10 * max_clauses)
    clauses = sorted(mined, key=lambda c: (-mined[c], c))
    if max_clauses is not None:
        clauses = clauses[:max_clauses]
    postings = build_postings(corpus)
    return TieringData(
        corpus=corpus,
        log=log,
        postings=postings,
        clauses=clauses,
        clause_support=np.array([mined[c] for c in clauses]),
        clause_doc_bits=clause_doc_incidence(postings, clauses, corpus.n_docs),
        clause_query_bits=clause_query_incidence(
            log.query_bits, clauses, corpus.vocab_size),
        query_doc_bits=query_doc_incidence(postings, log, corpus.n_docs),
    )
