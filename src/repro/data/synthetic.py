"""Synthetic corpus + heavy-tailed query-distribution generator.

Mirrors the statistics the paper reports for its commercial-search data at a
CPU-tractable scale: a Zipfian vocabulary, documents as term sets, and a query
distribution with (a) a Zipfian head, and (b) a heavy tail such that a
substantial fraction of *test* queries never appear in the *training* log —
exactly the regime where the paper's clause method beats query-selection
(flow) methods, cf. paper §2.3 and Fig. 5.

Everything here is host-side numpy preprocessing (the paper's analogue is
Lucene indexing); device arrays are produced by data/incidence.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitset


@dataclasses.dataclass
class Corpus:
    doc_tokens: list[tuple[int, ...]]   # sorted term ids per doc
    doc_bits: np.ndarray                # packed uint32 [n_docs, Wv] over vocab
    vocab_size: int

    @property
    def n_docs(self) -> int:
        return len(self.doc_tokens)


@dataclasses.dataclass
class QueryLog:
    """Unique queries with empirical train/test probabilities.

    train_weights/test_weights are empirical probabilities over the union of
    unique queries; a query unseen in train has train_weights == 0 (the
    "novel traffic" the paper's method must generalize to).
    """
    queries: list[tuple[int, ...]]
    query_bits: np.ndarray              # packed uint32 [Nq, Wv] over vocab
    train_weights: np.ndarray           # f64 [Nq], sums to 1
    test_weights: np.ndarray            # f64 [Nq], sums to 1
    n_train_samples: int
    n_test_samples: int

    @property
    def n_queries(self) -> int:
        return len(self.queries)

    def novel_test_mass(self) -> float:
        """Fraction of test traffic on queries unseen in training."""
        return float(self.test_weights[self.train_weights == 0].sum())


def _zipf_probs(n: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1) ** a
    return p / p.sum()


def make_corpus(
    rng: np.random.Generator,
    *,
    vocab_size: int = 2000,
    n_docs: int = 20000,
    doc_len_mean: float = 8.0,
    zipf_a: float = 1.05,
) -> Corpus:
    probs = _zipf_probs(vocab_size, zipf_a)
    # shuffle so token id is not rank (more realistic hashing)
    perm = rng.permutation(vocab_size)
    probs = probs[perm]
    docs: list[tuple[int, ...]] = []
    lengths = np.maximum(2, rng.poisson(doc_len_mean, size=n_docs))
    for i in range(n_docs):
        k = int(min(lengths[i], vocab_size))
        toks = rng.choice(vocab_size, size=k, replace=False, p=probs)
        docs.append(tuple(sorted(int(t) for t in set(toks.tolist()))))
    bits = np.zeros((n_docs, vocab_size), dtype=bool)
    for i, d in enumerate(docs):
        bits[i, list(d)] = True
    return Corpus(doc_tokens=docs, doc_bits=bitset.np_pack(bits), vocab_size=vocab_size)


def make_query_log(
    rng: np.random.Generator,
    corpus: Corpus,
    *,
    pool_size: int = 30000,
    n_train: int = 200000,
    n_test: int = 70000,
    max_query_len: int = 4,
    zipf_a: float = 0.9,
) -> QueryLog:
    """Build a query pool by sub-sampling document term sets (non-empty match
    sets guaranteed), Zipf-weight the pool, and draw iid train/test logs."""
    n_docs = corpus.n_docs
    pool: dict[tuple[int, ...], None] = {}
    while len(pool) < pool_size:
        need = pool_size - len(pool)
        doc_idx = rng.integers(0, n_docs, size=need * 2)
        sizes = rng.integers(1, max_query_len + 1, size=need * 2)
        for di, sz in zip(doc_idx, sizes):
            d = corpus.doc_tokens[int(di)]
            if len(d) == 0:
                continue
            sz = int(min(sz, len(d)))
            q = tuple(sorted(int(t) for t in rng.choice(d, size=sz, replace=False)))
            pool[q] = None
            if len(pool) >= pool_size:
                break
    queries = list(pool.keys())
    pool_probs = _zipf_probs(len(queries), zipf_a)
    pool_probs = pool_probs[rng.permutation(len(queries))]

    train_counts = rng.multinomial(n_train, pool_probs)
    test_counts = rng.multinomial(n_test, pool_probs)
    keep = (train_counts + test_counts) > 0
    queries = [q for q, k in zip(queries, keep) if k]
    train_counts = train_counts[keep]
    test_counts = test_counts[keep]

    bits = np.zeros((len(queries), corpus.vocab_size), dtype=bool)
    for i, q in enumerate(queries):
        bits[i, list(q)] = True

    return QueryLog(
        queries=queries,
        query_bits=bitset.np_pack(bits),
        train_weights=train_counts / max(1, n_train),
        test_weights=test_counts / max(1, n_test),
        n_train_samples=n_train,
        n_test_samples=n_test,
    )


def make_tiering_dataset(seed: int = 0, scale: str = "small"):
    """One-call dataset factory. Scales: tiny (tests), small (benches),
    medium (solver benchmarks)."""
    rng = np.random.default_rng(seed)
    presets = {
        "tiny": dict(vocab_size=64, n_docs=200, doc_len_mean=6.0,
                     pool=400, n_train=4000, n_test=1500),
        "small": dict(vocab_size=800, n_docs=4000, doc_len_mean=8.0,
                      pool=6000, n_train=60000, n_test=20000),
        "medium": dict(vocab_size=2000, n_docs=20000, doc_len_mean=8.0,
                       pool=30000, n_train=200000, n_test=70000),
    }
    p = presets[scale]
    corpus = make_corpus(rng, vocab_size=p["vocab_size"], n_docs=p["n_docs"],
                         doc_len_mean=p["doc_len_mean"])
    log = make_query_log(rng, corpus, pool_size=p["pool"],
                         n_train=p["n_train"], n_test=p["n_test"])
    return corpus, log
