"""The re-tiering control loop: serve → accumulate → detect → refit → swap.

One `RetieringController.step(window)` call per traffic window:

  1. serve the window's queries through the live `TieredEngine`
     (per-window stats via `ServeStats.reset/snapshot`, cumulative via
     `merge` — the engine's counters are window-scoped under this loop);
  2. fold the window into the `LogAccumulator`'s decayed weights;
  3. feed windowed stats + weights to the `DriftDetector`;
  4. on a trigger, re-solve via `TieringPipeline.refit`: prune stale clauses
     from the previous `SolverState` (`prune_state`) and warm-start from the
     rest — falling back to a cold solve if the warm tiering would cover
     less of the current traffic than the deployed one — then
  5. `TieredEngine.swap_tiering` the new generation in atomically.

Theorem 3.1 exactness is preserved on every window: ψ and D₁ always come
from one clause selection, whatever the weights that chose it.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import obs
from repro.core import bitset
from repro.obs.render import render_line
from repro.serve.engine import ServeStats, TieredEngine
from repro.stream.detector import DriftDetector
from repro.stream.drift import TrafficSimulator, TrafficWindow
from repro.stream.window import LogAccumulator, prune_partitions, prune_state

_REFITS = obs.counter("refits_total", "re-solves shipped", labels=("kind",))
_W_COV = obs.gauge("window_coverage", "last window's Tier-1 eligible fraction")
_W_SAVING = obs.gauge("window_cost_saving", "last window's word-traffic saving")
_W_TV = obs.gauge("window_tv_distance", "drift signal vs last refit")
_GEN = obs.gauge("live_generation", "tiering generation serving traffic")
_REFIT_S = obs.gauge("refit_seconds", "last refit wall-clock, seconds")
_W_CACHE = obs.gauge("frontend_cache_hit_rate",
                     "last window's front-end result-cache hit rate")


@dataclasses.dataclass
class WindowReport:
    """Everything the loop observed and did during one window."""
    index: int
    stats: ServeStats            # this window's serve counters (detached)
    coverage: float              # windowed Tier-1 eligible fraction
    cost_saving: float           # windowed word-traffic saving
    tv_distance: float           # drift signal vs last refit
    refit: str = ""              # "" | "warm" | "cold"
    refit_steps: int = 0         # selections made by the refit solve
    refit_seconds: float = 0.0   # wall time: prune + solve + build + swap
    pruned: int = 0              # clauses dropped before the warm start
    generation: int = 0          # engine generation serving this window's END
    parity_ok: bool | None = None  # Theorem-3.1 spot check (verify_swaps)
    shard_tv: tuple[float, ...] = ()  # per-shard TV drift (partitioned only)
    scope: tuple[int, ...] = ()  # shards a scoped warm refit re-tiered

    def line(self) -> str:
        refit = f"{self.refit}({self.refit_steps} steps, " \
                f"{self.refit_seconds:.2f}s, -{self.pruned})" if self.refit \
                else "-"
        return render_line(f"window {self.index:3d}", [
            ("cov", self.coverage), ("saving", self.cost_saving),
            ("tv", self.tv_distance), ("refit", refit),
            ("gen", self.generation),
            ("cache_hit", self.stats.cache_hit_rate
             if self.stats.cache_hits else None),
            ("scope", list(self.scope) if self.scope else None),
            ("parity", self.parity_ok)])

    def to_dict(self) -> dict:
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)
             if f.name != "stats"}
        d["stats"] = self.stats.to_dict()
        d["shard_tv"] = list(self.shard_tv)
        d["scope"] = list(self.scope)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "WindowReport":
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in names}
        kw["stats"] = ServeStats.from_dict(d.get("stats", {}))
        kw["shard_tv"] = tuple(kw.get("shard_tv", ()))
        kw["scope"] = tuple(kw.get("scope", ()))
        return cls(**kw)


@dataclasses.dataclass
class StreamReport:
    """A whole run: per-window reports + cumulative serve stats."""
    scenario: str
    windows: list[WindowReport]
    cumulative: ServeStats

    @property
    def mean_coverage(self) -> float:
        return float(np.mean([w.coverage for w in self.windows])) \
            if self.windows else 0.0

    @property
    def n_refits(self) -> int:
        return sum(1 for w in self.windows if w.refit)

    @property
    def n_warm(self) -> int:
        return sum(1 for w in self.windows if w.refit == "warm")

    @property
    def n_parity_checks(self) -> int:
        return sum(1 for w in self.windows if w.parity_ok is not None)

    def parity_all_ok(self) -> bool:
        """True iff no performed check failed — vacuously true when nothing
        was checked; gate on `n_parity_checks` for a non-vacuous claim."""
        return all(w.parity_ok for w in self.windows
                   if w.parity_ok is not None)

    def summary(self) -> str:
        return render_line(f"[{self.scenario}]", [
            ("@windows", f"{len(self.windows)} windows"),
            ("mean_cov", self.mean_coverage),
            ("cum_saving", self.cumulative.cost_saving),
            ("refits", f"{self.n_refits} ({self.n_warm} warm)")])

    def to_dict(self) -> dict:
        return {"scenario": self.scenario,
                "windows": [w.to_dict() for w in self.windows],
                "cumulative": self.cumulative.to_dict(),
                "mean_coverage": self.mean_coverage,
                "n_refits": self.n_refits, "n_warm": self.n_warm}

    @classmethod
    def from_dict(cls, d: dict) -> "StreamReport":
        return cls(scenario=d["scenario"],
                   windows=[WindowReport.from_dict(w)
                            for w in d.get("windows", [])],
                   cumulative=ServeStats.from_dict(d.get("cumulative", {})))


class RetieringController:
    """Drift-aware online re-tiering over a solved `TieringPipeline`.

    The controller owns the serving engine, the decayed-log accumulator and
    the drift detector; the pipeline it wraps is mutated on refit (its
    problem is reweighted in place of the traffic, its result/tiering
    replaced). `enable_refit=False` turns the loop into the static-tiering
    baseline — same serving, same accounting, never re-solves — so A/B runs
    compare on identical traffic.
    """

    def __init__(self, pipe, *, engine: TieredEngine | None = None,
                 accumulator: LogAccumulator | None = None,
                 detector: DriftDetector | None = None,
                 warm: bool = True, enable_refit: bool = True,
                 prune_below: float = 2e-3, cold_fallback: bool = True,
                 blend_prior: float = 0.35, verify_swaps: bool = False,
                 scoped: bool = True, shard_tv_threshold: float = 0.15,
                 scope_frac: float = 0.5, serve_batch: int | None = None):
        self.pipe = pipe
        # serve a window in chunks of this many queries (None = one batch);
        # the ingest loop uses small chunks so rolling swaps interleave with
        # traffic the way a live fleet would see them
        self.serve_batch = serve_batch
        self.engine = engine if engine is not None else pipe.deploy()
        self.queries = pipe.log.queries
        nq = pipe.log.n_queries
        self.accumulator = accumulator if accumulator is not None else \
            LogAccumulator(nq, halflife=1.0,
                           prior=np.asarray(pipe.log.train_weights),
                           prior_strength=32.0)
        self.detector = detector if detector is not None else DriftDetector()
        self.warm = warm
        self.enable_refit = enable_refit
        self.prune_below = prune_below
        self.cold_fallback = cold_fallback
        # refits hedge: solve against (1-λ)·decayed + λ·long-term prior, so
        # the tiering tilts toward the hot traffic without abandoning the
        # baseline head (over-specializing loses the epoch-boundary windows)
        self.blend_prior = blend_prior
        self._prior = np.asarray(pipe.log.train_weights, np.float64)
        self._prior = self._prior / max(self._prior.sum(), 1e-30)
        self.verify_swaps = verify_swaps
        # the offline tiering is the refit quality bar: a warm candidate
        # predicting below it (or below the deployed tiering) triggers the
        # cold-solve fallback instead of shipping a regression
        self._baseline_tiering = self.engine.tiering
        self._elig_cache: list = []    # (tiering, eligibility mask) pairs
        self.cumulative = ServeStats()
        # shard-aware re-tiering: when the pipe solved with a budget_split,
        # track each doc partition's traffic distribution so refits can be
        # SCOPED — only the drifted shards' clauses are unfrozen and only
        # their caps get re-spent (global drift still re-solves everything)
        self.scoped = scoped
        self.shard_tv_threshold = shard_tv_threshold
        self.scope_frac = scope_frac
        self._bounds: tuple[int, ...] | None = None
        if pipe.config is not None and pipe.config.partitioned and \
                pipe.data is not None:
            from repro.core.constraint import resolve_constraint
            constraint = resolve_constraint(pipe.problem, pipe.config)
            self._bounds = constraint.bounds
            qdb = pipe.data.query_doc_bits
            # mass[q, k] = |m(q) ∩ D_k|: each query's demand on shard k
            self._shard_mass = np.stack(
                [bitset.np_popcount(qdb[:, lo:hi]).astype(np.float64)
                 for lo, hi in zip(self._bounds, self._bounds[1:])], axis=1)
            self._shard_ref = self._shard_dists(self.accumulator.weights())
        self.detector.rebase(self.accumulator.weights(),
                             self.predicted_coverage(self.accumulator.weights()))

    # -- observability --------------------------------------------------------
    def _eligible(self, tiering) -> np.ndarray:
        """ψ eligibility over the query universe, cached per tiering object."""
        for t, elig in self._elig_cache:
            if t is tiering:
                return elig
        elig = tiering.classify_queries(self.pipe.log.query_bits)
        self._elig_cache = [(tiering, elig)] + self._elig_cache[:3]
        return elig

    def coverage_of(self, tiering, weights: np.ndarray) -> float:
        """Tier-1 eligible mass of `weights` under a given tiering."""
        return float(
            np.asarray(weights, np.float64)[self._eligible(tiering)].sum())

    def predicted_coverage(self, weights: np.ndarray) -> float:
        """Tier-1 eligible mass of `weights` under the DEPLOYED tiering."""
        return self.coverage_of(self.engine.tiering, weights)

    # -- per-shard drift ------------------------------------------------------
    def _shard_dists(self, weights: np.ndarray) -> np.ndarray:
        """Per-shard query distributions [Nq, P]: column k is the traffic a
        shard k machine sees, dist_k(q) ∝ w(q)·|m(q) ∩ D_k|."""
        d = np.asarray(weights, np.float64)[:, None] * self._shard_mass
        s = d.sum(axis=0)
        d = np.divide(d, s[None, :], out=np.full_like(d, 0.0),
                      where=s[None, :] > 0)
        d[:, s <= 0] = 1.0 / d.shape[0]
        return d

    def shard_drift(self, weights: np.ndarray) -> np.ndarray:
        """TV distance per shard between its CURRENT traffic distribution
        and the one at the last refit. Empty when the solve is unpartitioned."""
        if self._bounds is None:
            return np.empty(0)
        cur = self._shard_dists(weights)
        return 0.5 * np.abs(cur - self._shard_ref).sum(axis=0)

    # -- the loop -------------------------------------------------------------
    def _serve_window(self, window: TrafficWindow):
        """Serve + observe one window; returns (report, weights, signal,
        queries) so subclasses can splice work (e.g. ingest) between the
        serve and the refit decision."""
        self.engine.stats.reset()
        queries = [self.queries[i] for i in window.query_ids]
        bsz = self.serve_batch or len(queries) or 1
        for lo in range(0, len(queries), bsz):
            self.engine.serve(queries[lo:lo + bsz])
        wstats = self.engine.stats.snapshot()
        if self.cumulative.full_words_per_query not in \
                (0, wstats.full_words_per_query):
            # corpus grew since the last window: the cumulative saving
            # denominator follows the live width (merge pins equality)
            self.cumulative.full_words_per_query = \
                wstats.full_words_per_query
        self.cumulative.merge(wstats)

        self.accumulator.observe(window.query_ids)
        weights = self.accumulator.weights()
        signal = self.detector.update(wstats, weights,
                                      n_samples=self.accumulator.total())

        report = WindowReport(
            index=window.index, stats=wstats,
            coverage=wstats.tier1_fraction, cost_saving=wstats.cost_saving,
            tv_distance=signal.tv_distance, generation=self.engine.generation,
            shard_tv=tuple(float(t) for t in self.shard_drift(weights)))
        if signal.triggered:
            obs.event("drift_detected", window=window.index,
                      tv=float(signal.tv_distance),
                      coverage=float(wstats.tier1_fraction),
                      will_refit=bool(self.enable_refit))
        return report, weights, signal, queries

    def _refit_window(self, report: WindowReport, weights: np.ndarray,
                      queries: list[tuple[int, ...]]) -> None:
        lam = self.blend_prior
        solve_w = (1.0 - lam) * weights + lam * self._prior
        self._refit(solve_w, weights, report)
        if self.verify_swaps:
            report.parity_ok = self._check_parity(queries)

    def step(self, window: TrafficWindow) -> WindowReport:
        report, weights, signal, queries = self._serve_window(window)
        if signal.triggered and self.enable_refit:
            self._refit_window(report, weights, queries)
        self._observe_window(report)
        return report

    def _observe_window(self, report, serve: WindowReport | None = None
                        ) -> None:
        """Publish window gauges and (when an exporter is installed) one
        JSONL snapshot. `report` is what gets exported; `serve` points at
        its WindowReport leg when they differ (the ingest loop)."""
        s = serve if serve is not None else report
        _W_COV.set(s.coverage)
        _W_SAVING.set(s.cost_saving)
        _W_TV.set(s.tv_distance)
        _GEN.set(s.generation)
        if s.stats.cache_hits:      # fleet serves through a front-end cache
            _W_CACHE.set(round(s.stats.cache_hit_rate, 6))
        if obs.enabled() and obs.get_exporter() is not None:
            obs.export_window(s.index, report=report.to_dict())

    def run(self, simulator: TrafficSimulator) -> StreamReport:
        reports = [self.step(w) for w in simulator.windows()]
        return StreamReport(scenario=simulator.scenario, windows=reports,
                            cumulative=self.cumulative)

    # -- refit ----------------------------------------------------------------
    def _refit(self, solve_w: np.ndarray, raw_w: np.ndarray,
               report: WindowReport) -> None:
        with obs.span("refit", window=report.index):
            self._refit_inner(solve_w, raw_w, report)
        _REFITS.inc(kind=report.refit)
        _REFIT_S.set(round(report.refit_seconds, 4))
        obs.event("refit", window=report.index, mode=report.refit,
                  steps=report.refit_steps, pruned=report.pruned,
                  seconds=round(report.refit_seconds, 4),
                  generation=report.generation,
                  scope=list(report.scope))

    def _refit_inner(self, solve_w: np.ndarray, raw_w: np.ndarray,
                     report: WindowReport) -> None:
        t0 = time.perf_counter()
        prev = self.pipe.result
        deployed_cov = self.predicted_coverage(solve_w)
        kind = "cold"
        if self.warm and prev is not None and prev.state is not None:
            # prune under the NEW weights, then resume from what survives
            state, _, dropped = prune_state(
                self.pipe.problem, prev.state, weights=solve_w,
                min_unique_mass=self.prune_below)
            report.pruned = len(dropped)
            if self._bounds is not None and self.scoped:
                # scope the re-solve: unfreeze ONLY the drifted shards'
                # clauses (a drift everywhere degenerates to a full warm
                # re-solve, which is exactly right)
                tv = self.shard_drift(raw_w)
                drifted = tuple(int(k) for k in
                                np.nonzero(tv > self.shard_tv_threshold)[0])
                if drifted:
                    state, _, unfrozen = prune_partitions(
                        self.pipe.problem, state, self._bounds, drifted,
                        scope_frac=self.scope_frac)
                    report.scope = drifted
                    report.pruned += len(unfrozen)
            self.pipe.refit(solve_w, state=state)
            kind = "warm"
            baseline_cov = self.coverage_of(self._baseline_tiering, solve_w)
            if self.cold_fallback and \
                    self.coverage_of(self.pipe.tiering(), solve_w) + 1e-9 \
                    < max(deployed_cov, baseline_cov):
                # warm path couldn't un-specialize enough: pay for cold
                self.pipe.refit(solve_w, state=None)
                kind = "cold"
                report.pruned = 0          # cold solves don't prune
                report.scope = ()          # ... and aren't scoped
        else:
            self.pipe.refit(solve_w, state=None)
        with obs.span("swap"):
            buf = self.engine.prepare_tiering(self.pipe.tiering())  # off-path
            report.generation = self.engine.swap_tiering(buf)       # atomic
        self.detector.rebase(raw_w, self.predicted_coverage(raw_w))
        if self._bounds is not None:
            self._shard_ref = self._shard_dists(raw_w)
        report.refit = kind
        report.refit_steps = len(self.pipe.result.order)
        report.refit_seconds = time.perf_counter() - t0

    # -- Theorem 3.1 spot check -----------------------------------------------
    def _check_parity(self, queries: list[tuple[int, ...]]) -> bool:
        """Served match sets == single-tier oracle on a query sample."""
        sample = queries[:64]
        got = self.engine.serve(sample)
        want = self.engine.serve_reference(sample)
        return all(np.array_equal(a, b) for a, b in zip(got, want))


def run_stream(pipe, *, scenario: str = "rotate", n_windows: int = 8,
               queries_per_window: int = 512, seed: int = 0,
               strength: float = 1.0, warm: bool = True,
               enable_refit: bool = True, verify_swaps: bool = False,
               engine: TieredEngine | None = None,
               **controller_kw) -> StreamReport:
    """Replay a drift scenario end to end through a RetieringController.

    `engine` accepts anything with the TieredEngine serving surface — in
    particular a `cluster.TieredCluster`, whose `swap_tiering` rolls the
    re-tiering out replica-by-replica instead of one atomic store (the
    controller neither knows nor cares; exactness holds either way).
    """
    sim = TrafficSimulator(pipe.log, scenario, seed=seed, n_windows=n_windows,
                           queries_per_window=queries_per_window,
                           strength=strength)
    ctrl = RetieringController(pipe, engine=engine, warm=warm,
                               enable_refit=enable_refit,
                               verify_swaps=verify_swaps, **controller_kw)
    return ctrl.run(sim)
