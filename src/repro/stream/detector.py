"""Drift detection: decide WHEN the control loop should re-tier.

Two complementary signals, both cheap enough to run every window:

  * serve-quality regression — the windowed Tier-1 eligible fraction
    (`ServeStats.tier1_fraction`) dropping below the coverage the current
    tiering predicted at refit time means the deployed clause set no longer
    matches live traffic;
  * distribution shift — total-variation distance between the accumulator's
    decayed weights and the weights the current tiering was solved against.
    TV bounds the coverage change of ANY fixed clause set (coverage is an
    expectation of a 0/1 function), so a large TV is a leading indicator
    even before quality visibly degrades.

`rebase(weights, coverage)` re-anchors both references after a refit;
`update(stats, weights)` returns a `DriftSignal` each window.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.engine import ServeStats


@dataclasses.dataclass(frozen=True)
class DriftSignal:
    triggered: bool
    reasons: tuple[str, ...]
    tv_distance: float       # TV(current weights, weights at last refit)
    coverage_gap: float      # predicted coverage at refit - windowed coverage
    tv_noise_floor: float = 0.0  # expected TV from sampling noise alone


def tv_distance(p: np.ndarray, q: np.ndarray) -> float:
    """Total-variation distance between two distributions."""
    return float(0.5 * np.abs(np.asarray(p, np.float64)
                              - np.asarray(q, np.float64)).sum())


class DriftDetector:
    """Thresholded windowed drift triggers with refit hysteresis.

    coverage_drop        absolute tolerated drop of windowed tier1_fraction
                         below the coverage predicted at the last refit
    tv_threshold         TV distance that triggers regardless of coverage
                         (on top of the sampling-noise floor, see below)
    noise_scale          multiplier on the estimated TV sampling-noise floor
                         added to tv_threshold; 0 disables the correction
    min_windows_between  hysteresis: windows to wait after a refit
    warmup_windows       windows to observe before the first trigger

    An EMPIRICAL distribution over thousands of queries has a nonzero
    expected TV to its own source purely from sampling: per query,
    E|p̂_q - p_q| ≈ sqrt(2 p_q / (π n)), so the floor is
    0.5 · sqrt(2/(π n)) · Σ_q sqrt(p_q) for n effective samples. Without
    that correction the trigger fires forever on noise under a perfectly
    static workload (callers pass `n_samples`, e.g. the accumulator's
    decayed total).
    """

    def __init__(self, *, coverage_drop: float = 0.05,
                 tv_threshold: float = 0.2, noise_scale: float = 1.0,
                 min_windows_between: int = 1, warmup_windows: int = 1):
        self.coverage_drop = coverage_drop
        self.tv_threshold = tv_threshold
        self.noise_scale = noise_scale
        self.min_windows_between = min_windows_between
        self.warmup_windows = warmup_windows
        self._ref_weights: np.ndarray | None = None
        self._ref_coverage: float | None = None
        self._windows_seen = 0
        self._windows_since_refit = 10 ** 9

    def rebase(self, weights: np.ndarray, coverage: float) -> None:
        """Anchor the references to a freshly deployed tiering."""
        self._ref_weights = np.array(weights, np.float64, copy=True)
        self._ref_coverage = float(coverage)
        self._windows_since_refit = 0

    def update(self, stats: ServeStats, weights: np.ndarray,
               n_samples: float | None = None) -> DriftSignal:
        """Consume one window's serve stats + accumulator weights.

        `n_samples` is the effective sample count behind `weights` (e.g.
        `LogAccumulator.total()`); when given, the TV trigger only counts
        drift above the sampling-noise floor it implies.
        """
        tv = 0.0 if self._ref_weights is None \
            else tv_distance(weights, self._ref_weights)
        gap = 0.0 if self._ref_coverage is None \
            else self._ref_coverage - stats.tier1_fraction
        floor = 0.0
        if n_samples and self._ref_weights is not None and self.noise_scale:
            floor = self.noise_scale * 0.5 * \
                float(np.sqrt(2.0 / (np.pi * n_samples))
                      * np.sqrt(self._ref_weights).sum())
        self._windows_seen += 1
        self._windows_since_refit += 1

        reasons = []
        if tv > self.tv_threshold + floor:
            reasons.append(f"tv={tv:.3f}>{self.tv_threshold}+{floor:.3f}")
        if gap > self.coverage_drop:
            reasons.append(f"coverage_gap={gap:.3f}>{self.coverage_drop}")
        eligible = (self._windows_seen >= self.warmup_windows
                    and self._windows_since_refit >= self.min_windows_between)
        return DriftSignal(triggered=bool(reasons) and eligible,
                           reasons=tuple(reasons), tv_distance=tv,
                           coverage_gap=gap, tv_noise_floor=floor)
