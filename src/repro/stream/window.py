"""Sliding-window log accumulation + warm-start state hygiene.

`LogAccumulator` maintains exponentially-decayed empirical query weights over
the unique-query universe — the online analogue of the offline QueryLog's
`train_weights`. Its `weights()` feed `SCSKProblem.with_weights` (bitset
reuse) and `TieringPipeline.refit`.

`prune_state` is the other half of a cheap re-solve: before warm-starting
from the previous `SolverState`, drop selected clauses whose *unique*
weighted query coverage under the CURRENT distribution has decayed to
nothing. That frees knapsack budget (g) for clauses matching the new traffic
while keeping every still-hot clause — so the warm solve only pays for the
drift delta, not a from-scratch path.

`prune_partitions` scopes a warm re-solve to the doc-space partitions that
actually drifted (shard-aware re-tiering): selected clauses whose document
mass is concentrated in the drifted shards are unfrozen (dropped, freeing
their per-shard budget for the re-solve); every other clause stays in the
warm prefix, so the solver effectively only re-tier the drifted shards.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import bitset
from repro.core.problem import SCSKProblem
from repro.core.state import SolverState


class LogAccumulator:
    """Exponentially-decayed query counts over the unique-query universe.

    `halflife` is measured in windows: after observing h windows of purely
    new traffic, the old traffic contributes half the mass it did. A prior
    (e.g. the offline log's train_weights, scaled by `prior_strength`
    pseudo-observations) keeps early windows from being all sampling noise.
    """

    def __init__(self, n_queries: int, *, halflife: float = 2.0,
                 prior: np.ndarray | None = None,
                 prior_strength: float = 0.0):
        if halflife <= 0:
            raise ValueError("halflife must be positive (windows)")
        self.n_queries = n_queries
        self.decay = 0.5 ** (1.0 / halflife)
        self.counts = np.zeros(n_queries, np.float64)
        if prior is not None and prior_strength > 0:
            p = np.asarray(prior, np.float64)
            if p.shape != (n_queries,):
                raise ValueError(
                    f"prior must have shape ({n_queries},), got {p.shape}")
            self.counts += prior_strength * p / max(p.sum(), 1e-30)
        self.n_windows = 0

    def observe(self, query_ids: np.ndarray) -> None:
        """Fold one window's sampled query ids into the decayed counts."""
        self.counts *= self.decay
        np.add.at(self.counts, np.asarray(query_ids, np.int64), 1.0)
        self.n_windows += 1

    def weights(self) -> np.ndarray:
        """Normalized decayed empirical distribution, f64 [n_queries]."""
        s = self.counts.sum()
        if s <= 0:
            return np.full(self.n_queries, 1.0 / max(1, self.n_queries))
        return self.counts / s

    def total(self) -> float:
        return float(self.counts.sum())


def check_state_width(problem: SCSKProblem, state: SolverState) -> None:
    """Reject a `SolverState` whose doc bitset width doesn't match `problem`.

    Raised instead of silently zero-padding because the pad would be WRONG:
    after `append_docs` + `with_doc_block`, already-selected clauses may
    match the appended documents, so the only exact post-append state is a
    re-derivation (`problem.state_for`) over the grown incidence.
    """
    wd = int(np.asarray(state.covered_d).shape[0])
    if wd != problem.wd:
        raise ValueError(
            f"stale SolverState: covered_d has {wd} words but the problem "
            f"has wd={problem.wd} (corpus appended since the state was "
            "captured?); re-derive it with "
            "problem.state_for(np.nonzero(state.selected)[0])")
    if int(np.asarray(state.selected).shape[0]) != problem.n_clauses:
        raise ValueError(
            f"stale SolverState: {np.asarray(state.selected).shape[0]} "
            f"selection slots vs {problem.n_clauses} clauses")


def prune_state(problem: SCSKProblem, state: SolverState, *,
                min_unique_mass: float = 0.0,
                weights: np.ndarray | None = None,
                ) -> tuple[SolverState, np.ndarray, np.ndarray]:
    """Drop stale clauses from a SolverState; returns (state, kept, dropped).

    A selected clause is stale when the traffic mass it UNIQUELY covers
    (queries no other selected clause matches) under `weights` (default:
    `problem.query_weights`) is below `min_unique_mass`. Passing `weights`
    directly (length `n_queries`) avoids materializing a reweighted problem
    just for the pruning pass. Unique — not standalone — coverage is the
    right criterion: dropping a clause only loses the queries nothing else
    covers. The pruned state is rebuilt exactly (covered bitsets re-OR'd,
    `g_used` recomputed), so a solver can resume from it as if the kept
    clauses were its own selection prefix.

    Everything stays in the packed-bitset domain: the exactly-once query
    mask is two OR/AND accumulator sweeps over the K selected rows, and the
    per-clause unique mass is one fused `f_gains` (bit-matvec) call with
    that mask folded into the weights — no dense [K, n_queries] incidence
    is ever materialized.

    Width contract (repro.ingest): a state captured BEFORE a corpus append
    is stale — its `covered_d` is narrower than the grown `problem.wd`, and
    zero-padding it would under-count g (old clauses can match appended
    docs). Such a state is rejected with a `ValueError` naming both widths;
    re-derive it at the new width with `rebuild_state(problem, kept)`
    (= `problem.state_for`) before warm-starting a post-append refit.
    """
    check_state_width(problem, state)
    selected = np.asarray(state.selected)
    idx = np.nonzero(selected)[0]
    empty = np.empty(0, np.int64)
    if len(idx) == 0 or min_unique_mass <= 0:
        return state, idx.astype(np.int64), empty

    nq = problem.n_queries
    qrows = np.asarray(problem.clause_query_bits)[idx]            # [K, Wq]
    if weights is None:
        wpad = np.asarray(problem.query_weights, np.float32)
    else:
        weights = np.asarray(weights, np.float32)
        if weights.shape != (nq,):
            raise ValueError(
                f"weights must have shape ({nq},), got {weights.shape}")
        wpad = np.zeros(problem.wq * 32, np.float32)
        wpad[:nq] = weights
    seen_once = np.zeros(problem.wq, np.uint32)
    seen_multi = np.zeros(problem.wq, np.uint32)
    for r in qrows:
        seen_multi |= seen_once & r
        seen_once |= r
    once = seen_once & ~seen_multi            # queries covered exactly once
    # f_gains with covered_q = ~once zeroes every weight outside the mask,
    # so row j of the bit-matvec is exactly clause j's unique weighted mass
    unique_mass = np.asarray(problem.f_gains(
        jnp.asarray(~once), rows=jnp.asarray(qrows), weights=jnp.asarray(wpad)))
    drop = unique_mass < min_unique_mass
    if not drop.any():
        return state, idx.astype(np.int64), empty

    kept = idx[~drop].astype(np.int64)
    return rebuild_state(problem, kept), kept, idx[drop].astype(np.int64)


def rebuild_state(problem: SCSKProblem, kept: np.ndarray) -> SolverState:
    """Exact `SolverState` for a clause subset, as if it were a solve prefix
    (covered bitsets re-OR'd, `g_used` recomputed)."""
    return problem.state_for(kept)


def prune_partitions(problem: SCSKProblem, state: SolverState,
                     bounds: tuple[int, ...], parts,
                     *, scope_frac: float = 0.5,
                     ) -> tuple[SolverState, np.ndarray, np.ndarray]:
    """Unfreeze the clauses living in drifted doc partitions.

    Drops every selected clause whose document mass inside the partitions
    `parts` (indices into the word-aligned `bounds` split) is at least
    `scope_frac` of its total mass; returns (state, kept, dropped) like
    `prune_state`. The kept clauses stay a frozen warm prefix, so a re-solve
    from the returned state only spends budget re-tiering the drifted
    shards (plus whatever slack the caps leave elsewhere). Like
    `prune_state`, a stale-width state (pre-append) raises `ValueError`.
    """
    check_state_width(problem, state)
    selected = np.asarray(state.selected)
    idx = np.nonzero(selected)[0].astype(np.int64)
    parts = sorted(set(int(p) for p in parts))
    empty = np.empty(0, np.int64)
    if len(idx) == 0 or not parts:
        return state, idx, empty
    rows = np.asarray(problem.clause_doc_bits)[idx]              # [K, Wd]
    total = bitset.np_popcount(rows).astype(np.float64)
    in_scope = np.zeros(len(idx), np.float64)
    for k in parts:
        lo, hi = bounds[k], bounds[k + 1]
        in_scope += bitset.np_popcount(rows[:, lo:hi])
    drop = in_scope >= scope_frac * np.maximum(total, 1.0)
    if not drop.any():
        return state, idx, empty
    kept = idx[~drop]
    return rebuild_state(problem, kept), kept, idx[drop]
