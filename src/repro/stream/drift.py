"""Nonstationary traffic simulator: named drift scenarios over a QueryLog.

The paper frames tiering as *stochastic* optimization because live traffic
drifts away from any static log (§2.3, Fig. 5). This module turns the
synthetic QueryLog (data/synthetic.py) into a windowed, drifting request
stream: a scenario maps the base distribution p0 over the unique-query
universe to a per-window distribution p_t, and the simulator samples a
seeded query batch from each p_t.

Scenarios (all seeded, fully deterministic given (seed, n_windows)):

  static    p_t = p0 — the control/baseline stream.
  rotate    topic/head rotation: queries are partitioned into K topics and
            window t multiplicatively boosts topic (t mod K).
  burst     spike traffic: on burst windows a tiny random query set seizes
            a large fraction of the mass.
  churn     vocabulary churn: mass moves monotonically from queries seen in
            the training log onto NOVEL queries (train weight zero) — the
            regime where clause tiering must generalize.
  seasonal  gradual interpolation p_t = (1-a_t) p0 + a_t p1 toward a
            head-permuted target, a_t = strength * sin^2(pi t / (T-1)) —
            drifts out and back within one run.

A scenario factory has signature `factory(log, p0, rng, n_windows, strength)
-> (t -> p_t)`; register new ones in `SCENARIOS`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import numpy as np

from repro.data.synthetic import QueryLog


@dataclasses.dataclass(frozen=True)
class TrafficWindow:
    """One window of the stream: sampled batch + the true distribution."""
    index: int
    query_ids: np.ndarray    # int64 [n] ids into log.queries
    probs: np.ndarray        # f64 [Nq] the window's true distribution


def _normalize(p: np.ndarray) -> np.ndarray:
    s = p.sum()
    if s <= 0:
        return np.full_like(p, 1.0 / max(1, len(p)))
    return p / s


def _static(log: QueryLog, p0: np.ndarray, rng: np.random.Generator,
            n_windows: int, strength: float) -> Callable[[int], np.ndarray]:
    return lambda t: p0


def _rotate(log: QueryLog, p0: np.ndarray, rng: np.random.Generator,
            n_windows: int, strength: float) -> Callable[[int], np.ndarray]:
    """K random topics; the hot topic dwells for 3 windows, then rotates.

    Window t boosts topic ((t // 3) mod K) by 1 + 15*strength. The dwell is
    what makes reacting worthwhile: a controller that refits on the first
    window of a topic epoch serves the rest of the epoch well, while a
    per-window flip would always keep it one window behind.
    """
    k, dwell = 4, 3
    topic = rng.integers(0, k, size=len(p0))
    boost = 1.0 + 15.0 * strength

    def probs(t: int) -> np.ndarray:
        p = p0 * np.where(topic == ((t // dwell) % k), boost, 1.0)
        return _normalize(p)
    return probs


def _burst(log: QueryLog, p0: np.ndarray, rng: np.random.Generator,
           n_windows: int, strength: float) -> Callable[[int], np.ndarray]:
    """Recurring 2-window spikes: a ~1% query set takes 0.6*strength of the
    mass on windows t%4 ∈ {1,2} (a fresh set per burst), then vanishes.
    The 2-window persistence is what a reactive controller can exploit."""
    n = len(p0)
    frac = min(0.9, 0.6 * strength)
    sets = [rng.choice(n, size=max(1, n // 100), replace=False)
            for _ in range(n_windows // 4 + 1)]

    def probs(t: int) -> np.ndarray:
        if t % 4 not in (1, 2):
            return p0
        p = p0 * (1.0 - frac)
        spike = np.zeros(n)
        spike[sets[t // 4]] = frac / len(sets[t // 4])
        return _normalize(p + spike)
    return probs


def _churn(log: QueryLog, p0: np.ndarray, rng: np.random.Generator,
           n_windows: int, strength: float) -> Callable[[int], np.ndarray]:
    """Mass migrates from train-seen queries onto novel (train-unseen) ones."""
    novel = np.asarray(log.train_weights) == 0
    if not novel.any() or novel.all():           # degenerate log: no churn
        return lambda t: p0
    p_seen = _normalize(np.where(novel, 0.0, p0))
    p_novel = _normalize(np.where(novel, np.maximum(p0, 1e-12), 0.0))

    def probs(t: int) -> np.ndarray:
        a = min(0.9, 0.8 * strength) * (t / max(1, n_windows - 1))
        return _normalize((1.0 - a) * p_seen + a * p_novel)
    return probs


def _seasonal(log: QueryLog, p0: np.ndarray, rng: np.random.Generator,
              n_windows: int, strength: float) -> Callable[[int], np.ndarray]:
    """Smoothly interpolate toward a head-permuted target and back."""
    head = np.argsort(-p0)[:max(2, len(p0) // 2)]
    p1 = p0.copy()
    p1[head] = p0[head][rng.permutation(len(head))]
    p1 = _normalize(p1)

    def probs(t: int) -> np.ndarray:
        a = min(1.0, strength) * np.sin(np.pi * t / max(1, n_windows - 1)) ** 2
        return _normalize((1.0 - a) * p0 + a * p1)
    return probs


SCENARIOS: dict[str, Callable] = {
    "static": _static,
    "rotate": _rotate,
    "burst": _burst,
    "churn": _churn,
    "seasonal": _seasonal,
}


def list_scenarios() -> list[str]:
    return sorted(SCENARIOS)


class TrafficSimulator:
    """Seeded windowed request stream over a QueryLog's unique queries.

    Two simulators built with identical arguments yield bit-identical
    windows, so a static-tiering baseline and a re-tiering run can be
    compared on exactly the same traffic.
    """

    def __init__(self, log: QueryLog, scenario: str = "rotate", *,
                 seed: int = 0, n_windows: int = 8,
                 queries_per_window: int = 512, strength: float = 1.0,
                 base: str = "test"):
        if scenario not in SCENARIOS:
            raise KeyError(
                f"unknown scenario {scenario!r}; known: {list_scenarios()}")
        if base not in ("test", "train"):
            raise ValueError("base must be 'test' or 'train'")
        self.log = log
        self.scenario = scenario
        self.n_windows = n_windows
        self.queries_per_window = queries_per_window
        p0 = _normalize(np.asarray(
            log.test_weights if base == "test" else log.train_weights,
            np.float64))
        # structure rng (topic/burst/target choices) is independent of the
        # sampling rng so window distributions don't depend on batch size
        self._probs = SCENARIOS[scenario](
            log, p0, np.random.default_rng(seed), n_windows, strength)
        self._seed = seed

    def window_probs(self, t: int) -> np.ndarray:
        """The true query distribution of window t."""
        return self._probs(t)

    def windows(self) -> Iterator[TrafficWindow]:
        rng = np.random.default_rng(self._seed + 1)
        for t in range(self.n_windows):
            p = self.window_probs(t)
            ids = rng.choice(len(p), size=self.queries_per_window, p=p)
            yield TrafficWindow(index=t, query_ids=ids, probs=p)
