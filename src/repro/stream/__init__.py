"""repro.stream — online re-tiering: the tiering lifecycle over live traffic.

The offline pipeline (`repro.api.TieringPipeline`) solves once against a
static log; this package closes the loop for nonstationary traffic:

  * `TrafficSimulator` / `SCENARIOS` — seeded drift scenarios (topic
    rotation, bursts, vocabulary churn, seasonal interpolation) yielding
    query batches per window;
  * `LogAccumulator` — exponentially-decayed empirical query weights, the
    online counterpart of the offline log;
  * `DriftDetector` — windowed coverage-regression + total-variation
    triggers deciding when to re-tier;
  * `prune_state` — drops stale clauses from a `SolverState` so warm
    restarts only pay for the drift delta;
  * `RetieringController` / `run_stream` — the serve → accumulate → detect
    → refit (`TieringPipeline.refit`, warm-started, cold fallback) →
    `TieredEngine.swap_tiering` control loop, Theorem-3.1-exact on every
    window.

Quickstart:

    from repro import api, stream

    pipe = (api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
            .mine(min_support=1e-3).solve("greedy", budget_frac=0.5))
    report = stream.run_stream(pipe, scenario="rotate", n_windows=8)
    print(report.summary())

CLI: `python -m repro.launch.stream --scenario burst --windows 3 --scale tiny`
"""
from repro.stream.controller import (                       # noqa: F401
    RetieringController, StreamReport, WindowReport, run_stream)
from repro.stream.detector import (                         # noqa: F401
    DriftDetector, DriftSignal, tv_distance)
from repro.stream.drift import (                            # noqa: F401
    SCENARIOS, TrafficSimulator, TrafficWindow, list_scenarios)
from repro.stream.window import (                            # noqa: F401
    LogAccumulator, check_state_width, prune_partitions, prune_state,
    rebuild_state)

__all__ = [
    "DriftDetector", "DriftSignal", "LogAccumulator", "RetieringController",
    "SCENARIOS", "StreamReport", "TrafficSimulator", "TrafficWindow",
    "WindowReport", "check_state_width", "list_scenarios",
    "prune_partitions", "prune_state", "rebuild_state", "run_stream",
    "tv_distance",
]
