"""Quickstart: the full paper pipeline through the `repro.api` facade.

  data -> FPGrowth clause mining -> SCSK solve (Opt/Pes greedy) ->
  clause tiering -> two-tier serving with guaranteed-complete match sets.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro import api  # noqa: E402


def main() -> None:
    # 1. corpus + heavy-tailed query log (train/test split)
    pipe = api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
    corpus, log = pipe.corpus, pipe.log
    print(f"corpus: {corpus.n_docs} docs, {log.n_queries} unique queries, "
          f"{log.novel_test_mass():.1%} of test traffic unseen in training")

    # 2. regularized ground set: frequent clauses (paper §3.3, FPGrowth)
    pipe.mine(min_support=1e-3)
    print(f"mined {len(pipe.data.clauses)} clauses with support >= 1e-3")

    # 3. SCSK solve: max query coverage s.t. |Tier-1 docs| <= B (paper §4).
    #    Any registered solver works here — api.list_solvers() names them.
    pipe.solve("optpes", budget_frac=0.5)
    print(f"solved: {pipe.result.summary()}")

    # 4. deployable tiering artifact + coverage report (paper Fig. 5 axes)
    cov = pipe.coverage()
    print(f"coverage: train={cov['train']:.3f} test={cov['test']:.3f} "
          f"tier1={cov['tier1_frac']:.2%} of corpus")
    assert pipe.verify(), "Theorem 3.1 violated?!"

    # 5. serve traffic through the two-tier engine
    engine = pipe.deploy()
    queries = [log.queries[i] for i in np.random.default_rng(0).choice(
        log.n_queries, 256)]
    results = engine.serve(queries)
    ref = engine.serve_reference(queries)
    assert all(np.array_equal(a, b) for a, b in zip(results, ref))
    print(f"served {len(queries)} queries — match sets identical to "
          f"single-tier oracle; {engine.stats.tier1_fraction:.1%} hit Tier 1, "
          f"word-traffic saving {engine.stats.cost_saving:.1%}")

    # 6. budget sweeps warm-start one SolverState instead of re-solving
    #    (paper Fig. 3: greedy finds the whole solution path)
    sweep = pipe.sweep([corpus.n_docs // 4, corpus.n_docs // 2], "greedy")
    print("sweep:  " + "; ".join(
        f"B={int(r.g_final)}: f={r.f_final:.3f}" for r in sweep))


if __name__ == "__main__":
    main()
