"""Quickstart: the full paper pipeline in ~40 lines.

  data -> FPGrowth clause mining -> SCSK solve (Opt/Pes greedy) ->
  clause tiering -> two-tier serving with guaranteed-complete match sets.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import SCSKProblem, optpes_greedy  # noqa: E402
from repro.core.tiering import ClauseTiering  # noqa: E402
from repro.data import incidence, synthetic  # noqa: E402
from repro.serve.engine import TieredEngine  # noqa: E402


def main() -> None:
    # 1. corpus + heavy-tailed query log (train/test split)
    corpus, log = synthetic.make_tiering_dataset(seed=0, scale="tiny")
    print(f"corpus: {corpus.n_docs} docs, {log.n_queries} unique queries, "
          f"{log.novel_test_mass():.1%} of test traffic unseen in training")

    # 2. regularized ground set: frequent clauses (paper §3.3, FPGrowth)
    data = incidence.build_tiering_data(corpus, log, min_support=1e-3)
    print(f"mined {len(data.clauses)} clauses with support >= 1e-3")

    # 3. SCSK solve: max query coverage s.t. |Tier-1 docs| <= B (paper §4)
    problem = SCSKProblem.from_data(data)
    budget = corpus.n_docs // 2
    result = optpes_greedy(problem, budget)
    print(f"solved: {result.summary()}")

    # 4. deployable tiering artifact + coverage report (paper Fig. 5 axes)
    tiering = ClauseTiering.from_selection(data, result.selected)
    cov = tiering.coverage(data)
    print(f"coverage: train={cov['train']:.3f} test={cov['test']:.3f} "
          f"tier1={cov['tier1_frac']:.2%} of corpus")
    assert tiering.verify_correctness(data), "Theorem 3.1 violated?!"

    # 5. serve traffic through the two-tier engine
    engine = TieredEngine(data.postings, tiering, data.n_docs)
    queries = [log.queries[i] for i in np.random.default_rng(0).choice(
        log.n_queries, 256)]
    results = engine.serve(queries)
    ref = engine.serve_reference(queries)
    assert all(np.array_equal(a, b) for a, b in zip(results, ref))
    print(f"served {len(queries)} queries — match sets identical to "
          f"single-tier oracle; {engine.stats.tier1_fraction:.1%} hit Tier 1, "
          f"word-traffic saving {engine.stats.cost_saving:.1%}")


if __name__ == "__main__":
    main()
