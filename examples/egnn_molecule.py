"""EGNN on batched small molecules (the `molecule` shape): train a few steps
on a synthetic E(n)-invariant target and verify rotation invariance of the
prediction — the property EGNN buys architecturally.

Run: PYTHONPATH=src python examples/egnn_molecule.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.models import egnn as G  # noqa: E402
from repro.train.optimizer import OptimizerConfig  # noqa: E402
from repro.train.trainer import make_train_step  # noqa: E402


def make_batch(rng, n_graphs=32, n_nodes=12, n_edges=40, d_feat=8):
    nodes = n_graphs * n_nodes
    feat = rng.standard_normal((nodes, d_feat)).astype(np.float32)
    coords = rng.standard_normal((nodes, 3)).astype(np.float32)
    graph_ids = np.repeat(np.arange(n_graphs), n_nodes)
    src = np.concatenate([rng.integers(0, n_nodes, n_edges) + g * n_nodes
                          for g in range(n_graphs)])
    dst = np.concatenate([rng.integers(0, n_nodes, n_edges) + g * n_nodes
                          for g in range(n_graphs)])
    edges = np.stack([src, dst]).astype(np.int32)
    # invariant target: mean pairwise distance within the graph (per edge avg)
    d = np.linalg.norm(coords[src] - coords[dst], axis=1)
    targets = np.array([d[g * n_edges:(g + 1) * n_edges].mean()
                        for g in range(n_graphs)], np.float32)
    return {"node_feat": jnp.asarray(feat), "coords": jnp.asarray(coords),
            "edges": jnp.asarray(edges), "graph_ids": jnp.asarray(graph_ids),
            "targets": jnp.asarray(targets)}


def main() -> None:
    cfg = G.EGNNConfig(n_layers=3, d_hidden=32, d_feat=8, n_classes=1,
                       task="graph_reg")
    rng = np.random.default_rng(0)
    batch = make_batch(rng)

    init_state, train_step = make_train_step(
        lambda p, b: G.loss_fn(p, b, cfg),
        OptimizerConfig(lr=2e-3, warmup_steps=10, decay_steps=150))
    state = init_state(G.init_params(jax.random.key(0), cfg))
    step = jax.jit(train_step)
    first = None
    for i in range(150):
        state, m = step(state, batch)
        first = first or float(m["loss"])
    print(f"train mse: {first:.4f} -> {float(m['loss']):.4f}")
    assert float(m["loss"]) < first

    # E(3) invariance of predictions under rotation + translation
    theta = 0.7
    q = np.array([[np.cos(theta), -np.sin(theta), 0],
                  [np.sin(theta), np.cos(theta), 0], [0, 0, 1]], np.float32)
    rot = dict(batch)
    rot["coords"] = batch["coords"] @ jnp.asarray(q).T + 3.0
    out1, _ = G.serve_step(state["params"], batch, cfg)
    out2, _ = G.serve_step(state["params"], rot, cfg)
    err = float(jnp.abs(out1 - out2).max())
    print(f"rotation+translation invariance error: {err:.2e}")
    assert err < 1e-3
    print("EGNN example OK")


if __name__ == "__main__":
    main()
