"""End-to-end driver (deliverable b): train a small LM for a few hundred
steps with the production trainer — sharded state, checkpointing, resume.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
The model is a ~10M-param gemma2-style decoder (CPU-tractable); the exact
same code path drives the full assigned configs on a real mesh.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="artifacts/ckpt/train_lm_example")
    args = ap.parse_args()

    from repro.distributed import mesh_context
    from repro.launch import mesh as mesh_lib
    from repro.models import transformer as T
    from repro.train.optimizer import OptimizerConfig
    from repro.train.trainer import DriverConfig, TrainingDriver, \
        make_train_step

    cfg = T.TransformerConfig(
        name="lm-10m", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
        d_head=32, d_ff=1024, vocab_size=4096, local_window=64,
        global_every=2, attn_softcap=50.0, final_softcap=30.0,
        embed_scale=True, dtype="float32")
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")

    # synthetic char-ish data with learnable structure (n-gram sequences)
    rng = np.random.default_rng(0)
    trans = rng.dirichlet(np.ones(64) * 0.05, size=cfg.vocab_size)
    nxt = np.argsort(-trans, axis=1)[:, :64]

    def batches():
        while True:
            toks = np.zeros((args.batch, args.seq), np.int32)
            toks[:, 0] = rng.integers(0, cfg.vocab_size, args.batch)
            for t in range(1, args.seq):
                pick = rng.integers(0, 64, args.batch)
                toks[:, t] = nxt[toks[:, t - 1], pick]
            yield {"tokens": toks, "labels": toks}

    mesh = mesh_lib.make_host_mesh()
    with mesh, mesh_context.use_mesh(mesh):
        init_state, train_step = make_train_step(
            lambda p, b: T.loss_fn(p, b, cfg),
            OptimizerConfig(lr=1e-3, warmup_steps=20,
                            decay_steps=args.steps))
        driver = TrainingDriver(init_state, train_step, DriverConfig(
            ckpt_dir=args.ckpt, ckpt_every=50, max_steps=args.steps))
        state, history = driver.run(
            lambda: T.init_params(jax.random.key(0), cfg), batches())

    print(f"steps run this process: {len(history)}")
    print(f"loss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")
    assert history[-1]["loss"] < history[0]["loss"], "no learning?"
    print("checkpoints in", args.ckpt, "- rerun to resume from step",
          int(state["step"]))


if __name__ == "__main__":
    main()
