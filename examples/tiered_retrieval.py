"""Tiered candidate retrieval: the paper's technique inside the two-tower
serving path (the assigned `two-tower-retrieval` arch x `retrieval_cand`).

Eligible queries score only the Tier-1 slice of the candidate matrix —
~budget_frac of the FLOPs/bytes — and Theorem 3.1 guarantees the top-k over
*matching* items is unchanged. This script measures both.

Run: PYTHONPATH=src python examples/tiered_retrieval.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import bitset  # noqa: E402
from repro.models.tiered_retrieval import (  # noqa: E402
    build_tiered_index, tiered_retrieval_scores)


def main() -> None:
    # offline: build_tiered_index runs the api.TieringPipeline facade
    # (mine -> solve -> tiering); any registered solver name slots in
    index = build_tiered_index(seed=0, scale="tiny", budget_frac=0.5,
                               solver="optpes")
    data = index.data
    n_items = data.n_docs
    print(f"catalog: {n_items} items; Tier-1 = {index.tier1_frac:.1%} "
          f"({len(index.tier1_ids)} items)")

    # candidate embeddings (the two-tower item tower output, precomputed)
    rng = np.random.default_rng(0)
    cand = jnp.asarray(rng.standard_normal((n_items, 64)), jnp.float32)
    tier1_ids = jnp.asarray(index.tier1_ids)

    checked = served_t1 = 0
    flops_saved = 0.0
    for qi in rng.choice(data.n_queries, 300, replace=False):
        q = data.log.queries[qi]
        elig = bool(index.tiering.classify_queries(
            data.log.query_bits[qi:qi + 1])[0])
        match = jnp.asarray(bitset.np_unpack(
            data.query_doc_bits[qi], n_items))
        user = jnp.asarray(rng.standard_normal(64), jnp.float32)
        v, i = tiered_retrieval_scores(user, cand, tier1_ids, elig, match,
                                       k=10)
        # oracle: full-corpus scoring
        vf, iff = tiered_retrieval_scores(user, cand, tier1_ids, False,
                                          match, k=10)
        valid = np.asarray(v) > -np.inf
        np.testing.assert_array_equal(np.asarray(i)[valid],
                                      np.asarray(iff)[valid],
                                      err_msg=str(q))
        checked += 1
        if elig:
            served_t1 += 1
            flops_saved += 1.0 - index.tier1_frac
    print(f"{checked} queries checked: top-k identical to full-corpus "
          f"scoring on every eligible query (Theorem 3.1)")
    print(f"Tier-1 rate: {served_t1 / checked:.1%}; avg candidate-scoring "
          f"FLOP saving: {flops_saved / checked:.1%}")


if __name__ == "__main__":
    main()
