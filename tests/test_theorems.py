"""Hypothesis property tests for the paper's theorems (3.1, 3.3, 3.4, 4.1, 4.2)."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import SCSKProblem, bitset
from repro.data import incidence, synthetic


def _random_instance(seed, n_docs=40, vocab=24, n_queries=60):
    rng = np.random.default_rng(seed)
    corpus = synthetic.make_corpus(rng, vocab_size=vocab, n_docs=n_docs,
                                   doc_len_mean=5.0)
    log = synthetic.make_query_log(rng, corpus, pool_size=n_queries,
                                   n_train=500, n_test=200, max_query_len=3)
    data = incidence.build_tiering_data(corpus, log, min_support=1e-4,
                                        max_clause_len=3, max_clauses=120)
    return data, SCSKProblem.from_data(data)


def _f(problem, sel_idx):
    cq = (bitset.or_rows(problem.clause_query_bits[jnp.asarray(sel_idx)], 0)
          if len(sel_idx) else jnp.zeros(problem.wq, jnp.uint32))
    return float(problem.f_value(cq))


def _g(problem, sel_idx):
    cd = (bitset.or_rows(problem.clause_doc_bits[jnp.asarray(sel_idx)], 0)
          if len(sel_idx) else jnp.zeros(problem.wd, jnp.uint32))
    return float(problem.g_value(cd))


@given(st.integers(0, 10_000))
@settings(max_examples=12, deadline=None)
def test_monotone_submodular_f_and_g(seed):
    """Theorems 3.3 / 3.4: monotonicity and diminishing returns."""
    data, problem = _random_instance(seed)
    c = problem.n_clauses
    if c < 3:
        return
    rng = np.random.default_rng(seed + 1)
    for fn in (_f, _g):
        y = list(rng.choice(c, size=min(4, c - 1), replace=False))
        extra = [j for j in range(c) if j not in y]
        z = y + list(rng.choice(extra, size=min(3, len(extra)), replace=False))
        j = int(rng.choice([i for i in range(c) if i not in z]))
        gain_y = fn(problem, y + [j]) - fn(problem, y)
        gain_z = fn(problem, z + [j]) - fn(problem, z)
        assert gain_y >= -1e-9          # monotone
        assert gain_z >= -1e-9
        assert gain_y >= gain_z - 1e-6  # submodular (Y ⊆ Z)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_theorem_3_1_correctness(seed):
    """Any clause selection yields a correct query classifier."""
    from repro.core.tiering import ClauseTiering
    data, problem = _random_instance(seed)
    rng = np.random.default_rng(seed + 2)
    c = problem.n_clauses
    sel = np.zeros(c, bool)
    sel[rng.choice(c, size=max(1, c // 4), replace=False)] = True
    tiering = ClauseTiering.from_selection(data, sel)
    assert tiering.verify_correctness(data)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_theorem_4_1_lower_bound_update(seed):
    """g̲ updated by eq. (14) stays a valid lower bound along any greedy path."""
    data, problem = _random_instance(seed)
    c = problem.n_clauses
    if c < 4:
        return
    rng = np.random.default_rng(seed + 3)
    covered_d = jnp.zeros(problem.wd, jnp.uint32)
    glow = np.asarray(problem.g_gains(covered_d), np.float64)  # exact at X^0
    order = rng.permutation(c)[:5]
    for j_t in order:
        gg = np.asarray(problem.g_gains(covered_d), np.float64)
        # invariant BEFORE update: glow <= exact gains
        assert np.all(glow <= gg + 1e-6)
        # select j_t, apply (14)
        glow = np.maximum(0.0, glow - gg[j_t])
        covered_d = covered_d | problem.clause_doc_bits[int(j_t)]
    gg = np.asarray(problem.g_gains(covered_d), np.float64)
    assert np.all(glow <= gg + 1e-6)


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_theorem_4_2_refresh_set_contains_argmax(seed):
    """The optimistic/pessimistic refresh set C always contains the exact
    greedy argmax j^(t)."""
    from repro.core.greedy import ratio_of
    data, problem = _random_instance(seed)
    c = problem.n_clauses
    if c < 4:
        return
    rng = np.random.default_rng(seed + 4)
    covered_q, covered_d = problem.empty_state()
    # exact bounds at X^0, then take two arbitrary steps with (14)-updates
    fbar = problem.f_gains(covered_q)
    flow = fbar
    gbar = problem.g_gains(covered_d)
    glow = gbar
    budget = float(problem.n_docs)
    for j_t in rng.permutation(c)[:2]:
        fg = problem.f_gains(covered_q)
        gg = problem.g_gains(covered_d)
        flow = jnp.maximum(0.0, flow - fg[int(j_t)])
        glow = jnp.maximum(0.0, glow - gg[int(j_t)])
        covered_q, covered_d = problem.add_clause(covered_q, covered_d, int(j_t))
    fg = np.asarray(problem.f_gains(covered_q))
    gg = np.asarray(problem.g_gains(covered_d))
    exact_ratio = np.asarray(ratio_of(jnp.asarray(fg), jnp.asarray(gg)))
    feasible = fg > 0
    if not feasible.any():
        return
    j_star = int(np.argmax(np.where(feasible, exact_ratio, -np.inf)))
    opt = np.asarray(ratio_of(fbar, glow))
    pes = np.asarray(ratio_of(flow, gbar))
    in_c = opt >= pes.max()
    assert in_c[j_star]
