"""Two-tier serving engine: end-to-end correctness vs single-tier oracle."""
import numpy as np

from repro.core import SOLVERS
from repro.core.tiering import ClauseTiering
from repro.serve.engine import TieredEngine


def _engine(tiny_data, tiny_problem):
    r = SOLVERS["optpes"](tiny_problem, tiny_data.n_docs // 2)
    tiering = ClauseTiering.from_selection(tiny_data, r.selected)
    return TieredEngine(tiny_data.postings, tiering, tiny_data.n_docs)


def test_served_match_sets_are_complete(tiny_data, tiny_problem):
    engine = _engine(tiny_data, tiny_problem)
    rng = np.random.default_rng(0)
    qidx = rng.choice(tiny_data.n_queries, size=64, replace=False)
    queries = [tiny_data.log.queries[i] for i in qidx]
    got = engine.serve(queries)
    want = engine.serve_reference(queries)
    for q, a, b in zip(queries, got, want):
        np.testing.assert_array_equal(a, b, err_msg=str(q))


def test_engine_routes_and_saves_cost(tiny_data, tiny_problem):
    engine = _engine(tiny_data, tiny_problem)
    queries = [tiny_data.log.queries[i] for i in range(200)]
    engine.serve(queries)
    s = engine.stats
    assert s.n_queries == 200
    assert 0 < s.n_tier1 < 200          # both tiers exercised
    assert s.cost_saving > 0.0          # tiering actually saves traffic


def test_unseen_query_with_known_clause_is_eligible(tiny_data, tiny_problem):
    """The paper's central generalization property, end to end: a query never
    seen in any log is still served by Tier 1 when it contains a selected
    clause."""
    engine = _engine(tiny_data, tiny_problem)
    clause = engine.tiering.clauses[0]
    novel_query = tuple(sorted(set(clause) | {int(c) + 1 for c in clause[:1]}))
    elig = engine.classify([novel_query, (63,)])
    assert elig[0]
    got = engine.serve([novel_query])
    want = engine.serve_reference([novel_query])
    np.testing.assert_array_equal(got[0], want[0])
