"""Two-tier serving engine: end-to-end correctness vs single-tier oracle,
plus the zero-downtime re-tiering surface (swap_tiering, ServeStats
reset/merge) the streaming control loop rides on."""
import numpy as np
import pytest

from repro.core import SOLVERS
from repro.core.tiering import ClauseTiering
from repro.serve.engine import ServeStats, TieredEngine


def _engine(tiny_data, tiny_problem):
    r = SOLVERS["optpes"](tiny_problem, tiny_data.n_docs // 2)
    tiering = ClauseTiering.from_selection(tiny_data, r.selected)
    return TieredEngine(tiny_data.postings, tiering, tiny_data.n_docs)


def test_served_match_sets_are_complete(tiny_data, tiny_problem):
    engine = _engine(tiny_data, tiny_problem)
    rng = np.random.default_rng(0)
    qidx = rng.choice(tiny_data.n_queries, size=64, replace=False)
    queries = [tiny_data.log.queries[i] for i in qidx]
    got = engine.serve(queries)
    want = engine.serve_reference(queries)
    for q, a, b in zip(queries, got, want):
        np.testing.assert_array_equal(a, b, err_msg=str(q))


def test_engine_routes_and_saves_cost(tiny_data, tiny_problem):
    engine = _engine(tiny_data, tiny_problem)
    queries = [tiny_data.log.queries[i] for i in range(200)]
    engine.serve(queries)
    s = engine.stats
    assert s.n_queries == 200
    assert 0 < s.n_tier1 < 200          # both tiers exercised
    assert s.cost_saving > 0.0          # tiering actually saves traffic


def test_swap_tiering_parity_every_generation(tiny_data, tiny_problem):
    """Theorem 3.1 must hold before AND after a hot swap: every eligible
    query's Tier-1 result set equals single-tier matching."""
    engine = _engine(tiny_data, tiny_problem)
    queries = [tiny_data.log.queries[i] for i in range(128)]

    def assert_parity():
        got = engine.serve(queries)
        want = engine.serve_reference(queries)
        for q, a, b in zip(queries, got, want):
            np.testing.assert_array_equal(a, b, err_msg=str(q))

    assert engine.generation == 0
    assert_parity()
    # re-tier to a different (smaller-budget) clause selection and swap
    r2 = SOLVERS["optpes"](tiny_problem, tiny_data.n_docs // 4)
    t2 = ClauseTiering.from_selection(tiny_data, r2.selected)
    buf = engine.prepare_tiering(t2)          # built off the request path
    assert engine.tiering is not t2           # still serving the old gen
    assert engine.swap_tiering(buf) == 1
    assert engine.tiering is t2
    assert_parity()
    # raw-ClauseTiering swap path (prepare happens inside)
    r3 = SOLVERS["greedy"](tiny_problem, tiny_data.n_docs // 2)
    assert engine.swap_tiering(
        ClauseTiering.from_selection(tiny_data, r3.selected)) == 2
    assert_parity()


def test_swap_changes_routing_but_stats_merge(tiny_data, tiny_problem):
    """Per-window stats around a swap must merge into the cumulative total."""
    engine = _engine(tiny_data, tiny_problem)
    queries = [tiny_data.log.queries[i] for i in range(150)]

    engine.stats.reset()
    engine.serve(queries)
    before = engine.stats.snapshot()

    r2 = SOLVERS["optpes"](tiny_problem, tiny_data.n_docs // 4)
    engine.swap_tiering(ClauseTiering.from_selection(tiny_data, r2.selected))
    engine.stats.reset()
    engine.serve(queries)
    after = engine.stats.snapshot()

    # the quarter-budget tiering routes fewer queries to Tier 1
    assert after.n_tier1 < before.n_tier1

    total = ServeStats()
    total.merge(before).merge(after)
    assert total.n_queries == 300
    assert total.n_tier1 == before.n_tier1 + after.n_tier1
    assert total.tier1_words == before.tier1_words + after.tier1_words
    assert total.tier2_words == before.tier2_words + after.tier2_words
    assert total.full_words_per_query == before.full_words_per_query
    assert 0.0 < total.cost_saving < 1.0


def test_stats_reset_and_merge_guard():
    s = ServeStats(n_queries=5, n_tier1=3, tier1_words=10, tier2_words=20,
                   full_words_per_query=7)
    s.reset()
    assert (s.n_queries, s.n_tier1, s.tier1_words, s.tier2_words) == \
        (0, 0, 0, 0)
    assert s.full_words_per_query == 7      # engine constant survives reset
    with pytest.raises(ValueError, match="postings widths"):
        s.merge(ServeStats(full_words_per_query=9))


def test_unseen_query_with_known_clause_is_eligible(tiny_data, tiny_problem):
    """The paper's central generalization property, end to end: a query never
    seen in any log is still served by Tier 1 when it contains a selected
    clause."""
    engine = _engine(tiny_data, tiny_problem)
    clause = engine.tiering.clauses[0]
    novel_query = tuple(sorted(set(clause) | {int(c) + 1 for c in clause[:1]}))
    elig = engine.classify([novel_query, (63,)])
    assert elig[0]
    got = engine.serve([novel_query])
    want = engine.serve_reference([novel_query])
    np.testing.assert_array_equal(got[0], want[0])
