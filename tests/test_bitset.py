import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import bitset  # noqa: E402


@given(st.integers(0, 2**32 - 1), st.integers(1, 200))
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    bits = rng.random(n) < 0.3
    packed = bitset.np_pack(bits)
    assert packed.shape == (bitset.n_words(n),)
    np.testing.assert_array_equal(bitset.np_unpack(packed, n), bits)
    # jnp path agrees with numpy path
    jpacked = np.asarray(bitset.pack(jnp.asarray(bits)))
    np.testing.assert_array_equal(jpacked, packed)
    np.testing.assert_array_equal(
        np.asarray(bitset.unpack(jnp.asarray(packed), n)), bits)


@given(st.integers(0, 2**32 - 1), st.integers(1, 300))
@settings(max_examples=30, deadline=None)
def test_popcount(seed, n):
    rng = np.random.default_rng(seed)
    bits = rng.random(n) < 0.5
    packed = bitset.np_pack(bits)
    assert bitset.np_popcount(packed) == bits.sum()
    assert int(bitset.popcount(jnp.asarray(packed))) == bits.sum()


def test_from_indices_and_to_indices():
    idx = np.array([0, 3, 31, 32, 64, 64, 90])  # duplicate on purpose
    out = np.asarray(bitset.from_indices(jnp.asarray(idx), 96))
    expected = bitset.np_from_indices(idx, 96)
    np.testing.assert_array_equal(out, expected)
    np.testing.assert_array_equal(
        bitset.np_to_indices(expected, 96), np.unique(idx))


def test_from_indices_with_validity_mask():
    idx = jnp.asarray([5, 17, 40, 0, 0])
    valid = jnp.asarray([True, True, True, False, False])
    out = np.asarray(bitset.from_indices(idx, 64, valid=valid))
    expected = bitset.np_from_indices(np.array([5, 17, 40]), 64)
    np.testing.assert_array_equal(out, expected)


def test_count_and_not():
    rng = np.random.default_rng(0)
    a = rng.random((8, 130)) < 0.4
    m = rng.random(130) < 0.5
    got = np.asarray(bitset.count_and_not(
        jnp.asarray(bitset.np_pack(a)), jnp.asarray(bitset.np_pack(m))))
    np.testing.assert_array_equal(got, (a & ~m).sum(axis=1))


def test_is_subset():
    a = bitset.np_pack(np.array([1, 0, 1, 0, 0, 0], bool))
    b = bitset.np_pack(np.array([1, 1, 1, 0, 1, 0], bool))
    assert bool(bitset.is_subset(jnp.asarray(a), jnp.asarray(b)))
    assert not bool(bitset.is_subset(jnp.asarray(b), jnp.asarray(a)))


@pytest.mark.parametrize("n", [1, 31, 32, 33, 64, 100])
def test_or_rows(n):
    rng = np.random.default_rng(n)
    rows = rng.random((5, n)) < 0.3
    packed = jnp.asarray(bitset.np_pack(rows))
    got = np.asarray(bitset.or_rows(packed, axis=0))
    np.testing.assert_array_equal(got, bitset.np_pack(rows.any(axis=0)))
