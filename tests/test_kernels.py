"""Pallas kernel sweeps: interpret-mode kernel vs pure-jnp oracle.

Every kernel is swept over shapes (incl. non-tile-multiple edges) and the
supported dtypes, asserting allclose against kernels/ref.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitset
from repro.kernels import fused_match as fm
from repro.kernels import ops, ref
from repro.kernels.bit_matvec import bit_matvec
from repro.kernels.clause_match import clause_match
from repro.kernels.coverage_gain import coverage_gain
from repro.kernels.partition_gain import partition_gain
from repro.kernels.sparse_gain import sparse_gain

SHAPES_CW = [(1, 1), (3, 2), (8, 4), (130, 5), (64, 33), (300, 17)]


def _rand_bits(rng, c, w):
    return rng.integers(0, 2**32, size=(c, w), dtype=np.uint32)


@pytest.mark.parametrize("c,w", SHAPES_CW)
@pytest.mark.parametrize("r", [1, 3])
def test_bit_matvec_interpret_vs_ref(c, w, r):
    rng = np.random.default_rng(c * 100 + w + r)
    a = jnp.asarray(_rand_bits(rng, c, w))
    x = jnp.asarray(rng.standard_normal((w * 32, r)), jnp.float32)
    got = bit_matvec(a, x, block_c=32, block_w=8, interpret=True)
    want = ref.bit_matvec(a, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("c,w", SHAPES_CW)
def test_coverage_gain_interpret_vs_ref(c, w):
    rng = np.random.default_rng(c * 7 + w)
    a = jnp.asarray(_rand_bits(rng, c, w))
    mask = jnp.asarray(_rand_bits(rng, 1, w)[0])
    got = coverage_gain(a, mask, block_c=16, block_w=8, interpret=True)
    want = ref.coverage_gain(a, mask)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("c,m,universe", [(1, 4, 64), (5, 7, 100),
                                          (33, 40, 2048), (128, 65, 512)])
def test_sparse_gain_interpret_vs_ref(c, m, universe):
    rng = np.random.default_rng(c + m)
    ids = rng.integers(0, universe, size=(c, m)).astype(np.int32)
    ids[rng.random((c, m)) < 0.3] = -1        # padding
    covered = rng.random(universe) < 0.5
    mask = jnp.asarray(bitset.np_pack(covered))
    got = sparse_gain(jnp.asarray(ids), mask, block_c=8, block_m=16,
                      interpret=True)
    want = ref.sparse_gain(jnp.asarray(ids), mask)
    np.testing.assert_array_equal(got, want)


def test_sparse_gain_agrees_with_dense_path():
    """The production sparse path computes the same gains as the dense
    bitset path for identical match sets."""
    rng = np.random.default_rng(0)
    universe = 300
    rows = rng.random((20, universe)) < 0.05
    covered = rng.random(universe) < 0.4
    dense = ref.coverage_gain(jnp.asarray(bitset.np_pack(rows)),
                              jnp.asarray(bitset.np_pack(covered)))
    ids = np.full((20, rows.sum(axis=1).max()), -1, np.int32)
    for i, r in enumerate(rows):
        nz = np.nonzero(r)[0]
        ids[i, :len(nz)] = nz
    sparse = ref.sparse_gain(jnp.asarray(ids),
                             jnp.asarray(bitset.np_pack(covered)))
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sparse))


@pytest.mark.parametrize("b,k,wv", [(1, 1, 1), (7, 3, 2), (65, 17, 3),
                                    (130, 70, 5), (16, 1, 9)])
def test_clause_match_interpret_vs_ref(b, k, wv):
    rng = np.random.default_rng(b * 31 + k * 7 + wv)
    # sparse clauses so subset hits actually occur
    q = jnp.asarray(_rand_bits(rng, b, wv))
    c = jnp.asarray(bitset.np_pack(rng.random((k, wv * 32)) < 0.05))
    got = clause_match(q, c, block_b=16, block_k=8, interpret=True)
    want = ref.clause_match(q, c)
    np.testing.assert_array_equal(got, want)


def test_clause_match_padded_clause_rows_never_match():
    """Zero-padded clause rows are the empty clause (⊆ everything); the
    kernel must mask them or every query would classify eligible."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(_rand_bits(rng, 20, 2))
    # one impossible clause: block_k=8 forces 7 padded rows in its block
    c = jnp.asarray(bitset.np_pack(np.ones((1, 64), bool)))
    got = clause_match(q, c, block_b=8, block_k=8, interpret=True)
    assert not np.asarray(got).any()


def test_clause_match_empty_inputs_dispatch():
    q = jnp.zeros((5, 2), jnp.uint32)
    c = jnp.zeros((0, 2), jnp.uint32)
    assert not np.asarray(ops.clause_match(q, c)).any()
    assert ops.clause_match(jnp.zeros((0, 2), jnp.uint32),
                            jnp.ones((3, 2), jnp.uint32)).shape == (0,)


def test_block_dim_helper():
    """Shared pad-to-block/grid arithmetic used by every kernel wrapper."""
    assert ops.block_dim(300, 128) == (128, 84, 3)
    assert ops.block_dim(5, 128) == (5, 0, 1)       # clamped to extent
    assert ops.block_dim(128, 128) == (128, 0, 1)
    b, pad, n = ops.block_dim(17, 8)
    assert (17 + pad) % b == 0 and n * b == 17 + pad


def test_ops_dispatch_consistency():
    """xla / interpret backends agree through the ops layer."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(_rand_bits(rng, 65, 9))
    x = jnp.asarray(rng.standard_normal((9 * 32, 1)), jnp.float32)
    mask = jnp.asarray(_rand_bits(rng, 1, 9)[0])
    np.testing.assert_allclose(
        ops.bit_matvec(a, x, backend="xla"),
        ops.bit_matvec(a, x, backend="interpret"), rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(
        ops.coverage_gain(a, mask, backend="xla"),
        ops.coverage_gain(a, mask, backend="interpret"))
    q = jnp.asarray(_rand_bits(rng, 40, 9))
    c = jnp.asarray(bitset.np_pack(np.random.default_rng(2)
                                   .random((13, 9 * 32)) < 0.05))
    np.testing.assert_array_equal(
        ops.clause_match(q, c, backend="xla"),
        ops.clause_match(q, c, backend="interpret"))


# -- odd-shape parity sweep ----------------------------------------------------
# every packed-bit kernel at shapes that are NOT multiples of the block
# sizes, with deliberately awkward (non-pow2) blocks — the pad/mask logic of
# the double-buffered streaming kernels is what this pins vs kernels/ref.py

ODD_SHAPES = [(13, 3), (97, 7), (201, 11)]            # (C or B/K axis, words)
ODD_BLOCKS = [(8, 3), (24, 5), (56, 17)]


@pytest.mark.parametrize("c,w", ODD_SHAPES)
@pytest.mark.parametrize("bc,bw", ODD_BLOCKS)
def test_odd_shape_parity_sweep(c, w, bc, bw):
    rng = np.random.default_rng(c * 1000 + w * 10 + bc + bw)
    a = jnp.asarray(_rand_bits(rng, c, w))
    x = jnp.asarray(rng.standard_normal((w * 32, 2)), jnp.float32)
    mask = jnp.asarray(_rand_bits(rng, 1, w)[0])
    q = jnp.asarray(_rand_bits(rng, c, w))
    cl = jnp.asarray(bitset.np_pack(rng.random((max(1, c // 3), w * 32)) < 0.04))
    bounds = tuple(int(v) for v in np.linspace(0, w, min(w, 3) + 1).astype(int))

    np.testing.assert_allclose(
        bit_matvec(a, x, block_c=bc, block_w=bw, interpret=True),
        ref.bit_matvec(a, x), rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(
        coverage_gain(a, mask, block_c=bc, block_w=bw, interpret=True),
        ref.coverage_gain(a, mask))
    np.testing.assert_array_equal(
        clause_match(q, cl, block_b=bc, block_k=bw, interpret=True),
        ref.clause_match(q, cl))
    np.testing.assert_array_equal(
        partition_gain(a, mask, bounds, block_c=bc, block_w=bw,
                       interpret=True),
        ops._partition_gain_xla(a, mask, bounds))


@pytest.mark.parametrize("strategy", ["plain", "scan", "gemm"])
@pytest.mark.parametrize("b,k,wv", [(7, 3, 2), (65, 17, 3), (130, 70, 5)])
def test_clause_match_xla_strategies_exact(strategy, b, k, wv):
    """Every autotunable host decomposition is integer-exact vs the ref."""
    rng = np.random.default_rng(b * 31 + k * 7 + wv)
    q = jnp.asarray(_rand_bits(rng, b, wv))
    c = jnp.asarray(bitset.np_pack(rng.random((k, wv * 32)) < 0.05))
    got = ops._clause_match_xla(q, c, strategy=strategy, chunk_b=16)
    np.testing.assert_array_equal(got, ref.clause_match(q, c))


@pytest.mark.parametrize("strategy", ["scan", "unroll", "lut"])
@pytest.mark.parametrize("c,w,r", [(13, 3, 1), (64, 33, 2), (300, 17, 4)])
def test_bit_matvec_xla_strategies_allclose(strategy, c, w, r):
    rng = np.random.default_rng(c + w + r)
    a = jnp.asarray(_rand_bits(rng, c, w))
    x = jnp.asarray(rng.standard_normal((w * 32, r)), jnp.float32)
    got = ops._bit_matvec_xla(a, x, strategy=strategy, chunk_w=5)
    np.testing.assert_allclose(got, ref.bit_matvec(a, x),
                               rtol=1e-5, atol=1e-4)


# -- fused classify + tier-selected AND-match ----------------------------------

def _fused_case(seed, b=19, l=4, v=37, w=5, wv=3, k=6):
    rng = np.random.default_rng(seed)
    t1 = rng.integers(0, 2**32, size=(v, w), dtype=np.uint32)
    t2 = t1 | rng.integers(0, 2**32, size=(v, w), dtype=np.uint32)
    toks = rng.integers(-1, v, size=(b, l)).astype(np.int32)
    q = _rand_bits(rng, b, wv)
    cl = bitset.np_pack(rng.random((k, wv * 32)) < 0.1)
    cl[: k // 2] &= q[: k // 2]           # force some eligible queries
    return tuple(jnp.asarray(z) for z in (q, cl, toks, t1, t2))


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_fused_match_equals_two_step(backend):
    """fused_match == clause_match + per-query tier pick + match_batch."""
    from repro.serve import matching
    q, cl, toks, t1, t2 = _fused_case(0)
    match, elig = ops.fused_match(q, cl, toks, t1, t2, backend=backend)
    want_elig = np.asarray(ref.clause_match(q, cl))
    assert want_elig.any() and not want_elig.all()    # both tiers exercised
    m1 = np.asarray(matching.match_batch(t1, toks))
    m2 = np.asarray(matching.match_batch(t2, toks))
    np.testing.assert_array_equal(np.asarray(elig), want_elig)
    np.testing.assert_array_equal(
        np.asarray(match), np.where(want_elig[:, None], m1, m2))


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_fused_match_empty_clause_set_routes_tier2(backend):
    q, _, toks, t1, t2 = _fused_case(1)
    from repro.serve import matching
    match, elig = ops.fused_match(
        q, jnp.zeros((0, q.shape[1]), jnp.uint32), toks, t1, t2,
        backend=backend)
    assert not np.asarray(elig).any()
    np.testing.assert_array_equal(
        np.asarray(match), np.asarray(matching.match_batch(t2, toks)))


def test_bit_matvec_weighted_gain_semantics():
    """bit_matvec(A, w*(1-covered)) == weighted uncovered count per row."""
    rng = np.random.default_rng(3)
    n = 100
    rows = rng.random((12, n)) < 0.2
    covered = rng.random(n) < 0.5
    w = rng.random(n).astype(np.float32)
    a = jnp.asarray(bitset.np_pack(rows))
    wq = a.shape[1] * 32
    x = np.zeros(wq, np.float32)
    x[:n] = w * ~covered
    got = np.asarray(ops.bit_matvec(a, jnp.asarray(x)[:, None], backend="xla"))[:, 0]
    want = (rows & ~covered) @ w
    np.testing.assert_allclose(got, want, rtol=1e-5)
