"""Pallas kernel sweeps: interpret-mode kernel vs pure-jnp oracle.

Every kernel is swept over shapes (incl. non-tile-multiple edges) and the
supported dtypes, asserting allclose against kernels/ref.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitset
from repro.kernels import ops, ref
from repro.kernels.bit_matvec import bit_matvec
from repro.kernels.clause_match import clause_match
from repro.kernels.coverage_gain import coverage_gain
from repro.kernels.sparse_gain import sparse_gain

SHAPES_CW = [(1, 1), (3, 2), (8, 4), (130, 5), (64, 33), (300, 17)]


def _rand_bits(rng, c, w):
    return rng.integers(0, 2**32, size=(c, w), dtype=np.uint32)


@pytest.mark.parametrize("c,w", SHAPES_CW)
@pytest.mark.parametrize("r", [1, 3])
def test_bit_matvec_interpret_vs_ref(c, w, r):
    rng = np.random.default_rng(c * 100 + w + r)
    a = jnp.asarray(_rand_bits(rng, c, w))
    x = jnp.asarray(rng.standard_normal((w * 32, r)), jnp.float32)
    got = bit_matvec(a, x, block_c=32, block_w=8, interpret=True)
    want = ref.bit_matvec(a, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("c,w", SHAPES_CW)
def test_coverage_gain_interpret_vs_ref(c, w):
    rng = np.random.default_rng(c * 7 + w)
    a = jnp.asarray(_rand_bits(rng, c, w))
    mask = jnp.asarray(_rand_bits(rng, 1, w)[0])
    got = coverage_gain(a, mask, block_c=16, block_w=8, interpret=True)
    want = ref.coverage_gain(a, mask)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("c,m,universe", [(1, 4, 64), (5, 7, 100),
                                          (33, 40, 2048), (128, 65, 512)])
def test_sparse_gain_interpret_vs_ref(c, m, universe):
    rng = np.random.default_rng(c + m)
    ids = rng.integers(0, universe, size=(c, m)).astype(np.int32)
    ids[rng.random((c, m)) < 0.3] = -1        # padding
    covered = rng.random(universe) < 0.5
    mask = jnp.asarray(bitset.np_pack(covered))
    got = sparse_gain(jnp.asarray(ids), mask, block_c=8, block_m=16,
                      interpret=True)
    want = ref.sparse_gain(jnp.asarray(ids), mask)
    np.testing.assert_array_equal(got, want)


def test_sparse_gain_agrees_with_dense_path():
    """The production sparse path computes the same gains as the dense
    bitset path for identical match sets."""
    rng = np.random.default_rng(0)
    universe = 300
    rows = rng.random((20, universe)) < 0.05
    covered = rng.random(universe) < 0.4
    dense = ref.coverage_gain(jnp.asarray(bitset.np_pack(rows)),
                              jnp.asarray(bitset.np_pack(covered)))
    ids = np.full((20, rows.sum(axis=1).max()), -1, np.int32)
    for i, r in enumerate(rows):
        nz = np.nonzero(r)[0]
        ids[i, :len(nz)] = nz
    sparse = ref.sparse_gain(jnp.asarray(ids),
                             jnp.asarray(bitset.np_pack(covered)))
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sparse))


@pytest.mark.parametrize("b,k,wv", [(1, 1, 1), (7, 3, 2), (65, 17, 3),
                                    (130, 70, 5), (16, 1, 9)])
def test_clause_match_interpret_vs_ref(b, k, wv):
    rng = np.random.default_rng(b * 31 + k * 7 + wv)
    # sparse clauses so subset hits actually occur
    q = jnp.asarray(_rand_bits(rng, b, wv))
    c = jnp.asarray(bitset.np_pack(rng.random((k, wv * 32)) < 0.05))
    got = clause_match(q, c, block_b=16, block_k=8, interpret=True)
    want = ref.clause_match(q, c)
    np.testing.assert_array_equal(got, want)


def test_clause_match_padded_clause_rows_never_match():
    """Zero-padded clause rows are the empty clause (⊆ everything); the
    kernel must mask them or every query would classify eligible."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(_rand_bits(rng, 20, 2))
    # one impossible clause: block_k=8 forces 7 padded rows in its block
    c = jnp.asarray(bitset.np_pack(np.ones((1, 64), bool)))
    got = clause_match(q, c, block_b=8, block_k=8, interpret=True)
    assert not np.asarray(got).any()


def test_clause_match_empty_inputs_dispatch():
    q = jnp.zeros((5, 2), jnp.uint32)
    c = jnp.zeros((0, 2), jnp.uint32)
    assert not np.asarray(ops.clause_match(q, c)).any()
    assert ops.clause_match(jnp.zeros((0, 2), jnp.uint32),
                            jnp.ones((3, 2), jnp.uint32)).shape == (0,)


def test_block_dim_helper():
    """Shared pad-to-block/grid arithmetic used by every kernel wrapper."""
    assert ops.block_dim(300, 128) == (128, 84, 3)
    assert ops.block_dim(5, 128) == (5, 0, 1)       # clamped to extent
    assert ops.block_dim(128, 128) == (128, 0, 1)
    b, pad, n = ops.block_dim(17, 8)
    assert (17 + pad) % b == 0 and n * b == 17 + pad


def test_ops_dispatch_consistency():
    """xla / interpret backends agree through the ops layer."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(_rand_bits(rng, 65, 9))
    x = jnp.asarray(rng.standard_normal((9 * 32, 1)), jnp.float32)
    mask = jnp.asarray(_rand_bits(rng, 1, 9)[0])
    np.testing.assert_allclose(
        ops.bit_matvec(a, x, backend="xla"),
        ops.bit_matvec(a, x, backend="interpret"), rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(
        ops.coverage_gain(a, mask, backend="xla"),
        ops.coverage_gain(a, mask, backend="interpret"))
    q = jnp.asarray(_rand_bits(rng, 40, 9))
    c = jnp.asarray(bitset.np_pack(np.random.default_rng(2)
                                   .random((13, 9 * 32)) < 0.05))
    np.testing.assert_array_equal(
        ops.clause_match(q, c, backend="xla"),
        ops.clause_match(q, c, backend="interpret"))


def test_bit_matvec_weighted_gain_semantics():
    """bit_matvec(A, w*(1-covered)) == weighted uncovered count per row."""
    rng = np.random.default_rng(3)
    n = 100
    rows = rng.random((12, n)) < 0.2
    covered = rng.random(n) < 0.5
    w = rng.random(n).astype(np.float32)
    a = jnp.asarray(bitset.np_pack(rows))
    wq = a.shape[1] * 32
    x = np.zeros(wq, np.float32)
    x[:n] = w * ~covered
    got = np.asarray(ops.bit_matvec(a, jnp.asarray(x)[:, None], backend="xla"))[:, 0]
    want = (rows & ~covered) @ w
    np.testing.assert_allclose(got, want, rtol=1e-5)
