"""repro.stream: drift simulator, accumulator, reweighting, re-tiering loop.

The acceptance spine: on the seeded topic-rotation scenario at tiny scale,
the drift-aware controller must (a) beat the static-tiering baseline on mean
windowed Tier-1 coverage, (b) actually reuse the prior SolverState (warm
refit step counts < a cold solve's), and (c) keep Theorem-3.1 parity across
every hot swap.
"""
import dataclasses

import numpy as np
import pytest

from repro import api, stream


@pytest.fixture(scope="module")
def pipe_factory(tiny_data):
    def fresh():
        return (api.TieringPipeline.from_data(tiny_data)
                .solve("greedy", budget_frac=0.5))
    return fresh


# -- SCSKProblem.with_weights -------------------------------------------------

def _drifted_weights(log, seed=7):
    rng = np.random.default_rng(seed)
    w = np.asarray(log.train_weights) * rng.uniform(0.1, 4.0, log.n_queries)
    return w / w.sum()


def test_with_weights_matches_fresh_problem(tiny_data, tiny_problem):
    """Bitset reuse is a pure optimization: solving a reweighted problem must
    equal solving a problem freshly built with the same weights."""
    from repro.core.problem import SCSKProblem
    w = _drifted_weights(tiny_data.log)
    fresh_data = dataclasses.replace(
        tiny_data, log=dataclasses.replace(tiny_data.log, train_weights=w))
    fresh = SCSKProblem.from_data(fresh_data)
    rewt = tiny_problem.with_weights(w)

    np.testing.assert_array_equal(np.asarray(rewt.query_weights),
                                  np.asarray(fresh.query_weights))
    cfg = api.SolveConfig(budget=float(tiny_data.n_docs // 2))
    ra, rb = api.solve(rewt, cfg), api.solve(fresh, cfg)
    assert ra.order == rb.order
    np.testing.assert_array_equal(ra.selected, rb.selected)
    assert ra.f_final == pytest.approx(rb.f_final)


def test_with_weights_shares_bitsets_and_leaves_original(tiny_problem):
    before = np.asarray(tiny_problem.query_weights).copy()
    w = np.zeros(tiny_problem.n_queries, np.float32)
    w[0] = 1.0
    rewt = tiny_problem.with_weights(w)
    assert rewt.clause_query_bits is tiny_problem.clause_query_bits
    assert rewt.clause_doc_bits is tiny_problem.clause_doc_bits
    assert rewt.test_weights is tiny_problem.test_weights
    assert float(np.asarray(rewt.query_weights).sum()) == pytest.approx(1.0)
    # the original problem is untouched (frozen dataclass copy)
    np.testing.assert_array_equal(np.asarray(tiny_problem.query_weights),
                                  before)


def test_with_weights_rejects_bad_shape(tiny_problem):
    with pytest.raises(ValueError, match="shape"):
        tiny_problem.with_weights(np.ones(tiny_problem.n_queries + 3))


# -- traffic simulator --------------------------------------------------------

def test_simulator_is_deterministic(tiny_data):
    log = tiny_data.log
    mk = lambda s: list(stream.TrafficSimulator(
        log, "rotate", seed=s, n_windows=4, queries_per_window=64).windows())
    a, b, c = mk(0), mk(0), mk(1)
    for wa, wb in zip(a, b):
        np.testing.assert_array_equal(wa.query_ids, wb.query_ids)
        np.testing.assert_array_equal(wa.probs, wb.probs)
    assert any(not np.array_equal(wa.query_ids, wc.query_ids)
               for wa, wc in zip(a, c))


@pytest.mark.parametrize("scenario", stream.list_scenarios())
def test_scenarios_yield_valid_drifting_distributions(tiny_data, scenario):
    log = tiny_data.log
    sim = stream.TrafficSimulator(log, scenario, seed=0, n_windows=6,
                                  queries_per_window=32)
    p0 = sim.window_probs(0)
    drifted = False
    for w in sim.windows():
        assert w.probs.shape == (log.n_queries,)
        assert (w.probs >= 0).all()
        assert w.probs.sum() == pytest.approx(1.0)
        assert w.query_ids.shape == (32,)
        drifted |= not np.allclose(w.probs, p0)
    assert drifted == (scenario != "static")


def test_churn_moves_mass_to_novel_queries(tiny_data):
    log = tiny_data.log
    sim = stream.TrafficSimulator(log, "churn", seed=0, n_windows=6)
    novel = np.asarray(log.train_weights) == 0
    first = sim.window_probs(0)[novel].sum()
    last = sim.window_probs(5)[novel].sum()
    assert last > first + 0.1


def test_unknown_scenario_raises(tiny_data):
    with pytest.raises(KeyError, match="unknown scenario"):
        stream.TrafficSimulator(tiny_data.log, "nope")


# -- log accumulator ----------------------------------------------------------

def test_accumulator_tracks_and_decays():
    acc = stream.LogAccumulator(4, halflife=1.0)
    acc.observe(np.array([0, 0, 0, 1]))
    assert acc.weights()[0] == pytest.approx(0.75)
    for _ in range(5):
        acc.observe(np.array([2, 2, 2, 2]))
    w = acc.weights()
    assert w[2] > 0.9                      # new traffic dominates
    assert w[0] < 0.05                     # old traffic decayed away
    assert w.sum() == pytest.approx(1.0)


def test_accumulator_prior_fades():
    prior = np.array([1.0, 0.0, 0.0])
    acc = stream.LogAccumulator(3, halflife=1.0, prior=prior,
                                prior_strength=4.0)
    assert acc.weights()[0] == pytest.approx(1.0)   # prior only
    for _ in range(6):
        acc.observe(np.array([1] * 8))
    assert acc.weights()[1] > 0.9


# -- prune_state --------------------------------------------------------------

def test_prune_state_noop_and_full(tiny_problem):
    cfg = api.SolveConfig(budget=float(tiny_problem.n_docs // 2))
    r = api.solve(tiny_problem, cfg)
    same, kept, dropped = stream.prune_state(tiny_problem, r.state,
                                             min_unique_mass=0.0)
    assert same is r.state and len(dropped) == 0
    empty, kept2, dropped2 = stream.prune_state(tiny_problem, r.state,
                                                min_unique_mass=2.0)
    assert len(kept2) == 0 and len(dropped2) == len(kept)
    assert int(empty.selected.sum()) == 0
    assert float(empty.g_used) == 0.0


def test_prune_state_rebuilds_consistent_state(tiny_problem, tiny_data):
    from repro.core import bitset
    cfg = api.SolveConfig(budget=float(tiny_problem.n_docs // 2))
    r = api.solve(tiny_problem, cfg)
    rewt = tiny_problem.with_weights(_drifted_weights(tiny_data.log))
    state, kept, dropped = stream.prune_state(rewt, r.state,
                                              min_unique_mass=5e-3)
    assert len(kept) + len(dropped) == len(r.order)
    assert int(state.selected.sum()) == len(kept) == int(state.step)
    # g_used must equal the popcount of the rebuilt doc bitset
    assert float(state.g_used) == float(
        bitset.np_popcount(np.asarray(state.covered_d)).sum())
    # resuming a solver from the pruned state must stay within budget
    r2 = api.solve(rewt, cfg, state=state)
    assert r2.g_final <= cfg.budget


# -- refit + warm starts ------------------------------------------------------

def test_refit_warm_start_does_fewer_steps(pipe_factory, tiny_data):
    drifted = stream.TrafficSimulator(
        tiny_data.log, "rotate", seed=0, n_windows=12).window_probs(3)

    cold_pipe = pipe_factory().refit(drifted, state=None)
    cold_steps = len(cold_pipe.result.order)

    warm_pipe = pipe_factory()
    prev = warm_pipe.result
    state, kept, _ = stream.prune_state(warm_pipe.problem, prev.state,
                                        weights=drifted,
                                        min_unique_mass=2e-3)
    # weights= kwarg ≡ pruning a reweighted problem (no rebuild needed)
    via_problem, _, _ = stream.prune_state(
        warm_pipe.problem.with_weights(drifted), prev.state,
        min_unique_mass=2e-3)
    np.testing.assert_array_equal(np.asarray(state.selected),
                                  np.asarray(via_problem.selected))
    warm_pipe.refit(drifted, state=state)
    warm_steps = len(warm_pipe.result.order)

    assert 0 < warm_steps < cold_steps      # the prior state was reused
    # warm keeps every surviving clause of the previous solve
    assert np.all(np.asarray(warm_pipe.result.selected)[kept])
    assert warm_pipe.verify()               # Theorem 3.1 on the refit tiering


def test_refit_budget_frac(pipe_factory, tiny_data):
    w = np.asarray(tiny_data.log.train_weights)
    pipe = pipe_factory().refit(w, budget_frac=0.25)
    assert pipe.config.budget == float(tiny_data.n_docs // 4)
    assert pipe.result.g_final <= tiny_data.n_docs // 4
    with pytest.raises(ValueError, match="not both"):
        pipe.refit(w, budget=10.0, budget_frac=0.1)


def test_refit_rejects_flow_solvers_and_bad_warm(pipe_factory, tiny_data):
    w = np.asarray(tiny_data.log.train_weights)
    with pytest.raises(ValueError, match="SCSK solver"):
        pipe_factory().refit(w, solver="flow-popularity")
    pipe = pipe_factory()
    with pytest.raises(ValueError, match="warm start"):
        pipe.refit(w, solver="isk1", state=pipe.result.state)


# -- the acceptance spine -----------------------------------------------------

def test_rotation_retiering_beats_static_with_parity(pipe_factory):
    kw = dict(scenario="rotate", n_windows=12, queries_per_window=512, seed=0)
    static = stream.run_stream(pipe_factory(), enable_refit=False, **kw)
    retiered = stream.run_stream(pipe_factory(), verify_swaps=True, **kw)

    assert static.n_refits == 0
    assert retiered.n_refits > 0
    assert retiered.n_warm > 0              # warm-started re-solves happened
    assert retiered.mean_coverage > static.mean_coverage
    # Theorem 3.1 parity held after every hot swap
    checked = [w for w in retiered.windows if w.parity_ok is not None]
    assert checked and all(w.parity_ok for w in checked)
    # the engine swapped generations without dropping a window
    assert retiered.cumulative.n_queries == static.cumulative.n_queries


def test_stream_cumulative_equals_window_sum(pipe_factory):
    report = stream.run_stream(pipe_factory(), scenario="burst", n_windows=4,
                               queries_per_window=128, seed=0)
    assert report.cumulative.n_queries == 4 * 128
    assert report.cumulative.n_tier1 == \
        sum(w.stats.n_tier1 for w in report.windows)
    assert report.cumulative.tier1_words == \
        sum(w.stats.tier1_words for w in report.windows)
    assert report.cumulative.tier2_words == \
        sum(w.stats.tier2_words for w in report.windows)


def test_detector_noise_floor_suppresses_sampling_jitter():
    """With n_samples given, TV below the sampling-noise floor must not
    trigger — a perfectly static workload refits zero times — while real
    drift far above the floor still does."""
    from repro.serve.engine import ServeStats
    det = stream.DriftDetector(tv_threshold=0.05, coverage_drop=1.0,
                               warmup_windows=0, min_windows_between=0)
    rng = np.random.default_rng(0)
    p = rng.dirichlet(np.ones(500))
    det.rebase(p, 0.7)
    stats = ServeStats(n_queries=10, n_tier1=7)
    # empirical re-draws of p itself: TV is pure sampling noise
    n = 400
    for _ in range(5):
        emp = np.bincount(rng.choice(500, size=n, p=p), minlength=500) / n
        sig = det.update(stats, emp, n_samples=n)
        assert sig.tv_noise_floor > 0
        assert not sig.triggered, sig.reasons
    # genuine drift: half the mass moves to one query
    drifted = 0.5 * p + 0.5 * np.eye(500)[0]
    assert det.update(stats, drifted, n_samples=n).triggered


def test_detector_triggers_on_tv_and_hysteresis():
    det = stream.DriftDetector(tv_threshold=0.1, coverage_drop=0.5,
                               min_windows_between=2, warmup_windows=1)
    from repro.serve.engine import ServeStats
    stats = ServeStats(n_queries=10, n_tier1=7)
    p = np.array([0.5, 0.5, 0.0])
    q = np.array([0.0, 0.5, 0.5])
    det.rebase(p, 0.7)
    s1 = det.update(stats, q)
    assert s1.tv_distance == pytest.approx(0.5)
    assert not s1.triggered                 # hysteresis: 1 < min_windows=2
    s2 = det.update(stats, q)
    assert s2.triggered and "tv" in s2.reasons[0]
    det.rebase(q, 0.7)
    assert not det.update(stats, q).triggered   # anchored: no drift now


# -- shard-aware re-tiering ---------------------------------------------------

def test_prune_partitions_unfreezes_only_scoped_clauses(tiny_problem,
                                                        tiny_data):
    """Dropping one partition's clauses keeps every other clause frozen and
    rebuilds the state exactly (a solver can resume from it)."""
    from repro.core import SolveConfig, bitset, partition_bounds, registry
    from repro.stream import prune_partitions
    b = float(tiny_data.n_docs // 2)
    r = registry.solve(tiny_problem, SolveConfig(budget=b, solver="greedy"))
    bounds = partition_bounds(tiny_problem.n_docs, 2)
    state, kept, dropped = stream.prune_partitions(
        tiny_problem, r.state, bounds, [1], scope_frac=0.5)
    assert set(kept) | set(dropped) == set(np.nonzero(r.selected)[0])
    assert not (set(kept) & set(dropped))
    rows = np.asarray(tiny_problem.clause_doc_bits)
    lo, hi = bounds[1], bounds[2]
    for j in dropped:       # dropped: >= half their doc mass in partition 1
        frac = bitset.np_popcount(rows[j, lo:hi]) / \
            max(bitset.np_popcount(rows[j]), 1)
        assert frac >= 0.5
    for j in kept:
        frac = bitset.np_popcount(rows[j, lo:hi]) / \
            max(bitset.np_popcount(rows[j]), 1)
        assert frac < 0.5
    # rebuilt state is exact: covered bitsets == OR of kept rows
    want_d = np.bitwise_or.reduce(rows[kept], axis=0) if len(kept) else \
        np.zeros(tiny_problem.wd, np.uint32)
    np.testing.assert_array_equal(np.asarray(state.covered_d), want_d)
    assert float(state.g_used) == bitset.np_popcount(want_d)
    # scoping everything == a full unfreeze
    state_all, kept_all, dropped_all = stream.prune_partitions(
        tiny_problem, r.state, bounds, [0, 1], scope_frac=0.0)
    assert len(kept_all) == 0 or len(dropped_all) > 0


def test_controller_scoped_refit_with_traffic_split(tiny_data):
    """The control loop over a traffic-split solve: refits re-allocate the
    per-shard caps (equal total), per-shard drift is reported every window,
    scoped refits record which shards they re-tiered, and the final fills
    respect the final caps."""
    pipe = api.TieringPipeline.from_data(tiny_data).solve(
        "greedy", budget_frac=0.5, budget_split="traffic", n_shards=2)
    total = pipe.result.extra["caps"].sum()
    report = stream.run_stream(pipe, scenario="rotate", n_windows=6,
                               queries_per_window=256, seed=0,
                               verify_swaps=True)
    assert report.n_refits > 0
    assert report.parity_all_ok()
    for w in report.windows:
        assert len(w.shard_tv) == 2            # reported every window
    scoped = [w for w in report.windows if w.refit and w.scope]
    assert scoped, "no refit recorded its scope"
    caps = pipe.result.extra["caps"]
    assert caps.sum() == total                 # re-allocated, same total
    assert np.all(pipe.result.extra["g_part"] <= caps + 1e-6)


def test_controller_single_shard_drift_scopes_one_shard(tiny_data):
    """Traffic drifting toward queries matching ONE shard's documents must
    yield a single-shard scope on the triggered refit."""
    from repro.core import bitset, partition_bounds
    bounds = partition_bounds(tiny_data.n_docs, 2)
    qdb = tiny_data.query_doc_bits
    mass0 = np.asarray([bitset.np_popcount(r[:bounds[1]]) for r in qdb])
    mass1 = np.asarray([bitset.np_popcount(r[bounds[1]:]) for r in qdb])
    only1 = (mass1 > 0) & (mass0 == 0)
    if only1.sum() < 8:
        pytest.skip("tiny log has too few shard-1-exclusive queries")
    pipe = api.TieringPipeline.from_data(tiny_data).solve(
        "greedy", budget_frac=0.5, budget_split="traffic", n_shards=2)
    ctrl = stream.RetieringController(pipe, shard_tv_threshold=0.2)
    # synthesize windows: shard-1-exclusive queries only
    ids = np.nonzero(only1)[0]
    from repro.stream.drift import TrafficWindow
    probs = np.where(only1, 1.0, 0.0)
    probs = probs / probs.sum()
    scope_seen = ()
    for i in range(4):
        win = TrafficWindow(index=i, query_ids=np.resize(ids, 256),
                            probs=probs)
        rep = ctrl.step(win)
        if rep.refit and rep.scope:
            scope_seen = rep.scope
            break
    assert scope_seen, "drift toward shard 1 never triggered a scoped refit"
    assert 1 in scope_seen
