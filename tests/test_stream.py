"""repro.stream: drift simulator, accumulator, reweighting, re-tiering loop.

The acceptance spine: on the seeded topic-rotation scenario at tiny scale,
the drift-aware controller must (a) beat the static-tiering baseline on mean
windowed Tier-1 coverage, (b) actually reuse the prior SolverState (warm
refit step counts < a cold solve's), and (c) keep Theorem-3.1 parity across
every hot swap.
"""
import dataclasses

import numpy as np
import pytest

from repro import api, stream


@pytest.fixture(scope="module")
def pipe_factory(tiny_data):
    def fresh():
        return (api.TieringPipeline.from_data(tiny_data)
                .solve("greedy", budget_frac=0.5))
    return fresh


# -- SCSKProblem.with_weights -------------------------------------------------

def _drifted_weights(log, seed=7):
    rng = np.random.default_rng(seed)
    w = np.asarray(log.train_weights) * rng.uniform(0.1, 4.0, log.n_queries)
    return w / w.sum()


def test_with_weights_matches_fresh_problem(tiny_data, tiny_problem):
    """Bitset reuse is a pure optimization: solving a reweighted problem must
    equal solving a problem freshly built with the same weights."""
    from repro.core.problem import SCSKProblem
    w = _drifted_weights(tiny_data.log)
    fresh_data = dataclasses.replace(
        tiny_data, log=dataclasses.replace(tiny_data.log, train_weights=w))
    fresh = SCSKProblem.from_data(fresh_data)
    rewt = tiny_problem.with_weights(w)

    np.testing.assert_array_equal(np.asarray(rewt.query_weights),
                                  np.asarray(fresh.query_weights))
    cfg = api.SolveConfig(budget=float(tiny_data.n_docs // 2))
    ra, rb = api.solve(rewt, cfg), api.solve(fresh, cfg)
    assert ra.order == rb.order
    np.testing.assert_array_equal(ra.selected, rb.selected)
    assert ra.f_final == pytest.approx(rb.f_final)


def test_with_weights_shares_bitsets_and_leaves_original(tiny_problem):
    before = np.asarray(tiny_problem.query_weights).copy()
    w = np.zeros(tiny_problem.n_queries, np.float32)
    w[0] = 1.0
    rewt = tiny_problem.with_weights(w)
    assert rewt.clause_query_bits is tiny_problem.clause_query_bits
    assert rewt.clause_doc_bits is tiny_problem.clause_doc_bits
    assert rewt.test_weights is tiny_problem.test_weights
    assert float(np.asarray(rewt.query_weights).sum()) == pytest.approx(1.0)
    # the original problem is untouched (frozen dataclass copy)
    np.testing.assert_array_equal(np.asarray(tiny_problem.query_weights),
                                  before)


def test_with_weights_rejects_bad_shape(tiny_problem):
    with pytest.raises(ValueError, match="shape"):
        tiny_problem.with_weights(np.ones(tiny_problem.n_queries + 3))


# -- traffic simulator --------------------------------------------------------

def test_simulator_is_deterministic(tiny_data):
    log = tiny_data.log
    mk = lambda s: list(stream.TrafficSimulator(
        log, "rotate", seed=s, n_windows=4, queries_per_window=64).windows())
    a, b, c = mk(0), mk(0), mk(1)
    for wa, wb in zip(a, b):
        np.testing.assert_array_equal(wa.query_ids, wb.query_ids)
        np.testing.assert_array_equal(wa.probs, wb.probs)
    assert any(not np.array_equal(wa.query_ids, wc.query_ids)
               for wa, wc in zip(a, c))


@pytest.mark.parametrize("scenario", stream.list_scenarios())
def test_scenarios_yield_valid_drifting_distributions(tiny_data, scenario):
    log = tiny_data.log
    sim = stream.TrafficSimulator(log, scenario, seed=0, n_windows=6,
                                  queries_per_window=32)
    p0 = sim.window_probs(0)
    drifted = False
    for w in sim.windows():
        assert w.probs.shape == (log.n_queries,)
        assert (w.probs >= 0).all()
        assert w.probs.sum() == pytest.approx(1.0)
        assert w.query_ids.shape == (32,)
        drifted |= not np.allclose(w.probs, p0)
    assert drifted == (scenario != "static")


def test_churn_moves_mass_to_novel_queries(tiny_data):
    log = tiny_data.log
    sim = stream.TrafficSimulator(log, "churn", seed=0, n_windows=6)
    novel = np.asarray(log.train_weights) == 0
    first = sim.window_probs(0)[novel].sum()
    last = sim.window_probs(5)[novel].sum()
    assert last > first + 0.1


def test_unknown_scenario_raises(tiny_data):
    with pytest.raises(KeyError, match="unknown scenario"):
        stream.TrafficSimulator(tiny_data.log, "nope")


# -- log accumulator ----------------------------------------------------------

def test_accumulator_tracks_and_decays():
    acc = stream.LogAccumulator(4, halflife=1.0)
    acc.observe(np.array([0, 0, 0, 1]))
    assert acc.weights()[0] == pytest.approx(0.75)
    for _ in range(5):
        acc.observe(np.array([2, 2, 2, 2]))
    w = acc.weights()
    assert w[2] > 0.9                      # new traffic dominates
    assert w[0] < 0.05                     # old traffic decayed away
    assert w.sum() == pytest.approx(1.0)


def test_accumulator_prior_fades():
    prior = np.array([1.0, 0.0, 0.0])
    acc = stream.LogAccumulator(3, halflife=1.0, prior=prior,
                                prior_strength=4.0)
    assert acc.weights()[0] == pytest.approx(1.0)   # prior only
    for _ in range(6):
        acc.observe(np.array([1] * 8))
    assert acc.weights()[1] > 0.9


# -- prune_state --------------------------------------------------------------

def test_prune_state_noop_and_full(tiny_problem):
    cfg = api.SolveConfig(budget=float(tiny_problem.n_docs // 2))
    r = api.solve(tiny_problem, cfg)
    same, kept, dropped = stream.prune_state(tiny_problem, r.state,
                                             min_unique_mass=0.0)
    assert same is r.state and len(dropped) == 0
    empty, kept2, dropped2 = stream.prune_state(tiny_problem, r.state,
                                                min_unique_mass=2.0)
    assert len(kept2) == 0 and len(dropped2) == len(kept)
    assert int(empty.selected.sum()) == 0
    assert float(empty.g_used) == 0.0


def test_prune_state_rebuilds_consistent_state(tiny_problem, tiny_data):
    from repro.core import bitset
    cfg = api.SolveConfig(budget=float(tiny_problem.n_docs // 2))
    r = api.solve(tiny_problem, cfg)
    rewt = tiny_problem.with_weights(_drifted_weights(tiny_data.log))
    state, kept, dropped = stream.prune_state(rewt, r.state,
                                              min_unique_mass=5e-3)
    assert len(kept) + len(dropped) == len(r.order)
    assert int(state.selected.sum()) == len(kept) == int(state.step)
    # g_used must equal the popcount of the rebuilt doc bitset
    assert float(state.g_used) == float(
        bitset.np_popcount(np.asarray(state.covered_d)).sum())
    # resuming a solver from the pruned state must stay within budget
    r2 = api.solve(rewt, cfg, state=state)
    assert r2.g_final <= cfg.budget


# -- refit + warm starts ------------------------------------------------------

def test_refit_warm_start_does_fewer_steps(pipe_factory, tiny_data):
    drifted = stream.TrafficSimulator(
        tiny_data.log, "rotate", seed=0, n_windows=12).window_probs(3)

    cold_pipe = pipe_factory().refit(drifted, state=None)
    cold_steps = len(cold_pipe.result.order)

    warm_pipe = pipe_factory()
    prev = warm_pipe.result
    state, kept, _ = stream.prune_state(warm_pipe.problem, prev.state,
                                        weights=drifted,
                                        min_unique_mass=2e-3)
    # weights= kwarg ≡ pruning a reweighted problem (no rebuild needed)
    via_problem, _, _ = stream.prune_state(
        warm_pipe.problem.with_weights(drifted), prev.state,
        min_unique_mass=2e-3)
    np.testing.assert_array_equal(np.asarray(state.selected),
                                  np.asarray(via_problem.selected))
    warm_pipe.refit(drifted, state=state)
    warm_steps = len(warm_pipe.result.order)

    assert 0 < warm_steps < cold_steps      # the prior state was reused
    # warm keeps every surviving clause of the previous solve
    assert np.all(np.asarray(warm_pipe.result.selected)[kept])
    assert warm_pipe.verify()               # Theorem 3.1 on the refit tiering


def test_refit_budget_frac(pipe_factory, tiny_data):
    w = np.asarray(tiny_data.log.train_weights)
    pipe = pipe_factory().refit(w, budget_frac=0.25)
    assert pipe.config.budget == float(tiny_data.n_docs // 4)
    assert pipe.result.g_final <= tiny_data.n_docs // 4
    with pytest.raises(ValueError, match="not both"):
        pipe.refit(w, budget=10.0, budget_frac=0.1)


def test_refit_rejects_flow_solvers_and_bad_warm(pipe_factory, tiny_data):
    w = np.asarray(tiny_data.log.train_weights)
    with pytest.raises(ValueError, match="SCSK solver"):
        pipe_factory().refit(w, solver="flow-popularity")
    pipe = pipe_factory()
    with pytest.raises(ValueError, match="warm start"):
        pipe.refit(w, solver="isk1", state=pipe.result.state)


# -- the acceptance spine -----------------------------------------------------

def test_rotation_retiering_beats_static_with_parity(pipe_factory):
    kw = dict(scenario="rotate", n_windows=12, queries_per_window=512, seed=0)
    static = stream.run_stream(pipe_factory(), enable_refit=False, **kw)
    retiered = stream.run_stream(pipe_factory(), verify_swaps=True, **kw)

    assert static.n_refits == 0
    assert retiered.n_refits > 0
    assert retiered.n_warm > 0              # warm-started re-solves happened
    assert retiered.mean_coverage > static.mean_coverage
    # Theorem 3.1 parity held after every hot swap
    checked = [w for w in retiered.windows if w.parity_ok is not None]
    assert checked and all(w.parity_ok for w in checked)
    # the engine swapped generations without dropping a window
    assert retiered.cumulative.n_queries == static.cumulative.n_queries


def test_stream_cumulative_equals_window_sum(pipe_factory):
    report = stream.run_stream(pipe_factory(), scenario="burst", n_windows=4,
                               queries_per_window=128, seed=0)
    assert report.cumulative.n_queries == 4 * 128
    assert report.cumulative.n_tier1 == \
        sum(w.stats.n_tier1 for w in report.windows)
    assert report.cumulative.tier1_words == \
        sum(w.stats.tier1_words for w in report.windows)
    assert report.cumulative.tier2_words == \
        sum(w.stats.tier2_words for w in report.windows)


def test_detector_noise_floor_suppresses_sampling_jitter():
    """With n_samples given, TV below the sampling-noise floor must not
    trigger — a perfectly static workload refits zero times — while real
    drift far above the floor still does."""
    from repro.serve.engine import ServeStats
    det = stream.DriftDetector(tv_threshold=0.05, coverage_drop=1.0,
                               warmup_windows=0, min_windows_between=0)
    rng = np.random.default_rng(0)
    p = rng.dirichlet(np.ones(500))
    det.rebase(p, 0.7)
    stats = ServeStats(n_queries=10, n_tier1=7)
    # empirical re-draws of p itself: TV is pure sampling noise
    n = 400
    for _ in range(5):
        emp = np.bincount(rng.choice(500, size=n, p=p), minlength=500) / n
        sig = det.update(stats, emp, n_samples=n)
        assert sig.tv_noise_floor > 0
        assert not sig.triggered, sig.reasons
    # genuine drift: half the mass moves to one query
    drifted = 0.5 * p + 0.5 * np.eye(500)[0]
    assert det.update(stats, drifted, n_samples=n).triggered


def test_detector_triggers_on_tv_and_hysteresis():
    det = stream.DriftDetector(tv_threshold=0.1, coverage_drop=0.5,
                               min_windows_between=2, warmup_windows=1)
    from repro.serve.engine import ServeStats
    stats = ServeStats(n_queries=10, n_tier1=7)
    p = np.array([0.5, 0.5, 0.0])
    q = np.array([0.0, 0.5, 0.5])
    det.rebase(p, 0.7)
    s1 = det.update(stats, q)
    assert s1.tv_distance == pytest.approx(0.5)
    assert not s1.triggered                 # hysteresis: 1 < min_windows=2
    s2 = det.update(stats, q)
    assert s2.triggered and "tv" in s2.reasons[0]
    det.rebase(q, 0.7)
    assert not det.update(stats, q).triggered   # anchored: no drift now
