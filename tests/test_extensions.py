"""Beyond-2-tier and stochastic-solver extensions (paper §3.2 / §6)."""
import numpy as np

from repro.core.multitier import build_multitier, verify_multitier
from repro.core.stochastic import stochastic_greedy


def test_stochastic_greedy_approaches_exact(tiny_problem, tiny_data):
    from repro.core import greedy
    budget = tiny_data.n_docs // 2
    exact = greedy(tiny_problem, budget)
    stoch = stochastic_greedy(tiny_problem, budget, batch_queries=2048,
                              seed=0)
    assert stoch.g_final <= budget + 1e-6          # cost stays exact
    assert stoch.f_final >= 0.93 * exact.f_final   # estimator noise bounded


def test_stochastic_greedy_small_batch_is_worse_but_feasible(tiny_problem,
                                                             tiny_data):
    budget = tiny_data.n_docs // 2
    tiny_batch = stochastic_greedy(tiny_problem, budget, batch_queries=32,
                                   seed=1)
    assert tiny_batch.g_final <= budget + 1e-6
    assert tiny_batch.f_final > 0.2                # still learns something


def test_multitier_nesting_and_correctness(tiny_data):
    budgets = [tiny_data.n_docs // 8, tiny_data.n_docs // 4,
               tiny_data.n_docs // 2]
    mt = build_multitier(tiny_data, budgets)
    # budgets respected
    for docs, b in zip(mt.tier_docs, budgets):
        assert docs.sum() <= b
    # nesting + per-level Theorem 3.1, exhaustively
    assert verify_multitier(mt, tiny_data)


def test_multitier_routing_monotone_coverage(tiny_data):
    budgets = [tiny_data.n_docs // 8, tiny_data.n_docs // 2]
    mt = build_multitier(tiny_data, budgets)
    cov = mt.coverage(tiny_data.log.query_bits, tiny_data.log.test_weights)
    assert len(cov) == 3
    assert abs(sum(cov) - tiny_data.log.test_weights.sum()) < 1e-9
    # a 3-tier system beats the equivalent 2-tier on expected scan cost
    cost3 = mt.expected_cost(tiny_data.log.query_bits,
                             tiny_data.log.test_weights)
    mt2 = build_multitier(tiny_data, [budgets[-1]])
    cost2 = mt2.expected_cost(tiny_data.log.query_bits,
                              tiny_data.log.test_weights)
    assert cost3 <= cost2 + 1e-9
    assert cost3 < 1.0                              # beats untiered
