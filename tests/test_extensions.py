"""Beyond-2-tier and stochastic-solver extensions (paper §3.2 / §6)."""
import numpy as np
import pytest

from repro.core.multitier import build_multitier, verify_multitier
from repro.core.stochastic import stochastic_greedy


def test_stochastic_greedy_approaches_exact(tiny_problem, tiny_data):
    from repro.core import greedy
    budget = tiny_data.n_docs // 2
    exact = greedy(tiny_problem, budget)
    stoch = stochastic_greedy(tiny_problem, budget, batch_queries=2048,
                              seed=0)
    assert stoch.g_final <= budget + 1e-6          # cost stays exact
    assert stoch.f_final >= 0.93 * exact.f_final   # estimator noise bounded


def test_stochastic_greedy_small_batch_is_worse_but_feasible(tiny_problem,
                                                             tiny_data):
    budget = tiny_data.n_docs // 2
    tiny_batch = stochastic_greedy(tiny_problem, budget, batch_queries=32,
                                   seed=1)
    assert tiny_batch.g_final <= budget + 1e-6
    assert tiny_batch.f_final > 0.2                # still learns something


def test_multitier_nesting_and_correctness(tiny_data):
    budgets = [tiny_data.n_docs // 8, tiny_data.n_docs // 4,
               tiny_data.n_docs // 2]
    mt = build_multitier(tiny_data, budgets)
    # budgets respected
    for docs, b in zip(mt.tier_docs, budgets):
        assert docs.sum() <= b
    # nesting + per-level Theorem 3.1, exhaustively
    assert verify_multitier(mt, tiny_data)


def test_multitier_routing_monotone_coverage(tiny_data):
    budgets = [tiny_data.n_docs // 8, tiny_data.n_docs // 2]
    mt = build_multitier(tiny_data, budgets)
    cov = mt.coverage(tiny_data.log.query_bits, tiny_data.log.test_weights)
    assert len(cov) == 3
    assert abs(sum(cov) - tiny_data.log.test_weights.sum()) < 1e-9
    # a 3-tier system beats the equivalent 2-tier on expected scan cost
    cost3 = mt.expected_cost(tiny_data.log.query_bits,
                             tiny_data.log.test_weights)
    mt2 = build_multitier(tiny_data, [budgets[-1]])
    cost2 = mt2.expected_cost(tiny_data.log.query_bits,
                              tiny_data.log.test_weights)
    assert cost3 <= cost2 + 1e-9
    assert cost3 < 1.0                              # beats untiered


def _drifted_weights(log, seed=7):
    rng = np.random.default_rng(seed)
    w = np.asarray(log.train_weights, np.float64) * rng.uniform(
        0.05, 1.0, size=log.n_queries)
    return w / w.sum()


def test_multitier_route_is_weight_independent(tiny_data):
    """ψ-routing depends only on the clause sets, never on the weights, so
    reweighting the problem (`SCSKProblem.with_weights`) must not move any
    query between tiers of a FIXED multi-tiering."""
    mt = build_multitier(tiny_data, [tiny_data.n_docs // 4,
                                     tiny_data.n_docs // 2])
    routes = mt.route(tiny_data.log.query_bits)
    np.testing.assert_array_equal(routes, mt.route(tiny_data.log.query_bits))
    w2 = _drifted_weights(tiny_data.log)
    cov = mt.coverage(tiny_data.log.query_bits, w2)
    assert abs(sum(cov) - w2.sum()) < 1e-9
    # coverage under the new weights is the routes' masses, per level
    for k, c in enumerate(cov):
        assert c == w2[routes == k].sum()


def test_multitier_expected_cost_under_reweighted_problem(tiny_data):
    """expected_cost under drifted weights: matches the brute-force
    route-mass × tier-size sum, and a multitier SOLVED on the reweighted
    problem (via with_weights) costs no more on those weights than on the
    stale ones would suggest structurally."""
    from repro.core.problem import SCSKProblem
    w2 = _drifted_weights(tiny_data.log)
    budgets = [tiny_data.n_docs // 4, tiny_data.n_docs // 2]
    mt = build_multitier(tiny_data, budgets)
    cost = mt.expected_cost(tiny_data.log.query_bits, w2)
    routes = mt.route(tiny_data.log.query_bits)
    sizes = [d.mean() for d in mt.tier_docs] + [1.0]
    brute = sum(w2[routes == k].sum() * sizes[k]
                for k in range(len(mt.tiers) + 1))
    assert cost == pytest.approx(brute, rel=1e-12)
    assert 0.0 < cost <= 1.0 + 1e-9

    # solve the REWEIGHTED problem (bitset-sharing with_weights path) and
    # build the multitier from that solver — still nested + Thm-3.1-exact,
    # and its expected cost under w2 must beat the untiered system
    problem2 = SCSKProblem.from_data(tiny_data).with_weights(w2)

    def reweighted_solver(_problem, budget, **kw):
        from repro.core import greedy
        return greedy(problem2, budget)

    mt2 = build_multitier(tiny_data, budgets, solver=reweighted_solver)
    assert verify_multitier(mt2, tiny_data)
    cost2 = mt2.expected_cost(tiny_data.log.query_bits, w2)
    assert cost2 < 1.0
    # the multitier tuned to w2 serves w2 no worse than the stale one
    assert cost2 <= cost + 0.05
