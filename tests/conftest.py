import os
import sys

# tests run against the source tree
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# smoke tests and kernel tests must see exactly ONE device; the 512-device
# dry-run sets XLA_FLAGS itself in a subprocess (launch/dryrun.py).

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def tiny_data():
    from repro.data import incidence, synthetic
    corpus, log = synthetic.make_tiering_dataset(0, "tiny")
    return incidence.build_tiering_data(corpus, log, min_support=0.001)


@pytest.fixture(scope="session")
def tiny_problem(tiny_data):
    from repro.core import SCSKProblem
    return SCSKProblem.from_data(tiny_data)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(42)
