"""Tile autotuner: bucketing, cache resolution, search, and — the part that
matters — parity of autotuned tile/strategy picks through the ops dispatch
layer (a tuned entry must never change results, only speed)."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitset
from repro.distributed import plan as dplan
from repro.kernels import autotune, ops, ref


@pytest.fixture(autouse=True)
def _fresh_cache_state():
    autotune.invalidate()
    yield
    autotune.invalidate()


def test_pow2_bucketing_is_stable():
    assert autotune.bucket("clause_match", 512, 128, 64) == "b512_k128_w64"
    assert autotune.bucket("clause_match", 300, 100, 33) == "b512_k128_w64"
    assert autotune.bucket("bit_matvec", 4096, 512, 1) == "c4096_w512_r1"
    assert autotune.bucket("partition_gain", 4096, 512, 4) == "c4096_w512_p4"


def test_bucket_from_args_matches_bucket():
    q = jnp.zeros((300, 33), jnp.uint32)
    c = jnp.zeros((100, 33), jnp.uint32)
    assert autotune.bucket_from_args("clause_match", (q, c)) \
        == "b512_k128_w64"
    a = jnp.zeros((65, 9), jnp.uint32)
    x = jnp.zeros((9 * 32, 3), jnp.float32)
    assert autotune.bucket_from_args("bit_matvec", (a, x)) == "c128_w16_r4"
    assert autotune.bucket_from_args("sparse_gain", (a, x)) is None


def test_tile_params_miss_and_disable(tmp_path, monkeypatch):
    path = tmp_path / "tiles.json"
    path.write_text(json.dumps({
        "version": autotune.CACHE_VERSION,
        "entries": {"clause_match|xla|b8_k8_w1":
                    {"strategy": "gemm", "_us": 12.0}}}))
    monkeypatch.setenv(autotune.ENV_VAR, str(path))
    autotune.invalidate()
    got = autotune.tile_params("clause_match", "xla", "b8_k8_w1")
    assert got == {"strategy": "gemm"}          # bookkeeping keys dropped
    assert autotune.tile_params("clause_match", "xla", "b16_k8_w1") == {}
    assert autotune.tile_params("clause_match", "interpret", "b8_k8_w1") == {}
    monkeypatch.setenv(autotune.ENV_VAR, "off")
    assert autotune.tile_params("clause_match", "xla", "b8_k8_w1") == {}


def test_search_writes_picks_from_the_candidate_space(tmp_path):
    out = tmp_path / "tiles.json"
    blob = autotune.search(
        [("clause_match", "xla", (32, 8, 2)),
         ("bit_matvec", "xla", (64, 4, 1))],
        seed=0, reps=1, out=str(out))
    assert out.exists()
    entries = blob["entries"]
    assert set(entries) == {"clause_match|xla|b32_k8_w2",
                            "bit_matvec|xla|c64_w4_r1"}
    cm = {k: v for k, v in entries["clause_match|xla|b32_k8_w2"].items()
          if not k.startswith("_")}
    assert cm in autotune.SPACES[("clause_match", "xla")]
    # persisted file round-trips through the lookup path
    os.environ[autotune.ENV_VAR] = str(out)
    try:
        autotune.invalidate()
        assert autotune.tile_params("clause_match", "xla", "b32_k8_w2") == cm
    finally:
        del os.environ[autotune.ENV_VAR]


def test_ensure_cache_respects_disable(monkeypatch):
    monkeypatch.setenv(autotune.ENV_VAR, "0")
    path, n = autotune.ensure_cache()
    assert path == "<disabled>" and n == 0


def test_autotuned_picks_are_parity_exact(tmp_path, monkeypatch):
    """Dispatching through ops with a cache full of NON-default picks (odd
    strategies, odd blocks) must reproduce the reference bit-for-bit /
    allclose — the satellite acceptance for autotuned tile parity."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.integers(0, 2**32, (300, 33), dtype=np.uint32))
    cl = jnp.asarray(bitset.np_pack(rng.random((100, 33 * 32)) < 0.03))
    a = jnp.asarray(rng.integers(0, 2**32, (65, 9), dtype=np.uint32))
    x = jnp.asarray(rng.standard_normal((9 * 32, 3)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2**32, 9, dtype=np.uint32))
    bounds = (0, 3, 7, 9)
    entries = {
        "clause_match|xla|b512_k128_w64": {"strategy": "gemm"},
        "bit_matvec|xla|c128_w16_r4": {"strategy": "lut"},
        "clause_match|interpret|b512_k128_w64": {"block_b": 56, "block_k": 17},
        "bit_matvec|interpret|c128_w16_r4": {"block_c": 24, "block_w": 5},
        "coverage_gain|interpret|c128_w16": {"block_c": 24, "block_w": 5},
        "partition_gain|interpret|c128_w16_p4":
            {"block_c": 24, "block_w": 5},
    }
    path = tmp_path / "tiles.json"
    path.write_text(json.dumps(
        {"version": autotune.CACHE_VERSION, "entries": entries}))
    monkeypatch.setenv(autotune.ENV_VAR, str(path))
    autotune.invalidate()

    plan = dplan.current_plan()
    assert plan.tile_params(
        "bit_matvec", "interpret",
        autotune.bucket_from_args("bit_matvec", (a, x))) \
        == {"block_c": 24, "block_w": 5}

    for backend in ("xla", "interpret"):
        np.testing.assert_array_equal(
            ops.clause_match(q, cl, backend=backend), ref.clause_match(q, cl))
        np.testing.assert_allclose(
            ops.bit_matvec(a, x, backend=backend), ref.bit_matvec(a, x),
            rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(
        ops.coverage_gain(a, mask, backend="interpret"),
        ref.coverage_gain(a, mask))
    np.testing.assert_array_equal(
        ops.partition_gain(a, mask, bounds, backend="interpret"),
        ops._partition_gain_xla(a, mask, bounds))
