"""The paper's Table-1 worked example, asserted exactly (§2.1 and §3.1)."""
import numpy as np

from repro.core import bitset
from repro.core.tiering import ClauseTiering
from repro.data import incidence
from repro.data.synthetic import Corpus

RED, BLUE, SHIRT, PANTS, STRIPED = range(5)
DOCS = [
    (RED, SHIRT, STRIPED),      # D1
    (BLUE, SHIRT, STRIPED),     # D2
    (RED, SHIRT),               # D3
    (RED, PANTS, STRIPED),      # D4
    (BLUE, PANTS, STRIPED),     # D5
    (BLUE, PANTS),              # D6
]


def make_corpus():
    bits = np.zeros((6, 5), bool)
    for i, d in enumerate(DOCS):
        bits[i, list(d)] = True
    return Corpus(doc_tokens=[tuple(sorted(d)) for d in DOCS],
                  doc_bits=bitset.np_pack(bits), vocab_size=5)


def test_match_sets():
    corpus = make_corpus()
    postings = incidence.build_postings(corpus)
    # m({red, shirt}) = {D1, D3}
    m = incidence.match_bits(postings, (RED, SHIRT), 6)
    np.testing.assert_array_equal(bitset.np_to_indices(m, 6), [0, 2])
    # m({blue, pants, striped}) = {D5}
    m = incidence.match_bits(postings, (BLUE, PANTS, STRIPED), 6)
    np.testing.assert_array_equal(bitset.np_to_indices(m, 6), [4])


def test_clause_classifiers_section_3_1():
    """X = {{red}, {blue, shirt}} => D1 = {D1..D4}; serves 'red shirt' etc,
    but not 'blue pants' (paper's §3.1 walkthrough)."""
    corpus = make_corpus()
    postings = incidence.build_postings(corpus)
    clauses = [(RED,), (BLUE, SHIRT)]
    cd = incidence.clause_doc_incidence(postings, clauses, 6)
    tier1 = bitset.np_unpack(cd[0] | cd[1], 6)
    np.testing.assert_array_equal(np.nonzero(tier1)[0], [0, 1, 2, 3])

    tiering = ClauseTiering(
        clauses=clauses,
        clause_vocab_bits=bitset.np_pack(np.array(
            [[1, 0, 0, 0, 0], [0, 1, 1, 0, 0]], bool)),
        tier1_docs=tier1, vocab_size=5)

    def q(toks):
        b = np.zeros((1, 5), bool)
        b[0, list(toks)] = True
        return bool(tiering.classify_queries(bitset.np_pack(b))[0])

    assert q((RED,))
    assert q((RED, SHIRT))
    assert q((RED, PANTS))
    assert q((BLUE, SHIRT, STRIPED))
    assert not q((BLUE, PANTS))


def test_theorem_3_1_on_example():
    """Eligible queries' match sets are contained in Tier 1."""
    corpus = make_corpus()
    postings = incidence.build_postings(corpus)
    clauses = [(RED,), (BLUE, SHIRT)]
    cd = incidence.clause_doc_incidence(postings, clauses, 6)
    tier1_bits = cd[0] | cd[1]
    for query in [(RED,), (RED, SHIRT), (RED, PANTS), (BLUE, SHIRT, STRIPED)]:
        m = incidence.match_bits(postings, query, 6)
        assert not np.any(m & ~tier1_bits), query
