"""benchmarks.compare: BENCH/JSONL tree loading, tolerance-rule matching,
direction-aware regression detection, and the run_gate exit contract CI
leans on."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import compare  # noqa: E402

from repro import obs  # noqa: E402


def _bench(tmp_path, name, rows, seconds=1.5):
    p = tmp_path / f"BENCH_{name}.json"
    p.write_text(json.dumps({"seconds": seconds, "rows": rows}))
    return p


def _row(name, us=10.0, derived="", data=None):
    r = {"name": name, "us_per_call": us, "derived": derived}
    if data is not None:
        r["data"] = data
    return r


def _tree(tmp_path, sub, p95):
    d = tmp_path / sub
    d.mkdir()
    _bench(d, "cluster", [_row(
        "serve", us=3.0,
        derived=f"p95={p95};cov=0.42;consistent=True;note=free_text",
        data={"latency_hist": {"count": 100, "sum": 12.5,
                               "buckets": [1, 2, 3]}, "qps": 2000.0})])
    return str(d)


# -- parsing & loading ---------------------------------------------------------

def test_parse_derived_numbers_bools_and_noise():
    assert compare.parse_derived(
        "p95=1.5;ok=True;bad=false;pct=12%;label=t2;stray") == \
        {"p95": 1.5, "ok": 1.0, "bad": 0.0, "pct": 12.0}


def test_load_tree_flattens_rows_and_skips_bare_lists(tmp_path):
    root = _tree(tmp_path, "a", p95=1.5)
    # roofline-style bare row LIST carries no gateable metrics -> no section
    (tmp_path / "a" / "BENCH_roofline.json").write_text(
        json.dumps([{"arch": "x", "roofline_frac": 0.5}]))
    tree = compare.load_tree(root)
    assert set(tree) == {"cluster"}
    m = tree["cluster"]
    assert m["cluster:seconds"] == 1.5
    assert m["cluster/serve:us_per_call"] == 3.0
    assert m["cluster/serve:p95"] == 1.5
    assert m["cluster/serve:consistent"] == 1.0
    assert m["cluster/serve:data.qps"] == 2000.0
    assert m["cluster/serve:data.latency_hist.count"] == 100.0
    # list leaves (bucket arrays) are deliberately not exploded
    assert not any("buckets" in k for k in m)
    assert compare.load_tree(str(tmp_path / "missing")) == {}


def test_load_tree_reads_obs_jsonl(tmp_path):
    d = tmp_path / "o"
    d.mkdir()
    prev_on = obs.set_enabled(True)
    prev_ex = obs.set_exporter(obs.JsonlExporter(str(d), run="run"))
    obs.reset()
    try:
        c = obs.counter("t_cmp_total", labels=("arm",))
        c.inc(3, arm="a")
        c.inc(4, arm="b")
        obs.gauge("t_cmp_g").set(7.5)
        obs.histogram("t_cmp_h", buckets=(1.0,)).observe_many([0.5, 2.0])
        obs.export_window(0)
    finally:
        obs.reset()
        obs.set_exporter(prev_ex)
        obs.set_enabled(prev_on)
    tree = compare.load_tree(str(d))
    m = tree["obs.run"]
    assert m["obs.run:n_snapshots"] == 1.0
    assert m["obs.run:t_cmp_total"] == 7.0          # counters sum series
    assert m["obs.run:t_cmp_g"] == 7.5              # gauges average
    assert m["obs.run:t_cmp_h.count"] == 2.0
    assert m["obs.run:t_cmp_h.sum"] == 2.5


# -- tolerance rules -----------------------------------------------------------

def test_rule_matching_is_ordered_first_wins():
    rules = [{"pattern": "*:us_per_call", "skip": True},
             {"pattern": "*:p95*", "rel": 0.5, "direction": "high_bad"},
             {"pattern": "*:p9*", "rel": 0.01}]
    d = dict(compare.DEFAULT_TOLERANCE)
    assert compare.rule_for("x/y:us_per_call", d, rules)["skip"] is True
    r = compare.rule_for("x/y:p95", d, rules)
    assert r["rel"] == 0.5 and r["direction"] == "high_bad"
    assert r["abs"] == d["abs"]                     # default fills the rest
    assert compare.rule_for("x/y:p99", d, rules)["rel"] == 0.01
    assert compare.rule_for("x/y:cov", d, rules) == d


def test_load_tolerances_validates_patterns(tmp_path):
    p = tmp_path / "tol.json"
    p.write_text(json.dumps({"default": {"rel": 0.1},
                             "rules": [{"rel": 0.5}]}))
    with pytest.raises(ValueError, match="without a pattern"):
        compare.load_tolerances(str(p))
    p.write_text(json.dumps({"default": {"rel": 0.1}, "rules": []}))
    default, rules = compare.load_tolerances(str(p))
    assert default["rel"] == 0.1
    assert default["abs"] == compare.DEFAULT_TOLERANCE["abs"]
    assert rules == []
    assert compare.load_tolerances(None)[0] == compare.DEFAULT_TOLERANCE


def test_compare_metric_directions():
    high = {"rel": 0.1, "abs": 0.0, "direction": "high_bad"}
    low = {"rel": 0.1, "abs": 0.0, "direction": "low_bad"}
    both = {"rel": 0.1, "abs": 0.0, "direction": "both"}
    assert compare.compare_metric("k", 100.0, 109.0, high)[0] == "ok"
    assert compare.compare_metric("k", 100.0, 111.0, high)[0] == "REGRESSED"
    assert compare.compare_metric("k", 100.0, 50.0, high)[0] == "ok"   # better
    assert compare.compare_metric("k", 100.0, 50.0, low)[0] == "REGRESSED"
    assert compare.compare_metric("k", 100.0, 200.0, low)[0] == "ok"
    assert compare.compare_metric("k", 100.0, 200.0, both)[0] == "REGRESSED"
    assert compare.compare_metric("k", 100.0, 50.0, both)[0] == "REGRESSED"
    # abs floor makes zero-baseline metrics gateable
    tight = {"rel": 0.0, "abs": 0.5, "direction": "both"}
    assert compare.compare_metric("k", 0.0, 0.4, tight)[0] == "ok"
    assert compare.compare_metric("k", 0.0, 0.6, tight)[0] == "REGRESSED"
    assert compare.compare_metric("k", 1.0, 9.0, {"skip": True}) == \
        ("skipped", "")


# -- the gate ------------------------------------------------------------------

RULES = {"default": {"rel": 0.25, "abs": 1e-9, "direction": "both"},
         "rules": [{"pattern": "*:us_per_call", "skip": True},
                   {"pattern": "*:seconds", "skip": True},
                   {"pattern": "*:p95*", "rel": 0.5, "abs": 0.01,
                    "direction": "high_bad"},
                   {"pattern": "*cov*", "rel": 0.1, "abs": 0.02,
                    "direction": "low_bad"}]}


def _tol(tmp_path):
    p = tmp_path / "tol.json"
    p.write_text(json.dumps(RULES))
    return str(p)


def test_self_diff_is_clean(tmp_path, capsys):
    base = _tree(tmp_path, "base", p95=1.5)
    assert compare.run_gate(base, base, tolerance_file=_tol(tmp_path)) == 0
    out = capsys.readouterr().out
    assert "REGRESSION" not in out and "ok" in out


def test_injected_regression_fails_the_gate(tmp_path, capsys):
    base = _tree(tmp_path, "base", p95=1.5)
    cand = _tree(tmp_path, "cand", p95=4.0)     # > 1.5 * (1 + 0.5) + 0.01
    assert compare.run_gate(base, cand, tolerance_file=_tol(tmp_path)) == 1
    out = capsys.readouterr().out
    assert "cluster/serve:p95" in out and "REGRESSED" in out
    assert "us_per_call" not in out             # skipped rows stay quiet
    # the same move in the GOOD direction passes: high_bad ignores drops
    assert compare.run_gate(cand, base, tolerance_file=_tol(tmp_path)) == 0


def test_missing_metric_and_new_metric(tmp_path):
    base = _tree(tmp_path, "base", p95=1.5)
    d = tmp_path / "cand"
    d.mkdir()
    # candidate row lost cov/consistent/data AND the wall-clock fields
    (d / "BENCH_cluster.json").write_text(json.dumps(
        {"rows": [{"name": "serve", "derived": "p95=1.5;extra=2"}]}))
    findings = compare.diff_trees(
        compare.load_tree(base), compare.load_tree(str(d)),
        *compare.load_tolerances(_tol(tmp_path)))
    by = {f["key"]: f["status"] for f in findings}
    assert by["cluster/serve:cov"] == "MISSING"       # disappeared -> fail
    assert by["cluster/serve:extra"] == "new"         # appeared -> fine
    assert by["cluster/serve:data.qps"] == "MISSING"
    assert compare.gate(findings) == 1
    # a skip rule also waives disappearance: wall-clock metrics may vanish
    assert by["cluster:seconds"] == "skipped"
    assert by["cluster/serve:us_per_call"] == "skipped"


def test_sections_only_compared_when_common(tmp_path, capsys):
    base = _tree(tmp_path, "base", p95=1.5)
    cand = _tree(tmp_path, "cand", p95=1.5)
    # candidate grows an extra section the baseline predates: a VISIBLE
    # skipped-with-notice finding, never a failure
    _bench(tmp_path / "cand", "ingest", [_row("pipe", derived="docs=5")])
    assert compare.run_gate(base, cand, tolerance_file=_tol(tmp_path)) == 0
    out = capsys.readouterr().out
    assert "skipped-new-section" in out
    assert "regenerate the" in out
    # disjoint trees cannot vouch for anything -> hard failure
    d = tmp_path / "other"
    d.mkdir()
    _bench(d, "solvers", [_row("x", derived="v=1")])
    assert compare.run_gate(base, str(d), tolerance_file=_tol(tmp_path)) == 1
    assert "no common sections" in capsys.readouterr().out


def test_candidate_dropping_a_whole_section_fails_the_gate(tmp_path, capsys):
    # the baseline gates two sections; a candidate that silently stops
    # emitting one of them must FAIL, not sail through as "not common"
    base = _tree(tmp_path, "base", p95=1.5)
    _bench(tmp_path / "base", "ingest", [_row("pipe", derived="docs=5")])
    cand = _tree(tmp_path, "cand", p95=1.5)
    assert compare.run_gate(base, cand, tolerance_file=_tol(tmp_path)) == 1
    out = capsys.readouterr().out
    assert "SECTION-MISSING" in out
    assert "dropped this whole section" in out
    findings = compare.diff_trees(compare.load_tree(base),
                                  compare.load_tree(cand),
                                  dict(compare.DEFAULT_TOLERANCE), [])
    assert compare.gate(findings) == 1


def test_empty_trees_fail_closed(tmp_path, capsys):
    base = _tree(tmp_path, "base", p95=1.5)
    empty = tmp_path / "empty"
    empty.mkdir()
    assert compare.run_gate(str(empty), base) == 1
    assert "baseline" in capsys.readouterr().out
    assert compare.run_gate(base, str(empty)) == 1
    assert "candidate" in capsys.readouterr().out


def test_checked_in_tiny_baseline_self_gates():
    """The CI gate's own baseline must diff clean against itself with the
    shipped tolerance file — guards both artifact and rule-file syntax."""
    root = os.path.join(os.path.dirname(__file__), "..")
    baseline = os.path.join(root, "benchmarks", "baselines", "tiny")
    tol = os.path.join(root, "benchmarks", "tolerances.json")
    assert compare.run_gate(baseline, baseline, tolerance_file=tol) == 0
