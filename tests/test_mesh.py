"""Mesh-resident data plane: the ExecutionPlan placement resolver, the
shared `distributed.mesh_fused` gate, and — in a subprocess with 4 fake CPU
devices (the main test process must keep seeing 1 device) — bit-identity of
the fused shard_map router serve vs the host scatter-gather path over
shards×replicas ∈ {1,2,4}², and of `partition_gain`'s owner-local path vs
the xla reference for uneven word partitions."""
import os
import subprocess
import sys

import numpy as np
import pytest


# -- backend resolution (the old bare-assert bug) -----------------------------

def test_resolve_backend_rejects_bad_argument():
    from repro.kernels import ops
    with pytest.raises(ValueError, match="pallas, interpret, xla"):
        ops.resolve_backend("cuda")


def test_resolve_backend_rejects_bad_env(monkeypatch):
    from repro.kernels import ops
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "bogus")
    with pytest.raises(ValueError, match="REPRO_KERNEL_BACKEND"):
        ops.resolve_backend()
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "xla,clause_match=nope")
    with pytest.raises(ValueError, match="valid choices"):
        ops.resolve_backend()


def test_resolve_backend_accepts_valid_choices(monkeypatch):
    from repro import distributed
    for b in ("pallas", "interpret", "xla"):
        assert distributed.resolve_backend(b) == b
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    assert distributed.resolve_backend() == "interpret"
    monkeypatch.delenv("REPRO_KERNEL_BACKEND")
    assert distributed.resolve_backend() in ("pallas", "xla")   # auto


def test_per_op_placement(monkeypatch):
    """REPRO_KERNEL_BACKEND can pin individual ops to a path."""
    from repro import distributed
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "xla,clause_match=interpret")
    plan = distributed.current_plan()
    assert plan.placement("clause_match") == "interpret"
    assert plan.placement("bit_matvec") == "xla"
    # an explicit per-call backend beats the env placement
    assert plan.placement("clause_match", "xla") == "xla"
    assert plan.pinned("clause_match") and not plan.pinned("bit_matvec")
    # a per-op "auto" restores auto-resolution (xla on CPU), not the default
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret,bit_matvec=auto")
    plan = distributed.current_plan()
    assert plan.placement("bit_matvec") == "xla"
    assert plan.placement("clause_match") == "interpret"


# -- the plan on the default (1-device) mesh ----------------------------------

def test_current_plan_single_device_defaults():
    from repro import distributed
    plan = distributed.current_plan()
    assert plan.shard_axis is None and not plan.shard_fused
    assert not plan.model_fused
    assert plan.n_shard_devices == 1


def test_mesh_fused_gates_off_mesh():
    """On a 1-device mesh every fusion gate returns None (direct path)."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro import distributed
    assert distributed.mesh_fused(lambda x: x, in_specs=(P(),),
                                  out_specs=P()) is None
    with distributed.use_mesh(distributed.shard_mesh(1)):
        plan = distributed.current_plan()
        assert plan.shard_axis == "shard" and not plan.shard_fused
        assert distributed.mesh_fused(lambda x: x, in_specs=(P(),),
                                      out_specs=P(), axis="shard") is None
    del jax


def test_owner_row_identity_off_mesh():
    import jax.numpy as jnp
    from repro import distributed
    mat = jnp.arange(12, dtype=jnp.uint32).reshape(4, 3)
    np.testing.assert_array_equal(
        np.asarray(distributed.owner_row(mat, jnp.int32(2))),
        np.asarray(mat[2]))


def test_serve_host_path_on_one_device_shard_mesh(tiny_data):
    """A size-1 "shard" mesh must leave serving on the (host) direct path
    and stay oracle-exact — plain CPU runs are unchanged by the plan layer."""
    from repro import api, distributed
    pipe = api.TieringPipeline.from_data(tiny_data).solve(
        "greedy", budget_frac=0.5)
    with distributed.use_mesh(distributed.shard_mesh(1)):
        fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2)
        got = fleet.serve(tiny_data.log.queries[:64])
    want = fleet.serve_reference(tiny_data.log.queries[:64])
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)
    assert not fleet.router._mesh_tables        # fused path never engaged


# -- 4-device parity, in a subprocess -----------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, numpy as np, jax.numpy as jnp
from repro import api, distributed as D
from repro.kernels import ops

assert len(jax.devices()) == 4

# --- partition_gain: owner-local path == xla reference, uneven partitions
rng = np.random.default_rng(0)
a = jnp.asarray(rng.integers(0, 2**32, (37, 13), dtype=np.uint32))
m = jnp.asarray(rng.integers(0, 2**32, (13,), dtype=np.uint32))
for bounds in [(0, 3, 4, 9, 13), (0, 13), (0, 1, 2, 3, 4, 5, 6, 13)]:
    ref = ops._partition_gain_xla(a, m, bounds)
    with D.use_mesh(D.shard_mesh()):
        got = ops.partition_gain(a, m, bounds)
        jitted = jax.jit(lambda a, m, b=bounds: ops.partition_gain(a, m, b))(
            a, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(jitted), np.asarray(ref))
# a pinned path steps around the mesh fusion (and still agrees)
with D.use_mesh(D.shard_mesh()):
    pinned = ops.partition_gain(a, m, (0, 3, 4, 9, 13), backend="xla")
np.testing.assert_array_equal(
    np.asarray(pinned), np.asarray(ops._partition_gain_xla(a, m,
                                                           (0, 3, 4, 9, 13))))
print("partition-gain-owner-local OK")

# --- fused shard_map serve == host scatter-gather, shards x replicas {1,2,4}^2
pipe = (api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
        .mine(min_support=1e-3).solve("greedy", budget_frac=0.5))
queries = pipe.log.queries[:192]


def snap(fleet):
    s = fleet.stats
    return (s.n_tier1, s.tier1_words, s.tier2_words,
            [(t.psi_generation, t.t1_generations, t.n_tier1, t.n_tier2,
              t.t1_shards, t.t1_contents, t.expected_contents)
             for t in fleet.trace])


for n_shards in (1, 2, 4):
    for reps in (1, 2, 4):
        host_fleet = pipe.deploy_cluster(n_shards=n_shards, t1_replicas=reps,
                                         t2_replicas=reps)
        host = []
        for s in range(0, len(queries), 64):
            host.extend(host_fleet.serve(queries[s:s + 64]))
        mesh_fleet = pipe.deploy_cluster(n_shards=n_shards, t1_replicas=reps,
                                         t2_replicas=reps)
        with D.use_mesh(D.shard_mesh()):
            mesh = []
            for s in range(0, len(queries), 64):
                mesh.extend(mesh_fleet.serve(queries[s:s + 64]))
        for a, b in zip(host, mesh):
            np.testing.assert_array_equal(a, b)
        assert snap(host_fleet) == snap(mesh_fleet), (n_shards, reps)
        assert mesh_fleet.consistency_ok()
        assert mesh_fleet.router._mesh_tables, "fused path never engaged"
print("fused-serve-parity-9combos OK")

# --- mid-roll parity incl. the Tier-2-only fallback gap, fused end to end
from repro import cluster
from repro.core import SOLVERS
from repro.core.tiering import ClauseTiering
data = pipe.data
r2 = SOLVERS["greedy"](pipe.problem, int(data.n_docs * 0.25))
t_new = ClauseTiering.from_selection(data, r2.selected)
with D.use_mesh(D.shard_mesh()):
    fleet = cluster.TieredCluster(data.postings, pipe.tiering(), data.n_docs,
                                  n_shards=2, t1_replicas=1)
    fleet.serve(queries[:64])
    fleet.swap_tiering(t_new)
    fallback = batches = 0
    while fleet.router.rollout is not None and batches < 64:
        got = fleet.serve(queries[:64])
        want = fleet.serve_reference(queries[:64])
        for a, b in zip(got, want):
            np.testing.assert_array_equal(a, b)
        fallback += fleet.trace[-1].psi_generation == -1
        batches += 1
    assert fallback > 0, "expected a Tier-2 fallback window"
    assert fleet.consistency_ok()
print("fused-rolling-swap OK")

# --- partitioned solves are bit-identical under the shard mesh
cold = api.TieringPipeline.from_data(data).solve(
    "greedy", budget_frac=0.5, budget_split="traffic", n_shards=4)
with D.use_mesh(D.shard_mesh()):
    fused = api.TieringPipeline.from_data(data).solve(
        "greedy", budget_frac=0.5, budget_split="traffic", n_shards=4)
assert cold.result.order == fused.result.order
np.testing.assert_array_equal(np.asarray(cold.result.extra["g_part"]),
                              np.asarray(fused.result.extra["g_part"]))
print("partitioned-solve-identity OK")
print("ALL-MESH-OK")
"""


def test_mesh_parity_4dev():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": os.environ.get(
            "PATH", "/usr/bin:/bin"), "HOME": os.environ.get("HOME", "/root")},
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=900)
    assert "ALL-MESH-OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
