"""Flash-attention Pallas kernel: shape/feature sweep vs ref oracle, plus
consistency with the XLA chunked-attention path used by the models."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.models import common


def _qkv(rng, b, sq, skv, hq, hkv, d, dtype=np.float32):
    q = rng.standard_normal((b, sq, hq, d)).astype(dtype)
    k = rng.standard_normal((b, skv, hkv, d)).astype(dtype)
    v = rng.standard_normal((b, skv, hkv, d)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


CASES = [
    # b, sq, skv, hq, hkv, d, causal, window, cap, q_offset
    (1, 16, 16, 2, 1, 8, True, None, None, 0),
    (2, 32, 32, 4, 2, 16, True, None, None, 0),
    (1, 32, 32, 4, 4, 8, True, 8, None, 0),          # sliding window
    (1, 24, 24, 2, 1, 8, True, None, 20.0, 0),       # softcap
    (1, 16, 16, 8, 2, 8, False, None, None, 0),      # bidirectional
    (1, 1, 48, 4, 2, 8, True, None, None, 47),       # decode step
    (1, 1, 48, 4, 2, 8, True, 16, 30.0, 40),         # decode + window + cap
    (1, 20, 36, 2, 2, 8, True, None, None, 16),      # ragged, non-tile sizes
]


@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,d,causal,window,cap,q_offset", CASES)
def test_flash_vs_ref(b, sq, skv, hq, hkv, d, causal, window, cap, q_offset):
    rng = np.random.default_rng(sq * skv + hq)
    q, k, v = _qkv(rng, b, sq, skv, hq, hkv, d)
    got = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          q_offset=q_offset, block_q=8, block_k=8,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=cap, q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 1, 16, 16, 4, 2, 16)
    q, k, v = q.astype(dtype), k.astype(dtype), v.astype(dtype)
    got = flash_attention(q, k, v, block_q=8, block_k=8, interpret=True)
    want = ref.flash_attention(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_xla_chunked_path_matches_ref():
    """The model-side chunked attention (what the dry-run lowers) is
    numerically identical to the oracle too."""
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, 2, 32, 32, 4, 2, 16)
    got = common.chunked_attention(q, k, v, causal=True, window=8,
                                   cap=30.0, chunk=8)
    want = ref.flash_attention(q, k, v, causal=True, window=8, softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_kv_len_masking():
    """chunked_attention's kv_len masking == truncating the cache."""
    rng = np.random.default_rng(2)
    q, k, v = _qkv(rng, 1, 1, 32, 4, 2, 8)
    got = common.chunked_attention(q, k, v, causal=True, q_offset=19,
                                   kv_len=jnp.int32(20), chunk=8)
    want = ref.flash_attention(q, k[:, :20], v[:, :20], causal=True,
                               q_offset=19)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
