"""Model-component correctness: MoE dispatch, embedding bag, FM identity,
EGNN equivariance, neighbor sampler, decode==forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models import common, egnn as G, embedding, moe as M, recsys as R
from repro.models import sampler as S
from repro.models import transformer as T


def test_moe_expert_parallel_matches_oracle():
    cfg = M.MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=4.0)
    rng = jax.random.key(0)
    params = M.init_moe_params(rng, 16, cfg)
    x = jax.random.normal(rng, (32, 16))
    y_ep, aux = M.moe_apply(params, x, cfg)
    y_oracle = M.moe_apply_dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_oracle),
                               rtol=1e-5, atol=1e-5)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens():
    """With tiny capacity, overflowing tokens are dropped, not corrupted."""
    cfg = M.MoEConfig(n_experts=2, top_k=1, d_expert=8,
                      capacity_factor=0.25)
    params = M.init_moe_params(jax.random.key(0), 4, cfg)
    x = jax.random.normal(jax.random.key(1), (16, 4))
    y, _ = M.moe_apply(params, x, cfg)
    assert np.all(np.isfinite(np.asarray(y)))


def test_embedding_bag_vs_loop():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((50, 8)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, 50, (6, 5)), jnp.int32)
    out = embedding.bag_lookup(table, idx)
    for b in range(6):
        want = sum(np.asarray(table)[i] for i in np.asarray(idx[b]) if i >= 0)
        want = want if isinstance(want, np.ndarray) else np.zeros(8)
        np.testing.assert_allclose(np.asarray(out[b]), want, rtol=1e-6)


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_fm_identity(seed):
    """FM trick ½((Σv)² − Σv²) == Σ_{i<j} <v_i, v_j> (pairwise)."""
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((7, 4))
    fast = 0.5 * ((v.sum(0) ** 2 - (v * v).sum(0))).sum()
    slow = sum(v[i] @ v[j] for i in range(7) for j in range(i + 1, 7))
    np.testing.assert_allclose(fast, slow, rtol=1e-9)


def test_deepfm_fm_term_matches_pairwise():
    cfg = R.DeepFMConfig(n_fields=4, vocab_per_field=10, embed_dim=3,
                         mlp_dims=(8,))
    params = R.deepfm_init(jax.random.key(0), cfg)
    ids = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    idx = np.asarray(ids + np.arange(4) * 10)[0]
    v = np.asarray(params["emb"])[idx]
    want_fm2 = sum(v[i] @ v[j] for i in range(4) for j in range(i + 1, 4))
    # isolate fm2: zero the mlp + linear + bias contributions
    p2 = dict(params)
    p2["lin"] = jnp.zeros_like(params["lin"])
    p2["mlp"] = [dict(w=jnp.zeros_like(l["w"]), b=jnp.zeros_like(l["b"]))
                 for l in params["mlp"]]
    got = float(R.deepfm_logits(p2, ids, cfg)[0])
    np.testing.assert_allclose(got, want_fm2, rtol=1e-5)


def test_egnn_equivariance():
    cfg = G.EGNNConfig(n_layers=3, d_hidden=16, d_feat=8, n_classes=4)
    params = G.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    batch = {
        "node_feat": jnp.asarray(rng.standard_normal((30, 8)), jnp.float32),
        "coords": jnp.asarray(rng.standard_normal((30, 3)), jnp.float32),
        "edges": jnp.asarray(rng.integers(0, 30, (2, 90)), jnp.int32),
    }
    theta = 1.1
    q = np.array([[np.cos(theta), -np.sin(theta), 0],
                  [np.sin(theta), np.cos(theta), 0], [0, 0, 1]], np.float32)
    h1, x1 = G.forward(params, batch, cfg)
    rot = dict(batch)
    rot["coords"] = batch["coords"] @ jnp.asarray(q).T + 7.0
    h2, x2 = G.forward(params, rot, cfg)
    # untrained random MLPs amplify magnitudes (|x| ~ 5e3): compare
    # relatively — equivariance is exact up to f32 rounding
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-3, atol=1e-2)           # invariant
    np.testing.assert_allclose(np.asarray(x1 @ jnp.asarray(q).T + 7.0),
                               np.asarray(x2), rtol=2e-3, atol=1e-2)


def test_neighbor_sampler():
    rng = np.random.default_rng(0)
    edges = rng.integers(0, 100, (2, 600)).astype(np.int64)
    g = S.CSRGraph.from_edges(edges, 100)
    seeds = np.array([3, 14, 15])
    nodes, sub_edges, seed_mask = S.sample_subgraph(
        g, seeds, (5, 3), rng, pad_nodes=80, pad_edges=120)
    assert nodes.shape == (80,) and sub_edges.shape == (2, 120)
    real = nodes[nodes >= 0]
    assert set(seeds) <= set(real.tolist())
    # every sampled edge exists in the original graph — the sampler emits
    # (neighbor -> node), i.e. messages flow INTO the sampled node, so the
    # original CSR edge is (dst, src)
    emap = set(zip(edges[0].tolist(), edges[1].tolist()))
    for s, d in zip(*sub_edges):
        if s < 0:
            continue
        assert (real[d], real[s]) in emap
    # fanout respected: each node contributes <= fanout edges per hop
    assert seed_mask[:len(real)].sum() == len(seeds)


def test_sampler_respects_fanout():
    rng = np.random.default_rng(1)
    edges = np.stack([np.zeros(50, np.int64),
                      np.arange(50, dtype=np.int64)])
    # node 0 has 50 out-neighbors; reverse for sampling from dst
    g = S.CSRGraph.from_edges(edges, 51)
    nodes, sub_edges, _ = S.sample_subgraph(g, np.array([0]), (7,), rng)
    valid = sub_edges[0] >= 0
    assert valid.sum() == 7


def test_decode_matches_forward_with_window():
    cfg = T.TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
        d_ff=64, vocab_size=64, local_window=4, global_every=2,
        dtype="float32")
    params = T.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 12), 0, 64)
    h, _ = T.forward(params, toks, cfg)
    logits_full = h @ T.unembed_matrix(params, cfg).astype(h.dtype)
    cache = T.init_cache(cfg, 1, 16)
    for i in range(12):
        logits_step, cache = T.decode_step(params, cache, toks[:, i:i + 1],
                                           jnp.int32(i), cfg)
    np.testing.assert_allclose(np.asarray(logits_step),
                               np.asarray(logits_full[:, -1]),
                               rtol=1e-3, atol=1e-3)


def test_tiered_retrieval_preserves_topk():
    from repro.core import bitset
    from repro.models.tiered_retrieval import (build_tiered_index,
                                               tiered_retrieval_scores)
    index = build_tiered_index(seed=0, scale="tiny", budget_frac=0.5)
    data = index.data
    rng = np.random.default_rng(0)
    cand = jnp.asarray(rng.standard_normal((data.n_docs, 16)), jnp.float32)
    t1 = jnp.asarray(index.tier1_ids)
    elig_all = index.tiering.classify_queries(data.log.query_bits)
    checked = 0
    for qi in np.nonzero(elig_all)[0][:20]:
        match = jnp.asarray(bitset.np_unpack(data.query_doc_bits[qi],
                                             data.n_docs))
        user = jnp.asarray(rng.standard_normal(16), jnp.float32)
        v1, i1 = tiered_retrieval_scores(user, cand, t1, True, match, k=5)
        v2, i2 = tiered_retrieval_scores(user, cand, t1, False, match, k=5)
        valid = np.asarray(v1) > -np.inf
        np.testing.assert_array_equal(np.asarray(i1)[valid],
                                      np.asarray(i2)[valid])
        checked += 1
    assert checked > 0
