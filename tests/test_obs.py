"""repro.obs telemetry plane: typed instruments + registry semantics, span
nesting, event log, ring bounding, JSONL export round-trips, the uniform
to_dict/from_dict report surface, disabled-path bit-identity (in-process AND
— with 4 fake devices + forced refits — a full `run_ingest` subprocess under
REPRO_OBS=0 vs on), plus the <5% disabled-overhead pin on the serve hot
path."""
import hashlib
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro import obs


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test sees an enabled, empty, exporter-free, rule-free plane —
    and leaves the process-global singletons the way it found them."""
    prev_on = obs.set_enabled(True)
    prev_ex = obs.set_exporter(None)
    obs.SLO.set_rules([])
    obs.reset()
    yield
    obs.reset()
    obs.SLO.set_rules([])
    obs.set_exporter(prev_ex)
    obs.set_enabled(prev_on)


def _fresh_pipe(seed=0):
    from repro import api
    return (api.TieringPipeline.from_synthetic(seed=seed, scale="tiny")
            .mine(min_support=1e-3).solve("greedy", budget_frac=0.5))


def _strip_timing(obj):
    """Drop wall-clock-dependent keys so two deterministic runs compare."""
    if isinstance(obj, dict):
        return {k: _strip_timing(v) for k, v in obj.items()
                if "seconds" not in k and k != "ts"}
    if isinstance(obj, list):
        return [_strip_timing(v) for v in obj]
    return obj


# -- Ring ---------------------------------------------------------------------

def test_ring_bounds_and_drop_accounting():
    r = obs.Ring(3)
    for i in range(7):
        r.append(i)
    assert r.to_list() == [4, 5, 6]
    assert len(r) == 3 and r.n_seen == 7 and r.n_dropped == 4
    assert r[0] == 4 and r[-1] == 6 and r[1:] == [5, 6]
    assert bool(r) and list(r) == [4, 5, 6]


def test_ring_unbounded_and_invalid_capacity():
    r = obs.Ring(None)
    r.extend(range(100))
    assert len(r) == 100 and r.n_dropped == 0
    with pytest.raises(ValueError):
        obs.Ring(0)


# -- registry & instruments ---------------------------------------------------

def test_counter_labels_total_and_monotonicity():
    c = obs.counter("t_words", labels=("tier", "shard"))
    c.inc(5, tier="t1", shard=0)
    c.inc(3, tier="t2", shard=1)
    c.inc(2, tier="t1", shard=0)
    assert c.value(tier="t1", shard=0) == 7
    assert c.total() == 10
    assert obs.REGISTRY.total("t_words") == 10
    with pytest.raises(ValueError, match="monotonic"):
        c.inc(-1, tier="t1", shard=0)
    with pytest.raises(ValueError, match="labels"):
        c.inc(1, tier="t1")                     # missing a label


def test_registry_idempotent_and_conflicts():
    a = obs.counter("t_same", labels=("x",))
    assert obs.counter("t_same", labels=("x",)) is a
    with pytest.raises(ValueError, match="already registered"):
        obs.gauge("t_same")                     # kind conflict
    with pytest.raises(ValueError, match="already registered"):
        obs.counter("t_same", labels=("y",))    # label conflict
    obs.histogram("t_h", buckets=(1.0, 2.0))
    with pytest.raises(ValueError, match="conflicting buckets"):
        obs.histogram("t_h", buckets=(1.0, 3.0))


def test_histogram_observe_percentile_snapshot():
    h = obs.histogram("t_lat", buckets=(1.0, 10.0, 100.0))
    h.observe(0.5)
    h.observe_many([5.0, 5.0, 50.0, 500.0])
    snap = h.snapshot()
    assert snap["counts"] == [1, 2, 1, 1]       # last bucket = overflow
    assert snap["count"] == 5 and snap["min"] == 0.5 and snap["max"] == 500.0
    assert snap["sum"] == pytest.approx(560.5)
    assert 1.0 <= h.percentile(50) <= 10.0
    assert h.percentile(100) == 500.0           # overflow lands on max
    assert obs.histogram("t_empty").percentile(50) != \
        obs.histogram("t_empty").percentile(50)  # NaN on empty


def test_registry_reset_keeps_instrument_identity():
    c = obs.counter("t_keep")
    c.inc(4)
    obs.reset()
    assert c.value() == 0
    assert obs.counter("t_keep") is c
    c.inc(1)                                    # held references still work
    assert obs.REGISTRY.total("t_keep") == 1


# -- spans & events -----------------------------------------------------------

def test_span_nesting_parent_depth_and_dict():
    with obs.span("outer", n=2) as a:
        with obs.span("inner") as b:
            b.set(hits=3)
        assert b.parent == a.id and b.depth == a.depth + 1
    recs = obs.SPANS.to_list()                  # finished spans, as dicts
    assert [r["name"] for r in recs] == ["inner", "outer"]  # exit order
    d = recs[0]
    assert d["name"] == "inner" and d["hits"] == 3
    assert d["wall_ms"] >= 0.0 and d["parent"] == a.id
    assert d["depth"] == 1 and recs[1]["depth"] == 0
    assert {"id", "parent", "depth", "t0_s", "wall_ms", "sync_ms"} <= set(d)
    assert obs.SPANS.of_name("inner") == [d]
    assert obs.SPANS.children(a.id) == [d]


def test_span_sync_passes_through_host_values():
    with obs.span("s") as sp:
        assert sp.sync([1, 2, 3]) == [1, 2, 3]  # non-JAX values untouched
        arr = sp.sync(np.arange(3))
        np.testing.assert_array_equal(arr, [0, 1, 2])


def test_event_log_and_cursors():
    obs.event("alpha", x=1)
    seq = obs.EVENTS.seq
    obs.event("beta", y=2)
    since = obs.EVENTS.since(seq)
    assert [e["kind"] for e in since] == ["beta"]
    assert since[0]["y"] == 2 and "t_s" in since[0]
    assert [e["kind"] for e in obs.EVENTS.of_kind("alpha")] == ["alpha"]


def test_disabled_plane_is_noop():
    obs.set_enabled(False)
    sp = obs.span("anything", n=1)
    assert sp is obs.NULL_SPAN                  # shared singleton: no alloc
    with sp as s:
        s.set(x=1)
        assert s.sync("v") == "v"
    assert obs.event("nothing") is None
    c = obs.counter("t_off")
    c.inc(5)
    g = obs.gauge("t_off_g")
    g.set(3.0)
    h = obs.histogram("t_off_h")
    h.observe(1.0)
    assert c.total() == 0 and g.value() is None and h.snapshot()["count"] == 0
    assert len(obs.SPANS.ring) == 0 and len(obs.EVENTS) == 0
    # ... but a detached always=True instrument records regardless
    d = obs.Histogram("t_detached", always=True, buckets=(1.0, 2.0))
    d.observe(1.5)
    assert d.snapshot()["count"] == 1


# -- render -------------------------------------------------------------------

def test_render_line_formatting():
    from repro.obs.render import render_line
    line = render_line("tag", [("@head", "3 windows"), ("cov", 0.5),
                               ("ok", True), ("bad", False),
                               ("skip", None), ("xs", [1, 2])])
    assert line == "tag  3 windows  cov=0.500  ok=ok  bad=FAIL  xs=[1,2]"


# -- export -------------------------------------------------------------------

def test_jsonl_exporter_round_trip(tmp_path):
    ex = obs.JsonlExporter(tmp_path, run="r1")
    ex.export({"window": 0, "v": np.int64(3), "a": np.arange(2)})
    ex.export({"window": 1, "v": 4, "a": []})
    snaps = obs.read_jsonl(ex.path)
    assert [s["window"] for s in snaps] == [0, 1]
    assert snaps[0]["v"] == 3 and snaps[0]["a"] == [0, 1]
    assert obs.load_dir(tmp_path) == {"r1": snaps}
    # a named run restarts its file on re-construction
    obs.JsonlExporter(tmp_path, run="r1").export({"window": 9})
    assert [s["window"] for s in obs.read_jsonl(ex.path)] == [9]


def test_export_window_cursors_and_gating(tmp_path):
    assert obs.export_window(0) is None         # no exporter installed: no-op
    obs.set_exporter(obs.JsonlExporter(tmp_path, run="w"))
    with obs.span("s1"):
        pass
    obs.event("e1")
    snap0 = obs.export_window(0)
    with obs.span("s2"):
        pass
    snap1 = obs.export_window(1, extra_key="x")
    assert [s["name"] for s in snap0["spans"]] == ["s1"]
    assert [s["name"] for s in snap1["spans"]] == ["s2"]   # cursor advanced
    assert [e["kind"] for e in snap0["events"]] == ["e1"]
    assert snap1["events"] == [] and snap1["extra_key"] == "x"
    snaps = obs.read_jsonl(obs.get_exporter().path)
    assert len(snaps) == 2
    for s in snaps:
        assert {"window", "ts", "metrics", "spans", "events"} <= set(s)
    obs.set_enabled(False)
    assert obs.export_window(2) is None         # disabled: no write
    assert len(obs.read_jsonl(obs.get_exporter().path)) == 2


def test_launch_obs_check_gate():
    from repro.launch.obs import check
    good = {"r": [{"window": 0, "ts": 0.0, "events": [], "spans": [],
                   "metrics": {"m": {"type": "counter", "series": [
                       {"labels": {}, "value": 3}]}}}]}
    assert check(good, ["m"]) == 0
    assert check(good, ["missing_metric"]) == 1
    assert check({}, []) == 1                   # no runs at all
    assert check({"r": [{"window": 0}]}, []) == 1   # missing required keys


def test_launch_obs_check_max_dropped_frac():
    from repro.launch.obs import check

    def run(spans_seen, spans_dropped):
        return {"r": [{"window": 0, "ts": 0.0, "events": [], "spans": [],
                       "metrics": {},
                       "rings": {"spans": {"n_seen": spans_seen,
                                           "n_dropped": spans_dropped},
                                 "events": {"n_seen": 0, "n_dropped": 0}}}]}

    assert check(run(100, 10), [], max_dropped_frac=0.5) == 0
    assert check(run(100, 60), [], max_dropped_frac=0.5) == 1
    assert check(run(0, 0), [], max_dropped_frac=0.0) == 0
    # a snapshot without the rings block can't prove retention: fail
    legacy = {"r": [{"window": 0, "ts": 0.0, "events": [], "spans": [],
                     "metrics": {}}]}
    assert check(legacy, [], max_dropped_frac=0.5) == 1
    assert check(legacy, []) == 0               # ... unless the flag is off


def test_snapshot_rings_and_empty_window(tmp_path):
    obs.set_exporter(obs.JsonlExporter(tmp_path, run="rings"))
    empty = obs.export_window(0)                # no activity at all: valid
    assert empty["spans"] == [] and empty["events"] == []
    assert empty["slo"] == {}                   # no rules installed
    assert empty["rings"]["spans"] == {"n_seen": 0, "n_dropped": 0}
    from repro.obs.events import DEFAULT_EVENT_CAPACITY
    n = DEFAULT_EVENT_CAPACITY + 50
    for i in range(n):
        obs.event("flood", i=i)
    dropped = obs.export_window(1)
    assert dropped["rings"]["events"] == {"n_seen": n, "n_dropped": 50}
    # the payload round-trips through JSONL read/load_dir intact
    snaps = obs.read_jsonl(obs.get_exporter().path)
    assert obs.load_dir(tmp_path) == {"rings": snaps}
    assert [s["window"] for s in snaps] == [0, 1]
    assert snaps[1]["rings"]["events"]["n_dropped"] == 50
    assert snaps[0]["rings"] == empty["rings"]
    for s in snaps:
        assert {"window", "ts", "metrics", "spans", "events",
                "slo", "rings"} <= set(s)


# -- uniform report dict surface ----------------------------------------------

def test_serve_stats_round_trip():
    from repro.serve.engine import ServeStats
    s = ServeStats(n_queries=10, n_tier1=6, tier1_words=120, tier2_words=400,
                   full_words_per_query=100)
    d = s.to_dict()
    assert d["tier1_fraction"] == pytest.approx(0.6)
    assert 0.0 < d["cost_saving"] <= 1.0        # derived keys exported...
    assert ServeStats.from_dict(d) == s         # ...and ignored on the way in


def test_stream_and_ingest_report_round_trips():
    from repro import ingest, stream
    from repro.ingest.controller import IngestReport, IngestWindowReport
    from repro.stream.controller import StreamReport, WindowReport
    pipe = _fresh_pipe()
    rep = stream.run_stream(pipe, scenario="rotate", n_windows=2,
                            queries_per_window=64, seed=0)
    rt = StreamReport.from_dict(rep.to_dict())
    assert rt.to_dict() == rep.to_dict()
    assert isinstance(rt.windows[0], WindowReport)
    assert rt.summary() == rep.summary()
    irep = ingest.run_ingest(_fresh_pipe(), scenario="rotate", n_windows=2,
                             queries_per_window=64, seed=0,
                             arrivals_per_window=8.0)
    irt = IngestReport.from_dict(irep.to_dict())
    assert irt.to_dict() == irep.to_dict()
    assert isinstance(irt.windows[0], IngestWindowReport)
    assert irt.windows[0].line() == irep.windows[0].line()
    assert irt.summary() == irep.summary()


def test_loadgen_hist_and_round_trip_and_switch_independence():
    from repro import cluster
    from repro.cluster.loadgen import LoadgenReport
    pipe = _fresh_pipe()
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2)
    plan = cluster.ClusterPlan.of_cluster(fleet)
    elig = fleet.classify(pipe.log.queries[:256])

    def run():
        return cluster.run_loadgen(plan, elig, n_queries=1000, seed=0)

    rep = run()
    hist = rep.latency_hist
    assert sum(hist["counts"]) == hist["count"] == 1000
    assert hist["min"] <= rep.p50_ms <= rep.p95_ms <= hist["max"]
    assert LoadgenReport.from_dict(rep.to_dict()).to_dict() == rep.to_dict()
    # the histogram is detached (always=True): REPRO_OBS must not change it
    obs.set_enabled(False)
    assert run().to_dict() == rep.to_dict()


# -- BatchTrace bounding ------------------------------------------------------

def test_cluster_trace_ring_bounding():
    from repro.cluster.router import DEFAULT_TRACE_CAPACITY
    pipe = _fresh_pipe()
    fleet = pipe.deploy_cluster(n_shards=2, trace_capacity=4)
    batch = pipe.log.queries[:16]
    for _ in range(6):
        fleet.serve(batch)
    assert len(fleet.trace) == 4                # last 4 batches survive
    assert fleet.trace.n_seen == 6 and fleet.trace.n_dropped == 2
    assert fleet.consistency_ok()               # checks run on the window
    unbounded = pipe.deploy_cluster(n_shards=2, trace_capacity=None)
    for _ in range(3):
        unbounded.serve(batch)
    assert len(unbounded.trace) == 3 and unbounded.trace.n_dropped == 0
    default = pipe.deploy_cluster(n_shards=2)
    default.serve(batch)
    assert default.trace.capacity == DEFAULT_TRACE_CAPACITY


# -- instrumented call sites --------------------------------------------------

def test_engine_serve_spans_metrics_and_bit_identity():
    pipe = _fresh_pipe()
    queries = pipe.log.queries[:64]
    engine = pipe.deploy()
    on = engine.serve(queries)
    spans = {s["name"]: s for s in obs.SPANS.to_list()}
    assert {"serve", "classify", "merge"} <= set(spans)
    assert "t1_match" in spans or "t2_match" in spans
    for name in ("classify", "merge"):
        assert spans[name]["parent"] == spans["serve"]["id"]
    assert obs.REGISTRY.total("serve_queries_total") == 64
    assert obs.REGISTRY.total("serve_words_total") > 0
    # identical serve with the plane off — results and stats bit-equal
    obs.set_enabled(False)
    engine_off = pipe.deploy()
    off = engine_off.serve(queries)
    for a, b in zip(on, off):
        np.testing.assert_array_equal(a, b)
    assert engine.stats.to_dict() == engine_off.stats.to_dict()
    assert obs.REGISTRY.total("serve_queries_total") == 64   # no new counts


def test_cluster_serve_per_shard_counters_and_events():
    pipe = _fresh_pipe()
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2)
    fleet.serve(pipe.log.queries[:64])
    c = obs.REGISTRY.get("cluster_words_total")
    shards = {s["labels"]["shard"] for s in c.to_dict()["series"]}
    assert shards == {"0", "1"}
    from repro.core import SOLVERS
    from repro.core.tiering import ClauseTiering
    r2 = SOLVERS["greedy"](pipe.problem, int(pipe.data.n_docs * 0.25))
    fleet.swap_tiering(ClauseTiering.from_selection(pipe.data, r2.selected),
                       immediate=True)
    assert obs.EVENTS.of_kind("rollout_begin")
    assert obs.EVENTS.of_kind("rollout_done")
    assert obs.EVENTS.of_kind("replica_swap")   # per-replica commits


def test_run_stream_bit_identical_with_plane_off():
    kw = dict(scenario="rotate", n_windows=3, queries_per_window=96, seed=0)
    from repro import stream
    on = stream.run_stream(_fresh_pipe(), **kw)
    assert obs.REGISTRY.total("serve_queries_total") > 0
    assert len(obs.SPANS.ring) > 0
    obs.set_enabled(False)
    off = stream.run_stream(_fresh_pipe(), **kw)
    assert _strip_timing(on.to_dict()) == _strip_timing(off.to_dict())


def test_solver_trace_emits_solve_event():
    _fresh_pipe()
    ev = obs.EVENTS.of_kind("solve_done")
    assert ev and ev[-1]["solver"] == "greedy"
    assert ev[-1]["n_selections"] > 0 and ev[-1]["f_final"] > 0
    assert obs.REGISTRY.total("solver_selections_total") > 0


# -- kernel profiler (repro.obs.profile) --------------------------------------

def test_kernel_profiler_counters_and_measuring():
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 2 ** 32, (64, 8), dtype=np.uint32))
    mask = jnp.asarray(rng.integers(0, 2 ** 32, 8, dtype=np.uint32))
    on = np.asarray(ops.coverage_gain(a, mask))
    assert obs.REGISTRY.total("kernel_words_scanned_total") == 64 * 8
    assert obs.REGISTRY.total("kernel_bytes_moved_total") > 0
    assert obs.PROFILER.summary() == []         # not measuring: no sync rows
    with obs.PROFILER.measuring():
        ops.coverage_gain(a, mask)
        ops.coverage_gain(a, mask)
    rows = obs.PROFILER.summary()
    assert [(r["op"], r["path"], r["calls"]) for r in rows] == \
        [("coverage_gain", "xla", 2)]
    r = rows[0]
    assert r["words_scanned"] == 2 * 64 * 8
    assert r["achieved_gbps"] > 0.0 and r["roofline_frac"] > 0.0
    assert r["roofline_frac"] == pytest.approx(
        r["achieved_gbps"] / (obs.HBM_BW / 1e9), abs=1e-6)  # 6-dp rounding
    obs.reset()
    assert obs.PROFILER.summary() == []         # reset drops the aggregation
    # disabled: dispatch records nothing and the result stays bit-identical
    obs.set_enabled(False)
    off = np.asarray(ops.coverage_gain(a, mask))
    np.testing.assert_array_equal(on, off)
    assert obs.REGISTRY.total("kernel_words_scanned_total") == 0
    with obs.PROFILER.measuring():
        ops.coverage_gain(a, mask)
    assert obs.PROFILER.summary() == []


def test_kernel_profiler_labels_every_public_op():
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(0, 2 ** 32, (32, 4), dtype=np.uint32))
    x = jnp.asarray(rng.standard_normal((4 * 32, 2)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2 ** 32, 4, dtype=np.uint32))
    q = jnp.asarray(rng.integers(0, 2 ** 32, (8, 4), dtype=np.uint32))
    ids = jnp.asarray(rng.integers(0, 50, (16, 6)), jnp.int32)
    ops.bit_matvec(a, x)
    ops.coverage_gain(a, mask)
    ops.clause_match(q, a[:3])
    ops.partition_gain(a, mask, (0, 2, 4))
    ops.sparse_gain(ids, jnp.zeros(50, bool))
    c = obs.REGISTRY.get("kernel_words_scanned_total")
    by_op = {s["labels"]["op"]: s["value"] for s in c.to_dict()["series"]}
    assert set(by_op) == {"bit_matvec", "coverage_gain", "clause_match",
                          "partition_gain", "sparse_gain"}
    assert by_op["bit_matvec"] == 32 * 4
    assert by_op["partition_gain"] == 32 * 4 + 4
    # the empty-operand clause_match early return never dispatches
    before = by_op["clause_match"]
    ops.clause_match(q, a[:0])
    c2 = {s["labels"]["op"]: s["value"]
          for s in c.to_dict()["series"]}["clause_match"]
    assert c2 == before


# -- SLO engine over live windows ---------------------------------------------

def test_slo_disabled_is_complete_noop():
    obs.SLO.set_rules(obs.default_slo_rules())
    obs.set_enabled(False)
    assert obs.SLO.evaluate(0) == {}
    assert obs.SLO.breached() == []
    assert obs.REGISTRY.total("slo_breaches_total") == 0


def test_slo_breach_and_recover_deterministic(tmp_path):
    """A seeded loadgen overload window against a tightened p95 rule must
    produce exactly slo_breach -> slo_recovered, in the JSONL payload, the
    EventLog, the breach counter, and the dashboard segment."""
    from repro import cluster
    pipe = _fresh_pipe()
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2)
    plan = cluster.ClusterPlan.of_cluster(fleet)
    elig = fleet.classify(pipe.log.queries[:256])
    obs.set_exporter(obs.JsonlExporter(tmp_path, run="slo"))
    obs.SLO.set_rules([obs.SLORule(
        "p95_tight", "p95:loadgen_latency_ms", max=1.0,
        fast_windows=1, slow_windows=4, slow_burn=0.25, clear_windows=2)])

    def window(i, qps):
        cluster.run_loadgen(plan, elig, rate_qps=qps, n_queries=400, seed=i)
        return obs.export_window(i)

    s0 = window(0, 1e6)       # open-loop overload: queueing blows the tail
    assert s0["slo"]["rules"]["p95_tight"]["bad"] is True
    assert s0["slo"]["breached"] == ["p95_tight"]
    assert [e["rule"] for e in s0["events"]
            if e["kind"] == "slo_breach"] == ["p95_tight"]
    assert "slo=BREACH(p95_tight)" in obs.dashboard()
    assert obs.REGISTRY.total("slo_breaches_total") == 1

    s1 = window(1, 50.0)      # light load: good, but hysteresis holds
    assert s1["slo"]["rules"]["p95_tight"]["bad"] is False
    assert s1["slo"]["breached"] == ["p95_tight"]
    s2 = window(2, 50.0)      # second consecutive good window: recovered
    assert s2["slo"]["breached"] == []
    assert [e["rule"] for e in s2["events"]
            if e["kind"] == "slo_recovered"] == ["p95_tight"]
    assert "slo=ok(1)" in obs.dashboard()
    assert obs.REGISTRY.total("slo_breaches_total") == 1   # transitions only

    snaps = obs.read_jsonl(obs.get_exporter().path)
    kinds = [(s["window"], e["kind"]) for s in snaps for e in s["events"]
             if e["kind"].startswith("slo_")]
    assert kinds == [(0, "slo_breach"), (2, "slo_recovered")]
    # primed series: the counter exports even for never-breached rules
    series = snaps[-1]["metrics"]["slo_breaches_total"]["series"]
    assert {s["labels"]["rule"]: s["value"]
            for s in series} == {"p95_tight": 1}


# -- disabled-path overhead pin ----------------------------------------------

def test_disabled_overhead_under_5pct():
    """serve/engine.py's exact hot-path wrapping (span + sync + counter inc)
    must cost <5% over bare `match_batch` when the plane is off."""
    import jax.numpy as jnp
    from repro.serve import matching
    rng = np.random.default_rng(0)
    postings = jnp.asarray(
        rng.integers(0, 2 ** 32, (1024, 128), dtype=np.uint32))
    toks = jnp.asarray(rng.integers(0, 1024 * 32, (128, 8)), np.int32)
    ctr = obs.counter("t_overhead")

    def plain():
        np.asarray(matching.match_batch(postings, toks))

    def wrapped():
        with obs.span("t1_match", n=128) as sp:
            sp.sync(matching.match_batch(postings, toks))
        ctr.inc(128)

    def best(fn, iters=20, reps=5):
        fn()                                    # warm/compile
        out = []
        for _ in range(reps):
            t0 = time.perf_counter()
            for _ in range(iters):
                fn()
            out.append((time.perf_counter() - t0) / iters)
        return min(out)

    obs.set_enabled(False)
    t_plain = best(plain)
    t_obs = best(wrapped)
    assert t_obs <= t_plain * 1.05 + 5e-5, \
        f"disabled-path overhead: plain={t_plain * 1e6:.1f}us " \
        f"obs={t_obs * 1e6:.1f}us (+{(t_obs / t_plain - 1) * 100:.1f}%)"
    assert ctr.total() == 0                     # it really was off


# -- acceptance: forced-4-device ingest run, obs on vs REPRO_OBS=0 ------------

ACCEPT_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import hashlib, json
import jax
import numpy as np
from repro import api, distributed as D, ingest, obs, stream

assert len(jax.devices()) == 4
out_dir = sys.argv[1]
if obs.enabled():
    obs.set_exporter(obs.JsonlExporter(out_dir, run="accept"))

pipe = (api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
        .mine(min_support=1e-3).solve("greedy", budget_frac=0.5))
fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2, t2_replicas=2)
# coverage_drop=-1 forces the drift trigger every eligible window, so the
# 3-window run deterministically produces drift/refit/swap events
report = ingest.run_ingest(
    pipe, scenario="rotate", n_windows=3, queries_per_window=192, seed=0,
    arrivals_per_window=24.0, engine=fleet,
    detector=stream.DriftDetector(coverage_drop=-1.0, warmup_windows=0,
                                  min_windows_between=0))
assert report.n_refits >= 1 and report.n_ingested >= 1


def strip(o):
    if isinstance(o, dict):
        return {k: strip(v) for k, v in o.items() if "seconds" not in k}
    if isinstance(o, list):
        return [strip(v) for v in o]
    return o


queries = pipe.log.queries[:64]
digest = {"report": strip(report.to_dict()),
          "stats": strip(fleet.stats.to_dict()),
          "trace": [(t.psi_generation, t.n_tier1, t.n_tier2)
                    for t in fleet.trace],
          "matches": [np.asarray(m).tolist() for m in fleet.serve(queries)]}
host2 = pipe.deploy_cluster(n_shards=2, t1_replicas=2, t2_replicas=2)
a = host2.serve(queries)
mesh_fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2, t2_replicas=2)
with D.use_mesh(D.shard_mesh()):
    b = mesh_fleet.serve(queries)
for x, y in zip(a, b):
    np.testing.assert_array_equal(x, y)
digest["mesh"] = [np.asarray(m).tolist() for m in b]

if obs.enabled():
    snaps = obs.read_jsonl(obs.get_exporter().path)
    assert len(snaps) == 3, len(snaps)
    for s in snaps:
        assert {"window", "ts", "metrics", "spans", "events"} <= set(s)
    words = snaps[-1]["metrics"]["cluster_words_total"]["series"]
    combos = {(s["labels"]["tier"], s["labels"]["shard"]) for s in words}
    assert {("t1", "0"), ("t1", "1"), ("t2", "0"),
            ("t2", "1")} <= combos, combos
    spans = [sp for s in snaps for sp in s["spans"]]
    serves = [sp for sp in spans if sp["name"] == "serve"]
    nested = False
    for sv in serves:
        kids = {sp["name"] for sp in spans if sp["parent"] == sv["id"]}
        if {"classify", "t1_match", "merge"} <= kids:
            assert sv["wall_ms"] >= 0.0 and sv["depth"] == 0
            nested = True
    assert nested, "no serve span nesting classify/t1_match/merge"
    kinds = {e["kind"] for s in snaps for e in s["events"]}
    assert {"drift_detected", "refit", "corpus_swap"} <= kinds, kinds
    mesh_spans = obs.SPANS.of_name("mesh_fused")
    assert mesh_spans and mesh_spans[-1]["sync_ms"] >= 0.0
    print("OBS-ACCEPT-OK")

blob = json.dumps(digest, sort_keys=True, default=float)
print("DIGEST=" + hashlib.sha256(blob.encode()).hexdigest())
print("INGEST-OBS-DONE")
"""


def _run_accept(tmp_path, obs_env):
    env = {"PYTHONPATH": "src", "PATH": os.environ.get(
        "PATH", "/usr/bin:/bin"), "HOME": os.environ.get("HOME", "/root")}
    if obs_env is not None:
        env["REPRO_OBS"] = obs_env
    out = subprocess.run(
        [sys.executable, "-c", ACCEPT_SCRIPT, str(tmp_path)],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=900)
    assert "INGEST-OBS-DONE" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    digest = [ln for ln in out.stdout.splitlines()
              if ln.startswith("DIGEST=")][0]
    return out.stdout, digest


def test_ingest_obs_acceptance_4dev_and_off_bit_identity(tmp_path):
    stdout_on, digest_on = _run_accept(tmp_path, None)
    assert "OBS-ACCEPT-OK" in stdout_on
    assert os.path.exists(tmp_path / "accept.jsonl")
    _, digest_off = _run_accept(tmp_path, "0")
    assert digest_on == digest_off              # REPRO_OBS=0: bit-identical
