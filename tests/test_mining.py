import numpy as np
from hypothesis_compat import given, settings, st

from repro.data import mining


@given(st.integers(0, 100_000))
@settings(max_examples=25, deadline=None)
def test_fpgrowth_matches_brute_force(seed):
    rng = np.random.default_rng(seed)
    n_tx = int(rng.integers(5, 40))
    txs, ws = [], []
    for _ in range(n_tx):
        k = int(rng.integers(1, 6))
        txs.append(tuple(sorted(set(rng.integers(0, 12, size=k).tolist()))))
        ws.append(float(rng.integers(1, 5)))
    min_support = float(rng.uniform(0.5, 4.0))
    got = mining.fpgrowth(txs, ws, min_support, max_len=3)
    want = mining.brute_force_frequent(txs, ws, min_support, max_len=3)
    assert set(got) == set(want)
    for clause, sup in want.items():
        assert abs(got[clause] - sup) < 1e-9, clause


def test_fpgrowth_weighted_probabilities():
    txs = [(0, 1), (0, 2), (0, 1, 2)]
    ws = [0.5, 0.3, 0.2]
    out = mining.fpgrowth(txs, ws, 0.19, max_len=2)
    assert abs(out[(0,)] - 1.0) < 1e-12
    assert abs(out[(0, 1)] - 0.7) < 1e-12
    assert abs(out[(1, 2)] - 0.2) < 1e-12


def test_fpgrowth_max_len():
    txs = [(0, 1, 2, 3)] * 3
    out = mining.fpgrowth(txs, None, 1.0, max_len=2)
    assert max(len(c) for c in out) == 2
