"""Trainer, optimizer, checkpoint/restart, elastic re-shard, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import CompressionConfig, quantized_psum
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.trainer import DriverConfig, TrainingDriver, make_train_step


def _quadratic_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    loss = jnp.mean((pred - batch["y"]) ** 2)
    return loss, {"mse": loss}


def _make_batch(rng, n=64, d=8):
    x = rng.standard_normal((n, d)).astype(np.float32)
    w_true = np.arange(d, dtype=np.float32)
    y = x @ w_true + 0.1
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def _params(d=8):
    return {"w": jnp.zeros(d), "b": jnp.zeros(())}


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor", "sgd"])
def test_optimizers_reduce_loss(opt_name):
    rng = np.random.default_rng(0)
    batch = _make_batch(rng)
    lr = 0.2 if opt_name == "sgd" else 0.1
    init_state, train_step = make_train_step(
        _quadratic_loss,
        OptimizerConfig(name=opt_name, lr=lr, warmup_steps=1,
                        weight_decay=0.0,
                        grad_clip=0.0 if opt_name == "sgd" else 1.0))
    state = init_state(_params())
    step = jax.jit(train_step)
    first = None
    for _ in range(100):
        state, m = step(state, batch)
        first = first or float(m["loss"])
    assert float(m["loss"]) < 0.2 * first


def test_grad_accumulation_matches_full_batch():
    rng = np.random.default_rng(1)
    batch = _make_batch(rng, n=64)
    micro = {k: v.reshape(4, 16, *v.shape[1:]) for k, v in batch.items()}
    opt = OptimizerConfig(name="sgd", lr=0.1, warmup_steps=1, grad_clip=0.0)
    i1, s1 = make_train_step(_quadratic_loss, opt)
    i4, s4 = make_train_step(_quadratic_loss, opt, n_micro=4)
    st1, _ = jax.jit(s1)(i1(_params()), batch)
    st4, _ = jax.jit(s4)(i4(_params()), micro)
    np.testing.assert_allclose(st1["params"]["w"], st4["params"]["w"],
                               rtol=1e-5)


def test_adamw_bf16_states():
    init_state, train_step = make_train_step(
        _quadratic_loss, OptimizerConfig(name="adamw", state_dtype="bfloat16"))
    state = init_state(_params())
    assert state["opt"]["m"]["w"].dtype == jnp.bfloat16


def test_adafactor_factored_shapes():
    opt = make_optimizer(OptimizerConfig(name="adafactor",
                                         min_dim_factored=4))
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros(16)}
    st = opt.init(params)
    assert st["fac"]["w"]["vr"].shape == (8,)
    assert st["fac"]["w"]["vc"].shape == (16,)
    assert st["fac"]["b"]["v"].shape == (16,)


# -----------------------------------------------------------------------------
# checkpointing / fault tolerance
# -----------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"params": _params(), "step": jnp.int32(7),
             "nested": {"a": jnp.arange(5)}}
    ckpt.save(str(tmp_path), 7, state, extra={"note": "hi"})
    step, restored, extra = ckpt.restore(str(tmp_path), state)
    assert step == 7 and extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), b)


def test_checkpoint_detects_corruption(tmp_path):
    state = {"w": jnp.arange(10, dtype=jnp.float32)}
    path = ckpt.save(str(tmp_path), 1, state)
    # corrupt the npz payload
    npz = os.path.join(path, "arrays.npz")
    data = dict(np.load(npz))
    data["w"] = data["w"] + 1
    np.savez(npz, **data)
    with pytest.raises(IOError, match="checksum"):
        ckpt.restore(str(tmp_path), state)


def test_checkpoint_gc_keeps_last(tmp_path):
    state = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, state, keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    remaining = sorted(d for d in os.listdir(tmp_path))
    assert len(remaining) == 2


def test_driver_restart_after_injected_failure(tmp_path):
    """Train 30 steps with a crash at step 20: the relaunched driver resumes
    from the last checkpoint and finishes; loss history is contiguous."""
    rng = np.random.default_rng(2)
    batch = _make_batch(rng)

    def batches():
        while True:
            yield batch

    init_state, train_step = make_train_step(
        _quadratic_loss, OptimizerConfig(name="sgd", lr=0.05, warmup_steps=1))
    cfg = DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=10, max_steps=30,
                       fail_at_step=20)
    driver = TrainingDriver(init_state, train_step, cfg)
    with pytest.raises(RuntimeError, match="injected failure"):
        driver.run(_params, batches())
    assert ckpt.latest_step(str(tmp_path)) == 20

    cfg2 = DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=10, max_steps=30)
    driver2 = TrainingDriver(init_state, train_step, cfg2)
    state, history = driver2.run(_params, batches())
    assert int(state["step"]) == 30
    assert len(history) == 10          # resumed at 20, ran 10 more


def test_elastic_reshard_roundtrip(tmp_path):
    """Save under one sharding, restore under a different mesh layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mesh_a = jax.make_mesh((1,), ("data",))
    sharded = jax.device_put(state["w"],
                             NamedSharding(mesh_a, P("data", None)))
    ckpt.save(str(tmp_path), 3, {"w": sharded})
    _, restored, _ = ckpt.restore(str(tmp_path), {"w": state["w"]})
    mesh_b = jax.make_mesh((1, 1), ("x", "y"))
    replaced = jax.device_put(restored["w"],
                              NamedSharding(mesh_b, P(None, "y")))
    np.testing.assert_array_equal(np.asarray(replaced), np.asarray(state["w"]))


def test_straggler_policy_skips_slow_batches(tmp_path):
    import itertools
    import time as _t
    rng = np.random.default_rng(3)
    batch = _make_batch(rng)

    def batches():
        for i in itertools.count():
            if i == 2:
                _t.sleep(0.05)       # one straggler
            yield batch

    init_state, train_step = make_train_step(
        _quadratic_loss, OptimizerConfig(name="sgd", lr=0.01))
    cfg = DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=100, max_steps=5,
                       batch_deadline_s=0.02)
    driver = TrainingDriver(init_state, train_step, cfg)
    state, history = driver.run(_params, batches())
    assert driver.straggler.skipped >= 1
    assert int(state["step"]) == 5


# -----------------------------------------------------------------------------
# gradient compression
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compressed_training_converges(kind):
    rng = np.random.default_rng(4)
    batch = _make_batch(rng)
    init_state, train_step = make_train_step(
        _quadratic_loss,
        OptimizerConfig(name="sgd", lr=0.05, warmup_steps=1),
        compression=CompressionConfig(kind=kind, topk_frac=0.5))
    state = init_state(_params())
    step = jax.jit(train_step)
    first = None
    for _ in range(200):
        state, m = step(state, batch)
        first = first or float(m["loss"])
    # sparsified/quantized grads + EF converge, just slower than exact
    assert float(m["loss"]) < 0.6 * first


def test_error_feedback_accumulates():
    cfg = CompressionConfig(kind="topk", topk_frac=0.34)
    from repro.distributed.compression import compress_grads, init_error_state
    grads = {"w": jnp.asarray([1.0, 0.5, 0.01])}
    ef = init_error_state(cfg, grads)
    comp, ef = compress_grads(cfg, grads, ef)
    assert float(comp["w"][0]) == 1.0
    assert float(comp["w"][2]) == 0.0           # dropped...
    assert float(ef["ef"]["w"][2]) == pytest.approx(0.01)  # ...but remembered
    comp2, ef = compress_grads(cfg, {"w": jnp.zeros(3)}, ef)
    # with zero new grads the error keeps accumulating, not vanishing
    assert float(ef["ef"]["w"][2]) > 0 or float(comp2["w"][2]) > 0


def test_quantized_psum_single_device():
    mesh = jax.make_mesh((1,), ("data",))
    from repro.models.moe import shard_map
    from jax.sharding import PartitionSpec as P
    x = jnp.asarray([1.0, -3.0, 0.5])
    out = shard_map(lambda v: quantized_psum(v, "data"), mesh,
                    in_specs=(P(),), out_specs=P())(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=3 / 127)
